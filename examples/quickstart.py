"""Quickstart: build a cgRX index, run point/range lookups, apply updates.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import jax.numpy as jnp

from repro.core import cgrx, footprint, nodes
from repro.data import keygen


def main(n: int = 100_000, lookups: int = 10_000) -> None:
    # 1. Paper workload: 50% dense / 50% uniform 32-bit keys.
    keys, rows, raw = keygen.keyset(n, uniformity=0.5, bits=32, seed=0)
    print(f"key set: {len(raw):,} keys, uniformity 50%")

    # 2. Build the coarse-granular index (bucket size 16 — the paper's
    #    recommendation, Sec. 5.4).
    idx = cgrx.build(keys, jnp.asarray(rows), bucket_size=16)
    fp = footprint.footprint(idx)
    print(f"cgRX built: {idx.num_buckets:,} buckets, "
          f"{fp['total_bytes']/1e6:.1f} MB "
          f"(reps {fp['rep_bytes']/1e6:.2f} MB, "
          f"tree {fp['tree_bytes']/1e3:.1f} KB)")

    # 3. Point lookups.
    q_raw = keygen.uniform_lookups(raw, lookups, seed=1)
    res = cgrx.lookup(idx, keygen.as_keys(q_raw, 32))
    assert bool(res.found.all())
    assert (raw[np.asarray(res.row_id)] == q_raw).all()
    print(f"{lookups:,} point lookups: all hit, rowIDs verified")

    # 4. Range lookup: one successor search + sequential scan (Sec. 3.2).
    sraw = np.sort(raw)
    lo, hi = keygen.range_lookups(sraw, 4, 64, seed=2)
    rr = cgrx.range_lookup(idx, keygen.as_keys(lo, 32),
                           keygen.as_keys(hi, 32), max_hits=64)
    print(f"range lookups: counts={np.asarray(rr.count).tolist()}")

    # 5. Batched serving: plan mixed point/range traffic into padded
    #    lanes and serve the whole batch in ONE device call (repro.query).
    from repro.query import QueryBatch, RankEngine

    engine = RankEngine(idx)                       # backend = build method
    plan = (QueryBatch()
            .add_points(keygen.as_keys(q_raw[:256], 32))
            .add_ranges(keygen.as_keys(lo, 32), keygen.as_keys(hi, 32))
            .plan(max_hits=64))
    batch_res = engine.execute(plan)
    assert bool(batch_res.points.found.all())
    print(f"batched engine: {plan.n_point} points + {plan.n_range} ranges "
          f"in one call ({plan.lanes} lanes, backend '{engine.backend_name}')")

    # 6. Updates via the node-chain variant (Sec. 4): the search structure
    #    is immutable; buckets grow bucket-locally.
    store = nodes.build(keys, jnp.asarray(rows), node_cap=32)
    ins = np.setdiff1d(np.arange(raw.max() + 1, raw.max() + 1001,
                                 dtype=np.uint64), raw)
    store = nodes.apply_batch(
        store, keygen.as_keys(ins, 32),
        jnp.arange(len(raw), len(raw) + len(ins), dtype=jnp.int32), None)
    r = nodes.lookup(store, keygen.as_keys(ins, 32))
    assert bool(r.found.all())
    print(f"inserted {len(ins)} keys without touching the rep structure "
          f"(max chain {store.max_chain})")


if __name__ == "__main__":
    main()
