"""Quickstart: the unified ``repro.db`` session API end-to-end.

One declarative ``IndexSpec`` picks the deployment tier — ``static``
(immutable, cheapest reads), ``live`` (updatable epoch store), or
``sharded`` (range-partitioned) — and the returned ``Session`` is the
same typed surface for all of them: ``lookup`` / ``range`` / ``insert``
/ ``delete`` / ``scan_ranks`` tickets, resolved by one ``flush()`` with
ONE device dispatch per op class.

Sessions are context managers: ``close()`` flushes pending tickets and,
for durable specs, seals the write-ahead log — so the idiomatic form is
``with repro.db.open(spec, keys) as sess:``.  The final section shows
the durability contract: ``IndexSpec(durability='wal', wal_dir=...)``
logs every write before it runs, and ``db.open(spec, recover=True)``
resumes the store bit-identically after a crash.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import tempfile

import numpy as np

import repro.db as db
from repro.data import keygen


def run_static(sess: db.Session, raw: np.ndarray, lookups: int):
    st = sess.stats()
    nb = sess.nbytes()
    print(f"cgRX built: {st.num_buckets:,} buckets, "
          f"{nb['total_bytes']/1e6:.1f} MB "
          f"(reps {nb['rep_bytes']/1e6:.2f} MB, "
          f"tree {nb['tree_bytes']/1e3:.1f} KB)")

    # Point lookups (a Ticket auto-flushes on result access).
    q_raw = keygen.uniform_lookups(raw, lookups, seed=1)
    res = sess.lookup(keygen.as_keys(q_raw, 32)).result()
    assert bool(res.found.all())
    assert (raw[np.asarray(res.row_id)] == q_raw).all()
    print(f"{lookups:,} point lookups: all hit, rowIDs verified")

    # Range lookup: one successor search + sequential scan (Sec. 3.2).
    sraw = np.sort(raw)
    lo, hi = keygen.range_lookups(sraw, 4, 64, seed=2)
    rr = sess.range(keygen.as_keys(lo, 32), keygen.as_keys(hi, 32)).result()
    print(f"range lookups: counts={np.asarray(rr.count).tolist()}")

    # Batched serving is the API's execution model: queue mixed
    # traffic, then ONE flush = one coalesced engine dispatch.
    t_pts = sess.lookup(keygen.as_keys(q_raw[:256], 32))
    t_rng = sess.range(keygen.as_keys(lo, 32), keygen.as_keys(hi, 32))
    t_rnk = sess.scan_ranks(keygen.as_keys(q_raw[:64], 32))
    before = dict(sess.dispatches)
    rep = sess.flush()
    spent = {k: sess.dispatches[k] - before[k] for k in before}
    assert bool(t_pts.result().found.all())
    assert (np.asarray(t_rng.result().count)
            == np.asarray(rr.count)).all()
    assert (np.asarray(t_rnk.result())
            == np.searchsorted(sraw, q_raw[:64])).all()
    print(f"batched flush: {rep.n_point} points + {rep.n_range} ranges "
          f"+ {rep.n_rank} rank scans in one dispatch per class "
          f"(this flush: {spent})")

    # The static tier rejects writes with a typed error.
    try:
        sess.insert(keygen.as_keys(q_raw[:1], 32), np.zeros(1, np.int32))
    except db.ReadOnlyTierError:
        print("static tier: writes rejected (ReadOnlyTierError)")
    else:
        raise AssertionError("static tier accepted a write")
    return q_raw, lo, hi, np.asarray(rr.count)


def run_live(live: db.Session, raw: np.ndarray, q_raw, lo, hi,
             rr_count) -> None:
    # Live tier (paper Sec. 4): chains grow bucket-locally, the search
    # structure is immutable.
    ins = np.setdiff1d(np.arange(raw.max() + 1, raw.max() + 1001,
                                 dtype=np.uint64), raw)
    t_ins = live.insert(keygen.as_keys(ins, 32),
                        np.arange(len(raw), len(raw) + len(ins),
                                  dtype=np.int32))
    t_hit = live.lookup(keygen.as_keys(ins, 32))   # same-flush read hits
    live.flush()
    assert t_ins.result() == len(ins)
    assert bool(t_hit.result().found.all())
    ls = live.stats()
    print(f"live tier: inserted {len(ins)} keys without touching the rep "
          f"structure (epoch {ls.epoch}, max chain {ls.max_chain}, "
          f"{ls.live_keys:,} live keys)")

    # Composable query plans: one sess.query(expr) entry point over a
    # small IR — IN-lists, rank-only aggregates, hit caps, join
    # probes — and a whole flush still compiles to ONE dispatch per
    # op class.
    inlist = np.concatenate([q_raw[:64], q_raw[:64]])      # 50% duplicates
    t_in = live.query(db.isin(keygen.as_keys(inlist, 32)))
    t_cnt = live.query(db.count(db.between(keygen.as_keys(lo, 32),
                                           keygen.as_keys(hi, 32))))
    t_top = live.query(db.limit(4, db.between(keygen.as_keys(lo, 32),
                                              keygen.as_keys(hi, 32))))
    outer_rows = np.arange(32, dtype=np.int32)
    t_join = live.query(db.probe(keygen.as_keys(q_raw[:32], 32),
                                 outer_rows))
    before = dict(live.dispatches)
    rep = live.flush()
    spent = {k: live.dispatches[k] - before[k] for k in before}
    assert spent == {"apply": 0, "query": 1, "rank": 0}
    assert bool(t_in.result().found.all())                 # dups answered
    counts = np.asarray(t_cnt.result())
    assert (counts >= rr_count).all()                      # superset: +inserts
    assert t_top.result().row_ids.shape == (len(lo), 4)
    assert bool(t_join.result().matched.all())
    n_unique = len(np.unique(inlist))
    print(f"query plans: IN-list({len(inlist)} keys -> {n_unique} unique "
          f"lanes) + COUNT({rep.n_agg} ranges, rank-only) + limit(4) + "
          f"{len(outer_rows)} join probes fused into {rep.n_point} point "
          f"lanes, one dispatch (this flush: {spent}; "
          f"counts={counts.tolist()})")


def run_durable(raw: np.ndarray) -> None:
    # Durability: a WAL'd session logs + fsyncs every write BEFORE the
    # device dispatch; recovery (newest snapshot + WAL-tail replay)
    # resumes the store bit-identically.
    wal_dir = tempfile.mkdtemp(prefix="repro-quickstart-wal-")
    spec = db.IndexSpec(tier="live", durability="wal", wal_dir=wal_dir,
                        node_cap=32, policy=db.CompactionPolicy().never())
    boot = np.sort(raw[:4096])
    new = np.setdiff1d(np.arange(raw.max() + 2000, raw.max() + 2065,
                                 dtype=np.uint64), raw)
    with db.open(spec, keygen.as_keys(boot, 32)) as durable:
        durable.insert(keygen.as_keys(new, 32),
                       np.arange(len(new), dtype=np.int32))
        durable.delete(keygen.as_keys(boot[:32], 32))
        durable.flush()
    # The session is gone ("crash"); the log is not.
    with db.open(spec, recover=True) as recovered:
        back = recovered.lookup(keygen.as_keys(new, 32)).result()
        gone = recovered.lookup(keygen.as_keys(boot[:32], 32)).result()
        assert bool(back.found.all()) and not bool(gone.found.any())
        print(f"durable tier: {len(new)} logged inserts + 32 deletes "
              f"survived close + recover=True (WAL in {wal_dir})")


def main(n: int = 100_000, lookups: int = 10_000) -> None:
    # Paper workload: 50% dense / 50% uniform 32-bit keys.
    keys, rows, raw = keygen.keyset(n, uniformity=0.5, bits=32, seed=0)
    print(f"key set: {len(raw):,} keys, uniformity 50%")

    # The tier is a spec knob; sessions are context managers (close()
    # flushes pending tickets and seals any WAL segment).
    with db.open(db.IndexSpec(tier="static", bucket_size=16),
                 keys, rows) as sess:
        q_raw, lo, hi, rr_count = run_static(sess, raw, lookups)

    with db.open(db.IndexSpec(tier="live", node_cap=32,
                              policy=db.CompactionPolicy().never()),
                 keys, rows) as live:
        run_live(live, raw, q_raw, lo, hi, rr_count)

    run_durable(raw)


if __name__ == "__main__":
    main()
