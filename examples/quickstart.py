"""Quickstart: the unified ``repro.db`` session API end-to-end.

One declarative ``IndexSpec`` picks the deployment tier — ``static``
(immutable, cheapest reads), ``live`` (updatable epoch store), or
``sharded`` (range-partitioned) — and the returned ``Session`` is the
same typed surface for all of them: ``lookup`` / ``range`` / ``insert``
/ ``delete`` / ``scan_ranks`` tickets, resolved by one ``flush()`` with
ONE device dispatch per op class.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np

import repro.db as db
from repro.data import keygen


def main(n: int = 100_000, lookups: int = 10_000) -> None:
    # 1. Paper workload: 50% dense / 50% uniform 32-bit keys.
    keys, rows, raw = keygen.keyset(n, uniformity=0.5, bits=32, seed=0)
    print(f"key set: {len(raw):,} keys, uniformity 50%")

    # 2. Open a STATIC session (bucket size 16 — the paper's
    #    recommendation, Sec. 5.4).  The tier is a spec knob.
    sess = db.open(db.IndexSpec(tier="static", bucket_size=16), keys, rows)
    st = sess.stats()
    nb = sess.nbytes()
    print(f"cgRX built: {st.num_buckets:,} buckets, "
          f"{nb['total_bytes']/1e6:.1f} MB "
          f"(reps {nb['rep_bytes']/1e6:.2f} MB, "
          f"tree {nb['tree_bytes']/1e3:.1f} KB)")

    # 3. Point lookups (a Ticket auto-flushes on result access).
    q_raw = keygen.uniform_lookups(raw, lookups, seed=1)
    res = sess.lookup(keygen.as_keys(q_raw, 32)).result()
    assert bool(res.found.all())
    assert (raw[np.asarray(res.row_id)] == q_raw).all()
    print(f"{lookups:,} point lookups: all hit, rowIDs verified")

    # 4. Range lookup: one successor search + sequential scan (Sec. 3.2).
    sraw = np.sort(raw)
    lo, hi = keygen.range_lookups(sraw, 4, 64, seed=2)
    rr = sess.range(keygen.as_keys(lo, 32), keygen.as_keys(hi, 32)).result()
    print(f"range lookups: counts={np.asarray(rr.count).tolist()}")

    # 5. Batched serving is the API's execution model: queue mixed
    #    traffic, then ONE flush = one coalesced engine dispatch.
    t_pts = sess.lookup(keygen.as_keys(q_raw[:256], 32))
    t_rng = sess.range(keygen.as_keys(lo, 32), keygen.as_keys(hi, 32))
    t_rnk = sess.scan_ranks(keygen.as_keys(q_raw[:64], 32))
    before = dict(sess.dispatches)
    rep = sess.flush()
    spent = {k: sess.dispatches[k] - before[k] for k in before}
    assert bool(t_pts.result().found.all())
    assert (np.asarray(t_rng.result().count)
            == np.asarray(rr.count)).all()
    assert (np.asarray(t_rnk.result())
            == np.searchsorted(sraw, q_raw[:64])).all()
    print(f"batched flush: {rep.n_point} points + {rep.n_range} ranges "
          f"+ {rep.n_rank} rank scans in one dispatch per class "
          f"(this flush: {spent})")

    # 6. The static tier rejects writes with a typed error...
    try:
        sess.insert(keygen.as_keys(q_raw[:1], 32), np.zeros(1, np.int32))
    except db.ReadOnlyTierError:
        print("static tier: writes rejected (ReadOnlyTierError)")
    else:
        raise AssertionError("static tier accepted a write")

    # 7. ...so switch the SPEC to the live tier (paper Sec. 4: chains
    #    grow bucket-locally, the search structure is immutable).
    live = db.open(db.IndexSpec(tier="live", node_cap=32,
                                policy=db.CompactionPolicy().never()),
                   keys, rows)
    ins = np.setdiff1d(np.arange(raw.max() + 1, raw.max() + 1001,
                                 dtype=np.uint64), raw)
    t_ins = live.insert(keygen.as_keys(ins, 32),
                        np.arange(len(raw), len(raw) + len(ins),
                                  dtype=np.int32))
    t_hit = live.lookup(keygen.as_keys(ins, 32))   # same-flush read hits
    live.flush()
    assert t_ins.result() == len(ins)
    assert bool(t_hit.result().found.all())
    ls = live.stats()
    print(f"live tier: inserted {len(ins)} keys without touching the rep "
          f"structure (epoch {ls.epoch}, max chain {ls.max_chain}, "
          f"{ls.live_keys:,} live keys)")

    # 8. Composable query plans: one sess.query(expr) entry point over a
    #    small IR — IN-lists, rank-only aggregates, hit caps, join
    #    probes — and a whole flush still compiles to ONE dispatch per
    #    op class.
    inlist = np.concatenate([q_raw[:64], q_raw[:64]])      # 50% duplicates
    t_in = live.query(db.isin(keygen.as_keys(inlist, 32)))
    t_cnt = live.query(db.count(db.between(keygen.as_keys(lo, 32),
                                           keygen.as_keys(hi, 32))))
    t_top = live.query(db.limit(4, db.between(keygen.as_keys(lo, 32),
                                              keygen.as_keys(hi, 32))))
    outer_rows = np.arange(32, dtype=np.int32)
    t_join = live.query(db.probe(keygen.as_keys(q_raw[:32], 32),
                                 outer_rows))
    before = dict(live.dispatches)
    rep = live.flush()
    spent = {k: live.dispatches[k] - before[k] for k in before}
    assert spent == {"apply": 0, "query": 1, "rank": 0}
    assert bool(t_in.result().found.all())                 # dups answered
    counts = np.asarray(t_cnt.result())
    assert (counts >= np.asarray(rr.count)).all()          # superset: +inserts
    assert t_top.result().row_ids.shape == (len(lo), 4)
    assert bool(t_join.result().matched.all())
    n_unique = len(np.unique(inlist))
    print(f"query plans: IN-list({len(inlist)} keys -> {n_unique} unique "
          f"lanes) + COUNT({rep.n_agg} ranges, rank-only) + limit(4) + "
          f"{len(outer_rows)} join probes fused into {rep.n_point} point "
          f"lanes, one dispatch (this flush: {spent}; "
          f"counts={counts.tolist()})")


if __name__ == "__main__":
    main()
