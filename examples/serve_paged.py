"""Serve a small model with batched requests over the cgRX-paged KV cache.

The page table is the paper's updatable node-chain index: sequence
admission inserts block keys, retirement deletes them — watch the index
churn counters while throughput stays flat.

    PYTHONPATH=src python examples/serve_paged.py
"""
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import jax

from repro.configs import get_config
from repro.models import lm
from repro.serving.engine import Engine


def main() -> None:
    cfg = get_config("starcoder2-3b").tiny()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=3, max_seq=64, page_size=8,
                 num_pages=128)
    rng = np.random.default_rng(0)
    for i in range(6):
        eng.submit(rng.integers(0, cfg.vocab_size, 8 + i), max_new_tokens=8)
    results = eng.run_to_completion()
    s = eng.stats
    ts = eng.cache.table.stats()          # unified repro.db Stats surface
    print(f"completed {len(results)} requests, {s.tokens_out} tokens")
    print(f"page-table churn: +{s.index_inserts} / -{s.index_deletes} blocks "
          f"(chains <= {ts.max_chain}, reps untouched: "
          f"{ts.num_buckets} buckets at epoch {ts.epoch} since build)")
    assert len(eng.cache.free_pages) == 128, "page leak"


if __name__ == "__main__":
    main()
