"""Sharded cgRX serving: static mesh mode + the live sharded tier.

Two tiers over the same splitter math (core/distributed.py):

1. **Static read-only mode** — the key space is range-partitioned over the
   mesh's model axis, query batches are data-parallel, and each lookup
   costs exactly one small all-reduce (index size never enters the
   collective).  Runs on 8 emulated host devices, the same code path the
   512-chip dry-run exercises.
2. **Live mode** — the unified session API (``repro.db``) with
   ``tier='sharded'``: every shard owns an epoch-versioned ``LiveIndex``;
   mixed insert/delete batches route to owning shards (one apply dispatch
   per shard), cross-shard ranges decompose at the splitters and merge
   with a rank-offset prefix, and a hot shard compacts without pausing
   its siblings.  The accelerated structures never move — and the tier
   is just a spec knob: the same ``Session`` calls serve a single-node
   live store or a static index unchanged.

    PYTHONPATH=src python examples/distributed_index.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import jax
import jax.numpy as jnp

import repro.db as db
from repro.core import distributed as dist


def main() -> None:
    rng = np.random.default_rng(0)
    n = 200_000
    raw = np.unique(rng.integers(0, 1 << 45, int(1.3 * n),
                                 dtype=np.uint64))[:n]
    keys = db.as_key_array(raw)

    # ---- static read-only mode: mesh-mapped lookups, one psum each ----
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    print(f"mesh {dict(mesh.shape)}; {len(raw):,} keys range-partitioned "
          f"into 4 shards")
    sidx = dist.build_sharded(keys, jnp.arange(n, dtype=jnp.int32),
                              bucket_size=16, num_shards=4, mesh=mesh)

    sel = rng.integers(0, n, 4096)
    found, rowid = dist.sharded_lookup(sidx, keys[sel])
    assert np.asarray(found).all()
    assert (raw[np.asarray(rowid)] == raw[sel]).all()
    print(f"static mode point lookups: 4096/4096 hit across shards "
          f"(1 psum of 8B/query)")

    sraw = np.sort(raw)
    starts = rng.integers(0, n - 2000, 1024)
    lo, hi = sraw[starts], sraw[starts + 999]
    cnt = dist.sharded_range_count(sidx, db.as_key_array(lo),
                                   db.as_key_array(hi))
    assert (np.asarray(cnt) == 1000).all()
    print("static mode range counts: 1024 ranges spanning shard "
          "boundaries, all exact")

    # ---- live mode: repro.db session over the sharded tier — routed ----
    # ---- updates, cross-shard ranges, per-shard compaction, skew    ----
    spec = db.IndexSpec(tier="sharded", shards=4, node_cap=32,
                        policy=db.CompactionPolicy(max_chain=4),
                        max_imbalance=2.0, max_hits=16)
    # Context-manager form: close() flushes pending tickets on exit (and
    # seals the WAL for durable specs) — the session lifecycle contract.
    with db.open(spec, keys, np.arange(n, dtype=np.int32)) as sess:
        upd = np.setdiff1d(np.unique(rng.integers(0, 1 << 45, 6000,
                                                  dtype=np.uint64)),
                           raw)[:4096]
        dels = np.unique(raw[rng.integers(0, n, 2048)])
        sess.insert(db.as_key_array(upd),
                    np.arange(n, n + len(upd), dtype=np.int32))
        sess.delete(db.as_key_array(dels))
        rep = sess.flush()                # ONE routed apply for the flush
        st = sess.stats()
        print(f"live mode updates: {len(upd)} inserts + {len(dels)} "
              f"deletes routed via splitters, 1 apply/shard; "
              f"epochs {list(st.detail.epochs)}; "
              f"policy={rep.compacted or '-'}")

        res = sess.lookup(db.as_key_array(upd)).result()
        gone = sess.lookup(db.as_key_array(dels)).result()
        assert bool(np.asarray(res.found).all())
        assert not bool(np.asarray(gone.found).any())

        live_np = np.sort(np.setdiff1d(np.concatenate([raw, upd]), dels))
        starts = rng.integers(0, len(live_np) - 150_000, 256)
        lo = db.as_key_array(live_np[starts])
        hi = db.as_key_array(live_np[starts + 149_999])
        rng_res = sess.range(lo, hi).result()
        assert (np.asarray(rng_res.count) == 150_000).all()
        st = sess.stats()
        print(f"live mode ranges: 256 ranges decomposed at the splitters "
              f"across {st.num_shards} shards, counts exact after updates "
              f"(imbalance {st.detail.imbalance:.2f}, "
              f"rebalances {st.detail.rebalances})")

        # Global rank scans merge with the same rank-offset prefix.
        ranks = sess.scan_ranks(lo).result()
        assert (np.asarray(ranks) == starts).all()
        print(f"live mode rank scans: 256 global ranks bit-identical to "
              f"the host oracle (session dispatches: {sess.dispatches})")


if __name__ == "__main__":
    main()
