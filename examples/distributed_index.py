"""Mesh-sharded cgRX: point + range lookups over a range-partitioned index.

Runs on 8 emulated host devices (the same code path the 512-chip dry-run
exercises): the key space is range-partitioned over the model axis, query
batches are data-parallel, and each lookup costs exactly one small
all-reduce — index size never enters the collective.

    PYTHONPATH=src python examples/distributed_index.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import distributed as dist
from repro.core.keys import KeyArray


def main() -> None:
    rng = np.random.default_rng(0)
    n = 200_000
    raw = np.unique(rng.integers(0, 1 << 45, int(1.3 * n),
                                 dtype=np.uint64))[:n]
    keys = KeyArray.from_u64(raw)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    print(f"mesh {dict(mesh.shape)}; {len(raw):,} keys range-partitioned "
          f"into 4 shards")
    sidx = dist.build_sharded(keys, jnp.arange(n, dtype=jnp.int32),
                              bucket_size=16, num_shards=4, mesh=mesh)

    sel = rng.integers(0, n, 4096)
    found, rowid = dist.sharded_lookup(sidx, keys[sel])
    assert np.asarray(found).all()
    assert (raw[np.asarray(rowid)] == raw[sel]).all()
    print(f"point lookups: 4096/4096 hit across shards "
          f"(1 psum of 8B/query)")

    sraw = np.sort(raw)
    starts = rng.integers(0, n - 2000, 1024)
    lo, hi = sraw[starts], sraw[starts + 999]
    cnt = dist.sharded_range_count(sidx, KeyArray.from_u64(lo),
                                   KeyArray.from_u64(hi))
    assert (np.asarray(cnt) == 1000).all()
    print("range counts: 1024 ranges spanning shard boundaries, all exact")


if __name__ == "__main__":
    main()
