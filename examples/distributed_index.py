"""Mesh-sharded cgRX: lookups AND updates over a range-partitioned index.

Runs on 8 emulated host devices (the same code path the 512-chip dry-run
exercises): the key space is range-partitioned over the model axis, query
batches are data-parallel, and each lookup costs exactly one small
all-reduce — index size never enters the collective.

The update half mirrors the paper's Sec. 4 at cluster scale: every shard
owns a ``LiveIndex`` (epoch-versioned updatable store, repro.store), and
a mixed insert/delete batch is routed to its owning shard with
``dist.route_updates`` (successor search over the shard splitters — the
same math as the lookup routing), then applied shard-locally with ONE
``LiveIndex.apply`` per shard.  The accelerated structures never move.

    PYTHONPATH=src python examples/distributed_index.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import distributed as dist
from repro.core.keys import KeyArray
from repro.store import CompactionPolicy, LiveConfig, LiveIndex


def main() -> None:
    rng = np.random.default_rng(0)
    n = 200_000
    raw = np.unique(rng.integers(0, 1 << 45, int(1.3 * n),
                                 dtype=np.uint64))[:n]
    keys = KeyArray.from_u64(raw)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    print(f"mesh {dict(mesh.shape)}; {len(raw):,} keys range-partitioned "
          f"into 4 shards")
    sidx = dist.build_sharded(keys, jnp.arange(n, dtype=jnp.int32),
                              bucket_size=16, num_shards=4, mesh=mesh)

    sel = rng.integers(0, n, 4096)
    found, rowid = dist.sharded_lookup(sidx, keys[sel])
    assert np.asarray(found).all()
    assert (raw[np.asarray(rowid)] == raw[sel]).all()
    print(f"point lookups: 4096/4096 hit across shards "
          f"(1 psum of 8B/query)")

    sraw = np.sort(raw)
    starts = rng.integers(0, n - 2000, 1024)
    lo, hi = sraw[starts], sraw[starts + 999]
    cnt = dist.sharded_range_count(sidx, KeyArray.from_u64(lo),
                                   KeyArray.from_u64(hi))
    assert (np.asarray(cnt) == 1000).all()
    print("range counts: 1024 ranges spanning shard boundaries, all exact")

    # ---- sharded updates: one LiveIndex per shard, batches routed by ----
    # ---- splitter search, one apply dispatch per shard              ----
    shards = []
    for s in range(sidx.num_shards):
        rows_s = np.asarray(sidx.row_ids[s])
        mask = rows_s >= 0                       # strip sentinel padding
        shard_keys = KeyArray(sidx.keys.lo[s][mask], sidx.keys.hi[s][mask])
        cfg = LiveConfig(node_cap=32,
                         policy=CompactionPolicy(max_chain=4))
        shards.append(LiveIndex.build(shard_keys,
                                      jnp.asarray(rows_s[mask]), cfg))

    upd = np.setdiff1d(np.unique(rng.integers(0, 1 << 45, 6000,
                                              dtype=np.uint64)), raw)[:4096]
    dels = raw[rng.integers(0, n, 2048)]
    owner_ins = np.asarray(dist.route_updates(sidx, KeyArray.from_u64(upd)))
    owner_del = np.asarray(dist.route_updates(sidx, KeyArray.from_u64(dels)))
    for s, live in enumerate(shards):
        ins_s = upd[owner_ins == s]
        del_s = dels[owner_del == s]
        live.apply(KeyArray.from_u64(ins_s),
                   jnp.arange(n + s * len(upd), n + s * len(upd) + len(ins_s),
                              dtype=jnp.int32),
                   KeyArray.from_u64(del_s))
    hit = sum(int(np.asarray(
        shards[s].lookup(KeyArray.from_u64(upd[owner_ins == s])).found).sum())
        for s in range(len(shards)))
    gone = sum(int(np.asarray(
        shards[s].lookup(KeyArray.from_u64(dels[owner_del == s])).found).sum())
        for s in range(len(shards)))
    assert hit == len(upd) and gone == 0
    epochs = [lv.epoch for lv in shards]
    print(f"sharded updates: {len(upd)} inserts + {len(np.unique(dels))} "
          f"deletes routed via splitters, 1 apply/shard; epochs {epochs}")


if __name__ == "__main__":
    main()
