"""End-to-end driver: train a small LM for a few hundred steps on CPU.

Uses the same train-step factory the 512-chip dry-run lowers, with
checkpointing + fault-tolerance runtime attached.  The synthetic stream
has copy structure, so the loss visibly falls.

    PYTHONPATH=src python examples/train_tiny_lm.py --steps 200
"""
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import tokens as data_tokens
from repro.models import lm
from repro.runtime import Heartbeat, StragglerMonitor
from repro.training import optim, step as step_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch).tiny()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = optim.AdamWConfig(lr_peak=3e-3, warmup_steps=20,
                                total_steps=args.steps)
    opt = optim.init_state(params)
    fn = jax.jit(step_mod.make_train_step(cfg, opt_cfg),
                 donate_argnums=(0, 1))

    ckpt = CheckpointManager(args.ckpt, keep=2)
    hb = Heartbeat("/tmp/repro_example_hb.json").start()
    mon = StragglerMonitor()

    first_loss = last_loss = None
    for i in range(args.steps):
        t0 = time.time()
        batch = jax.tree.map(jnp.asarray, data_tokens.synthetic_batch(
            i, args.batch, args.seq, cfg.vocab_size))
        params, opt, m = fn(params, opt, batch)
        loss = float(m["loss"])
        mon.record(i, time.time() - t0)
        hb.update(i)
        if first_loss is None:
            first_loss = loss
        last_loss = loss
        if i % 20 == 0:
            print(f"step {i:4d}  loss {loss:.4f}  lr {float(m['lr']):.2e}")
        if (i + 1) % 100 == 0:
            ckpt.save_async(i + 1, (params, opt), {"data_step": i + 1})
    ckpt.wait()
    hb.stop()
    print(f"loss {first_loss:.3f} -> {last_loss:.3f} "
          f"over {args.steps} steps")
    assert last_loss < first_loss, "training did not reduce the loss"


if __name__ == "__main__":
    main()
