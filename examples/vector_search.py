"""Vector search on the rank engine: the coarse-bucket ANN tier.

The paper's recipe — index coarse buckets, post-filter after retrieval —
is IVF for embeddings: quantize to coarse centroids, index the centroid
IDs as keys, refine retrieved buckets with exact distances.  One spec
knob opens it:

    PYTHONPATH=src python examples/vector_search.py
"""
import numpy as np

import repro.db as db
from repro.data import keygen

DIM, NCENT = 32, 16


def main() -> None:
    corpus = keygen.embedding_set(2048, DIM, nclusters=12, seed=0)
    queries = keygen.embedding_queries(corpus, 8, seed=1)

    spec = db.IndexSpec(tier="live", kind="vector", dim=DIM,
                        ncentroids=NCENT, nprobe=4, max_hits=512)
    with db.open(spec, corpus) as sess:
        # Probes are tickets like any other read: they coalesce into the
        # flush's one dispatch per op class, then one fused distance_topk
        # launch refines each ticket's candidates into exact top-k.
        t = sess.probe_vectors(queries, k=5)
        res = t.result()                          # auto-flush
        print("nearest rowIDs per query (nprobe=4):")
        print(np.asarray(res.row_id))

        # Live updates ride the scalar write path: insert new embeddings
        # (arena + composite keys in one flush) and delete by rowID.
        fresh = keygen.embedding_set(256, DIM, nclusters=12, seed=2)
        sess.insert_vectors(fresh)
        sess.delete_vectors(np.arange(16, dtype=np.int32))
        # Exhaustive probe: every bucket, probe_cap >= largest bucket.
        res2 = sess.probe_vectors(queries, k=5, nprobe=NCENT,
                                  probe_cap=4096).result()
        print("after insert+delete, exhaustive probe (exact):")
        print(np.asarray(res2.row_id))

        # Exhaustive probe == brute force, bit for bit.
        alive = np.concatenate([corpus[16:], fresh])
        rows = np.concatenate([np.arange(16, 2048), np.arange(2048, 2304)])
        d2 = ((alive[None] - queries[:, None]) ** 2).sum(-1)
        d2 = d2.astype(np.float32)
        order = np.lexsort((np.broadcast_to(rows, d2.shape), d2),
                           axis=-1)[:, :5]
        assert np.array_equal(np.asarray(res2.row_id), rows[order]), \
            "exhaustive probe must equal brute force"
        print("exhaustive probe matches the brute-force oracle")
        print("dispatch rounds:", sess.dispatches)


if __name__ == "__main__":
    main()
