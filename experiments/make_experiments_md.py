"""Assemble EXPERIMENTS.md from dry-run artifacts + roofline + perf variants.

    PYTHONPATH=src python experiments/make_experiments_md.py
"""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import roofline  # noqa: E402

ROOT = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(ROOT)


def load(mesh):
    return roofline.load_dir(os.path.join(ROOT, "dryrun", mesh))


def load_variants(mesh):
    return [r for r in roofline.load_dir(os.path.join(ROOT, "dryrun", mesh),
                                         include_variants=True)
            if r.get("tag")]


def dryrun_table(recs):
    out = ["| arch | shape | status | lower (s) | compile (s) | "
           "params/dev (GB) | opt+cache/dev (GB) | coll types | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "OK":
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']} | — | —"
                       f" | — | — | — | {r.get('reason', '')[:70]} |")
            continue
        extra = (r.get("opt_bytes_per_dev", 0)
                 + r.get("cache_bytes_per_dev", 0)) / 1e9
        colls = ",".join(sorted(r.get("collectives", {}).keys()))
        mb = r.get("num_microbatches", "")
        note = f"mb={mb}" if mb else ""
        out.append(
            f"| {r['arch']} | {r['shape']} | OK "
            f"| {r.get('seconds_lower', 0):.1f} "
            f"| {r.get('seconds_compile', 0):.1f} "
            f"| {r.get('param_bytes_per_dev', 0)/1e9:.2f} "
            f"| {extra:.2f} | {colls} | {note} |")
    return "\n".join(out)


def perf_table(base_recs, var_recs, cells):
    rows = ["| cell | variant | t_compute | t_memory | t_collective | "
            "dominant | bound step (s) | Δ dominant vs baseline |",
            "|---|---|---|---|---|---|---|---|"]
    base_by = {(r["arch"], r["shape"]): r for r in base_recs
               if r["status"] == "OK"}
    for arch, shape in cells:
        b = base_by.get((arch, shape))
        if not b:
            continue
        bt = roofline.cell_terms(b)
        rows.append(
            f"| {arch}/{shape} | **baseline** | {bt['t_compute']:.3e} "
            f"| {bt['t_memory']:.3e} | {bt['t_collective']:.3e} "
            f"| {bt['dominant']} | {bt['step_time_bound_s']:.3e} | — |")
        base_dom = bt[f"t_{bt['dominant']}"]
        for v in var_recs:
            if (v["arch"], v["shape"]) != (arch, shape) or v["status"] != "OK":
                continue
            vt = roofline.cell_terms(v)
            delta = (vt[f"t_{bt['dominant']}"] - base_dom) / base_dom * 100
            rows.append(
                f"| {arch}/{shape} | {v['tag']} | {vt['t_compute']:.3e} "
                f"| {vt['t_memory']:.3e} | {vt['t_collective']:.3e} "
                f"| {vt['dominant']} | {vt['step_time_bound_s']:.3e} "
                f"| {delta:+.1f}% |")
    return "\n".join(rows)


def main():
    pod1 = load("pod1")
    pod2 = load("pod2")
    variants = load_variants("pod1")

    hill_cells = [("qwen3-32b", "train_4k"),
                  ("deepseek-v2-lite-16b", "decode_32k"),
                  ("qwen1.5-32b", "decode_32k")]

    with open(os.path.join(ROOT, "EXPERIMENTS_header.md")) as f:
        header = f.read()
    with open(os.path.join(ROOT, "EXPERIMENTS_perf_narrative.md")) as f:
        narrative = f.read()

    parts = [header]
    parts.append("\n## §Dry-run — single pod (16x16 = 256 chips)\n")
    parts.append(dryrun_table(pod1))
    parts.append("\n\n## §Dry-run — multi-pod (2x16x16 = 512 chips)\n")
    parts.append(dryrun_table(pod2))
    parts.append("\n\n## §Roofline (single-pod mesh, per chip)\n")
    parts.append(
        "\nTerms in seconds: compute = FLOPs/197e12, memory = HBM-bytes/"
        "819e9, collective = bytes-on-wire/50e9 (per-link serialization "
        "upper bound).  FLOPs and collective bytes are loop-trip-corrected "
        "from the compiled HLO (launch/hlo_loops.py); HBM bytes count "
        "heavy-op boundaries (in-place update-slices at touched-region "
        "size).  `useful/HLO` = MODEL_FLOPS / corrected-HLO-FLOPs "
        "(remat/redundancy waste; ~0.75 = full-remat-consistent for "
        "matmul-dominated cells; SSM decode can exceed 1 because the "
        "6ND/2ND convention undercounts per-token state-update work); "
        "`MFU bound` = model-flops-time / dominant-term-time = the "
        "ceiling a perfect overlap could reach.  Notable structural "
        "findings: qwen1.5-32b (MHA kv=40) pays a large memory term "
        "because 40 KV heads do not divide the 16-way model axis — the "
        "divisibility fallback replicates KV projections; padding to 48 "
        "KV heads or 8-way head sharding is the identified lever.  "
        "Zamba2 compute terms are MAX-bound upper estimates (shared-attn "
        "conditional counted every layer, executes every 6th).\n\n")
    parts.append(roofline.markdown(pod1))
    parts.append("\n\n### Per-cell bottleneck notes\n")
    for rec in pod1:
        if rec.get("status") != "OK":
            continue
        t = roofline.row(rec)
        parts.append(f"- **{rec['arch']}/{rec['shape']}**: dominant = "
                     f"{t['dominant']}; {t['suggest']}.")
    parts.append("\n\n## §Perf — hillclimbing log\n")
    parts.append(narrative)
    parts.append("\n\n### Variant measurements (dry-run, pod1)\n")
    parts.append(perf_table(pod1, variants, hill_cells))
    parts.append("\n")

    out = os.path.join(REPO, "EXPERIMENTS.md")
    with open(out, "w") as f:
        f.write("\n".join(parts))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
