"""Compiled query plans vs hand-rolled per-fragment dispatch loops.

The query-plan redesign's pitch: richer workloads (IN-lists, range
aggregates) no longer cost one dispatch per fragment — the compiler
fuses a flush's expression trees onto one physical plan per op class,
and aggregates execute rank-only (no rowID materialization).  This suite
times both sides of that claim on the live tier:

    sugar/*              the unchanged ``lookup``/``range`` verbs (now
                         thin IR sugar): the metrics the perf gate uses
                         to bound COMPILER OVERHEAD on the legacy paths
                         — identical names and semantics exist pre-IR,
                         so the committed baseline gates the lowering
                         machinery itself;
    inlist/per_fragment  the pre-IR way to serve an IN-list: chunk it and
                         dispatch one lookup flush per chunk;
    inlist/fused         ``sess.query(isin(...))``: deduped to one lane
                         per unique key, one dispatch for the whole list;
    count/materialized   the pre-IR way to count: a full range lookup
                         that gathers the (R, max_hits) rowID block and
                         reads only ``.count``;
    count/fused          ``sess.query(count(between(...)))``: rank-only;
    count/kernel_direct  ``kernels.ops.range_count``: the hand-rolled
                         kernel-level floor the compiled plan should sit
                         near (one fused launch + a subtraction).

New-API metrics are skipped gracefully on trees that predate the IR
(guarded by ``hasattr``), so this file can be replayed against an older
checkout to (re)record the legacy baselines.
"""
from benchmarks.common import emit, parse_args, timeit

import numpy as np

import repro.db as db
from repro.core.bucketing import build_buckets
from repro.data import keygen
from repro.kernels import ops as kops

FRAGMENTS = 8          # chunks of the hand-rolled IN-list loop
DUP_FACTOR = 2         # IN-list duplication (isin dedupes these away)
N_RANGES = 64


def _flush_timer(sess, submit):
    """Median seconds for submit()+flush() (flush blocks on results)."""
    def run():
        submit()
        sess.flush()
        return ()
    return timeit(run)


def main(args=None) -> None:
    args = args or parse_args()
    seed = getattr(args, "seed", None) or 0
    n = max(4096, min(args.n, 1 << 20))
    n_q = max(256, min(args.q, 1 << 20) >> 3)

    keys, rows, raw = keygen.keyset(n, 1.0, bits=64, seed=seed)
    sraw = np.sort(raw)
    spec = db.IndexSpec(tier="live", node_cap=32, max_hits=32,
                        policy=db.CompactionPolicy().never())
    sess = db.open(spec, keys, rows)
    rng = np.random.default_rng(seed + 1)

    # ---- legacy-named sugar paths (the compiler-overhead gate) ----
    q = raw[rng.integers(0, len(raw), n_q)]
    qk = keygen.as_keys(q, 64)
    t = _flush_timer(sess, lambda: sess.lookup(qk))
    emit(f"sugar/point_b{n_q}", t, f"{n_q/t:.0f} lookups/s")

    starts = rng.integers(0, len(sraw) - n // 4, N_RANGES)
    lo = keygen.as_keys(sraw[starts], 64)
    hi = keygen.as_keys(sraw[starts + n // 4 - 1], 64)
    t = _flush_timer(sess, lambda: sess.range(lo, hi))
    emit(f"sugar/range_b{N_RANGES}", t, f"{N_RANGES/t:.0f} ranges/s")

    # ---- IN-list: per-fragment dispatch loop vs one fused plan ----
    base = raw[rng.integers(0, len(raw), n_q)]
    inlist = base[rng.integers(0, len(base), DUP_FACTOR * n_q)]
    chunks = [keygen.as_keys(c, 64)
              for c in np.array_split(inlist, FRAGMENTS)]

    def per_fragment():
        for c in chunks:
            sess.lookup(c)
            sess.flush()          # one dispatch PER fragment (the old way)
    t_loop = timeit(lambda: (per_fragment(), ())[1])
    emit(f"inlist/per_fragment_f{FRAGMENTS}", t_loop,
         f"{len(inlist)/t_loop:.0f} keys/s")

    if hasattr(db, "isin"):
        ik = keygen.as_keys(inlist, 64)
        t_fused = _flush_timer(sess, lambda: sess.query(db.isin(ik)))
        emit("inlist/fused", t_fused,
             f"{len(inlist)/t_fused:.0f} keys/s "
             f"({t_loop/t_fused:.1f}x vs loop)")

    # ---- COUNT(*) over ranges: materialize-and-discard vs rank-only ----
    def count_materialized():
        r = sess.range(lo, hi)
        sess.flush()
        return np.asarray(r.result().count)
    t_mat = timeit(count_materialized)
    emit(f"count/materialized_b{N_RANGES}", t_mat,
         f"{N_RANGES/t_mat:.0f} counts/s (gathers max_hits rowIDs)")

    if hasattr(db, "count"):
        def count_fused():
            c = sess.query(db.count(db.between(lo, hi)))
            sess.flush()
            return np.asarray(c.result())
        t_cnt = timeit(count_fused)
        emit(f"count/fused_b{N_RANGES}", t_cnt,
             f"{N_RANGES/t_cnt:.0f} counts/s "
             f"({t_mat/t_cnt:.1f}x vs materialized)")
        assert (count_fused() == count_materialized()).all()

    if hasattr(kops, "range_count"):
        buckets = build_buckets(keys, rows, 16)
        t_k = timeit(lambda: kops.range_count(buckets, lo, hi))
        emit(f"count/kernel_direct_b{N_RANGES}", t_k,
             f"{N_RANGES/t_k:.0f} counts/s (hand-rolled floor)")


if __name__ == "__main__":
    main(parse_args())
