"""Live store under mixed read/write traffic vs rebuild-per-wave.

The lifecycle complement of Fig. 15 (bench_updates.py): instead of timing
one update primitive, drive the whole live tier through the unified
session API (``repro.db``, tier='live') — epoch snapshot + chains +
compaction policy + flush admission batching — with mixed workloads
(90/10 and 50/50 lookup/update) and compare against the naive serving
strategy of rebuilding a fresh ``CgrxIndex`` every wave.

Emitted per wave: live-path wall time (one apply dispatch + one engine
dispatch per flush, ops/s derived) vs the rebuild baseline, plus the
compaction pauses the policy actually took (the cost the epoch swap moves
off the read path).

CPU-container caveat: the live path runs eagerly, so the first wave at
each chain depth pays one-time XLA compilation (the power-of-two shape
bucketing in ``nodes.apply_batch`` and the engine's shared executable
cache keep that set small); later waves show the steady state.  Fig. 15
(bench_updates.py) times the raw update primitive without the lifecycle.
"""
from benchmarks.common import emit, parse_args

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.db as db
from repro.core import cgrx
from repro.data import keygen
from repro.query import QueryBatch, RankEngine

WAVES = 8


def _mixed_wave(rng, live_np, space, n_ops, read_frac):
    """One wave's traffic: lookups over the live set + insert/delete."""
    n_read = int(n_ops * read_frac)
    n_write = n_ops - n_read
    n_ins = n_write // 2
    n_del = n_write - n_ins
    q = live_np[rng.integers(0, len(live_np), max(n_read, 1))]
    ins = np.setdiff1d(
        np.unique(rng.integers(0, space, int(n_ins * 1.5) + 8,
                               dtype=np.uint64)), live_np)[:n_ins]
    dels = live_np[rng.choice(len(live_np), n_del, replace=False)]
    return q, ins, dels


def main(args=None) -> None:
    args = args or parse_args()
    seed = getattr(args, "seed", None)
    # Scaled workload: the store path is eager host-driven (chain walks,
    # per-version engines); sizes track --n/--q but stay container-sane.
    n = max(2048, min(args.n, 1 << 20) >> 6)
    ops = max(512, min(args.q, 1 << 21) >> 9)
    space = np.uint64((1 << 44) - 1)

    for read_frac, tag in ((0.9, "mix90"), (0.5, "mix50")):
        keys, rows, raw = keygen.keyset(n, 1.0, bits=64,
                                        seed=0 if seed is None else seed)
        spec = db.IndexSpec(
            tier="live", node_cap=32, max_hits=16,
            policy=db.CompactionPolicy(max_chain=3, min_fill=0.2,
                                       max_tombstone_ratio=0.5))
        sess = db.open(spec, keys, rows)

        live_np = raw.copy()
        next_row = n
        rng = np.random.default_rng(2 if seed is None else seed + 1)
        pauses = []
        for wave in range(WAVES):
            q, ins, dels = _mixed_wave(rng, live_np, space, ops, read_frac)

            # --- live path: one flush = one write + one read dispatch ---
            sess.insert(keygen.as_keys(ins, 64),
                        np.arange(next_row, next_row + len(ins),
                                  dtype=np.int32))
            sess.delete(keygen.as_keys(dels, 64))
            sess.lookup(keygen.as_keys(q, 64))
            t0 = time.perf_counter()
            rep = sess.flush()
            t_live = time.perf_counter() - t0
            if rep.compacted:
                pauses.append(rep.compact_seconds)

            next_row += len(ins)
            live_np = np.setdiff1d(np.concatenate([live_np, ins]), dels)

            # --- baseline: rebuild a fresh CgrxIndex, then serve reads ---
            t0 = time.perf_counter()
            rebuilt = cgrx.build(keygen.as_keys(live_np, 64),
                                 jnp.arange(len(live_np), dtype=jnp.int32),
                                 16)
            plan = QueryBatch().add_points(keygen.as_keys(q, 64)).plan()
            res = RankEngine(rebuilt).execute(plan)
            jax.block_until_ready(res.points.row_id)
            t_reb = time.perf_counter() - t0

            emit(f"live_store_{tag}_wave{wave}", t_live,
                 f"ops={ops};rebuild={t_reb*1e3:.1f}ms;"
                 f"speedup={t_reb/max(t_live,1e-9):.2f}x;"
                 f"epoch={rep.epoch};compacted={rep.compacted or '-'};"
                 f"chains<={sess.stats().max_chain}")

        s = sess.stats()
        pause_ms = ";".join(f"{p*1e3:.1f}" for p in pauses) or "-"
        emit(f"live_store_{tag}_summary", sum(pauses),
             f"compactions={s.compactions};epoch={s.epoch};"
             f"live={s.live_keys};fill={s.detail.fill_factor:.2f};"
             f"pauses_ms={pause_ms}")

        # Sanity: the store still answers exactly like a fresh rebuild.
        sel = np.random.default_rng(3).integers(0, len(live_np), 256)
        got = sess.lookup(keygen.as_keys(live_np[sel], 64)).result()
        assert bool(np.asarray(got.found).all()), "live store lost keys"


if __name__ == "__main__":
    main()
