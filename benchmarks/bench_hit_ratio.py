"""Fig. 13: varying the hit ratio (in-range vs out-of-range misses),
32-bit keys, uniformity 100%."""
from benchmarks.common import emit, parse_args, timeit

import jax
import jax.numpy as jnp

from repro.core import baselines as bl
from repro.core import cgrx
from repro.data import keygen


def main(args=None) -> None:
    args = args or parse_args()
    n, q = args.n, args.q // 4
    keys, rows, raw = keygen.keyset(n, 1.0, bits=32, seed=0)
    rows_j = jnp.asarray(rows)
    idx = cgrx.build(keys, rows_j, 16)
    rx = bl.rx_build(keys, rows_j)
    ht = bl.ht_build(keys, rows_j)

    cases = [("hit100", 1.0, False), ("hit50_in", 0.5, False),
             ("hit0_in", 0.0, False), ("hit50_out", 0.5, True),
             ("hit0_out", 0.0, True)]
    for name, ratio, out in cases:
        q_raw = keygen.hit_ratio_lookups(raw, q, ratio, out, bits=32, seed=1)
        qk = keygen.as_keys(q_raw, 32)
        sec = timeit(jax.jit(lambda qq: cgrx.lookup(idx, qq).row_id), qk)
        emit(f"fig13_{name}_cgRX16", sec, "")
        sec = timeit(jax.jit(lambda qq: bl.rx_lookup(rx, qq).row_id), qk)
        emit(f"fig13_{name}_RX", sec, "")
        sec = timeit(jax.jit(lambda qq: bl.ht_lookup(ht, qq).row_id), qk)
        emit(f"fig13_{name}_HT", sec, "")


if __name__ == "__main__":
    main()
