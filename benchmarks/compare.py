"""Gate benchmark results against a committed baseline.

    PYTHONPATH=src python -m benchmarks.compare BENCH_BASELINE.json \
        current.json [--threshold 0.25] [--track REGEX]

Both files hold the ``{suite: {metric: us_per_call}}`` map written by
``benchmarks.run --json``.  Every metric present in BOTH files is
*tracked*; a tracked metric whose current time exceeds
``baseline * (1 + threshold)`` is a regression and fails the run
(exit 1).  Metrics only in the current run are new (reported, never
fatal); metrics only in the baseline are missing (fatal with
``--strict``, else a warning — a renamed benchmark shouldn't brick CI).

``--track`` restricts tracking to ``suite/metric`` names matching the
regex — CI can gate just the serving-path suites while the paper-figure
sweeps stay informational.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from typing import Dict, List, Optional, Tuple

DEFAULT_THRESHOLD = 0.25


def flatten(results: Dict[str, Dict[str, float]]) -> Dict[str, float]:
    """{suite: {metric: us}} -> {'suite/metric': us}.

    ``_``-prefixed pseudo-suites are provenance, not metrics: run.py
    stamps its ``--json`` payload with ``_meta`` (git SHA, jax version,
    seed) so artifacts stay traceable without entering the gate.
    """
    return {f"{suite}/{metric}": float(us)
            for suite, metrics in results.items()
            if not suite.startswith("_")
            for metric, us in metrics.items()}


def compare(baseline: Dict[str, Dict[str, float]],
            current: Dict[str, Dict[str, float]],
            threshold: float = DEFAULT_THRESHOLD,
            track: Optional[str] = None
            ) -> Tuple[List[str], List[str], List[str], int]:
    """Returns (regressions, missing, new, n_tracked) — report lines plus
    the count of metrics actually gated (all lists respect ``track``).

    A regression line reads ``suite/metric: 123.4us -> 456.7us (+270.0%)``.
    """
    base = flatten(baseline)
    cur = flatten(current)
    pat = re.compile(track) if track else None
    tracked = [k for k in base if k in cur and (pat is None or pat.search(k))]

    regressions = []
    for k in sorted(tracked):
        b, c = base[k], cur[k]
        if b > 0 and c > b * (1.0 + threshold):
            regressions.append(
                f"{k}: {b:.1f}us -> {c:.1f}us (+{(c / b - 1) * 100:.1f}%)")
    missing = [k for k in sorted(base)
               if k not in cur and (pat is None or pat.search(k))]
    new = [k for k in sorted(cur)
           if k not in base and (pat is None or pat.search(k))]
    return regressions, missing, new, len(tracked)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("current", help="fresh benchmarks.run --json output")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="max allowed slowdown fraction (default 0.25)")
    ap.add_argument("--track", default=None, metavar="REGEX",
                    help="only gate suite/metric names matching REGEX")
    ap.add_argument("--strict", action="store_true",
                    help="fail when a baseline metric is missing")
    args = ap.parse_args(argv)

    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.current) as fh:
        current = json.load(fh)

    regressions, missing, new, n_tracked = compare(baseline, current,
                                                   args.threshold, args.track)
    print(f"# compared {n_tracked} tracked metrics "
          f"(threshold +{args.threshold * 100:.0f}%)")
    for line in regressions:
        print(f"REGRESSION {line}")
    for k in missing:
        print(f"MISSING {k} (in baseline, not in current run)")
    for k in new:
        print(f"NEW {k} (not in baseline; commit a refreshed baseline "
              f"to track it)")
    if regressions:
        print(f"# FAIL: {len(regressions)} regression(s)")
        return 1
    if missing and args.strict:
        print(f"# FAIL: {len(missing)} missing metric(s) (--strict)")
        return 1
    print("# OK: no tracked regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
