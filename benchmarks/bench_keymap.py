"""Fig. 8: key mapping x representation (naive vs optimized) x uniformity.

The paper's scaled key mapping exists to steer OptiX's opaque BVH builder;
our grouping is explicit (DESIGN.md Sec. 2), so the observable analogue is
the *ray count* and *lookup time* difference between naive and optimized
representations across key distributions — which this benchmark measures,
along with the triangle/memory reduction of the optimized scene.
"""
from benchmarks.common import emit, parse_args, timeit

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import grid
from repro.data import keygen


def main(args=None) -> None:
    args = args or parse_args()
    n, q = args.n // 4, args.q // 16   # grid probes are searchsorted-heavy
    for bits in (32, 64):
        for uniformity in (0.0, 0.5, 1.0):
            keys, rows, raw = keygen.keyset(n, uniformity, bits=bits, seed=0)
            q_raw = keygen.uniform_lookups(raw, q, seed=1)
            qk = keygen.as_keys(q_raw, bits)
            for representation in ("naive", "optimized"):
                for bucket in (4, 16, 256):
                    scene, buckets = grid.build_scene(
                        keys, jnp.asarray(rows), bucket, representation)
                    fn = jax.jit(lambda qq: grid.point_lookup(
                        scene, buckets, qq)[0])
                    sec = timeit(fn, qk)
                    _, _, rays = grid.point_lookup(scene, buckets, qk)
                    mean_rays = float(jnp.mean(rays))
                    mem = scene.nbytes_model()
                    emit(f"fig8_{bits}b_u{int(uniformity*100)}"
                         f"_{representation}_b{bucket}", sec,
                         f"rays={mean_rays:.2f};tris={scene.triangles_materialized};"
                         f"vbuf={mem['vertex_buffer_bytes']}")


if __name__ == "__main__":
    main()
