"""Sharded live store: cross-shard ranges + non-blocking compaction.

The scaling complement of bench_live_store.py: drive the range-partitioned
sharded tier through the unified session API (``repro.db``,
tier='sharded') and measure

  * routed point lookups vs a single-shard live-tier session oracle over
    the same live set (found/row_id/position asserted bit-identical);
  * cross-shard range lookups — every range spans all S shards, decomposed
    at the splitters, merged with the rank-offset prefix (start/count/rows
    asserted bit-identical to the oracle);
  * per-shard compaction independence: sibling-shard reads timed while one
    hot shard holds an in-flight epoch-swap task, vs with no swap in
    flight — the pause a single-shard store would impose on everyone is
    confined to the one shard.

CPU-container caveat (DESIGN.md Sec. 7): eager host-driven path; relative
numbers (sharded vs oracle, during- vs outside-compaction) are the claim.
"""
from benchmarks.common import emit, parse_args, timeit

import time

import jax
import numpy as np

import repro.db as db
from repro.data import keygen

NUM_SHARDS = 4


def _assert_points_identical(got, want):
    for f in ("found", "row_id", "position"):
        g, w = np.asarray(getattr(got, f)), np.asarray(getattr(want, f))
        assert (g == w).all(), f"sharded/oracle point divergence: {f}"


def _assert_ranges_identical(got, want):
    for f in want._fields:
        g, w = np.asarray(getattr(got, f)), np.asarray(getattr(want, f))
        assert (g == w).all(), f"sharded/oracle range divergence: {f}"


def main(args=None) -> None:
    args = args or parse_args()
    seed = getattr(args, "seed", None)
    n = max(2048, min(args.n, 1 << 20) >> 4)
    q = max(256, min(args.q, 1 << 21) >> 7)

    keys, rows, raw = keygen.keyset(n, 1.0, bits=64,
                                    seed=0 if seed is None else seed)
    never = db.CompactionPolicy().never()
    store = db.open(db.IndexSpec(tier="sharded", shards=NUM_SHARDS,
                                 node_cap=32, policy=never,
                                 max_imbalance=None, max_hits=32),
                    keys, rows)
    oracle = db.open(db.IndexSpec(tier="live", node_cap=32, policy=never,
                                  max_hits=32), keys, rows)

    rng = np.random.default_rng(3 if seed is None else seed + 1)
    # Mutate both identically so chains actually exist on the read path.
    space = np.uint64((1 << 44) - 1)
    ins = np.setdiff1d(np.unique(
        rng.integers(0, space, n // 2, dtype=np.uint64)), raw)[:n // 4]
    dels = raw[rng.choice(n, n // 8, replace=False)]
    ins_k = keygen.as_keys(ins, 64)
    ins_r = np.arange(n, n + len(ins), dtype=np.int32)
    del_k = keygen.as_keys(dels, 64)
    for sess in (store, oracle):
        sess.insert(ins_k, ins_r)
        sess.delete(del_k)
        sess.flush()
    live_np = np.sort(np.setdiff1d(np.concatenate([raw, ins]), dels))

    # ---- routed point lookups, bit-identity asserted ----
    pts = keygen.as_keys(live_np[rng.integers(0, len(live_np), q)], 64)
    t_shard = timeit(lambda: store.lookup(pts).result().row_id)
    t_single = timeit(lambda: oracle.lookup(pts).result().row_id)
    _assert_points_identical(store.lookup(pts).result(),
                             oracle.lookup(pts).result())
    emit("sharded_points", t_shard,
         f"q={q};shards={NUM_SHARDS};single={t_single*1e6:.1f}us;"
         f"bit_identical=yes")

    # ---- cross-shard ranges: every range spans all S shards ----
    n_rng = max(q // 16, 16)
    span = int(len(live_np) * 0.8)          # covers >= 3 shard boundaries
    starts = rng.integers(0, len(live_np) - span, n_rng)
    lo = keygen.as_keys(live_np[starts], 64)
    hi = keygen.as_keys(live_np[starts + span - 1], 64)
    t_shard = timeit(lambda: store.range(lo, hi).result().row_ids)
    t_single = timeit(lambda: oracle.range(lo, hi).result().row_ids)
    _assert_ranges_identical(store.range(lo, hi).result(),
                             oracle.range(lo, hi).result())
    emit("sharded_cross_shard_ranges", t_shard,
         f"ranges={n_rng};span~{span};single={t_single*1e6:.1f}us;"
         f"bit_identical=yes")

    # ---- per-shard compaction does not block sibling reads ----
    sib_pts = keygen.as_keys(
        live_np[rng.integers(len(live_np) // 2, len(live_np), q)], 64)

    def sibling_reads():
        return store.lookup(sib_pts).result().row_id

    shards = store.tier.store.shards      # the one below-the-API reach:
    t_before = timeit(sibling_reads)      # drive an in-flight epoch swap
    task = shards[0].begin_compaction("bench")    # hot shard swaps
    t_during = timeit(sibling_reads)
    t0 = time.perf_counter()
    shards[0].finish_compaction(task)
    jax.block_until_ready(shards[0].store.node_keys.lo)
    t_swap = time.perf_counter() - t0
    epochs = list(store.stats().detail.epochs)
    emit("sharded_reads_during_sibling_compaction", t_during,
         f"before={t_before*1e6:.1f}us;"
         f"ratio={t_during/max(t_before,1e-9):.2f};"
         f"swap_pause={t_swap*1e3:.1f}ms;epochs={epochs}")
    assert epochs[0] == 1 and all(e == 0 for e in epochs[1:]), \
        "compaction leaked to sibling shards"


if __name__ == "__main__":
    main()
