"""Fig. 15: node-chain batch updates vs full rebuild.

Bulk-load 64-bit uniformity-100% keys (node size 32, half filled), then
eight insertion waves inflating the set ~2.2x, then eight deletion waves
back to the original size; a lookup batch runs after every wave.  The
rebuild baseline re-sorts from scratch per wave (bucket size 16 = same
bucket count, per the paper's setup)."""
from benchmarks.common import emit, parse_args, timeit

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cgrx, nodes
from repro.data import keygen


def main(args=None) -> None:
    args = args or parse_args()
    n, q = args.n // 2, args.q // 8
    keys, rows, raw = keygen.keyset(n, 1.0, bits=64, seed=0)
    rows_j = jnp.asarray(rows)

    store = nodes.build(keys, rows_j, node_cap=32)       # half-filled
    flat = keys
    flat_rows = rows_j

    rng = np.random.default_rng(1)
    total_inflate = int(1.2 * n)
    wave_size = total_inflate // 8
    inserted_waves = []

    live = raw.copy()
    next_row = n
    for wave in range(8):
        # Draw inserts from the SAME space as the key set (full 64-bit for
        # uniformity 100%) so they spread across buckets like the paper's.
        ins = np.setdiff1d(
            np.unique(rng.integers(0, np.iinfo(np.uint64).max,
                                   int(wave_size * 1.4),
                                   dtype=np.uint64)), live)[:wave_size]
        inserted_waves.append(ins)
        ins_k = keygen.as_keys(ins, 64)
        ins_r = jnp.arange(next_row, next_row + len(ins), dtype=jnp.int32)
        next_row += len(ins)

        t0 = time.perf_counter()
        store = nodes.apply_batch(store, ins_k, ins_r, None)
        jax.block_until_ready(store.node_keys.lo)
        t_upd = time.perf_counter() - t0

        live = np.concatenate([live, ins])
        t0 = time.perf_counter()
        rebuilt = cgrx.build(keygen.as_keys(live, 64),
                             jnp.arange(len(live), dtype=jnp.int32), 16)
        jax.block_until_ready(rebuilt.buckets.keys.lo)
        t_reb = time.perf_counter() - t0
        emit(f"fig15a_ins{wave}", t_upd,
             f"rebuild={t_reb*1e3:.1f}ms;speedup={t_reb/max(t_upd,1e-9):.2f}x")

        q_raw = live[rng.integers(0, len(live), q)]
        qk = keygen.as_keys(q_raw, 64)
        sec_n = timeit(jax.jit(lambda qq: nodes.lookup(store, qq).row_id), qk)
        sec_r = timeit(jax.jit(lambda qq: cgrx.lookup(rebuilt, qq).row_id), qk)
        emit(f"fig15b_ins{wave}", sec_n,
             f"rebuilt_lookup={sec_r*1e3:.1f}ms;chains<={store.max_chain}")

    for wave in range(8):
        dels = inserted_waves[7 - wave]
        t0 = time.perf_counter()
        store = nodes.apply_batch(store, None, None, keygen.as_keys(dels, 64))
        jax.block_until_ready(store.node_keys.lo)
        t_upd = time.perf_counter() - t0
        live = np.setdiff1d(live, dels)
        t0 = time.perf_counter()
        rebuilt = cgrx.build(keygen.as_keys(live, 64),
                             jnp.arange(len(live), dtype=jnp.int32), 16)
        jax.block_until_ready(rebuilt.buckets.keys.lo)
        t_reb = time.perf_counter() - t0
        emit(f"fig15a_del{wave}", t_upd,
             f"rebuild={t_reb*1e3:.1f}ms;speedup={t_reb/max(t_upd,1e-9):.2f}x")
        q_raw = live[rng.integers(0, len(live), q)]
        qk = keygen.as_keys(q_raw, 64)
        sec_n = timeit(jax.jit(lambda qq: nodes.lookup(store, qq).row_id), qk)
        emit(f"fig15b_del{wave}", sec_n, f"chains<={store.max_chain}")


if __name__ == "__main__":
    main()
