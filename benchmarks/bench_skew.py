"""Fig. 14: Zipf-skewed lookups, coefficient 0.0 (uniform) .. 5.0."""
from benchmarks.common import emit, parse_args, timeit

import jax
import jax.numpy as jnp

from repro.core import baselines as bl
from repro.core import cgrx
from repro.data import keygen


def main(args=None) -> None:
    args = args or parse_args()
    n, q = args.n, args.q // 4
    keys, rows, raw = keygen.keyset(n, 1.0, bits=32, seed=0)
    rows_j = jnp.asarray(rows)
    idx = cgrx.build(keys, rows_j, 16)
    ht = bl.ht_build(keys, rows_j)
    bp = bl.bp_build(keys, rows_j)

    for theta in (0.0, 0.25, 0.5, 1.0, 2.0, 5.0):
        q_raw = keygen.zipf_lookups(raw, q, theta, seed=1)
        qk = keygen.as_keys(q_raw, 32)
        sec = timeit(jax.jit(lambda qq: cgrx.lookup(idx, qq).row_id), qk)
        emit(f"fig14_z{theta}_cgRX16", sec, "")
        sec = timeit(jax.jit(lambda qq: bl.ht_lookup(ht, qq).row_id), qk)
        emit(f"fig14_z{theta}_HT", sec, "")
        sec = timeit(jax.jit(lambda qq: bl.bp_lookup(bp, qq).row_id), qk)
        emit(f"fig14_z{theta}_B+", sec, "")


if __name__ == "__main__":
    main()
