"""Fig. 12: range lookups on a dense 23-bit key range — normalized
cumulative lookup time (total time / entries retrieved), hits/range
1..1024, vs RX / SA / B+ (HT has no range support)."""
from benchmarks.common import emit, parse_args, timeit

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as bl
from repro.core import cgrx
from repro.data import keygen


def main(args=None) -> None:
    args = args or parse_args()
    n = min(args.n, 1 << 23)          # dense 23-bit range (paper setup)
    q = args.q // 32
    keys, rows, raw = keygen.keyset(n, 0.0, bits=32, seed=0)
    rows_j = jnp.asarray(rows)
    sraw = np.sort(raw)

    idxs = {f"cgRX{b}": cgrx.build(keys, rows_j, b) for b in (4, 16, 64)}
    sa = bl.sa_build(keys, rows_j)
    bp = bl.bp_build(keys, rows_j)
    rx = bl.rx_build(keys, rows_j)

    for hits in (1, 4, 16, 64, 256, 1024):
        nq = max(q // hits, 64)
        lo, hi = keygen.range_lookups(sraw, nq, hits, seed=1)
        lo_k, hi_k = keygen.as_keys(lo, 32), keygen.as_keys(hi, 32)
        total = nq * hits

        for name, idx in idxs.items():
            fn = jax.jit(lambda a, b: cgrx.range_lookup(
                idx, a, b, max_hits=hits).row_ids)
            sec = timeit(fn, lo_k, hi_k)
            emit(f"fig12_h{hits}_{name}", sec / total,
                 f"total_s={sec:.4f};nq={nq}")
        fn = jax.jit(lambda a, b: bl.sa_range(sa, a, b, hits)[1])
        sec = timeit(fn, lo_k, hi_k)
        emit(f"fig12_h{hits}_SA", sec / total, f"total_s={sec:.4f}")
        fn = jax.jit(lambda a, b: bl.bp_range(bp, a, b, hits)[1])
        sec = timeit(fn, lo_k, hi_k)
        emit(f"fig12_h{hits}_B+", sec / total, f"total_s={sec:.4f}")
        fn = jax.jit(lambda a, b: bl.rx_range(rx, a, b, hits)[1])
        sec = timeit(fn, lo_k, hi_k)
        emit(f"fig12_h{hits}_RX", sec / total, f"total_s={sec:.4f}")


if __name__ == "__main__":
    main()
