"""Run every paper-table benchmark.  Output: ``name,us_per_call,derived``.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig12] \
                                            [--json out.json]

Default sizes are container-scale (2^18 keys); --full is paper-scale
(2^26 keys / 2^27 lookups, needs paper-class memory).  ``--json`` also
writes the machine-readable ``{suite: {metric: us_per_call}}`` map —
the perf-CI artifact benchmarks/compare.py gates regressions against.
"""
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import importlib
import json
import sys
import time
import traceback

from benchmarks import common

SUITES = [
    ("fig8_keymap", "benchmarks.bench_keymap"),
    ("table1_bucket_config", "benchmarks.bench_bucket_config"),
    ("fig10_bucket_size", "benchmarks.bench_bucket_size"),
    ("fig11_footprint", "benchmarks.bench_footprint"),
    ("fig12_range", "benchmarks.bench_range"),
    ("fig13_hit_ratio", "benchmarks.bench_hit_ratio"),
    ("fig14_skew", "benchmarks.bench_skew"),
    ("fig15_updates", "benchmarks.bench_updates"),
    ("kernels", "benchmarks.bench_kernels"),
    ("batched_lookup", "benchmarks.bench_batched_lookup"),
    ("live_store", "benchmarks.bench_live_store"),
    ("sharded_store", "benchmarks.bench_sharded_store"),
]


class _Args:
    def __init__(self, n, q):
        self.n, self.q, self.full = n, q, False


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--q", type=int, default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write {suite: {metric: us_per_call}} JSON")
    args = ap.parse_args()
    n = args.n or (1 << 26 if args.full else 1 << 18)
    q = args.q or (1 << 27 if args.full else 1 << 19)

    failures = []
    for name, mod_name in SUITES:
        if args.only and args.only not in name:
            continue
        print(f"# === {name} (n={n}, q={q}) ===", flush=True)
        t0 = time.time()
        common.set_suite(name)
        try:
            mod = importlib.import_module(mod_name)
            mod.main(_Args(n, q))
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:                                  # noqa: BLE001
            failures.append(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()[-2000:]}",
                  flush=True)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(common.RESULTS, fh, indent=2, sort_keys=True)
        print(f"# wrote {args.json} "
              f"({sum(len(m) for m in common.RESULTS.values())} metrics)")
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)
    print("# ALL BENCHMARKS COMPLETED")


if __name__ == "__main__":
    main()
