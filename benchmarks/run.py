"""Run every paper-table benchmark.  Output: ``name,us_per_call,derived``.

    PYTHONPATH=src python -m benchmarks.run [--full] [--suites a,b] \
                                            [--seed S] [--json out.json] \
                                            [--scenario NAME]

Default sizes are container-scale (2^18 keys); --full is paper-scale
(2^26 keys / 2^27 lookups, needs paper-class memory).  ``--suites``
filters by comma-separated substrings (``--only`` is the historical
single-pattern spelling); ``--seed`` threads a workload seed into the
suites that accept one.  ``--json`` also writes the machine-readable
``{suite: {metric: us_per_call}}`` map — stamped with provenance under
the ``_meta`` pseudo-suite (git SHA, jax version, seed, sizes) so
``benchmarks/compare.py`` artifacts are traceable to the tree and
toolchain that produced them (compare.py ignores ``_``-prefixed suites).
"""
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import importlib
import json
import subprocess
import sys
import time
import traceback

from benchmarks import common

SUITES = [
    ("fig8_keymap", "benchmarks.bench_keymap"),
    ("table1_bucket_config", "benchmarks.bench_bucket_config"),
    ("fig10_bucket_size", "benchmarks.bench_bucket_size"),
    ("fig11_footprint", "benchmarks.bench_footprint"),
    ("fig12_range", "benchmarks.bench_range"),
    ("fig13_hit_ratio", "benchmarks.bench_hit_ratio"),
    ("fig14_skew", "benchmarks.bench_skew"),
    ("fig15_updates", "benchmarks.bench_updates"),
    ("kernels", "benchmarks.bench_kernels"),
    ("batched_lookup", "benchmarks.bench_batched_lookup"),
    ("live_store", "benchmarks.bench_live_store"),
    ("sharded_store", "benchmarks.bench_sharded_store"),
    ("query_plan", "benchmarks.bench_query_plan"),
    ("recovery", "benchmarks.bench_recovery"),
    ("vector", "benchmarks.bench_vector"),
    ("scenarios", "benchmarks.scenarios"),
]


class _Args:
    def __init__(self, n, q, seed=None):
        self.n, self.q, self.seed, self.full = n, q, seed, False


def _git_sha() -> str:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=root).stdout.strip()
        if not sha:
            return "unknown"
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], capture_output=True,
            text=True, timeout=10, cwd=root).stdout.strip()
        return f"{sha}-dirty" if dirty else sha
    except Exception:                                      # noqa: BLE001
        return "unknown"


def _selected(name: str, args) -> bool:
    if args.only and args.only not in name:
        return False
    if args.suites:
        pats = [p.strip() for p in args.suites.split(",") if p.strip()]
        return any(p in name for p in pats)
    return True


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="single substring filter (historical)")
    ap.add_argument("--suites", default=None, metavar="A,B",
                    help="comma-separated suite-name substrings to run")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--q", type=int, default=None)
    ap.add_argument("--seed", type=int, default=None,
                    help="workload seed for suites that accept one")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write {suite: {metric: us_per_call}} JSON "
                         "(+ provenance under '_meta')")
    ap.add_argument("--scenario", default=None, metavar="NAME",
                    help="run ONE adaptive-runtime scenario "
                         "(benchmarks.scenarios) instead of the suites; "
                         "its Session.telemetry() export is stamped into "
                         "the --json payload under '_telemetry'")
    args = ap.parse_args()
    n = args.n or (1 << 26 if args.full else 1 << 18)
    q = args.q or (1 << 27 if args.full else 1 << 19)

    telemetry = None
    if args.scenario:
        from benchmarks import scenarios as sc

        common.set_suite("scenarios")
        if args.scenario not in sc.SCENARIOS:
            print(f"# ERROR: unknown scenario {args.scenario!r}; known: "
                  f"{sorted(sc.SCENARIOS)}")
            sys.exit(2)
        print(f"# === scenario {args.scenario} (n={n}, q={q}) ===",
              flush=True)
        telemetry = {args.scenario:
                     sc.run_scenario(args.scenario, n, q, args.seed or 0)}

    failures = []
    n_ran = 0
    for name, mod_name in ([] if args.scenario else SUITES):
        if not _selected(name, args):
            continue
        n_ran += 1
        print(f"# === {name} (n={n}, q={q}) ===", flush=True)
        t0 = time.time()
        common.set_suite(name)
        try:
            mod = importlib.import_module(mod_name)
            mod.main(_Args(n, q, args.seed))
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:                                  # noqa: BLE001
            failures.append(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()[-2000:]}",
                  flush=True)
    if n_ran == 0 and not args.scenario:
        # A typo'd filter must not produce a green (and, with --json,
        # metric-free) run that measured nothing.
        print(f"# ERROR: no suites matched --only={args.only!r} "
              f"--suites={args.suites!r}; known: "
              f"{[n for n, _ in SUITES]}")
        sys.exit(2)
    if args.json:
        import jax

        payload = dict(common.RESULTS)
        payload["_meta"] = {
            "git_sha": _git_sha(),
            "jax_version": jax.__version__,
            "seed": args.seed,
            "n": n,
            "q": q,
        }
        if telemetry is not None:
            # Adaptive-runtime observability rides along with provenance:
            # `_`-prefixed, so compare.py never gates on it.
            payload["_telemetry"] = telemetry
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(f"# wrote {args.json} "
              f"({sum(len(m) for m in common.RESULTS.values())} metrics, "
              f"sha {payload['_meta']['git_sha'][:12]})")
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)
    print("# ALL BENCHMARKS COMPLETED")


if __name__ == "__main__":
    main()
