"""Benchmark harness helpers.

CPU-container caveat (recorded in DESIGN.md Sec. 7): wall times here are
CPU-backend numbers — valid for the paper's *relative* comparisons (bucket
size trade-off, representation, layout, update-vs-rebuild) and for
throughput-per-byte ratios; absolute GPU/TPU throughputs are not claimed.
Sizes default to 2^20 keys / 2^21 lookups (the paper uses 2^26 / 2^27 on
a 24 GB RTX 4090); pass ``--full`` to run paper-scale if you have the RAM
and patience.
"""
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import time
from typing import Callable, Dict

import jax
import numpy as np

N_KEYS = 1 << 20
N_LOOKUPS = 1 << 21

# Machine-readable result sink: {suite: {metric: us_per_call}}.  ``emit``
# records every metric here under the current suite (benchmarks.run names
# the suite before invoking it; standalone module runs land in 'adhoc');
# ``benchmarks.run --json out.json`` dumps it, and benchmarks/compare.py
# gates CI on it against the committed BENCH_BASELINE.json.
RESULTS: Dict[str, Dict[str, float]] = {}
_CURRENT_SUITE = "adhoc"


def set_suite(name: str) -> None:
    """Name the suite subsequent ``emit`` calls record under."""
    global _CURRENT_SUITE
    _CURRENT_SUITE = name
    RESULTS.setdefault(name, {})


def parse_args(extra: Callable = None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale 2^26 keys / 2^27 lookups")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--q", type=int, default=None)
    ap.add_argument("--seed", type=int, default=None,
                    help="workload seed (suites that accept one)")
    if extra:
        extra(ap)
    args = ap.parse_args()
    args.n = args.n or (1 << 26 if args.full else N_KEYS)
    args.q = args.q or (1 << 27 if args.full else N_LOOKUPS)
    return args


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds with jit warmup; blocks on results."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, seconds: float, derived: str = "") -> None:
    RESULTS.setdefault(_CURRENT_SUITE, {})[name] = seconds * 1e6
    print(f"{name},{seconds*1e6:.1f}us,{derived}", flush=True)
