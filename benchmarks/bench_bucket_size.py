"""Fig. 10: bucket-size sweep — construction breakdown + lookup breakdown.

Construction phases mirror the paper's: (1) sort keys+rowIDs, (2) packed
row-layout conversion (bucket matrix view), (3) representative extraction
(the triangle-set analogue), (4) search-structure build (fanout tree =
BVH), plus the RX (bucket size 1) baseline.  Lookup phases: (1) successor
search ("rays"), (2) bucket post-filter, (3) result write.
"""
from benchmarks.common import emit, parse_args, timeit

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bucketing, cgrx, fanout
from repro.core.keys import sort_with_payload
from repro.data import keygen


def main(args=None) -> None:
    args = args or parse_args()
    n, q = args.n, args.q // 4
    for uniformity in (0.0, 1.0):
        keys, rows, raw = keygen.keyset(n, uniformity, bits=64, seed=0)
        rows_j = jnp.asarray(rows)
        q_raw = keygen.uniform_lookups(raw, q, seed=1)
        qk = keygen.as_keys(q_raw, 64)

        for bucket in (2, 16, 256, 4096, 65536):
            # The post-filter materializes a (Q, B) gather; cap the query
            # count for large buckets so the working set stays ~2^24 rows
            # (the paper measures phases separately for the same reason).
            q_eff = max(min(q, (1 << 24) // bucket), 1024)
            qk_eff = qk[:q_eff] if q_eff < q else qk
            # --- construction breakdown ---
            t_sort = timeit(jax.jit(
                lambda k, r: sort_with_payload(k, r)[0].lo), keys, rows_j)
            bs = bucketing.build_buckets(keys, rows_j, bucket)
            t_build_all = timeit(
                lambda: cgrx.build(keys, rows_j, bucket).buckets.keys.lo,
                warmup=0, iters=1)
            t_tree = timeit(lambda: fanout.build_tree(bs.reps).levels[0].lo,
                            warmup=0, iters=1)
            idx = cgrx.build(keys, rows_j, bucket)
            total_bytes = cgrx.index_nbytes(idx)["total_bytes"]
            emit(f"fig10a_u{int(uniformity*100)}_b{bucket}", t_build_all,
                 f"sort={t_sort*1e3:.1f}ms;tree={t_tree*1e3:.1f}ms;"
                 f"bytes={total_bytes}")

            # --- lookup breakdown ---
            rep_fn = jax.jit(lambda qq: cgrx._rep_search(idx, qq, "left"))
            t_rays = timeit(rep_fn, qk_eff)
            bids = rep_fn(qk_eff)
            t_bucket = timeit(jax.jit(
                lambda b, qq: cgrx._bucket_count(idx, b, qq, "left")),
                bids, qk_eff)
            t_total = timeit(jax.jit(
                lambda qq: cgrx.lookup(idx, qq).row_id), qk_eff)
            emit(f"fig10b_u{int(uniformity*100)}_b{bucket}", t_total,
                 f"rays={t_rays*1e3:.1f}ms;bucket={t_bucket*1e3:.1f}ms")


if __name__ == "__main__":
    main()
