"""Durability costs: WAL append overhead and recovery vs tail length.

Two questions the durable serving tier (``IndexSpec(durability=...)``)
must answer with numbers:

1. **Write-path overhead** — the WAL appends + fsyncs every mixed batch
   BEFORE its device dispatch, so the 90/10 lookup/update mix of
   bench_live_store is rerun here twice, ``durability='none'`` vs
   ``'wal'``, same workload/seeds, and both totals are emitted; the
   overhead ratio is the number the durability docs quote.  The 'none'
   path is the historical memory-only session — CI gates it against the
   pre-durability baseline (bench_live_store), so this suite only needs
   the durable/none *ratio*.

2. **Recovery time vs WAL-tail length** — recovery = newest snapshot +
   replay, so its cost scales with the tail.  Fresh stores are run for
   increasing wave counts under 'wal' (one baseline snapshot, no
   re-snapshots), closed, and ``repro.db.recover_tier`` is timed cold.
   The 'wal+snapshot' mode exists exactly to bound this curve.
"""
from benchmarks.common import emit, parse_args

import shutil
import tempfile
import time

import numpy as np

import repro.db as db
from repro.data import keygen

WAVES = 8


def _wave(rng, live_np, space, n_ops, read_frac=0.9):
    n_read = int(n_ops * read_frac)
    n_write = n_ops - n_read
    n_ins = n_write // 2
    n_del = n_write - n_ins
    q = live_np[rng.integers(0, len(live_np), max(n_read, 1))]
    ins = np.setdiff1d(
        np.unique(rng.integers(0, space, int(n_ins * 1.5) + 8,
                               dtype=np.uint64)), live_np)[:n_ins]
    dels = live_np[rng.choice(len(live_np), n_del, replace=False)]
    return q, ins, dels


def _run_mix(spec, keys, rows, raw, ops, seed, waves=WAVES) -> float:
    """Total flush wall time over ``waves`` 90/10 mixed waves."""
    live_np = raw.copy()
    next_row = len(raw)
    rng = np.random.default_rng(seed)
    space = np.uint64((1 << 44) - 1)
    total = 0.0
    with db.open(spec, keys, rows) as sess:
        for _ in range(waves):
            q, ins, dels = _wave(rng, live_np, space, ops)
            sess.insert(keygen.as_keys(ins, 64),
                        np.arange(next_row, next_row + len(ins),
                                  dtype=np.int32))
            sess.delete(keygen.as_keys(dels, 64))
            sess.lookup(keygen.as_keys(q, 64))
            t0 = time.perf_counter()
            sess.flush()
            total += time.perf_counter() - t0
            next_row += len(ins)
            live_np = np.setdiff1d(np.concatenate([live_np, ins]), dels)
    return total


def main(args=None) -> None:
    args = args or parse_args()
    seed = getattr(args, "seed", None) or 0
    n = max(2048, min(args.n, 1 << 20) >> 6)
    ops = max(256, min(args.q, 1 << 21) >> 9)
    policy = db.CompactionPolicy(max_chain=3, min_fill=0.2,
                                 max_tombstone_ratio=0.5)
    base_kw = dict(tier="live", node_cap=32, max_hits=16, policy=policy)
    scratch = tempfile.mkdtemp(prefix="repro-bench-recovery-")
    try:
        # ---- 1. WAL append overhead on the 90/10 mix ----
        # Warmup pass: pay the XLA compiles (shared executable cache)
        # before either timed run, so 'none' vs 'wal' is fsync cost, not
        # who compiled first.
        keys, rows, raw = keygen.keyset(n, 1.0, bits=64, seed=seed)
        _run_mix(db.IndexSpec(**base_kw), keys, rows, raw, ops, seed + 1)
        keys, rows, raw = keygen.keyset(n, 1.0, bits=64, seed=seed)
        t_none = _run_mix(db.IndexSpec(**base_kw), keys, rows, raw,
                          ops, seed + 1)
        keys, rows, raw = keygen.keyset(n, 1.0, bits=64, seed=seed)
        t_wal = _run_mix(
            db.IndexSpec(**base_kw, durability="wal",
                         wal_dir=f"{scratch}/mix"),
            keys, rows, raw, ops, seed + 1)
        emit("recovery_mix90_none", t_none, f"waves={WAVES};ops={ops}")
        emit("recovery_mix90_wal", t_wal,
             f"waves={WAVES};ops={ops};"
             f"overhead={(t_wal / max(t_none, 1e-9) - 1) * 100:+.1f}%")

        # ---- 2. recovery time vs WAL-tail length ----
        for waves in (2, WAVES // 2, WAVES):
            spec = db.IndexSpec(**base_kw, durability="wal",
                                wal_dir=f"{scratch}/tail{waves}")
            keys, rows, raw = keygen.keyset(n, 1.0, bits=64, seed=seed)
            _run_mix(spec, keys, rows, raw, ops, seed + 2, waves=waves)
            t0 = time.perf_counter()
            tier, seq = db.recover_tier(spec)
            t_rec = time.perf_counter() - t0
            st = tier.stats()
            emit(f"recovery_tail{waves}", t_rec,
                 f"records={seq};live={st.live_keys};epoch={st.epoch}")
    finally:
        shutil.rmtree(scratch, ignore_errors=True)


if __name__ == "__main__":
    main()
