"""Vector tier: probe throughput vs brute force vs nprobe + live updates.

The ANN trade the coarse-bucket tier sells is the paper's trade: probe a
few centroid buckets (rank-engine range lookups) and post-filter exactly
(``distance_topk``), instead of scoring the whole corpus.  Emitted:

  probe_p*        us per probe batch at nprobe = 1 / quarter / all
                  (derived column: measured recall@10 vs brute force)
  brute_force     us per batch for the dense all-pairs top-k baseline
  insert_wave     us per live insert wave through the session write path
                  (derived: vectors/s)

CPU-container caveat: distances run through the jnp path (the Pallas
kernel is the TPU configuration); relative shape — probe cost growing
with nprobe toward the brute-force ceiling — is the signal, absolute
times are container-scale.
"""
from benchmarks.common import emit, parse_args, timeit

import jax
import jax.numpy as jnp
import numpy as np

import repro.db as db
from repro.data import keygen

DIM = 32
NCENT = 64
K = 10


def _recall(got: np.ndarray, want: np.ndarray) -> float:
    return float(np.mean([len(set(g) & set(w)) / len(w)
                          for g, w in zip(got, want)]))


def main(args=None) -> None:
    args = args or parse_args()
    seed = getattr(args, "seed", None) or 0
    n = max(2048, min(args.n, 1 << 17))
    q = max(64, min(args.q >> 6, 1024))

    corpus = keygen.embedding_set(n, DIM, nclusters=24, seed=seed)
    queries = keygen.embedding_queries(corpus, q, seed=seed + 1)
    qdev = jnp.asarray(queries)

    # Brute-force baseline: dense all-pairs distances + top-k on device.
    corpus_dev = jnp.asarray(corpus)

    @jax.jit
    def brute(qs):
        d2 = jnp.sum((corpus_dev[None, :, :] - qs[:, None, :]) ** 2, -1)
        neg, idx = jax.lax.top_k(-d2, K)
        return idx

    oracle = np.asarray(brute(qdev))
    t_brute = timeit(brute, qdev)
    emit("brute_force", t_brute, f"n={n} q={q}")

    cap = max(256, (4 * n) // NCENT)
    spec = db.IndexSpec(tier="live", kind="vector", dim=DIM,
                        ncentroids=NCENT, max_hits=cap)
    sess = db.open(spec, corpus)

    for p, tag in ((1, "p1"), (max(2, NCENT // 4), f"p{max(2, NCENT//4)}"),
                   (NCENT, "exhaustive")):
        def probe():
            return sess.probe_vectors(queries, K, nprobe=p).result()

        res = probe()
        rec = _recall(np.asarray(res.row_id), oracle)
        t = timeit(probe)
        emit(f"probe_{tag}", t,
             f"recall@{K}={rec:.3f} {t_brute/t:.2f}x-vs-brute")

    # Live update throughput: insert waves through the session path.
    waves = 4
    wave_n = max(256, n >> 4)
    fresh = keygen.embedding_set(waves * wave_n, DIM, nclusters=24,
                                 seed=seed + 2)
    import time as _time
    times = []
    for w in range(waves):
        t0 = _time.perf_counter()
        sess.insert_vectors(fresh[w * wave_n:(w + 1) * wave_n])
        sess.flush()
        times.append(_time.perf_counter() - t0)
    t_wave = float(np.median(times))
    emit("insert_wave", t_wave,
         f"{wave_n/t_wave:.0f}vec/s wave={wave_n}")


if __name__ == "__main__":
    main()
