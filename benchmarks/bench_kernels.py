"""Beyond-paper: Pallas kernel block-shape sweep (interpret mode).

Interpret-mode wall time is NOT TPU performance; the sweep's purpose is
(a) regression coverage over BlockSpec configurations and (b) the VMEM
working-set table per block shape that the §Perf napkin math uses.
"""
from benchmarks.common import emit, parse_args, timeit

import jax.numpy as jnp
import numpy as np

from repro.kernels import successor


def vmem_bytes(block_q, block_r, is64):
    lanes = 128
    per = 4  # uint32 words
    q = block_q * lanes * per * (2 if is64 else 1)
    r = block_r * lanes * per * (2 if is64 else 1)
    out = block_q * lanes * 4
    work = block_q * lanes * block_r * lanes * 1  # bool predicate tile
    return q + r + out + work


def main(args=None) -> None:
    args = args or parse_args()
    rng = np.random.default_rng(0)
    raw = np.sort(rng.integers(0, 1 << 40, 1 << 14, dtype=np.uint64))
    qs = rng.integers(0, 1 << 40, 1 << 12, dtype=np.uint64)
    rl = jnp.asarray((raw & 0xFFFFFFFF).astype(np.uint32))
    rh = jnp.asarray((raw >> np.uint64(32)).astype(np.uint32))
    ql = jnp.asarray((qs & 0xFFFFFFFF).astype(np.uint32))
    qh = jnp.asarray((qs >> np.uint64(32)).astype(np.uint32))

    for bq in (1, 2, 8):
        for br in (2, 8, 16):
            sec = timeit(lambda: successor.successor_count(
                rl, rh, ql, qh, "left", block_q=bq, block_r=br),
                warmup=1, iters=2)
            emit(f"kern_succ_bq{bq}_br{br}", sec,
                 f"vmem={vmem_bytes(bq, br, True)}B")


if __name__ == "__main__":
    main()
