"""Hostile-traffic scenario harness for the adaptive serving runtime.

Each scenario drives a full ``repro.db`` session loop (not a single
kernel) with one of the ``repro.data.keygen`` adversarial workload
shapes and measures what the tuning plane (telemetry bus + admission
controller + autotuner) does about it:

``flash_crowd``      a burst of overlapping range lookups against an
                     SLO'd session: the admission controller's deadline
                     flushing keeps request sojourn under the SLO where
                     the unprotected baseline batches itself into one
                     giant tail-blowing flush;
``zipf_hotshard``    spatially-Zipfian points on a sharded store: the
                     skew monitor's touch histogram triggers bounded
                     ``migrate_step`` ticks, vs the stop-and-rebuild
                     full rebalance's single long pause;
``boundary_hotspot`` points straddling ONE splitter (heat the size
                     histogram cannot see, split across two adjacent
                     shards) — the incremental migrator nudges that
                     splitter;
``tenant_mix``       mixed-skew multi-tenant points on the live tier:
                     the autotuner explores the flat backends and
                     commits to the measured-fastest.

Scenario sizes are capped (session-loop benchmarks are dominated by
flush count, not key count), so the suite doubles as the CI perf-smoke
job.  ``benchmarks.run --scenario <name>`` runs one scenario and stamps
its ``Session.telemetry()`` export into the ``--json`` payload under
``_telemetry`` alongside ``_meta``.
"""
from benchmarks.common import emit

import time

import numpy as np

import repro.db as db
from repro.data import keygen

# Session-loop scenarios: cap sizes so a scenario is flush-count bound.
MAX_N = 1 << 14
MAX_Q = 1 << 13


def _clamp(n, q):
    return min(n, MAX_N), min(q, MAX_Q)


def _p99(xs):
    return float(np.percentile(np.asarray(xs, np.float64), 99))


# ---------------------------------------------------------------------------
# flash_crowd: deadline flushing vs unprotected batching.
# ---------------------------------------------------------------------------

def scenario_flash_crowd(n: int, q: int, seed: int = 0) -> dict:
    n, q = _clamp(n, q // 8)
    slo_ms = 100.0          # CPU-container floor: a flush is ~tens of ms
    keys, rows, raw = keygen.keyset(n, 1.0, bits=32, seed=seed)
    lo, hi = keygen.flash_crowd_ranges(raw, q, width=32, crowd_frac=0.9,
                                       seed=seed + 1)

    def drive(spec):
        """Submit the crowd one range at a time (no manual flushing —
        the admission controller owns the flush decision) and record
        each request's sojourn: submit -> resolved by some flush."""
        sess = db.open(spec, keys, rows)
        # Warm the plan shapes (lanes pad to multiples of query.LANE, so
        # a couple of flush widths cover the steady state): jit compile
        # time is toolchain cost, not the queueing behavior under test.
        for w in (1, 48):
            sess.range(keygen.as_keys(lo[:w], 32),
                       keygen.as_keys(hi[:w], 32))
            sess.flush()
        sojourn, waiting = [], []
        for i in range(len(lo)):
            t0 = time.perf_counter()
            sess.range(keygen.as_keys(lo[i:i + 1], 32),
                       keygen.as_keys(hi[i:i + 1], 32))
            waiting.append(t0)
            if sess.pending == 0:          # a deadline flush drained us
                now = time.perf_counter()
                sojourn.extend(now - t for t in waiting)
                waiting.clear()
        sess.flush()
        now = time.perf_counter()
        sojourn.extend(now - t for t in waiting)
        tel = sess.telemetry()
        sess.close()
        return sojourn, tel

    sojourn_slo, tel = drive(db.IndexSpec(tier="live", slo_ms=slo_ms))
    sojourn_base, _ = drive(db.IndexSpec(tier="live"))

    p99_slo, p99_base = _p99(sojourn_slo), _p99(sojourn_base)
    viol = sum(s > slo_ms / 1e3 for s in sojourn_slo)
    emit("flash_crowd_p99_slo", p99_slo,
         f"slo={slo_ms}ms violations={viol}/{len(sojourn_slo)}")
    emit("flash_crowd_p99_baseline", p99_base, "unprotected")
    return tel


# ---------------------------------------------------------------------------
# zipf_hotshard: incremental migration vs stop-and-rebuild pause.
# ---------------------------------------------------------------------------

def _drive_sharded(keys, rows, batches, spec):
    """Run the lookup batches through flushes, timing each full flush
    call (autotuner actions INCLUDED — the pause is the point)."""
    sess = db.open(spec, keys, rows)
    pauses = []
    for qb in batches:
        sess.lookup(qb)
        t0 = time.perf_counter()
        sess.flush()
        pauses.append(time.perf_counter() - t0)
    tel = sess.telemetry()
    st = sess.tier.store.stats()
    sess.close()
    return pauses, tel, st


def scenario_zipf_hotshard(n: int, q: int, seed: int = 0) -> dict:
    n, q = _clamp(n, q)
    keys, rows, raw = keygen.keyset(n, 1.0, bits=32, seed=seed)
    hot = keygen.zipfian_keys(raw, q, theta=1.2, seed=seed + 1)
    batches = [keygen.as_keys(b, 32)
               for b in np.array_split(hot, 24) if len(b)]

    def spec(mode):
        return db.IndexSpec(tier="sharded", shards=4, autotune=True,
                            max_imbalance=1.3, rebalance_mode=mode,
                            migrate_max_keys=256)

    pauses_inc, tel, st = _drive_sharded(keys, rows, batches,
                                         spec("incremental"))
    _, tel_full, _ = _drive_sharded(keys, rows, batches, spec("full"))

    # The pause comparison is the placement action itself (bus spans the
    # autotuner records around migrate_step / rebalance), at steady
    # state (p50): the first ticks of each new apply shape pay a one-off
    # jit compile that is toolchain cost, not the per-tick pause.
    mig = tel["spans"].get("migrate", {"p50": 0.0, "n": 0})
    reb = tel_full["spans"].get("rebalance", {"p50": 0.0, "n": 0})
    emit("zipf_hotshard_migrate_tick_p50", mig["p50"],
         f"migrations={st.migrations}")
    emit("zipf_hotshard_rebalance_p50", reb["p50"],
         f"n={reb['n']} stop-and-rebuild")
    emit("zipf_hotshard_flush_p99", _p99(pauses_inc),
         f"touch_imb={st.touch_imbalance:.2f}")
    return tel


def scenario_boundary_hotspot(n: int, q: int, seed: int = 0) -> dict:
    n, q = _clamp(n, q)
    shards = 4
    keys, rows, raw = keygen.keyset(n, 1.0, bits=32, seed=seed)
    hot = keygen.boundary_hot_keys(raw, q, shards, boundary=2,
                                   width=256, seed=seed + 1)
    batches = [keygen.as_keys(b, 32)
               for b in np.array_split(hot, 24) if len(b)]
    spec = db.IndexSpec(tier="sharded", shards=shards, autotune=True,
                        max_imbalance=1.3, rebalance_mode="incremental",
                        migrate_max_keys=256)
    pauses, tel, st = _drive_sharded(keys, rows, batches, spec)
    emit("boundary_hotspot_flush_p99", _p99(pauses),
         f"migrations={st.migrations} "
         f"touch_imb={st.touch_imbalance:.2f}")
    return tel


# ---------------------------------------------------------------------------
# tenant_mix: backend explore-then-commit under mixed skew.
# ---------------------------------------------------------------------------

def scenario_tenant_mix(n: int, q: int, seed: int = 0) -> dict:
    n, q = _clamp(n, q)
    keys, rows, raw = keygen.keyset(n, 1.0, bits=32, seed=seed)
    mix, _tids = keygen.tenant_mix(raw, q, seed=seed + 1)
    batches = [keygen.as_keys(b, 32)
               for b in np.array_split(mix, 16) if len(b)]
    sess = db.open(db.IndexSpec(tier="live", autotune=True), keys, rows)
    for qb in batches:
        sess.lookup(qb)
        sess.flush()
    tel = sess.telemetry()
    sess.close()
    committed = tel["autotune"]["committed_backend"]
    q50 = tel["spans"].get("query", {}).get("p50", 0.0)
    emit("tenant_mix_query_p50", q50, f"backend={committed}")
    return tel


SCENARIOS = {
    "flash_crowd": scenario_flash_crowd,
    "zipf_hotshard": scenario_zipf_hotshard,
    "boundary_hotspot": scenario_boundary_hotspot,
    "tenant_mix": scenario_tenant_mix,
}


def run_scenario(name: str, n: int, q: int, seed: int = 0) -> dict:
    """Run ONE scenario; emits its metrics and returns the session's
    ``telemetry()`` export (stamped under ``_telemetry`` by run.py)."""
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; known: "
                       f"{sorted(SCENARIOS)}")
    return SCENARIOS[name](n, q, seed)


def main(args=None) -> None:
    from benchmarks.common import parse_args
    args = args or parse_args()
    seed = args.seed or 0
    for name in SCENARIOS:
        run_scenario(name, args.n, args.q, seed)


if __name__ == "__main__":
    main()
