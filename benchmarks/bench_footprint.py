"""Fig. 11: memory footprint, point-lookup time, throughput-per-byte
("bang for the buck") — cgRX{4,16,64,256} vs HT / B+ / SA / RX, 32-bit."""
from benchmarks.common import emit, parse_args, timeit

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as bl
from repro.core import cgrx, footprint
from repro.data import keygen


def main(args=None) -> None:
    args = args or parse_args()
    n, q = args.n, args.q // 2
    for uniformity in (0.0, 0.5, 1.0):
        keys, rows, raw = keygen.keyset(n, uniformity, bits=32, seed=0)
        rows_j = jnp.asarray(rows)
        q_raw = keygen.uniform_lookups(raw, q, seed=1)
        qk = keygen.as_keys(q_raw, 32)
        u = int(uniformity * 100)

        entries = []
        for b in (4, 16, 64, 256):
            idx = cgrx.build(keys, rows_j, b)
            fn = jax.jit(lambda qq: cgrx.lookup(idx, qq).row_id)
            sec = timeit(fn, qk)
            fp = footprint.footprint(idx, paper_model=True)["total_bytes"]
            entries.append((f"cgRX{b}", sec, fp))
        ht = bl.ht_build(keys, rows_j)
        entries.append(("HT", timeit(jax.jit(
            lambda qq: bl.ht_lookup(ht, qq).row_id), qk),
            footprint.footprint(ht)["total_bytes"]))
        bp = bl.bp_build(keys, rows_j)
        entries.append(("B+", timeit(jax.jit(
            lambda qq: bl.bp_lookup(bp, qq).row_id), qk),
            footprint.footprint(bp)["total_bytes"]))
        sa = bl.sa_build(keys, rows_j)
        entries.append(("SA", timeit(jax.jit(
            lambda qq: bl.sa_lookup(sa, qq).row_id), qk),
            footprint.footprint(sa)["total_bytes"]))
        rx = bl.rx_build(keys, rows_j)
        entries.append(("RX", timeit(jax.jit(
            lambda qq: bl.rx_lookup(rx, qq).row_id), qk),
            footprint.footprint(rx)["total_bytes"]))

        for name, sec, fp in entries:
            thr = q / sec
            emit(f"fig11_u{u}_{name}", sec,
                 f"bytes={fp};thr={thr:.3e}/s;bang={thr/fp:.4f}")


if __name__ == "__main__":
    main()
