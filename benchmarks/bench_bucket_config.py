"""Table 1: bucket search method (linear vs binary-rank) x memory layout
(column / aligned row / packed row), 64-bit keys, uniformity 100%."""
from benchmarks.common import emit, parse_args, timeit

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cgrx
from repro.core.keys import KeyArray, key_eq, key_le, key_lt
from repro.data import keygen


def linear_search_rank(rows: KeyArray, q: KeyArray) -> jnp.ndarray:
    """Left-to-right scan (paper's linear search): sequential fori."""
    B = rows.lo.shape[-1]

    def body(i, pos):
        ki = KeyArray(rows.lo[..., i],
                      None if rows.hi is None else rows.hi[..., i])
        return pos + key_lt(ki, q).astype(jnp.int32)

    return jax.lax.fori_loop(0, B, body, jnp.zeros(q.shape, jnp.int32))


def gather_layouts(idx, bucket_id, layout):
    """column: separate key/rowID arrays (two gathers);
    aligned row: 16-byte padded rows (k_hi, k_lo, rowid, pad);
    packed row: 12-byte rows (k_hi, k_lo, rowid)."""
    B = idx.bucket_size
    offs = bucket_id[:, None] * B + jnp.arange(B, dtype=jnp.int32)
    if layout == "column":
        ks = idx.buckets.keys.take(offs)
        rs = jnp.take(idx.buckets.row_ids, offs, mode="clip")
        return ks, rs
    # row layouts: interleaved uint32 words
    width = 4 if layout == "aligned" else 3
    k = idx.buckets.keys
    words = [k.hi if k.hi is not None else jnp.zeros_like(k.lo), k.lo,
             idx.buckets.row_ids.view(jnp.uint32) if hasattr(
                 idx.buckets.row_ids, "view")
             else idx.buckets.row_ids.astype(jnp.uint32)]
    if width == 4:
        words.append(jnp.zeros_like(k.lo))
    inter = jnp.stack(words, axis=1).reshape(-1)      # (n*width,)
    woffs = offs[..., None] * width + jnp.arange(width)
    rows = jnp.take(inter, woffs.reshape(offs.shape[0], -1), mode="clip")
    rows = rows.reshape(offs.shape[0], B, width)
    ks = KeyArray(rows[..., 1], rows[..., 0])
    rs = rows[..., 2].astype(jnp.int32)
    return ks, rs


def main(args=None) -> None:
    args = args or parse_args()
    n, q = args.n, args.q // 4
    keys, rows, raw = keygen.keyset(n, 1.0, bits=64, seed=0)
    q_raw = keygen.uniform_lookups(raw, q, seed=1)
    qk = keygen.as_keys(q_raw, 64)

    for bucket in (4, 16, 256):
        idx = cgrx.build(keys, jnp.asarray(rows), bucket)

        for search in ("binary", "linear"):
            if search == "linear" and bucket > 256:
                continue
            for layout in ("column", "aligned", "packed"):
                def lookup(qq):
                    b = cgrx._rep_search(idx, qq, "left")
                    bc = jnp.minimum(b, idx.num_buckets - 1)
                    ks, rs = gather_layouts(idx, bc, layout)
                    qb = KeyArray(qq.lo[:, None],
                                  None if qq.hi is None else qq.hi[:, None])
                    if search == "binary":
                        pos = jnp.sum(key_lt(ks, qb).astype(jnp.int32), -1)
                    else:
                        pos = linear_search_rank(ks, qq)
                    safe = jnp.minimum(pos, idx.bucket_size - 1)
                    hit_lo = jnp.take_along_axis(ks.lo, safe[:, None], 1)[:, 0]
                    hit_hi = (jnp.take_along_axis(ks.hi, safe[:, None], 1)[:, 0]
                              if ks.hi is not None else None)
                    found = key_eq(KeyArray(hit_lo, hit_hi), qq)
                    return jnp.where(
                        found,
                        jnp.take_along_axis(rs, safe[:, None], 1)[:, 0], -1)

                fn = jax.jit(lookup)
                sec = timeit(fn, qk)
                emit(f"table1_b{bucket}_{search}_{layout}", sec,
                     f"q={q}")


if __name__ == "__main__":
    main()
