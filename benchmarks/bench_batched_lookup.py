"""Batched rank engine vs per-query dispatch: throughput vs batch size.

For each batch size B the same mixed workload (3/4 point lookups, 1/8
ranges = 1/4 of lanes) is served two ways:

    unbatched   one jitted device call per request (the seed's serving
                shape: B dispatches per tick);
    batched     one ``RankEngine.execute`` call for the whole planned
                lane batch (one dispatch per tick).

Output rows: ``batched_lookup/<backend>/b<B>,<us>,<qps + speedup>``.
The paper-relevant number is the speedup at production batch sizes
(acceptance floor: >= 2x at B=256 on the CPU backend) — the per-call
overhead the batching amortizes is exactly what RT-core batching buys
RTCUDB on GPU.

    PYTHONPATH=src python -m benchmarks.bench_batched_lookup [--tiny]

``--tiny`` is the CI smoke shape (small key set, two batch sizes, jnp
backends only — interpret-mode kernels are too slow for smoke runs).
"""
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import cgrx
from repro.data import keygen
from repro.query import QueryBatch, RankEngine


def _workload(raw, batch, seed):
    """Mixed batch: 3/4 point keys (hits), 1/8 ranges (2 lanes each)."""
    rng = np.random.default_rng(seed)
    n_point = (batch * 3) // 4
    n_range = (batch - n_point) // 2
    pts = keygen.as_keys(rng.choice(raw, n_point), 64)
    sraw = np.sort(raw)
    starts = rng.integers(0, len(sraw) - 64, n_range)
    lo = keygen.as_keys(sraw[starts], 64)
    hi = keygen.as_keys(sraw[starts + rng.integers(1, 64, n_range)], 64)
    return pts, lo, hi, n_point, n_range


def main(args) -> None:
    tiny = getattr(args, "tiny", False)
    n = min(args.n, 1 << 14) if tiny else args.n
    batches = (64, 256) if tiny else (16, 64, 256, 1024)
    backends = ("tree", "binary") if tiny else ("tree", "binary", "kernel")
    max_hits = 64

    rng = np.random.default_rng(0)
    raw = np.unique(rng.integers(0, 1 << 44, int(2.5 * n),
                                 dtype=np.uint64))[:n]
    keys = keygen.as_keys(raw, 64)
    rows = jnp.arange(len(raw), dtype=jnp.int32)

    for backend in backends:
        idx = cgrx.build(keys, rows, 16, method=backend)
        engine = RankEngine(idx)
        # Interpret-mode kernels pay a large python-per-grid-step cost in
        # the unbatched loop; keep that suite at serving-scale batches.
        bs = tuple(b for b in batches if b <= 256) \
            if backend == "kernel" else batches
        for batch in bs:
            pts, lo, hi, n_point, n_range = _workload(raw, batch, seed=batch)
            plan = (QueryBatch().add_points(pts).add_ranges(lo, hi)
                    .plan(max_hits=max_hits))

            # Unbatched: one device call per request (jitted per shape).
            one_pt = jax.jit(lambda q: cgrx.lookup(idx, q).row_id)
            one_rg = jax.jit(
                lambda a, b: cgrx.range_lookup(idx, a, b, max_hits).count)

            def unbatched():
                outs = [one_pt(pts[i:i + 1]) for i in range(n_point)]
                outs += [one_rg(lo[i:i + 1], hi[i:i + 1])
                         for i in range(n_range)]
                return outs

            def batched():
                res = engine.execute(plan)
                return res.points.row_id, res.ranges.count

            # Lighter timing for the interpret-mode kernel backend.
            iters = 1 if backend == "kernel" else 3
            sec_u = timeit(unbatched, iters=iters)
            sec_b = timeit(batched, iters=iters)
            q = n_point + n_range
            emit(f"batched_lookup/{backend}/b{batch}/unbatched", sec_u,
                 f"{q / sec_u:,.0f}qps")
            emit(f"batched_lookup/{backend}/b{batch}/batched", sec_b,
                 f"{q / sec_b:,.0f}qps speedup={sec_u / sec_b:.1f}x")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shape (small n, jnp backends only)")
    ap.add_argument("--n", type=int, default=1 << 18)
    args = ap.parse_args()
    main(args)
