"""Training substrate: loss goes down, microbatching is exact, ef-compression."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.data import tokens as data_tokens
from repro.models import lm
from repro.training import compression, optim, step as step_mod


def test_loss_decreases_on_synthetic_data():
    cfg = get_config("yi-6b").tiny()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.init_state(params)
    fn = jax.jit(step_mod.make_train_step(
        cfg, optim.AdamWConfig(lr_peak=3e-3, warmup_steps=2,
                               total_steps=25)))
    losses = []
    for i in range(25):
        batch = jax.tree.map(jnp.asarray, data_tokens.synthetic_batch(
            i % 4, 8, 64, cfg.vocab_size))
        params, opt, m = fn(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


def test_microbatched_grads_match_full():
    cfg = get_config("starcoder2-3b").tiny()
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    opt = optim.init_state(params)
    batch = jax.tree.map(jnp.asarray, data_tokens.synthetic_batch(
        0, 8, 32, cfg.vocab_size))
    ocfg = optim.AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=5)
    p1, _, m1 = jax.jit(step_mod.make_train_step(cfg, ocfg, 1))(
        params, opt, batch)
    p4, _, m4 = jax.jit(step_mod.make_train_step(cfg, ocfg, 4))(
        params, opt, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-2
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-2, atol=3e-3)


def test_lr_schedule_shape():
    c = optim.AdamWConfig(lr_peak=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(optim.lr_schedule(c, jnp.int32(s))) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup rises
    assert lrs[2] == pytest.approx(1.0)      # peak
    assert lrs[4] == pytest.approx(0.1, abs=0.02)  # decays to 10%


def test_grad_clip():
    c = optim.AdamWConfig(clip_norm=1.0)
    params = {"w": jnp.zeros((4,))}
    state = optim.init_state(params)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, m = optim.apply_updates(c, params, state, huge)
    assert float(m["grad_norm"]) > 1e6  # reported pre-clip


def test_ef_quantization_preserves_signal():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(256, 64)), jnp.float32)}
    err = compression.init_error(g)
    # accumulate: sum of dequantized + final error == sum of true grads
    total_true = np.zeros((256, 64), np.float32)
    total_deq = np.zeros((256, 64), np.float32)
    for i in range(20):
        gi = {"w": jnp.asarray(rng.normal(size=(256, 64)), jnp.float32)}
        total_true += np.asarray(gi["w"])
        deq, err = compression.ef_quantize(gi, err)
        total_deq += np.asarray(deq["w"])
    resid = total_true - total_deq
    np.testing.assert_allclose(resid, np.asarray(err["w"]), rtol=1e-3,
                               atol=1e-3)
    # error stays bounded by one quantization step
    assert np.abs(np.asarray(err["w"])).max() < 0.1


def test_allreduce_bytes_estimate():
    g = {"w": jnp.zeros((1000,))}
    assert compression.estimate_allreduce_bytes(g, False) == 4000
    assert compression.estimate_allreduce_bytes(g, True) == 1000
