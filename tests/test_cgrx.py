"""cgRX index invariants vs the sorted-array oracle (paper Alg. 1-2)."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import cgrx
from repro.core.keys import KeyArray


def mk(raw, is64=True):
    raw = np.asarray(raw, dtype=np.uint64)
    return KeyArray.from_u64(raw) if is64 else KeyArray.from_u32(
        raw.astype(np.uint32))


def build_random(n, bucket, method, is64=True, seed=0, space=1 << 48):
    rng = np.random.default_rng(seed)
    raw = np.unique(rng.integers(0, space, int(2.5 * n), dtype=np.uint64))[:n]
    keys = mk(raw, is64)
    idx = cgrx.build(keys, jnp.arange(len(raw), dtype=jnp.int32), bucket,
                     method=method)
    return raw, keys, idx


@pytest.mark.parametrize("method", ["tree", "binary", "kernel"])
@pytest.mark.parametrize("bucket", [2, 16, 64])
def test_point_lookup_hits(method, bucket):
    raw, keys, idx = build_random(3000, bucket, method)
    rng = np.random.default_rng(1)
    sel = rng.integers(0, len(raw), 700)
    res = cgrx.lookup(idx, keys[sel])
    assert bool(res.found.all())
    assert (raw[np.asarray(res.row_id)] == raw[sel]).all()
    # bucket id must contain the key's rank
    order = np.argsort(raw, kind="stable")
    rank = {int(raw[o]): i for i, o in enumerate(order)}
    want_bucket = np.array([rank[int(raw[s])] // bucket for s in sel])
    assert (np.asarray(res.bucket_id) == want_bucket).all()


@pytest.mark.parametrize("method", ["tree", "binary"])
def test_point_lookup_misses(method):
    raw, keys, idx = build_random(2000, 16, method)
    rng = np.random.default_rng(2)
    probe = rng.integers(0, 1 << 48, 3000, dtype=np.uint64)
    misses = np.setdiff1d(probe, raw)[:500]
    res = cgrx.lookup(idx, mk(misses))
    assert not bool(res.found.any())
    assert (np.asarray(res.row_id) == -1).all()


@given(st.integers(2, 64), st.integers(10, 400), st.integers(0, 2**32))
@settings(max_examples=15, deadline=None)
def test_rank_equals_numpy_searchsorted(bucket, n, seed):
    rng = np.random.default_rng(seed)
    raw = np.unique(rng.integers(0, 1 << 40, 3 * n, dtype=np.uint64))[:n]
    keys = mk(raw)
    idx = cgrx.build(keys, None, bucket)
    q = rng.integers(0, 1 << 40, 64, dtype=np.uint64)
    q[:8] = raw[rng.integers(0, len(raw), 8)]
    sraw = np.sort(raw)
    for side in ("left", "right"):
        got = np.asarray(cgrx.rank(idx, mk(q), side=side))
        assert (got == np.searchsorted(sraw, q, side=side)).all()


def test_duplicates_first_bucket():
    # duplicate keys spanning buckets: lookup returns the FIRST occurrence.
    raw = np.array([3, 7, 7, 7, 7, 7, 9, 12, 15, 20], np.uint64)
    rows = jnp.arange(10, dtype=jnp.int32)
    idx = cgrx.build(mk(raw), rows, 2)
    res = cgrx.lookup(idx, mk(np.array([7], np.uint64)))
    assert bool(res.found.all())
    assert int(res.position[0]) == 1  # rank_left of 7
    # range [7,7] returns all five duplicates
    rr = cgrx.range_lookup(idx, mk(np.array([7], np.uint64)),
                           mk(np.array([7], np.uint64)), max_hits=8)
    assert int(rr.count[0]) == 5


@pytest.mark.parametrize("method", ["tree", "binary", "kernel"])
def test_range_lookup_vs_oracle(method):
    raw, keys, idx = build_random(2500, 16, method, seed=5)
    sraw = np.sort(raw)
    rng = np.random.default_rng(3)
    starts = rng.integers(0, len(raw) - 130, 40)
    widths = rng.integers(1, 128, 40)
    lo = sraw[starts]
    hi = sraw[np.minimum(starts + widths, len(raw) - 1)]
    rr = cgrx.range_lookup(idx, mk(lo), mk(hi), max_hits=160)
    order = np.argsort(raw, kind="stable")
    for i in range(len(starts)):
        span = order[starts[i]:min(starts[i] + widths[i], len(raw) - 1) + 1]
        got = set(np.asarray(rr.row_ids[i]).tolist()) - {-1}
        assert got == set(span.tolist())
        assert int(rr.count[i]) == len(span)


def test_empty_range():
    raw, keys, idx = build_random(500, 8, "tree")
    hi_key = np.array([raw.max() + 10], np.uint64)
    rr = cgrx.range_lookup(idx, mk(hi_key), mk(hi_key + 5), max_hits=4)
    assert int(rr.count[0]) == 0


def test_footprint_decreases_with_bucket_size():
    raw, _, idx4 = build_random(4000, 4, "tree")
    _, _, idx64 = build_random(4000, 64, "tree")
    f4 = cgrx.index_nbytes(idx4)
    f64 = cgrx.index_nbytes(idx64)
    assert f64["rep_bytes"] < f4["rep_bytes"]
    assert f64["tree_bytes"] <= f4["tree_bytes"]
    # key-rowID array is the same data either way
    assert abs(f64["key_rowid_bytes"] - f4["key_rowid_bytes"]) \
        <= 64 * 12  # padding slack
