import os
import sys

# Force the CPU platform: skips third-party PJRT plugin discovery (a
# partially-installed neuron plugin in this image can corrupt jax internals)
# and keeps tests seeing exactly ONE device (the dry-run sets its own flags).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
