"""Paper-faithful grid scene: Algorithm 2 lookups, markers, flipping."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import grid
from repro.core.keys import KeyArray


def mk(raw, is64=True):
    raw = np.asarray(raw, dtype=np.uint64)
    return KeyArray.from_u64(raw) if is64 else KeyArray.from_u32(
        raw.astype(np.uint32))


@pytest.mark.parametrize("representation", ["naive", "optimized"])
@pytest.mark.parametrize("is64,space", [(False, 1 << 32), (True, 1 << 55)])
def test_grid_lookup_hits_and_misses(representation, is64, space):
    rng = np.random.default_rng(7)
    n = 2500
    raw = np.unique(rng.integers(0, space, 3 * n, dtype=np.uint64))[:n]
    keys = mk(raw, is64)
    scene, buckets = grid.build_scene(keys, jnp.arange(len(raw), dtype=jnp.int32),
                                      8, representation)
    sel = rng.integers(0, len(raw), 800)
    rowid, found, rays = grid.point_lookup(scene, buckets, keys[sel])
    assert bool(found.all())
    assert (raw[np.asarray(rowid)] == raw[sel]).all()
    # misses must be detected exactly
    probe = rng.integers(0, space, 2000, dtype=np.uint64)
    info = np.isin(probe, raw)
    rowid, found, _ = grid.point_lookup(scene, buckets, mk(probe, is64))
    assert (np.asarray(found) == info).all()


def test_optimized_fires_fewer_rays_and_triangles():
    """Paper Sec. 5.2: for sparse 64-bit sets the optimized representation
    fires fewer rays and materializes fewer triangles."""
    rng = np.random.default_rng(8)
    raw = np.unique(rng.integers(0, 1 << 55, 9000, dtype=np.uint64))[:8000]
    keys = mk(raw)
    sn, bn = grid.build_scene(keys, None, 8, "naive")
    so, bo = grid.build_scene(keys, None, 8, "optimized")
    sel = rng.integers(0, len(raw), 2000)
    _, _, rays_n = grid.point_lookup(sn, bn, keys[sel])
    _, _, rays_o = grid.point_lookup(so, bo, keys[sel])
    assert float(rays_o.mean()) < float(rays_n.mean())
    assert so.triangles_materialized < sn.triangles_materialized


def test_prim_remap_formula():
    nb = 5
    prim = jnp.array([0, 4, 5, 9, 10, 14])
    got = np.asarray(grid.remap_prim(prim, nb))
    # paper: i>=2nb -> i-2nb+1 ; i>=nb -> i-nb+1 ; else i
    assert got.tolist() == [0, 4, 1, 5, 1, 5]


def test_single_row_skips_markers():
    # All keys in one row (same y,z): no row/plane markers allocated.
    raw = np.arange(10, 40, dtype=np.uint64)   # x bits only
    scene, _ = grid.build_scene(mk(raw, False), None, 4, "naive")
    assert not scene.multi_line and not scene.multi_plane
    assert scene.slots_allocated == scene.num_buckets


def test_32bit_single_plane():
    rng = np.random.default_rng(9)
    raw = np.unique(rng.integers(0, 1 << 32, 4000, dtype=np.uint64))[:3000]
    scene, buckets = grid.build_scene(mk(raw, False), None, 8, "optimized")
    assert not scene.multi_plane  # 32-bit keys always share z=0
    sel = rng.integers(0, len(raw), 500)
    _, found, rays = grid.point_lookup(scene, buckets, mk(raw[sel], False))
    assert bool(found.all())
    # paper: 32-bit lookups need at most 3 rays
    assert int(np.asarray(rays).max()) <= 3


def test_memory_model_accounting():
    rng = np.random.default_rng(10)
    raw = np.unique(rng.integers(0, 1 << 50, 5000, dtype=np.uint64))[:4000]
    sn, _ = grid.build_scene(mk(raw), None, 8, "naive")
    so, _ = grid.build_scene(mk(raw), None, 8, "optimized")
    mn, mo = sn.nbytes_model(), so.nbytes_model()
    # naive allocates (1+multiLine+multiPlane)*nb slots; optimized <= same
    assert mo["vertex_buffer_bytes"] <= mn["vertex_buffer_bytes"]


def test_kernel_probe_parity():
    """Pallas ray-probe backend == pure-jnp probes (same buckets + rays)."""
    rng = np.random.default_rng(11)
    raw = np.unique(rng.integers(0, 1 << 55, 3000, dtype=np.uint64))[:2000]
    keys = mk(raw)
    for representation in ("naive", "optimized"):
        scene, buckets = grid.build_scene(keys, None, 8, representation)
        sel = rng.integers(0, len(raw), 300)
        a = grid.lookup(scene, keys[sel], use_kernel=False)
        b = grid.lookup(scene, keys[sel], use_kernel=True)
        assert (np.asarray(a.bucket_id) == np.asarray(b.bucket_id)).all()
        assert (np.asarray(a.rays) == np.asarray(b.rays)).all()
