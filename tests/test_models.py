"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + finiteness (assignment requirement), plus decode parity."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import lm


def make_batch(cfg, B=2, S=64, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)),
    }
    if cfg.num_patches:
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_patches, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_loss(arch):
    cfg = get_config(arch).tiny()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    hidden = jax.jit(lambda p, b: lm.forward(cfg, p, b))(params, batch)
    S_total = 64 + cfg.num_patches
    assert hidden.shape == (2, S_total, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, dtype=np.float32)).all()
    loss, metrics = jax.jit(lambda p, b: lm.loss_fn(cfg, p, b))(params, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0


@pytest.mark.parametrize("arch", ["dbrx-132b", "zamba2-1.2b", "qwen3-32b",
                                  "mamba2-370m", "deepseek-v2-lite-16b"])
def test_smoke_train_step(arch):
    """One full gradient step (representative family members)."""
    from repro.training import optim, step as step_mod
    cfg = get_config(arch).tiny()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.init_state(params)
    fn = jax.jit(step_mod.make_train_step(cfg, optim.AdamWConfig(
        lr_peak=1e-3, warmup_steps=1, total_steps=10)))
    p2, o2, m = fn(params, opt, make_batch(cfg))
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"])) and float(m["grad_norm"]) > 0
    assert int(o2.step) == 1
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode(arch):
    cfg = get_config(arch).tiny()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B = 2
    caches = lm.init_decode_caches(cfg, B, 32)
    tok = jnp.ones((B, 1), jnp.int32)
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(cfg, p, c, t, pos))
    logits, caches = step(params, caches, tok, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    logits2, caches = step(params, caches, tok, jnp.int32(1))
    assert np.isfinite(np.asarray(logits2)).all()


def test_decode_matches_prefill_gqa():
    """Teacher-forced decode logits == prefill logits (dense GQA arch)."""
    cfg = get_config("yi-6b").tiny()
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 1, 12
    rng = np.random.default_rng(3)
    toks = rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)
    batch = {"tokens": jnp.asarray(toks)}
    hidden = lm.forward(cfg, params, batch)
    full_logits = lm.logits_chunked(cfg, params, hidden)

    caches = lm.init_decode_caches(cfg, B, S + 2)
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(cfg, p, c, t, pos))
    outs = []
    for i in range(S):
        lg, caches = step(params, caches, jnp.asarray(toks[:, i:i + 1]),
                          jnp.int32(i))
        outs.append(np.asarray(lg[0, 0], np.float32))
    dec = np.stack(outs)
    ref = np.asarray(full_logits[0], np.float32)
    np.testing.assert_allclose(dec, ref, rtol=0.08, atol=0.08)


def test_decode_matches_prefill_mamba():
    cfg = get_config("mamba2-370m").tiny()
    params = lm.init_params(cfg, jax.random.PRNGKey(2))
    B, S = 1, 16
    rng = np.random.default_rng(4)
    toks = rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)
    hidden = lm.forward(cfg, params, {"tokens": jnp.asarray(toks)})
    full_logits = lm.logits_chunked(cfg, params, hidden)
    caches = lm.init_decode_caches(cfg, B, S)
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(cfg, p, c, t, pos))
    outs = []
    for i in range(S):
        lg, caches = step(params, caches, jnp.asarray(toks[:, i:i + 1]),
                          jnp.int32(i))
        outs.append(np.asarray(lg[0, 0], np.float32))
    np.testing.assert_allclose(np.stack(outs),
                               np.asarray(full_logits[0], np.float32),
                               rtol=0.1, atol=0.15)


def test_blockwise_attention_equals_naive():
    from repro.models.attention import blockwise_causal_attention
    rng = np.random.default_rng(5)
    B, S, H, KV, D = 2, 50, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
    out = blockwise_causal_attention(q, k, v, block_q=16, block_kv=8)
    # naive reference
    G = H // KV
    kf = jnp.repeat(k, G, axis=2)
    vf = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kf) / np.sqrt(D)
    mask = np.tril(np.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vf)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_scan_matches_sequential():
    """Chunked SSD == naive per-step recurrence."""
    from repro.models.ssm import ssd_scan, ssd_decode_step
    rng = np.random.default_rng(6)
    b, L, h, p, g, n = 2, 37, 4, 8, 1, 16
    x = jnp.asarray(rng.normal(size=(b, L, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, size=(b, L, h)), jnp.float32)
    A = jnp.asarray(-np.exp(rng.normal(size=(h,)) * 0.3), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, L, g, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, L, g, n)), jnp.float32)
    y, final = ssd_scan(x, dt, A, B, C, chunk=8)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(L):
        yt, state = ssd_decode_step(state, x[:, t], dt[:, t], A, B[:, t],
                                    C[:, t])
        ys.append(yt)
    ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(final), np.asarray(state),
                               rtol=2e-3, atol=2e-3)


def test_int8_kv_cache_decode_close_to_bf16():
    """Quantized KV cache (beyond-paper 'bang per byte'): decode logits
    stay within a few percent of the bf16 cache."""
    cfg = get_config("qwen1.5-32b").tiny()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 10
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)
    c16 = lm.init_decode_caches(cfg, B, S + 2)
    c8 = lm.init_decode_caches(cfg, B, S + 2, dtype=jnp.int8)
    assert c8.kv[0].dtype == jnp.int8 and c8.kv_scale is not None
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(cfg, p, c, t, pos))
    outs16, outs8 = [], []
    for i in range(S):
        t = jnp.asarray(toks[:, i:i + 1])
        lg16, c16 = step(params, c16, t, jnp.int32(i))
        lg8, c8 = step(params, c8, t, jnp.int32(i))
        outs16.append(np.asarray(lg16))
        outs8.append(np.asarray(lg8))
    a, b = np.stack(outs16), np.stack(outs8)
    rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert rel < 0.05, rel
