"""Property tests for the splitter math in core/distributed.py.

The routing layer (``route_keys`` / ``route_ranges`` over
``compute_splitters``/``partition_cuts``) is the ownership contract BOTH
serving tiers build on — the static mesh path and the live sharded store
agree on which shard owns a key only because they share these functions.
These tests pin the contract against brute-force host oracles:

  * a splitter is a shard's max key; shard ``s`` owns the half-open
    interval ``(splitters[s-1], splitters[s]]`` and the LAST shard also
    absorbs everything beyond the last splitter;
  * round-trip: every key of the build set routes to the shard whose
    ``partition_cuts`` slice physically holds it;
  * a range's ``(first, last)`` span is exactly the set of shards whose
    owned interval intersects ``[lo, hi]``.

Runs hypothesis-driven when hypothesis is installed (randomized key sets
and cut points) and as fixed-seed sweeps always, via the
``tests/_hypothesis_compat.py`` shim.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.distributed import (compute_splitters, partition_cuts,
                                    route_keys, route_ranges)
from repro.core.keys import KeyArray


def brute_route(splitters_np: np.ndarray, keys_np: np.ndarray) -> np.ndarray:
    """Oracle owner per key: first shard whose max-key splitter is >= the
    key (linear scan, not a searchsorted — deliberately a different
    algorithm); keys beyond every splitter go to the last shard."""
    S = len(splitters_np)
    out = np.empty(len(keys_np), np.int32)
    for i, k in enumerate(keys_np):
        for s in range(S):
            if k <= splitters_np[s]:
                out[i] = s
                break
        else:
            out[i] = S - 1
    return out


def brute_span(splitters_np: np.ndarray, lo: np.ndarray,
               hi: np.ndarray):
    """Oracle (first, last) intersecting shard per range, by checking
    every shard's owned interval (prev_splitter, splitter] (+inf for the
    last shard) against [lo, hi]."""
    S = len(splitters_np)
    firsts, lasts = [], []
    for L, U in zip(lo, hi):
        hit = []
        for s in range(S):
            lower = int(splitters_np[s - 1]) if s else -1
            upper = int(splitters_np[s]) if s < S - 1 else (1 << 63)
            if int(U) > lower and int(L) <= upper:
                hit.append(s)
        # Empty intersection can't happen: shard 0's interval starts
        # below every key and the last shard's is unbounded above.
        firsts.append(hit[0])
        lasts.append(hit[-1])
    return np.array(firsts, np.int32), np.array(lasts, np.int32)


def check_splitter_contract(seed: int, n: int, num_shards: int) -> None:
    rng = np.random.default_rng(seed)
    raw = np.unique(rng.integers(0, 1 << 44, int(n * 1.5) + num_shards,
                                 dtype=np.uint64))[:max(n, num_shards)]
    skeys = KeyArray.from_u64(raw)
    splitters = compute_splitters(skeys, num_shards)
    splitters_np = splitters.to_numpy()
    cuts = partition_cuts(len(raw), num_shards)

    # compute_splitters = last key of each partition_cuts slice.
    want = raw[np.maximum(cuts[1:] - 1, 0)]
    assert (splitters_np == want).all()

    # Round-trip: each build key routes to the slice that holds it.
    owner = np.asarray(route_keys(splitters, skeys))
    slice_of = np.searchsorted(cuts[1:], np.arange(len(raw)), side="right")
    assert (owner == slice_of).all(), "route_keys disagrees with the cuts"

    # Probe keys (members, misses, beyond-max) vs the brute-force oracle.
    probes = np.unique(np.concatenate([
        raw[rng.integers(0, len(raw), 64)],
        rng.integers(0, 1 << 44, 64, dtype=np.uint64),
        np.array([0, raw.max(), raw.max() + 7], dtype=np.uint64),
    ]))
    got = np.asarray(route_keys(splitters, KeyArray.from_u64(probes)))
    assert (got == brute_route(splitters_np, probes)).all()

    # Ranges (random endpoints, ordered) vs the interval-intersection
    # oracle; also the route_keys consistency first == owner(lo).
    a = rng.integers(0, 1 << 44, 48, dtype=np.uint64)
    b = rng.integers(0, 1 << 44, 48, dtype=np.uint64)
    lo, hi = np.minimum(a, b), np.maximum(a, b)
    first, last = route_ranges(splitters, KeyArray.from_u64(lo),
                               KeyArray.from_u64(hi))
    first, last = np.asarray(first), np.asarray(last)
    wfirst, wlast = brute_span(splitters_np, lo, hi)
    assert (first == wfirst).all() and (last == wlast).all()
    assert (first <= last).all()


@pytest.mark.parametrize("seed,n,num_shards", [
    (0, 500, 4), (1, 64, 8), (2, 1000, 3), (3, 17, 5), (4, 300, 1),
])
def test_splitter_contract_fixed(seed, n, num_shards):
    check_splitter_contract(seed, n, num_shards)


def test_partition_cuts_shape_and_errors():
    cuts = partition_cuts(10, 4)
    assert cuts[0] == 0 and cuts[-1] == 10
    assert (np.diff(cuts) >= 0).all()
    assert len(cuts) == 5
    with pytest.raises(ValueError):
        partition_cuts(3, 4)


@given(st.integers(0, 2**31), st.integers(8, 600), st.integers(1, 9))
@settings(max_examples=20, deadline=None)
def test_property_splitter_contract(seed, n, num_shards):
    check_splitter_contract(seed, n, num_shards)
