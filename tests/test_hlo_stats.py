"""HLO parsers: collective bytes and loop-trip correction."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_loops, hlo_stats


def test_shape_bytes():
    assert hlo_stats.shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert hlo_stats.shape_bytes("(bf16[4], s32[2,2])") == 8 + 16
    assert hlo_stats.shape_bytes("pred[]") == 1


def test_collective_stats_synthetic():
    hlo = """
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[8,256]{1,0} all-gather(%y), dimensions={0}
  %d = f32[2]{0} all-reduce-done(%s)
"""
    st = hlo_stats.collective_stats(hlo)
    assert st["all-reduce"]["bytes"] == 4096
    assert st["all-gather"]["bytes"] == 8 * 256 * 2


def test_loop_correction_counts_scan_trips():
    """A jitted scan of matmuls: corrected flops ≈ trips x body flops."""
    M = 64
    TRIPS = 7

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=TRIPS)
        return y

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((M, M), jnp.float32),
        jax.ShapeDtypeStruct((M, M), jnp.float32)).compile()
    res = hlo_loops.analyze(comp.as_text())
    want = 2 * M * M * M * TRIPS
    got = res["corrected_flops"]
    assert 0.9 * want <= got <= 1.1 * want, (got, want)
    # flat cost_analysis undercounts by the trip factor
    ca = comp.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flat = ca.get("flops", 0)
    assert flat < got / (TRIPS - 1)


def test_nested_loops_multiply():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    res = hlo_loops.analyze(comp.as_text())
    want = 2 * 32 ** 3 * 15
    assert 0.85 * want <= res["corrected_flops"] <= 1.15 * want
