"""Sharding rule engine: every large parameter must actually shard.

Regression guard for the replicated-MLP bug: a rule pattern that silently
fails to match leaves the weight replicated — semantically fine, fatally
wasteful at 512 chips.  This test walks every assigned architecture's
abstract parameter tree on a 4x4 mesh and asserts no leaf above 1M
elements resolves to a fully-replicated spec.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.models import lm
from repro.parallel import sharding

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


@pytest.fixture(scope="module")
def mesh():
    # 1 host device is enough: specs are resolved against mesh *shape*.
    dev = jax.devices()[0]
    return jax.sharding.Mesh(
        np.array([dev] * 1).reshape(1, 1), ("data", "model"))


def fake_mesh_shape():
    class M:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 4}
    return M()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_no_large_replicated_params(arch):
    cfg = get_config(arch)
    params_shape = jax.eval_shape(
        lambda k: lm.init_params(cfg, k), jax.random.PRNGKey(0))
    mesh = fake_mesh_shape()
    axes = sharding.MeshAxes()
    offenders = []

    def leaf(path, x):
        pstr = sharding._path_str(path)
        spec = sharding.spec_for_param(pstr, x.shape, mesh, axes)
        n = int(np.prod(x.shape))
        if n > 1_000_000 and all(s is None for s in spec):
            offenders.append((pstr, x.shape))
        return spec

    jax.tree_util.tree_map_with_path(leaf, params_shape)
    assert not offenders, offenders


def test_mlp_rules_match_bare_arrays():
    mesh = fake_mesh_shape()
    axes = sharding.MeshAxes()
    s = sharding.spec_for_param("blocks/mlp/wi_gate", (4, 1024, 4096),
                                mesh, axes)
    assert s == P(None, "data", "model")
    s = sharding.spec_for_param("blocks/mlp/wo", (4, 4096, 1024), mesh, axes)
    assert s == P(None, "model", "data")
    s = sharding.spec_for_param("blocks/mlp/wi", (4, 1024, 4096), mesh, axes)
    assert s == P(None, "data", "model")


def test_divisibility_fallback_drops_axis():
    mesh = fake_mesh_shape()
    axes = sharding.MeshAxes()
    # kv head dim 2 cannot split over 4-way model axis -> replicated dim
    s = sharding.spec_for_param("blocks/attn/wk/w", (1024, 2 * 33), mesh,
                                axes)
    assert s[1] is None and s[0] == "data"
    drops = sharding.explain_drops()
    assert any("attn/wk/w" in d for d in drops)
