"""Property tests for the hostile-traffic workload generators.

The scenario harness (benchmarks/scenarios.py) and the gated scenario
tests both lean on ``repro.data.keygen``'s adaptive-runtime generators
being (a) deterministic under a fixed seed, (b) closed over the live key
set, and (c) actually shaped like their docstrings claim (Zipf slope,
crowd concentration, boundary window, tenant slices).  Each claim is
checked against a plain-numpy oracle; hypothesis drives the shapes via
the optional ``tests/_hypothesis_compat.py`` shim.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.data import keygen

PROPS = settings(max_examples=20, deadline=None)


def _raw(n, seed=0):
    """A deduplicated uint64 key set with irregular gaps."""
    rng = np.random.default_rng(seed)
    return np.unique(rng.integers(0, 1 << 48, n).astype(np.uint64))


# ---------------------------------------------------------------------------
# Determinism + membership (all four generators).
# ---------------------------------------------------------------------------

@PROPS
@given(n=st.integers(64, 512), q=st.integers(1, 256),
       seed=st.integers(0, 2**31))
def test_zipfian_keys_deterministic_and_member(n, q, seed):
    raw = _raw(n)
    a = keygen.zipfian_keys(raw, q, 1.1, seed=seed)
    b = keygen.zipfian_keys(raw, q, 1.1, seed=seed)
    np.testing.assert_array_equal(a, b)
    assert len(a) == q
    assert np.isin(a, raw).all()


@PROPS
@given(n=st.integers(64, 512), q=st.integers(1, 256),
       seed=st.integers(0, 2**31))
def test_flash_crowd_deterministic_and_member(n, q, seed):
    raw = _raw(n)
    lo1, hi1 = keygen.flash_crowd_ranges(raw, q, width=16, seed=seed)
    lo2, hi2 = keygen.flash_crowd_ranges(raw, q, width=16, seed=seed)
    np.testing.assert_array_equal(lo1, lo2)
    np.testing.assert_array_equal(hi1, hi2)
    assert np.isin(lo1, raw).all() and np.isin(hi1, raw).all()
    assert (lo1 <= hi1).all()


@PROPS
@given(n=st.integers(64, 512), q=st.integers(1, 256),
       boundary=st.integers(1, 3), seed=st.integers(0, 2**31))
def test_boundary_hot_deterministic_and_member(n, q, boundary, seed):
    raw = _raw(n)
    a = keygen.boundary_hot_keys(raw, q, 4, boundary, seed=seed)
    b = keygen.boundary_hot_keys(raw, q, 4, boundary, seed=seed)
    np.testing.assert_array_equal(a, b)
    assert len(a) == q
    assert np.isin(a, raw).all()


@PROPS
@given(n=st.integers(64, 512), q=st.integers(1, 256),
       seed=st.integers(0, 2**31))
def test_tenant_mix_deterministic_and_member(n, q, seed):
    raw = _raw(n)
    k1, t1 = keygen.tenant_mix(raw, q, seed=seed)
    k2, t2 = keygen.tenant_mix(raw, q, seed=seed)
    np.testing.assert_array_equal(k1, k2)
    np.testing.assert_array_equal(t1, t2)
    assert np.isin(k1, raw).all()
    assert ((t1 >= 0) & (t1 < 3)).all()


# ---------------------------------------------------------------------------
# Shape oracles.
# ---------------------------------------------------------------------------

def test_zipfian_keys_follow_zipf_slope():
    """Empirical log-log frequency-vs-rank slope ~= -theta (spatial
    mode: rank 1 = smallest key value)."""
    raw = _raw(512, seed=3)
    theta = 1.2
    ks = keygen.zipfian_keys(raw, 200_000, theta, seed=5)
    srt = np.sort(raw)
    counts = np.bincount(np.searchsorted(srt, ks), minlength=len(srt))
    top = np.arange(1, 33)                 # head ranks: dense statistics
    slope = np.polyfit(np.log(top), np.log(counts[:32]), 1)[0]
    assert slope == pytest.approx(-theta, abs=0.15)
    # Spatial mode: hottest key IS the smallest key.
    assert counts.argmax() == 0


def test_zipfian_keys_spatial_vs_shuffled():
    raw = _raw(256, seed=4)
    spat = keygen.zipfian_keys(raw, 50_000, 1.5, seed=6, spatial=True)
    shuf = keygen.zipfian_keys(raw, 50_000, 1.5, seed=6, spatial=False)
    # Spatial: the hot half of the traffic sits in the low half of key
    # space.  Shuffled: it lands wherever insertion order put it.
    median = np.median(np.sort(raw))
    assert (spat <= median).mean() > 0.9
    assert np.isin(shuf, raw).all()


def test_zipfian_keys_theta_zero_is_uniform():
    raw = _raw(128, seed=5)
    ks = keygen.zipfian_keys(raw, 50_000, 0.0, seed=7)
    counts = np.bincount(np.searchsorted(np.sort(raw), ks),
                         minlength=len(raw))
    assert counts.max() / counts.mean() < 1.5


def test_flash_crowd_width_oracle():
    """Every emitted range spans EXACTLY ``width`` consecutive live
    keys (searchsorted count oracle)."""
    raw = _raw(400, seed=6)
    width = 24
    lo, hi = keygen.flash_crowd_ranges(raw, 128, width=width,
                                       crowd_frac=0.8, seed=8)
    srt = np.sort(raw)
    spans = (np.searchsorted(srt, hi, "right")
             - np.searchsorted(srt, lo, "left"))
    np.testing.assert_array_equal(spans, np.full(128, width))


def test_flash_crowd_concentration():
    """The crowd fraction of starts collapses into one width//4 window
    at the pinned center."""
    raw = _raw(400, seed=7)
    q, width, frac = 256, 32, 0.9
    lo, _hi = keygen.flash_crowd_ranges(raw, q, width=width,
                                        crowd_frac=frac, center=100,
                                        seed=9)
    srt = np.sort(raw)
    starts = np.searchsorted(srt, lo)
    n_crowd = int(round(q * frac))
    in_window = ((starts >= 100) & (starts < 100 + width // 4)).sum()
    assert in_window >= n_crowd            # uniforms may land there too


def test_flash_crowd_validates_crowd_frac():
    with pytest.raises(ValueError):
        keygen.flash_crowd_ranges(_raw(64), 8, crowd_frac=1.5)


def test_boundary_hot_window_membership():
    """hot_frac of the batch lands inside the width-key window centered
    on the requested splitter cut."""
    raw = _raw(512, seed=8)
    srt = np.sort(raw)
    n, shards, boundary, width = len(srt), 4, 2, 64
    ks = keygen.boundary_hot_keys(raw, 1000, shards, boundary,
                                  width=width, hot_frac=0.95, seed=10)
    cut = boundary * n // shards
    window = set(srt[cut - width // 2:cut + width // 2].tolist())
    in_window = np.fromiter((int(k) in window for k in ks), bool)
    assert in_window.mean() >= 0.90        # 0.95 hot minus uniform noise
    # The window genuinely straddles the cut: heat on BOTH sides.
    below = set(srt[cut - width // 2:cut].tolist())
    above = set(srt[cut:cut + width // 2].tolist())
    assert any(int(k) in below for k in ks)
    assert any(int(k) in above for k in ks)


def test_boundary_hot_validates_boundary():
    raw = _raw(64)
    with pytest.raises(ValueError):
        keygen.boundary_hot_keys(raw, 8, 4, 0)
    with pytest.raises(ValueError):
        keygen.boundary_hot_keys(raw, 8, 4, 4)


def test_tenant_mix_slice_membership_and_weights():
    """Each query's key falls in ITS tenant's contiguous slice, and
    tenant frequencies track the requested weights."""
    raw = _raw(300, seed=9)
    srt = np.sort(raw)
    n, q = len(srt), 5000
    tenants = ((0.7, 1.2), (0.2, 0.5), (0.1, 0.0))
    ks, tids = keygen.tenant_mix(raw, q, tenants, seed=11)
    t = len(tenants)
    for tid in range(t):
        sel = tids == tid
        slice_ = srt[tid * n // t:(tid + 1) * n // t]
        assert np.isin(ks[sel], slice_).all()
    freqs = np.bincount(tids, minlength=t) / q
    np.testing.assert_allclose(freqs, [0.7, 0.2, 0.1], atol=0.05)


def test_tenant_mix_validates_weights():
    with pytest.raises(ValueError):
        keygen.tenant_mix(_raw(64), 8, tenants=())
    with pytest.raises(ValueError):
        keygen.tenant_mix(_raw(64), 8, tenants=((0.5, 1.0), (-0.1, 0.0)))
