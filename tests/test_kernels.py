"""Per-kernel interpret-mode sweeps: shapes x dtypes vs ref.py oracles."""
import numpy as np
import jax.numpy as jnp
import pytest
from numpy.testing import assert_allclose

from repro.core.keys import KeyArray
from repro.kernels import bucket_search, grid_probe, ops, ref, successor


def pack(raw, is64):
    raw = np.asarray(raw, dtype=np.uint64)
    if is64:
        return (jnp.asarray((raw & 0xFFFFFFFF).astype(np.uint32)),
                jnp.asarray((raw >> np.uint64(32)).astype(np.uint32)))
    return jnp.asarray(raw.astype(np.uint32)), None


@pytest.mark.parametrize("is64", [False, True])
@pytest.mark.parametrize("n_reps", [1, 7, 129, 1000, 5000])
@pytest.mark.parametrize("side", ["left", "right"])
def test_successor_kernel_sweep(is64, n_reps, side):
    rng = np.random.default_rng(n_reps)
    space = (1 << 45) if is64 else (1 << 30)
    raw = np.sort(rng.integers(0, space, n_reps, dtype=np.uint64))
    q = rng.integers(0, space, 517, dtype=np.uint64)
    q[:20] = raw[rng.integers(0, n_reps, 20)]
    q[20] = 0
    rl, rh = pack(raw, is64)
    ql, qh = pack(q, is64)
    got = successor.successor_count(rl, rh, ql, qh, side)
    want = ref.successor_count_ref(rl, rh, ql, qh, side)
    assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block_q,block_r", [(8, 8), (1, 1), (2, 16)])
def test_successor_kernel_block_shapes(block_q, block_r):
    rng = np.random.default_rng(0)
    raw = np.sort(rng.integers(0, 1 << 40, 2000, dtype=np.uint64))
    q = rng.integers(0, 1 << 40, 300, dtype=np.uint64)
    rl, rh = pack(raw, True)
    ql, qh = pack(q, True)
    got = successor.successor_count(rl, rh, ql, qh, "left",
                                    block_q=block_q, block_r=block_r)
    want = ref.successor_count_ref(rl, rh, ql, qh, "left")
    assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("is64", [False, True])
@pytest.mark.parametrize("B", [1, 4, 16, 130, 700])
@pytest.mark.parametrize("side", ["left", "right"])
def test_bucket_rank_kernel_sweep(is64, B, side):
    rng = np.random.default_rng(B)
    space = (1 << 45) if is64 else (1 << 30)
    Q = 201
    rows = np.sort(rng.integers(0, space, (Q, B), dtype=np.uint64), axis=1)
    q = rng.integers(0, space, Q, dtype=np.uint64)
    if is64:
        rl = jnp.asarray((rows & 0xFFFFFFFF).astype(np.uint32))
        rh = jnp.asarray((rows >> np.uint64(32)).astype(np.uint32))
    else:
        rl, rh = jnp.asarray(rows.astype(np.uint32)), None
    ql, qh = pack(q, is64)
    got = bucket_search.bucket_rank_kernel(rl, rh, ql, qh, side)
    want = ref.bucket_rank_ref(rl, rh, ql, qh, side)
    assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("T", [1, 100, 4000])
@pytest.mark.parametrize("Q", [1, 333])
def test_lex3_kernel_sweep(T, Q):
    rng = np.random.default_rng(T + Q)
    tz = rng.integers(0, 1 << 18, T).astype(np.int32)
    ty = rng.integers(0, 1 << 23, T).astype(np.int32)
    tx = rng.integers(0, 1 << 23, T).astype(np.int32)
    o = np.lexsort((tx, ty, tz))
    tz, ty, tx = tz[o], ty[o], tx[o]
    qz = rng.integers(0, 1 << 18, Q).astype(np.int32)
    qy = rng.integers(0, 1 << 23, Q).astype(np.int32)
    qx = rng.integers(0, 1 << 23, Q).astype(np.int32)
    args = tuple(map(jnp.asarray, (tz, ty, tx, qz, qy, qx)))
    got = grid_probe.lex3_count(*args)
    want = ref.lex3_count_ref(*args)
    assert_allclose(np.asarray(got), np.asarray(want))


def test_two_level_equals_flat():
    rng = np.random.default_rng(5)
    raw = np.sort(rng.integers(0, 1 << 50, 40000, dtype=np.uint64))
    q = rng.integers(0, 1 << 50, 400, dtype=np.uint64)
    reps = KeyArray.from_u64(raw)
    queries = KeyArray.from_u64(q)
    for side in ("left", "right"):
        flat = np.asarray(ops.successor_search_flat(reps, queries, side))
        two = np.asarray(ops.successor_search(reps, queries, side))
        assert (flat == two).all()
        assert (flat == np.searchsorted(raw, q, side=side)).all()


def test_edge_max_key():
    # 0xFFFF.. keys must not be confused with padding.
    raw = np.array([5, 10, 0xFFFFFFFFFFFFFFFF], dtype=np.uint64)
    reps = KeyArray.from_u64(raw)
    q = KeyArray.from_u64(np.array([0xFFFFFFFFFFFFFFFF], dtype=np.uint64))
    got_l = np.asarray(ops.successor_search_flat(reps, q, "left"))
    got_r = np.asarray(ops.successor_search_flat(reps, q, "right"))
    assert got_l[0] == 2 and got_r[0] == 3
