"""Key arithmetic + searchsorted: property tests against numpy uint64."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.keys import (KeyArray, key_eq, key_le, key_lt, searchsorted,
                             sort_with_payload, unique_mask)


def mk(raw, is64):
    raw = np.asarray(raw, dtype=np.uint64)
    return KeyArray.from_u64(raw) if is64 else KeyArray.from_u32(
        raw.astype(np.uint32))


@given(st.lists(st.integers(0, 2**64 - 1), min_size=1, max_size=50),
       st.lists(st.integers(0, 2**64 - 1), min_size=1, max_size=50))
@settings(max_examples=40, deadline=None)
def test_compare_ops_match_numpy_u64(a, b):
    n = min(len(a), len(b))
    a, b = np.array(a[:n], np.uint64), np.array(b[:n], np.uint64)
    ka, kb = mk(a, True), mk(b, True)
    assert (np.asarray(key_lt(ka, kb)) == (a < b)).all()
    assert (np.asarray(key_le(ka, kb)) == (a <= b)).all()
    assert (np.asarray(key_eq(ka, kb)) == (a == b)).all()


@given(st.lists(st.integers(0, 2**64 - 1), min_size=1, max_size=80),
       st.lists(st.integers(0, 2**64 - 1), min_size=1, max_size=40),
       st.sampled_from(["left", "right"]))
@settings(max_examples=40, deadline=None)
def test_searchsorted_matches_numpy(keys, queries, side):
    raw = np.sort(np.array(keys, np.uint64))
    q = np.array(queries, np.uint64)
    got = np.asarray(searchsorted(mk(raw, True), mk(q, True), side=side))
    want = np.searchsorted(raw, q, side=side)
    assert (got == want).all()


@given(st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=80),
       st.sampled_from(["left", "right"]))
@settings(max_examples=30, deadline=None)
def test_searchsorted_u32(keys, side):
    raw = np.sort(np.array(keys, np.uint64) & np.uint64(0xFFFFFFFF))
    q = np.concatenate([raw[:5], raw[-3:], np.array([0, 2**32 - 1], np.uint64)])
    got = np.asarray(searchsorted(mk(raw, False), mk(q, False), side=side))
    assert (got == np.searchsorted(raw, q, side=side)).all()


def test_sort_with_payload_stable():
    rng = np.random.default_rng(0)
    raw = rng.integers(0, 1 << 50, 500, dtype=np.uint64)
    payload = jnp.arange(500, dtype=jnp.int32)
    sk, sp = sort_with_payload(mk(raw, True), payload)
    order = np.argsort(raw, kind="stable")
    assert (sk.to_numpy() == raw[order]).all()
    assert (np.asarray(sp) == order).all()


def test_unique_mask():
    raw = np.array([1, 1, 2, 5, 5, 5, 9], np.uint64)
    m = np.asarray(unique_mask(mk(raw, True)))
    assert (m == [True, False, True, True, False, False, True]).all()


def test_roundtrip_u64():
    rng = np.random.default_rng(1)
    raw = rng.integers(0, 2**63, 100, dtype=np.uint64)
    assert (mk(raw, True).to_numpy() == raw).all()
