"""Perf-CI plumbing: JSON result recording + the regression gate.

The contract the perf-smoke CI job relies on: ``benchmarks.common.emit``
records every metric into the machine-readable ``{suite: {metric: us}}``
map, ``benchmarks.run --json`` dumps it, and ``benchmarks.compare`` exits
non-zero when any tracked metric regresses past the threshold — verified
here with a synthetic 2x slowdown.
"""
import json

import pytest

from benchmarks import common
from benchmarks import compare as cmp


BASE = {
    "live_store": {"wave0": 100.0, "wave1": 200.0},
    "sharded_store": {"points": 50.0},
}


def _dump(tmp_path, name, data):
    p = tmp_path / name
    p.write_text(json.dumps(data))
    return str(p)


# ---------------------------------------------------------------------------
# emit() -> RESULTS recording (what --json serializes).
# ---------------------------------------------------------------------------

def test_emit_records_under_current_suite(capsys):
    common.set_suite("unit_suite")
    common.emit("metric_a", 1.5e-3, "derived=x")
    common.emit("metric_b", 2e-6)
    out = capsys.readouterr().out
    assert "metric_a,1500.0us,derived=x" in out
    assert common.RESULTS["unit_suite"]["metric_a"] == pytest.approx(1500.0)
    assert common.RESULTS["unit_suite"]["metric_b"] == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# compare(): the gate logic.
# ---------------------------------------------------------------------------

def test_synthetic_2x_slowdown_fails_build(tmp_path, capsys):
    slow = {"live_store": {"wave0": 200.0, "wave1": 400.0},
            "sharded_store": {"points": 100.0}}
    rc = cmp.main([_dump(tmp_path, "base.json", BASE),
                   _dump(tmp_path, "cur.json", slow)])
    out = capsys.readouterr().out
    assert rc == 1
    assert out.count("REGRESSION") == 3
    assert "live_store/wave0: 100.0us -> 200.0us (+100.0%)" in out


def test_within_threshold_passes(tmp_path):
    ok = {"live_store": {"wave0": 120.0, "wave1": 240.0},
          "sharded_store": {"points": 60.0}}  # +20% < 25% default
    assert cmp.main([_dump(tmp_path, "base.json", BASE),
                     _dump(tmp_path, "cur.json", ok)]) == 0


def test_improvement_and_custom_threshold(tmp_path):
    cur = {"live_store": {"wave0": 10.0, "wave1": 260.0},
           "sharded_store": {"points": 50.0}}  # wave1 is +30%
    base = _dump(tmp_path, "base.json", BASE)
    assert cmp.main([base, _dump(tmp_path, "a.json", cur)]) == 1
    assert cmp.main([base, _dump(tmp_path, "b.json", cur),
                     "--threshold", "0.5"]) == 0


def test_track_regex_limits_the_gate(tmp_path):
    slow = {"live_store": {"wave0": 1000.0, "wave1": 1000.0},
            "sharded_store": {"points": 50.0}}
    base = _dump(tmp_path, "base.json", BASE)
    cur = _dump(tmp_path, "cur.json", slow)
    assert cmp.main([base, cur, "--track", "sharded_store/"]) == 0
    assert cmp.main([base, cur, "--track", "live_store/"]) == 1


def test_missing_and_new_metrics(tmp_path, capsys):
    cur = {"live_store": {"wave0": 100.0},
           "brand_new_suite": {"m": 1.0}}
    base = _dump(tmp_path, "base.json", BASE)
    c = _dump(tmp_path, "cur.json", cur)
    assert cmp.main([base, c]) == 0  # missing is a warning by default
    out = capsys.readouterr().out
    assert "MISSING live_store/wave1" in out
    assert "NEW brand_new_suite/m" in out
    assert cmp.main([base, c, "--strict"]) == 1


def test_meta_pseudo_suite_ignored_by_gate(tmp_path):
    """run.py stamps provenance under '_meta' (git SHA, jax version,
    seed); the gate must neither track it nor choke on its non-float
    values."""
    base = dict(BASE, _meta={"git_sha": "abc123", "jax_version": "0.4.37",
                             "seed": None})
    cur = dict(BASE, _meta={"git_sha": "def456", "jax_version": "0.5.0",
                            "seed": 7})
    assert "_meta/git_sha" not in cmp.flatten(base)
    b = _dump(tmp_path, "base.json", base)
    c = _dump(tmp_path, "cur.json", cur)
    assert cmp.main([b, c]) == 0
    # strict mode too: _meta never counts as a missing metric
    assert cmp.main([b, c, "--strict"]) == 0
