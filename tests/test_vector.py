"""Vector tier: recall@k vs a brute-force numpy oracle, cross-tier
parity, spec-boundary validation, and the flush dispatch-counter pin.

Exactness setup: the corpora snap components to a dyadic grid
(``keygen.embedding_set(grid=...)``), so every squared distance is an
exact float32 — numpy and JAX order candidates identically and the
exhaustive-probe suite can demand BIT-identical results, not allclose.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

import repro.db as db
from repro.data import keygen
from repro.db.errors import InvalidSpecError, ReadOnlyTierError
from repro.kernels import ops, ref
from repro.kernels.distance_topk import distance_topk_kernel
from repro.models.embeddings import token_embeddings
from repro.store.arena import EmbeddingArena
from repro.vector import (CoarseQuantizer, bucket_bounds, composite_keys,
                          train_kmeans)

DIM = 16
NCENT = 8
GRID = 16


def corpus(n=512, seed=3):
    return keygen.embedding_set(n, DIM, nclusters=6, spread=0.15,
                                seed=seed, grid=GRID)


def queries_for(vecs, q=32, seed=4):
    return keygen.embedding_queries(vecs, q, seed=seed, grid=GRID)


def brute_force(vecs, queries, k, live=None):
    """Numpy oracle: exact top-k with the (distance, rowID) tie-break.

    ``live`` masks the oracle to the given rowIDs (the live set after
    deletes); returned rowIDs are -1-padded past the live count."""
    d2 = ((vecs[None, :, :] - queries[:, None, :]) ** 2).sum(-1)
    d2 = d2.astype(np.float32)
    rows = np.arange(len(vecs))
    if live is not None:
        mask = np.zeros(len(vecs), bool)
        mask[np.asarray(live)] = True
        d2 = np.where(mask[None, :], d2, np.inf)
    order = np.lexsort((np.broadcast_to(rows, d2.shape), d2),
                       axis=-1)[:, :k]
    dist = np.take_along_axis(d2, order, axis=-1)
    out_rows = np.where(np.isfinite(dist), order, -1).astype(np.int32)
    return out_rows, np.where(np.isfinite(dist), dist,
                              np.inf).astype(np.float32)


def vector_spec(tier="live", **kw):
    kw.setdefault("kind", "vector")
    kw.setdefault("dim", DIM)
    kw.setdefault("ncentroids", NCENT)
    kw.setdefault("max_hits", 128)
    return db.IndexSpec(tier=tier, **kw)


# ---------------------------------------------------------------------------
# Spec boundary (satellite: typed errors naming field and value).
# ---------------------------------------------------------------------------

class TestSpecValidation:
    def test_vector_spec_roundtrip(self):
        s = vector_spec(nprobe=4)
        assert s.kind == "vector" and s.effective_nprobe == 4
        assert s.scalar_spec().kind == "scalar"
        assert s.scalar_spec().dim is None

    def test_nprobe_defaults_exhaustive(self):
        assert vector_spec().effective_nprobe == NCENT

    def test_unknown_kind(self):
        with pytest.raises(InvalidSpecError, match="pointcloud"):
            db.IndexSpec(kind="pointcloud")

    def test_vector_without_dim(self):
        with pytest.raises(InvalidSpecError, match="dim"):
            db.IndexSpec(kind="vector", ncentroids=4)

    def test_vector_without_ncentroids(self):
        with pytest.raises(InvalidSpecError, match="ncentroids"):
            db.IndexSpec(kind="vector", dim=8)

    @pytest.mark.parametrize("field,value", [("dim", 0), ("dim", -3),
                                             ("ncentroids", 0),
                                             ("nprobe", 0)])
    def test_non_positive_values_named(self, field, value):
        kw = {"kind": "vector", "dim": 8, "ncentroids": 4}
        kw[field] = value
        with pytest.raises(InvalidSpecError) as e:
            db.IndexSpec(**kw)
        assert field in str(e.value) and str(value) in str(e.value)

    def test_nprobe_exceeds_ncentroids(self):
        with pytest.raises(InvalidSpecError, match="nprobe=9"):
            db.IndexSpec(kind="vector", dim=8, ncentroids=4, nprobe=9)

    @pytest.mark.parametrize("field,value", [("dim", 8),
                                             ("ncentroids", 4),
                                             ("nprobe", 2)])
    def test_vector_options_on_scalar_spec(self, field, value):
        with pytest.raises(InvalidSpecError) as e:
            db.IndexSpec(**{field: value})
        assert field in str(e.value) and "vector" in str(e.value)

    def test_durable_vector_rejected(self, tmp_path):
        with pytest.raises(InvalidSpecError, match="durability"):
            db.IndexSpec(kind="vector", dim=8, ncentroids=4,
                         durability="wal", wal_dir=str(tmp_path))

    def test_build_tier_rejects_vector_spec(self):
        keys = db.as_key_array(np.arange(8, dtype=np.uint32))
        with pytest.raises(InvalidSpecError, match="repro.db.open"):
            db.build_tier(vector_spec(), keys)

    def test_open_needs_corpus(self):
        with pytest.raises(ValueError, match="embedding corpus"):
            db.open(vector_spec())

    def test_open_rejects_recover(self):
        with pytest.raises(InvalidSpecError, match="recover"):
            db.open(vector_spec(), corpus(64), recover=True)


# ---------------------------------------------------------------------------
# Quantizer, composite keys, arena.
# ---------------------------------------------------------------------------

class TestComponents:
    def test_kmeans_deterministic_and_assign_ties_low(self):
        vecs = corpus(256)
        q1 = train_kmeans(vecs, NCENT, seed=0)
        q2 = train_kmeans(vecs, NCENT, seed=0)
        assert np.array_equal(np.asarray(q1.centroids),
                              np.asarray(q2.centroids))
        a = np.asarray(q1.assign(vecs))
        assert a.min() >= 0 and a.max() < NCENT
        # topn is nearest-first and its first column equals assign.
        top = np.asarray(q1.topn(vecs, 3))
        assert np.array_equal(top[:, 0], a)

    def test_kmeans_needs_enough_vectors(self):
        with pytest.raises(ValueError, match="ncentroids"):
            train_kmeans(corpus(4), NCENT)

    def test_quantizer_is_pytree(self):
        import jax
        q = train_kmeans(corpus(64), 4)
        leaves = jax.tree_util.tree_leaves(q)
        assert len(leaves) == 1 and leaves[0].shape == (4, DIM)

    def test_composite_keys_roundtrip(self):
        cids = np.array([3, 0, 7], np.int32)
        rows = np.array([10, 99, 0], np.int32)
        keys = composite_keys(cids, rows)
        raw = keys.to_numpy()
        assert np.array_equal(raw >> 32, cids.astype(np.uint64))
        assert np.array_equal(raw & 0xFFFFFFFF, rows.astype(np.uint64))
        lo, hi = bucket_bounds(cids)
        assert np.array_equal(lo.to_numpy(), cids.astype(np.uint64) << 32)
        assert np.array_equal(hi.to_numpy(),
                              (cids.astype(np.uint64) << 32) | 0xFFFFFFFF)

    def test_arena_grow_gather_alloc(self):
        a = EmbeddingArena(4)
        rows = a.alloc(3)
        vecs = np.arange(12, dtype=np.float32).reshape(3, 4)
        a.add(rows, vecs)
        assert a.capacity >= 3 and a.next_row == 3
        got = np.asarray(a.gather(jnp.asarray(rows)))
        assert np.array_equal(got, vecs)
        # geometric growth keeps old content
        big = a.alloc(100)
        a.add(big, np.ones((100, 4), np.float32))
        assert np.array_equal(np.asarray(a.gather(jnp.asarray(rows))), vecs)
        # out-of-range gathers clamp, never fault
        assert np.asarray(a.gather(jnp.asarray([-1]))).shape == (1, 4)

    def test_arena_shape_errors(self):
        a = EmbeddingArena(4)
        with pytest.raises(ValueError, match="vectors"):
            a.add(np.array([0]), np.ones((1, 5), np.float32))
        with pytest.raises(ValueError, match="non-negative"):
            a.add(np.array([-1]), np.ones((1, 4), np.float32))


# ---------------------------------------------------------------------------
# distance_topk: kernel vs ref oracle.
# ---------------------------------------------------------------------------

class TestDistanceTopk:
    def _case(self, seed=7, Q=6, C=40, D=16):
        rng = np.random.default_rng(seed)
        q = rng.normal(size=(Q, D)).astype(np.float32)
        c = rng.normal(size=(Q, C, D)).astype(np.float32)
        r = rng.permutation(np.arange(Q * C, dtype=np.int32)).reshape(Q, C)
        v = rng.random((Q, C)) > 0.2
        return q, c, r, v

    @pytest.mark.parametrize("k", [1, 7, 64])
    def test_kernel_matches_ref(self, k):
        q, c, r, v = self._case()
        dk, rk = distance_topk_kernel(*map(jnp.asarray, (q, c, r, v)), k,
                                      interpret=True)
        dr, rr = ref.distance_topk_ref(*map(jnp.asarray, (q, c, r, v)), k)
        assert np.array_equal(np.asarray(rk), np.asarray(rr))
        np.testing.assert_allclose(np.asarray(dk), np.asarray(dr))

    def test_fewer_candidates_than_k_pads(self):
        q, c, r, v = self._case()
        v2 = np.zeros_like(v)
        v2[:, :3] = True
        dk, rk = distance_topk_kernel(*map(jnp.asarray, (q, c, r, v2)), 8,
                                      interpret=True)
        rk = np.asarray(rk)
        assert (rk[:, 3:] == -1).all() and (rk[:, :3] >= 0).all()
        assert np.isinf(np.asarray(dk)[:, 3:]).all()

    def test_tie_break_prefers_low_row(self):
        # Two identical candidates with different rowIDs: the smaller
        # rowID must win in both implementations.
        q = np.zeros((1, 4), np.float32)
        c = np.zeros((1, 2, 4), np.float32)
        r = np.array([[9, 2]], np.int32)
        v = np.ones((1, 2), bool)
        _, rk = distance_topk_kernel(*map(jnp.asarray, (q, c, r, v)), 2,
                                     interpret=True)
        _, rr = ref.distance_topk_ref(*map(jnp.asarray, (q, c, r, v)), 2)
        assert np.asarray(rk).tolist() == [[2, 9]]
        assert np.asarray(rr).tolist() == [[2, 9]]

    def test_ops_wrapper_paths(self):
        q, c, r, v = self._case(Q=3, C=16, D=8)
        args = tuple(map(jnp.asarray, (q, c, r, v)))
        d_auto, r_auto = ops.distance_topk(*args, 5)
        d_ref, r_ref = ops.distance_topk(*args, 5, method="ref")
        d_k, r_k = ops.distance_topk(*args, 5, method="kernel")
        assert np.array_equal(np.asarray(r_auto), np.asarray(r_ref))
        assert np.array_equal(np.asarray(r_k), np.asarray(r_ref))
        np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_ref))
        with pytest.raises(ValueError, match="method"):
            ops.distance_topk(*args, 5, method="gpu")

    def test_ops_wrapper_empty_batch(self):
        d, r = ops.distance_topk(jnp.zeros((0, 4)), jnp.zeros((0, 3, 4)),
                                 jnp.zeros((0, 3), jnp.int32),
                                 jnp.zeros((0, 3), bool), 5)
        assert d.shape == (0, 5) and r.shape == (0, 5)


# ---------------------------------------------------------------------------
# Recall@k property suite vs the brute-force oracle.
# ---------------------------------------------------------------------------

class TestRecall:
    @pytest.mark.parametrize("tier", ["static", "live", "sharded"])
    def test_exhaustive_probe_bit_identical(self, tier):
        vecs = corpus()
        qs = queries_for(vecs)
        sess = db.open(vector_spec(tier=tier, nprobe=NCENT), vecs)
        res = sess.probe_vectors(qs, k=10, probe_cap=len(vecs)).result()
        o_rows, o_dist = brute_force(vecs, qs, 10)
        assert np.array_equal(np.asarray(res.row_id), o_rows)
        assert np.array_equal(np.asarray(res.distance), o_dist)
        assert (np.asarray(res.count) == 10).all()

    def test_partial_probe_recall_floor(self):
        vecs = corpus(1024, seed=11)
        qs = queries_for(vecs, 64, seed=12)
        sess = db.open(vector_spec(nprobe=2, ncentroids=NCENT), vecs)
        res = sess.probe_vectors(qs, k=10, probe_cap=1024).result()
        o_rows, _ = brute_force(vecs, qs, 10)
        got = np.asarray(res.row_id)
        recall = np.mean([len(set(g) & set(o)) / 10.0
                          for g, o in zip(got, o_rows)])
        # Pinned floor: clustered corpus + queries near corpus points,
        # 2/8 buckets probed. Deterministic workload, so a regression
        # here is a real quantizer/probe change, not noise.
        assert recall >= 0.8, f"recall@10 {recall:.3f} under floor"
        # and more probes monotonically reach exactness
        full = sess.probe_vectors(qs, k=10, nprobe=NCENT,
                                  probe_cap=1024).result()
        assert np.array_equal(np.asarray(full.row_id), o_rows)

    def test_probe_cap_bounds_candidates(self):
        vecs = corpus()
        qs = queries_for(vecs, 8)
        sess = db.open(vector_spec(nprobe=NCENT), vecs)
        res = sess.probe_vectors(qs, k=4, probe_cap=1).result()
        # one candidate per bucket -> at most NCENT candidates
        assert (np.asarray(res.count) <= NCENT).all()


# ---------------------------------------------------------------------------
# Live updates + cross-tier parity on the same op sequence.
# ---------------------------------------------------------------------------

class TestLiveAndParity:
    def _drive(self, sess, vecs):
        """One mixed insert/delete/probe sequence; returns probe results."""
        qs = queries_for(vecs, 16, seed=21)
        extra = keygen.embedding_set(48, DIM, nclusters=6, seed=22,
                                     grid=GRID)
        out = []
        sess.insert_vectors(extra[:32])
        out.append(sess.probe_vectors(qs, k=8, probe_cap=2048))
        sess.flush()
        sess.delete_vectors(np.arange(0, 40, 2, dtype=np.int32))
        sess.insert_vectors(extra[32:],
                            row_ids=np.arange(len(vecs) + 32,
                                              len(vecs) + 48))
        out.append(sess.probe_vectors(qs, k=8, probe_cap=2048))
        sess.flush()
        return [t.result() for t in out]

    def test_live_matches_oracle_through_updates(self):
        vecs = corpus()
        sess = db.open(vector_spec(tier="live", nprobe=NCENT), vecs)
        r1, r2 = self._drive(sess, vecs)
        extra = keygen.embedding_set(48, DIM, nclusters=6, seed=22,
                                     grid=GRID)
        all_vecs = np.concatenate([vecs, extra])
        qs = queries_for(vecs, 16, seed=21)
        live1 = np.arange(len(vecs) + 32)
        o_rows, o_dist = brute_force(all_vecs, qs, 8, live=live1)
        assert np.array_equal(np.asarray(r1.row_id), o_rows)
        live2 = np.setdiff1d(np.arange(len(vecs) + 48),
                             np.arange(0, 40, 2))
        o_rows2, o_dist2 = brute_force(all_vecs, qs, 8, live=live2)
        assert np.array_equal(np.asarray(r2.row_id), o_rows2)
        assert np.array_equal(np.asarray(r2.distance), o_dist2)

    def test_live_sharded_parity(self):
        vecs = corpus()
        live = db.open(vector_spec(tier="live", nprobe=NCENT), vecs)
        shard = db.open(vector_spec(tier="sharded", nprobe=NCENT, shards=3),
                        vecs)
        for a, b in zip(self._drive(live, vecs), self._drive(shard, vecs)):
            assert np.array_equal(np.asarray(a.row_id),
                                  np.asarray(b.row_id))
            assert np.array_equal(np.asarray(a.distance),
                                  np.asarray(b.distance))

    def test_static_tier_rejects_vector_writes(self):
        sess = db.open(vector_spec(tier="static"), corpus(64))
        with pytest.raises(ReadOnlyTierError):
            sess.insert_vectors(corpus(4, seed=5))
        with pytest.raises(ReadOnlyTierError):
            sess.delete_vectors(np.array([0], np.int32))


# ---------------------------------------------------------------------------
# Session surface: dispatch pin, coalescing, validation, stats.
# ---------------------------------------------------------------------------

class TestSessionSurface:
    def test_dispatch_counter_pin(self):
        """N probes + scalar reads + writes in one flush = one apply +
        one query dispatch (the acceptance pin: probes fuse into the
        one-dispatch-per-op-class flush; the only extra launch is the
        per-ticket distance_topk post-filter, which is not a dispatch
        round)."""
        vecs = corpus()
        sess = db.open(vector_spec(nprobe=2), vecs)
        assert sess.dispatches == {"apply": 0, "query": 0, "rank": 0}
        qs = queries_for(vecs, 8)
        tickets = [sess.probe_vectors(qs, k=4) for _ in range(3)]
        sess.insert_vectors(corpus(8, seed=30))
        sess.insert_vectors(corpus(8, seed=31),
                            row_ids=np.arange(520, 528))
        rep = sess.flush()
        assert sess.dispatches == {"apply": 1, "query": 1, "rank": 0}
        # every probe resolved from that one dispatch
        for t in tickets:
            assert t.result().row_id.shape == (8, 4)
        # 3 probe tickets x 8 queries x nprobe=2 ranges
        assert rep.n_range == 3 * 8 * 2

    def test_probe_validation(self):
        sess = db.open(vector_spec(), corpus(64))
        qs = queries_for(corpus(64), 4)
        with pytest.raises(ValueError, match="nprobe"):
            sess.probe_vectors(qs, k=2, nprobe=NCENT + 1)
        with pytest.raises(ValueError, match="k >= 1"):
            sess.probe_vectors(qs, k=0)
        with pytest.raises(ValueError, match=r"\(Q, 16\)"):
            sess.probe_vectors(np.zeros((4, 3), np.float32), k=2)
        with pytest.raises(ValueError, match="probe_cap"):
            sess.probe_vectors(qs, k=2, probe_cap=-1)

    def test_zero_query_probe_resolves_immediately(self):
        sess = db.open(vector_spec(), corpus(64))
        t = sess.probe_vectors(np.zeros((0, DIM), np.float32), k=5)
        assert t.ready
        res = t.result()
        assert res.row_id.shape == (0, 5) and res.count.shape == (0,)

    def test_write_validation(self):
        sess = db.open(vector_spec(), corpus(64))
        with pytest.raises(ValueError, match="row_ids"):
            sess.insert_vectors(corpus(4, seed=5),
                                row_ids=np.arange(3))
        with pytest.raises(ValueError, match="previously inserted"):
            sess.delete_vectors(np.array([9999], np.int32))
        t = sess.insert_vectors(np.zeros((0, DIM), np.float32))
        assert t.ready and t.result() == 0
        t = sess.delete_vectors(np.zeros((0,), np.int32))
        assert t.ready and t.result() == 0

    def test_stats_and_nbytes_report_vector_tier(self):
        vecs = corpus(128)
        sess = db.open(vector_spec(), vecs)
        s = sess.stats()
        assert s.tier == "vector" and s.live_keys == 128
        nb = sess.nbytes()
        assert nb["arena_bytes"] >= 128 * DIM * 4
        assert nb["centroid_bytes"] == NCENT * DIM * 4
        assert nb["total_bytes"] > nb["arena_bytes"]

    def test_compaction_inherited(self):
        vecs = corpus(256)
        policy = db.CompactionPolicy(max_chain=1)
        sess = db.open(vector_spec(tier="live", nprobe=NCENT,
                                   policy=policy), vecs)
        sess.insert_vectors(corpus(64, seed=40))
        rep = sess.flush()
        assert rep.compacted is not None
        qs = queries_for(vecs, 8)
        res = sess.probe_vectors(qs, k=5, probe_cap=1024).result()
        all_vecs = np.concatenate([vecs, corpus(64, seed=40)])
        o_rows, _ = brute_force(all_vecs, qs, 5)
        assert np.array_equal(np.asarray(res.row_id), o_rows)

    def test_lm_embedding_corpus_roundtrip(self):
        """models/embeddings.py vectors drive the tier end to end."""
        vecs = token_embeddings(96, DIM, seed=2)
        assert vecs.shape == (96, DIM) and vecs.dtype == np.float32
        assert np.array_equal(vecs, token_embeddings(96, DIM, seed=2))
        sess = db.open(vector_spec(nprobe=NCENT), vecs)
        res = sess.probe_vectors(vecs[:5], k=1, probe_cap=256).result()
        # nearest neighbor of a corpus vector is itself
        assert np.array_equal(np.asarray(res.row_id)[:, 0], np.arange(5))


# ---------------------------------------------------------------------------
# Postmap IR node (the lowering hook the probe rides).
# ---------------------------------------------------------------------------

class TestPostmap:
    def test_postmap_wraps_any_expr(self):
        keys = db.as_key_array(np.arange(32, dtype=np.uint32))
        sess = db.open(db.IndexSpec(tier="live"), keys)
        e = db.postmap(lambda cnt: cnt * 2,
                       db.count(db.between(keys[:4], keys[4:8])))
        doubled = sess.query(e).result()
        plain = sess.query(db.count(db.between(keys[:4],
                                               keys[4:8]))).result()
        assert np.array_equal(np.asarray(doubled), np.asarray(plain) * 2)

    def test_postmap_empty_submission_runs_fn(self):
        keys = db.as_key_array(np.arange(8, dtype=np.uint32))
        sess = db.open(db.IndexSpec(tier="live"), keys)
        t = sess.query(db.postmap(lambda cnt: cnt.shape,
                                  db.count(db.between(keys[:0],
                                                      keys[:0]))))
        assert t.ready and t.result() == (0,)

    def test_postmap_type_errors(self):
        keys = db.as_key_array(np.arange(4, dtype=np.uint32))
        with pytest.raises(TypeError, match="callable"):
            db.postmap(3, db.eq(keys))
        with pytest.raises(TypeError, match="expression"):
            db.postmap(lambda r: r, "nope")
