"""Crash recovery vs the uncrashed oracle, at every WAL record boundary.

The durability contract (store/wal.py, db/tiers.py): every write batch
is appended + fsynced BEFORE its device dispatch, so after a kill at ANY
record boundary, recovery (newest snapshot + WAL-tail replay) rebuilds a
store whose lookups, ranges, and rank scans are bit-identical to an
uncrashed store over the same surviving prefix of applies.  These tests
run ONE durable primary through a random mixed insert/delete/compaction
sequence, then simulate the kill at every boundary by materializing a
copy of the durable directory whose WAL holds exactly the first k
records — recovery over the copy must match a fresh non-durable session
over the oracle's live set (query results depend only on the live key
multiset; physical chain layout is free to differ).

Also here: torn-tail bytes at the log end (crash mid-append) are
dropped, not fatal — and stay non-fatal after further recovery cycles;
an incomplete per-shard group at the sharded log tail rolls back the
whole group; a killed snapshot (crash mid-compaction under
'wal+snapshot', newest step dir gone) falls back to the previous
snapshot + a longer replay tail; corruption anywhere but the tail
raises instead of silently skipping batches.
"""
import dataclasses
import os
import shutil

import numpy as np
import pytest

import repro.db as db
from repro.store import wal as wal_mod

POLICY = db.CompactionPolicy(max_chain=3)


def keys_of(oracle):
    return db.as_key_array(np.asarray(sorted(oracle), dtype=np.uint64))


def _mixed_run(sess, oracle, rng, waves, pool, n_ins=12, n_del=6):
    """Drive ``sess`` through random mixed waves, mirroring each applied
    wave into ``oracle`` (key -> row dict).  Returns the oracle snapshot
    AFTER each wave (index k = state after k applies)."""
    states = [dict(oracle)]
    next_row = 10_000
    for _ in range(waves):
        live = np.fromiter(oracle, np.uint64, len(oracle))
        fresh = np.setdiff1d(rng.choice(pool, n_ins, replace=False), live)
        dels = rng.choice(live, min(n_del, len(live)), replace=False)
        rows = np.arange(next_row, next_row + len(fresh), dtype=np.int32)
        sess.insert(db.as_key_array(fresh), rows)
        sess.delete(db.as_key_array(dels))
        sess.flush()
        next_row += len(fresh)
        for k, r in zip(fresh, rows):
            oracle[int(k)] = int(r)
        for k in dels:
            del oracle[int(k)]
        states.append(dict(oracle))
    return states


def _write_wal(dirpath, records):
    """Materialize a log directory holding exactly ``records``."""
    os.makedirs(dirpath, exist_ok=True)
    if not records:
        return
    path = os.path.join(dirpath, f"seg-{records[0].seq:012d}.wal")
    with open(path, "wb") as f:
        for rec in records:
            f.write(wal_mod.encode_record(
                rec.seq, rec.epoch, rec.part, rec.nparts,
                rec.ins_keys(), rec.ins_rows, rec.del_keys()))


def _reference_session(spec, oracle):
    """Uncrashed oracle: a fresh NON-durable session over the live set.
    Query results depend only on the live key multiset, so this is the
    bit-identity reference for any recovered store."""
    ref_spec = dataclasses.replace(spec, durability="none", wal_dir=None)
    ks = np.asarray(sorted(oracle), dtype=np.uint64)
    rows = np.asarray([oracle[int(k)] for k in ks], np.int32)
    return db.open(ref_spec, db.as_key_array(ks), rows)


def _check_recovery(spec, oracle, probes_np, ctx):
    m = len(probes_np) // 2
    a, b = probes_np[:m], probes_np[m: 2 * m]
    lo = db.as_key_array(np.minimum(a, b))
    hi = db.as_key_array(np.maximum(a, b))
    probes = db.as_key_array(probes_np)
    with db.open(spec, recover=True) as got, \
            _reference_session(spec, oracle) as ref:
        g_pts = got.lookup(probes).result()
        w_pts = ref.lookup(probes).result()
        for f in ("found", "row_id", "position"):
            g = np.asarray(getattr(g_pts, f))
            w = np.asarray(getattr(w_pts, f))
            assert (g == w).all(), f"{ctx}: point field {f} diverges"
        g_rng = got.range(lo, hi).result()
        w_rng = ref.range(lo, hi).result()
        for f in ("start", "count", "row_ids"):
            g = np.asarray(getattr(g_rng, f))
            w = np.asarray(getattr(w_rng, f))
            assert (g == w).all(), f"{ctx}: range field {f} diverges"
        g_rk = np.asarray(got.scan_ranks(probes).result())
        w_rk = np.asarray(ref.scan_ranks(probes).result())
        assert (g_rk == w_rk).all(), f"{ctx}: rank scan diverges"


# ---------------------------------------------------------------------------
# Live tier.
# ---------------------------------------------------------------------------

def test_live_kill_at_every_record_boundary(tmp_path):
    rng = np.random.default_rng(7)
    pool = np.unique(rng.integers(1, 1 << 40, 4096, dtype=np.uint64))
    base, rest = pool[:256], pool[256:]
    spec = db.IndexSpec(tier="live", durability="wal",
                        wal_dir=str(tmp_path / "primary"),
                        node_cap=8, policy=POLICY, max_hits=32)
    oracle = {int(k): i for i, k in enumerate(np.sort(base))}
    with db.open(spec, keys_of(oracle)) as sess:
        states = _mixed_run(sess, oracle, rng, waves=6, pool=rest)
        assert sess.stats().compactions > 0, \
            "the run must cross a compaction epoch swap"
    records, truncated = wal_mod.read_records(
        os.path.join(spec.wal_dir, "wal"))
    assert not truncated and len(records) == 6
    probes_np = np.sort(pool[:600])        # present, deleted, never-present

    for k in range(len(records) + 1):
        kill = str(tmp_path / f"kill-{k}")
        shutil.copytree(os.path.join(spec.wal_dir, "snapshots"),
                        os.path.join(kill, "snapshots"))
        _write_wal(os.path.join(kill, "wal"), records[:k])
        kspec = dataclasses.replace(spec, wal_dir=kill)
        _check_recovery(kspec, states[k], probes_np,
                        f"kill after {k} records")


def test_live_torn_tail_bytes_dropped(tmp_path):
    rng = np.random.default_rng(11)
    pool = np.unique(rng.integers(1, 1 << 32, 1024, dtype=np.uint64))
    spec = db.IndexSpec(tier="live", durability="wal",
                        wal_dir=str(tmp_path / "p"), node_cap=8,
                        policy=POLICY, max_hits=32)
    oracle = {int(k): i for i, k in enumerate(np.sort(pool[:128]))}
    with db.open(spec, keys_of(oracle)) as sess:
        states = _mixed_run(sess, oracle, rng, waves=3, pool=pool[128:])
    wdir = os.path.join(spec.wal_dir, "wal")
    segs = sorted(f for f in os.listdir(wdir) if f.endswith(".wal"))
    last = os.path.join(wdir, segs[-1])
    # Crash mid-append: the final record's bytes are half-flushed.
    with open(last, "rb+") as f:
        f.truncate(os.path.getsize(last) - 9)
    probes = np.sort(pool[:300])
    _check_recovery(spec, states[2], probes, "torn tail")
    # A later cycle must still read the log (the recovery writer
    # truncated the torn tail before opening its own segment).
    _check_recovery(spec, states[2], probes, "torn tail, second cycle")


def test_live_mid_compaction_snapshot_kill(tmp_path):
    """'wal+snapshot' re-snapshots at each compaction; a kill between
    the epoch swap and the snapshot commit leaves the OLD snapshot +
    the full WAL tail — replay must carry recovery across the swap."""
    rng = np.random.default_rng(13)
    pool = np.unique(rng.integers(1, 1 << 36, 2048, dtype=np.uint64))
    spec = db.IndexSpec(tier="live", durability="wal+snapshot",
                        wal_dir=str(tmp_path / "p"), node_cap=8,
                        policy=POLICY, max_hits=32)
    oracle = {int(k): i for i, k in enumerate(np.sort(pool[:192]))}
    with db.open(spec, keys_of(oracle)) as sess:
        _mixed_run(sess, oracle, rng, waves=6, pool=pool[192:])
        assert sess.stats().compactions > 0
    snaps = os.path.join(spec.wal_dir, "snapshots")
    steps = sorted(d for d in os.listdir(snaps) if d.startswith("step-"))
    assert len(steps) >= 2, "compaction must have added snapshots"
    shutil.rmtree(os.path.join(snaps, steps[-1]))   # the mid-swap kill
    _check_recovery(spec, oracle, np.sort(pool[:500]),
                    "snapshot killed mid-compaction")


# ---------------------------------------------------------------------------
# Sharded tier.
# ---------------------------------------------------------------------------

def test_sharded_kill_at_every_group_boundary(tmp_path):
    rng = np.random.default_rng(17)
    pool = np.unique(rng.integers(1, 1 << 44, 4096, dtype=np.uint64))
    spec = db.IndexSpec(tier="sharded", shards=4, durability="wal",
                        wal_dir=str(tmp_path / "primary"),
                        node_cap=8, policy=POLICY, max_hits=32)
    oracle = {int(k): i for i, k in enumerate(np.sort(pool[:384]))}
    with db.open(spec, keys_of(oracle)) as sess:
        states = _mixed_run(sess, oracle, rng, waves=5, pool=pool[384:],
                            n_ins=24, n_del=10)
    shard_dirs = [os.path.join(spec.wal_dir, "wal", f"shard-{i:04d}")
                  for i in range(4)]
    groups = wal_mod.read_groups(shard_dirs)
    assert len(groups) == 5
    probes_np = np.sort(pool[:700])

    def materialize(tag, upto, partial_parts=0):
        kill = str(tmp_path / tag)
        shutil.copytree(os.path.join(spec.wal_dir, "snapshots"),
                        os.path.join(kill, "snapshots"))
        per_shard = {i: [] for i in range(4)}
        for g in groups[:upto]:
            for shard_id, rec in g:
                per_shard[shard_id].append(rec)
        if partial_parts and upto < len(groups):
            for shard_id, rec in groups[upto][:partial_parts]:
                per_shard[shard_id].append(rec)
        for i in range(4):
            _write_wal(os.path.join(kill, "wal", f"shard-{i:04d}"),
                       per_shard[i])
        return dataclasses.replace(spec, wal_dir=kill)

    for k in range(len(groups) + 1):
        _check_recovery(materialize(f"kill-{k}", k), states[k], probes_np,
                        f"kill after {k} groups")
    # A group missing part of its per-shard fan-out is the crash point:
    # the whole group rolls back (its fsync set never completed).
    for k in (2, 4):
        if len(groups[k]) > 1:
            _check_recovery(
                materialize(f"kill-{k}-partial", k, partial_parts=1),
                states[k], probes_np, f"partial group at seq {k}")


def test_sharded_incomplete_group_mid_log_raises(tmp_path):
    """Incompleteness is only excusable at the log tail; a hole in the
    middle is corruption and must raise, not silently skip a batch."""
    rng = np.random.default_rng(19)
    pool = np.unique(rng.integers(1, 1 << 44, 2048, dtype=np.uint64))
    spec = db.IndexSpec(tier="sharded", shards=4, durability="wal",
                        wal_dir=str(tmp_path / "p"), node_cap=8,
                        policy=POLICY, max_hits=32)
    oracle = {int(k): i for i, k in enumerate(np.sort(pool[:384]))}
    with db.open(spec, keys_of(oracle)) as sess:
        _mixed_run(sess, oracle, rng, waves=4, pool=pool[384:],
                   n_ins=24, n_del=10)
    dirs = [os.path.join(spec.wal_dir, "wal", f"shard-{i:04d}")
            for i in range(4)]
    groups = wal_mod.read_groups(dirs)
    victim = next(g for g in groups[:-1] if len(g) > 1)
    drop_seq, drop_shard = victim[0][1].seq, victim[0][0]
    per_shard = {i: [] for i in range(4)}
    for g in groups:
        for shard_id, rec in g:
            if not (rec.seq == drop_seq and shard_id == drop_shard):
                per_shard[shard_id].append(rec)
    for i, d in enumerate(dirs):
        shutil.rmtree(d)
        _write_wal(d, per_shard[i])
    with pytest.raises(db.RecoveryError):
        db.open(spec, recover=True)


def test_wal_corrupt_before_final_segment_raises(tmp_path):
    """Undecodable bytes are only excusable in the LAST segment (a torn
    tail); the same damage in an earlier segment is corruption."""
    d = str(tmp_path / "log")
    log = wal_mod.WriteAheadLog(d)
    for i in range(3):
        log.append(db.as_key_array(np.array([i + 1], np.uint64)),
                   np.array([i], np.int32), None, epoch=0)
    log.close()
    log2 = wal_mod.WriteAheadLog(d)      # opens a second, newer segment
    log2.append(db.as_key_array(np.array([9], np.uint64)),
                np.array([9], np.int32), None, epoch=0)
    log2.close()
    first_seg = os.path.join(d, sorted(
        f for f in os.listdir(d) if f.endswith(".wal"))[0])
    with open(first_seg, "rb+") as f:
        f.seek(wal_mod._HEADER.size + 1)   # payload byte: CRC now fails
        f.write(b"\xee")
    with pytest.raises(wal_mod.WalError):
        wal_mod.read_records(d)
