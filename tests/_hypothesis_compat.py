"""Optional-hypothesis shim so tier-1 collection never needs hypothesis.

Property-test modules import ``given``/``settings``/``st`` from here
instead of from ``hypothesis`` directly.  When hypothesis is installed
(see requirements-test.txt) the real symbols are re-exported and the
property tests run as written; when it is not (the minimal container),
``@given``-decorated tests collect cleanly and report as SKIPPED while
every plain pytest test in the same module still runs.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    def given(*_args, **_kwargs):
        def deco(fn):
            # Zero-arg replacement: pytest must not try to resolve the
            # strategy parameters as fixtures, so drop the signature.
            def skipper():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-test.txt)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: any attribute is a
        callable returning None (strategies are only consumed by the real
        ``given``, which this shim replaces)."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
