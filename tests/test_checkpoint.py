"""Checkpointing: atomicity, resume determinism, elastic re-shard."""
import os
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import tokens as data_tokens
from repro.models import lm
from repro.training import optim, step as step_mod


def _train(cfg, steps, ckpt_dir=None, resume=False, ckpt_every=3,
           schedule_steps=8):
    # NB: the LR schedule length must be fixed across runs (a resumed job
    # continues the same schedule), independent of how many steps this
    # particular invocation executes.
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.init_state(params)
    fn = jax.jit(step_mod.make_train_step(
        cfg, optim.AdamWConfig(lr_peak=1e-3, warmup_steps=2,
                               total_steps=schedule_steps)))
    start = 0
    mgr = CheckpointManager(ckpt_dir, keep=2) if ckpt_dir else None
    if resume and mgr and mgr.latest_step() is not None:
        (params, opt), meta = mgr.restore(mgr.latest_step(), (params, opt))
        start = meta["data_step"]
    losses = {}
    for i in range(start, steps):
        batch = jax.tree.map(jnp.asarray, data_tokens.synthetic_batch(
            i, 4, 32, cfg.vocab_size))
        params, opt, m = fn(params, opt, batch)
        losses[i] = float(m["loss"])
        if mgr and (i + 1) % ckpt_every == 0:
            mgr.save(i + 1, (params, opt), {"data_step": i + 1})
    return params, losses


def test_resume_bitwise_equivalent(tmp_path):
    cfg = get_config("yi-6b").tiny()
    p_full, l_full = _train(cfg, 8)
    d = str(tmp_path / "ck")
    _train(cfg, 6, ckpt_dir=d)                     # checkpoints at 3, 6
    p_res, l_res = _train(cfg, 8, ckpt_dir=d, resume=True)  # resumes at 6
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert l_res[7] == l_full[7]


def test_atomic_no_partial_checkpoints(tmp_path):
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d, keep=2)
    tree = {"a": jnp.arange(10), "b": {"c": jnp.ones((3, 3))}}
    mgr.save(1, tree)
    mgr.save(2, tree)
    mgr.save(3, tree)
    steps = mgr.all_steps()
    assert steps == [2, 3]  # keep=2 pruned step 1
    assert not any(x.startswith("tmp-") for x in os.listdir(d))
    restored, _ = mgr.restore(3, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10))


def test_async_save(tmp_path):
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d)
    tree = {"w": jnp.ones((128, 128))}
    mgr.save_async(5, tree, {"data_step": 5})
    mgr.wait()
    assert mgr.latest_step() == 5


def test_manifest_gates_all_steps(tmp_path):
    """A step directory is committed only once its manifest exists: a
    crash window between the tmp->step rename becoming visible and the
    manifest write (or a manually damaged step) must stay invisible to
    ``all_steps``/``latest_step`` instead of being offered for restore."""
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d, keep=4)
    tree = {"a": jnp.arange(4)}
    mgr.save(1, tree)
    mgr.save(2, tree)
    assert mgr.all_steps() == [1, 2]
    os.remove(os.path.join(d, "step-0000000002", "manifest.json"))
    assert mgr.all_steps() == [1]
    assert mgr.latest_step() == 1
    # A bare directory (rename landed, nothing inside) is also invisible.
    os.makedirs(os.path.join(d, "step-0000000007"))
    assert mgr.all_steps() == [1]


def test_read_manifest_round_trip(tmp_path):
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d)
    mgr.save(3, {"a": jnp.arange(2)}, {"kind": "live", "seq": 9})
    manifest = mgr.read_manifest(3)
    assert manifest["step"] == 3
    assert manifest["meta"] == {"kind": "live", "seq": 9}


def test_elastic_reshard(tmp_path):
    """Checkpoint written unsharded restores onto a different layout
    (simulated by restoring with explicit device_put shardings)."""
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d)
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mgr.save(1, tree)
    dev = jax.devices()[0]
    sh = {"w": jax.sharding.SingleDeviceSharding(dev)}
    restored, _ = mgr.restore(1, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
