"""End-to-end system behaviour: the public API flows a user would run."""
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import cgrx, footprint, nodes
from repro.core.keys import KeyArray
from repro.data import keygen

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def test_paper_workload_end_to_end():
    """The paper's core loop: generate a uniformity-mixed key set, build
    cgRX, run point + range lookups, apply update waves, compare footprint
    against the fine-granular predecessor."""
    keys, rows, raw = keygen.keyset(20000, uniformity=0.5, bits=32, seed=0)
    idx = cgrx.build(keys, jnp.asarray(rows), bucket_size=16)

    q_raw = keygen.uniform_lookups(raw, 4096, seed=1)
    res = cgrx.lookup(idx, keygen.as_keys(q_raw, 32))
    assert bool(res.found.all())
    assert (raw[np.asarray(res.row_id)] == q_raw).all()

    z_raw = keygen.zipf_lookups(raw, 2048, theta=1.5, seed=2)
    rz = cgrx.lookup(idx, keygen.as_keys(z_raw, 32))
    assert bool(rz.found.all())

    m_raw = keygen.hit_ratio_lookups(raw, 2048, 0.5, out_of_range=False,
                                     bits=32, seed=3)
    rm = cgrx.lookup(idx, keygen.as_keys(m_raw, 32))
    assert (np.asarray(rm.found) == np.isin(m_raw, raw)).all()

    sraw = np.sort(raw)
    lo, hi = keygen.range_lookups(sraw, 64, 32, seed=4)
    rr = cgrx.range_lookup(idx, keygen.as_keys(lo, 32),
                           keygen.as_keys(hi, 32), max_hits=64)
    assert (np.asarray(rr.count) == 32).all()

    store = nodes.build(keys, jnp.asarray(rows), node_cap=32)
    ins = np.setdiff1d(
        np.arange(raw.max() + 1, raw.max() + 2001, dtype=np.uint64), raw)
    store = nodes.apply_batch(
        store, keygen.as_keys(ins, 32),
        jnp.arange(len(raw), len(raw) + len(ins), dtype=jnp.int32), None)
    r2 = nodes.lookup(store, keygen.as_keys(ins, 32))
    assert bool(r2.found.all())

    from repro.core import baselines as bl
    rx = bl.rx_build(keys, jnp.asarray(rows))
    f_rx = footprint.footprint(rx)["total_bytes"]
    f_cg = footprint.footprint(idx, paper_model=True)["total_bytes"]
    assert f_cg < 0.5 * f_rx


def test_quickstart_example_runs():
    from examples import quickstart
    quickstart.main(n=4000, lookups=1024)


def test_keygen_distributions():
    keys, rows, raw = keygen.keyset(5000, uniformity=0.0, bits=32)
    assert raw.max() == len(raw) - 1            # fully dense
    keys, rows, raw = keygen.keyset(5000, uniformity=1.0, bits=64, seed=1)
    assert raw.max() > 1 << 40                   # sparse draws
    z = keygen.zipf_lookups(raw, 5000, theta=3.0, seed=2)
    # extreme skew: a few keys dominate
    _, counts = np.unique(z, return_counts=True)
    assert counts.max() > 500
