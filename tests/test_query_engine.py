"""Batched rank engine == per-query path, bit for bit, on every backend.

The acceptance property of the query subsystem (docs/ARCHITECTURE.md):
one ``RankEngine.execute`` call over a planned lane batch must reproduce
``core/cgrx.lookup`` / ``core/cgrx.range_lookup`` exactly — same
bucketIDs, rowIDs, found flags, positions, range starts/counts/rows —
for every registered backend, including mixed point/range batches,
missing keys, duplicate keys and duplicate queries.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import cgrx
from repro.core.keys import KeyArray
from repro.query import (QueryBatch, RankEngine, available_backends,
                         get_backend, get_probe)

# Flat backends rank over CgrxIndex-shaped indexes; the 'node' backend
# serves chained node stores and is covered by tests/test_live_store.py.
BACKENDS = available_backends(kind="flat")


def mk(raw, is64=True):
    raw = np.asarray(raw, dtype=np.uint64)
    return KeyArray.from_u64(raw) if is64 else KeyArray.from_u32(
        raw.astype(np.uint32))


def build(n=3000, bucket=16, method="tree", is64=True, seed=0,
          duplicates=False):
    rng = np.random.default_rng(seed)
    space = 1 << 44 if is64 else 1 << 30
    raw = rng.integers(0, space, n, dtype=np.uint64)
    if duplicates:
        raw[n // 2:] = rng.choice(raw[: n // 2], n - n // 2)  # heavy dups
    else:
        raw = np.unique(raw)
    keys = mk(raw, is64)
    idx = cgrx.build(keys, jnp.arange(len(raw), dtype=jnp.int32), bucket,
                     method=method)
    return raw, keys, idx


def mixed_workload(raw, is64, seed=1, n_point=80, n_range=40):
    """Points: hits, misses, duplicate queries; ranges: random extents."""
    rng = np.random.default_rng(seed)
    space = 1 << 44 if is64 else 1 << 30
    hits = rng.choice(raw, n_point - n_point // 4)
    misses = rng.integers(0, space, n_point // 4 - 2, dtype=np.uint64)
    pts_raw = np.concatenate([hits, misses, hits[:2]])  # dup queries
    sraw = np.sort(raw)
    lo_raw = rng.integers(0, space, n_range, dtype=np.uint64)
    hi_raw = np.minimum(lo_raw + rng.integers(0, space // 8, n_range,
                                              dtype=np.uint64), space - 1)
    return (mk(pts_raw, is64), mk(lo_raw, is64), mk(hi_raw, is64),
            pts_raw, sraw)


def assert_tuple_equal(got, want, ctx):
    for f in want._fields:
        g, w = np.asarray(getattr(got, f)), np.asarray(getattr(want, f))
        assert (g == w).all(), f"{ctx}: field {f} diverges"


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("is64", [False, True])
def test_batched_equals_per_query_mixed(backend, is64):
    """>= 64 mixed point/range lookups in one call, bit-identical."""
    raw, _, idx = build(method=backend, is64=is64)
    pts, lo, hi, _, _ = mixed_workload(raw, is64)
    assert len(pts) + len(lo) >= 64

    want_p = cgrx.lookup(idx, pts)
    want_r = cgrx.range_lookup(idx, lo, hi, max_hits=32)

    engine = RankEngine(idx)
    plan = QueryBatch().add_points(pts).add_ranges(lo, hi).plan(max_hits=32)
    res = engine.execute(plan)

    assert_tuple_equal(res.points, want_p, f"{backend}/points")
    assert_tuple_equal(res.ranges, want_r, f"{backend}/ranges")


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_with_duplicate_keys(backend):
    """Duplicate keys in the indexed set: batched == per-query."""
    raw, _, idx = build(n=2000, bucket=8, method=backend, duplicates=True)
    pts, lo, hi, _, _ = mixed_workload(raw, True, seed=3)
    want_p = cgrx.lookup(idx, pts)
    want_r = cgrx.range_lookup(idx, lo, hi, max_hits=16)
    res = RankEngine(idx).execute(
        QueryBatch().add_points(pts).add_ranges(lo, hi).plan(max_hits=16))
    assert_tuple_equal(res.points, want_p, f"{backend}/dup-points")
    assert_tuple_equal(res.ranges, want_r, f"{backend}/dup-ranges")


@pytest.mark.parametrize("backend", BACKENDS)
def test_rank_batch_mixed_sides_matches_oracle(backend):
    """Per-lane sides == numpy searchsorted left/right per lane."""
    raw, _, idx = build(n=2500, bucket=16, method=backend, seed=5)
    sraw = np.sort(raw)
    rng = np.random.default_rng(6)
    q_raw = np.concatenate([rng.choice(raw, 100),
                            rng.integers(0, 1 << 44, 100, dtype=np.uint64)])
    sides = rng.integers(0, 2, len(q_raw)).astype(np.int32)
    got = np.asarray(get_backend(backend).rank_batch(
        idx, mk(q_raw), jnp.asarray(sides)))
    want = np.where(sides == 1,
                    np.searchsorted(sraw, q_raw, side="right"),
                    np.searchsorted(sraw, q_raw, side="left"))
    assert (got == want).all()


@pytest.mark.parametrize("backend", BACKENDS)
def test_large_rep_array_two_level_path(backend):
    """Enough buckets to force the hierarchical/splitter kernel paths."""
    raw, _, idx = build(n=20000, bucket=2, method=backend, seed=7)
    assert idx.num_buckets > 4096          # past the flat-kernel threshold
    pts = mk(np.random.default_rng(8).choice(raw, 64))
    want = cgrx.lookup(idx, pts)
    got = RankEngine(idx).lookup(pts)
    assert_tuple_equal(got, want, f"{backend}/two-level")


def test_engine_backend_override():
    """An index built with one method can be served by any backend."""
    raw, _, idx = build(method="tree")
    pts = mk(np.sort(raw)[:70])
    want = RankEngine(idx, backend="tree").lookup(pts)
    for backend in BACKENDS:
        got = RankEngine(idx, backend=backend).lookup(pts)
        assert_tuple_equal(got, want, f"override/{backend}")


def test_plan_layout_and_padding():
    pts = mk(np.arange(10, dtype=np.uint64))
    lo, hi = mk(np.arange(5, dtype=np.uint64)), mk(np.arange(5, 10, dtype=np.uint64))
    plan = QueryBatch().add_points(pts).add_ranges(lo, hi).plan(lane=128)
    assert plan.n_point == 10 and plan.n_range == 5
    assert plan.lanes == 128                     # 20 lanes padded up
    sides = np.asarray(plan.sides)
    assert (sides[:15] == 0).all()               # points + range los
    assert (sides[15:20] == 1).all()             # range his
    assert (sides[20:] == 0).all()               # padding


def test_registry_errors():
    assert set(BACKENDS) >= {"tree", "binary", "kernel"}
    with pytest.raises(KeyError):
        get_backend("no-such-backend")
    with pytest.raises(KeyError):
        get_probe("no-such-probe")
    # A never-touched batch plans to the canonical zero-lane plan
    # (32-bit by default) instead of raising — callers need no pre-check.
    plan = QueryBatch().plan()
    assert (plan.lanes, plan.n_point, plan.n_range, plan.n_agg) == (0,) * 4
    assert not plan.keys.is64
    with pytest.raises(ValueError):
        QueryBatch().plan(max_hits=0)            # invalid hit capacity
    with pytest.raises(ValueError):
        QueryBatch().plan(max_hits=(1 << 20) + 1)
    with pytest.raises(ValueError):
        QueryBatch().add_points(mk([1])).add_points(
            KeyArray.from_u32(np.array([1], np.uint32)))  # width mix


def test_all_empty_plan_dispatches_nothing():
    """Zero points AND zero ranges (every submission empty): the plan is
    a canonical zero-lane batch and the engine returns empty results
    without building/caching an executable or touching the device — the
    empty-flush fast path repro.db.Session relies on."""
    _, _, idx = build(n=500)
    engine = RankEngine(idx)
    empty = mk(np.zeros(0, np.uint64))
    plan = (QueryBatch().add_points(empty).add_ranges(empty, empty)
            .plan(max_hits=8))
    assert plan.lanes == 0 and plan.n_point == 0 and plan.n_range == 0
    res = engine.execute(plan)
    assert res.points.found.shape == (0,)
    assert res.points.row_id.shape == (0,)
    assert res.ranges.row_ids.shape == (0, 8)
    assert engine._exec_cache == {}      # no executable built or cached
