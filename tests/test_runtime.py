"""Fault-tolerance runtime: heartbeat, straggler, preemption, elastic mesh."""
import os
import time
import numpy as np
import jax
import jax.numpy as jnp

from repro.runtime import ElasticMesh, Heartbeat, PreemptionGuard, StragglerMonitor


def test_heartbeat_alive_and_stale(tmp_path):
    p = str(tmp_path / "hb.json")
    hb = Heartbeat(p, interval=0.05).start()
    hb.update(7)
    time.sleep(0.15)
    assert Heartbeat.is_alive(p, stale_after=1.0)
    hb.stop()
    assert not Heartbeat.is_alive(p, stale_after=0.0)  # instantly stale
    assert not Heartbeat.is_alive(str(tmp_path / "missing.json"), 10)


def test_straggler_detection_and_recovery():
    events = []
    mon = StragglerMonitor(threshold=3.0,
                           on_straggler=lambda s, d, e: events.append(s))
    for i in range(10):
        mon.record(i, 0.1)
    assert mon.record(10, 0.9)          # 9x the EMA -> straggler
    assert events == [10]
    # straggler does not poison the EMA
    assert abs(mon.ema - 0.1) < 1e-6
    assert not mon.record(11, 0.11)


def test_preemption_guard_checkpoint_path(tmp_path):
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path / "ck"))
    state = {"w": jnp.ones((4,))}
    with PreemptionGuard() as guard:
        for step in range(100):
            state = {"w": state["w"] + 1}
            if step == 5:
                guard.trigger()          # simulated SIGTERM
            if guard.preempted():
                mgr.save(step, state, {"data_step": step})
                break
    assert mgr.latest_step() == 5
    restored, meta = mgr.restore(5, state)
    assert meta["data_step"] == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.full((4,), 7.0))


def test_elastic_mesh_shrinks_data_axis():
    em = ElasticMesh(model_axis=16)
    assert em.mesh_for(256) == (16, 16)
    assert em.mesh_for(128) == (8, 16)     # lost half the pod
    assert em.mesh_for(96) == (4, 16)      # odd counts -> pow2 data
    em2 = ElasticMesh(model_axis=16, pod_axis=2)
    assert em2.mesh_for(512) == (2, 16, 16)


def test_elastic_mesh_model_fallback():
    em = ElasticMesh(model_axis=16)
    # so few devices the model axis must shrink too
    assert em.mesh_for(8) == (1, 8)
