"""Updatable node-chain variant (paper Sec. 4) vs a dict oracle."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import nodes
from repro.core.keys import KeyArray


def mk(raw, is64=True):
    raw = np.asarray(raw, dtype=np.uint64)
    return KeyArray.from_u64(raw) if is64 else KeyArray.from_u32(
        raw.astype(np.uint32))


def test_bulk_load_lookup():
    rng = np.random.default_rng(0)
    raw = np.unique(rng.integers(0, 1 << 44, 8000, dtype=np.uint64))[:6000]
    store = nodes.build(mk(raw), jnp.arange(len(raw), dtype=jnp.int32), 32)
    res = nodes.lookup(store, mk(raw))
    assert bool(res.found.all())
    assert (np.asarray(res.row_id) == np.arange(len(raw))).all()


@pytest.mark.parametrize("is64", [False, True])
def test_update_waves_match_oracle(is64):
    rng = np.random.default_rng(1)
    space = 1 << 44 if is64 else 1 << 30
    raw = np.unique(rng.integers(0, space, 6000, dtype=np.uint64))[:4000]
    store = nodes.build(mk(raw, is64), jnp.arange(len(raw), dtype=jnp.int32),
                        node_cap=32)
    live = {int(k): i for i, k in enumerate(raw)}
    nxt = len(raw)
    for wave in range(4):
        live_arr = np.array(sorted(live.keys()), dtype=np.uint64)
        ins = np.setdiff1d(
            np.unique(rng.integers(0, space, 3000, dtype=np.uint64)),
            live_arr)[:1000]
        dels = live_arr[rng.choice(len(live_arr), 700, replace=False)]
        ins_rows = np.arange(nxt, nxt + len(ins), dtype=np.int32)
        nxt += len(ins)
        store = nodes.apply_batch(store, mk(ins, is64), jnp.asarray(ins_rows),
                                  mk(dels, is64))
        for k, r in zip(ins, ins_rows):
            live[int(k)] = int(r)
        for k in dels:
            live.pop(int(k))
        la = np.array(list(live.keys()), dtype=np.uint64)
        lr = np.array([live[int(k)] for k in la])
        res = nodes.lookup(store, mk(la, is64))
        assert bool(res.found.all()), f"wave {wave}"
        assert (np.asarray(res.row_id) == lr).all()
        resd = nodes.lookup(store, mk(dels, is64))
        assert not bool(resd.found.any())


def test_insert_beyond_max_rep_goes_to_last_bucket():
    raw = np.arange(0, 1000, 2, dtype=np.uint64)
    store = nodes.build(mk(raw), None, node_cap=16)
    big = np.array([5000, 6000], dtype=np.uint64)
    store = nodes.apply_batch(store, mk(big),
                              jnp.asarray([7000, 7001], dtype=jnp.int32), None)
    res = nodes.lookup(store, mk(big))
    assert bool(res.found.all())
    assert np.asarray(res.row_id).tolist() == [7000, 7001]


def test_insert_delete_cancellation():
    raw = np.arange(0, 512, dtype=np.uint64)
    store = nodes.build(mk(raw), None, node_cap=16)
    k = np.array([600], dtype=np.uint64)
    store = nodes.apply_batch(store, mk(k), jnp.asarray([999], jnp.int32),
                              mk(k))  # insert AND delete -> cancelled
    res = nodes.lookup(store, mk(k))
    assert not bool(res.found.any())


def test_delete_then_reinsert_same_batch_stays_found():
    """Regression: a live key appearing in both batches must cancel the
    pair on BOTH sides (paper: removed from both batches), leaving the
    pre-existing copy live — not tombstoned with found=False."""
    raw = np.arange(0, 512, dtype=np.uint64)
    store = nodes.build(mk(raw), None, node_cap=16)
    k = np.array([100], dtype=np.uint64)  # live, rowID 100
    store = nodes.apply_batch(store, mk(k), jnp.asarray([999], jnp.int32),
                              mk(k))
    res = nodes.lookup(store, mk(k))
    assert bool(res.found.all())
    assert np.asarray(res.row_id).tolist() == [100]
    # Untouched neighbours unaffected.
    others = np.array([99, 101], dtype=np.uint64)
    reso = nodes.lookup(store, mk(others))
    assert bool(reso.found.all())


def test_delete_then_reinsert_across_batches():
    """Delete in one batch, reinsert in the next: found with the new row."""
    raw = np.arange(0, 512, dtype=np.uint64)
    store = nodes.build(mk(raw), None, node_cap=16)
    k = np.array([100], dtype=np.uint64)
    store = nodes.apply_batch(store, None, None, mk(k))
    assert not bool(nodes.lookup(store, mk(k)).found.any())
    store = nodes.apply_batch(store, mk(k), jnp.asarray([999], jnp.int32),
                              None)
    res = nodes.lookup(store, mk(k))
    assert bool(res.found.all())
    assert np.asarray(res.row_id).tolist() == [999]


def test_cancellation_is_pairwise_for_duplicates():
    """ins=[X,X] + del=[X]: ONE pair cancels, the surplus insert lands."""
    raw = np.arange(0, 512, dtype=np.uint64)
    store = nodes.build(mk(raw), None, node_cap=16)
    k = np.array([600, 600], dtype=np.uint64)
    store = nodes.apply_batch(store, mk(k), jnp.asarray([7, 8], jnp.int32),
                              mk(k[:1]))
    res = nodes.lookup(store, mk(k[:1]))
    assert bool(res.found.all())
    assert int(np.asarray(res.row_id)[0]) == 8  # earlier duplicate cancelled
    # Mirror image: ins=[X] + del=[X,X] against a live X -> X deleted.
    store2 = nodes.build(mk(raw), None, node_cap=16)
    k2 = np.array([100], dtype=np.uint64)
    store2 = nodes.apply_batch(store2, mk(k2), jnp.asarray([9], jnp.int32),
                               mk(np.array([100, 100], dtype=np.uint64)))
    assert not bool(nodes.lookup(store2, mk(k2)).found.any())


def test_bucket_count_tracks_live_keys():
    rng = np.random.default_rng(9)
    raw = np.unique(rng.integers(0, 1 << 40, 3000, dtype=np.uint64))[:2000]
    store = nodes.build(mk(raw), None, node_cap=16)
    assert int(nodes.live_count(store)) == len(raw)
    dels = raw[rng.choice(len(raw), 300, replace=False)]
    ins = np.setdiff1d(np.unique(rng.integers(0, 1 << 40, 1000,
                                              dtype=np.uint64)), raw)[:200]
    store = nodes.apply_batch(store, mk(ins),
                              jnp.arange(len(ins), dtype=jnp.int32), mk(dels))
    assert int(nodes.live_count(store)) == len(raw) + len(ins) - len(dels)


def test_chain_growth_and_splits():
    raw = np.arange(0, 256, dtype=np.uint64) * 1000
    store = nodes.build(mk(raw), None, node_cap=8)
    assert store.max_chain == 1
    # insert a burst targeting one bucket -> chain must grow
    burst = np.arange(1, 60, dtype=np.uint64)  # all in bucket 0
    store = nodes.apply_batch(store, mk(burst),
                              jnp.arange(1000, 1000 + len(burst), dtype=jnp.int32),
                              None)
    assert store.max_chain > 1
    res = nodes.lookup(store, mk(burst))
    assert bool(res.found.all())
    # reps were never touched
    assert store.num_buckets == len(store.reps.lo)


def test_rebuild_equivalence():
    rng = np.random.default_rng(3)
    raw = np.unique(rng.integers(0, 1 << 40, 3000, dtype=np.uint64))[:2000]
    store = nodes.build(mk(raw), None, node_cap=16)
    ins = np.setdiff1d(np.unique(rng.integers(0, 1 << 40, 2000,
                                              dtype=np.uint64)), raw)[:500]
    store = nodes.apply_batch(store, mk(ins),
                              jnp.arange(9000, 9000 + len(ins), dtype=jnp.int32),
                              None)
    rebuilt = nodes.rebuild(store)
    assert rebuilt.max_chain == 1
    la = np.concatenate([raw, ins])
    r1 = nodes.lookup(store, mk(la))
    r2 = nodes.lookup(rebuilt, mk(la))
    assert bool(r1.found.all()) and bool(r2.found.all())
    assert (np.asarray(r1.row_id) == np.asarray(r2.row_id)).all()


@given(st.integers(0, 2**31), st.integers(8, 64))
@settings(max_examples=8, deadline=None)
def test_property_random_update_sequence(seed, node_cap):
    rng = np.random.default_rng(seed)
    raw = np.unique(rng.integers(0, 1 << 32, 800, dtype=np.uint64))[:500]
    store = nodes.build(mk(raw), None, node_cap=int(node_cap))
    live = {int(k): i for i, k in enumerate(raw)}
    la = np.array(sorted(live), dtype=np.uint64)
    ins = np.setdiff1d(np.unique(rng.integers(0, 1 << 32, 400,
                                              dtype=np.uint64)), la)[:150]
    dels = la[rng.choice(len(la), 100, replace=False)]
    store = nodes.apply_batch(
        store, mk(ins), jnp.arange(10_000, 10_000 + len(ins), dtype=jnp.int32),
        mk(dels))
    for k, r in zip(ins, range(10_000, 10_000 + len(ins))):
        live[int(k)] = r
    for k in dels:
        live.pop(int(k))
    la = np.array(list(live), dtype=np.uint64)
    res = nodes.lookup(store, mk(la))
    assert bool(res.found.all())
    assert (np.asarray(res.row_id)
            == np.array([live[int(k)] for k in la])).all()
