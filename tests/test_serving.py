"""Serving engine + cgRX paged KV cache: index churn under real lifecycle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serving import paged
from repro.serving.engine import Engine


def test_page_table_alloc_lookup_free():
    cache = paged.create(num_layers=2, num_pages=64, page_size=8,
                         kv_heads=2, head_dim=16)
    # allocate blocks for 3 sequences
    cache, p0 = paged.alloc_blocks(cache, [1, 1, 1], [0, 1, 2])
    cache, p1 = paged.alloc_blocks(cache, [2, 2], [0, 1])
    assert len(set(p0) | set(p1)) == 5      # distinct physical pages
    rows, found = paged.lookup_pages(cache, np.array([1, 1, 2, 3]),
                                     np.array([0, 2, 1, 0]))
    found = np.asarray(found)
    rows = np.asarray(rows)
    assert found.tolist() == [True, True, True, False]
    assert rows[0] == p0[0] and rows[1] == p0[2] and rows[2] == p1[1]
    # free sequence 1 -> its pages return to the pool, lookups miss
    cache.seq_len[1] = 24
    cache = paged.free_sequence(cache, 1)
    rows, found = paged.lookup_pages(cache, np.array([1, 2]),
                                     np.array([0, 0]))
    assert np.asarray(found).tolist() == [False, True]
    assert len(cache.free_pages) == 64 - 2


def test_page_table_survives_churn():
    """Many alloc/free cycles: the successor structure never rebuilds and
    lookups stay correct (the paper's Fig. 15 property)."""
    cache = paged.create(num_layers=1, num_pages=128, page_size=4,
                         kv_heads=1, head_dim=8)
    rng = np.random.default_rng(0)
    live = {}
    next_seq = 0
    for _round in range(6):
        # allocate a few sequences
        for _ in range(4):
            sid = next_seq
            next_seq += 1
            nb = int(rng.integers(1, 5))
            cache, pages = paged.alloc_blocks(cache, [sid] * nb,
                                              list(range(nb)))
            cache.seq_len[sid] = nb * cache.page_size
            live[sid] = (nb, pages)
        # free a random one
        victim = rng.choice(list(live.keys()))
        cache = paged.free_sequence(cache, int(victim))
        del live[victim]
        # verify all live mappings
        for sid, (nb, pages) in live.items():
            rows, found = paged.lookup_pages(
                cache, np.full(nb, sid), np.arange(nb))
            assert np.asarray(found).all()
            assert np.asarray(rows).tolist() == pages
    # reps/BVH untouched: num_buckets fixed since build, epoch never
    # swapped (the table session's policy is never()).
    st = cache.table.stats()
    assert st.num_buckets == 1
    assert st.epoch == 0 and st.compactions == 0


def test_engine_end_to_end():
    cfg = get_config("yi-6b").tiny()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=2, max_seq=48, page_size=8,
                 num_pages=64)
    rng = np.random.default_rng(1)
    for _ in range(3):
        eng.submit(rng.integers(0, cfg.vocab_size, 6), max_new_tokens=5)
    results = eng.run_to_completion()
    assert len(results) == 3
    assert all(len(toks) == 5 for toks in results.values())
    s = eng.stats
    assert s.index_inserts > 0 and s.index_deletes > 0
    # all pages returned to the pool after retirement
    assert len(eng.cache.free_pages) == 64


def test_gather_window_shapes():
    cache = paged.create(num_layers=3, num_pages=16, page_size=4,
                         kv_heads=2, head_dim=8)
    rows = jnp.asarray(np.array([[0, 1, -1], [2, 3, 4]], np.int32))
    k, v = paged.gather_window(cache, rows)
    assert k.shape == (3, 2, 12, 2, 8)
    assert v.shape == k.shape
