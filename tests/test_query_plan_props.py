"""Property tests for the logical query-plan IR vs a numpy oracle.

Random expression trees — eq / between / isin (with duplicate keys) /
limit / count / min_key / max_key / probe / rank_scan, in random
interleavings — are submitted through one ``Session.flush`` on a random
tier and checked field-by-field against a brute-force host oracle
(searchsorted + explicit scans over the sorted key set).  This covers
the compiler's fragment bookkeeping (section offsets, inverse scatter,
per-fragment caps, aggregate field selection) far beyond the hand-picked
cases in tests/test_query_plan.py, including aggregates over empty
ranges and IN-lists that are 100% duplicates.

Runs hypothesis-driven when hypothesis is installed (randomized seeds
and tree mixes) and as fixed-seed sweeps always, via the
``tests/_hypothesis_compat.py`` shim.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import repro.db as db
from repro.query import plan as qplan

NEVER = db.CompactionPolicy().never()
MISS = -1


def mk(raw):
    return db.KeyArray.from_u64(np.asarray(raw, dtype=np.uint64))


# ---------------------------------------------------------------------------
# Brute-force oracle over the sorted (keys, rows) host arrays.
# ---------------------------------------------------------------------------

def oracle_points(s, srows, pts):
    pos = np.searchsorted(s, pts, "left")
    found = (pos < len(s)) & (s[np.minimum(pos, len(s) - 1)] == pts)
    rows = np.where(found, srows[np.minimum(pos, len(s) - 1)], MISS)
    return found, rows.astype(np.int64), pos.astype(np.int64)


def oracle_range(s, srows, lo, hi, cap):
    start = np.searchsorted(s, lo, "left")
    end = np.searchsorted(s, hi, "right")
    count = np.maximum(end - start, 0)
    rows = np.full((len(lo), cap), MISS, np.int64)
    for i in range(len(lo)):
        take = min(int(count[i]), cap)
        rows[i, :take] = srows[start[i]:start[i] + take]
    return start, count, rows


def check_tree_mix(seed: int, tier: str, n: int) -> None:
    rng = np.random.default_rng(seed)
    raw = np.unique(rng.integers(1, 1 << 44, int(n * 1.6) + 8,
                                 dtype=np.uint64))[:max(n, 8)]
    rows = rng.permutation(len(raw)).astype(np.int32)
    order = np.argsort(raw)
    s, srows = raw[order], rows[order]
    sess = db.open(
        db.IndexSpec(tier=tier, node_cap=8, bucket_size=8, policy=NEVER,
                     max_hits=16, shards=3, max_imbalance=None),
        mk(raw), rows)

    def rand_points(m):
        mix = np.concatenate([
            raw[rng.integers(0, len(raw), m)],                 # members
            rng.integers(0, 1 << 44, m, dtype=np.uint64),      # probes
            np.array([0, raw.max(), raw.max() + 3], np.uint64)])
        return mix[rng.permutation(len(mix))]

    def rand_ranges(m):
        a = rng.integers(0, 1 << 44, m, dtype=np.uint64)
        b = rng.integers(0, 1 << 44, m, dtype=np.uint64)
        lo, hi = np.minimum(a, b), np.maximum(a, b)
        # Force some empty and some degenerate single-key ranges.
        if m >= 3:
            lo[0], hi[0] = raw.max() + 10, raw.max() + 20   # empty, beyond
            lo[1], hi[1] = raw[0], raw[0]                    # exactly one
            if raw[2] > raw[1] + 1:
                lo[2], hi[2] = raw[1] + 1, raw[2] - 1        # gap: empty
        return lo, hi

    checks = []
    for _ in range(int(rng.integers(4, 9))):
        kind = rng.choice(["eq", "isin", "between", "limit", "count",
                           "min", "max", "probe", "rank"])
        if kind == "eq":
            pts = rand_points(int(rng.integers(1, 6)))
            t = sess.query(db.eq(mk(pts)))
            checks.append(("eq", t, pts))
        elif kind == "isin":
            base = rand_points(int(rng.integers(1, 5)))
            dup = base[rng.integers(0, len(base),
                                    int(rng.integers(1, 3) * len(base)))]
            t = sess.query(db.isin(mk(dup)))
            checks.append(("eq", t, dup))     # same per-key contract
        elif kind == "between":
            lo, hi = rand_ranges(int(rng.integers(1, 5)))
            t = sess.query(db.between(mk(lo), mk(hi)))
            checks.append(("range", t, (lo, hi, 16)))
        elif kind == "limit":
            lo, hi = rand_ranges(int(rng.integers(1, 5)))
            cap = int(rng.integers(1, 24))
            t = sess.query(db.limit(cap, db.between(mk(lo), mk(hi))))
            checks.append(("range", t, (lo, hi, cap)))
        elif kind in ("count", "min", "max"):
            lo, hi = rand_ranges(int(rng.integers(1, 5)))
            node = {"count": db.count, "min": db.min_key,
                    "max": db.max_key}[kind](db.between(mk(lo), mk(hi)))
            t = sess.query(node)
            checks.append((kind, t, (lo, hi)))
        elif kind == "probe":
            pts = rand_points(int(rng.integers(1, 4)))
            outer = rng.integers(0, 1 << 20, len(pts)).astype(np.int32)
            t = sess.query(db.probe(mk(pts), outer))
            checks.append(("probe", t, (pts, outer)))
        else:
            pts = rand_points(int(rng.integers(1, 5)))
            side = str(rng.choice(["left", "right"]))
            t = sess.query(db.rank_scan(mk(pts), side))
            checks.append(("rank", t, (pts, side)))

    before = dict(sess.dispatches)
    sess.flush()
    spent = {k: sess.dispatches[k] - before[k] for k in before}
    assert spent["apply"] == 0 and spent["query"] <= 1 and spent["rank"] <= 1

    for kind, t, args in checks:
        res = t.result()
        if kind == "eq":
            found, rows_w, pos = oracle_points(s, srows, args)
            assert (np.asarray(res.found) == found).all()
            assert (np.asarray(res.row_id) == rows_w).all()
            assert (np.asarray(res.position) == pos).all()
        elif kind == "range":
            lo, hi, cap = args
            start, count, rows_w = oracle_range(s, srows, lo, hi, cap)
            assert (np.asarray(res.start) == start).all()
            assert (np.asarray(res.count) == count).all()
            assert np.asarray(res.row_ids).shape == (len(lo), cap)
            assert (np.asarray(res.row_ids) == rows_w).all()
        elif kind == "count":
            lo, hi = args
            _, count, _ = oracle_range(s, srows, lo, hi, 1)
            assert (np.asarray(res) == count).all()
        elif kind in ("min", "max"):
            lo, hi = args
            start, count, _ = oracle_range(s, srows, lo, hi, 1)
            assert (np.asarray(res.count) == count).all()
            ne = count > 0
            got = res.keys.to_numpy()[ne]
            if kind == "min":
                want = s[start[ne]]
            else:
                want = s[(start + count)[ne] - 1]
            assert (got == want).all()
        elif kind == "probe":
            pts, outer = args
            found, rows_w, _ = oracle_points(s, srows, pts)
            assert (np.asarray(res.outer_row) == outer).all()
            assert (np.asarray(res.matched) == found).all()
            assert (np.asarray(res.inner_row) == rows_w).all()
        else:
            pts, side = args
            assert (np.asarray(res)
                    == np.searchsorted(s, pts, side)).all()


@pytest.mark.parametrize("seed,tier,n", [
    (0, "static", 300), (1, "live", 200), (2, "sharded", 250),
    (3, "live", 40), (4, "sharded", 64), (5, "static", 900),
])
def test_tree_mix_fixed(seed, tier, n):
    check_tree_mix(seed, tier, n)


@given(st.integers(0, 2 ** 31), st.sampled_from(["static", "live",
                                                 "sharded"]),
       st.integers(16, 400))
@settings(max_examples=10, deadline=None)
def test_property_tree_mix(seed, tier, n):
    check_tree_mix(seed, tier, n)


def test_isin_all_duplicates_single_lane():
    """A 100%-duplicate IN-list dispatches exactly ONE unique lane."""
    raw = np.arange(0, 256, 2, dtype=np.uint64)
    sess = db.open(db.IndexSpec(tier="live", policy=NEVER), mk(raw),
                   np.arange(len(raw), dtype=np.int32))
    dup = np.full(50, raw[3], np.uint64)
    t = sess.query(db.isin(mk(dup)))
    rep = sess.flush()
    assert rep.n_point == 1
    assert np.asarray(t.result().found).all()
    assert (np.asarray(t.result().row_id) == 3).all()


def test_empty_result_shapes_match_expr():
    """qplan.empty_result mirrors each node's resolved shape contract."""
    e64 = mk(np.zeros(0, np.uint64))
    assert qplan.empty_result(qplan.eq(e64)).found.shape == (0,)
    assert qplan.empty_result(
        qplan.limit(9, qplan.between(e64, e64))).row_ids.shape == (0, 9)
    agg = qplan.empty_result(qplan.min_key(qplan.between(e64, e64)))
    assert agg.count.shape == (0,) and agg.keys.is64
    assert qplan.empty_result(
        qplan.count(qplan.between(e64, e64))).shape == (0,)
