"""The composable query-plan API (repro.query.plan + Session.query).

The acceptance properties of the redesign:

  * ``Session.query`` composes every IR node kind — eq, between, isin,
    count/min/max aggregates, limit, probe, rank_scan — and a whole
    flush's trees lower into ONE dispatch per op class;
  * the ``lookup``/``range``/``scan_ranks`` sugar stays bit-identical to
    its pre-IR results (the cross-tier parity suite in tests/test_db.py
    runs unchanged; here we additionally pin sugar == query(node));
  * aggregate-only flushes provably skip rowID materialization on all
    three tiers including multi-shard decomposition — pinned via the
    engine's trace-time ``STAGE_COUNTERS``;
  * satellites: a never-touched ``QueryBatch`` plans to the zero-lane
    plan, and ``max_hits`` is validated at the spec/plan boundary with
    the offending value in the message.
"""
import numpy as np
import jax.numpy as jnp
import pytest

import repro.db as db
from repro.core.bucketing import build_buckets
from repro.kernels import ops as kops
from repro.query import (MAX_MAX_HITS, QueryBatch, STAGE_COUNTERS,
                         compile_exprs)
from repro.query import plan as qplan

NEVER = db.CompactionPolicy().never()
MISS = -1


def mk(raw):
    return db.KeyArray.from_u64(np.asarray(raw, dtype=np.uint64))


def spec_for(tier, scope=None, max_hits=32):
    return db.IndexSpec(tier=tier, node_cap=16, bucket_size=16,
                        policy=NEVER, max_hits=max_hits, shards=4,
                        max_imbalance=None, cache_scope=scope)


@pytest.fixture(scope="module")
def workload():
    rng = np.random.default_rng(11)
    raw = np.unique(rng.integers(0, 1 << 44, 4000, dtype=np.uint64))[:2500]
    rows = np.arange(len(raw), dtype=np.int32)
    sraw = np.sort(raw)
    srows = rows[np.argsort(raw)]
    hits = raw[rng.integers(0, len(raw), 80)]
    misses = np.setdiff1d(
        np.unique(rng.integers(0, 1 << 44, 60, dtype=np.uint64)), raw)[:40]
    pts = np.concatenate([hits, misses])
    # Wide ranges: cross 3+ shard boundaries on the 4-shard tier.
    starts = rng.integers(0, len(sraw) - 2100, 12)
    lo, hi = sraw[starts], sraw[starts + 2000]
    return dict(raw=raw, rows=rows, sraw=sraw, srows=srows, pts=pts,
                lo=lo, hi=hi, rng=rng)


def sessions(w, scope_prefix):
    for tier in ("static", "live", "sharded"):
        yield tier, db.open(spec_for(tier, f"{scope_prefix}-{tier}"),
                            mk(w["raw"]), w["rows"])


# ---------------------------------------------------------------------------
# Sugar == query(node): the verbs are thin IR constructors.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tier", ["static", "live", "sharded"])
def test_sugar_is_query_of_ir_node(tier, workload):
    w = workload
    sess = db.open(spec_for(tier), mk(w["raw"]), w["rows"])
    s_pts = sess.lookup(mk(w["pts"]))
    s_rng = sess.range(mk(w["lo"]), mk(w["hi"]))
    s_rnk = sess.scan_ranks(mk(w["pts"]), side="right")
    q_pts = sess.query(db.eq(mk(w["pts"])))
    q_rng = sess.query(db.between(mk(w["lo"]), mk(w["hi"])))
    q_rnk = sess.query(db.rank_scan(mk(w["pts"]), side="right"))
    sess.flush()
    for a, b in ((s_pts, q_pts), (s_rng, q_rng)):
        for f, g in zip(a.result(), b.result()):
            assert (np.asarray(f) == np.asarray(g)).all()
    assert (np.asarray(s_rnk.result()) == np.asarray(q_rnk.result())).all()


# ---------------------------------------------------------------------------
# IN-lists: dedup dispatch, duplicate-faithful results.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tier", ["static", "live", "sharded"])
def test_isin_duplicates_vs_oracle(tier, workload):
    w = workload
    sess = db.open(spec_for(tier), mk(w["raw"]), w["rows"])
    inlist = np.concatenate([w["pts"][:30], w["pts"][:30], w["pts"][:7],
                             np.array([0, 1, 2], np.uint64)])
    t = sess.query(db.isin(mk(inlist)))
    rep = sess.flush()
    # Dedup is the point of the node: lanes = UNIQUE keys only.
    assert rep.n_point == len(np.unique(inlist))
    res = t.result()
    found = np.asarray(res.found)
    rows = np.asarray(res.row_id)
    assert found.shape == inlist.shape
    want_found = np.isin(inlist, w["raw"])
    assert (found == want_found).all()
    pos = np.searchsorted(w["sraw"], inlist, "left")
    want_rows = np.where(want_found,
                         w["srows"][np.minimum(pos, len(w["sraw"]) - 1)],
                         MISS)
    assert (rows == want_rows).all()
    # Duplicates answered identically for free.
    assert (rows[:30] == rows[30:60]).all()


# ---------------------------------------------------------------------------
# Aggregates: count / min / max vs host oracle, incl. empty ranges and
# multi-shard spans.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tier", ["static", "live", "sharded"])
def test_aggregates_vs_oracle(tier, workload):
    w = workload
    sess = db.open(spec_for(tier), mk(w["raw"]), w["rows"])
    # Mix wide (multi-shard) ranges with empty ones (lo > hi and gaps).
    lo = np.concatenate([w["lo"], [w["sraw"][10] + 1], [w["sraw"][-1] + 5]])
    hi = np.concatenate([w["hi"], [w["sraw"][10]], [w["sraw"][-1] + 9]])
    t_cnt = sess.query(db.count(db.between(mk(lo), mk(hi))))
    t_min = sess.query(db.min_key(db.between(mk(lo), mk(hi))))
    t_max = sess.query(db.max_key(db.between(mk(lo), mk(hi))))
    rep = sess.flush()
    assert rep.n_agg == 3 * len(lo)

    s = w["sraw"]
    want_cnt = (np.searchsorted(s, hi, "right")
                - np.searchsorted(s, lo, "left")).astype(np.int64)
    want_cnt = np.maximum(want_cnt, 0)
    cnt = np.asarray(t_cnt.result())
    assert (cnt == want_cnt).all()

    mn, mx = t_min.result(), t_max.result()
    assert (np.asarray(mn.count) == want_cnt).all()
    assert (np.asarray(mx.count) == want_cnt).all()
    nonempty = want_cnt > 0
    assert nonempty.any() and (~nonempty).any()   # both cases exercised
    got_min = mn.keys.to_numpy()[nonempty]
    got_max = mx.keys.to_numpy()[nonempty]
    want_min = s[np.searchsorted(s, lo, "left")[nonempty]]
    want_max = s[np.searchsorted(s, hi, "right")[nonempty] - 1]
    assert (got_min == want_min).all()
    assert (got_max == want_max).all()


def test_aggregate_spans_cross_shards(workload):
    """The aggregate parity above really exercises 3+-shard spans, and
    the sharded merge (sum / min / max) matches a single-shard oracle."""
    w = workload
    sess = db.open(spec_for("sharded"), mk(w["raw"]), w["rows"])
    store = sess.tier.store
    spans = 1 + store.route(mk(w["hi"])) - store.route(mk(w["lo"]))
    assert spans.max() >= 3
    oracle = db.open(spec_for("live"), mk(w["raw"]), w["rows"])
    t_s = sess.query(db.min_key(db.between(mk(w["lo"]), mk(w["hi"]))))
    t_o = oracle.query(db.min_key(db.between(mk(w["lo"]), mk(w["hi"]))))
    sess.flush(); oracle.flush()
    assert (np.asarray(t_s.result().count)
            == np.asarray(t_o.result().count)).all()
    assert (t_s.result().keys.to_numpy()
            == t_o.result().keys.to_numpy()).all()


# ---------------------------------------------------------------------------
# limit(k): per-range hit caps.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tier", ["static", "live", "sharded"])
def test_limit_caps_rows_keeps_count(tier, workload):
    w = workload
    sess = db.open(spec_for(tier), mk(w["raw"]), w["rows"])
    t_full = sess.query(db.between(mk(w["lo"]), mk(w["hi"])))
    t_lim = sess.query(db.limit(5, db.between(mk(w["lo"]), mk(w["hi"]))))
    sess.flush()
    full, lim = t_full.result(), t_lim.result()
    assert np.asarray(full.row_ids).shape == (len(w["lo"]), 32)
    assert np.asarray(lim.row_ids).shape == (len(w["lo"]), 5)
    assert (np.asarray(lim.count) == np.asarray(full.count)).all()
    assert (np.asarray(lim.start) == np.asarray(full.start)).all()
    assert (np.asarray(lim.row_ids)
            == np.asarray(full.row_ids)[:, :5]).all()


def test_limit_above_session_default_widens_plan(workload):
    """A limit(k) larger than the session default gets its k columns —
    the plan's max_hits is the max of the fragments' caps."""
    w = workload
    sess = db.open(spec_for("live", max_hits=8), mk(w["raw"]), w["rows"])
    t_small = sess.query(db.between(mk(w["lo"]), mk(w["hi"])))
    t_big = sess.query(db.limit(48, db.between(mk(w["lo"]), mk(w["hi"]))))
    sess.flush()
    assert np.asarray(t_small.result().row_ids).shape == (len(w["lo"]), 8)
    assert np.asarray(t_big.result().row_ids).shape == (len(w["lo"]), 48)
    # The big fragment's extra columns are real rows, not padding noise:
    cnt = np.asarray(t_big.result().count)
    rows = np.asarray(t_big.result().row_ids)
    valid = np.arange(48)[None, :] < np.minimum(cnt, 48)[:, None]
    assert (rows[valid] >= 0).all() and (rows[~valid] == MISS).all()


# ---------------------------------------------------------------------------
# Join probes.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tier", ["static", "live", "sharded"])
def test_probe_join_vs_oracle(tier, workload):
    w = workload
    sess = db.open(spec_for(tier), mk(w["raw"]), w["rows"])
    outer_rows = np.arange(len(w["pts"]), dtype=np.int32) * 3 + 7
    t = sess.query(db.probe(mk(w["pts"]), outer_rows))
    sess.flush()
    res = t.result()
    assert (np.asarray(res.outer_row) == outer_rows).all()
    want_found = np.isin(w["pts"], w["raw"])
    assert (np.asarray(res.matched) == want_found).all()
    pos = np.searchsorted(w["sraw"], w["pts"], "left")
    want_inner = np.where(
        want_found, w["srows"][np.minimum(pos, len(w["sraw"]) - 1)], MISS)
    assert (np.asarray(res.inner_row) == want_inner).all()


# ---------------------------------------------------------------------------
# Fusion: >= 5 node kinds, one dispatch per op class per flush.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tier", ["static", "live", "sharded"])
def test_five_node_kinds_fuse_into_one_dispatch(tier, workload):
    w = workload
    sess = db.open(spec_for(tier), mk(w["raw"]), w["rows"])
    inlist = np.concatenate([w["pts"][:20], w["pts"][:20]])
    outer = np.arange(16, dtype=np.int32)
    tickets = [
        sess.query(db.eq(mk(w["pts"][:24]))),
        sess.query(db.between(mk(w["lo"]), mk(w["hi"]))),
        sess.query(db.isin(mk(inlist))),
        sess.query(db.count(db.between(mk(w["lo"]), mk(w["hi"])))),
        sess.query(db.max_key(db.between(mk(w["lo"]), mk(w["hi"])))),
        sess.query(db.probe(mk(w["pts"][:16]), outer)),
        sess.query(db.limit(3, db.between(mk(w["lo"]), mk(w["hi"])))),
        sess.query(db.rank_scan(mk(w["pts"][:10]))),
    ]
    before = dict(sess.dispatches)
    rep = sess.flush()
    spent = {k: sess.dispatches[k] - before[k] for k in before}
    assert spent == {"apply": 0, "query": 1, "rank": 1}
    assert rep.n_point == 24 + len(np.unique(inlist)) + 16
    assert rep.n_range == 2 * len(w["lo"])     # between + limit fragments
    assert rep.n_agg == 2 * len(w["lo"])       # count + max_key fragments
    assert rep.n_rank == 10
    for t in tickets:
        assert t.ready
    # Spot-check correctness survived the fusion.
    assert (np.asarray(tickets[3].result())
            == np.asarray(tickets[1].result().count)).all()
    assert bool(np.asarray(tickets[0].result().found).all())
    assert (np.asarray(tickets[7].result())
            == np.searchsorted(w["sraw"], w["pts"][:10], "left")).all()


# ---------------------------------------------------------------------------
# The aggregate-only fast path: no rowID materialization, any tier.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tier", ["static", "live", "sharded"])
def test_aggregate_only_flush_skips_row_gather(tier, workload):
    """An aggregate-only flush must trace NO point or rowID gather stage
    into its pipeline, on every tier — including the sharded tier, whose
    per-shard sub-plans decompose a 3+-shard span.  STAGE_COUNTERS bumps
    when a pipeline body runs (trace time under jit), so fresh sessions
    with fresh cache scopes see exactly the stages built."""
    w = workload
    sess = db.open(spec_for(tier, scope=f"aggskip-{tier}"),
                   mk(w["raw"]), w["rows"])
    before = dict(STAGE_COUNTERS)
    t = sess.query(db.count(db.between(mk(w["lo"]), mk(w["hi"]))))
    rep = sess.flush()
    spent = {k: STAGE_COUNTERS[k] - before[k] for k in STAGE_COUNTERS}
    assert spent["point_gather"] == 0 and spent["row_gather"] == 0, spent
    assert spent["agg"] >= 1 and spent["rank"] >= 1
    assert rep.n_point == 0 and rep.n_range == 0
    assert rep.n_agg == len(w["lo"])
    s = w["sraw"]
    want = (np.searchsorted(s, w["hi"], "right")
            - np.searchsorted(s, w["lo"], "left"))
    assert (np.asarray(t.result()) == want).all()


# ---------------------------------------------------------------------------
# Empty submissions, validation, compiler errors.
# ---------------------------------------------------------------------------

def test_zero_length_trees_resolve_immediately():
    raw = np.arange(0, 512, 2, dtype=np.uint64)
    sess = db.open(spec_for("live"), mk(raw),
                   np.arange(len(raw), dtype=np.int32))
    e = mk(np.zeros(0, np.uint64))
    t_isin = sess.query(db.isin(e))
    t_cnt = sess.query(db.count(db.between(e, e)))
    t_min = sess.query(db.min_key(db.between(e, e)))
    t_lim = sess.query(db.limit(5, db.between(e, e)))
    t_probe = sess.query(db.probe(e, np.zeros(0, np.int32)))
    assert sess.pending == 0
    rep = sess.flush()
    assert sess.dispatches == {"apply": 0, "query": 0, "rank": 0}
    assert (rep.n_point, rep.n_range, rep.n_agg, rep.n_rank) == (0,) * 4
    assert t_isin.result().found.shape == (0,)
    assert t_cnt.result().shape == (0,)
    assert t_min.result().count.shape == (0,)
    assert t_min.result().keys.shape == (0,)
    assert t_lim.result().row_ids.shape == (0, 5)
    assert t_probe.result().matched.shape == (0,)


def test_ir_construction_errors():
    k = mk([1, 2])
    with pytest.raises(TypeError):
        db.count(db.eq(k))                 # aggregates wrap ranges only
    with pytest.raises(TypeError):
        db.limit(4, db.eq(k))
    with pytest.raises(ValueError):
        db.limit(0, db.between(k, k))
    with pytest.raises(ValueError, match=str((1 << 20) + 1)):
        db.limit((1 << 20) + 1, db.between(k, k))
    with pytest.raises(ValueError):
        db.between(k, mk([1]))             # shape mismatch
    with pytest.raises(ValueError):
        db.probe(k, np.zeros(3, np.int32))
    with pytest.raises(ValueError):
        db.rank_scan(k, side="middle")
    raw = np.arange(0, 64, 2, dtype=np.uint64)
    sess = db.open(spec_for("live"), mk(raw),
                   np.arange(len(raw), dtype=np.int32))
    with pytest.raises(TypeError):
        sess.query("not an expression")


def test_max_hits_validated_at_every_boundary():
    """Satellite: non-positive or absurd max_hits fails loudly AT the
    boundary, always naming the offending value."""
    for bad in (0, -1, MAX_MAX_HITS + 1):
        with pytest.raises(db.InvalidSpecError, match=str(bad)):
            db.IndexSpec(max_hits=bad)
        with pytest.raises(ValueError, match=str(bad)):
            QueryBatch().plan(max_hits=bad)
    idx_raw = np.arange(0, 64, 2, dtype=np.uint64)
    tier = db.build_tier(spec_for("live"), mk(idx_raw))
    with pytest.raises(db.InvalidSpecError, match="-7"):
        db.Session(tier, max_hits=-7)
    # InvalidSpecError stays a ValueError for old-style callers.
    assert issubclass(db.InvalidSpecError, ValueError)


def test_never_touched_batch_plans_to_zero_lanes():
    """Satellite regression: QueryBatch().plan() on a never-touched
    batch returns the canonical zero-lane plan (32-bit default) instead
    of raising — callers need no emptiness pre-check."""
    plan = QueryBatch().plan()
    assert (plan.lanes, plan.n_point, plan.n_range, plan.n_agg) == (0,) * 4
    assert not plan.keys.is64 and plan.max_hits == 64
    # ...and the engine serves it without dispatching anything.
    raw = np.arange(0, 128, 2, dtype=np.uint64)
    tier = db.build_tier(spec_for("static"), mk(raw))
    res = tier.execute(plan)
    assert res.points.found.shape == (0,) and res.aggs is None


def test_compile_exprs_standalone_layout():
    """The compiler is usable below the Session: fragments collect in
    submission order and the plan max_hits is the max of the caps."""
    k = mk([5, 9]); lo = mk([1, 3]); hi = mk([8, 12])
    prog = compile_exprs([qplan.eq(k),
                          qplan.limit(7, qplan.between(lo, hi)),
                          qplan.count(qplan.between(lo, hi)),
                          qplan.rank_scan(k, "right")],
                         default_max_hits=4)
    assert (prog.n_point, prog.n_range, prog.n_agg, prog.n_rank) == (2, 2, 2, 2)
    assert prog.plan.max_hits == 7          # max(limit cap, default)
    assert not prog.plan.agg_keys           # count-only: no key planes
    assert prog.plan.lanes == 128           # 2 + 2*2 + 2*2 padded to a lane
    sides = np.asarray(prog.plan.sides)
    assert sides[:2].tolist() == [0, 0]             # point lanes
    assert sides[2:6].tolist() == [0, 0, 1, 1]      # range lo/lo/hi/hi
    assert sides[6:10].tolist() == [0, 0, 1, 1]     # agg lo/lo/hi/hi
    assert np.asarray(prog.rank_sides).tolist() == [1, 1]


# ---------------------------------------------------------------------------
# The kernel-level rank-only count helper.
# ---------------------------------------------------------------------------

def test_kernel_range_count_matches_oracle():
    rng = np.random.default_rng(3)
    raw = np.unique(rng.integers(0, 1 << 40, 2000, dtype=np.uint64))[:1500]
    s = np.sort(raw)
    buckets = build_buckets(mk(raw), jnp.arange(len(raw), dtype=jnp.int32),
                            16)
    a = rng.integers(0, 1 << 40, 40, dtype=np.uint64)
    b = rng.integers(0, 1 << 40, 40, dtype=np.uint64)
    lo, hi = np.minimum(a, b), np.maximum(a, b)
    got = np.asarray(kops.range_count(buckets, mk(lo), mk(hi)))
    want = np.searchsorted(s, hi, "right") - np.searchsorted(s, lo, "left")
    assert (got == want).all()
