"""Live index store vs a rebuilt-from-scratch CgrxIndex oracle.

The store's acceptance property: after ANY sequence of insert/delete
batches, ``LiveIndex.lookup`` / ``LiveIndex.range_lookup`` — served
through the rank engine's 'node' backend over degraded chains — must be
bit-identical (found, rowID, rank position, range start/count/rows) to a
``cgrx.build`` from scratch over the same live set.  Plus: epoch-swap
consistency (reads during compaction), automatic policy triggers, the
metrics surface, and the tick frontend's one-dispatch-per-class batching.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import cgrx, nodes
from repro.core.keys import KeyArray
from repro.query import QueryBatch, available_backends, get_backend
from repro.store import (CompactionPolicy, LiveConfig, LiveFrontend,
                         LiveIndex, LiveStats, should_compact)

NEVER = CompactionPolicy().never()


def mk(raw, is64=True):
    raw = np.asarray(raw, dtype=np.uint64)
    return KeyArray.from_u64(raw) if is64 else KeyArray.from_u32(
        raw.astype(np.uint32))


def build_live(raw, is64=True, **cfg_kwargs):
    cfg_kwargs.setdefault("policy", NEVER)
    cfg = LiveConfig(**cfg_kwargs)
    return LiveIndex.build(mk(raw, is64),
                           jnp.arange(len(raw), dtype=jnp.int32), cfg)


def build_oracle(live_dict, is64=True, bucket_size=16):
    """cgrx.build from scratch over the oracle's live (key -> row) map."""
    ks = np.array(sorted(live_dict), dtype=np.uint64)
    rows = np.array([live_dict[int(k)] for k in ks], dtype=np.int32)
    return cgrx.build(mk(ks, is64), jnp.asarray(rows), bucket_size,
                      presorted=True), ks


def assert_points_equal(got, want, ctx):
    for f in ("found", "row_id", "position"):
        g, w = np.asarray(getattr(got, f)), np.asarray(getattr(want, f))
        assert (g == w).all(), f"{ctx}: field {f} diverges"


def assert_ranges_equal(got, want, ctx):
    for f in want._fields:
        g, w = np.asarray(getattr(got, f)), np.asarray(getattr(want, f))
        assert (g == w).all(), f"{ctx}: field {f} diverges"


def check_against_oracle(live, live_dict, rng, is64, ctx, n_q=150):
    """Points (hits+misses) and ranges, live store vs fresh cgrx build."""
    oracle, ks = build_oracle(live_dict, is64)
    space = 1 << 44 if is64 else 1 << 30
    hits = ks[rng.integers(0, len(ks), n_q)] if len(ks) else \
        np.zeros(0, np.uint64)
    misses = np.setdiff1d(
        np.unique(rng.integers(0, space, n_q // 2, dtype=np.uint64)), ks)
    q = np.concatenate([hits, misses])
    qk = mk(q, is64)
    assert_points_equal(live.lookup(qk), cgrx.lookup(oracle, qk),
                        f"{ctx}/points")

    lo_raw = rng.integers(0, space, 40, dtype=np.uint64)
    span = rng.integers(0, space // 4, 40, dtype=np.uint64)
    hi_raw = np.minimum(lo_raw + span, space - 1)
    lo, hi = mk(lo_raw, is64), mk(hi_raw, is64)
    assert_ranges_equal(live.range_lookup(lo, hi, max_hits=32),
                        cgrx.range_lookup(oracle, lo, hi, max_hits=32),
                        f"{ctx}/ranges")


# ---------------------------------------------------------------------------
# Bit-identity vs the rebuilt oracle after randomized update waves.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("is64", [False, True])
def test_lookup_and_range_match_oracle_after_waves(is64):
    rng = np.random.default_rng(2)
    space = 1 << 44 if is64 else 1 << 30
    raw = np.unique(rng.integers(0, space, 5000, dtype=np.uint64))[:3000]
    live = build_live(raw, is64, node_cap=16)
    live_dict = {int(k): i for i, k in enumerate(raw)}
    nxt = len(raw)
    check_against_oracle(live, live_dict, rng, is64, "wave-init")
    for wave in range(4):
        la = np.array(sorted(live_dict), dtype=np.uint64)
        ins = np.setdiff1d(
            np.unique(rng.integers(0, space, 2500, dtype=np.uint64)),
            la)[:800]
        dels = la[rng.choice(len(la), 500, replace=False)]
        rows = np.arange(nxt, nxt + len(ins), dtype=np.int32)
        nxt += len(ins)
        live.apply(mk(ins, is64), jnp.asarray(rows), mk(dels, is64))
        for k, r in zip(ins, rows):
            live_dict[int(k)] = int(r)
        for k in dels:
            live_dict.pop(int(k))
        check_against_oracle(live, live_dict, rng, is64, f"wave{wave}")
    assert live.store.max_chain > 1  # the chains actually degraded


@pytest.mark.parametrize("rep_method", ["tree", "binary", "kernel"])
def test_rep_method_backends_agree(rep_method):
    """The 'node' backend's rep-search stage is pluggable; every method
    must serve the same results (the kernel path reuses the Pallas
    hierarchical successor kernel on the immutable rep array)."""
    rng = np.random.default_rng(4)
    raw = np.unique(rng.integers(0, 1 << 40, 3000, dtype=np.uint64))[:2000]
    live = build_live(raw, node_cap=16, rep_method=rep_method)
    live_dict = {int(k): i for i, k in enumerate(raw)}
    ins = np.setdiff1d(np.unique(rng.integers(0, 1 << 40, 1500,
                                              dtype=np.uint64)), raw)[:600]
    live.insert(mk(ins), jnp.arange(5000, 5000 + len(ins), dtype=jnp.int32))
    for i, k in enumerate(ins):
        live_dict[int(k)] = 5000 + i
    check_against_oracle(live, live_dict, rng, True, f"rep/{rep_method}")


def test_mixed_plan_through_engine_one_call():
    """A mixed point/range plan against the live store == per-call API."""
    rng = np.random.default_rng(5)
    raw = np.unique(rng.integers(0, 1 << 40, 3000, dtype=np.uint64))[:2000]
    live = build_live(raw, node_cap=16)
    dels = raw[rng.choice(len(raw), 300, replace=False)]
    live.delete(mk(dels))
    pts = mk(raw[rng.integers(0, len(raw), 90)])
    sraw = np.sort(np.setdiff1d(raw, dels))
    starts = rng.integers(0, len(sraw) - 20, 30)
    lo, hi = mk(sraw[starts]), mk(sraw[starts + 19])
    plan = QueryBatch().add_points(pts).add_ranges(lo, hi).plan(max_hits=32)
    res = live.execute(plan)
    assert_points_equal(res.points, live.lookup(pts), "plan/points")
    assert_ranges_equal(res.ranges, live.range_lookup(lo, hi, 32),
                        "plan/ranges")


def test_node_backend_registered_with_kind():
    assert "node" in available_backends()
    assert "node" in available_backends(kind="node")
    assert "node" not in available_backends(kind="flat")
    assert get_backend("node").kind == "node"
    assert {"tree", "binary", "kernel"} <= set(available_backends("flat"))


# ---------------------------------------------------------------------------
# Compaction: epoch swap, consistency during the swap, policy triggers.
# ---------------------------------------------------------------------------

def test_epoch_swap_reads_consistent_during_compaction():
    rng = np.random.default_rng(7)
    raw = np.unique(rng.integers(0, 1 << 40, 4000, dtype=np.uint64))[:2500]
    live = build_live(raw, node_cap=16)
    live_dict = {int(k): i for i, k in enumerate(raw)}
    ins0 = np.setdiff1d(np.unique(rng.integers(0, 1 << 40, 2000,
                                               dtype=np.uint64)), raw)[:800]
    live.insert(mk(ins0), jnp.arange(10_000, 10_000 + len(ins0),
                                     dtype=jnp.int32))
    for i, k in enumerate(ins0):
        live_dict[int(k)] = 10_000 + i

    task = live.begin_compaction("test")
    assert live.compacting and live.epoch == 0
    # Reads during the in-flight compaction serve the live epoch.
    check_against_oracle(live, live_dict, rng, True, "mid-compaction-pre")

    # A write landing MID-compaction: visible immediately AND after swap.
    la = np.array(sorted(live_dict), dtype=np.uint64)
    ins1 = np.setdiff1d(np.unique(rng.integers(0, 1 << 40, 1000,
                                               dtype=np.uint64)), la)[:300]
    dels1 = la[rng.choice(len(la), 200, replace=False)]
    live.apply(mk(ins1), jnp.arange(20_000, 20_000 + len(ins1),
                                    dtype=jnp.int32), mk(dels1))
    for i, k in enumerate(ins1):
        live_dict[int(k)] = 20_000 + i
    for k in dels1:
        live_dict.pop(int(k))
    assert len(task.replay) == 1
    check_against_oracle(live, live_dict, rng, True, "mid-compaction-post")

    live.finish_compaction(task)
    assert live.epoch == 1 and not live.compacting
    assert live.store.max_chain == 1  # chains folded away...
    check_against_oracle(live, live_dict, rng, True, "post-swap")


def test_epoch_swap_replay_preserves_midflight_writes():
    """After the swap, a key inserted mid-compaction must survive with
    its row, and a key deleted mid-compaction must stay gone — even
    though the cut was taken before either happened."""
    raw = np.arange(0, 4096, 2, dtype=np.uint64)
    live = build_live(raw, node_cap=16)
    task = live.begin_compaction("test")
    live.insert(mk([1001]), jnp.asarray([777], jnp.int32))
    live.delete(mk([100]))
    live.finish_compaction(task)
    res = live.lookup(mk([1001, 100, 102]))
    assert np.asarray(res.found).tolist() == [True, False, True]
    assert np.asarray(res.row_id)[0] == 777


def test_auto_compaction_chain_trigger_end_to_end():
    rng = np.random.default_rng(8)
    raw = np.arange(0, 4096, 8, dtype=np.uint64)  # 512 keys, dense buckets
    pol = CompactionPolicy(max_chain=3, min_fill=None,
                           max_tombstone_ratio=None)
    live = build_live(raw, node_cap=8, policy=pol, auto_compact=True)
    live_dict = {int(k): i for i, k in enumerate(raw)}
    nxt = len(raw)
    # Bursts into a narrow key range force chain growth past the trigger.
    for wave in range(4):
        ins = np.setdiff1d(np.arange(wave * 40, wave * 40 + 160,
                                     dtype=np.uint64),
                           np.array(sorted(live_dict), dtype=np.uint64))[:100]
        rows = np.arange(nxt, nxt + len(ins), dtype=np.int32)
        nxt += len(ins)
        live.insert(mk(ins), jnp.asarray(rows))
        for k, r in zip(ins, rows):
            live_dict[int(k)] = int(r)
    st_ = live.stats()
    assert st_.compactions >= 1, "chain trigger never fired"
    assert live.epoch == st_.compactions
    assert live.store.max_chain < 3
    check_against_oracle(live, live_dict, rng, True, "auto-compact")


def test_tombstone_trigger_and_policy_eval():
    raw = np.arange(0, 8192, 4, dtype=np.uint64)  # 2048 keys
    pol = CompactionPolicy(max_chain=None, min_fill=None,
                           max_tombstone_ratio=0.3)
    live = build_live(raw, node_cap=16, policy=pol, auto_compact=True)
    dels = raw[: len(raw) // 2]
    live.delete(mk(dels))
    assert live.stats().compactions == 1
    assert live.stats().deletes_since_compact == 0
    res = live.lookup(mk(dels[:32]))
    assert not bool(res.found.any())
    # Policy evaluation is pure: a healthy stats snapshot fires nothing.
    healthy = live.stats()
    assert should_compact(pol, healthy) is None


def test_metrics_surface():
    raw = np.arange(0, 2048, 2, dtype=np.uint64)
    live = build_live(raw, node_cap=16)
    live.insert(mk([1, 3, 5]), jnp.asarray([900, 901, 902], jnp.int32))
    live.delete(mk([0, 2]))
    s = live.stats()
    assert isinstance(s, LiveStats)
    assert s.epoch == 0 and s.compactions == 0 and not s.compacting
    assert s.live_keys == 1024 + 3 - 2
    assert s.applies == 2 and s.inserts == 3 and s.deletes == 2
    assert s.deletes_since_compact == 2
    assert 0.0 < s.fill_factor <= 1.0
    assert s.store_bytes > 0 and s.snapshot_bytes > 0
    assert s.total_bytes == s.store_bytes + s.snapshot_bytes
    live.compact()
    s2 = live.stats()
    assert s2.epoch == 1 and s2.compactions == 1
    assert s2.deletes_since_compact == 0
    assert s2.live_keys == s.live_keys


def test_snapshot_reader_point_in_time():
    """The epoch snapshot is a consistent immutable view: it serves the
    epoch base even while the store mutates, and advances on swap."""
    raw = np.arange(0, 2048, 2, dtype=np.uint64)
    live = build_live(raw, node_cap=16)
    reader = live.snapshot_reader()
    live.insert(mk([1, 3]), jnp.asarray([900, 901], jnp.int32))
    live.delete(mk([0, 2]))
    # Store sees the delta...
    assert bool(live.lookup(mk([1, 3])).found.all())
    assert not bool(live.lookup(mk([0, 2])).found.any())
    # ...the epoch-base reader does not (point-in-time semantics).
    snap = reader.lookup(mk([1, 3, 0, 2]))
    assert np.asarray(snap.found).tolist() == [False, False, True, True]
    live.compact()
    snap2 = live.snapshot_reader().lookup(mk([1, 3, 0, 2]))
    assert np.asarray(snap2.found).tolist() == [True, True, False, False]


# ---------------------------------------------------------------------------
# Frontend: tick-batched mixed ops.
# ---------------------------------------------------------------------------

def test_frontend_mixed_tick_writes_before_reads():
    rng = np.random.default_rng(11)
    raw = np.unique(rng.integers(0, 1 << 40, 3000, dtype=np.uint64))[:2000]
    live = build_live(raw, node_cap=16)
    fe = LiveFrontend(live, max_hits=16)

    ins = np.setdiff1d(np.unique(rng.integers(0, 1 << 40, 500,
                                              dtype=np.uint64)), raw)[:100]
    dels = raw[rng.choice(len(raw), 80, replace=False)]
    keep = np.setdiff1d(raw, dels)

    t_ins = fe.submit_insert(mk(ins), np.arange(7000, 7100, dtype=np.int32))
    t_del = fe.submit_delete(mk(dels))
    # Same-tick reads observe the writes (writes drain first).
    t_new = fe.submit_point(mk(ins[:20]))
    t_gone = fe.submit_point(mk(dels[:20]))
    t_old = fe.submit_point(mk(keep[:20]))
    sl = np.sort(np.concatenate([keep, ins]))
    starts = rng.integers(0, len(sl) - 10, 15)
    t_rng = fe.submit_range(mk(sl[starts]), mk(sl[starts + 9]))
    assert fe.pending == 6

    rep = fe.tick()
    assert fe.pending == 0
    assert (rep.n_point, rep.n_range) == (60, 15)
    assert (rep.n_insert, rep.n_delete) == (100, 80)
    assert fe.result(t_ins) == 100 and fe.result(t_del) == 80
    assert bool(fe.result(t_new).found.all())
    assert not bool(fe.result(t_gone).found.any())
    assert bool(fe.result(t_old).found.all())
    r = fe.result(t_rng)
    assert (np.asarray(r.count) == 10).all()
    with pytest.raises(KeyError):
        fe.result(t_rng)  # results pop once

    # Next tick: empty is fine, and ticket results keep streaming.
    t2 = fe.submit_point(mk(ins[:5]))
    rep2 = fe.tick()
    assert rep2.tick == 1 and rep2.n_insert == 0
    assert bool(fe.result(t2).found.all())


def test_frontend_empty_submissions_resolve():
    """Zero-length submissions settle immediately with empty results —
    a tick that dispatches nothing must not strand their tickets."""
    raw = np.arange(0, 512, dtype=np.uint64)
    live = build_live(raw, node_cap=16)
    fe = LiveFrontend(live, max_hits=8)
    empty = mk(np.zeros(0, np.uint64))
    t_p = fe.submit_point(empty)
    t_r = fe.submit_range(empty, empty)
    t_i = fe.submit_insert(empty, np.zeros(0, np.int32))
    t_d = fe.submit_delete(empty)
    assert fe.pending == 0
    rep = fe.tick()  # nothing to dispatch
    assert (rep.n_point, rep.n_range, rep.n_insert, rep.n_delete) == (0,) * 4
    assert fe.result(t_p).found.shape == (0,)
    assert fe.result(t_r).row_ids.shape == (0, 8)
    assert fe.result(t_i) == 0 and fe.result(t_d) == 0


def test_frontend_tick_reports_compaction_pause():
    raw = np.arange(0, 4096, 8, dtype=np.uint64)
    pol = CompactionPolicy(max_chain=2, min_fill=None,
                           max_tombstone_ratio=None)
    live = build_live(raw, node_cap=8, policy=pol)
    fe = LiveFrontend(live)
    ins = np.arange(1, 400, 2, dtype=np.uint64)  # dense burst -> chains
    fe.submit_insert(mk(ins), np.arange(len(ins), dtype=np.int32))
    rep = fe.tick()
    assert rep.compacted is not None
    assert rep.compact_seconds > 0.0
    assert live.epoch >= 1


# ---------------------------------------------------------------------------
# Property test (hypothesis when installed; skips cleanly otherwise).
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31), st.sampled_from([8, 16, 32]))
@settings(max_examples=6, deadline=None)
def test_property_random_waves_match_oracle(seed, node_cap):
    rng = np.random.default_rng(seed)
    raw = np.unique(rng.integers(0, 1 << 32, 900, dtype=np.uint64))[:600]
    live = build_live(raw, node_cap=int(node_cap))
    live_dict = {int(k): i for i, k in enumerate(raw)}
    nxt = len(raw)
    for _ in range(2):
        la = np.array(sorted(live_dict), dtype=np.uint64)
        ins = np.setdiff1d(
            np.unique(rng.integers(0, 1 << 32, 500, dtype=np.uint64)),
            la)[:150]
        dels = la[rng.choice(len(la), 100, replace=False)]
        rows = np.arange(nxt, nxt + len(ins), dtype=np.int32)
        nxt += len(ins)
        live.apply(mk(ins), jnp.asarray(rows), mk(dels))
        for k, r in zip(ins, rows):
            live_dict[int(k)] = int(r)
        for k in dels:
            live_dict.pop(int(k))
    check_against_oracle(live, live_dict, rng, True, f"prop{seed}", n_q=60)
