"""Gated hostile-traffic scenarios (the adaptive runtime's acceptance).

Three end-to-end properties, each driven through the public ``repro.db``
surface on CI-sized stores:

* flash crowd — an SLO'd session's deadline flushing keeps request
  sojourn p99 inside the SLO while the unprotected baseline (identical
  traffic, caller-controlled flushing) blows it;
* hot shard — balanced-size/hot-traffic skew triggers bounded
  incremental migration that (a) brings the measured touch imbalance
  back under the spec's ``max_imbalance``, (b) pauses per tick for less
  than one stop-and-rebuild rebalance, and (c) never perturbs a read:
  results stay bit-identical to the single-shard oracle throughout;
* scenario registry — ``benchmarks.scenarios`` stays importable with a
  stable scenario catalog (the CI perf-smoke job runs it for real).
"""
import time

import numpy as np
import jax.numpy as jnp
import pytest

import repro.db as db
from repro.core import cgrx
from repro.core.keys import KeyArray
from repro.data import keygen
from repro.store import (CompactionPolicy, LiveConfig, ShardedConfig,
                         ShardedLiveStore)

NEVER = CompactionPolicy().never()


def mk(raw):
    return KeyArray.from_u64(np.asarray(raw, dtype=np.uint64))


# ---------------------------------------------------------------------------
# Flash crowd: deadline flushing vs unprotected batching.
# ---------------------------------------------------------------------------

class TestFlashCrowdSlo:
    def _drive(self, spec, keys, lo, hi):
        """Submit one range per tick; only the admission controller (or
        the final drain) flushes.  Returns per-request sojourn times."""
        sess = db.open(spec, keys)
        # Pre-compile the steady-state plan shapes (lanes pad to
        # multiples of query.LANE): jit warmup is toolchain cost, not
        # the queueing behavior under test.
        for w in (1, 48):
            sess.range(keygen.as_keys(lo[:w], 32),
                       keygen.as_keys(hi[:w], 32))
            sess.flush()
        sojourn, waiting = [], []
        for i in range(len(lo)):
            t0 = time.perf_counter()
            sess.range(keygen.as_keys(lo[i:i + 1], 32),
                       keygen.as_keys(hi[i:i + 1], 32))
            waiting.append(t0)
            if sess.pending == 0:             # a deadline flush drained
                now = time.perf_counter()
                sojourn.extend(now - t for t in waiting)
                waiting.clear()
        sess.flush()
        now = time.perf_counter()
        sojourn.extend(now - t for t in waiting)
        tel = sess.telemetry()
        sess.close()
        return np.asarray(sojourn), tel

    def test_slo_p99_within_deadline_baseline_violates(self):
        slo_ms = 750.0
        n, q = 2048, 320
        keys, _rows, raw = keygen.keyset(n, 1.0, bits=32, seed=0)
        lo, hi = keygen.flash_crowd_ranges(raw, q, width=16,
                                           crowd_frac=0.9, seed=1)

        s_slo, tel = self._drive(db.IndexSpec(tier="live", slo_ms=slo_ms),
                                 keys, lo, hi)
        s_base, _ = self._drive(db.IndexSpec(tier="live"),
                                keys, lo, hi)

        # The controller actually drove the flushing...
        assert tel["admission"]["deadline_flushes"] >= 1
        assert tel["flushes"] > 2             # more than the warmups
        p99_slo = float(np.percentile(s_slo, 99))
        p99_base = float(np.percentile(s_base, 99))
        # ...kept the tail inside the SLO...
        assert p99_slo <= slo_ms / 1e3, (
            f"SLO'd p99 {p99_slo * 1e3:.1f}ms > slo {slo_ms}ms")
        # ...while the unprotected baseline batches itself into one
        # giant flush whose oldest requests blow the same deadline.
        assert p99_base > slo_ms / 1e3
        assert p99_base > p99_slo


# ---------------------------------------------------------------------------
# Hot shard: bounded incremental migration.
# ---------------------------------------------------------------------------

def _imbalanced_store(num_shards=2, n=1024, extra=3072):
    """Equal-split build, then a pile of inserts above the key range:
    deterministic size skew with identical shapes per call."""
    cfg = ShardedConfig(num_shards=num_shards,
                        live=LiveConfig(node_cap=16, policy=NEVER),
                        auto_rebalance=False)
    raw = np.arange(n, dtype=np.uint64) * 5
    store = ShardedLiveStore.build(
        mk(raw), jnp.arange(n, dtype=jnp.int32), cfg)
    hi = np.asarray(store.splitters.lo).max()
    more = np.arange(extra, dtype=np.uint64) * 3 + hi + 1
    store.apply(ins_keys=mk(more),
                ins_rows=jnp.arange(extra, dtype=jnp.int32))
    return store


class TestHotShardMigration:
    def test_converges_under_max_imbalance(self):
        """Uniform heat on ONE shard's lower key range: sizes stay
        balanced, the touch histogram triggers migration, and after the
        splitter has chased the heat the re-measured touch imbalance is
        back under the spec's bound."""
        n = 2048
        raw = np.arange(n, dtype=np.uint64) * 5
        max_imb = 1.3
        sess = db.open(db.IndexSpec(tier="sharded", shards=2,
                                    autotune=True, max_imbalance=max_imb,
                                    rebalance_mode="incremental",
                                    migrate_max_keys=128), raw)
        store = sess.tier.store
        srt = np.sort(raw)
        hot = srt[n // 2:n // 2 + 512]        # bottom of shard 1's range
        rng = np.random.default_rng(7)
        for _ in range(10):
            sess.lookup(db.as_key_array(hot[rng.integers(0, 512, 128)]))
            sess.flush()
        assert store.migrations >= 1
        # Freeze placement, re-observe the NEW layout with fresh traffic.
        sess._autotuner.max_imbalance = None
        store.touch.reset()
        for _ in range(4):
            sess.lookup(db.as_key_array(hot[rng.integers(0, 512, 128)]))
            sess.flush()
        final = store.stats().touch_imbalance
        assert 0.0 < final <= max_imb + 0.25, (
            f"touch imbalance {final:.2f} not rebalanced under "
            f"{max_imb} (migrations={store.migrations})")
        sess.close()

    def test_tick_pause_shorter_than_rebuild(self):
        """One bounded migration tick vs one stop-and-rebuild rebalance.

        Migration is O(donor): it live-cuts ONE shard and applies a
        64-key boundary run; rebuild extracts and re-splits every shard.
        At 8 shards the structural gap is ~8x of work, so even with
        hosted-runner jitter the warm per-tick pause stays strictly
        under a warm rebuild.  Each action mutates its store, so every
        measurement gets a freshly built identical twin (same shapes ->
        the first twin's compile warms all the rest)."""
        dims = dict(num_shards=8, n=16384, extra=2048)

        _imbalanced_store(**dims).migrate_step(64, use_touch=False)
        t_migrate = []
        for _ in range(2):
            s = _imbalanced_store(**dims)
            t0 = time.perf_counter()
            moved = s.migrate_step(64, use_touch=False)
            t_migrate.append(time.perf_counter() - t0)
            assert moved == 64                 # quantized budget honored

        _imbalanced_store(**dims).rebalance()
        t_rebalance = []
        for _ in range(2):
            s = _imbalanced_store(**dims)
            t0 = time.perf_counter()
            s.rebalance()
            t_rebalance.append(time.perf_counter() - t0)

        assert min(t_migrate) < min(t_rebalance), (
            f"migrate tick {min(t_migrate) * 1e3:.1f}ms not shorter "
            f"than rebuild {min(t_rebalance) * 1e3:.1f}ms")

    def test_reads_bit_identical_to_oracle_throughout(self):
        """After every migration tick, points AND ranges equal a fresh
        single-shard build over the same live multiset."""
        rng = np.random.default_rng(11)
        raw = np.unique(rng.integers(0, 1 << 40, 1200).astype(np.uint64))
        cfg = ShardedConfig(num_shards=4,
                            live=LiveConfig(node_cap=16, policy=NEVER),
                            auto_rebalance=False)
        store = ShardedLiveStore.build(
            mk(raw), jnp.arange(len(raw), dtype=jnp.int32), cfg)
        oracle = cgrx.build(mk(raw),
                            jnp.arange(len(raw), dtype=jnp.int32), 16,
                            presorted=True)
        q = mk(np.concatenate([raw[::4], raw[:7] + 1]))
        starts = rng.integers(0, len(raw) - 40, 16)
        lo, hi = mk(raw[starts]), mk(raw[starts + 39])

        # Heat one shard so the touch-aware donor pick engages.
        cut0 = np.asarray(store.shards[1].live_cut()[0].lo)
        for _ in range(4):
            store.lookup(mk(cut0[:64]))

        for tick in range(5):
            moved = store.migrate_step(64)
            if moved == 0:
                break
            got = store.lookup(q)
            want = cgrx.lookup(oracle, q)
            for f in ("found", "row_id", "position"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(got, f)),
                    np.asarray(getattr(want, f)),
                    err_msg=f"tick {tick}: point field {f}")
            gr = store.range_lookup(lo, hi, 64)
            wr = cgrx.range_lookup(oracle, lo, hi, 64)
            for f in wr._fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(gr, f)),
                    np.asarray(getattr(wr, f)),
                    err_msg=f"tick {tick}: range field {f}")
        assert store.migrations >= 1


# ---------------------------------------------------------------------------
# Scenario harness surface.
# ---------------------------------------------------------------------------

class TestScenarioRegistry:
    def test_catalog(self):
        from benchmarks import scenarios
        assert set(scenarios.SCENARIOS) == {
            "flash_crowd", "zipf_hotshard", "boundary_hotspot",
            "tenant_mix"}
        with pytest.raises(KeyError):
            scenarios.run_scenario("nope", 64, 64)

    def test_tenant_mix_scenario_exports_telemetry(self):
        from benchmarks import scenarios
        tel = scenarios.run_scenario("tenant_mix", 1024, 512, seed=0)
        assert tel["flushes"] >= 1
        assert "query" in tel["spans"]
        assert tel["autotune"]["candidates"]
