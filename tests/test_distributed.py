"""Multi-device tests (8 fake host devices, subprocess-isolated because
device count locks at first jax init)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_sharded_index_lookup():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.keys import KeyArray
        from repro.core import distributed as dist
        rng = np.random.default_rng(0)
        raw = np.unique(rng.integers(0, 1<<45, 12000, dtype=np.uint64))[:8000]
        keys = KeyArray.from_u64(raw)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        sidx = dist.build_sharded(keys, jnp.arange(len(raw), dtype=jnp.int32),
                                  16, 4, mesh=mesh)
        sel = rng.integers(0, len(raw), 2048)
        found, rowid = dist.sharded_lookup(sidx, keys[sel])
        assert np.asarray(found).all()
        assert (raw[np.asarray(rowid)] == raw[sel]).all()
        missing = np.setdiff1d(rng.integers(0, 1<<45, 4000, dtype=np.uint64), raw)[:2048]
        fm, _ = dist.sharded_lookup(sidx, KeyArray.from_u64(np.resize(missing, 2048)))
        assert not np.asarray(fm).any()
        print("SHARDED_OK")
    """)
    assert "SHARDED_OK" in out


def test_sharded_train_step_runs_and_matches_single():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import lm
        from repro.parallel import sharding
        from repro.training import optim, step as step_mod
        from repro.data import tokens as dt

        cfg = get_config("yi-6b").tiny()
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        opt = optim.init_state(params)
        batch = jax.tree.map(jnp.asarray, dt.synthetic_batch(0, 4, 32, cfg.vocab_size))
        ocfg = optim.AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=5)

        # single-device reference
        f1 = jax.jit(step_mod.make_train_step(cfg, ocfg))
        p1, o1, m1 = f1(params, opt, batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        policy = sharding.activation_policy(mesh)
        pspecs = sharding.param_specs(params, mesh)
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
        osh = optim.AdamWState(step=NamedSharding(mesh, P()),
                               m=jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs),
                               v=jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs))
        params_s = jax.tree.map(lambda x, s: jax.device_put(x, s), params, psh)
        opt_s = optim.AdamWState(step=opt.step,
            m=jax.tree.map(lambda x, s: jax.device_put(x, s), opt.m, psh),
            v=jax.tree.map(lambda x, s: jax.device_put(x, s), opt.v, psh))
        bspecs = sharding.batch_specs(batch, mesh)
        bsh = jax.tree.map(lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), batch, bspecs)
        f8 = jax.jit(step_mod.make_train_step(cfg, ocfg, policy=policy),
                     in_shardings=(psh, osh, jax.tree.map(lambda s: NamedSharding(mesh, s), bspecs)))
        p8, o8, m8 = f8(params_s, opt_s, bsh)
        l1, l8 = float(m1["loss"]), float(m8["loss"])
        assert abs(l1 - l8) / abs(l1) < 5e-2, (l1, l8)
        # parameters close after one step
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p8)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-2, atol=2e-2)
        print("TRAIN8_OK", l1, l8)
    """)
    assert "TRAIN8_OK" in out


def test_mini_dryrun_multi_pod_axes():
    """2x2x2 (pod,data,model) mesh: the multi-pod code path compiles and
    runs a real step (miniature of the 2x16x16 production dry-run)."""
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import lm
        from repro.parallel import sharding
        from repro.training import optim, step as step_mod
        from repro.data import tokens as dt

        cfg = get_config("yi-6b").tiny()
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        policy = sharding.activation_policy(mesh)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        opt = optim.init_state(params)
        pspecs = sharding.param_specs(params, mesh)
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
        osh = optim.AdamWState(step=NamedSharding(mesh, P()),
                               m=jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs),
                               v=jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs))
        batch = jax.tree.map(jnp.asarray, dt.synthetic_batch(0, 8, 32, cfg.vocab_size))
        bspecs = sharding.batch_specs(batch, mesh)
        bsh = jax.tree.map(lambda s: NamedSharding(mesh, s), bspecs)
        f = jax.jit(step_mod.make_train_step(cfg, optim.AdamWConfig(), policy=policy),
                    in_shardings=(psh, osh, bsh))
        lowered = f.lower(params, opt, batch)
        comp = lowered.compile()
        txt = comp.as_text()
        params_s = jax.tree.map(lambda x, s: jax.device_put(x, s), params, psh)
        opt_s = optim.AdamWState(step=opt.step,
            m=jax.tree.map(lambda x, s: jax.device_put(x, s), opt.m, psh),
            v=jax.tree.map(lambda x, s: jax.device_put(x, s), opt.v, psh))
        batch_s = jax.tree.map(lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), batch, bspecs)
        p2, o2, m = comp(params_s, opt_s, batch_s)
        assert np.isfinite(float(m["loss"]))
        print("PODMESH_OK", ("all-reduce" in txt))
    """)
    assert "PODMESH_OK True" in out


def test_compressed_pod_mean():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.training import compression
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        g = {"w": jnp.ones((64, 64)) * 3.0, "b": jnp.full((16,), -1.5)}
        out = compression.compressed_pod_mean(mesh, g)
        np.testing.assert_allclose(np.asarray(out["w"]), 3.0, rtol=2e-2)
        np.testing.assert_allclose(np.asarray(out["b"]), -1.5, rtol=2e-2)
        print("COMPRESS_OK")
    """)
    assert "COMPRESS_OK" in out


def test_sharded_range_count():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.keys import KeyArray
        from repro.core import distributed as dist
        rng = np.random.default_rng(4)
        raw = np.unique(rng.integers(0, 1<<45, 12000, dtype=np.uint64))[:8000]
        keys = KeyArray.from_u64(raw)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        sidx = dist.build_sharded(keys, jnp.arange(len(raw), dtype=jnp.int32),
                                  16, 4, mesh=mesh)
        sraw = np.sort(raw)
        starts = rng.integers(0, len(raw) - 200, 512)
        widths = rng.integers(1, 128, 512)
        lo = sraw[starts]; hi = sraw[np.minimum(starts + widths - 1, len(raw)-1)]
        cnt = dist.sharded_range_count(
            sidx, KeyArray.from_u64(lo), KeyArray.from_u64(hi))
        want = np.searchsorted(sraw, hi, 'right') - np.searchsorted(sraw, lo, 'left')
        assert (np.asarray(cnt) == want).all(), (np.asarray(cnt)[:5], want[:5])
        # cross-shard ranges (span multiple partitions)
        lo2 = sraw[:4]; hi2 = sraw[-4:]
        cnt2 = dist.sharded_range_count(
            sidx, KeyArray.from_u64(lo2), KeyArray.from_u64(hi2))
        want2 = np.searchsorted(sraw, hi2, 'right') - np.searchsorted(sraw, lo2, 'left')
        assert (np.asarray(cnt2) == want2).all()
        print("RANGE_COUNT_OK")
    """)
    assert "RANGE_COUNT_OK" in out
