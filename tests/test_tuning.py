"""Adaptive serving runtime: telemetry bus, admission control, autotuner.

Pins, per subsystem:

* ``TelemetryBus`` — windowed quantiles against numpy oracles, ring
  bounds, tag folding, stage-counter baselining, JSON-able export;
* ``TouchTracker`` — EWMA decay, imbalance contract, reset;
* ``AdmissionController`` — the submission protocol's edge cases: SLO
  unset leaves the session BIT-IDENTICAL to the historical behavior
  (dispatch counter pinned), a 1-item queue survives a flush storm,
  ``OverloadError`` carries an accurate queue depth, shed-then-retry
  succeeds, deadline flushing fires exactly when predicted cost eats
  the headroom;
* ``AutoTuner`` — explore-then-commit converges on the measured-fastest
  backend (prior only orders exploration), and BOTH placement trigger
  paths fire: size imbalance (the historical axis) and touch-rate
  imbalance — the balanced-size/hot-shard workload the size histogram
  cannot see (the ``ShardedStats.imbalance`` blindness this PR fixes);
* ``runtime.ft`` — heartbeats and straggler flags land on the bus.
"""
import json

import numpy as np
import jax.numpy as jnp
import pytest

import repro.db as db
from repro.core.keys import KeyArray
from repro.runtime.ft import Heartbeat, StragglerMonitor
from repro.store import (CompactionPolicy, LiveConfig, ShardedConfig,
                         ShardedLiveStore)
from repro.tuning import (AdmissionController, AutoTuner, TelemetryBus,
                          TouchTracker, prior_cost, prior_order)

NEVER = CompactionPolicy().never()


def mk(raw):
    return KeyArray.from_u64(np.asarray(raw, dtype=np.uint64))


def build_store(raw, num_shards=4, **cfg_kwargs):
    cfg_kwargs.setdefault("auto_rebalance", False)
    cfg = ShardedConfig(num_shards=num_shards,
                        live=LiveConfig(node_cap=16, policy=NEVER),
                        **cfg_kwargs)
    rows = jnp.arange(len(raw), dtype=jnp.int32)
    return ShardedLiveStore.build(mk(raw), rows, cfg)


# ---------------------------------------------------------------------------
# TelemetryBus.
# ---------------------------------------------------------------------------

class TestTelemetryBus:
    def test_quantiles_match_numpy(self):
        bus = TelemetryBus()
        vals = [0.001 * i for i in range(1, 101)]
        for v in vals:
            bus.span("query", v)
        q = bus.quantiles("query")
        assert q["n"] == 100
        assert q["p50"] == pytest.approx(np.percentile(vals, 50))
        assert q["p99"] == pytest.approx(np.percentile(vals, 99))
        assert q["mean"] == pytest.approx(np.mean(vals))
        assert bus.p99("query") == q["p99"]

    def test_ring_is_windowed(self):
        bus = TelemetryBus(capacity=8)
        for _ in range(100):
            bus.span("apply", 1.0)
        for _ in range(8):
            bus.span("apply", 3.0)          # overwrite the whole window
        q = bus.quantiles("apply")
        assert q["mean"] == pytest.approx(3.0)   # old 1.0s fell off
        assert q["n"] == 108                     # count is lifetime

    def test_tagged_spans_fold_into_untagged(self):
        bus = TelemetryBus()
        bus.span("query", 0.010, tag="tree")
        bus.span("query", 0.020, tag="binary")
        assert bus.quantiles("query")["n"] == 2
        table = bus.by_tag("query")
        assert set(table) == {"tree", "binary"}
        assert table["tree"]["p50"] == pytest.approx(0.010)

    def test_rate_is_seconds_per_item(self):
        bus = TelemetryBus()
        bus.span("flush", 0.10, n=100)
        bus.span("flush", 0.30, n=100)
        assert bus.rate("flush") == pytest.approx(0.002)
        assert bus.rate("never-seen") == 0.0

    def test_stage_counters_report_deltas(self):
        bus = TelemetryBus()
        bus.counters({"gather": 10, "rank": 5})     # baseline
        bus.counters({"gather": 17, "rank": 5})
        assert bus.counter("stage_gather") == 7
        assert bus.counter("stage_rank") == 0

    def test_event_ring_is_bounded(self):
        bus = TelemetryBus(event_capacity=4)
        for i in range(10):
            bus.event("beat", step=i)
        evs = bus.events("beat")
        assert len(evs) == 4
        assert [e["step"] for e in evs] == [6, 7, 8, 9]

    def test_export_is_json_able(self, tmp_path):
        bus = TelemetryBus()
        bus.span("query", 0.01, n=4, tag="tree")
        bus.bump("lanes_point", 4)
        bus.gauge("fill", 0.5)
        bus.touch([1.0, 3.0])
        bus.event("autotune", action="noop")
        bus.flush_mark()
        out = bus.export()
        assert out["flushes"] == 1
        assert "query:tree" in out["spans"] and "query" in out["spans"]
        assert out["counters"]["lanes_point"] == 4
        assert out["touch_rates"] == [1.0, 3.0]
        json.dumps(out)                       # must round-trip
        p = tmp_path / "tel.json"
        bus.export_json(str(p))
        assert json.loads(p.read_text())["gauges"]["fill"] == 0.5


class TestTouchTracker:
    def test_imbalance_contract(self):
        t = TouchTracker(4)
        assert t.imbalance == 0.0             # no data yet
        t.record(np.array([100, 0, 0, 0]))
        assert t.imbalance == pytest.approx(4.0)
        t.record(np.array([0, 100, 0, 0]))    # decays toward balance
        assert 1.0 < t.imbalance < 4.0
        t.reset()
        assert t.imbalance == 0.0 and t.total_events == 0

    def test_decay_forgets_old_heat(self):
        t = TouchTracker(2, decay=0.5)
        t.record(np.array([64, 0]))
        for _ in range(20):
            t.record(np.array([0, 64]))
        assert np.argmax(t.rates) == 1
        assert t.imbalance < 2.01             # near-balanced history gone


# ---------------------------------------------------------------------------
# AdmissionController.
# ---------------------------------------------------------------------------

def _keys(vals):
    return db.as_key_array(np.asarray(vals, np.uint64))


class TestAdmission:
    def test_slo_unset_is_bit_identical(self):
        """A default spec constructs NO controller, and the session's
        dispatch counters + results match the historical behavior."""
        raw = np.arange(512, dtype=np.uint64) * 3
        plain = db.open(db.IndexSpec(tier="live"), raw)
        assert plain._admission is None and plain._autotuner is None
        q = _keys([0, 3, 9, 5])
        t1 = plain.lookup(q)
        plain.insert(_keys([1000]), np.asarray([7]))
        plain.flush()
        assert plain.dispatches == {"apply": 1, "query": 1, "rank": 0}

        slo = db.open(db.IndexSpec(tier="live", slo_ms=1e6), raw)
        t2 = slo.lookup(q)
        slo.insert(_keys([1000]), np.asarray([7]))
        slo.flush()
        # A generous SLO never forces a flush: same dispatch rounds,
        # bit-identical results.
        assert slo.dispatches == plain.dispatches
        for f in ("found", "row_id", "position"):
            np.testing.assert_array_equal(
                np.asarray(getattr(t1.result(), f)),
                np.asarray(getattr(t2.result(), f)))

    def test_overload_error_carries_queue_state(self):
        raw = np.arange(64, dtype=np.uint64)
        sess = db.open(db.IndexSpec(tier="live", max_pending=2), raw)
        sess.lookup(_keys([1]))
        sess.lookup(_keys([2]))
        with pytest.raises(db.OverloadError) as ei:
            sess.lookup(_keys([3]))
        err = ei.value
        assert err.queue_depth == 2
        assert err.max_pending == 2
        assert err.estimated_wait > 0.0
        assert sess.pending == 2              # shed BEFORE enqueue
        assert sess.telemetry()["admission"]["shed"] == 1

    def test_shed_then_retry_succeeds(self):
        raw = np.arange(64, dtype=np.uint64)
        sess = db.open(db.IndexSpec(tier="live", max_pending=1), raw)
        sess.lookup(_keys([1]))
        with pytest.raises(db.OverloadError):
            sess.lookup(_keys([2]))
        sess.flush()
        t = sess.lookup(_keys([2]))           # queue drained: admitted
        assert bool(np.asarray(t.result().found)[0])

    def test_flush_storm_under_one_item_queue(self):
        """max_pending=1: every second submission sheds; flushing after
        each shed keeps the session serving every admitted request."""
        raw = np.arange(256, dtype=np.uint64)
        sess = db.open(db.IndexSpec(tier="live", max_pending=1), raw)
        shed = 0
        for i in range(40):
            try:
                sess.insert(_keys([1000 + i]), np.asarray([i]))
            except db.OverloadError:
                shed += 1
                sess.flush()
                # An admitted retry after the drain must succeed.
                sess.insert(_keys([1000 + i]), np.asarray([i]))
        sess.flush()
        assert shed == 39                     # every non-first fill shed
        assert sess.telemetry()["admission"]["shed"] == 39
        # The storm never lost an ADMITTED item.
        t = sess.lookup(_keys([int(1000 + i) for i in range(40)]))
        assert np.asarray(t.result().found).all()
        # And the queue bound genuinely holds: without draining, only
        # the first submission of a burst is admitted.
        with pytest.raises(db.OverloadError):
            sess.insert(_keys([2000]), np.asarray([0]))
            sess.insert(_keys([2001]), np.asarray([1]))
        assert sess.pending == 1

    def test_deadline_flush_fires_on_headroom(self):
        bus = TelemetryBus()
        ctl = AdmissionController(bus, slo_ms=100.0)
        ctl.note_submit(now=0.0)
        # Far from the deadline: predicted cost fits, no flush.
        assert not ctl.should_flush(now=0.0, pending=1)
        # Teach the model a 10ms/item cost: at 8 pending the 2x-padded
        # prediction (160ms) eats the 100ms budget from t=0.
        ctl.observe_flush(0.10, 10)
        ctl.observe_flush(0.10, 10)
        assert ctl.should_flush(now=0.0, pending=8)
        assert ctl.deadline_flushes == 1
        assert bus.counter("admission_deadline_flush") == 1
        ctl.on_flush()
        assert ctl.deadline() is None         # disarmed

    def test_deadline_flush_in_session(self):
        """An SLO'd session flushes from the submission path once the
        queue's predicted drain cost threatens the oldest deadline."""
        raw = np.arange(512, dtype=np.uint64)
        sess = db.open(db.IndexSpec(tier="live", slo_ms=20.0), raw)
        # Teach the cost model an expensive flush: 1s for 10 items.
        sess._admission.observe_flush(1.0, 10)
        tickets = [sess.lookup(_keys([int(i)])) for i in range(4)]
        # 100ms/item * 2 safety margin >= 20ms SLO at pending=1: the
        # second submission must have flushed the first.
        assert sess.telemetry()["admission"]["deadline_flushes"] >= 1
        sess.flush()
        assert all(t.ready for t in tickets)

    def test_validation(self):
        with pytest.raises(ValueError):
            AdmissionController(TelemetryBus(), slo_ms=0)
        with pytest.raises(ValueError):
            AdmissionController(TelemetryBus(), max_pending=0)
        with pytest.raises(db.InvalidSpecError):
            db.IndexSpec(slo_ms=-1)
        with pytest.raises(db.InvalidSpecError):
            db.IndexSpec(max_pending=0)
        with pytest.raises(db.InvalidSpecError):
            db.IndexSpec(rebalance_mode="sometimes")


# ---------------------------------------------------------------------------
# AutoTuner.
# ---------------------------------------------------------------------------

class _FakeStats:
    num_buckets = 64


class _FakeTier:
    """Duck-typed tier recording backend repoints."""

    def __init__(self, backend="tree"):
        self.current_backend = backend
        self.history = [backend]

    def set_backend(self, name):
        self.current_backend = name
        self.history.append(name)

    def stats(self):
        return _FakeStats()


class TestAutoTuner:
    def test_prior_orders_by_roofline(self):
        order = prior_order(("tree", "binary", "kernel"), num_buckets=64)
        assert set(order) == {"tree", "binary", "kernel"}
        costs = [prior_cost(b, 64) for b in order]
        assert costs == sorted(costs)

    def test_explore_then_commit_picks_measured_fastest(self):
        """The prior only orders exploration; the commit is measured.
        'kernel' is made the measured-fastest even though its prior
        (launch overhead) ranks it last."""
        bus = TelemetryBus()
        tier = _FakeTier()
        tuner = AutoTuner(tier, bus, explore_flushes=2)
        assert tuner.candidates[-1] == "kernel"   # worst under the prior
        lat = {"tree": 0.010, "binary": 0.008, "kernel": 0.002}
        for _ in range(3 * 2 + 2):                # enough ticks to commit
            bus.span("query", lat[tier.current_backend], n=4,
                     tag=tier.current_backend)
            tuner.tick()
            if tuner.committed_backend:
                break
        assert tuner.committed_backend == "kernel"
        assert tier.current_backend == "kernel"
        commits = [e for e in bus.events("autotune")
                   if e["action"] == "commit_backend"]
        assert len(commits) == 1 and commits[0]["backend"] == "kernel"
        # Every candidate was actually explored before the commit.
        assert set(tier.history) == {"tree", "binary", "kernel"}

    def test_commit_without_traffic_keeps_prior_pick(self):
        bus = TelemetryBus()
        tier = _FakeTier()
        tuner = AutoTuner(tier, bus, explore_flushes=1)
        for _ in range(5):
            tuner.tick()
        assert tuner.committed_backend == tuner.candidates[0]

    def test_session_convergence_end_to_end(self):
        """A live session under autotune commits to the backend with the
        fastest measured tagged p50 — pinned via its own telemetry."""
        raw = np.arange(2048, dtype=np.uint64) * 5
        sess = db.open(db.IndexSpec(tier="live", autotune=True), raw)
        q = _keys((np.arange(256) * 5) % 2048)
        while sess._autotuner.committed_backend is None:
            sess.lookup(q)
            sess.flush()
        tel = sess.telemetry()
        committed = tel["autotune"]["committed_backend"]
        table = {t: s for t, s in sess.bus.by_tag("query").items()
                 if s["n"]}
        assert committed in table
        assert table[committed]["p50"] == min(s["p50"]
                                              for s in table.values())
        sess.close()


# ---------------------------------------------------------------------------
# Placement triggers: size-skew vs touch-skew (the blindness fix).
# ---------------------------------------------------------------------------

class _StoreTier:
    """Minimal tier wrapper handing the tuner a sharded store."""

    def __init__(self, store):
        self.store = store

    def stats(self):
        return self.store.stats()


class TestPlacementTriggers:
    def _hot_traffic(self, store, shard, batches=6):
        """Point-lookup traffic confined to ONE shard's key range."""
        cuts = [np.asarray(s.live_cut()[0].lo) for s in store.shards]
        hot = cuts[shard]
        for _ in range(batches):
            store.lookup(mk(hot[:64]))

    def test_touch_trigger_fires_where_size_is_blind(self):
        """Balanced sizes + one hot shard: ``imbalance`` (size) sees
        nothing, ``touch_imbalance`` does, and the tuner migrates."""
        raw = np.arange(1024, dtype=np.uint64) * 7
        store = build_store(raw, num_shards=4)
        self._hot_traffic(store, shard=2)
        st = store.stats()
        assert st.imbalance <= 1.1            # size histogram: balanced
        assert st.touch_imbalance > 2.0       # the axis size cannot see
        bus = TelemetryBus()
        tuner = AutoTuner(_StoreTier(store), bus, max_imbalance=1.5,
                          rebalance_mode="incremental",
                          migrate_max_keys=64)
        tuner.tick()
        assert store.migrations == 1
        evs = [e for e in bus.events("autotune")
               if e["action"] == "migrate_step"]
        assert len(evs) == 1 and evs[0]["moved"] >= 1
        assert evs[0]["touch_imbalance"] > 2.0
        # Migration reset the touch window: no ping-pong on stale heat.
        assert store.stats().touch_imbalance == 0.0

    def test_size_trigger_still_fires(self):
        """The historical size-skew path: maybe_rebalance (the
        WAL-replay-deterministic trigger) acts on live counts alone."""
        raw = np.arange(1024, dtype=np.uint64) * 7
        store = build_store(raw, num_shards=4, auto_rebalance=True,
                            max_imbalance=1.5,
                            rebalance_mode="incremental",
                            migrate_max_keys=64)
        # Pile inserts onto shard 3's keyspace: size imbalance, no reads.
        hi = np.asarray(store.splitters.lo).max()
        extra = np.arange(2048, dtype=np.uint64) * 3 + hi
        store.apply(ins_keys=mk(extra),
                    ins_rows=jnp.arange(len(extra), dtype=jnp.int32))
        assert store.stats().imbalance > 1.5
        # (apply itself may already have migrated via maybe_compact —
        # the size trigger is live on the write path too.)
        assert store.maybe_rebalance() == "migrate"
        assert store.migrations >= 1

    def test_replay_determinism_ignores_touch(self):
        """maybe_rebalance must be a function of the replayed multiset:
        read heat (absent from the WAL) may NOT trigger it."""
        raw = np.arange(1024, dtype=np.uint64) * 7
        store = build_store(raw, num_shards=4, auto_rebalance=True,
                            max_imbalance=1.5)
        self._hot_traffic(store, shard=1)
        assert store.stats().touch_imbalance > 2.0
        assert store.maybe_rebalance() is None
        assert store.migrations == 0 and store.rebalances == 0

    def test_migration_preserves_reads(self):
        """Reads stay bit-identical across migrate_step ticks (multiset
        unchanged), while the splitters genuinely moved."""
        rng = np.random.default_rng(3)
        raw = np.unique(rng.integers(0, 1 << 40, 1500).astype(np.uint64))
        store = build_store(raw, num_shards=4)
        before = np.asarray(store.splitters.lo).copy()
        q = mk(np.concatenate([raw[::3], raw[:5] + 1]))   # hits + misses
        want = store.lookup(q)
        self._hot_traffic(store, shard=0)
        moved = store.migrate_step(128)
        assert moved >= 1
        got = store.lookup(q)
        for f in ("found", "row_id", "position"):
            np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                          np.asarray(getattr(want, f)))
        assert not np.array_equal(np.asarray(store.splitters.lo), before)


# ---------------------------------------------------------------------------
# runtime.ft reports onto the bus.
# ---------------------------------------------------------------------------

class TestFtOnBus:
    def test_heartbeat_events(self, tmp_path):
        bus = TelemetryBus()
        hb = Heartbeat(str(tmp_path / "hb.json"), bus=bus)
        hb.write_now(step=3, payload={"wal_seq": 17})
        evs = bus.events("heartbeat")
        assert evs and evs[-1]["step"] == 3 and evs[-1]["wal_seq"] == 17

    def test_straggler_events(self):
        bus = TelemetryBus()
        mon = StragglerMonitor(threshold=2.0, bus=bus)
        mon.record(0, 1.0)
        assert mon.record(1, 10.0)            # 10x the EMA: flagged
        evs = bus.events("straggler")
        assert evs and evs[0]["step"] == 1
        assert evs[0]["duration"] == pytest.approx(10.0)
