"""Session lifecycle: close()/context-manager contract, typed errors,
and the durable-open front door (repro.db.open recover=/durability=).
"""
import warnings

import numpy as np
import pytest

import repro.db as db
from repro.core import deprecation
from repro.store import CompactionPolicy, LiveConfig, LiveIndex
from repro.serving import paged

NEVER = CompactionPolicy().never()


def mk(raw):
    return db.as_key_array(np.asarray(raw, dtype=np.uint64))


def small_session(**spec_kw):
    raw = np.arange(1, 513, dtype=np.uint64) * 5
    spec = db.IndexSpec(tier="live", node_cap=16, policy=NEVER, **spec_kw)
    return db.open(spec, mk(raw)), raw


# ---------------------------------------------------------------------------
# close() / context manager.
# ---------------------------------------------------------------------------

def test_context_manager_closes_and_rejects_after(tmp_path):
    with db.open(db.IndexSpec(tier="live", policy=NEVER),
                 mk(np.arange(1, 65))) as sess:
        assert not sess.closed
        assert bool(sess.lookup(mk([5])).result().found.all())
    assert sess.closed
    for op in (lambda: sess.lookup(mk([5])),
               lambda: sess.insert(mk([7]), np.array([1], np.int32)),
               lambda: sess.delete(mk([5])),
               lambda: sess.flush(),
               lambda: sess.snapshot()):
        with pytest.raises(db.SessionClosedError):
            op()
    sess.close()                           # idempotent


def test_close_flushes_pending_tickets():
    sess, raw = small_session()
    t = sess.lookup(mk(raw[:8]))
    assert not t.ready and sess.pending
    sess.close()
    assert t.ready and bool(t.result().found.all())
    assert sess.pending == 0


def test_ticket_on_session_closed_mid_flush_raises_typed():
    """close() propagates a flush failure but still closes the session;
    the ticket stranded by that flush resolves to the typed error."""
    sess, raw = small_session()
    t = sess.lookup(mk(raw[:4]))
    # Mixed 32/64-bit keys in one flush: the close()-driven flush raises.
    sess.lookup(db.KeyArray.from_u32(np.array([1], np.uint32)))
    with pytest.raises(ValueError):
        sess.close()
    assert sess.closed
    with pytest.raises(db.SessionClosedError):
        t.result()


def test_dropped_ticket_error_is_typed():
    sess, raw = small_session()
    t = sess.lookup(mk(raw[:4]))
    sess.lookup(db.KeyArray.from_u32(np.array([1], np.uint32)))
    with pytest.raises(ValueError):
        sess.flush()
    with pytest.raises(db.DroppedTicketError):
        t.result()
    # Back-compat: callers matching the historical RuntimeError still do.
    assert issubclass(db.DroppedTicketError, RuntimeError)
    sess.close()


def test_paged_cache_close_closes_table_session():
    cache = paged.create(num_layers=1, num_pages=8, page_size=4,
                         kv_heads=1, head_dim=4)
    assert not cache.table.closed
    cache.close()
    assert cache.table.closed
    cache.close()                          # idempotent


# ---------------------------------------------------------------------------
# wrap_store deprecation (bare-store adoption of durable-capable tiers).
# ---------------------------------------------------------------------------

def test_wrap_store_updatable_adoption_warns_once():
    raw = np.arange(0, 256, 2, dtype=np.uint64)
    live = LiveIndex.build(mk(raw),
                           np.arange(len(raw), dtype=np.int32),
                           LiveConfig(node_cap=16, policy=NEVER))
    deprecation.reset("db.wrap_store")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        db.wrap_store(live)
        db.wrap_store(live)               # second adoption: silent
    deps = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1 and "wal_dir" in str(deps[0].message)

    # Static snapshots have nothing to log: no warning.
    from repro.core import cgrx
    idx = cgrx.build(mk(raw), np.arange(len(raw), dtype=np.int32), 16)
    deprecation.reset("db.wrap_store")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        db.wrap_store(idx)
    assert not [w for w in rec
                if issubclass(w.category, DeprecationWarning)]


# ---------------------------------------------------------------------------
# Durable open contract.
# ---------------------------------------------------------------------------

def test_spec_validation(tmp_path):
    with pytest.raises(db.InvalidSpecError):
        db.IndexSpec(durability="wal")                  # no wal_dir
    with pytest.raises(db.InvalidSpecError):
        db.IndexSpec(durability="paper")                # unknown mode
    with pytest.raises(db.InvalidSpecError):
        db.IndexSpec(tier="static", durability="wal",
                     wal_dir=str(tmp_path))             # nothing to log


def test_recover_needs_durable_spec():
    with pytest.raises(db.InvalidSpecError):
        db.open(db.IndexSpec(tier="live"), recover=True)


def test_open_refuses_silent_reinit_and_keyed_recover(tmp_path):
    spec = db.IndexSpec(tier="live", durability="wal",
                        wal_dir=str(tmp_path / "d"), policy=NEVER)
    raw = np.arange(1, 129, dtype=np.uint64)
    with db.open(spec, mk(raw)):
        pass
    with pytest.raises(db.RecoveryError):
        db.open(spec, mk(raw))             # would orphan the existing log
    with pytest.raises(db.InvalidSpecError):
        db.open(spec, mk(raw), recover=True)   # log is the source of truth
    with db.open(spec, recover=True) as sess:
        assert bool(sess.lookup(mk(raw[:4])).result().found.all())


def test_recover_empty_dir_needs_keys(tmp_path):
    spec = db.IndexSpec(tier="live", durability="wal",
                        wal_dir=str(tmp_path / "empty"), policy=NEVER)
    with pytest.raises(db.RecoveryError):
        db.open(spec, recover=True)
    # Open-or-create: recover=True with keys bootstraps when empty.
    with db.open(spec, mk(np.arange(1, 65)), ) as sess:
        assert sess.durable


def test_snapshot_requires_durability_and_returns_seq(tmp_path):
    sess, _ = small_session()
    with pytest.raises(db.InvalidSpecError):
        sess.snapshot()
    sess.close()

    spec = db.IndexSpec(tier="live", durability="wal",
                        wal_dir=str(tmp_path / "d"), policy=NEVER)
    with db.open(spec, mk(np.arange(1, 129))) as sess:
        sess.insert(mk([5000]), np.array([900], np.int32))
        seq = sess.snapshot()              # flushes pending writes first
        assert seq == 1
    with db.open(spec, recover=True) as sess:
        assert bool(sess.lookup(mk([5000])).result().found.all())
