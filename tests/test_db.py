"""The unified ``repro.db`` session API: cross-tier parity + semantics.

The acceptance property of the redesign: the SAME mixed op sequence —
point lookups (hits and misses), multi-shard ranges, rank scans,
inserts, deletes — submitted through the one ``Session`` surface on the
``static`` (reads-only prefix), ``live``, and ``sharded`` tiers yields
bit-identical results and rank outputs to the pre-redesign oracles
(``core.cgrx`` single calls for static, a directly-driven
``store.LiveIndex`` for the updatable tiers).  Plus: ticket/auto-flush
semantics, the one-dispatch-per-op-class flush invariant, the all-empty
flush no-op, typed write rejection on the static tier, the unified
stats/nbytes surface, spec validation, and the deprecation shims
(``LiveFrontend``, ``cgrx.lookup``-style conveniences) warning once with
unchanged behavior.
"""
import warnings

import numpy as np
import jax.numpy as jnp
import pytest

import repro.db as db
from repro.core import cgrx, deprecation
from repro.core.keys import KeyArray
from repro.store import CompactionPolicy, LiveConfig, LiveFrontend, LiveIndex

NEVER = CompactionPolicy().never()


def mk(raw):
    return KeyArray.from_u64(np.asarray(raw, dtype=np.uint64))


def assert_points_equal(got, want, ctx):
    for f in ("found", "row_id", "position"):
        g, w = np.asarray(getattr(got, f)), np.asarray(getattr(want, f))
        assert (g == w).all(), f"{ctx}: field {f} diverges"


def assert_ranges_equal(got, want, ctx):
    for f in want._fields:
        g, w = np.asarray(getattr(got, f)), np.asarray(getattr(want, f))
        assert (g == w).all(), f"{ctx}: field {f} diverges"


def spec_for(tier):
    return db.IndexSpec(tier=tier, node_cap=16, bucket_size=16,
                        policy=NEVER, max_hits=32,
                        shards=4, max_imbalance=None)


@pytest.fixture(scope="module")
def workload():
    rng = np.random.default_rng(7)
    raw = np.unique(rng.integers(0, 1 << 44, 5000, dtype=np.uint64))[:3000]
    rows = np.arange(len(raw), dtype=np.int32)
    sraw = np.sort(raw)
    hits = raw[rng.integers(0, len(raw), 120)]
    misses = np.setdiff1d(
        np.unique(rng.integers(0, 1 << 44, 80, dtype=np.uint64)), raw)[:60]
    pts = np.concatenate([hits, misses])
    # Ranges spanning most of the key space -> cross 3+ shard boundaries
    # on the 4-shard tier.
    starts = rng.integers(0, len(sraw) - 2500, 24)
    lo, hi = sraw[starts], sraw[starts + 2400]
    ins = np.setdiff1d(np.unique(
        rng.integers(0, 1 << 44, 1500, dtype=np.uint64)), raw)[:500]
    dels = raw[rng.choice(len(raw), 300, replace=False)]
    return dict(raw=raw, rows=rows, pts=pts, lo=lo, hi=hi,
                ins=ins, dels=dels)


# ---------------------------------------------------------------------------
# Cross-tier parity vs the pre-redesign oracles.
# ---------------------------------------------------------------------------

def run_read_prefix(sess, w):
    """The reads-only prefix of the shared op sequence, as one flush."""
    t_p = sess.lookup(mk(w["pts"]))
    t_r = sess.range(mk(w["lo"]), mk(w["hi"]))
    t_l = sess.scan_ranks(mk(w["pts"]), side="left")
    t_h = sess.scan_ranks(mk(w["pts"]), side="right")
    sess.flush()
    return (t_p.result(), t_r.result(),
            np.asarray(t_l.result()), np.asarray(t_h.result()))


@pytest.mark.parametrize("tier", ["static", "live", "sharded"])
def test_read_prefix_matches_cgrx_oracle(tier, workload):
    """Every tier, same Session calls, bit-identical to the pre-redesign
    ``core.cgrx`` single-call oracle (and its rank outputs)."""
    w = workload
    sess = db.open(spec_for(tier), mk(w["raw"]), w["rows"])
    points, ranges, rk_l, rk_r = run_read_prefix(sess, w)

    oracle = cgrx.build(mk(np.sort(w["raw"])),
                        jnp.asarray(w["rows"][np.argsort(w["raw"])]),
                        16, presorted=True)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        o_pts = cgrx.lookup(oracle, mk(w["pts"]))
        o_rng = cgrx.range_lookup(oracle, mk(w["lo"]), mk(w["hi"]),
                                  max_hits=32)
    o_l = np.asarray(cgrx.rank(oracle, mk(w["pts"]), side="left"))
    o_r = np.asarray(cgrx.rank(oracle, mk(w["pts"]), side="right"))

    assert_points_equal(points, o_pts, f"{tier}/points")
    assert_ranges_equal(ranges, o_rng, f"{tier}/ranges")
    assert (rk_l == o_l).all() and (rk_r == o_r).all(), f"{tier}/ranks"
    # Host oracle for the ranks too (independent of cgrx):
    sraw = np.sort(w["raw"])
    assert (rk_l == np.searchsorted(sraw, w["pts"], "left")).all()
    assert (rk_r == np.searchsorted(sraw, w["pts"], "right")).all()


@pytest.mark.parametrize("tier", ["live", "sharded"])
def test_mixed_sequence_matches_live_oracle(tier, workload):
    """Full sequence (reads, then a mixed write+read flush, then reads)
    vs a directly-driven pre-redesign ``LiveIndex`` oracle."""
    w = workload
    sess = db.open(spec_for(tier), mk(w["raw"]), w["rows"])
    oracle = LiveIndex.build(mk(w["raw"]), jnp.asarray(w["rows"]),
                             LiveConfig(node_cap=16, policy=NEVER))

    # reads-only prefix
    points, ranges, rk_l, _ = run_read_prefix(sess, w)
    assert_points_equal(points, oracle.lookup(mk(w["pts"])),
                        f"{tier}/pre/points")
    assert_ranges_equal(ranges, oracle.range_lookup(mk(w["lo"]),
                                                    mk(w["hi"]), 32),
                        f"{tier}/pre/ranges")

    # one mixed flush: writes land before the same flush's reads
    ins_rows = np.arange(9000, 9000 + len(w["ins"]), dtype=np.int32)
    t_i = sess.insert(mk(w["ins"]), ins_rows)
    t_d = sess.delete(mk(w["dels"]))
    t_new = sess.lookup(mk(w["ins"]))
    t_gone = sess.lookup(mk(w["dels"]))
    t_rng = sess.range(mk(w["lo"]), mk(w["hi"]))
    rep = sess.flush()
    assert (rep.n_insert, rep.n_delete) == (len(w["ins"]), len(w["dels"]))
    assert t_i.result() == len(w["ins"]) and t_d.result() == len(w["dels"])

    oracle.apply(mk(w["ins"]), jnp.asarray(ins_rows), mk(w["dels"]))
    assert_points_equal(t_new.result(), oracle.lookup(mk(w["ins"])),
                        f"{tier}/post/ins")
    assert_points_equal(t_gone.result(), oracle.lookup(mk(w["dels"])),
                        f"{tier}/post/dels")
    assert not bool(np.asarray(t_gone.result().found).any())
    assert_ranges_equal(t_rng.result(),
                        oracle.range_lookup(mk(w["lo"]), mk(w["hi"]), 32),
                        f"{tier}/post/ranges")

    # rank outputs after the writes, vs oracle engine + host truth
    live_np = np.sort(np.setdiff1d(
        np.concatenate([w["raw"], w["ins"]]), w["dels"]))
    rk = np.asarray(sess.scan_ranks(mk(w["pts"])).result())
    assert (rk == np.searchsorted(live_np, w["pts"], "left")).all()
    o_rk = np.asarray(oracle.engine.rank_batch(
        mk(w["pts"]), jnp.zeros(len(w["pts"]), jnp.int32)))
    assert (rk == o_rk).all()

    # unified stats reflect the traffic
    st = sess.stats()
    assert st.tier == tier
    assert st.live_keys == len(live_np)
    assert st.inserts == len(w["ins"]) and st.deletes == len(w["dels"])
    assert st.num_shards == (4 if tier == "sharded" else 1)


def test_multi_shard_ranges_cross_boundaries(workload):
    """The parity ranges really do span 3+ shards (guards the fixture)."""
    w = workload
    sess = db.open(spec_for("sharded"), mk(w["raw"]), w["rows"])
    store = sess.tier.store
    spans = 1 + store.route(mk(w["hi"])) - store.route(mk(w["lo"]))
    assert spans.max() >= 3


# ---------------------------------------------------------------------------
# Session semantics: tickets, flush batching, empty flush.
# ---------------------------------------------------------------------------

def small_session(tier="live", **kw):
    raw = np.arange(0, 4096, 2, dtype=np.uint64)
    spec = spec_for(tier)
    sess = db.open(spec, mk(raw), np.arange(len(raw), dtype=np.int32))
    return sess, raw


def test_ticket_auto_flush_and_idempotent_result():
    sess, raw = small_session()
    t = sess.lookup(mk(raw[:10]))
    assert not t.ready and sess.pending == 1
    res = t.result()                      # auto-flush
    assert sess.pending == 0 and t.ready
    assert bool(np.asarray(res.found).all())
    assert t.result() is res              # idempotent, not pop-once

    t2 = sess.insert(mk([1]), np.asarray([777], np.int32))
    assert t2.result() == 1               # auto-flush on write tickets too
    assert np.asarray(sess.lookup(mk([1])).result().row_id)[0] == 777


def test_one_dispatch_per_op_class_per_flush():
    sess, raw = small_session()
    # several submissions of every class -> exactly one dispatch each
    sess.insert(mk([1, 3]), np.asarray([900, 901], np.int32))
    sess.insert(mk([5]), np.asarray([902], np.int32))
    sess.delete(mk(raw[:4]))
    sess.lookup(mk(raw[4:8]))
    sess.lookup(mk(raw[8:12]))
    sess.range(mk(raw[4:6]), mk(raw[6:8]))
    sess.scan_ranks(mk(raw[:6]))
    sess.scan_ranks(mk(raw[:6]), side="right")
    rep = sess.flush()
    assert sess.dispatches == {"apply": 1, "query": 1, "rank": 1}
    assert (rep.n_insert, rep.n_delete) == (3, 4)
    assert (rep.n_point, rep.n_range, rep.n_rank) == (8, 2, 12)


def test_empty_flush_is_cheap_noop():
    """All-empty flush: no dispatch, no executable, tickets settle
    (the satellite regression: zero points AND zero ranges must not
    build a degenerate padded batch)."""
    sess, raw = small_session()
    empty = mk(np.zeros(0, np.uint64))
    t_p = sess.lookup(empty)
    t_r = sess.range(empty, empty)
    t_i = sess.insert(empty, np.zeros(0, np.int32))
    t_d = sess.delete(empty)
    t_s = sess.scan_ranks(empty)
    assert sess.pending == 0              # all resolved at submission
    rep = sess.flush()
    assert (rep.n_point, rep.n_range, rep.n_insert, rep.n_delete,
            rep.n_rank) == (0,) * 5
    assert sess.dispatches == {"apply": 0, "query": 0, "rank": 0}
    assert t_p.result().found.shape == (0,)
    assert t_r.result().row_ids.shape == (0, 32)
    assert t_i.result() == 0 and t_d.result() == 0
    assert t_s.result().shape == (0,)


def test_auto_compact_off_means_flush_never_pauses():
    """IndexSpec(auto_compact=False): the policy would fire, but flush
    must not take the epoch-swap pause — maintenance belongs to the
    caller (who can run tier.maybe_compact() explicitly)."""
    raw = np.arange(0, 4096, 8, dtype=np.uint64)
    pol = CompactionPolicy(max_chain=2, min_fill=None,
                           max_tombstone_ratio=None)
    spec = db.IndexSpec(tier="live", node_cap=8, policy=pol,
                        auto_compact=False)
    sess = db.open(spec, mk(raw), np.arange(len(raw), dtype=np.int32))
    ins = np.arange(1, 400, 2, dtype=np.uint64)   # dense burst -> chains
    sess.insert(mk(ins), np.arange(len(ins), dtype=np.int32))
    rep = sess.flush()
    assert rep.compacted is None and rep.compact_seconds == 0.0
    assert sess.epoch == 0 and sess.stats().compactions == 0
    # the caller-driven path still works
    assert sess.tier.maybe_compact() == "chain"
    assert sess.epoch == 1


def test_discarded_tickets_do_not_accumulate_results():
    """Fire-and-forget submissions: once a flush drains its queues the
    session holds no reference to the tickets (or their results) — a
    serving loop that never retains read tickets cannot leak."""
    import weakref

    sess, raw = small_session()
    t = sess.lookup(mk(raw[:8]))
    ref = weakref.ref(t)
    sess.flush()
    assert t.ready
    # ...and the reverse direction: a resolved ticket drops its session
    # reference, so retained result tickets cannot pin index buffers.
    assert t._session is None
    del t
    assert ref() is None, "session retained a resolved ticket"


def test_flush_report_counts_compaction():
    raw = np.arange(0, 4096, 8, dtype=np.uint64)
    spec = db.IndexSpec(tier="live", node_cap=8,
                        policy=CompactionPolicy(max_chain=2, min_fill=None,
                                                max_tombstone_ratio=None))
    sess = db.open(spec, mk(raw), np.arange(len(raw), dtype=np.int32))
    ins = np.arange(1, 400, 2, dtype=np.uint64)   # dense burst -> chains
    sess.insert(mk(ins), np.arange(len(ins), dtype=np.int32))
    rep = sess.flush()
    assert rep.compacted is not None and rep.compact_seconds > 0.0
    assert sess.epoch >= 1


# ---------------------------------------------------------------------------
# Static tier: typed write rejection; spec validation.
# ---------------------------------------------------------------------------

def test_static_tier_rejects_writes_typed():
    sess, raw = small_session("static")
    with pytest.raises(db.ReadOnlyTierError):
        sess.insert(mk([1]), np.asarray([0], np.int32))
    with pytest.raises(db.ReadOnlyTierError):
        sess.delete(mk(raw[:2]))
    # reads unaffected after the rejection
    assert bool(np.asarray(sess.lookup(mk(raw[:8])).result().found).all())


def test_spec_validation():
    with pytest.raises(db.InvalidSpecError):
        db.IndexSpec(tier="nope")
    with pytest.raises(db.InvalidSpecError):
        db.IndexSpec(backend="bvh")
    with pytest.raises(db.InvalidSpecError):
        db.IndexSpec(bucket_size=0)
    with pytest.raises(db.InvalidSpecError):
        db.IndexSpec(tier="sharded", shards=0)
    # InvalidSpecError is a ValueError: old-style callers still catch it.
    assert issubclass(db.InvalidSpecError, ValueError)


def test_stats_and_nbytes_uniform_across_tiers():
    raw = np.unique(np.random.default_rng(0).integers(
        0, 1 << 40, 3000, dtype=np.uint64))[:2000]
    rows = np.arange(len(raw), dtype=np.int32)
    for tier in ("static", "live", "sharded"):
        sess = db.open(spec_for(tier), mk(raw), rows)
        st = sess.stats()
        assert isinstance(st, db.Stats) and st.tier == tier
        assert st.live_keys == len(raw)
        assert st.total_bytes > 0 and st.max_chain >= 1
        nb = sess.nbytes()
        assert nb["total_bytes"] == st.total_bytes


# ---------------------------------------------------------------------------
# Deprecation shims: warn once, behavior unchanged.
# ---------------------------------------------------------------------------

def test_cgrx_convenience_warns_once_behavior_unchanged():
    raw = np.arange(0, 2048, 2, dtype=np.uint64)
    idx = cgrx.build(mk(raw), jnp.arange(len(raw), dtype=jnp.int32), 16)
    q = mk(raw[:32])
    deprecation.reset("cgrx.lookup")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        r1 = cgrx.lookup(idx, q)
        r2 = cgrx.lookup(idx, q)          # second call: silent
    deps = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1 and "repro.db" in str(deps[0].message)
    # unchanged behavior: identical to the session over the same index
    sess = db.Session(db.StaticTier(idx))
    assert_points_equal(r1, sess.lookup(q).result(), "dep/cgrx.lookup")
    assert_points_equal(r2, r1, "dep/second-call")


def test_frontend_shim_warns_once_behavior_unchanged():
    raw = np.arange(0, 2048, 2, dtype=np.uint64)
    live = LiveIndex.build(mk(raw), jnp.arange(len(raw), dtype=jnp.int32),
                           LiveConfig(node_cap=16, policy=NEVER))
    deprecation.reset("store.LiveFrontend")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        fe = LiveFrontend(live, max_hits=8)
        LiveFrontend(live, max_hits=8)    # second construction: silent
    deps = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1 and "repro.db" in str(deps[0].message)

    # unchanged behavior: the historical ticket/tick contract
    t_i = fe.submit_insert(mk([1, 3]), np.asarray([900, 901], np.int32))
    t_p = fe.submit_point(mk([1, 3, 0]))
    with pytest.raises(KeyError):
        fe.result(t_p)                    # unserved -> KeyError, no flush
    rep = fe.tick()
    assert (rep.n_insert, rep.n_point) == (2, 3)
    assert fe.result(t_i) == 2
    res = fe.result(t_p)
    assert np.asarray(res.found).tolist() == [True, True, True]
    with pytest.raises(KeyError):
        fe.result(t_p)                    # pop-once


def test_frontend_shim_runs_policy_even_with_auto_compact_off():
    """Historical tick contract: tick() evaluated the policy on every
    write tick regardless of the store's auto_compact knob (which only
    governed direct apply() calls) — the shim must preserve that."""
    raw = np.arange(0, 4096, 8, dtype=np.uint64)
    live = LiveIndex.build(
        mk(raw), jnp.arange(len(raw), dtype=jnp.int32),
        LiveConfig(node_cap=8, auto_compact=False,
                   policy=CompactionPolicy(max_chain=2, min_fill=None,
                                           max_tombstone_ratio=None)))
    fe = LiveFrontend(live)
    ins = np.arange(1, 400, 2, dtype=np.uint64)   # dense burst -> chains
    fe.submit_insert(mk(ins), np.arange(len(ins), dtype=np.int32))
    rep = fe.tick()
    assert rep.compacted == "chain" and live.epoch == 1


def test_failed_flush_drops_tickets_loudly():
    """A flush that raises after draining its queues must not leave
    tickets that later return the private sentinel as a result."""
    sess, raw = small_session()
    t = sess.lookup(mk(raw[:4]))
    # mixed 32/64-bit keys in one flush -> QueryBatch raises mid-flush
    sess.lookup(db.KeyArray.from_u32(np.array([1], np.uint32)))
    with pytest.raises(ValueError):
        sess.flush()
    with pytest.raises(RuntimeError, match="failed flush"):
        t.result()


def test_wrap_store_adopts_existing_stores(workload):
    """repro.db.wrap_store: an already-built store serves via a Session
    with bit-identical results (the shim path under LiveFrontend)."""
    w = workload
    live = LiveIndex.build(mk(w["raw"]), jnp.asarray(w["rows"]),
                           LiveConfig(node_cap=16, policy=NEVER))
    sess = db.Session(db.wrap_store(live), max_hits=32)
    assert_points_equal(sess.lookup(mk(w["pts"])).result(),
                        live.lookup(mk(w["pts"])), "wrap/points")
    with pytest.raises(TypeError):
        db.wrap_store(object())

    class DuckStore:                      # old frontend's duck contract:
        apply = maybe_compact = execute = sync = None  # no .config

    tier = db.wrap_store(DuckStore())
    assert isinstance(tier, db.LiveTier) and tier.auto_compact is True
