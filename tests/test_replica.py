"""Epoch-lagged read replicas: refresh, staleness, failover.

A replica is recovery-as-a-service: ``ReadReplica.refresh`` runs the
same snapshot + WAL-tail rebuild as crash recovery against the primary's
``wal_dir`` and swaps the tier atomically.  So the acceptance property
mirrors test_wal_recovery: a refreshed replica answers bit-identically
to the primary at the WAL position it caught up to, while the primary
keeps writing ahead of it (the epoch-lagged contract); staleness is
measured against the primary's heartbeat beacon, and reads fail over
to the freshest healthy member or raise ``StaleReplicaError`` with the
lag attached.
"""
import numpy as np
import pytest

import repro.db as db
from repro.store import ReadReplica, ReplicaSet

POLICY = db.CompactionPolicy(max_chain=4)


def mk(raw):
    return db.as_key_array(np.asarray(raw, dtype=np.uint64))


def durable_session(tmp_path, tier="live", **kw):
    spec = db.IndexSpec(tier=tier, durability="wal",
                        wal_dir=str(tmp_path / "primary"),
                        node_cap=16, policy=POLICY, max_hits=32, **kw)
    raw = np.arange(1, 513, dtype=np.uint64) * 9
    return db.open(spec, mk(raw)), spec, raw


def assert_matches_primary(replica_like, sess, probes):
    got = replica_like.lookup(probes)
    want = sess.lookup(probes).result()
    for f in ("found", "row_id", "position"):
        assert (np.asarray(getattr(got, f))
                == np.asarray(getattr(want, f))).all(), f


def test_replica_requires_durable_spec():
    with pytest.raises(db.InvalidSpecError):
        ReadReplica(db.IndexSpec(tier="live"))


def test_unrefreshed_replica_raises_stale(tmp_path):
    # Reads before any refresh have nothing to serve.
    spec = db.IndexSpec(tier="live", durability="wal",
                        wal_dir=str(tmp_path / "d"))
    r = ReadReplica(spec)
    with pytest.raises(db.StaleReplicaError):
        r.lookup(mk([1]))


def test_replica_serves_primary_state_and_tracks_lag(tmp_path):
    sess, spec, raw = durable_session(tmp_path)
    try:
        probes = mk(np.concatenate([raw[:32], raw[:8] + 1]))
        replica = ReadReplica(spec, "replica-0")
        replica.refresh()
        assert_matches_primary(replica, sess, probes)

        # Primary writes ahead: replica stays consistent at its OLD
        # position (epoch-lagged), the beacon shows the lag, a refresh
        # catches up.
        new = np.arange(10_000, 10_064, dtype=np.uint64)
        sess.insert(mk(new), np.arange(64, dtype=np.int32))
        sess.delete(mk(raw[:16]))
        sess.flush()
        assert not bool(
            np.asarray(replica.lookup(mk(new[:4])).found).any())
        rs = ReplicaSet(spec, n=2, straggler_threshold=1e9)
        rs.refresh_all()
        lag = rs.staleness()
        assert lag["seq_lag"] == 0 and lag["epoch_lag"] == 0
        assert_matches_primary(rs, sess, mk(np.concatenate([new, raw[:32]])))
    finally:
        sess.close()


def test_failover_and_stale_error_carry_lag(tmp_path):
    sess, spec, raw = durable_session(tmp_path)
    try:
        # A huge straggler threshold keeps refresh-duration noise (JIT
        # compiles) from flagging members; failover is forced by hand.
        rs = ReplicaSet(spec, n=2, max_seq_lag=0,
                        straggler_threshold=1e9)
        rs.refresh_all()
        assert rs.serving().name in ("replica-0", "replica-1")

        # Flag the freshest member a straggler: reads fail over.
        stuck = rs.serving().name
        rs.suspect.add(stuck)
        other = rs.serving().name
        assert other != stuck

        # Primary advances; with max_seq_lag=0 nobody qualifies.
        sess.insert(mk([99_991]), np.array([7], np.int32))
        sess.flush()
        rs.suspect.clear()
        with pytest.raises(db.StaleReplicaError) as ei:
            rs.serving()
        assert ei.value.seq_lag >= 1
        assert ei.value.epoch_lag is not None

        # One refresh (most-lagged first) restores service.
        assert rs.refresh() is not None
        assert rs.refresh() is not None
        assert bool(np.asarray(
            rs.lookup(mk([99_991])).found).all())
    finally:
        sess.close()


def test_session_close_stops_attached_replica_threads(tmp_path):
    sess, spec, raw = durable_session(tmp_path)
    rs = ReplicaSet(spec, n=1)
    rs.refresh_all()
    rs.start(interval=30.0)
    sess.attach_replicas(rs)
    assert rs._thread is not None
    sess.close()
    assert rs._thread is None


def test_sharded_replica_round_trip(tmp_path):
    sess, spec, raw = durable_session(tmp_path, tier="sharded", shards=4)
    try:
        new = np.arange(70_000, 70_128, dtype=np.uint64)
        sess.insert(mk(new), np.arange(128, dtype=np.int32))
        sess.delete(mk(raw[:32]))
        sess.flush()
        replica = ReadReplica(spec, "r0")
        replica.refresh()
        probes = mk(np.concatenate([new, raw[:64]]))
        assert_matches_primary(replica, sess, probes)
        # Ranges and rank scans serve from the replica's epoch too.
        lo, hi = mk(raw[100:110]), mk(raw[200:210])
        g = replica.range_lookup(lo, hi, max_hits=32)
        w = sess.range(lo, hi).result()
        for f in ("start", "count", "row_ids"):
            assert (np.asarray(getattr(g, f))
                    == np.asarray(getattr(w, f))).all(), f
    finally:
        sess.close()
