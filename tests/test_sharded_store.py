"""ShardedLiveStore vs a single-shard oracle.

The sharded tier's acceptance property: routing + cross-shard range
decomposition + the rank-offset prefix merge must be invisible — after
ANY sequence of routed insert/delete batches, lookups and range lookups
over the S-shard store are bit-identical to a fresh single ``cgrx.build``
over the same live set (found/row_id/position for points; start/count/
row_ids for ranges — bucket_id is shard-local by documentation).  Plus:
ranges spanning 3+ shards and empty shards, per-shard compaction
independence under concurrent reads, the skew-triggered splitter
rebalance on a Zipf insert stream, and the shard-aware frontend tick.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import cgrx
from repro.core.distributed import compute_splitters, route_keys, route_ranges
from repro.core.keys import KeyArray
from repro.query import QueryBatch
from repro.store import (CompactionPolicy, LiveConfig, LiveFrontend,
                         ShardedConfig, ShardedLiveStore, ShardedStats)

NEVER = CompactionPolicy().never()


def mk(raw):
    return KeyArray.from_u64(np.asarray(raw, dtype=np.uint64))


def build_store(raw, num_shards=4, rows=None, **cfg_kwargs):
    live = cfg_kwargs.pop("live", None) or LiveConfig(node_cap=16,
                                                     policy=NEVER)
    cfg_kwargs.setdefault("auto_rebalance", False)
    cfg = ShardedConfig(num_shards=num_shards, live=live, **cfg_kwargs)
    if rows is None:
        rows = jnp.arange(len(raw), dtype=jnp.int32)
    return ShardedLiveStore.build(mk(raw), rows, cfg)


def build_oracle(live_dict, bucket_size=16):
    ks = np.array(sorted(live_dict), dtype=np.uint64)
    rows = np.array([live_dict[int(k)] for k in ks], dtype=np.int32)
    return cgrx.build(mk(ks), jnp.asarray(rows), bucket_size,
                      presorted=True), ks


def assert_points_equal(got, want, ctx):
    # bucket_id is shard-local by design; everything else is global.
    for f in ("found", "row_id", "position"):
        g, w = np.asarray(getattr(got, f)), np.asarray(getattr(want, f))
        assert (g == w).all(), f"{ctx}: field {f} diverges"


def assert_ranges_equal(got, want, ctx):
    for f in want._fields:
        g, w = np.asarray(getattr(got, f)), np.asarray(getattr(want, f))
        assert (g == w).all(), f"{ctx}: field {f} diverges"


def check_against_oracle(store, live_dict, rng, ctx, n_q=150,
                         max_hits=32, wide_frac=0.6):
    """Points (hits+misses) and cross-shard ranges vs a fresh build."""
    oracle, ks = build_oracle(live_dict)
    space = 1 << 44
    hits = ks[rng.integers(0, len(ks), n_q)]
    misses = np.setdiff1d(
        np.unique(rng.integers(0, space, n_q // 2, dtype=np.uint64)), ks)
    q = mk(np.concatenate([hits, misses]))
    assert_points_equal(store.lookup(q), cgrx.lookup(oracle, q),
                        f"{ctx}/points")

    # Wide ranges: spans covering >= 3 of the shards, plus narrow ones.
    span = max(int(len(ks) * wide_frac), 2)
    starts = rng.integers(0, len(ks) - span, 25)
    lo, hi = mk(ks[starts]), mk(ks[starts + span - 1])
    assert_ranges_equal(store.range_lookup(lo, hi, max_hits),
                        cgrx.range_lookup(oracle, lo, hi, max_hits),
                        f"{ctx}/wide-ranges")
    starts = rng.integers(0, len(ks) - 10, 25)
    lo, hi = mk(ks[starts]), mk(ks[starts + 9])
    assert_ranges_equal(store.range_lookup(lo, hi, max_hits),
                        cgrx.range_lookup(oracle, lo, hi, max_hits),
                        f"{ctx}/narrow-ranges")


# ---------------------------------------------------------------------------
# Router / splitter math (shared with core.distributed's static tier).
# ---------------------------------------------------------------------------

def test_router_ownership_is_contiguous_and_total():
    raw = np.sort(np.unique(
        np.random.default_rng(0).integers(0, 1 << 40, 4000,
                                          dtype=np.uint64)))
    splitters = compute_splitters(mk(raw), 4)
    owners = np.asarray(route_keys(splitters, mk(raw)))
    assert (np.diff(owners) >= 0).all()          # contiguous ranges
    assert set(np.unique(owners)) == {0, 1, 2, 3}
    # Beyond-max keys go to the last shard; range spans are [first, last].
    beyond = np.asarray(route_keys(splitters, mk([(1 << 44) - 1])))
    assert beyond[0] == 3
    first, last = route_ranges(splitters, mk([raw[0]]), mk([raw[-1]]))
    assert int(first[0]) == 0 and int(last[0]) == 3


def test_build_routes_every_built_key_to_its_shard():
    raw = np.sort(np.unique(
        np.random.default_rng(1).integers(0, 1 << 40, 3000,
                                          dtype=np.uint64)))
    store = build_store(raw)
    owners = store.route(mk(raw))
    for s in range(store.num_shards):
        sel = mk(raw[owners == s])
        assert bool(np.asarray(store.shards[s].lookup(sel).found).all())


# ---------------------------------------------------------------------------
# Bit-identity vs the single-shard oracle.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("num_shards", [2, 4, 7])
def test_cross_shard_bit_identity_after_waves(num_shards):
    rng = np.random.default_rng(2)
    space = 1 << 44
    raw = np.unique(rng.integers(0, space, 5000, dtype=np.uint64))[:3000]
    store = build_store(raw, num_shards=num_shards)
    live_dict = {int(k): i for i, k in enumerate(raw)}
    nxt = len(raw)
    check_against_oracle(store, live_dict, rng, "init")
    for wave in range(3):
        la = np.array(sorted(live_dict), dtype=np.uint64)
        ins = np.setdiff1d(
            np.unique(rng.integers(0, space, 2500, dtype=np.uint64)),
            la)[:800]
        dels = la[rng.choice(len(la), 500, replace=False)]
        rows = np.arange(nxt, nxt + len(ins), dtype=np.int32)
        nxt += len(ins)
        store.apply(mk(ins), jnp.asarray(rows), mk(dels))
        for k, r in zip(ins, rows):
            live_dict[int(k)] = int(r)
        for k in dels:
            live_dict.pop(int(k))
        check_against_oracle(store, live_dict, rng, f"wave{wave}")
    assert store.stats().max_chain > 1   # chains actually degraded
    assert store.applies == 3


def test_range_spanning_all_shards_with_empty_shard():
    """A middle shard emptied by deletes must stay transparent: ranges
    spanning it keep exact global start/count/rows."""
    rng = np.random.default_rng(3)
    raw = np.arange(0, 40960, 10, dtype=np.uint64)   # 4096 keys
    store = build_store(raw)
    live_dict = {int(k): i for i, k in enumerate(raw)}
    # Empty shard 1 completely (its span is the second quarter).
    owners = store.route(mk(raw))
    victims = raw[owners == 1]
    assert len(victims) > 0
    store.delete(mk(victims))
    for k in victims:
        live_dict.pop(int(k))
    assert store.stats().shard_live[1] == 0
    check_against_oracle(store, live_dict, rng, "empty-shard")
    # A range that starts inside the emptied span.
    oracle, ks = build_oracle(live_dict)
    lo, hi = mk([int(victims[0])]), mk([int(raw[-1])])
    assert_ranges_equal(store.range_lookup(lo, hi, 16),
                        cgrx.range_lookup(oracle, lo, hi, 16),
                        "range-from-empty-shard")


def test_mixed_plan_one_dispatch_per_shard():
    """A mixed point/range plan through execute() == the per-call APIs,
    and only touched shards dispatch."""
    rng = np.random.default_rng(4)
    raw = np.unique(rng.integers(0, 1 << 40, 4000, dtype=np.uint64))[:3000]
    store = build_store(raw)
    pts = mk(raw[rng.integers(0, len(raw), 60)])
    sraw = np.sort(raw)
    starts = rng.integers(0, len(sraw) - 2500, 20)
    lo, hi = mk(sraw[starts]), mk(sraw[starts + 2499])
    plan = QueryBatch().add_points(pts).add_ranges(lo, hi).plan(max_hits=32)
    res = store.execute(plan)
    assert_points_equal(res.points, store.lookup(pts), "plan/points")
    assert_ranges_equal(res.ranges, store.range_lookup(lo, hi, 32),
                        "plan/ranges")
    # A plan confined to shard 0's span leaves sibling engines untouched.
    lo0 = mk(sraw[:8])
    hi0 = mk(sraw[8:16])
    engines_before = [s._engine for s in store.shards]
    store.execute(QueryBatch().add_ranges(lo0, hi0).plan(max_hits=8))
    assert store.shards[0]._engine is not None
    for s, before in zip(store.shards[1:], engines_before[1:]):
        assert s._engine is before   # untouched shard: no new engine bind


def test_inserts_beyond_last_splitter_land_in_last_shard():
    raw = np.arange(1000, 5096, dtype=np.uint64)
    store = build_store(raw)
    live_dict = {int(k): i for i, k in enumerate(raw)}
    big = np.arange(1 << 43, (1 << 43) + 300, dtype=np.uint64)
    store.insert(mk(big), jnp.arange(90000, 90300, dtype=jnp.int32))
    for i, k in enumerate(big):
        live_dict[int(k)] = 90000 + i
    assert (store.route(mk(big)) == store.num_shards - 1).all()
    check_against_oracle(store, live_dict, np.random.default_rng(5),
                         "beyond-max")


# ---------------------------------------------------------------------------
# Per-shard compaction: independence + consistency under concurrent reads.
# ---------------------------------------------------------------------------

def test_hot_shard_compacts_alone():
    raw = np.arange(0, 40960, 10, dtype=np.uint64)
    pol = CompactionPolicy(max_chain=3, min_fill=None,
                           max_tombstone_ratio=None)
    store = build_store(raw, live=LiveConfig(node_cap=8, policy=pol))
    # Dense burst confined to shard 0's key span.
    ins = np.arange(1, 2000, 2, dtype=np.uint64)
    summary = store.insert(mk(ins),
                           jnp.arange(50000, 50000 + len(ins),
                                      dtype=jnp.int32))
    st = store.stats()
    assert summary is not None and "s0:" in summary
    assert st.epochs[0] >= 1
    assert all(e == 0 for e in st.epochs[1:]), "compaction leaked to siblings"
    assert store.epoch == max(st.epochs)


def test_reads_consistent_during_one_shards_compaction():
    rng = np.random.default_rng(7)
    raw = np.unique(rng.integers(0, 1 << 40, 5000, dtype=np.uint64))[:3000]
    store = build_store(raw)
    live_dict = {int(k): i for i, k in enumerate(raw)}
    ins = np.setdiff1d(np.unique(rng.integers(0, 1 << 40, 2000,
                                              dtype=np.uint64)), raw)[:600]
    store.insert(mk(ins), jnp.arange(10_000, 10_000 + len(ins),
                                     dtype=jnp.int32))
    for i, k in enumerate(ins):
        live_dict[int(k)] = 10_000 + i

    task = store.shards[1].begin_compaction("test")
    assert store.compacting
    # Reads across ALL shards (including the one mid-swap) stay exact.
    check_against_oracle(store, live_dict, rng, "mid-shard-compaction")
    # A routed write mid-swap: shard 1's slice lands in its replay log.
    la = np.array(sorted(live_dict), dtype=np.uint64)
    ins2 = np.setdiff1d(np.unique(rng.integers(0, 1 << 40, 1200,
                                               dtype=np.uint64)), la)[:300]
    store.insert(mk(ins2), jnp.arange(20_000, 20_000 + len(ins2),
                                      dtype=jnp.int32))
    for i, k in enumerate(ins2):
        live_dict[int(k)] = 20_000 + i
    owners2 = store.route(mk(ins2))
    assert len(task.replay) == (1 if (owners2 == 1).any() else 0)
    store.shards[1].finish_compaction(task)
    assert not store.compacting
    check_against_oracle(store, live_dict, rng, "post-shard-swap")


def test_manual_compact_shard():
    raw = np.arange(0, 8192, 2, dtype=np.uint64)
    store = build_store(raw)
    store.compact_shard(2)
    assert store.stats().epochs == (0, 0, 1, 0)


# ---------------------------------------------------------------------------
# Skew monitor: splitter rebalance on a Zipf-skewed insert stream.
# ---------------------------------------------------------------------------

def test_zipf_skew_triggers_rebalance_and_stays_exact():
    rng = np.random.default_rng(8)
    raw = np.arange(0, 1 << 20, 256, dtype=np.uint64)    # 4096 keys
    store = build_store(raw, auto_rebalance=True, max_imbalance=1.5,
                        min_rebalance_keys=256)
    live_dict = {int(k): i for i, k in enumerate(raw)}
    # Zipf head: almost all inserts land in shard 0's key span.
    z = rng.zipf(1.3, 40000)
    z = np.setdiff1d(np.unique(z[z < (1 << 18)]).astype(np.uint64), raw)[:5000]
    summary = store.insert(mk(z), jnp.arange(90000, 90000 + len(z),
                                             dtype=jnp.int32))
    for i, k in enumerate(z):
        live_dict[int(k)] = 90000 + i
    st = store.stats()
    assert summary is not None and "rebalance" in summary
    assert st.rebalances >= 1
    assert st.imbalance < 1.5            # splitters recomputed to equal fill
    check_against_oracle(store, live_dict, rng, "post-rebalance")
    # Routing agrees with the NEW splitters: every live key still hits.
    ks = np.array(sorted(live_dict), dtype=np.uint64)
    res = store.lookup(mk(ks[rng.integers(0, len(ks), 400)]))
    assert bool(np.asarray(res.found).all())


def test_rebalance_skipped_below_min_keys_and_while_compacting():
    raw = np.arange(0, 1280, 10, dtype=np.uint64)        # 128 keys
    store = build_store(raw, auto_rebalance=True, max_imbalance=1.2,
                        min_rebalance_keys=100_000)
    ins = np.arange(1, 300, 2, dtype=np.uint64)
    store.insert(mk(ins), jnp.arange(5000, 5000 + len(ins),
                                     dtype=jnp.int32))
    assert store.rebalances == 0         # too small to churn
    store2 = build_store(raw, auto_rebalance=True, max_imbalance=1.2,
                         min_rebalance_keys=0)
    task = store2.shards[0].begin_compaction("test")
    assert not store2.maybe_rebalance()  # in-flight swap blocks rebalance
    store2.shards[0].abort_compaction()
    del task


# ---------------------------------------------------------------------------
# Stats rollup + shard-aware frontend tick.
# ---------------------------------------------------------------------------

def test_sharded_stats_rollup():
    raw = np.arange(0, 8192, 2, dtype=np.uint64)
    store = build_store(raw)
    store.insert(mk([1, 3, 5]), jnp.asarray([900, 901, 902], jnp.int32))
    store.delete(mk([0, 2]))
    st = store.stats()
    assert isinstance(st, ShardedStats)
    assert st.num_shards == 4 and len(st.shards) == 4
    assert st.live_keys == 4096 + 3 - 2
    assert st.live_keys == sum(st.shard_live)
    assert st.applies == 2 and st.inserts == 3 and st.deletes == 2
    assert st.compactions == 0 and st.rebalances == 0
    assert st.total_bytes == sum(s.total_bytes for s in st.shards)
    assert st.imbalance >= 1.0 and not st.compacting


def test_frontend_drives_sharded_store():
    rng = np.random.default_rng(11)
    raw = np.unique(rng.integers(0, 1 << 40, 4000, dtype=np.uint64))[:3000]
    store = build_store(raw)
    fe = LiveFrontend(store, max_hits=16)

    ins = np.setdiff1d(np.unique(rng.integers(0, 1 << 40, 500,
                                              dtype=np.uint64)), raw)[:100]
    dels = raw[rng.choice(len(raw), 80, replace=False)]
    keep = np.setdiff1d(raw, dels)
    t_ins = fe.submit_insert(mk(ins), np.arange(7000, 7100, dtype=np.int32))
    t_del = fe.submit_delete(mk(dels))
    t_new = fe.submit_point(mk(ins[:20]))     # same-tick read sees write
    t_gone = fe.submit_point(mk(dels[:20]))
    sl = np.sort(np.concatenate([keep, ins]))
    starts = rng.integers(0, len(sl) - 2000, 10)
    t_rng = fe.submit_range(mk(sl[starts]), mk(sl[starts + 1999]))

    rep = fe.tick()
    assert (rep.n_insert, rep.n_delete) == (100, 80)
    assert fe.result(t_ins) == 100 and fe.result(t_del) == 80
    assert bool(fe.result(t_new).found.all())
    assert not bool(fe.result(t_gone).found.any())
    r = fe.result(t_rng)
    assert (np.asarray(r.count) == 2000).all()
    assert rep.epoch == store.epoch
