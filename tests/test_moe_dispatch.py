"""Bucketed (sort-based) MoE dispatch == dense masked reference."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.models import moe as moe_mod


def dense_reference(p, x, num_experts, top_k):
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xt, p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    out = jnp.zeros_like(xt)
    for e in range(num_experts):
        h = jax.nn.silu(xt @ p["wi_gate"][e]) * (xt @ p["wi_up"][e])
        ye = h @ p["wo"][e]
        for k in range(top_k):
            w = jnp.where(experts[:, k] == e, gates[:, k], 0.0)
            out = out + ye * w[:, None]
    if "shared" in p:
        sh = p["shared"]
        g = jax.nn.silu(xt @ sh["wi_gate"]) * (xt @ sh["wi_up"])
        out = out + g @ sh["wo"]
    return out.reshape(B, S, d)


def test_dispatch_matches_dense():
    key = jax.random.PRNGKey(0)
    E, k, d, f = 8, 2, 32, 48
    p = moe_mod.init_moe(key, d, f, E, num_shared=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, d), jnp.float32)
    got = moe_mod.moe_block(p, x, num_experts=E, top_k=k,
                            capacity_factor=8.0,  # no drops
                            dtype=jnp.float32)
    want = dense_reference(p, x, E, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_capacity_drops_degrade_gracefully():
    key = jax.random.PRNGKey(2)
    E, k, d, f = 4, 2, 16, 16
    p = moe_mod.init_moe(key, d, f, E)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, d), jnp.float32)
    tight = moe_mod.moe_block(p, x, num_experts=E, top_k=k,
                              capacity_factor=0.5, dtype=jnp.float32)
    loose = moe_mod.moe_block(p, x, num_experts=E, top_k=k,
                              capacity_factor=8.0, dtype=jnp.float32)
    assert np.isfinite(np.asarray(tight)).all()
    # tight capacity must differ (tokens dropped) but stay bounded
    assert float(jnp.max(jnp.abs(tight))) <= float(jnp.max(jnp.abs(loose))) * 4


def test_aux_loss_balanced_router():
    key = jax.random.PRNGKey(4)
    E, d = 8, 16
    p = moe_mod.init_moe(key, d, 16, E)
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 64, d), jnp.float32)
    aux = moe_mod.aux_load_balance_loss(p, x, E, 2)
    # perfectly balanced -> 1.0; random init should be near 1
    assert 0.8 < float(aux) < 1.6
