"""HT / B+ / SA / RX baseline correctness (paper Sec. 6 competitors)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import baselines as bl
from repro.core import footprint as fp
from repro.core.keys import KeyArray


def mk(raw, is64=True):
    raw = np.asarray(raw, dtype=np.uint64)
    return KeyArray.from_u64(raw) if is64 else KeyArray.from_u32(
        raw.astype(np.uint32))


@pytest.fixture(scope="module")
def dataset():
    rng = np.random.default_rng(0)
    raw = np.unique(rng.integers(0, 1 << 45, 9000, dtype=np.uint64))[:6000]
    keys = mk(raw)
    rows = jnp.arange(len(raw), dtype=jnp.int32)
    sel = rng.integers(0, len(raw), 1200)
    missing = np.setdiff1d(
        rng.integers(0, 1 << 45, 2000, dtype=np.uint64), raw)[:600]
    return raw, keys, rows, sel, missing


@pytest.mark.parametrize("build,lookup", [
    (bl.sa_build, bl.sa_lookup),
    (bl.ht_build, bl.ht_lookup),
    (bl.bp_build, bl.bp_lookup),
    (bl.rx_build, bl.rx_lookup),
])
def test_point_lookup(dataset, build, lookup):
    raw, keys, rows, sel, missing = dataset
    idx = build(keys, rows)
    r = lookup(idx, keys[sel])
    assert bool(r.found.all())
    assert (raw[np.asarray(r.row_id)] == raw[sel]).all()
    rm = lookup(idx, mk(missing))
    assert not bool(rm.found.any())
    assert fp.footprint(idx)["total_bytes"] > 0


def test_sa_range(dataset):
    raw, keys, rows, sel, _ = dataset
    sa = bl.sa_build(keys, rows)
    sraw = np.sort(raw)
    lo, hi = sraw[100], sraw[140]
    c, rws = bl.sa_range(sa, mk([lo]), mk([hi]), 64)
    assert int(c[0]) == 41
    order = np.argsort(raw, kind="stable")
    assert set(np.asarray(rws[0]).tolist()) - {-1} == set(order[100:141].tolist())


def test_bp_range(dataset):
    raw, keys, rows, *_ = dataset
    bp = bl.bp_build(keys, rows)
    sraw = np.sort(raw)
    c, rws = bl.bp_range(bp, mk([sraw[10]]), mk([sraw[20]]), 16)
    assert int(c[0]) == 11


def test_ht_32bit():
    rng = np.random.default_rng(2)
    raw = np.unique(rng.integers(0, 1 << 30, 4000, dtype=np.uint64))[:3000]
    ht = bl.ht_build(mk(raw, False), None)
    r = bl.ht_lookup(ht, mk(raw[:500], False))
    assert bool(r.found.all())


def test_rx_footprint_model(dataset):
    raw, keys, rows, *_ = dataset
    rx = bl.rx_build(keys, rows)
    f = fp.footprint(rx)
    # 36B per key vertex buffer (paper: 78% overhead for 64-bit keys)
    assert f["vertex_buffer_bytes"] == 36 * len(raw)


def test_footprint_ordering(dataset):
    """Paper Fig. 11a: RX footprint >> cgRX; cgRX(64) approaches SA
    (the paper's own claim places near-SA footprint at bucket 64)."""
    from repro.core import cgrx
    raw, keys, rows, *_ = dataset
    rx = bl.rx_build(keys, rows)
    sa = bl.sa_build(keys, rows)
    f_sa = fp.footprint(sa)["total_bytes"]
    f_rx = fp.footprint(rx, paper_model=True)["total_bytes"]
    cg16 = fp.footprint(cgrx.build(keys, rows, 16), paper_model=True)["total_bytes"]
    cg64 = fp.footprint(cgrx.build(keys, rows, 64), paper_model=True)["total_bytes"]
    assert f_rx > cg16 > cg64 > 0
    assert cg64 < 1.15 * f_sa   # approaches space-optimal at bucket 64
    assert cg16 < 0.35 * f_rx   # far below the fine-granular predecessor
