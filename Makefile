# Tier-1 verification entry points (same commands CI runs).
PY ?= python
export JAX_PLATFORMS ?= cpu

.PHONY: check test bench-smoke quickstart

check: test bench-smoke

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_batched_lookup --tiny

quickstart:
	PYTHONPATH=src $(PY) examples/quickstart.py
