# Tier-1 verification entry points (same commands CI runs).
PY ?= python
export JAX_PLATFORMS ?= cpu

.PHONY: check test bench-smoke quickstart

check: test bench-smoke

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# Every registered benchmark suite at tiny sizes: benchmark scripts can't
# silently rot (benchmarks/run.py exits non-zero on any suite failure).
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --n 4096 --q 4096

quickstart:
	PYTHONPATH=src $(PY) examples/quickstart.py
