# Tier-1 verification entry points (same commands CI runs).
PY ?= python
export JAX_PLATFORMS ?= cpu

.PHONY: check test lint bench-smoke bench-json bench-compare quickstart \
	examples scenarios

check: lint test bench-smoke examples

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# Fast static gate (separate CI job; config in pyproject.toml).
lint:
	ruff check .

# Every registered benchmark suite at tiny sizes: benchmark scripts can't
# silently rot (benchmarks/run.py exits non-zero on any suite failure).
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --n 4096 --q 4096

# Same smoke run, but also write the machine-readable results the perf
# CI gate consumes (BENCH_BASELINE.json is a committed run of this).
bench-json:
	PYTHONPATH=src $(PY) -m benchmarks.run --n 4096 --q 4096 \
		--json bench_results.json

bench-compare: bench-json
	PYTHONPATH=src $(PY) -m benchmarks.compare BENCH_BASELINE.json \
		bench_results.json

# Hostile-traffic scenario harness (benchmarks/scenarios.py): every
# scenario end-to-end, plus one --scenario run whose Session.telemetry()
# export is stamped into the JSON (the CI artifact).
scenarios:
	PYTHONPATH=src $(PY) -m benchmarks.run --suites scenarios \
		--n 8192 --q 4096
	PYTHONPATH=src $(PY) -m benchmarks.run --scenario flash_crowd \
		--n 8192 --q 4096 --json scenario_telemetry.json

quickstart:
	PYTHONPATH=src $(PY) examples/quickstart.py

# Examples are executable docs of the public repro.db API: smoke-run the
# session-based ones in CI so API drift in examples fails the build.
examples:
	PYTHONPATH=src $(PY) examples/quickstart.py
	PYTHONPATH=src $(PY) examples/distributed_index.py
	PYTHONPATH=src $(PY) examples/vector_search.py
