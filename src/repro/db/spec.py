"""``IndexSpec``: the declarative description of an index deployment.

One spec describes WHAT to build — bucket geometry, successor-search
backend, compaction policy, range capacity — and WHERE on the tiering
ladder it runs:

    tier='static'    immutable ``CgrxIndex`` behind the rank engine;
                     cheapest reads, writes rejected with a typed error
    tier='live'      epoch-versioned ``LiveIndex`` (snapshot + chains)
    tier='sharded'   ``ShardedLiveStore``: S splitter-routed live shards

so moving a workload from read-only to updatable to range-partitioned is
a *spec edit*, not a code path: every tier serves the same ``Session``
surface (``repro.db.session``).  The spec maps onto the underlying
configs (``store.LiveConfig`` / ``store.ShardedConfig``) in one place
(``to_live_config`` / ``to_sharded_config``) so the knobs cannot drift.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.query.batch import validate_max_hits
from repro.store.compaction import CompactionPolicy
from repro.store.live import LiveConfig
from repro.store.sharded import ShardedConfig

from .errors import InvalidSpecError

TIERS = ("static", "live", "sharded")
BACKENDS = ("tree", "binary", "kernel")
DURABILITY = ("none", "wal", "wal+snapshot")
REBALANCE_MODES = ("incremental", "full")
KINDS = ("scalar", "vector")


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """Declarative index deployment (see module docstring).

    ``bucket_size``   keys per bucket: the static tier's B, and the
                      live/sharded tiers' immutable epoch-snapshot B;
    ``backend``       successor-search implementation for the rep stage
                      ('tree' | 'binary' | 'kernel') — the static tier's
                      engine backend and the live tiers' ``rep_method``;
    ``node_cap``      slots per chain node (live/sharded tiers);
    ``shards``        shard count (sharded tier only);
    ``policy``        compaction triggers (``store.CompactionPolicy``;
                      its ``max_chain`` bounds the lookup walk cost);
    ``auto_compact``  evaluate the policy on every write flush; off =
                      flush never pauses, maintenance is the caller's
                      (e.g. ``session.tier.maybe_compact()`` off-peak);
    ``max_hits``      row-id capacity per range result;
    ``max_imbalance`` sharded skew-rebalance trigger (None disables);
    ``jit``           jit the engine pipelines;
    ``cache_scope``   executable-cache namespace (see query/engine.py);
    ``kind``          'scalar' (key lookups, the historical surface) or
                      'vector' (the coarse-bucket ANN tier,
                      ``repro.vector``): embeddings are quantized to
                      coarse centroids and indexed as composite keys on
                      the SAME tier the spec names, so ``tier=`` still
                      picks static/live/sharded underneath;
    ``dim``           vector kind only: embedding dimensionality;
    ``ncentroids``    vector kind only: coarse centroid count (the
                      bucket count of the ANN layer);
    ``nprobe``        vector kind only: buckets probed per query
                      (default: ``ncentroids`` — exhaustive, exact);
    ``slo_ms``        optional per-request latency SLO in milliseconds:
                      arms the deadline-based admission controller
                      (``tuning/admission.py``) — the session flushes
                      BEFORE the oldest pending request's deadline would
                      pass, not only on ``Ticket.result()``;
    ``max_pending``   optional pending-queue bound: a submission that
                      would exceed it is shed with a typed
                      ``OverloadError`` (queue depth + estimated wait)
                      instead of inflating tail latency;
    ``autotune``      run the online autotuner (``tuning/autotune.py``)
                      after every flush: measured-cost backend
                      re-selection, and — on the sharded tier —
                      skew-triggered shard migration;
    ``rebalance_mode``  'incremental' (bounded ``migrate_step`` ticks
                      between adjacent shards — short pauses, the
                      autotuner's path) or 'full' (the historical
                      stop-and-rebuild extract→presorted-build);
    ``migrate_max_keys``  per-tick key budget of an incremental
                      migration step;
    ``durability``    'none' (memory-only, the historical behavior),
                      'wal' (every write batch fsynced to a write-ahead
                      log before its device dispatch, one baseline
                      snapshot at open), or 'wal+snapshot' (also
                      re-snapshot at every compaction/rebalance so the
                      replay tail stays short) — live/sharded tiers
                      only; the static tier has nothing to log;
    ``wal_dir``       durable-state directory (WAL segments, snapshots,
                      heartbeats); required when durability != 'none'.
    """

    tier: str = "live"
    bucket_size: int = 16
    backend: str = "tree"
    node_cap: int = 32
    shards: int = 4
    policy: CompactionPolicy = dataclasses.field(
        default_factory=CompactionPolicy)
    auto_compact: bool = True
    max_hits: int = 64
    max_imbalance: Optional[float] = 2.0
    jit: bool = True
    cache_scope: Optional[str] = None
    slo_ms: Optional[float] = None
    max_pending: Optional[int] = None
    autotune: bool = False
    rebalance_mode: str = "incremental"
    migrate_max_keys: int = 256
    durability: str = "none"
    wal_dir: Optional[str] = None
    kind: str = "scalar"
    dim: Optional[int] = None
    ncentroids: Optional[int] = None
    nprobe: Optional[int] = None

    def __post_init__(self):
        if self.tier not in TIERS:
            raise InvalidSpecError(
                f"unknown tier {self.tier!r}; expected one of {TIERS}")
        if self.backend not in BACKENDS:
            raise InvalidSpecError(
                f"unknown backend {self.backend!r}; expected one of "
                f"{BACKENDS}")
        if self.bucket_size <= 0 or self.node_cap <= 0:
            raise InvalidSpecError(
                "bucket_size and node_cap must be positive")
        try:
            # Shared with the lane planner: non-positive AND absurdly
            # large capacities fail here, at the spec boundary, naming
            # the offending value — not deep inside lane planning.
            validate_max_hits(self.max_hits)
        except ValueError as e:
            raise InvalidSpecError(str(e)) from None
        if self.tier == "sharded" and self.shards < 1:
            raise InvalidSpecError("sharded tier needs shards >= 1")
        if self.slo_ms is not None and (
                not isinstance(self.slo_ms, (int, float))
                or self.slo_ms <= 0):
            raise InvalidSpecError(
                f"slo_ms must be a positive number of milliseconds, got "
                f"slo_ms={self.slo_ms!r}")
        if self.max_pending is not None and (
                not isinstance(self.max_pending, int)
                or self.max_pending < 1):
            raise InvalidSpecError(
                f"max_pending must be a positive int (the pending-queue "
                f"bound), got max_pending={self.max_pending!r}")
        if self.rebalance_mode not in REBALANCE_MODES:
            raise InvalidSpecError(
                f"unknown rebalance_mode {self.rebalance_mode!r}; "
                f"expected one of {REBALANCE_MODES}")
        if self.migrate_max_keys < 1:
            raise InvalidSpecError(
                f"migrate_max_keys must be >= 1, got "
                f"{self.migrate_max_keys!r}")
        if self.durability not in DURABILITY:
            raise InvalidSpecError(
                f"unknown durability {self.durability!r}; expected one "
                f"of {DURABILITY}")
        if self.durability != "none":
            if self.wal_dir is None:
                raise InvalidSpecError(
                    f"durability={self.durability!r} needs a wal_dir to "
                    f"write the log and snapshots into")
            if self.tier == "static":
                raise InvalidSpecError(
                    "the static tier takes no writes, so there is "
                    "nothing to log; use durability='none' (a static "
                    "index is rebuilt from its source keys)")
        self._validate_kind()

    def _validate_kind(self) -> None:
        if self.kind not in KINDS:
            raise InvalidSpecError(
                f"unknown kind {self.kind!r}; expected one of {KINDS}")
        if self.kind == "scalar":
            for field in ("dim", "ncentroids", "nprobe"):
                value = getattr(self, field)
                if value is not None:
                    raise InvalidSpecError(
                        f"{field}={value!r} is a vector-spec option but "
                        f"kind='scalar'; set kind='vector' to open an "
                        f"ANN tier")
            return
        if self.dim is None:
            raise InvalidSpecError(
                "kind='vector' needs dim= (the embedding "
                "dimensionality); got dim=None")
        if not isinstance(self.dim, int) or self.dim < 1:
            raise InvalidSpecError(
                f"dim must be a positive int, got dim={self.dim!r}")
        if self.ncentroids is None:
            raise InvalidSpecError(
                "kind='vector' needs ncentroids= (the coarse bucket "
                "count); got ncentroids=None")
        if not isinstance(self.ncentroids, int) or self.ncentroids < 1:
            raise InvalidSpecError(
                f"ncentroids must be a positive int, got "
                f"ncentroids={self.ncentroids!r}")
        if self.nprobe is not None:
            if not isinstance(self.nprobe, int) or self.nprobe < 1:
                raise InvalidSpecError(
                    f"nprobe must be a positive int, got "
                    f"nprobe={self.nprobe!r}")
            if self.nprobe > self.ncentroids:
                raise InvalidSpecError(
                    f"nprobe={self.nprobe} exceeds "
                    f"ncentroids={self.ncentroids}; a probe cannot "
                    f"visit more buckets than exist")
        if self.durability != "none":
            raise InvalidSpecError(
                f"durability={self.durability!r} is scalar-only for "
                f"now: the WAL logs key batches, not embeddings, so a "
                f"recovered vector tier would lose its arena; use "
                f"durability='none' with kind='vector'")

    @property
    def durable(self) -> bool:
        return self.durability != "none"

    @property
    def effective_nprobe(self) -> int:
        """The probe width ``open()`` hands the session (vector kind):
        the spec's ``nprobe``, defaulting to exhaustive."""
        return self.nprobe if self.nprobe is not None else self.ncentroids

    def scalar_spec(self) -> "IndexSpec":
        """The inner scalar spec a vector tier builds its composite-key
        index with (same tier/geometry, vector fields stripped)."""
        return dataclasses.replace(self, kind="scalar", dim=None,
                                   ncentroids=None, nprobe=None)

    # -- mappings onto the underlying configs ---------------------------------

    def to_live_config(self) -> LiveConfig:
        return LiveConfig(node_cap=self.node_cap,
                          snapshot_bucket_size=self.bucket_size,
                          rep_method=self.backend,
                          policy=self.policy,
                          auto_compact=self.auto_compact,
                          jit=self.jit,
                          cache_scope=self.cache_scope)

    def to_sharded_config(self) -> ShardedConfig:
        return ShardedConfig(num_shards=self.shards,
                             live=self.to_live_config(),
                             max_imbalance=self.max_imbalance,
                             cache_scope=self.cache_scope or "sharded",
                             rebalance_mode=self.rebalance_mode,
                             migrate_max_keys=self.migrate_max_keys)
