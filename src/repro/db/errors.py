"""Typed errors of the ``repro.db`` session API."""
from __future__ import annotations


class DbError(Exception):
    """Base class for every ``repro.db`` error."""


class ReadOnlyTierError(DbError):
    """A write (insert/delete) was submitted to a read-only tier.

    The ``static`` tier wraps an immutable ``CgrxIndex``: it serves
    point/range/rank traffic at the lowest cost but rejects mutation at
    submission time — switch the spec to ``tier='live'`` (or
    ``'sharded'``) to accept writes.
    """


class InvalidSpecError(DbError, ValueError):
    """An ``IndexSpec`` (or ``Session``) knob is invalid: unknown tier or
    backend, non-positive bucket/node sizes, a non-positive shard count
    on the sharded tier, or a ``max_hits`` outside ``[1, MAX_MAX_HITS]``
    (``repro.query.batch``) — the message always names the offending
    value.  (Sharding knobs on an unsharded tier are inert, not an error
    — a spec may be flipped between tiers in place.)"""
