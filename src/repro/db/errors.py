"""Typed errors of the ``repro.db`` session API."""
from __future__ import annotations


class DbError(Exception):
    """Base class for every ``repro.db`` error."""


class ReadOnlyTierError(DbError):
    """A write (insert/delete) was submitted to a read-only tier.

    The ``static`` tier wraps an immutable ``CgrxIndex``: it serves
    point/range/rank traffic at the lowest cost but rejects mutation at
    submission time — switch the spec to ``tier='live'`` (or
    ``'sharded'``) to accept writes.
    """


class InvalidSpecError(DbError, ValueError):
    """An ``IndexSpec`` (or ``Session``) knob is invalid: unknown tier or
    backend, non-positive bucket/node sizes, a non-positive shard count
    on the sharded tier, a ``max_hits`` outside ``[1, MAX_MAX_HITS]``
    (``repro.query.batch``), or a durable ``durability=`` mode without a
    ``wal_dir`` — the message always names the offending value.
    (Sharding knobs on an unsharded tier are inert, not an error — a
    spec may be flipped between tiers in place.)"""


class RecoveryError(DbError):
    """Opening or recovering a durable store failed: the ``wal_dir``
    holds no recoverable state (or already holds state a fresh
    ``recover=False`` open would clobber), a snapshot manifest does not
    match the spec, or the write-ahead log is corrupt somewhere other
    than its torn tail.  Filesystem/WAL-level causes (``OSError``,
    ``store.wal.WalCorruptError``) are chained as ``__cause__`` instead
    of escaping raw from ``checkpoint``/``store.wal`` internals."""


class StaleReplicaError(DbError):
    """No replica is fresh enough to serve: every member of the
    ``ReplicaSet`` is stale, failed, or flagged as a straggler.

    ``epoch_lag`` is the best available replica's lag behind the
    primary's last-published epoch, and ``seq_lag`` the same in WAL
    sequence numbers (either may be ``None`` when the primary's beacon
    is unreadable) — attached so a caller can decide between retrying,
    relaxing its freshness bound, or alerting.
    """

    def __init__(self, message: str, *, epoch_lag=None, seq_lag=None):
        super().__init__(message)
        self.epoch_lag = epoch_lag
        self.seq_lag = seq_lag


class SessionClosedError(DbError):
    """A request was submitted to (or a pending ticket resolved against)
    a ``Session`` after ``close()``: the WAL segment is sealed and the
    tier may be torn down, so the operation can never be served.  Open a
    new session (``repro.db.open(..., recover=True)`` resumes a durable
    one)."""


class OverloadError(DbError):
    """The session's bounded pending queue is full: the submission was
    SHED before enqueue (admission backpressure,
    ``IndexSpec(max_pending=...)``), so nothing was queued and nothing
    needs cancelling — flush (or wait for the deadline controller to)
    and resubmit.

    ``queue_depth`` is the pending count at refusal, ``max_pending`` the
    configured bound, and ``estimated_wait`` the admission controller's
    predicted seconds to drain the queue (its measured flush cost
    model) — the retry-after hint.
    """

    def __init__(self, message: str, *, queue_depth: int,
                 max_pending: int, estimated_wait: float):
        super().__init__(message)
        self.queue_depth = queue_depth
        self.max_pending = max_pending
        self.estimated_wait = estimated_wait


class DroppedTicketError(DbError, RuntimeError):
    """A ``Ticket`` was dropped by a failed ``flush()``: the flush had
    already drained its queues when it raised (e.g. mixed key widths in
    one flush, or a device error mid-dispatch), so the ticket's op was
    lost and must be resubmitted.  Subclasses ``RuntimeError`` for
    callers that predate the typed hierarchy."""
