"""``Session``: the one typed surface for all index traffic.

Every request kind — point lookup, range lookup, insert, delete, raw
rank scan — is submitted as a future-style ``Ticket`` and served by
``flush()``, which drains the queues with ONE device dispatch per op
class:

    writes:  one ``tier.apply`` covering every insert AND delete of the
             flush (deletions-before-insertions semantics; ins∩del
             pairs cancel — the contract of ``nodes.apply_batch``);
    policy:  one compaction/rebalance check (timed: the pause an epoch
             swap takes is the number benchmarks plot);
    reads:   one ``tier.execute`` over a ``QueryBatch`` coalescing all
             points and ranges into a single padded lane batch;
    ranks:   one ``tier.scan_ranks`` covering every rank scan.

Within a flush, writes land before reads: a lookup submitted in the same
flush as an insert of its key hits.  Admission batching is therefore the
API's *built-in* execution model — callers never hand-roll a tick loop —
and a flush with nothing pending is a cheap no-op (no plan, no
executable, no device call).  Accessing an unresolved ``Ticket``'s
result auto-flushes, so single-call usage reads naturally::

    sess = repro.db.open(spec, keys, rows)
    res = sess.lookup(queries).result()          # auto-flush
    sess.insert(k, r); sess.delete(d)
    rng = sess.range(lo, hi)
    rep = sess.flush()                           # one dispatch per class
    rows = rng.result()

``dispatches`` counts coalesced dispatch *rounds* per op class (at most
one per class per flush) — the observable the perf gate uses to pin
"dispatch-per-flush count unchanged".  On the sharded tier one round
fans out to one device dispatch per *touched shard* (that is the tier's
routing contract, not per-request dispatch); the counter deliberately
counts rounds, the thing the session controls.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cgrx
from repro.core.keys import KeyArray, concat_keys
from repro.query import QueryBatch
from repro.query.batch import SIDE_LEFT, SIDE_RIGHT

from .errors import ReadOnlyTierError
from .tiers import IndexTier, Stats

_UNSET = object()

_SIDES = {"left": SIDE_LEFT, "right": SIDE_RIGHT}


class Ticket:
    """Future-style handle on one submitted request.

    ``result()`` returns the op's result, flushing the session first if
    the request is still queued (auto-flush); repeated calls return the
    same value.  Result types by kind: ``point`` -> ``LookupResult``,
    ``range`` -> ``RangeResult`` (fields sliced to the submission's
    shape), ``insert``/``delete`` -> submitted batch size (NOT the net
    change: cancelled pairs and deletes of absent keys still count),
    ``rank`` -> int32 global-rank array.

    The resolved value lives on the ticket itself (the session holds no
    reference back once the flush drains its queue), so fire-and-forget
    submissions — a serving loop that never retains its read tickets —
    cost nothing after the flush: dropped tickets are garbage-collected
    together with their results.  Resolution also drops the ticket's own
    session reference (a ready ticket never needs it again), so retained
    result tickets cannot pin a closed session's index buffers either.
    """

    __slots__ = ("_session", "id", "kind", "_value", "__weakref__")

    def __init__(self, session: "Session", tid: int, kind: str):
        self._session = session
        self.id = tid
        self.kind = kind
        self._value = _UNSET

    def _resolve(self, value) -> None:
        self._value = value
        self._session = None

    @property
    def ready(self) -> bool:
        return self._value is not _UNSET

    def result(self):
        if self._value is _UNSET:
            self._session.flush()
        if self._value is _UNSET:
            # Only reachable when a previous flush() raised after it had
            # already drained its queues (e.g. mixed key widths in one
            # flush, or a device error mid-dispatch): this ticket's op
            # was lost with that flush.  Fail loudly, not with a leaked
            # sentinel posing as a result.
            raise RuntimeError(
                f"{self!r} was dropped by a failed flush(); "
                f"resubmit the request")
        return self._value

    def __repr__(self) -> str:
        state = "ready" if self.ready else "pending"
        return f"Ticket({self.kind} #{self.id}, {state})"


@dataclasses.dataclass(frozen=True)
class FlushReport:
    """What one ``flush()`` did and what it cost."""

    flush: int                 # 0-based flush counter
    epoch: int                 # tier epoch serving this flush's reads
    n_point: int
    n_range: int
    n_insert: int
    n_delete: int
    n_rank: int
    compacted: Optional[str]   # firing trigger summary, or None
    update_seconds: float      # apply wall time
    lookup_seconds: float      # engine execute wall time
    rank_seconds: float        # scan_ranks wall time
    compact_seconds: float     # epoch-swap pause (0.0 when none fired)


class Session:
    """The single front door over one ``IndexTier`` (see module doc)."""

    def __init__(self, tier: IndexTier, *, max_hits: int = 64):
        self.tier = tier
        self.max_hits = max_hits
        self._next_ticket = 0
        self._flush_count = 0
        # Queues hold the Ticket objects themselves; flush resolves onto
        # them and drops the queue reference, so the session never
        # retains results the caller discarded.
        self._points: List[Tuple[Ticket, KeyArray]] = []
        self._ranges: List[Tuple[Ticket, KeyArray, KeyArray]] = []
        self._ins: List[Tuple[Ticket, KeyArray, jnp.ndarray]] = []
        self._dels: List[Tuple[Ticket, KeyArray]] = []
        self._scans: List[Tuple[Ticket, KeyArray, int]] = []
        # Coalesced dispatch rounds per op class since open (one per
        # class per non-empty flush is the invariant the perf gate
        # tracks; a sharded tier fans one round out per touched shard).
        self.dispatches: Dict[str, int] = {"apply": 0, "query": 0,
                                           "rank": 0}

    # -- submission -----------------------------------------------------------

    def _ticket(self, kind: str) -> Ticket:
        t = Ticket(self, self._next_ticket, kind)
        self._next_ticket += 1
        return t

    # Zero-length submissions resolve immediately (empty result / an
    # applied-count of 0) instead of queueing: an all-empty flush
    # dispatches nothing, so their tickets would otherwise never settle.

    def lookup(self, keys: KeyArray) -> Ticket:
        """Queue a point-lookup batch; resolves to ``LookupResult``."""
        t = self._ticket("point")
        if int(keys.shape[0]) == 0:
            t._resolve(cgrx.empty_lookup_result())
        else:
            self._points.append((t, keys))
        return t

    def range(self, lo: KeyArray, hi: KeyArray) -> Ticket:
        """Queue a range-lookup batch; resolves to ``RangeResult`` with
        ``max_hits`` row capacity per range."""
        if lo.shape != hi.shape:
            raise ValueError("range lo/hi shapes differ")
        t = self._ticket("range")
        if int(lo.shape[0]) == 0:
            t._resolve(cgrx.empty_range_result(self.max_hits))
        else:
            self._ranges.append((t, lo, hi))
        return t

    def insert(self, keys: KeyArray, rows: jnp.ndarray) -> Ticket:
        """Queue an insert batch; resolves to the submitted count."""
        self._check_writable("insert")
        t = self._ticket("insert")
        if int(keys.shape[0]) == 0:
            t._resolve(0)
        else:
            self._ins.append((t, keys, jnp.asarray(rows, jnp.int32)))
        return t

    def delete(self, keys: KeyArray) -> Ticket:
        """Queue a delete batch; resolves to the submitted count."""
        self._check_writable("delete")
        t = self._ticket("delete")
        if int(keys.shape[0]) == 0:
            t._resolve(0)
        else:
            self._dels.append((t, keys))
        return t

    def scan_ranks(self, keys: KeyArray, side: str = "left") -> Ticket:
        """Queue a raw rank scan (#keys < q, or <= q with
        ``side='right'``); resolves to an int32 global-rank array."""
        if side not in _SIDES:
            raise ValueError(f"side must be 'left' or 'right', got {side!r}")
        t = self._ticket("rank")
        if int(keys.shape[0]) == 0:
            t._resolve(jnp.zeros((0,), jnp.int32))
        else:
            self._scans.append((t, keys, _SIDES[side]))
        return t

    def _check_writable(self, op: str) -> None:
        if not self.tier.writable:
            raise ReadOnlyTierError(
                f"{op} submitted to the read-only '{self.tier.tier}' "
                f"tier; re-open with IndexSpec(tier='live') or "
                f"tier='sharded' to accept writes")

    @property
    def pending(self) -> int:
        """Queued (unserved) requests awaiting the next flush."""
        return (len(self._points) + len(self._ranges) + len(self._ins)
                + len(self._dels) + len(self._scans))

    # -- introspection --------------------------------------------------------

    @property
    def epoch(self) -> int:
        return self.tier.epoch

    def stats(self) -> Stats:
        return self.tier.stats()

    def nbytes(self) -> dict:
        return self.tier.nbytes()

    # -- the flush ------------------------------------------------------------

    def flush(self) -> FlushReport:
        """Drain every queue with one device dispatch per op class.

        Order: writes -> policy -> reads -> rank scans.  An all-empty
        flush is a cheap no-op: nothing is planned, compiled or
        dispatched (see tests/test_db.py).
        """
        points, self._points = self._points, []
        ranges, self._ranges = self._ranges, []
        ins, self._ins = self._ins, []
        dels, self._dels = self._dels, []
        scans, self._scans = self._scans, []

        n_insert = sum(int(k.shape[0]) for _, k, _ in ins)
        n_delete = sum(int(k.shape[0]) for _, k in dels)
        n_point = sum(int(k.shape[0]) for _, k in points)
        n_range = sum(int(lo.shape[0]) for _, lo, _ in ranges)
        n_rank = sum(int(k.shape[0]) for _, k, _ in scans)

        # ---- writes first: one apply for the whole flush ----
        t0 = time.perf_counter()
        if n_insert or n_delete:
            ik = ir = dk = None
            if ins:
                ik = _concat([k for _, k, _ in ins])
                ir = jnp.concatenate([r for _, _, r in ins])
            if dels:
                dk = _concat([k for _, k in dels])
            self.tier.apply(ik, ir, dk)
            self.tier.sync()
            self.dispatches["apply"] += 1
            for t, k, _ in ins:
                t._resolve(int(k.shape[0]))
            for t, k in dels:
                t._resolve(int(k.shape[0]))
        t_update = time.perf_counter() - t0

        # ---- policy check (the pause, when it fires) ----
        # Honors the spec's auto_compact knob: with it off, flush never
        # takes an epoch-swap pause — compaction timing belongs to the
        # caller (tier.maybe_compact() / the underlying store's compact).
        t0 = time.perf_counter()
        compacted = (self.tier.maybe_compact()
                     if (n_insert or n_delete) and self.tier.auto_compact
                     else None)
        if compacted:
            self.tier.sync()
        t_compact = time.perf_counter() - t0

        # ---- reads: one engine call for all points + ranges ----
        t0 = time.perf_counter()
        if n_point or n_range:
            batch = QueryBatch()
            for _, k in points:
                batch.add_points(k)
            for _, lo, hi in ranges:
                batch.add_ranges(lo, hi)
            res = self.tier.execute(batch.plan(max_hits=self.max_hits))
            self.dispatches["query"] += 1
            jax.block_until_ready(res.points.row_id if n_point
                                  else res.ranges.row_ids)
            off = 0
            for t, k in points:
                m = int(k.shape[0])
                t._resolve(_slice_tuple(res.points, off, off + m))
                off += m
            off = 0
            for t, lo, _ in ranges:
                m = int(lo.shape[0])
                t._resolve(_slice_tuple(res.ranges, off, off + m))
                off += m
        t_lookup = time.perf_counter() - t0

        # ---- rank scans: one scan_ranks call for all of them ----
        t0 = time.perf_counter()
        if n_rank:
            qk = _concat([k for _, k, _ in scans])
            sides = jnp.asarray(np.concatenate(
                [np.full(int(k.shape[0]), s, np.int32)
                 for _, k, s in scans]))
            ranks = self.tier.scan_ranks(qk, sides)
            self.dispatches["rank"] += 1
            jax.block_until_ready(ranks)
            off = 0
            for t, k, _ in scans:
                m = int(k.shape[0])
                t._resolve(ranks[off:off + m])
                off += m
        t_rank = time.perf_counter() - t0

        self._flush_count += 1
        return FlushReport(flush=self._flush_count - 1,
                           epoch=self.tier.epoch,
                           n_point=n_point, n_range=n_range,
                           n_insert=n_insert, n_delete=n_delete,
                           n_rank=n_rank, compacted=compacted,
                           update_seconds=t_update,
                           lookup_seconds=t_lookup,
                           rank_seconds=t_rank,
                           compact_seconds=t_compact if compacted else 0.0)


# ---------------------------------------------------------------------------
# Helpers.
# ---------------------------------------------------------------------------

def _concat(parts: List[KeyArray]) -> KeyArray:
    out = parts[0]
    for p in parts[1:]:
        out = concat_keys(out, p)
    return out


def _slice_tuple(res, lo: int, hi: int):
    """Slice every field of a NamedTuple result along axis 0."""
    return type(res)(*(f[lo:hi] for f in res))
