"""``Session``: the one typed surface for all index traffic.

Every request kind — point lookup, range lookup, IN-list, range
aggregate, join probe, insert, delete, raw rank scan — is submitted as a
future-style ``Ticket`` and served by ``flush()``, which drains the
queues with ONE device dispatch per op class:

    writes:  one ``tier.apply`` covering every insert AND delete of the
             flush (deletions-before-insertions semantics; ins∩del
             pairs cancel — the contract of ``nodes.apply_batch``);
    policy:  one compaction/rebalance check (timed: the pause an epoch
             swap takes is the number benchmarks plot);
    reads:   one ``tier.execute`` over the physical ``QueryPlan`` the
             logical-plan compiler (``repro.query.plan``) fuses from
             EVERY read expression of the flush — points, ranges,
             IN-lists, join probes and rank-only aggregates together;
    ranks:   one ``tier.scan_ranks`` covering every rank scan.

``query(expr)`` is the general entry point: it takes any expression tree
of the ``repro.query.plan`` IR (``eq`` / ``between`` / ``isin`` /
``limit`` / ``count`` / ``min_key`` / ``max_key`` / ``probe`` /
``rank_scan``, re-exported on ``repro.db``) and resolves to that tree's
result.  The historical verbs are THIN SUGAR over it —

    lookup(k)        = query(eq(k))
    range(lo, hi)    = query(between(lo, hi))
    scan_ranks(k, s) = query(rank_scan(k, s))

— constructing the same IR nodes the compiler lowers to the exact lane
layout the pre-IR session produced, so their results stay bit-identical.
A flush whose read set is aggregate-only executes the engine's rank-only
path: no rowID block is ever gathered (pin: ``query.STAGE_COUNTERS``).

Within a flush, writes land before reads: a lookup submitted in the same
flush as an insert of its key hits.  Admission batching is therefore the
API's *built-in* execution model — callers never hand-roll a tick loop —
and a flush with nothing pending is a cheap no-op (no plan, no
executable, no device call).  Accessing an unresolved ``Ticket``'s
result auto-flushes, so single-call usage reads naturally::

    sess = repro.db.open(spec, keys, rows)
    res = sess.lookup(queries).result()          # auto-flush
    sess.insert(k, r); sess.delete(d)
    cnt = sess.query(db.count(db.between(lo, hi)))
    rep = sess.flush()                           # one dispatch per class
    counts = cnt.result()

``dispatches`` counts coalesced dispatch *rounds* per op class (at most
one per class per flush) — the observable the perf gate uses to pin
"dispatch-per-flush count unchanged".  On the sharded tier one round
fans out to one device dispatch per *touched shard* (that is the tier's
routing contract, not per-request dispatch); the counter deliberately
counts rounds, the thing the session controls.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.keys import KeyArray, concat_keys
from repro.query import plan as qplan
from repro.query.batch import validate_max_hits
from repro.query.engine import stage_counter_snapshot

from .errors import (DroppedTicketError, InvalidSpecError,
                     ReadOnlyTierError, SessionClosedError)
from .tiers import IndexTier, Stats

_UNSET = object()


class Ticket:
    """Future-style handle on one submitted request.

    ``result()`` returns the op's result, flushing the session first if
    the request is still queued (auto-flush); repeated calls return the
    same value.  Result types by kind: ``point`` -> ``LookupResult``,
    ``range`` -> ``RangeResult`` (fields sliced to the submission's
    shape), ``insert``/``delete`` -> submitted batch size (NOT the net
    change: cancelled pairs and deletes of absent keys still count),
    ``rank`` -> int32 global-rank array; ``query`` tickets resolve to
    their expression tree's result type (see ``repro.query.plan``).

    The resolved value lives on the ticket itself (the session holds no
    reference back once the flush drains its queue), so fire-and-forget
    submissions — a serving loop that never retains its read tickets —
    cost nothing after the flush: dropped tickets are garbage-collected
    together with their results.  Resolution also drops the ticket's own
    session reference (a ready ticket never needs it again), so retained
    result tickets cannot pin a closed session's index buffers either.
    """

    __slots__ = ("_session", "id", "kind", "_value", "__weakref__")

    def __init__(self, session: "Session", tid: int, kind: str):
        self._session = session
        self.id = tid
        self.kind = kind
        self._value = _UNSET

    def _resolve(self, value) -> None:
        self._value = value
        self._session = None

    @property
    def ready(self) -> bool:
        return self._value is not _UNSET

    def result(self):
        if self._value is _UNSET:
            if self._session is not None and self._session.closed:
                # The session was closed (possibly mid-flush) before
                # this op could be served; no later flush can ever
                # resolve it.
                raise SessionClosedError(
                    f"{self!r} cannot resolve: its session was closed "
                    f"before the request was served; resubmit on a new "
                    f"session")
            self._session.flush()
        if self._value is _UNSET:
            # Only reachable when a previous flush() raised after it had
            # already drained its queues (e.g. mixed key widths in one
            # flush, or a device error mid-dispatch): this ticket's op
            # was lost with that flush.  Fail loudly, not with a leaked
            # sentinel posing as a result.
            raise DroppedTicketError(
                f"{self!r} was dropped by a failed flush(); "
                f"resubmit the request")
        return self._value

    def __repr__(self) -> str:
        state = "ready" if self.ready else "pending"
        return f"Ticket({self.kind} #{self.id}, {state})"


@dataclasses.dataclass(frozen=True)
class FlushReport:
    """What one ``flush()`` did and what it cost.

    ``n_point``/``n_range``/``n_agg`` count PHYSICAL fragments per
    section of the fused plan (an IN-list contributes its unique keys, a
    probe its probe lanes, an aggregate its ranges), ``n_rank`` the rank-
    scan lanes — the shapes the one dispatch per class actually served.
    """

    flush: int                 # 0-based flush counter
    epoch: int                 # tier epoch serving this flush's reads
    n_point: int
    n_range: int
    n_insert: int
    n_delete: int
    n_rank: int
    compacted: Optional[str]   # firing trigger summary, or None
    update_seconds: float      # apply wall time
    lookup_seconds: float      # engine execute wall time
    rank_seconds: float        # scan_ranks wall time
    compact_seconds: float     # epoch-swap pause (0.0 when none fired)
    n_agg: int = 0             # rank-only aggregate ranges served


class Session:
    """The single front door over one ``IndexTier`` (see module doc).

    Lifecycle: a session is a context manager; ``close()`` (or leaving
    the ``with`` block) flushes pending tickets, seals the WAL segment
    and stops replica/heartbeat threads on durable sessions, and marks
    the session closed — submissions and flushes afterwards raise
    ``SessionClosedError``.  ``close()`` is idempotent.  Non-durable
    sessions close too (the flush-pending contract is uniform); for them
    it is cheap and optional, which is why the historical no-``with``
    usage keeps working.
    """

    def __init__(self, tier: IndexTier, *, max_hits: int = 64,
                 durability=None, bus=None, admission=None,
                 autotuner=None):
        try:
            validate_max_hits(max_hits)
        except ValueError as e:
            raise InvalidSpecError(str(e)) from None
        self.tier = tier
        self.max_hits = max_hits
        # Optional tiers.DurabilityManager: owns WAL/snapshot/heartbeat
        # plumbing; None = the memory-only session this always was.
        self._durability = durability
        # Adaptive runtime (repro.tuning), all optional and all None by
        # default — a session without them is bit-identical to the
        # historical behavior (pinned in tests/test_tuning.py):
        #   bus        tuning.TelemetryBus fed once per flush
        #   admission  tuning.AdmissionController: deadline flushing +
        #              bounded-queue shedding at submission time
        #   autotuner  tuning.AutoTuner ticked after every flush
        self._bus = bus
        self._admission = admission
        self._autotuner = autotuner
        self._replicas: List[object] = []
        self._closed = False
        self._next_ticket = 0
        self._flush_count = 0
        # Queues hold the Ticket objects themselves; flush resolves onto
        # them and drops the queue reference, so the session never
        # retains results the caller discarded.  Reads are one queue of
        # (ticket, expression tree) pairs — the compiler assigns each
        # tree's fragments to the right op class at flush time.
        self._reads: List[Tuple[Ticket, qplan.Expr]] = []
        self._ins: List[Tuple[Ticket, KeyArray, jnp.ndarray]] = []
        self._dels: List[Tuple[Ticket, KeyArray]] = []
        # Coalesced dispatch rounds per op class since open (one per
        # class per non-empty flush is the invariant the perf gate
        # tracks; a sharded tier fans one round out per touched shard).
        self.dispatches: Dict[str, int] = {"apply": 0, "query": 0,
                                           "rank": 0}

    # -- submission -----------------------------------------------------------

    def _ticket(self, kind: str) -> Ticket:
        t = Ticket(self, self._next_ticket, kind)
        self._next_ticket += 1
        return t

    def _admit(self) -> None:
        """Backpressure gate, BEFORE enqueue: a full pending queue sheds
        this submission with ``OverloadError`` (queue unchanged, caller
        retries after a flush).  No-op without an admission controller."""
        if self._admission is not None:
            self._admission.check_admit(self.pending)

    def _post_submit(self) -> None:
        """Deadline check, AFTER enqueue: arms the SLO deadline on the
        first queued request and flushes while a flush started now can
        still finish inside the SLO.  No-op without a controller."""
        if self._admission is None:
            return
        self._admission.note_submit()
        if self._admission.should_flush(pending=self.pending):
            self.flush()

    # Zero-length submissions resolve immediately (empty result / an
    # applied-count of 0) instead of queueing: an all-empty flush
    # dispatches nothing, so their tickets would otherwise never settle.
    # They bypass _admit/_post_submit too — nothing enters the queue.

    def query(self, expr: qplan.Expr, *, kind: Optional[str] = None) -> Ticket:
        """Queue one logical-plan expression tree; resolves to the
        tree's result type (see ``repro.query.plan``).  All trees queued
        before a flush fuse into ONE dispatch per op class."""
        if not isinstance(expr, qplan.Expr):
            raise TypeError(
                f"query() takes a repro.query.plan expression "
                f"(eq/between/isin/limit/count/min_key/max_key/probe/"
                f"rank_scan), got {type(expr).__name__}")
        self._check_open("query")
        self._admit()
        t = self._ticket(kind or "query")
        if qplan.expr_size(expr) == 0:
            t._resolve(qplan.empty_result(expr, self.max_hits))
        else:
            self._reads.append((t, expr))
            self._post_submit()
        return t

    def lookup(self, keys: KeyArray) -> Ticket:
        """Queue a point-lookup batch; resolves to ``LookupResult``.
        Sugar for ``query(eq(keys))``."""
        return self.query(qplan.eq(keys), kind="point")

    def range(self, lo: KeyArray, hi: KeyArray) -> Ticket:
        """Queue a range-lookup batch; resolves to ``RangeResult`` with
        ``max_hits`` row capacity per range.  Sugar for
        ``query(between(lo, hi))``."""
        if lo.shape != hi.shape:
            raise ValueError("range lo/hi shapes differ")
        return self.query(qplan.between(lo, hi), kind="range")

    def insert(self, keys: KeyArray, rows: jnp.ndarray) -> Ticket:
        """Queue an insert batch; resolves to the submitted count."""
        self._check_writable("insert")
        self._admit()
        t = self._ticket("insert")
        if int(keys.shape[0]) == 0:
            t._resolve(0)
        else:
            self._ins.append((t, keys, jnp.asarray(rows, jnp.int32)))
            self._post_submit()
        return t

    def delete(self, keys: KeyArray) -> Ticket:
        """Queue a delete batch; resolves to the submitted count."""
        self._check_writable("delete")
        self._admit()
        t = self._ticket("delete")
        if int(keys.shape[0]) == 0:
            t._resolve(0)
        else:
            self._dels.append((t, keys))
            self._post_submit()
        return t

    def scan_ranks(self, keys: KeyArray, side: str = "left") -> Ticket:
        """Queue a raw rank scan (#keys < q, or <= q with
        ``side='right'``); resolves to an int32 global-rank array.
        Sugar for ``query(rank_scan(keys, side))``."""
        return self.query(qplan.rank_scan(keys, side), kind="rank")

    def _check_open(self, op: str) -> None:
        if self._closed:
            raise SessionClosedError(
                f"{op} submitted to a closed session; open a new one "
                f"(repro.db.open(..., recover=True) resumes a durable "
                f"store)")

    def _check_writable(self, op: str) -> None:
        self._check_open(op)
        if not self.tier.writable:
            raise ReadOnlyTierError(
                f"{op} submitted to the read-only '{self.tier.tier}' "
                f"tier; re-open with IndexSpec(tier='live') or "
                f"tier='sharded' to accept writes")

    @property
    def pending(self) -> int:
        """Queued (unserved) requests awaiting the next flush."""
        return len(self._reads) + len(self._ins) + len(self._dels)

    # -- lifecycle ------------------------------------------------------------

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def durable(self) -> bool:
        return self._durability is not None

    def snapshot(self, *, wait: bool = True) -> int:
        """Persist a consistent snapshot of the tier at the current WAL
        position (durable sessions only); pending requests are flushed
        first so the cut covers everything submitted.  Returns the
        covered WAL sequence number.  ``wait=False`` leaves the write on
        the checkpoint manager's background thread (joined automatically
        by the next snapshot or by ``close()``)."""
        self._check_open("snapshot")
        if self._durability is None:
            raise InvalidSpecError(
                "snapshot() needs a durable session; open with "
                "IndexSpec(durability='wal' or 'wal+snapshot', "
                "wal_dir=...)")
        if self.pending:
            self.flush()
        return self._durability.snapshot(self.tier, wait=wait)

    def attach_replicas(self, replica_set) -> None:
        """Register a ``store.replica.ReplicaSet`` with this session's
        lifecycle: ``close()`` stops its refresh threads."""
        self._replicas.append(replica_set)

    def close(self) -> None:
        """Flush pending tickets, seal the WAL segment, stop replica and
        heartbeat threads, and mark the session closed.  Idempotent.  A
        flush failure still closes the session (pending tickets then
        raise the typed ``SessionClosedError``/``DroppedTicketError``)."""
        if self._closed:
            return
        try:
            if self.pending:
                self.flush()
        finally:
            self._closed = True
            for rs in self._replicas:
                rs.stop()
            if self._durability is not None:
                self._durability.close(self.tier)

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- introspection --------------------------------------------------------

    @property
    def epoch(self) -> int:
        return self.tier.epoch

    def stats(self) -> Stats:
        return self.tier.stats()

    def nbytes(self) -> dict:
        return self.tier.nbytes()

    @property
    def bus(self):
        """The session's ``tuning.TelemetryBus`` (None when the session
        was constructed directly without one)."""
        return self._bus

    def telemetry(self) -> dict:
        """One JSON-able snapshot of the adaptive runtime: the bus's
        ``export()`` (spans/rates/gauges/counters/touch/events) plus the
        admission and autotuner controller states when configured.
        Empty dict on a session without a bus."""
        if self._bus is None:
            return {}
        out = self._bus.export()
        if self._admission is not None:
            out["admission"] = self._admission.snapshot()
        if self._autotuner is not None:
            out["autotune"] = self._autotuner.snapshot()
        return out

    # -- the flush ------------------------------------------------------------

    def flush(self) -> FlushReport:
        """Drain every queue with one device dispatch per op class.

        Order: writes -> policy -> reads (the fused plan) -> rank scans.
        An all-empty flush is a cheap no-op: nothing is planned, compiled
        or dispatched (see tests/test_db.py).
        """
        self._check_open("flush")
        reads, self._reads = self._reads, []
        ins, self._ins = self._ins, []
        dels, self._dels = self._dels, []

        n_insert = sum(int(k.shape[0]) for _, k, _ in ins)
        n_delete = sum(int(k.shape[0]) for _, k in dels)
        n_items = len(reads) + len(ins) + len(dels)
        # The backend serving THIS flush's reads (the autotuner only
        # repoints between flushes, at tick time), so tagged query spans
        # attribute latency to the backend that produced it.
        backend_tag = getattr(self.tier, "current_backend", None)

        # ---- writes first: one apply for the whole flush ----
        t0 = time.perf_counter()
        if n_insert or n_delete:
            ik = ir = dk = None
            if ins:
                ik = _concat([k for _, k, _ in ins])
                ir = jnp.concatenate([r for _, _, r in ins])
            if dels:
                dk = _concat([k for _, k in dels])
            self.tier.apply(ik, ir, dk)
            self.tier.sync()
            self.dispatches["apply"] += 1
            for t, k, _ in ins:
                t._resolve(int(k.shape[0]))
            for t, k in dels:
                t._resolve(int(k.shape[0]))
        t_update = time.perf_counter() - t0

        # ---- policy check (the pause, when it fires) ----
        # Honors the spec's auto_compact knob: with it off, flush never
        # takes an epoch-swap pause — compaction timing belongs to the
        # caller (tier.maybe_compact() / the underlying store's compact).
        t0 = time.perf_counter()
        compacted = (self.tier.maybe_compact()
                     if (n_insert or n_delete) and self.tier.auto_compact
                     else None)
        if compacted:
            self.tier.sync()
        t_compact = time.perf_counter() - t0

        # ---- durability bookkeeping (no-op on memory-only sessions) ----
        # The WAL records were already fsynced inside tier.apply (before
        # the dispatch); here the session re-snapshots after an epoch
        # swap ('wal+snapshot' keeps the replay tail short) and beats
        # the primary heartbeat with the new WAL position.
        if self._durability is not None and (n_insert or n_delete):
            if compacted and self._durability.auto_snapshot:
                self._durability.snapshot(self.tier)
            self._durability.beat(self.tier)

        # ---- reads: compile every expression onto one plan per class ----
        # Compiled after the writes so a compile error (e.g. mixed key
        # widths) cannot retract writes the caller already saw applied.
        program = (qplan.compile_exprs([e for _, e in reads],
                                       default_max_hits=self.max_hits)
                   if reads else None)

        t0 = time.perf_counter()
        res = None
        if program is not None and program.has_query:
            res = self.tier.execute(program.plan)
            self.dispatches["query"] += 1
            jax.block_until_ready(
                res.aggs.count if program.n_agg
                else (res.points.row_id if program.n_point
                      else res.ranges.row_ids))
        t_lookup = time.perf_counter() - t0

        # ---- rank scans: one scan_ranks call for all of them ----
        t0 = time.perf_counter()
        ranks = None
        if program is not None and program.has_rank:
            ranks = self.tier.scan_ranks(program.rank_keys,
                                         program.rank_sides)
            self.dispatches["rank"] += 1
            jax.block_until_ready(ranks)
        t_rank = time.perf_counter() - t0

        if program is not None:
            for (t, _), extract in zip(reads, program.extractors):
                t._resolve(extract(res, ranks))

        # ---- adaptive runtime: feed the bus, close the control loops ----
        # All three hooks are optional; an empty flush skips everything
        # (the cheap-no-op contract above).
        total_seconds = t_update + t_compact + t_lookup + t_rank
        if self._bus is not None and n_items:
            bus = self._bus
            if n_insert or n_delete:
                bus.span("apply", t_update, n=n_insert + n_delete)
            if compacted:
                bus.span("compact", t_compact)
            if program is not None and program.has_query:
                lanes = program.n_point + program.n_range + program.n_agg
                bus.span("query", t_lookup, n=lanes, tag=backend_tag)
                bus.bump("lanes_point", program.n_point)
                bus.bump("lanes_range", program.n_range)
                bus.bump("lanes_agg", program.n_agg)
            if program is not None and program.has_rank:
                bus.span("rank", t_rank, n=program.n_rank)
            bus.span("flush", total_seconds, n=n_items)
            bus.counters(stage_counter_snapshot())
            # Stats rollups are periodic, not per-flush: collecting
            # ShardedStats walks every shard, too heavy for the hot path.
            if bus.n_flushes % 16 == 0:
                st = self.tier.stats()
                for f in dataclasses.fields(st):
                    v = getattr(st, f.name)
                    if isinstance(v, (int, float)):
                        bus.gauge(f.name, float(v))
            touch = getattr(getattr(self.tier, "store", None), "touch",
                            None)
            if touch is not None:
                bus.touch(touch.snapshot())
            bus.flush_mark()
        if self._admission is not None:
            if n_items:
                self._admission.observe_flush(total_seconds, n_items)
            self._admission.on_flush()
        if self._autotuner is not None and n_items:
            self._autotuner.tick()

        self._flush_count += 1
        return FlushReport(flush=self._flush_count - 1,
                           epoch=self.tier.epoch,
                           n_point=program.n_point if program else 0,
                           n_range=program.n_range if program else 0,
                           n_insert=n_insert, n_delete=n_delete,
                           n_rank=program.n_rank if program else 0,
                           compacted=compacted,
                           update_seconds=t_update,
                           lookup_seconds=t_lookup,
                           rank_seconds=t_rank,
                           compact_seconds=t_compact if compacted else 0.0,
                           n_agg=program.n_agg if program else 0)


# ---------------------------------------------------------------------------
# Helpers.
# ---------------------------------------------------------------------------

def _concat(parts: List[KeyArray]) -> KeyArray:
    out = parts[0]
    for p in parts[1:]:
        out = concat_keys(out, p)
    return out
