"""The ``IndexTier`` protocol and its three implementations.

A tier is the deployment-level backing of a ``Session``: it knows how to
serve one planned mixed batch (``execute``), absorb one mixed write batch
(``apply``), answer raw rank queries (``scan_ranks``), evaluate its
maintenance policy (``maybe_compact``), fence device work (``sync``), and
report itself through ONE unified ``Stats``/``nbytes`` shape regardless
of what machinery sits underneath.

``execute`` is the tier's PLAN-LEVEL hook: it takes the full physical
``QueryPlan`` the logical-plan compiler fused — point lanes, materializing
ranges AND rank-only aggregate ranges — and must serve every section.
The static and live tiers hand the plan to one ``RankEngine`` call; the
sharded tier decomposes it at the splitters (points to owners, range and
aggregate spans to their intersecting shards) and merges per-fragment:
row blocks concatenate in shard order, aggregates merge by sum (counts)
and min/max (endpoint keys) — see ``store/sharded.py``.

    StaticTier    immutable ``CgrxIndex`` + ``RankEngine`` — rejects
                  writes with ``ReadOnlyTierError`` at apply time
    LiveTier      ``store.LiveIndex`` (epoch snapshot + chain delta)
    ShardedTier   ``store.ShardedLiveStore`` (splitter-routed shards);
                  rank queries merge with the same rank-offset prefix
                  the read path uses, so global ranks stay bit-identical
                  to a single-shard oracle

``build_tier`` constructs a tier from an ``IndexSpec``; ``wrap_store``
adopts an already-built ``LiveIndex``/``ShardedLiveStore`` (the
compatibility path ``store.LiveFrontend`` rides on — deprecated for
durable-capable stores, which adopt as memory-only tiers with no
``wal_dir`` to log into).

Durability (spec ``durability=`` / ``wal_dir=``) also lives at this
layer: ``DurabilityManager`` owns the wal_dir layout —

    <wal_dir>/wal/...            write-ahead log segments (store/wal.py;
                                 per-shard subdirs on the sharded tier)
    <wal_dir>/snapshots/step-*   epoch snapshots via checkpoint/store.py
    <wal_dir>/primary.hb         the writer's heartbeat beacon
    <wal_dir>/replicas/*.hb      per-replica beacons (store/replica.py)

— attaches WALs to the store objects, snapshots consistent cuts through
the async checkpoint manager, prunes covered log segments, and beats the
primary heartbeat; ``recover_tier`` rebuilds a tier from the newest
snapshot plus the WAL tail (the recovery = snapshot + replay invariant
tests/test_wal_recovery.py pins bit-identical).
"""
from __future__ import annotations

import dataclasses
import os
from typing import List, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointManager
from repro.core import cgrx
from repro.core.deprecation import warn_once
from repro.core.keys import KeyArray, concat_keys
from repro.query import BatchResult, QueryPlan, RankEngine
from repro.runtime.ft import Heartbeat
from repro.store import metrics as store_metrics
from repro.store import wal as wal_mod
from repro.store.live import LiveIndex
from repro.store.sharded import ShardedLiveStore

from .errors import InvalidSpecError, ReadOnlyTierError, RecoveryError
from .spec import IndexSpec


@dataclasses.dataclass(frozen=True)
class Stats:
    """One stats shape for every tier (the operator's dashboard row).

    ``detail`` carries the tier-native snapshot (``None`` for static,
    ``store.LiveStats`` for live, ``store.ShardedStats`` for sharded)
    for callers that need tier-specific depth — everything above it is
    tier-independent.
    """

    tier: str
    live_keys: int
    epoch: int
    num_shards: int            # 1 unless sharded
    num_buckets: int           # summed across shards
    max_chain: int             # 1 for the flat static tier
    total_bytes: int
    applies: int
    inserts: int
    deletes: int
    compactions: int
    compacting: bool
    detail: object = None


@runtime_checkable
class IndexTier(Protocol):
    """What a ``Session`` needs from its backing tier.

    ``execute`` serves one fused physical plan INCLUDING its aggregate
    section (``plan.n_agg``/``plan.agg_keys``) — a tier that ignored the
    section would strand aggregate tickets, so the cross-tier parity
    suite pins all three implementations against one oracle.
    ``auto_compact`` gates the session's per-flush policy step: with it
    off, ``flush()`` never takes an epoch-swap pause and maintenance
    timing belongs to the caller.
    """

    tier: str
    writable: bool
    auto_compact: bool

    def execute(self, plan: QueryPlan) -> BatchResult: ...

    def scan_ranks(self, queries: KeyArray,
                   sides: jnp.ndarray) -> jnp.ndarray: ...

    def apply(self, ins_keys: Optional[KeyArray],
              ins_rows: Optional[jnp.ndarray],
              del_keys: Optional[KeyArray]) -> None: ...

    def maybe_compact(self) -> Optional[str]: ...

    def sync(self) -> None: ...

    @property
    def epoch(self) -> int: ...

    def stats(self) -> Stats: ...

    def nbytes(self) -> dict: ...


# ---------------------------------------------------------------------------
# Static: immutable CgrxIndex behind the rank engine.
# ---------------------------------------------------------------------------

class StaticTier:
    """Read-only tier over an immutable ``CgrxIndex``."""

    tier = "static"
    writable = False
    auto_compact = False          # nothing to compact, ever

    def __init__(self, index: cgrx.CgrxIndex, *, jit: bool = True,
                 cache_scope: Optional[str] = None):
        self.index = index
        self._jit = jit
        self._cache_scope = cache_scope
        self.engine = RankEngine(index, jit=jit, cache_scope=cache_scope)

    @classmethod
    def build(cls, spec: IndexSpec, keys: KeyArray,
              row_ids: Optional[jnp.ndarray]) -> "StaticTier":
        index = cgrx.build(keys, row_ids, spec.bucket_size,
                           method=spec.backend)
        return cls(index, jit=spec.jit, cache_scope=spec.cache_scope)

    def execute(self, plan: QueryPlan) -> BatchResult:
        return self.engine.execute(plan)

    def scan_ranks(self, queries: KeyArray,
                   sides: jnp.ndarray) -> jnp.ndarray:
        return self.engine.rank_batch(queries, sides)

    def apply(self, ins_keys, ins_rows, del_keys) -> None:
        n_ins = int(ins_keys.shape[0]) if ins_keys is not None else 0
        n_del = int(del_keys.shape[0]) if del_keys is not None else 0
        raise ReadOnlyTierError(
            f"static tier rejects writes ({n_ins} inserts, {n_del} "
            f"deletes submitted); re-open with IndexSpec(tier='live') or "
            f"tier='sharded' for an updatable index")

    def maybe_compact(self) -> Optional[str]:
        return None

    # -- autotuner hooks (tuning/autotune.py) ---------------------------------

    @property
    def current_backend(self) -> str:
        return self.engine.backend_name

    def set_backend(self, name: str) -> None:
        """Re-point the serving backend ('tree' | 'binary' | 'kernel');
        the immutable index carries every structure all flat backends
        need, so this is just an engine rebind."""
        if name == self.engine.backend_name:
            return
        self.engine = RankEngine(self.index, backend=name, jit=self._jit,
                                 cache_scope=self._cache_scope)

    def sync(self) -> None:
        jax.block_until_ready(self.index.buckets.keys.lo)

    @property
    def epoch(self) -> int:
        return 0

    def stats(self) -> Stats:
        return Stats(tier=self.tier, live_keys=self.index.n, epoch=0,
                     num_shards=1, num_buckets=self.index.num_buckets,
                     max_chain=1,
                     total_bytes=self.nbytes()["total_bytes"],
                     applies=0, inserts=0, deletes=0, compactions=0,
                     compacting=False, detail=None)

    def nbytes(self) -> dict:
        return cgrx.index_nbytes(self.index)


# ---------------------------------------------------------------------------
# Live: one epoch-versioned LiveIndex.
# ---------------------------------------------------------------------------

class LiveTier:
    """Updatable tier over a single ``store.LiveIndex``."""

    tier = "live"
    writable = True

    def __init__(self, live: LiveIndex):
        self.live = live
        # Plain attribute (configs are frozen): overridable by adopters
        # like the LiveFrontend shim, whose historical contract runs the
        # policy every tick regardless of the store's own knob.  getattr
        # because wrap_store also adopts duck-typed stores with no
        # config (the old frontend's contract).
        self.auto_compact = getattr(getattr(live, "config", None),
                                    "auto_compact", True)

    @classmethod
    def build(cls, spec: IndexSpec, keys: KeyArray,
              row_ids: Optional[jnp.ndarray]) -> "LiveTier":
        return cls(LiveIndex.build(keys, row_ids, spec.to_live_config()))

    # Session drives the policy itself (after the write step, timed), so
    # apply never auto-compacts here.
    def apply(self, ins_keys, ins_rows, del_keys) -> None:
        self.live.apply(ins_keys, ins_rows, del_keys, auto_compact=False)

    def execute(self, plan: QueryPlan) -> BatchResult:
        return self.live.execute(plan)

    def scan_ranks(self, queries: KeyArray,
                   sides: jnp.ndarray) -> jnp.ndarray:
        return self.live.engine.rank_batch(queries, sides)

    def maybe_compact(self) -> Optional[str]:
        return self.live.maybe_compact()

    # -- autotuner hooks (tuning/autotune.py) ---------------------------------

    @property
    def current_backend(self) -> str:
        """The rep-stage successor-search method the chain-aware 'node'
        backend dispatches through."""
        return self.live.config.rep_method

    def set_backend(self, name: str) -> None:
        self.live.set_rep_method(name)

    @property
    def bucket_size(self) -> int:
        return self.live.config.snapshot_bucket_size

    def retune_bucket_size(self, bucket_size: int) -> None:
        """Epoch-swap to a new snapshot bucket size (see
        ``store.LiveIndex.retune_bucket_size``)."""
        self.live.retune_bucket_size(bucket_size)

    def sync(self) -> None:
        self.live.sync()

    @property
    def epoch(self) -> int:
        return self.live.epoch

    def stats(self) -> Stats:
        s = self.live.stats()
        return Stats(tier=self.tier, live_keys=s.live_keys, epoch=s.epoch,
                     num_shards=1, num_buckets=s.num_buckets,
                     max_chain=s.max_chain, total_bytes=s.total_bytes,
                     applies=s.applies, inserts=s.inserts,
                     deletes=s.deletes, compactions=s.compactions,
                     compacting=s.compacting, detail=s)

    def nbytes(self) -> dict:
        s = self.live.stats()
        return {"store_bytes": s.store_bytes,
                "snapshot_bytes": s.snapshot_bytes,
                "total_bytes": s.total_bytes}


# ---------------------------------------------------------------------------
# Sharded: S splitter-routed LiveIndex shards.
# ---------------------------------------------------------------------------

class ShardedTier:
    """Updatable range-partitioned tier over a ``ShardedLiveStore``."""

    tier = "sharded"
    writable = True

    def __init__(self, store: ShardedLiveStore):
        self.store = store
        # See LiveTier.__init__ (incl. the duck-typed-store getattr).
        self.auto_compact = getattr(
            getattr(getattr(store, "config", None), "live", None),
            "auto_compact", True)

    @classmethod
    def build(cls, spec: IndexSpec, keys: KeyArray,
              row_ids: Optional[jnp.ndarray]) -> "ShardedTier":
        return cls(ShardedLiveStore.build(keys, row_ids,
                                          spec.to_sharded_config()))

    def apply(self, ins_keys, ins_rows, del_keys) -> None:
        self.store.apply(ins_keys, ins_rows, del_keys, auto_compact=False)

    def execute(self, plan: QueryPlan) -> BatchResult:
        return self.store.execute(plan)

    def scan_ranks(self, queries: KeyArray,
                   sides: jnp.ndarray) -> jnp.ndarray:
        """Global mixed-side ranks across shards.

        Each key's owning shard answers locally; shards before the owner
        hold only smaller keys, so the rank-offset prefix over per-shard
        live counts lifts the local rank to the global one — the same
        merge identity the point/range read path uses, hence the same
        bit-identity to a single-shard oracle.
        """
        owners = self.store.route(queries)
        prefix = self.store.live_prefix()
        sides_np = np.asarray(sides)
        out = np.zeros(owners.shape[0], np.int32)
        for s, shard in enumerate(self.store.shards):
            idx = np.nonzero(owners == s)[0]
            if not len(idx):
                continue
            local = shard.engine.rank_batch(queries[idx],
                                            jnp.asarray(sides_np[idx]))
            out[idx] = np.asarray(local) + int(prefix[s])
        return jnp.asarray(out)

    def maybe_compact(self) -> Optional[str]:
        return self.store.maybe_compact()

    # -- autotuner hooks (tuning/autotune.py) ---------------------------------

    @property
    def current_backend(self) -> str:
        return self.store.config.live.rep_method

    def set_backend(self, name: str) -> None:
        """Re-point every shard's rep-stage method together (one scope,
        one compiled pipeline per plan shape across shards) and fold the
        choice into the store config so rebuilt/rebalanced shards
        inherit it."""
        cfg = self.store.config
        if name != cfg.live.rep_method:
            self.store.config = dataclasses.replace(
                cfg, live=dataclasses.replace(cfg.live, rep_method=name))
        for shard in self.store.shards:
            shard.set_rep_method(name)

    @property
    def bucket_size(self) -> int:
        return self.store.config.live.snapshot_bucket_size

    def retune_bucket_size(self, bucket_size: int) -> None:
        """Per-shard epoch swaps to the new snapshot geometry; siblings
        keep serving while each shard swaps (same independence as
        per-shard compaction)."""
        cfg = self.store.config
        if bucket_size != cfg.live.snapshot_bucket_size:
            self.store.config = dataclasses.replace(
                cfg, live=dataclasses.replace(
                    cfg.live, snapshot_bucket_size=bucket_size))
        for shard in self.store.shards:
            shard.retune_bucket_size(bucket_size)

    def sync(self) -> None:
        self.store.sync()

    @property
    def epoch(self) -> int:
        return self.store.epoch

    def stats(self) -> Stats:
        s: store_metrics.ShardedStats = self.store.stats()
        return Stats(tier=self.tier, live_keys=s.live_keys,
                     epoch=max(s.epochs), num_shards=s.num_shards,
                     num_buckets=sum(sh.num_buckets for sh in s.shards),
                     max_chain=s.max_chain, total_bytes=s.total_bytes,
                     applies=s.applies, inserts=s.inserts,
                     deletes=s.deletes, compactions=s.compactions,
                     compacting=s.compacting, detail=s)

    def nbytes(self) -> dict:
        s = self.store.stats()
        return {"store_bytes": sum(sh.store_bytes for sh in s.shards),
                "snapshot_bytes": sum(sh.snapshot_bytes for sh in s.shards),
                "total_bytes": s.total_bytes}


# ---------------------------------------------------------------------------
# Construction.
# ---------------------------------------------------------------------------

_TIER_CLASSES = {"static": StaticTier, "live": LiveTier,
                 "sharded": ShardedTier}


def build_tier(spec: IndexSpec, keys: KeyArray,
               row_ids: Optional[jnp.ndarray] = None) -> IndexTier:
    """Build the tier an ``IndexSpec`` names over a key/rowID set.

    Scalar specs only: a ``kind='vector'`` spec takes an embedding
    corpus, not a key set — route it through ``repro.db.open`` (which
    builds via ``repro.vector.build_vector_tier``)."""
    if spec.kind == "vector":
        raise InvalidSpecError(
            "build_tier is the scalar construction path; open a "
            "kind='vector' spec through repro.db.open(spec, vectors) "
            "(repro.vector.build_vector_tier underneath)")
    if row_ids is None:
        row_ids = jnp.arange(keys.shape[0], dtype=jnp.int32)
    return _TIER_CLASSES[spec.tier].build(spec, keys, row_ids)


def _adopt(store) -> IndexTier:
    """Adopt an already-built store object as a tier (no deprecation
    side-channel — the internal path shims like ``store.LiveFrontend``
    ride; their own deprecation warning already covers the call).
    Duck-typed fallback mirrors the old frontend's contract."""
    if isinstance(store, ShardedLiveStore):
        return ShardedTier(store)
    if isinstance(store, LiveIndex):
        return LiveTier(store)
    if hasattr(store, "shards"):          # sharded-shaped duck
        return ShardedTier(store)
    if hasattr(store, "apply"):           # live-shaped duck
        return LiveTier(store)
    if isinstance(store, cgrx.CgrxIndex):
        return StaticTier(store)
    raise TypeError(f"cannot adopt {type(store).__name__} as an IndexTier")


def wrap_store(store) -> IndexTier:
    """Adopt an already-built store object as a tier.

    Deprecated for updatable (durable-capable) stores: a bare-store
    adoption has no ``wal_dir``, so the resulting tier is memory-only
    and invisible to recovery — the lifecycle front door is
    ``repro.db.open(IndexSpec(durability=..., wal_dir=...))``.  Static
    snapshots adopt without complaint (nothing to log).
    """
    if not isinstance(store, cgrx.CgrxIndex) and (
            isinstance(store, (LiveIndex, ShardedLiveStore))
            or hasattr(store, "apply")):
        warn_once(
            "db.wrap_store",
            "wrap_store() adoption of an updatable store is deprecated: "
            "the adopted tier is memory-only (no wal_dir, so nothing is "
            "logged and recovery cannot see it); open it through "
            "repro.db.open(IndexSpec(durability='wal'|'wal+snapshot', "
            "wal_dir=...)) for a durable session")
    return _adopt(store)


# ---------------------------------------------------------------------------
# Durability: WAL attachment, snapshots, recovery.
# ---------------------------------------------------------------------------

def _wal_root(spec: IndexSpec) -> str:
    return os.path.join(spec.wal_dir, "wal")


def _shard_wal_dirs(spec: IndexSpec) -> List[str]:
    return [os.path.join(_wal_root(spec), f"shard-{i:04d}")
            for i in range(spec.shards)]


def _snapshot_dir(spec: IndexSpec) -> str:
    return os.path.join(spec.wal_dir, "snapshots")


def has_durable_state(spec: IndexSpec) -> bool:
    """True when ``spec.wal_dir`` already holds a recoverable store
    (i.e. at least one committed snapshot — every durable open writes a
    baseline snapshot before accepting traffic, so this is the
    existence test ``repro.db.open`` gates ``recover=`` on)."""
    d = _snapshot_dir(spec)
    if not os.path.isdir(d):
        return False
    return CheckpointManager(d, keep=2).latest_step() is not None


def _keys_from_state(state: dict, prefix: str) -> KeyArray:
    return KeyArray(state[prefix + "_lo"], state.get(prefix + "_hi"))


def _state_and_meta(spec: IndexSpec, tier, seq: int):
    """One flat dict pytree (the checkpoint payload) + the manifest meta
    that describes how to rebuild it.  The payload is the LOGICAL live
    cut (sorted keys/rows per store, splitters for the sharded tier),
    not the physical slab — restore bulk-loads exactly like an epoch
    swap, so recovered query results cannot depend on layout."""
    if tier.tier == "live":
        keys, rows = tier.live.live_cut()
        state = {"keys_lo": keys.lo, "rows": rows}
        if keys.is64:
            state["keys_hi"] = keys.hi
        meta = {"kind": "live", "seq": seq, "is64": keys.is64,
                "epoch": tier.live.epoch,
                "counters": tier.live.counter_state()}
    else:
        store = tier.store
        sp = store.splitters
        state = {"splitters_lo": sp.lo}
        if sp.is64:
            state["splitters_hi"] = sp.hi
        cuts = store.shard_cuts()
        for i, (keys, rows) in enumerate(cuts):
            state[f"s{i:04d}_keys_lo"] = keys.lo
            if keys.is64:
                state[f"s{i:04d}_keys_hi"] = keys.hi
            state[f"s{i:04d}_rows"] = rows
        meta = {"kind": "sharded", "seq": seq, "is64": sp.is64,
                "num_shards": store.num_shards,
                "epochs": [s.epoch for s in store.shards],
                "shard_counters": [s.counter_state()
                                   for s in store.shards],
                "counters": store.counter_state()}
    meta["state_keys"] = sorted(state)
    return state, meta


class DurabilityManager:
    """Owner of one durable store's on-disk lifecycle (see module doc).

    ``attach`` wires WriteAheadLogs onto the tier's store objects (so
    every ``apply`` hits disk before the device) and starts the primary
    heartbeat; ``snapshot`` persists a consistent cut through the async
    checkpoint manager at the current WAL position; ``finish_pending``
    joins the background write and only THEN prunes the log segments
    the committed snapshot covers (pruning before the rename would
    leave a crash window with neither snapshot nor log).
    """

    def __init__(self, spec: IndexSpec, *, heartbeat_interval: float = 5.0,
                 bus=None):
        self.spec = spec
        self.checkpoints = CheckpointManager(_snapshot_dir(spec), keep=2)
        self.auto_snapshot = spec.durability == "wal+snapshot"
        # The session's TelemetryBus (when it has one): the primary
        # heartbeat then reports each beat onto the bus event ring.
        self.heartbeat = Heartbeat(os.path.join(spec.wal_dir, "primary.hb"),
                                   interval=heartbeat_interval, bus=bus)
        self._wals: List[wal_mod.WriteAheadLog] = []
        self._pending_prune: Optional[int] = None
        self._started = False

    # -- wiring ---------------------------------------------------------------

    def attach(self, tier) -> None:
        """Attach WALs to the tier's stores (fresh segments — never
        appends after a possibly-torn tail) and start the beacon."""
        if tier.tier == "live":
            tier.live.wal = wal_mod.WriteAheadLog(_wal_root(self.spec))
            self._wals = [tier.live.wal]
        elif tier.tier == "sharded":
            tier.store.wals = [wal_mod.WriteAheadLog(d)
                               for d in _shard_wal_dirs(self.spec)]
            self._wals = list(tier.store.wals)
            tier.store.wal_seq = max(
                [w.next_seq for w in self._wals], default=0)
        else:
            raise RecoveryError(
                f"tier {tier.tier!r} takes no writes; nothing to attach "
                f"a WAL to")
        self.heartbeat.start()
        self._started = True
        self.beat(tier)

    def applied_seq(self, tier) -> int:
        """The next WAL sequence number — every record below it has been
        applied to the tier (the snapshot/beacon position)."""
        return (tier.live.wal.next_seq if tier.tier == "live"
                else tier.store.wal_seq)

    # -- snapshots ------------------------------------------------------------

    def snapshot(self, tier, *, wait: bool = False) -> int:
        """Persist a consistent cut at the current WAL position via the
        async checkpoint manager; returns the covered sequence number.
        The previous snapshot's write is joined first (the manager is
        single-slot), and the covered log tail is pruned only after its
        commit (``finish_pending``)."""
        self.finish_pending()
        seq = self.applied_seq(tier)
        state, meta = _state_and_meta(self.spec, tier, seq)
        try:
            self.checkpoints.save_async(seq, state, meta)
        except OSError as e:
            raise RecoveryError(
                f"snapshot at seq {seq} failed: {e}") from e
        self._pending_prune = seq
        if wait:
            self.finish_pending()
        return seq

    def finish_pending(self) -> None:
        """Join the in-flight snapshot write, then prune WAL segments it
        made redundant (every record with seq < the snapshot's)."""
        self.checkpoints.wait()
        if self._pending_prune is not None:
            for w in self._wals:
                w.prune(self._pending_prune - 1)
            self._pending_prune = None

    # -- heartbeat ------------------------------------------------------------

    def beat(self, tier) -> None:
        """Publish the primary's WAL position + epoch (one beat per
        flush; replicas measure lag against this beacon)."""
        seq = self.applied_seq(tier)
        self.heartbeat.write_now(step=seq,
                                 payload={"seq": seq, "epoch": tier.epoch})

    # -- teardown -------------------------------------------------------------

    def close(self, tier) -> None:
        """Session-close contract: join the pending snapshot, seal every
        WAL segment (fsynced), publish a final beat, stop the beacon."""
        self.finish_pending()
        for w in self._wals:
            w.seal()
        if self._started:
            self.beat(tier)
            self.heartbeat.stop()
            self._started = False


def recover_tier(spec: IndexSpec):
    """Rebuild the tier ``spec`` describes from its ``wal_dir``: restore
    the newest committed snapshot, then replay the WAL tail (records at
    or past the snapshot's sequence number) through the same
    apply-then-policy step a session flush runs, so the recovered store
    answers bit-identically to the uncrashed one.

    Returns ``(tier, applied_seq)``.  The tier comes back WITHOUT a WAL
    attached — the writer path (``repro.db.open(recover=True)``)
    attaches fresh segments afterwards; replicas (store/replica.py) call
    this repeatedly and never attach.
    """
    ckpt = CheckpointManager(_snapshot_dir(spec), keep=2)
    step = ckpt.latest_step()
    if step is None:
        raise RecoveryError(
            f"no snapshot to recover from in {spec.wal_dir!r} (pass "
            f"keys= to repro.db.open to initialize a fresh store)")
    try:
        manifest = ckpt.read_manifest(step)
        meta = manifest["meta"]
        state, _ = ckpt.restore(step, {k: 0 for k in meta["state_keys"]})
    except (OSError, ValueError, KeyError) as e:
        raise RecoveryError(
            f"snapshot step {step} in {spec.wal_dir!r} is unreadable: "
            f"{e}") from e
    if meta["kind"] != spec.tier:
        raise RecoveryError(
            f"snapshot in {spec.wal_dir!r} holds a {meta['kind']!r} "
            f"store but the spec says tier={spec.tier!r}")
    seq = int(meta["seq"])

    if spec.tier == "live":
        live = LiveIndex.from_cut(
            _keys_from_state(state, "keys"), state["rows"],
            spec.to_live_config(), epoch=int(meta["epoch"]),
            counters=meta["counters"])
        tier = LiveTier(live)
        try:
            records, _ = wal_mod.read_records(_wal_root(spec), seq)
        except wal_mod.WalError as e:
            raise RecoveryError(f"WAL in {spec.wal_dir!r} is corrupt: "
                                f"{e}") from e
        for rec in records:
            live.apply(rec.ins_keys(), rec.ins_row_array(),
                       rec.del_keys(), auto_compact=False)
            if spec.auto_compact:
                live.maybe_compact()
            seq = rec.seq + 1
        return tier, seq

    num_shards = int(meta["num_shards"])
    if num_shards != spec.shards:
        raise RecoveryError(
            f"snapshot in {spec.wal_dir!r} has {num_shards} shards but "
            f"the spec says shards={spec.shards}")
    cuts = [(_keys_from_state(state, f"s{i:04d}_keys"),
             state[f"s{i:04d}_rows"]) for i in range(num_shards)]
    store = ShardedLiveStore.from_cuts(
        cuts, _keys_from_state(state, "splitters"),
        spec.to_sharded_config(),
        epochs=[int(e) for e in meta["epochs"]],
        shard_counters=meta["shard_counters"],
        counters=meta["counters"])
    tier = ShardedTier(store)
    try:
        groups = wal_mod.read_groups(_shard_wal_dirs(spec), seq)
    except wal_mod.WalError as e:
        raise RecoveryError(f"WAL in {spec.wal_dir!r} is corrupt: "
                            f"{e}") from e
    for parts in groups:
        # Re-assemble the store-level batch and route it afresh: the
        # snapshot's splitters evolve deterministically under replay
        # (rebalance triggers on live counts, which the log reproduces),
        # so routing lands where the original run put things.
        ins_k = [r.ins_keys() for _, r in parts if r.n_ins]
        ins_r = [r.ins_row_array() for _, r in parts if r.n_ins]
        del_k = [r.del_keys() for _, r in parts if r.n_del]
        store.apply(
            _concat_keys_list(ins_k),
            jnp.concatenate(ins_r) if ins_r else None,
            _concat_keys_list(del_k),
            auto_compact=False)
        if spec.auto_compact:
            store.maybe_compact()
        seq = parts[0][1].seq + 1
    store.wal_seq = seq
    return tier, seq


def _concat_keys_list(parts: List[KeyArray]) -> Optional[KeyArray]:
    if not parts:
        return None
    out = parts[0]
    for p in parts[1:]:
        out = concat_keys(out, p)
    return out
