"""The ``IndexTier`` protocol and its three implementations.

A tier is the deployment-level backing of a ``Session``: it knows how to
serve one planned mixed batch (``execute``), absorb one mixed write batch
(``apply``), answer raw rank queries (``scan_ranks``), evaluate its
maintenance policy (``maybe_compact``), fence device work (``sync``), and
report itself through ONE unified ``Stats``/``nbytes`` shape regardless
of what machinery sits underneath.

``execute`` is the tier's PLAN-LEVEL hook: it takes the full physical
``QueryPlan`` the logical-plan compiler fused — point lanes, materializing
ranges AND rank-only aggregate ranges — and must serve every section.
The static and live tiers hand the plan to one ``RankEngine`` call; the
sharded tier decomposes it at the splitters (points to owners, range and
aggregate spans to their intersecting shards) and merges per-fragment:
row blocks concatenate in shard order, aggregates merge by sum (counts)
and min/max (endpoint keys) — see ``store/sharded.py``.

    StaticTier    immutable ``CgrxIndex`` + ``RankEngine`` — rejects
                  writes with ``ReadOnlyTierError`` at apply time
    LiveTier      ``store.LiveIndex`` (epoch snapshot + chain delta)
    ShardedTier   ``store.ShardedLiveStore`` (splitter-routed shards);
                  rank queries merge with the same rank-offset prefix
                  the read path uses, so global ranks stay bit-identical
                  to a single-shard oracle

``build_tier`` constructs a tier from an ``IndexSpec``; ``wrap_store``
adopts an already-built ``LiveIndex``/``ShardedLiveStore`` (the
compatibility path ``store.LiveFrontend`` rides on).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cgrx
from repro.core.keys import KeyArray
from repro.query import BatchResult, QueryPlan, RankEngine
from repro.store import metrics as store_metrics
from repro.store.live import LiveIndex
from repro.store.sharded import ShardedLiveStore

from .errors import ReadOnlyTierError
from .spec import IndexSpec


@dataclasses.dataclass(frozen=True)
class Stats:
    """One stats shape for every tier (the operator's dashboard row).

    ``detail`` carries the tier-native snapshot (``None`` for static,
    ``store.LiveStats`` for live, ``store.ShardedStats`` for sharded)
    for callers that need tier-specific depth — everything above it is
    tier-independent.
    """

    tier: str
    live_keys: int
    epoch: int
    num_shards: int            # 1 unless sharded
    num_buckets: int           # summed across shards
    max_chain: int             # 1 for the flat static tier
    total_bytes: int
    applies: int
    inserts: int
    deletes: int
    compactions: int
    compacting: bool
    detail: object = None


@runtime_checkable
class IndexTier(Protocol):
    """What a ``Session`` needs from its backing tier.

    ``execute`` serves one fused physical plan INCLUDING its aggregate
    section (``plan.n_agg``/``plan.agg_keys``) — a tier that ignored the
    section would strand aggregate tickets, so the cross-tier parity
    suite pins all three implementations against one oracle.
    ``auto_compact`` gates the session's per-flush policy step: with it
    off, ``flush()`` never takes an epoch-swap pause and maintenance
    timing belongs to the caller.
    """

    tier: str
    writable: bool
    auto_compact: bool

    def execute(self, plan: QueryPlan) -> BatchResult: ...

    def scan_ranks(self, queries: KeyArray,
                   sides: jnp.ndarray) -> jnp.ndarray: ...

    def apply(self, ins_keys: Optional[KeyArray],
              ins_rows: Optional[jnp.ndarray],
              del_keys: Optional[KeyArray]) -> None: ...

    def maybe_compact(self) -> Optional[str]: ...

    def sync(self) -> None: ...

    @property
    def epoch(self) -> int: ...

    def stats(self) -> Stats: ...

    def nbytes(self) -> dict: ...


# ---------------------------------------------------------------------------
# Static: immutable CgrxIndex behind the rank engine.
# ---------------------------------------------------------------------------

class StaticTier:
    """Read-only tier over an immutable ``CgrxIndex``."""

    tier = "static"
    writable = False
    auto_compact = False          # nothing to compact, ever

    def __init__(self, index: cgrx.CgrxIndex, *, jit: bool = True,
                 cache_scope: Optional[str] = None):
        self.index = index
        self.engine = RankEngine(index, jit=jit, cache_scope=cache_scope)

    @classmethod
    def build(cls, spec: IndexSpec, keys: KeyArray,
              row_ids: Optional[jnp.ndarray]) -> "StaticTier":
        index = cgrx.build(keys, row_ids, spec.bucket_size,
                           method=spec.backend)
        return cls(index, jit=spec.jit, cache_scope=spec.cache_scope)

    def execute(self, plan: QueryPlan) -> BatchResult:
        return self.engine.execute(plan)

    def scan_ranks(self, queries: KeyArray,
                   sides: jnp.ndarray) -> jnp.ndarray:
        return self.engine.rank_batch(queries, sides)

    def apply(self, ins_keys, ins_rows, del_keys) -> None:
        n_ins = int(ins_keys.shape[0]) if ins_keys is not None else 0
        n_del = int(del_keys.shape[0]) if del_keys is not None else 0
        raise ReadOnlyTierError(
            f"static tier rejects writes ({n_ins} inserts, {n_del} "
            f"deletes submitted); re-open with IndexSpec(tier='live') or "
            f"tier='sharded' for an updatable index")

    def maybe_compact(self) -> Optional[str]:
        return None

    def sync(self) -> None:
        jax.block_until_ready(self.index.buckets.keys.lo)

    @property
    def epoch(self) -> int:
        return 0

    def stats(self) -> Stats:
        return Stats(tier=self.tier, live_keys=self.index.n, epoch=0,
                     num_shards=1, num_buckets=self.index.num_buckets,
                     max_chain=1,
                     total_bytes=self.nbytes()["total_bytes"],
                     applies=0, inserts=0, deletes=0, compactions=0,
                     compacting=False, detail=None)

    def nbytes(self) -> dict:
        return cgrx.index_nbytes(self.index)


# ---------------------------------------------------------------------------
# Live: one epoch-versioned LiveIndex.
# ---------------------------------------------------------------------------

class LiveTier:
    """Updatable tier over a single ``store.LiveIndex``."""

    tier = "live"
    writable = True

    def __init__(self, live: LiveIndex):
        self.live = live
        # Plain attribute (configs are frozen): overridable by adopters
        # like the LiveFrontend shim, whose historical contract runs the
        # policy every tick regardless of the store's own knob.  getattr
        # because wrap_store also adopts duck-typed stores with no
        # config (the old frontend's contract).
        self.auto_compact = getattr(getattr(live, "config", None),
                                    "auto_compact", True)

    @classmethod
    def build(cls, spec: IndexSpec, keys: KeyArray,
              row_ids: Optional[jnp.ndarray]) -> "LiveTier":
        return cls(LiveIndex.build(keys, row_ids, spec.to_live_config()))

    # Session drives the policy itself (after the write step, timed), so
    # apply never auto-compacts here.
    def apply(self, ins_keys, ins_rows, del_keys) -> None:
        self.live.apply(ins_keys, ins_rows, del_keys, auto_compact=False)

    def execute(self, plan: QueryPlan) -> BatchResult:
        return self.live.execute(plan)

    def scan_ranks(self, queries: KeyArray,
                   sides: jnp.ndarray) -> jnp.ndarray:
        return self.live.engine.rank_batch(queries, sides)

    def maybe_compact(self) -> Optional[str]:
        return self.live.maybe_compact()

    def sync(self) -> None:
        self.live.sync()

    @property
    def epoch(self) -> int:
        return self.live.epoch

    def stats(self) -> Stats:
        s = self.live.stats()
        return Stats(tier=self.tier, live_keys=s.live_keys, epoch=s.epoch,
                     num_shards=1, num_buckets=s.num_buckets,
                     max_chain=s.max_chain, total_bytes=s.total_bytes,
                     applies=s.applies, inserts=s.inserts,
                     deletes=s.deletes, compactions=s.compactions,
                     compacting=s.compacting, detail=s)

    def nbytes(self) -> dict:
        s = self.live.stats()
        return {"store_bytes": s.store_bytes,
                "snapshot_bytes": s.snapshot_bytes,
                "total_bytes": s.total_bytes}


# ---------------------------------------------------------------------------
# Sharded: S splitter-routed LiveIndex shards.
# ---------------------------------------------------------------------------

class ShardedTier:
    """Updatable range-partitioned tier over a ``ShardedLiveStore``."""

    tier = "sharded"
    writable = True

    def __init__(self, store: ShardedLiveStore):
        self.store = store
        # See LiveTier.__init__ (incl. the duck-typed-store getattr).
        self.auto_compact = getattr(
            getattr(getattr(store, "config", None), "live", None),
            "auto_compact", True)

    @classmethod
    def build(cls, spec: IndexSpec, keys: KeyArray,
              row_ids: Optional[jnp.ndarray]) -> "ShardedTier":
        return cls(ShardedLiveStore.build(keys, row_ids,
                                          spec.to_sharded_config()))

    def apply(self, ins_keys, ins_rows, del_keys) -> None:
        self.store.apply(ins_keys, ins_rows, del_keys, auto_compact=False)

    def execute(self, plan: QueryPlan) -> BatchResult:
        return self.store.execute(plan)

    def scan_ranks(self, queries: KeyArray,
                   sides: jnp.ndarray) -> jnp.ndarray:
        """Global mixed-side ranks across shards.

        Each key's owning shard answers locally; shards before the owner
        hold only smaller keys, so the rank-offset prefix over per-shard
        live counts lifts the local rank to the global one — the same
        merge identity the point/range read path uses, hence the same
        bit-identity to a single-shard oracle.
        """
        owners = self.store.route(queries)
        prefix = self.store.live_prefix()
        sides_np = np.asarray(sides)
        out = np.zeros(owners.shape[0], np.int32)
        for s, shard in enumerate(self.store.shards):
            idx = np.nonzero(owners == s)[0]
            if not len(idx):
                continue
            local = shard.engine.rank_batch(queries[idx],
                                            jnp.asarray(sides_np[idx]))
            out[idx] = np.asarray(local) + int(prefix[s])
        return jnp.asarray(out)

    def maybe_compact(self) -> Optional[str]:
        return self.store.maybe_compact()

    def sync(self) -> None:
        self.store.sync()

    @property
    def epoch(self) -> int:
        return self.store.epoch

    def stats(self) -> Stats:
        s: store_metrics.ShardedStats = self.store.stats()
        return Stats(tier=self.tier, live_keys=s.live_keys,
                     epoch=max(s.epochs), num_shards=s.num_shards,
                     num_buckets=sum(sh.num_buckets for sh in s.shards),
                     max_chain=s.max_chain, total_bytes=s.total_bytes,
                     applies=s.applies, inserts=s.inserts,
                     deletes=s.deletes, compactions=s.compactions,
                     compacting=s.compacting, detail=s)

    def nbytes(self) -> dict:
        s = self.store.stats()
        return {"store_bytes": sum(sh.store_bytes for sh in s.shards),
                "snapshot_bytes": sum(sh.snapshot_bytes for sh in s.shards),
                "total_bytes": s.total_bytes}


# ---------------------------------------------------------------------------
# Construction.
# ---------------------------------------------------------------------------

_TIER_CLASSES = {"static": StaticTier, "live": LiveTier,
                 "sharded": ShardedTier}


def build_tier(spec: IndexSpec, keys: KeyArray,
               row_ids: Optional[jnp.ndarray] = None) -> IndexTier:
    """Build the tier an ``IndexSpec`` names over a key/rowID set."""
    if row_ids is None:
        row_ids = jnp.arange(keys.shape[0], dtype=jnp.int32)
    return _TIER_CLASSES[spec.tier].build(spec, keys, row_ids)


def wrap_store(store) -> IndexTier:
    """Adopt an already-built store object as a tier (the compatibility
    path: ``store.LiveFrontend`` hands its LiveIndex/ShardedLiveStore
    here).  Duck-typed fallback mirrors the old frontend's contract."""
    if isinstance(store, ShardedLiveStore):
        return ShardedTier(store)
    if isinstance(store, LiveIndex):
        return LiveTier(store)
    if hasattr(store, "shards"):          # sharded-shaped duck
        return ShardedTier(store)
    if hasattr(store, "apply"):           # live-shaped duck
        return LiveTier(store)
    if isinstance(store, cgrx.CgrxIndex):
        return StaticTier(store)
    raise TypeError(f"cannot adopt {type(store).__name__} as an IndexTier")
