"""``repro.db`` — one front door over static, live, and sharded indexes.

The paper's pitch is that ONE design change (coarse-granular buckets)
unifies point lookups, range lookups, and updates behind a single index;
this package is the API-level mirror of that claim: one declarative
``IndexSpec`` picks the deployment tier, ``open()`` builds it, and the
returned ``Session`` is the single typed surface every caller programs
against — benchmarks, examples, serving.  The tiering ladder
(static -> live -> sharded) is a spec knob, not a code path::

    import repro.db as db

    sess = db.open(db.IndexSpec(tier="live"), keys, row_ids)
    t = sess.lookup(queries)          # future-style Ticket
    sess.insert(new_keys, new_rows)   # writes batch with everything else
    rng = sess.range(lo, hi)
    rep = sess.flush()                # ONE device dispatch per op class
    res, rows = t.result(), rng.result()

Beyond the flat verbs, ``Session.query`` takes composable logical-plan
expressions (``repro.query.plan``, re-exported here): ``eq`` /
``between`` / ``isin`` (IN-lists, deduplicated) / ``limit`` (per-range
hit caps) / ``count`` / ``min_key`` / ``max_key`` (rank-only range
aggregates) / ``probe`` (index nested-loop join probes) / ``rank_scan``
— a whole flush's trees compile onto ONE physical plan per op class::

    t = sess.query(db.count(db.between(lo, hi)))   # no rowID gather
    j = sess.query(db.probe(keys, outer_rows))     # join probe
    sess.flush()                                   # still one dispatch

The same front door opens the coarse-bucket ANN tier (``repro.vector``):
``IndexSpec(kind='vector', dim=, ncentroids=, nprobe=)`` with an
(n, dim) embedding corpus returns a ``VectorSession`` whose
``probe_vectors(queries, k)`` lowers onto the same plan IR — probes
coalesce with every other ticket of a flush, and the only extra launch
is the exact ``distance_topk`` post-filter.

Layering: ``core`` (index math) -> ``query`` (batched rank engine +
logical-plan compiler) -> ``store`` (live/sharded lifecycles) -> ``db``
(this package).  Module map: ``spec`` (IndexSpec), ``tiers`` (IndexTier
protocol + the three implementations, unified ``Stats``), ``session``
(Session/Ticket/FlushReport), ``errors`` (typed errors).  See
docs/ARCHITECTURE.md ("Public API", "Query plans").
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

# Re-exported so spec construction needs only `import repro.db`.
from repro.core.keys import KeyArray
from repro.query.plan import (AggKeys, Expr, ProbeResult, between, count,
                              eq, isin, limit, max_key, min_key, postmap,
                              probe, rank_scan)
from repro.store.compaction import CompactionPolicy

from repro.store.replica import ReadReplica, ReplicaSet
# Adaptive runtime (no import cycle: repro.tuning never imports repro.db
# at module scope — its OverloadError import is lazy, inside check_admit).
from repro.tuning import AdmissionController, AutoTuner, TelemetryBus

from .errors import (DbError, DroppedTicketError, InvalidSpecError,
                     OverloadError, ReadOnlyTierError, RecoveryError,
                     SessionClosedError, StaleReplicaError)
from .session import FlushReport, Session, Ticket
from .spec import IndexSpec
from .tiers import (DurabilityManager, IndexTier, LiveTier, ShardedTier,
                    Stats, StaticTier, build_tier, has_durable_state,
                    recover_tier, wrap_store)

__all__ = [
    "AggKeys",
    "CompactionPolicy",
    "DbError",
    "DroppedTicketError",
    "DurabilityManager",
    "Expr",
    "FlushReport",
    "IndexSpec",
    "IndexTier",
    "InvalidSpecError",
    "KeyArray",
    "LiveTier",
    "OverloadError",
    "ProbeResult",
    "ReadOnlyTierError",
    "ReadReplica",
    "RecoveryError",
    "ReplicaSet",
    "Session",
    "SessionClosedError",
    "ShardedTier",
    "StaleReplicaError",
    "Stats",
    "StaticTier",
    "Ticket",
    "as_key_array",
    "between",
    "build_tier",
    "count",
    "eq",
    "has_durable_state",
    "isin",
    "limit",
    "max_key",
    "min_key",
    "open",
    "postmap",
    "probe",
    "rank_scan",
    "recover_tier",
    "wrap_store",
]


def as_key_array(keys) -> KeyArray:
    """Coerce host key containers to ``KeyArray`` (uint64 -> packed
    (hi, lo) pairs, uint32 -> single-word keys); passes KeyArrays
    through untouched."""
    if isinstance(keys, KeyArray):
        return keys
    arr = np.asarray(keys)
    if arr.dtype == np.uint32:
        return KeyArray.from_u32(arr)
    if arr.dtype == np.uint64:
        return KeyArray.from_u64(arr)
    raise TypeError(
        f"keys must be a KeyArray or a uint32/uint64 array, got "
        f"dtype {arr.dtype}")


def _adaptive_runtime(spec: IndexSpec, tier):
    """The tuning-plane objects ``spec`` asks for (tuning/ package).

    Every opened session gets a ``TelemetryBus`` (a bus nobody reads
    costs a few ring writes per flush — the perf gate holds the hot path
    to the compare.py threshold with it on).  The controllers are strictly
    opt-in: an ``AdmissionController`` only when ``slo_ms`` or
    ``max_pending`` is set, an ``AutoTuner`` only under ``autotune=True``
    — so a default spec keeps the session's flush behavior bit-identical
    to the historical one (pinned in tests/test_tuning.py).
    """
    bus = TelemetryBus()
    admission = None
    if spec.slo_ms is not None or spec.max_pending is not None:
        admission = AdmissionController(bus, slo_ms=spec.slo_ms,
                                        max_pending=spec.max_pending)
    autotuner = None
    if spec.autotune:
        autotuner = AutoTuner(tier, bus,
                              max_imbalance=spec.max_imbalance,
                              rebalance_mode=spec.rebalance_mode,
                              migrate_max_keys=spec.migrate_max_keys)
    return bus, admission, autotuner


def open(spec: Optional[IndexSpec] = None, keys=None, row_ids=None,
         *, recover: bool = False) -> Session:   # noqa: A001 - front door
    """Build (or recover) the tier ``spec`` describes and return the
    ``Session`` serving it.

    ``spec`` defaults to ``IndexSpec()`` (a live tier with the paper's
    recommended geometry).  ``keys`` may be a ``KeyArray`` or a host
    uint32/uint64 array; ``row_ids`` defaults to positions.

    Durable specs (``durability='wal'``/``'wal+snapshot'`` with a
    ``wal_dir``) add the recovery contract:

      * fresh open (``recover=False``): ``wal_dir`` must not already
        hold a store (``RecoveryError`` otherwise — a silent re-init
        would orphan the existing log); a baseline snapshot is written
        synchronously before the session accepts traffic, so the store
        is recoverable from its first write on.
      * ``recover=True``: resume the store in ``wal_dir`` — newest
        snapshot + WAL-tail replay; ``keys`` must be omitted (the log
        is the source of truth).  When ``wal_dir`` is still empty,
        ``keys`` bootstraps a fresh store instead (open-or-create).

    Sessions are context managers — prefer ``with repro.db.open(...)
    as sess:`` so pending tickets flush and the WAL segment seals on
    exit (see ``Session.close``).
    """
    spec = spec or IndexSpec()
    if spec.kind == "vector":
        # The ANN tier: `keys` is the (n, dim) float32 embedding corpus;
        # spec validation already rejected durable vector specs, so this
        # branch is memory-only by construction.
        if recover:
            raise InvalidSpecError(
                "recover=True needs a durable spec, and vector specs "
                "are memory-only for now (the WAL logs keys, not "
                "embeddings)")
        if keys is None:
            raise ValueError(
                "repro.db.open with kind='vector' needs an (n, dim) "
                "embedding corpus to index")
        from repro.vector import VectorSession, build_vector_tier
        tier = build_vector_tier(spec, keys, row_ids)
        bus, admission, autotuner = _adaptive_runtime(spec, tier)
        return VectorSession(tier, max_hits=spec.max_hits,
                             nprobe=spec.effective_nprobe, bus=bus,
                             admission=admission, autotuner=autotuner)
    if not spec.durable:
        if recover:
            raise InvalidSpecError(
                "recover=True needs a durable spec: IndexSpec("
                "durability='wal' or 'wal+snapshot', wal_dir=...)")
        if keys is None:
            raise ValueError("repro.db.open needs a key set to index")
        karr = as_key_array(keys)
        rows = None if row_ids is None else jnp.asarray(row_ids, jnp.int32)
        tier = build_tier(spec, karr, rows)
        bus, admission, autotuner = _adaptive_runtime(spec, tier)
        return Session(tier, max_hits=spec.max_hits, bus=bus,
                       admission=admission, autotuner=autotuner)

    existing = has_durable_state(spec)
    if existing and not recover:
        raise RecoveryError(
            f"wal_dir {spec.wal_dir!r} already holds a durable store; "
            f"pass recover=True to resume it, or point wal_dir at a "
            f"fresh directory")
    if existing:
        if keys is not None:
            raise InvalidSpecError(
                "recover=True resumes the store already in wal_dir; "
                "a key set cannot also be supplied (the WAL is the "
                "source of truth)")
        tier, _ = recover_tier(spec)
    else:
        if keys is None:
            raise RecoveryError(
                f"nothing to recover in {spec.wal_dir!r} and no keys "
                f"to initialize a fresh store from")
        karr = as_key_array(keys)
        rows = None if row_ids is None else jnp.asarray(row_ids, jnp.int32)
        tier = build_tier(spec, karr, rows)
    bus, admission, autotuner = _adaptive_runtime(spec, tier)
    manager = DurabilityManager(spec, bus=bus)
    manager.attach(tier)
    # Baseline snapshot (synchronous): recovery = snapshot + WAL tail,
    # so a snapshot must exist before the first logged write.
    manager.snapshot(tier, wait=True)
    return Session(tier, max_hits=spec.max_hits, durability=manager,
                   bus=bus, admission=admission, autotuner=autotuner)
