"""``EmbeddingArena``: the device-resident vector payload store.

The vector tier (``repro.vector``) keeps the INDEX small — each embedding
contributes one composite (centroidID, rowID) key to the scalar rank
engine — and parks the embeddings themselves here: one flat (capacity,
dim) float32 device buffer addressed by rowID.  Retrieval gathers
candidate embeddings straight out of this buffer for the
``distance_topk`` post-filter, so probe batches never touch the host.

Updates follow the store package's epoch discipline in miniature:
``add`` is a functional ``.at[rows].set`` producing a fresh buffer (the
old one stays valid for in-flight readers until they drop it), and the
buffer grows geometrically so a stream of live inserts costs amortized
O(1) copies.  Slots are never reclaimed on delete — the index simply
stops referencing the rowID, matching how the scalar tiers tombstone —
so ``nbytes`` reports high-water capacity.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


class EmbeddingArena:
    """Flat rowID-addressed (capacity, dim) float32 device buffer."""

    def __init__(self, dim: int, capacity: int = 0):
        if dim <= 0:
            raise ValueError(f"arena dim must be positive, got {dim}")
        self.dim = int(dim)
        self.data = jnp.zeros((int(capacity), self.dim), jnp.float32)
        self._next_row = 0

    @classmethod
    def build(cls, vectors: jnp.ndarray,
              rows: jnp.ndarray) -> "EmbeddingArena":
        """Arena seeded with ``vectors[i]`` at slot ``rows[i]``."""
        vectors = jnp.asarray(vectors, jnp.float32)
        arena = cls(vectors.shape[1])
        arena.add(rows, vectors)
        return arena

    @property
    def capacity(self) -> int:
        return int(self.data.shape[0])

    @property
    def next_row(self) -> int:
        """Smallest rowID never handed out (the ``alloc`` high-water)."""
        return self._next_row

    def alloc(self, n: int) -> np.ndarray:
        """Reserve ``n`` fresh consecutive rowIDs (host-side counter —
        the slots are written by the ``add`` that follows)."""
        rows = np.arange(self._next_row, self._next_row + n, dtype=np.int32)
        self._next_row += n
        return rows

    def _ensure(self, upto: int) -> None:
        if upto <= self.capacity:
            return
        cap = max(16, self.capacity)
        while cap < upto:
            cap *= 2
        grown = jnp.zeros((cap, self.dim), jnp.float32)
        self.data = grown.at[:self.capacity].set(self.data)

    def add(self, rows, vectors) -> None:
        """Write ``vectors[i]`` into slot ``rows[i]`` (grows to fit)."""
        rows = np.asarray(rows, np.int32)
        vectors = jnp.asarray(vectors, jnp.float32)
        if vectors.shape != (rows.shape[0], self.dim):
            raise ValueError(
                f"arena add expects ({rows.shape[0]}, {self.dim}) "
                f"vectors, got {vectors.shape}")
        if rows.shape[0] == 0:
            return
        if rows.min() < 0:
            raise ValueError("arena rowIDs must be non-negative")
        self._ensure(int(rows.max()) + 1)
        self.data = self.data.at[jnp.asarray(rows)].set(vectors)
        self._next_row = max(self._next_row, int(rows.max()) + 1)

    def gather(self, rows: jnp.ndarray) -> jnp.ndarray:
        """Embeddings at ``rows`` (any shape); out-of-range ids (e.g. the
        -1 padding of a range result) clamp to slot 0 — callers mask
        them out by validity, never by content."""
        idx = jnp.clip(jnp.asarray(rows, jnp.int32), 0, self.capacity - 1)
        return jnp.take(self.data, idx, axis=0)

    def nbytes(self) -> int:
        return int(self.data.size * self.data.dtype.itemsize)
