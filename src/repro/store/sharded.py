"""ShardedLiveStore: a range-partitioned live serving tier.

The single-shard ``LiveIndex`` (store/live.py) proves the paper's update
mechanism as a store; this module scales it out the way the static mesh
path (core/distributed.py) scales the immutable index: the key space is
range-partitioned into ``S`` shards by per-shard max-key *splitters*, and
every shard owns a complete ``LiveIndex`` — epoch snapshot + node-chain
delta + its own compaction lifecycle.  The splitter math is imported from
``core.distributed`` so the static read-only tier and this live tier agree
on ownership by construction.

Routing (one successor search over S splitters, host-negligible):

    point k        -> shard route_keys(splitters, k)   (exactly one owner)
    range [l, u]   -> shards route_ranges(...)          (a contiguous span)
    insert/delete  -> same search as points; the LAST shard absorbs keys
                      beyond the last splitter (mirroring how a cgRX last
                      bucket absorbs > maxRep inserts)

Reads: each shard that owns work gets ONE batched engine dispatch per tick
— its points plus every range whose span covers it, coalesced through the
``QueryBatch`` lane planner and served by the chain-aware 'node' backend.
A cross-shard range needs no clamping: a shard only ranks its own keys, so
issuing the full [l, u] to every shard in the span IS the decomposition at
the splitters.  Results merge with a *rank-offset prefix* over shard live
counts: global position = prefix[shard] + local rank, global range start =
prefix[first] + local start, counts add, and row blocks concatenate in
shard order (shards are ordered ranges, so concatenation is sorted order).
That makes every merged result bit-identical to a single-shard oracle over
the same live set (tests/test_sharded_store.py) — found/row_id/position
for points, start/count/row_ids for ranges.

Compaction is per-shard and independent: a hot shard epoch-swaps without
pausing its siblings (their engines, chains and epochs are untouched), and
reads during a shard's in-flight swap serve that shard's current epoch
exactly as in the single-shard store.

Skew: range partitions drift under non-uniform insert streams (a Zipf
head lands on one shard).  The skew monitor compares per-shard fill to the
balanced mean; past ``max_imbalance`` it recomputes equal-count splitters
and migrates boundary buckets through the existing extract→presorted-build
path — per-shard ``nodes.extract`` cuts concatenate (already globally
sorted, shards being ordered ranges) and reload into fresh equal shards.

All shards bind one executable-cache scope (query/engine.py), so S shards
with matching static bounds share ONE compiled pipeline per plan shape.

Unique-key workloads assumed, as everywhere in this repo (paper Sec. 4):
duplicates of a key that straddle a splitter would split ownership.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cgrx, nodes
from repro.core.distributed import (compute_splitters, partition_cuts,
                                    route_keys, route_ranges)
from repro.core.keys import KeyArray, concat_keys, sort_with_payload
from repro.query import BatchResult, QueryBatch, QueryPlan
from repro.query.backends import get_backend
from repro.tuning.telemetry import TouchTracker

from . import metrics
from .live import LiveConfig, LiveIndex

MISS = int(np.int32(cgrx.MISS))

# Routing runs on every read AND write tick; eager ``searchsorted`` would
# re-lower its fori_loop per call, so the router is jitted once here
# (cached per splitter/query shape — a handful of tiny executables).
_route_keys = jax.jit(route_keys)
_route_ranges = jax.jit(route_ranges)


@dataclasses.dataclass(frozen=True)
class ShardedConfig:
    """Partitioning + skew knobs; per-shard behavior lives in ``live``."""

    num_shards: int = 4
    live: LiveConfig = dataclasses.field(default_factory=LiveConfig)
    max_imbalance: Optional[float] = 2.0  # skew trigger: max shard fill
                                          # over balanced mean; None = off
    min_rebalance_keys: int = 256         # never rebalance tiny stores
    auto_rebalance: bool = True           # evaluate skew in maybe_compact
    cache_scope: str = "sharded"          # shared executable-cache scope
    rebalance_mode: str = "full"          # 'full' = stop-and-rebuild
                                          # extract→presorted-build (the
                                          # historical path); 'incremental'
                                          # = bounded migrate_step ticks
    migrate_max_keys: int = 256           # per-tick key budget of one
                                          # incremental migration step
    touch_decay: float = 0.95             # per-batch EWMA decay of the
                                          # per-shard touch histogram


class ShardedLiveStore:
    """Range-partitioned live index: S splitter-routed ``LiveIndex`` shards.

    Usage::

        store = ShardedLiveStore.build(keys, rows, ShardedConfig(num_shards=4))
        store.insert(new_keys, new_rows)       # routed, 1 apply per shard
        store.delete(old_keys)
        res = store.lookup(point_keys)         # global positions
        rng = store.range_lookup(lo, hi, 64)   # cross-shard merge
        store.stats()                          # metrics.ShardedStats
    """

    def __init__(self, shards: List[LiveIndex], splitters: KeyArray,
                 config: ShardedConfig):
        if len(shards) != config.num_shards:
            raise ValueError(f"{len(shards)} shards != {config.num_shards}")
        # Fail loudly if the per-shard serving path is mis-wired: every
        # shard read dispatches through a chain-aware ('node') backend.
        get_backend("node", kind="node")
        self.shards = shards
        self.splitters = splitters
        self.config = config
        self.rebalances = 0
        self.migrations = 0           # incremental migrate_step ticks
        self.applies = 0
        self.inserts = 0
        self.deletes = 0
        # Per-shard key-touch EWMA (tuning/telemetry.py): every routed
        # read and write batch bumps its touched shards, so the skew
        # monitor can see a HOT shard even when sizes are balanced.
        self.touch = TouchTracker(config.num_shards,
                                  decay=config.touch_decay)
        # Durability hook (db/tiers.py attaches): one WriteAheadLog per
        # shard, written pre-routed — ``wal_seq`` numbers STORE-level
        # applies, and the per-shard records of one apply share that seq
        # with (part, nparts) markers so recovery can tell a complete
        # group from one torn by a crash mid-fsync-set (store/wal.py).
        self.wals = None
        self.wal_seq = 0
        self._counts: Optional[np.ndarray] = None

    # -- construction ---------------------------------------------------------

    @classmethod
    def build(cls, keys: KeyArray, row_ids: Optional[jnp.ndarray] = None,
              config: Optional[ShardedConfig] = None,
              *, presorted: bool = False) -> "ShardedLiveStore":
        cfg = config or ShardedConfig()
        n = keys.shape[0]
        if n < cfg.num_shards:
            raise ValueError(
                f"need >= {cfg.num_shards} keys to build {cfg.num_shards} "
                f"shards, got {n}")
        if row_ids is None:
            row_ids = jnp.arange(n, dtype=jnp.int32)
        if not presorted:
            keys, row_ids = sort_with_payload(keys, row_ids.astype(jnp.int32))
        splitters = compute_splitters(keys, cfg.num_shards)
        shards = _load_shards(keys, row_ids, cfg)
        return cls(shards, splitters, cfg)

    # -- durable cut / restore ------------------------------------------------

    def shard_cuts(self) -> List[Tuple[KeyArray, jnp.ndarray]]:
        """One consistent sorted (keys, rows) cut per shard, in shard
        order — the snapshot payload.  Persisted together with the
        splitters so a restore reconstructs the SAME partitioning the
        per-shard WAL records were routed under."""
        return [s.live_cut() for s in self.shards]

    @classmethod
    def from_cuts(cls, cuts: List[Tuple[KeyArray, jnp.ndarray]],
                  splitters: KeyArray,
                  config: Optional[ShardedConfig] = None, *,
                  epochs: Optional[List[int]] = None,
                  shard_counters: Optional[List[dict]] = None,
                  counters: Optional[dict] = None) -> "ShardedLiveStore":
        """Rebuild a sharded store from persisted ``shard_cuts`` plus
        the manifest's splitters — recovery re-derives ownership from
        the snapshot rather than re-partitioning, so pre-routed WAL
        tails replay onto the shards that logged them."""
        cfg = config or ShardedConfig()
        live_cfg = dataclasses.replace(
            cfg.live, cache_scope=cfg.live.cache_scope or cfg.cache_scope)
        shards = [
            LiveIndex.from_cut(
                k, r, live_cfg,
                epoch=epochs[i] if epochs else 0,
                counters=shard_counters[i] if shard_counters else None)
            for i, (k, r) in enumerate(cuts)]
        store = cls(shards, splitters, cfg)
        for name in ("rebalances", "migrations", "applies", "inserts",
                     "deletes"):
            if counters and name in counters:
                setattr(store, name, int(counters[name]))
        return store

    def counter_state(self) -> dict:
        return {"rebalances": self.rebalances,
                "migrations": self.migrations, "applies": self.applies,
                "inserts": self.inserts, "deletes": self.deletes}

    @property
    def num_shards(self) -> int:
        return self.config.num_shards

    @property
    def epoch(self) -> int:
        """Max shard epoch (shards swap independently; per-shard counters
        are in ``stats().epochs``)."""
        return max(s.epoch for s in self.shards)

    @property
    def live_keys(self) -> int:
        return int(self._live_counts().sum())

    @property
    def compacting(self) -> bool:
        return any(s.compacting for s in self.shards)

    def sync(self) -> None:
        for s in self.shards:
            s.sync()

    # -- routing --------------------------------------------------------------

    def route(self, keys: KeyArray) -> np.ndarray:
        """Owning shard id per key (host array, for batch slicing)."""
        return np.asarray(_route_keys(self.splitters, keys))

    def _live_counts(self) -> np.ndarray:
        """Per-shard live-key counts (one small device sync per shard,
        cached; any write or rebalance invalidates)."""
        if self._counts is None:
            self._counts = np.array([s.live_keys for s in self.shards],
                                    np.int64)
        return self._counts

    def live_prefix(self) -> np.ndarray:
        """Exclusive prefix of per-shard live counts — the rank offset
        that lifts shard-local ranks to global positions (public: the
        db tier's ``scan_ranks`` merges with the same identity this
        module's read path uses)."""
        counts = self._live_counts()
        return np.concatenate([[0], np.cumsum(counts)[:-1]])

    def _invalidate(self) -> None:
        self._counts = None

    # -- reads ----------------------------------------------------------------

    def batch(self) -> QueryBatch:
        return QueryBatch()

    def lookup(self, queries: KeyArray) -> cgrx.LookupResult:
        plan = QueryBatch().add_points(queries).plan()
        return self.execute(plan).points

    def range_lookup(self, lo: KeyArray, hi: KeyArray,
                     max_hits: int = 64) -> cgrx.RangeResult:
        plan = QueryBatch().add_ranges(lo, hi).plan(max_hits=max_hits)
        return self.execute(plan).ranges

    def execute(self, plan: QueryPlan):
        """Serve a planned mixed point/range/aggregate batch across shards.

        The flat lane plan is split back into its sections (the lane
        layout is static: [points | lows | highs | agg-lows | agg-highs |
        pad]), each shard re-plans only its owned slice through the same
        QueryBatch planner, and one engine dispatch per touched shard
        serves it.  Aggregate fragments decompose at the splitters
        exactly like materializing ranges but merge by SUM (counts) /
        MIN / MAX (endpoint keys) instead of row concatenation — shards
        partition the key space, so per-shard counts add and the lowest
        (highest) shard with a non-empty intersection owns the global
        min (max).
        """
        np_, nr, na = plan.n_point, plan.n_range, plan.n_agg
        if np_ == 0 and nr == 0 and na == 0:  # empty flush: no dispatch
            return BatchResult(points=cgrx.empty_lookup_result(),
                               ranges=cgrx.empty_range_result(plan.max_hits),
                               aggs=None)
        pts = plan.keys[:np_]
        lo = plan.keys[np_:np_ + nr]
        hi = plan.keys[np_ + nr:np_ + 2 * nr]
        a0 = np_ + 2 * nr
        alo = plan.keys[a0:a0 + na]
        ahi = plan.keys[a0 + na:a0 + 2 * na]

        owners = self.route(pts) if np_ else np.zeros(0, np.int32)
        if nr:
            first_d, last_d = _route_ranges(self.splitters, lo, hi)
            first, last = np.asarray(first_d), np.asarray(last_d)
        else:
            first = last = np.zeros(0, np.int32)
        if na:
            afirst_d, alast_d = _route_ranges(self.splitters, alo, ahi)
            afirst, alast = np.asarray(afirst_d), np.asarray(alast_d)
        else:
            afirst = alast = np.zeros(0, np.int32)
        prefix = self.live_prefix()

        # Per-shard sub-batches -> one engine dispatch per touched shard.
        point_parts: List[Tuple[np.ndarray, object]] = []
        range_parts: List[Tuple[int, np.ndarray, object]] = []
        agg_parts: List[Tuple[int, np.ndarray, object]] = []
        touches = np.zeros(self.num_shards, np.int64)
        for s, shard in enumerate(self.shards):
            p_idx = np.nonzero(owners == s)[0]
            r_idx = np.nonzero((first <= s) & (s <= last))[0]
            a_idx = np.nonzero((afirst <= s) & (s <= alast))[0]
            touches[s] = len(p_idx) + len(r_idx) + len(a_idx)
            if not len(p_idx) and not len(r_idx) and not len(a_idx):
                continue
            qb = QueryBatch()
            if len(p_idx):
                qb.add_points(pts[p_idx])
            if len(r_idx):
                qb.add_ranges(lo[r_idx], hi[r_idx])
            if len(a_idx):
                qb.add_agg_ranges(alo[a_idx], ahi[a_idx])
            res = shard.execute(qb.plan(max_hits=plan.max_hits,
                                        agg_keys=plan.agg_keys))
            if len(p_idx):
                point_parts.append((p_idx, _shift_points(res.points,
                                                         prefix[s])))
            if len(r_idx):
                range_parts.append((s, r_idx, res.ranges))
            if len(a_idx):
                agg_parts.append((s, a_idx, res.aggs))

        self.touch.record(touches)
        points = _merge_points(np_, point_parts)
        ranges = _merge_ranges(nr, plan.max_hits, range_parts, first, prefix)
        aggs = (_merge_aggs(na, plan.agg_keys, agg_parts, plan.keys.is64)
                if na else None)
        return BatchResult(points=points, ranges=ranges, aggs=aggs)

    # -- writes ---------------------------------------------------------------

    def apply(self, ins_keys: Optional[KeyArray] = None,
              ins_rows: Optional[jnp.ndarray] = None,
              del_keys: Optional[KeyArray] = None,
              *, auto_compact: Optional[bool] = None) -> Optional[str]:
        """Route one mixed batch to owning shards, one apply per shard.

        Returns the policy summary string (see ``maybe_compact``) when any
        shard compacted or a rebalance fired, else None.
        """
        n_ins = int(ins_keys.shape[0]) if ins_keys is not None else 0
        n_del = int(del_keys.shape[0]) if del_keys is not None else 0
        if n_ins or n_del:
            owner_i = self.route(ins_keys) if n_ins else np.zeros(0, np.int32)
            owner_d = self.route(del_keys) if n_del else np.zeros(0, np.int32)
            if n_ins and ins_rows is not None:
                ins_rows = jnp.asarray(ins_rows, jnp.int32)
            parts = []
            for s in range(self.num_shards):
                i_idx = np.nonzero(owner_i == s)[0]
                d_idx = np.nonzero(owner_d == s)[0]
                if len(i_idx) or len(d_idx):
                    parts.append((s, i_idx, d_idx))
            if self.wals is not None:
                # Durability point: every touched shard's slice is on
                # disk (one fsync per touched log) before ANY shard's
                # device dispatch runs; the shared seq + (part, nparts)
                # markers make the group the atomic replay unit.
                for part, (s, i_idx, d_idx) in enumerate(parts):
                    self.wals[s].append(
                        ins_keys[i_idx] if len(i_idx) else None,
                        ins_rows[i_idx] if len(i_idx) else None,
                        del_keys[d_idx] if len(d_idx) else None,
                        epoch=self.shards[s].epoch, seq=self.wal_seq,
                        part=part, nparts=len(parts), sync=False)
                for s, _, _ in parts:
                    self.wals[s].sync()
                self.wal_seq += 1
            touches = np.zeros(self.num_shards, np.int64)
            for s, i_idx, d_idx in parts:
                touches[s] = len(i_idx) + len(d_idx)
                self.shards[s].apply(
                    ins_keys[i_idx] if len(i_idx) else None,
                    ins_rows[i_idx] if len(i_idx) else None,
                    del_keys[d_idx] if len(d_idx) else None,
                    auto_compact=False)
            self.touch.record(touches)
            self.applies += 1
            self.inserts += n_ins
            self.deletes += n_del
            self._invalidate()
        ac = self.config.live.auto_compact if auto_compact is None \
            else auto_compact
        return self.maybe_compact() if ac else None

    def insert(self, keys: KeyArray, rows: jnp.ndarray) -> Optional[str]:
        return self.apply(ins_keys=keys, ins_rows=rows)

    def delete(self, keys: KeyArray) -> Optional[str]:
        return self.apply(del_keys=keys)

    # -- maintenance: per-shard compaction + skew rebalance -------------------

    def maybe_compact(self) -> Optional[str]:
        """Evaluate every shard's compaction policy independently, then
        the skew monitor.  Returns a summary like ``'s1:chain,s3:fill'``
        (or ``'rebalance'``, or both) when anything fired, else None —
        the same Optional[str] contract the frontend's tick loop expects
        from a single ``LiveIndex``."""
        fired = []
        for i, shard in enumerate(self.shards):
            reason = shard.maybe_compact()
            if reason:
                fired.append(f"s{i}:{reason}")
        if self.config.auto_rebalance:
            what = self.maybe_rebalance()
            if what:
                fired.append(what)
        return ",".join(fired) or None

    def compact_shard(self, shard_id: int, reason: str = "manual") -> None:
        """Foreground-compact ONE shard; siblings keep serving untouched
        (their epochs, chains and engines don't move)."""
        self.shards[shard_id].compact(reason)

    def maybe_rebalance(self):
        """Fire a splitter refresh when per-shard fill diverged past
        ``max_imbalance``.  Skipped while any shard has an in-flight
        compaction task (its replay log references the store being
        replaced).

        The trigger quantity here is SIZE imbalance only, on purpose:
        this path runs inside ``maybe_compact`` — i.e. inside WAL-replay
        recovery — so it must be a deterministic function of the live
        multiset the log reproduces.  Touch-rate skew (read traffic the
        WAL never sees) is acted on by the autotuner's tick instead
        (``tuning/autotune.py``), whose actions recovery legitimately
        omits: the rank-offset merge keeps reads bit-identical whatever
        the splitters are.

        Returns a truthy summary — ``'rebalance'`` (full rebuild) or
        ``'migrate'`` (one bounded incremental step, per
        ``config.rebalance_mode``) — or None when nothing fired.
        """
        cfg = self.config
        if cfg.max_imbalance is None or self.compacting:
            return None
        counts = self._live_counts()
        total = int(counts.sum())
        if total < max(cfg.min_rebalance_keys, cfg.num_shards):
            return None
        if counts.max() <= cfg.max_imbalance * (total / cfg.num_shards):
            return None
        if cfg.rebalance_mode == "incremental":
            return ("migrate"
                    if self.migrate_step(cfg.migrate_max_keys,
                                         use_touch=False) else None)
        self.rebalance()
        return "rebalance"

    def migrate_step(self, max_keys: Optional[int] = None, *,
                     use_touch: bool = True) -> int:
        """Move at most ``max_keys`` keys from the most loaded shard to
        its less loaded neighbor, nudging ONE splitter — the bounded
        incremental alternative to ``rebalance``'s stop-and-rebuild.

        Shard pressure is the per-shard live count over the balanced
        mean, elementwise-max'd with the touch-rate EWMA over ITS mean
        when ``use_touch`` (so a balanced-size/hot-shard workload still
        picks the hot shard as donor; recovery-deterministic callers
        pass ``use_touch=False``).  The donor's boundary run of keys —
        highest when shedding up-range, lowest when shedding down-range —
        moves to the adjacent shard through plain ``apply`` calls
        (chain-local, O(moved) work; no epoch swap, no full extract of
        any non-donor shard), and the shared splitter moves with it, so
        routing agrees with placement at every step.

        Not WAL-logged: the live key multiset is unchanged, and merged
        reads depend only on that multiset (the same invariant recovery
        relies on), so a replay-rebuilt store answers bit-identically
        even though its splitters never migrated.  The touch EWMA resets
        afterwards so the monitor re-observes the new placement instead
        of ping-ponging on stale heat.

        Returns the number of keys moved (0 = nothing to do: tiny donor,
        no less-loaded neighbor, or a compaction in flight).
        """
        if self.compacting or self.num_shards < 2:
            return 0
        k_budget = (self.config.migrate_max_keys if max_keys is None
                    else int(max_keys))
        if k_budget < 1:
            return 0
        counts = self._live_counts().astype(np.float64)
        mean = counts.sum() / self.num_shards
        if mean <= 0:
            return 0
        pressure = counts / mean
        if use_touch and self.touch.total_events:
            rates = self.touch.rates
            rmean = rates.sum() / self.num_shards
            if rmean > 0:
                pressure = np.maximum(pressure, rates / rmean)
        donor = int(np.argmax(pressure))
        neighbors = [s for s in (donor - 1, donor + 1)
                     if 0 <= s < self.num_shards]
        recipient = min(neighbors, key=lambda s: pressure[s])
        if pressure[recipient] >= pressure[donor]:
            return 0
        n_donor = int(counts[donor])
        if n_donor <= 1:
            return 0
        # Never move past the balance point: cap at half the live-count
        # gap so one oversized budget cannot invert the imbalance.
        gap = int(counts[donor] - counts[recipient])
        if use_touch and self.touch.total_events:
            rates = self.touch.rates
            h_d, h_r = float(rates[donor]), float(rates[recipient])
            if h_d > h_r > -1.0 and h_d > 0:
                # Touch-picked donor with balanced sizes has gap ~ 0;
                # size the step off the HEAT surplus instead.  Under a
                # uniform-heat approximation, handing the recipient
                # (h_d - h_r) / 2h_d of the donor's keys balances heat.
                gap = max(gap, int(n_donor * (h_d - h_r) / h_d))
        k = min(k_budget, n_donor - 1, max(gap // 2, 1))
        # Quantize down to a power of two: migration applies then draw
        # from a tiny set of batch shapes the jit cache already holds,
        # instead of compiling a fresh executable per tick.
        k = 1 << (k.bit_length() - 1)
        keys, rows = self.shards[donor].live_cut()
        if recipient > donor:
            moved_k, moved_r = keys[n_donor - k:], rows[n_donor - k:]
            # New boundary: the donor's highest surviving key.
            self.splitters = _set_splitter(self.splitters, donor,
                                           keys[n_donor - k - 1])
        else:
            moved_k, moved_r = keys[:k], rows[:k]
            # The recipient absorbs up to the run's highest key.
            self.splitters = _set_splitter(self.splitters, recipient,
                                           keys[k - 1])
        self.shards[donor].apply(del_keys=moved_k, auto_compact=False)
        self.shards[recipient].apply(ins_keys=moved_k, ins_rows=moved_r,
                                     auto_compact=False)
        self.migrations += 1
        self.touch.reset()
        self._invalidate()
        return k

    def rebalance(self) -> None:
        """Recompute equal-count splitters and migrate boundary buckets.

        Migration IS the existing extract→presorted-build path: each
        shard's ``nodes.extract`` emits its live set sorted; shard cuts
        concatenate in shard order (already globally sorted — shards are
        ordered key ranges) and reload into fresh equal partitions.  Every
        shard restarts at epoch 0 with chains folded flat; store-level
        counters (applies/inserts/deletes/rebalances) survive.
        """
        parts_k, parts_r = [], []
        for shard in self.shards:
            skeys, srows, n_live = nodes.extract(shard.store)
            parts_k.append(skeys[:n_live])
            parts_r.append(srows[:n_live])
        all_keys = parts_k[0]
        all_rows = parts_r[0]
        for k, r in zip(parts_k[1:], parts_r[1:]):
            all_keys = concat_keys(all_keys, k)
            all_rows = jnp.concatenate([all_rows, r])
        self.splitters = compute_splitters(all_keys, self.config.num_shards)
        self.shards = _load_shards(all_keys, all_rows, self.config)
        self.rebalances += 1
        self.touch.reset()   # re-observe the new placement from scratch
        self._invalidate()

    # -- stats ----------------------------------------------------------------

    def stats(self) -> metrics.ShardedStats:
        return metrics.collect_sharded(self)


# ---------------------------------------------------------------------------
# Build/merge helpers.
# ---------------------------------------------------------------------------

def _set_splitter(splitters: KeyArray, i: int, key: KeyArray) -> KeyArray:
    """Replace splitter ``i`` with the scalar key at ``key``'s position
    (``key`` is a length-1 or scalar-indexed slice of a key set)."""
    lo = splitters.lo.at[i].set(jnp.reshape(key.lo, ()))
    hi = (None if splitters.hi is None
          else splitters.hi.at[i].set(jnp.reshape(key.hi, ())))
    return KeyArray(lo, hi)


def _load_shards(sorted_keys: KeyArray, sorted_rows: jnp.ndarray,
                 cfg: ShardedConfig) -> List[LiveIndex]:
    """Contiguous equal slices of a sorted key set -> one LiveIndex each,
    through the presorted bulk-load path.  Slice bounds come from the
    same ``partition_cuts`` that ``compute_splitters`` derives splitters
    from, so shard contents and routing cannot drift.  All shards share
    the store's executable-cache scope."""
    cuts = partition_cuts(sorted_keys.shape[0], cfg.num_shards)
    live_cfg = dataclasses.replace(
        cfg.live, cache_scope=cfg.live.cache_scope or cfg.cache_scope)
    return [LiveIndex.build(sorted_keys[int(a):int(b)],
                            sorted_rows[int(a):int(b)],
                            live_cfg, presorted=True)
            for a, b in zip(cuts[:-1], cuts[1:])]


def _shift_points(res: cgrx.LookupResult, offset: int) -> cgrx.LookupResult:
    """Lift shard-local rank positions to global ones (rank-offset
    prefix); found/row_id are location-independent, bucket_id stays
    shard-local (documented — shard bucketing differs from any
    single-shard build's)."""
    return res._replace(position=(res.position
                                  + jnp.int32(offset)).astype(jnp.int32))


def _merge_points(n_point: int,
                  parts: List[Tuple[np.ndarray, cgrx.LookupResult]]
                  ) -> cgrx.LookupResult:
    """Scatter per-shard point results back into request order."""
    if n_point == 0:
        return cgrx.empty_lookup_result()
    found = np.zeros(n_point, bool)
    row = np.full(n_point, MISS, np.int32)
    pos = np.zeros(n_point, np.int32)
    bucket = np.zeros(n_point, np.int32)
    for idx, res in parts:
        found[idx] = np.asarray(res.found)
        row[idx] = np.asarray(res.row_id)
        pos[idx] = np.asarray(res.position)
        bucket[idx] = np.asarray(res.bucket_id)
    return cgrx.LookupResult(bucket_id=jnp.asarray(bucket),
                             row_id=jnp.asarray(row),
                             found=jnp.asarray(found),
                             position=jnp.asarray(pos))


def _merge_ranges(n_range: int, max_hits: int,
                  parts: List[Tuple[int, np.ndarray, cgrx.RangeResult]],
                  first: np.ndarray, prefix: np.ndarray) -> cgrx.RangeResult:
    """Merge per-shard sub-range results into global ones.

    start = prefix[first shard] + its local start (shards before the span
    hold only keys < lo, so their full live counts ARE the rank offset);
    counts add across the span; row blocks concatenate in shard order —
    bit-identical to the single-shard scan because shard order IS sorted
    order.
    """
    if n_range == 0:
        return cgrx.empty_range_result(max_hits)
    start = np.zeros(n_range, np.int32)
    count = np.zeros(n_range, np.int32)
    rows = np.full((n_range, max_hits), MISS, np.int32)
    fill = np.zeros(n_range, np.int32)  # rows already merged per range
    for s, idx, res in sorted(parts, key=lambda p: p[0]):
        r_start = np.asarray(res.start)
        r_count = np.asarray(res.count)
        r_rows = np.asarray(res.row_ids)
        for k, j in enumerate(idx):
            c = int(r_count[k])
            if s == first[j]:
                start[j] = prefix[s] + int(r_start[k])
            count[j] += c
            take = min(c, max_hits - int(fill[j]))
            if take > 0:
                rows[j, fill[j]:fill[j] + take] = r_rows[k, :take]
                fill[j] += take
    return cgrx.RangeResult(start=jnp.asarray(start),
                            count=jnp.asarray(count),
                            row_ids=jnp.asarray(rows))


def _merge_aggs(n_agg: int, with_keys: bool,
                parts: List[Tuple[int, np.ndarray, cgrx.AggResult]],
                is64: bool) -> cgrx.AggResult:
    """Merge per-shard aggregate fragments into global aggregates.

    Shards partition the key space, so counts ADD across a range's span;
    shard order is key order, so the global min key is the first
    non-empty span shard's local min and the global max is the last
    non-empty one's local max.  Bit-identical to a single-shard oracle
    because each side of the identity ranks the same live multiset.
    """
    count = np.zeros(n_agg, np.int64)
    min_np = np.zeros(n_agg, np.uint64)
    max_np = np.zeros(n_agg, np.uint64)
    seen = np.zeros(n_agg, bool)
    for s, idx, res in sorted(parts, key=lambda p: p[0]):
        c = np.asarray(res.count)
        mn = res.min_key.to_numpy() if with_keys else None
        mx = res.max_key.to_numpy() if with_keys else None
        for k, j in enumerate(idx):
            if int(c[k]) <= 0:
                continue
            count[j] += int(c[k])
            if with_keys:
                if not seen[j]:
                    min_np[j] = mn[k]
                    seen[j] = True
                max_np[j] = mx[k]
    if not with_keys:
        return cgrx.AggResult(count=jnp.asarray(count.astype(np.int32)),
                              min_key=None, max_key=None)
    mk = KeyArray.from_u64 if is64 else \
        (lambda a: KeyArray.from_u32(a.astype(np.uint32)))
    return cgrx.AggResult(count=jnp.asarray(count.astype(np.int32)),
                          min_key=mk(min_np), max_key=mk(max_np))
