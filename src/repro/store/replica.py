"""Epoch-lagged read replicas over a durable store's snapshot stream.

The durable serving design (db/tiers.py) makes the primary's whole state
reconstructible from ``wal_dir`` — newest snapshot + WAL tail — and that
recovery path is exactly a replica's refresh: a ``ReadReplica`` runs
``repro.db.recover_tier`` against the same directory and swaps the
rebuilt tier in atomically (Python reference assignment), so readers on
the old tier finish undisturbed while the next read serves the newer
epoch.  Replicas never attach a WAL, never write snapshots, and never
mutate ``wal_dir`` beyond their own heartbeat beacon — the primary can't
tell they exist, which is what makes "feed the same snapshot stream to
replicas" a zero-cost fan-out on the write path.

``ReplicaSet`` is the serving façade: reads go to the freshest member
(the newest applied WAL position), a ``refresh()`` catches up the MOST
LAGGED follower first (so the serving member stays stable while a
follower rebuilds — the epoch-lagged contract), and failover is driven
by ``runtime/ft.py`` primitives:

  * every member writes a ``Heartbeat`` beacon (``replicas/<name>.hb``)
    with its applied seq/epoch; the primary's ``primary.hb`` beacon is
    the staleness reference;
  * a ``StragglerMonitor`` over refresh durations flags members whose
    rebuild blew past the fleet's EMA — flagged members are skipped by
    ``serving()`` until a healthy refresh clears them;
  * when no member is fresh enough (or all are flagged/failed), reads
    raise ``repro.db.StaleReplicaError`` with the epoch/seq lag
    attached, so the caller can retry, relax, or alert.

Consistency: a refresh mid-write is safe by construction — snapshots
commit atomically (rename + dir fsync), and a torn WAL record or
incomplete per-shard group at the log tail is dropped by the reader
(store/wal.py), which only ever makes the replica one apply MORE stale.

``repro.db`` is imported lazily inside methods: this module sits in the
store layer, which the db layer imports.
"""
from __future__ import annotations

import os
import threading
import time
from typing import List, Optional

import jax.numpy as jnp

from repro.core.keys import KeyArray
from repro.query import QueryBatch
from repro.runtime.ft import Heartbeat, StragglerMonitor


class ReadReplica:
    """One follower: a locally rebuilt tier + a heartbeat beacon."""

    def __init__(self, spec, name: str = "replica-0"):
        if not getattr(spec, "durable", False):
            from repro.db.errors import InvalidSpecError
            raise InvalidSpecError(
                "a replica follows a durable store; the spec needs "
                "durability='wal'|'wal+snapshot' and a wal_dir")
        self.spec = spec
        self.name = name
        self.tier = None               # set by the first refresh()
        self.applied_seq = -1
        self.last_error: Optional[Exception] = None
        hb_dir = os.path.join(spec.wal_dir, "replicas")
        os.makedirs(hb_dir, exist_ok=True)
        self.heartbeat = Heartbeat(os.path.join(hb_dir, f"{name}.hb"))

    @property
    def epoch(self) -> int:
        return self.tier.epoch if self.tier is not None else -1

    def refresh(self) -> float:
        """Catch up to the primary's durable state (snapshot + WAL
        tail), swap the tier atomically, beat the beacon.  Returns the
        rebuild wall time (the straggler monitor's input).  On failure
        the OLD tier keeps serving and the error is kept on
        ``last_error`` (and re-raised)."""
        from repro.db.tiers import recover_tier

        t0 = time.perf_counter()
        try:
            tier, seq = recover_tier(self.spec)
        except Exception as e:
            self.last_error = e
            raise
        self.tier = tier               # atomic swap: readers see old or new
        self.applied_seq = seq
        self.last_error = None
        self.heartbeat.write_now(
            step=seq, payload={"seq": seq, "epoch": tier.epoch})
        return time.perf_counter() - t0

    # -- reads (served from this replica's applied epoch) ---------------------

    def execute(self, plan):
        if self.tier is None:
            from repro.db.errors import StaleReplicaError
            raise StaleReplicaError(
                f"replica {self.name!r} has not refreshed yet")
        return self.tier.execute(plan)

    def lookup(self, queries: KeyArray):
        plan = QueryBatch().add_points(queries).plan()
        return self.execute(plan).points

    def range_lookup(self, lo: KeyArray, hi: KeyArray, max_hits: int = 64):
        plan = QueryBatch().add_ranges(lo, hi).plan(max_hits=max_hits)
        return self.execute(plan).ranges

    def scan_ranks(self, queries: KeyArray, sides: jnp.ndarray):
        if self.tier is None:
            from repro.db.errors import StaleReplicaError
            raise StaleReplicaError(
                f"replica {self.name!r} has not refreshed yet")
        return self.tier.scan_ranks(queries, sides)


class ReplicaSet:
    """N read replicas behind one serving surface (see module doc).

    Usage::

        rs = ReplicaSet(spec, n=2)
        rs.refresh_all()                     # initial catch-up
        res = rs.lookup(keys)                # freshest member serves
        rs.refresh()                         # most-lagged follower next
        lag = rs.staleness()                 # {'seq_lag', 'epoch_lag', ...}
        rs.start(interval=0.5); ...; rs.stop()   # background refresher

    ``max_seq_lag`` (optional) bounds how far behind the primary's
    beacon the serving member may be before reads fail over — and, with
    every member past it, raise ``StaleReplicaError``.
    """

    def __init__(self, spec, n: int = 2, *,
                 max_seq_lag: Optional[int] = None,
                 straggler_threshold: float = 3.0):
        self.spec = spec
        self.replicas: List[ReadReplica] = [
            ReadReplica(spec, f"replica-{i}") for i in range(n)]
        self.suspect: set = set()
        self.monitor = StragglerMonitor(
            threshold=straggler_threshold,
            on_straggler=lambda step, dur, ema: None)
        self.max_seq_lag = max_seq_lag
        self._refreshes = 0
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- refresh orchestration ------------------------------------------------

    def _record(self, replica: ReadReplica, duration: float) -> None:
        if self.monitor.record(self._refreshes, duration):
            self.suspect.add(replica.name)     # skipped until healthy
        else:
            self.suspect.discard(replica.name)
        self._refreshes += 1

    def refresh(self) -> Optional[str]:
        """Refresh the MOST LAGGED member (the epoch-lagged contract:
        the freshest member keeps serving while a follower rebuilds).
        Returns the refreshed member's name, or None when every refresh
        attempt failed."""
        order = sorted(self.replicas, key=lambda r: r.applied_seq)
        for replica in order:
            try:
                self._record(replica, replica.refresh())
                return replica.name
            except Exception:
                self.suspect.add(replica.name)
        return None

    def refresh_all(self) -> None:
        for replica in self.replicas:
            self._record(replica, replica.refresh())

    # -- failover / staleness -------------------------------------------------

    def primary_state(self) -> Optional[dict]:
        """The primary's last-published beacon ({'seq', 'epoch', ...}),
        or None when it is missing/unreadable."""
        return Heartbeat.read(
            os.path.join(self.spec.wal_dir, "primary.hb"))

    def serving(self) -> ReadReplica:
        """The freshest healthy member; raises ``StaleReplicaError``
        (with epoch/seq lag attached) when none qualifies."""
        from repro.db.errors import StaleReplicaError

        primary = self.primary_state()
        live = [r for r in self.replicas
                if r.tier is not None and r.name not in self.suspect]
        if self.max_seq_lag is not None and primary is not None:
            fresh = [r for r in live if (primary["seq"] - r.applied_seq)
                     <= self.max_seq_lag]
        else:
            fresh = live
        if fresh:
            return max(fresh, key=lambda r: (r.applied_seq, r.epoch))
        best = max(self.replicas, key=lambda r: r.applied_seq)
        seq_lag = (primary["seq"] - best.applied_seq) if primary else None
        epoch_lag = (primary["epoch"] - best.epoch) if primary else None
        raise StaleReplicaError(
            f"no replica is servable: best member {best.name!r} is "
            f"{seq_lag if seq_lag is not None else 'unknown'} WAL "
            f"records behind the primary "
            f"({len(self.suspect)} flagged as stragglers/failed)",
            epoch_lag=epoch_lag, seq_lag=seq_lag)

    def staleness(self) -> dict:
        """Lag of the would-be serving member vs the primary beacon."""
        primary = self.primary_state()
        best = max(self.replicas, key=lambda r: r.applied_seq)
        return {
            "replica": best.name,
            "applied_seq": best.applied_seq,
            "epoch": best.epoch,
            "primary_seq": primary["seq"] if primary else None,
            "seq_lag": (primary["seq"] - best.applied_seq)
            if primary else None,
            "epoch_lag": (primary["epoch"] - best.epoch)
            if primary else None,
        }

    # -- reads (delegate to the serving member) -------------------------------

    def execute(self, plan):
        return self.serving().execute(plan)

    def lookup(self, queries: KeyArray):
        return self.serving().lookup(queries)

    def range_lookup(self, lo: KeyArray, hi: KeyArray, max_hits: int = 64):
        return self.serving().range_lookup(lo, hi, max_hits)

    def scan_ranks(self, queries: KeyArray, sides: jnp.ndarray):
        return self.serving().scan_ranks(queries, sides)

    # -- background refresher -------------------------------------------------

    def start(self, interval: float = 5.0) -> "ReplicaSet":
        """Refresh the most-lagged follower every ``interval`` seconds
        on a daemon thread (stop() — or the owning session's close() —
        joins it)."""
        if self._thread is not None:
            return self
        self._stop.clear()

        def run():
            while not self._stop.wait(interval):
                try:
                    self.refresh()
                except Exception:                      # noqa: BLE001
                    pass                               # kept on last_error
        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None

    def __enter__(self) -> "ReplicaSet":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
