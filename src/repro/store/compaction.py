"""Compaction policy + epoch-swap task for the live index store.

The paper's update mechanism (Sec. 4) trades lookup cost for update cost:
chains grow, every lookup pays the ``max_chain`` walk bound, and deleted
slots leave the slab under-filled.  A long-lived store therefore needs a
policy for when to fold the degraded chains back into a fresh bulk-loaded
index — the paper's own Fig. 15 rebuild baseline, run *off the read path*
as an epoch swap:

    trigger  ->  begin: extract() the live set (a consistent cut)
             ->  ... reads AND writes keep hitting the old epoch ...
             ->  finish: bulk-load new store + snapshot from the cut,
                 replay the writes that landed mid-compaction, swap,
                 epoch += 1

``CompactionPolicy`` holds the trigger thresholds; ``should_compact``
evaluates them against a ``LiveStats`` snapshot and returns the firing
trigger's name (or ``None``).  ``CompactionTask`` is the in-flight state
between begin and finish — `LiveIndex` drives the lifecycle.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax.numpy as jnp

from repro.core.keys import KeyArray

from .metrics import LiveStats


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    """Trigger thresholds; any ``None`` disables that trigger.

    ``max_chain``       compact when the chain-length bound reaches this
                        (every lookup walks up to ``max_chain`` nodes);
    ``min_fill``        compact when live keys per allocated slot drop
                        below this (deletions fragmented the slab);
    ``max_tombstone_ratio``  compact when deletes since the last epoch
                        exceed this fraction of the live set;
    ``min_live_keys``   never compact below this size (tiny stores churn).
    """

    max_chain: Optional[int] = 4
    min_fill: Optional[float] = 0.25
    max_tombstone_ratio: Optional[float] = 0.5
    min_live_keys: int = 64

    def never(self) -> "CompactionPolicy":
        """A copy with every trigger disabled (manual compaction only)."""
        return CompactionPolicy(max_chain=None, min_fill=None,
                                max_tombstone_ratio=None,
                                min_live_keys=self.min_live_keys)


def should_compact(policy: CompactionPolicy, stats: LiveStats) -> Optional[str]:
    """Name of the firing trigger ('chain' | 'fill' | 'tombstone'), or
    ``None`` when the store is healthy (or too small to bother)."""
    if stats.live_keys < policy.min_live_keys:
        return None
    if policy.max_chain is not None and stats.max_chain >= policy.max_chain:
        return "chain"
    if policy.min_fill is not None and stats.fill_factor < policy.min_fill:
        return "fill"
    if (policy.max_tombstone_ratio is not None
            and stats.tombstone_ratio > policy.max_tombstone_ratio):
        return "tombstone"
    return None


@dataclasses.dataclass
class CompactionTask:
    """In-flight epoch swap: the consistent cut taken at ``begin`` plus
    the update batches that arrive while the rebuild runs (replayed onto
    the new epoch at ``finish``)."""

    reason: str
    epoch_at_begin: int
    keys: KeyArray              # sorted live keys at begin (n_live,)
    rows: jnp.ndarray           # aligned rowIDs
    n_live: int
    replay: List[Tuple] = dataclasses.field(default_factory=list)
