"""DEPRECATED tick frontend — a thin compatibility shim over ``repro.db``.

``LiveFrontend`` used to hand-roll the admission discipline (queue mixed
requests, drain with one device dispatch per op class per ``tick()``).
That execution model is now the *built-in* behavior of the unified
session API: ``repro.db.open(spec, ...)`` returns a ``Session`` whose
``flush()`` is exactly the old tick.  This class survives as a shim that
adopts an already-built ``LiveIndex``/``ShardedLiveStore`` into a
``Session`` (``repro.db.wrap_store``) and translates the historical
ticket-int / ``TickReport`` surface onto it — behavior-identical
(tests/test_live_store.py, tests/test_db.py), but every construction
emits one ``DeprecationWarning`` pointing at ``repro.db``.

Migration map:

    LiveFrontend(live)        ->  repro.db.open(IndexSpec(tier='live'|
                                  'sharded'), keys, rows)
    submit_point/submit_range ->  session.lookup / session.range
    submit_insert/submit_delete -> session.insert / session.delete
    tick()                    ->  session.flush()  (-> FlushReport)
    result(ticket)            ->  Ticket.result()  (auto-flushes)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp

from repro.core.deprecation import warn_once
from repro.core.keys import KeyArray

from .live import LiveIndex


@dataclasses.dataclass(frozen=True)
class TickReport:
    """What one ``tick()`` did and what it cost (legacy shape; the
    session's ``FlushReport`` adds rank-scan fields)."""

    tick: int
    epoch: int                 # epoch serving this tick's reads
    n_point: int
    n_range: int
    n_insert: int
    n_delete: int
    compacted: Optional[str]   # firing trigger name, or None
    update_seconds: float      # apply_batch wall time
    lookup_seconds: float      # engine execute wall time
    compact_seconds: float     # epoch-swap pause (0.0 when none fired)


class LiveFrontend:
    """Queue + tick loop driving a ``LiveIndex`` like a service.

    DEPRECATED: open a ``repro.db`` session instead (see module doc).
    """

    def __init__(self, live: LiveIndex, max_hits: int = 64):
        warn_once("store.LiveFrontend",
                  "store.LiveFrontend is deprecated; repro.db sessions "
                  "(repro.db.open) batch mixed traffic per flush() "
                  "natively — see the migration table in README.md")
        from repro import db  # deferred: store is imported by repro.db
        from repro.db import tiers as db_tiers

        self.live = live
        self.max_hits = max_hits
        # The internal adopt path: wrap_store() now warns for bare
        # updatable stores, and this shim's own deprecation warning
        # already covers the call (one warning per construction).
        tier = db_tiers._adopt(live)
        # Historical tick contract: the policy step runs on every tick
        # with writes, regardless of the store's own auto_compact knob
        # (which only governed direct apply() calls).
        tier.auto_compact = True
        self.session = db.Session(tier, max_hits=max_hits)
        self._tickets: Dict[int, object] = {}

    # -- submission (session tickets behind the historical dense ints) -------

    def _track(self, ticket) -> int:
        self._tickets[ticket.id] = ticket
        return ticket.id

    def submit_point(self, keys: KeyArray) -> int:
        return self._track(self.session.lookup(keys))

    def submit_range(self, lo: KeyArray, hi: KeyArray) -> int:
        return self._track(self.session.range(lo, hi))

    def submit_insert(self, keys: KeyArray, rows: jnp.ndarray) -> int:
        return self._track(self.session.insert(keys, rows))

    def submit_delete(self, keys: KeyArray) -> int:
        return self._track(self.session.delete(keys))

    @property
    def pending(self) -> int:
        return self.session.pending

    # -- results --------------------------------------------------------------

    def result(self, ticket: int):
        """Pop a served request's result (legacy pop-once contract:
        raises KeyError while still queued/unserved, and again on a
        second pop).  Never auto-flushes — that is the session API's
        affordance, not the tick loop's."""
        t = self._tickets.get(ticket)
        if t is None or not t.ready:
            raise KeyError(ticket)
        del self._tickets[ticket]
        return t.result()

    # -- the tick -------------------------------------------------------------

    def tick(self) -> TickReport:
        rep = self.session.flush()
        return TickReport(tick=rep.flush, epoch=rep.epoch,
                          n_point=rep.n_point, n_range=rep.n_range,
                          n_insert=rep.n_insert, n_delete=rep.n_delete,
                          compacted=rep.compacted,
                          update_seconds=rep.update_seconds,
                          lookup_seconds=rep.lookup_seconds,
                          compact_seconds=rep.compact_seconds)
