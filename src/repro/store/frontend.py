"""Tick-based mixed-workload frontend over a LiveIndex (or sharded store).

Mirrors the serving engine's admission discipline (serving/engine.py):
requests of all four kinds — point lookup, range lookup, insert, delete —
queue between ticks, and each ``tick()`` drains them with one device
dispatch per op class:

    writes:  ONE ``nodes.apply_batch`` covering every insert AND delete
             submitted this tick (deletions-before-insertions semantics,
             insert∩delete pairs cancel);
    reads:   ONE ``RankEngine.execute`` over a QueryBatch coalescing all
             points and ranges into a single padded lane batch;
    policy:  one compaction check (the pause, when it fires, is timed and
             reported — the number bench_live_store.py plots).

Within a tick, writes land before reads: a lookup submitted in the same
tick as an insert of its key hits.  Tickets are dense ints; results are
retrievable (once) after the tick that served them.

The backing store is duck-typed: anything exposing ``apply`` /
``maybe_compact`` / ``execute`` / ``sync`` / ``epoch`` serves.  With a
``ShardedLiveStore`` the same tick loop becomes shard-aware for free —
writes route to owning shards (one apply dispatch per touched shard),
reads decompose at the splitters (one engine dispatch per touched shard),
and the policy step compacts/rebalances shards independently.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cgrx
from repro.core.keys import KeyArray, concat_keys
from repro.query import QueryBatch

from .live import LiveIndex


def _empty_points() -> cgrx.LookupResult:
    z = jnp.zeros((0,), jnp.int32)
    return cgrx.LookupResult(bucket_id=z, row_id=z,
                             found=jnp.zeros((0,), bool), position=z)


def _empty_ranges(max_hits: int) -> cgrx.RangeResult:
    z = jnp.zeros((0,), jnp.int32)
    return cgrx.RangeResult(start=z, count=z,
                            row_ids=jnp.zeros((0, max_hits), jnp.int32))


@dataclasses.dataclass(frozen=True)
class TickReport:
    """What one ``tick()`` did and what it cost."""

    tick: int
    epoch: int                 # epoch serving this tick's reads
    n_point: int
    n_range: int
    n_insert: int
    n_delete: int
    compacted: Optional[str]   # firing trigger name, or None
    update_seconds: float      # apply_batch wall time
    lookup_seconds: float      # engine execute wall time
    compact_seconds: float     # epoch-swap pause (0.0 when none fired)


class LiveFrontend:
    """Queue + tick loop driving a ``LiveIndex`` like a service."""

    def __init__(self, live: LiveIndex, max_hits: int = 64):
        self.live = live
        self.max_hits = max_hits
        self._next_ticket = 0
        self._tick = 0
        self._points: List[Tuple[int, KeyArray]] = []
        self._ranges: List[Tuple[int, KeyArray, KeyArray]] = []
        self._ins: List[Tuple[int, KeyArray, jnp.ndarray]] = []
        self._dels: List[Tuple[int, KeyArray]] = []
        self._results: Dict[int, object] = {}

    # -- submission -----------------------------------------------------------

    def _ticket(self) -> int:
        t = self._next_ticket
        self._next_ticket += 1
        return t

    # Zero-length submissions resolve immediately (an empty result / an
    # applied-count of 0) instead of queueing: a tick with only empty ops
    # dispatches nothing, so their tickets would otherwise never settle.

    def submit_point(self, keys: KeyArray) -> int:
        t = self._ticket()
        if int(keys.shape[0]) == 0:
            self._results[t] = _empty_points()
        else:
            self._points.append((t, keys))
        return t

    def submit_range(self, lo: KeyArray, hi: KeyArray) -> int:
        if lo.shape != hi.shape:
            raise ValueError("range lo/hi shapes differ")
        t = self._ticket()
        if int(lo.shape[0]) == 0:
            self._results[t] = _empty_ranges(self.max_hits)
        else:
            self._ranges.append((t, lo, hi))
        return t

    def submit_insert(self, keys: KeyArray, rows: jnp.ndarray) -> int:
        t = self._ticket()
        if int(keys.shape[0]) == 0:
            self._results[t] = 0
        else:
            self._ins.append((t, keys, jnp.asarray(rows, jnp.int32)))
        return t

    def submit_delete(self, keys: KeyArray) -> int:
        t = self._ticket()
        if int(keys.shape[0]) == 0:
            self._results[t] = 0
        else:
            self._dels.append((t, keys))
        return t

    @property
    def pending(self) -> int:
        return (len(self._points) + len(self._ranges)
                + len(self._ins) + len(self._dels))

    # -- results --------------------------------------------------------------

    def result(self, ticket: int):
        """Pop a served request's result.

        Points -> ``cgrx.LookupResult``; ranges -> ``cgrx.RangeResult``
        (fields sliced to the submission's shape); writes -> the
        submitted batch size (NOT the net change: cancelled pairs and
        deletes of absent keys still count).  Raises KeyError while
        still queued/unserved.
        """
        return self._results.pop(ticket)

    # -- the tick -------------------------------------------------------------

    def tick(self) -> TickReport:
        points, self._points = self._points, []
        ranges, self._ranges = self._ranges, []
        ins, self._ins = self._ins, []
        dels, self._dels = self._dels, []

        n_insert = sum(int(k.shape[0]) for _, k, _ in ins)
        n_delete = sum(int(k.shape[0]) for _, k in dels)
        n_point = sum(int(k.shape[0]) for _, k in points)
        n_range = sum(int(lo.shape[0]) for _, lo, _ in ranges)

        # ---- writes first: one apply_batch for the whole tick ----
        t0 = time.perf_counter()
        if n_insert or n_delete:
            ik = ir = dk = None
            if ins:
                ik = _concat([k for _, k, _ in ins])
                ir = jnp.concatenate([r for _, _, r in ins])
            if dels:
                dk = _concat([k for _, k in dels])
            self.live.apply(ik, ir, dk, auto_compact=False)
            self.live.sync()
            for t, k, _ in ins:
                self._results[t] = int(k.shape[0])
            for t, k in dels:
                self._results[t] = int(k.shape[0])
        t_update = time.perf_counter() - t0

        # ---- compaction check (the pause, when it fires) ----
        t0 = time.perf_counter()
        compacted = self.live.maybe_compact() if (n_insert or n_delete) else None
        if compacted:
            self.live.sync()
        t_compact = time.perf_counter() - t0

        # ---- reads: one engine call for all points + ranges ----
        t0 = time.perf_counter()
        if n_point or n_range:
            batch = QueryBatch()
            for _, k in points:
                batch.add_points(k)
            for _, lo, hi in ranges:
                batch.add_ranges(lo, hi)
            res = self.live.execute(batch.plan(max_hits=self.max_hits))
            jax.block_until_ready(res.points.row_id if n_point
                                  else res.ranges.row_ids)
            off = 0
            for t, k in points:
                m = int(k.shape[0])
                self._results[t] = _slice_tuple(res.points, off, off + m)
                off += m
            off = 0
            for t, lo, _ in ranges:
                m = int(lo.shape[0])
                self._results[t] = _slice_tuple(res.ranges, off, off + m)
                off += m
        t_lookup = time.perf_counter() - t0

        self._tick += 1
        return TickReport(tick=self._tick - 1, epoch=self.live.epoch,
                          n_point=n_point, n_range=n_range,
                          n_insert=n_insert, n_delete=n_delete,
                          compacted=compacted, update_seconds=t_update,
                          lookup_seconds=t_lookup,
                          compact_seconds=t_compact if compacted else 0.0)


def _concat(parts: List[KeyArray]) -> KeyArray:
    out = parts[0]
    for p in parts[1:]:
        out = concat_keys(out, p)
    return out


def _slice_tuple(res, lo: int, hi: int):
    """Slice every field of a NamedTuple result along axis 0."""
    return type(res)(*(f[lo:hi] for f in res))
