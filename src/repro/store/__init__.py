"""Live index store: the lifecycle layer over the paper's update mechanism.

``core/nodes.py`` reproduces the paper's Sec. 4 mechanics (bucket-local
chain updates under an immutable accelerated structure); this package
turns them into one long-lived, updatable, queryable index:

``live``        ``LiveIndex`` — epoch-versioned CgrxIndex snapshot +
                NodeStore delta; insert/delete/lookup/range_lookup with
                every read served through the batched rank engine
                (``NodeIndexView`` adapts chains to the 'node' backend);
``compaction``  trigger policy (chain length / fill factor / tombstone
                ratio) + the begin/finish epoch-swap task that rebuilds
                off the read path and replays mid-compaction writes;
``metrics``     ``LiveStats``, the operator-facing stats surface;
``frontend``    DEPRECATED ``LiveFrontend`` — the tick-based mixed-op
                queue is now the built-in execution model of the unified
                session API (``repro.db.Session.flush``); this shim
                adopts an existing store into a Session and keeps the
                historical ticket/tick surface alive;
``sharded``     ``ShardedLiveStore`` — the range-partitioned serving
                tier: splitter-routed LiveIndex shards, cross-shard range
                decomposition + rank-offset merge, per-shard compaction
                and the skew-triggered splitter rebalance;
``arena``       ``EmbeddingArena`` — the device-resident rowID-addressed
                vector payload buffer behind the vector tier
                (``repro.vector``); the index holds (centroidID, rowID)
                keys, the arena holds the embeddings they point at;
``wal``         segmented write-ahead log of ``apply_batch`` inputs —
                append + fsync BEFORE the device dispatch; the recovery
                primitive behind ``IndexSpec(durability=...)``;
``replica``     ``ReadReplica``/``ReplicaSet`` — epoch-lagged read
                replicas rebuilt from the same snapshot + WAL stream,
                with heartbeat staleness tracking and straggler-driven
                failover (``runtime/ft.py``).

See docs/ARCHITECTURE.md ("Live store", "Sharded serving tier") for the
epoch and routing diagrams.
"""
from .arena import EmbeddingArena
from .compaction import CompactionPolicy, CompactionTask, should_compact
from .frontend import LiveFrontend, TickReport
from .live import LiveConfig, LiveIndex, NodeIndexView
from .metrics import LiveStats, ShardedStats, collect, collect_sharded
from .replica import ReadReplica, ReplicaSet
from .sharded import ShardedConfig, ShardedLiveStore
from .wal import WalCorruptError, WalError, WalRecord, WriteAheadLog

__all__ = [
    "CompactionPolicy",
    "CompactionTask",
    "EmbeddingArena",
    "LiveConfig",
    "LiveFrontend",
    "LiveIndex",
    "LiveStats",
    "NodeIndexView",
    "ReadReplica",
    "ReplicaSet",
    "ShardedConfig",
    "ShardedLiveStore",
    "ShardedStats",
    "TickReport",
    "WalCorruptError",
    "WalError",
    "WalRecord",
    "WriteAheadLog",
    "collect",
    "collect_sharded",
    "should_compact",
]
