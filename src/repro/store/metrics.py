"""Stats surface of the live index store.

One flat snapshot per call — the numbers an operator (or the compaction
policy, store/compaction.py) needs to reason about a long-lived updatable
index: where the epoch is, how degraded the chains are, how much memory
the two structures pin, and how much update traffic has accumulated since
the last compaction.  Collected host-side; the only device sync is the
live-key count (one small reduction).

``ShardedStats`` is the rollup over a range-partitioned store
(store/sharded.py): one ``LiveStats`` per shard plus the aggregates the
router and skew monitor act on (fill imbalance, per-shard epochs,
rebalance count).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class LiveStats:
    """Point-in-time stats of a ``LiveIndex`` (see ``collect``)."""

    epoch: int                 # compaction generation of the snapshot
    live_keys: int             # keys currently lookup-able
    num_buckets: int           # immutable bucket/rep count of this epoch
    max_chain: int             # static chain-length bound (walk cost)
    allocated_nodes: int       # nodes in use (rep region + linked region)
    node_cap: int              # slots per node
    store_bytes: int           # node slab + rep + tree footprint
    snapshot_bytes: int        # immutable CgrxIndex snapshot footprint
    applies: int               # apply_batch calls since build
    inserts: int               # keys submitted for insert since build
    deletes: int               # keys submitted for delete since build
    deletes_since_compact: int  # tombstone pressure driving compaction
    compactions: int           # epoch swaps completed
    compacting: bool           # a background compaction is in flight

    @property
    def fill_factor(self) -> float:
        """Live keys per allocated slot — low values mean wasted slab."""
        slots = self.allocated_nodes * self.node_cap
        return self.live_keys / slots if slots else 0.0

    @property
    def tombstone_ratio(self) -> float:
        """Deletes since the last compaction relative to the live set."""
        return self.deletes_since_compact / max(self.live_keys, 1)

    @property
    def total_bytes(self) -> int:
        return self.store_bytes + self.snapshot_bytes


@dataclasses.dataclass(frozen=True)
class ShardedStats:
    """Rollup over a ``ShardedLiveStore``: per-shard snapshots + the
    aggregates the operator and the skew monitor reason about."""

    num_shards: int
    shards: Tuple[LiveStats, ...]   # index = shard id (key-range order)
    rebalances: int                 # splitter recomputations since build
    applies: int                    # routed apply() calls since build
    inserts: int                    # keys submitted for insert since build
    deletes: int                    # keys submitted for delete since build
    migrations: int = 0             # incremental migrate_step ticks
    touch_rates: Tuple[float, ...] = ()  # per-shard key-touch EWMA (the
                                    # store's TouchTracker snapshot)

    @property
    def live_keys(self) -> int:
        return sum(s.live_keys for s in self.shards)

    @property
    def total_bytes(self) -> int:
        return sum(s.total_bytes for s in self.shards)

    @property
    def compactions(self) -> int:
        return sum(s.compactions for s in self.shards)

    @property
    def epochs(self) -> Tuple[int, ...]:
        """Per-shard epoch counters — independent by design: a hot shard
        epoch-swaps without its siblings moving."""
        return tuple(s.epoch for s in self.shards)

    @property
    def shard_live(self) -> Tuple[int, ...]:
        return tuple(s.live_keys for s in self.shards)

    @property
    def imbalance(self) -> float:
        """Max shard fill over the balanced mean — the SIZE axis of skew
        (1.0 = perfectly balanced).  Size alone can be fooled: a
        balanced-size store can still serve nearly all its traffic from
        one shard, which is what ``touch_imbalance`` sees."""
        mean = self.live_keys / max(self.num_shards, 1)
        return max(self.shard_live) / mean if mean else 0.0

    @property
    def touch_imbalance(self) -> float:
        """Max shard touch rate over the balanced mean — the LOAD axis
        of skew, from the store's per-shard key-touch EWMA (1.0 =
        balanced, 0.0 = no traffic observed yet).  The migration
        trigger reads BOTH axes so a balanced-size/hot-shard workload
        still rebalances."""
        total = sum(self.touch_rates)
        if total <= 0.0 or not self.touch_rates:
            return 0.0
        mean = total / len(self.touch_rates)
        return max(self.touch_rates) / mean

    @property
    def compacting(self) -> bool:
        return any(s.compacting for s in self.shards)

    @property
    def max_chain(self) -> int:
        return max(s.max_chain for s in self.shards)


def collect(live) -> LiveStats:
    """Build a ``LiveStats`` from a ``LiveIndex`` (duck-typed to avoid an
    import cycle: live.py imports this module for the return type)."""
    from repro.core import cgrx as cgrx_mod

    store = live.store
    return LiveStats(
        epoch=live.epoch,
        live_keys=live.live_keys,
        num_buckets=store.num_buckets,
        max_chain=store.max_chain,
        allocated_nodes=store.free_ptr,
        node_cap=store.node_cap,
        store_bytes=store.nbytes["total_bytes"],
        snapshot_bytes=cgrx_mod.index_nbytes(live.snapshot)["total_bytes"],
        applies=live.applies,
        inserts=live.inserts,
        deletes=live.deletes,
        deletes_since_compact=live.deletes_since_compact,
        compactions=live.compactions,
        compacting=live.compacting,
    )


def collect_sharded(store) -> ShardedStats:
    """Build a ``ShardedStats`` from a ``ShardedLiveStore`` (duck-typed,
    same import-cycle reasoning as ``collect``)."""
    touch = getattr(store, "touch", None)
    return ShardedStats(
        num_shards=store.num_shards,
        shards=tuple(collect(s) for s in store.shards),
        rebalances=store.rebalances,
        applies=store.applies,
        inserts=store.inserts,
        deletes=store.deletes,
        migrations=getattr(store, "migrations", 0),
        touch_rates=touch.snapshot() if touch is not None else (),
    )
