"""Write-ahead log of ``apply_batch`` inputs (the durability primitive).

The live tiers' whole update story (paper Sec. 4: bucket-local chains,
up to 5.6x faster than rebuilding) is memory-only without this module: a
process death loses every epoch and chain delta.  The WAL closes that
hole with the classic contract — every mixed insert/delete batch is
appended and **fsynced here before the device dispatch runs**, so the
on-disk log is always a superset of what any reader was ever served,
and

    recovery = latest durable snapshot + replay of the WAL tail

reproduces a store whose lookups, ranges and rank scans are bit-identical
to the uncrashed one (tests/test_wal_recovery.py kills at every record
boundary).  Query results depend only on the live key multiset, which the
log replays exactly; physical layout (chains, bucket ids) may differ —
the same already-documented freedom the sharded tier's merge has.

Layout: a log is a DIRECTORY of sequence-numbered segment files
(``seg-<first_seq:012d>.wal``).  A writer always opens a *new* segment
(never appends after a possibly-torn tail), sealing the previous one;
``prune(upto_seq)`` drops segments wholly covered by a durable snapshot.
Record framing (little-endian)::

    magic u32 | seq u64 | epoch u32 | part u16 | nparts u16 | flags u8
    | n_ins u32 | n_del u32 | crc u32 (of payload)
    payload: ins_lo u32[n_ins] [ins_hi u32[n_ins]] ins_rows i32[n_ins]
             del_lo u32[n_del] [del_hi u32[n_del]]

``part``/``nparts`` group the per-shard records of ONE store-level apply
(``ShardedLiveStore`` keeps a per-shard log; the group is the atomic
replay unit).  A torn record at the tail of the LAST segment is a crash
mid-append — the dispatch for it never ran, so replay stops there; any
earlier decode failure is real corruption and raises ``WalCorruptError``.

This module must not import ``repro.db`` (the db layer imports the store
layer); the typed ``repro.db.errors.RecoveryError`` wraps these errors at
the session boundary.
"""
from __future__ import annotations

import dataclasses
import os
import struct
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.keys import KeyArray

MAGIC = 0x57414C31                      # "WAL1"
_HEADER = struct.Struct("<IQIHHBIII")   # magic seq epoch part nparts
                                        # flags n_ins n_del crc
_FLAG_IS64 = 1


class WalError(RuntimeError):
    """Base class for write-ahead-log failures."""


class WalCorruptError(WalError):
    """A record failed to decode somewhere other than the torn tail of
    the last segment — the log is damaged, not merely crash-truncated."""


@dataclasses.dataclass(frozen=True)
class WalRecord:
    """One logged ``apply_batch`` input, as host arrays.

    ``seq`` orders records globally; ``part``/``nparts`` tie together the
    per-shard pieces of one store-level apply (1/1 for a single store).
    Key words are kept as the same (lo, hi) uint32 pairs the device uses,
    so encode→decode is exact for 32- and 64-bit key sets alike.
    """

    seq: int
    epoch: int
    part: int
    nparts: int
    is64: bool
    ins_lo: np.ndarray
    ins_hi: Optional[np.ndarray]
    ins_rows: np.ndarray
    del_lo: np.ndarray
    del_hi: Optional[np.ndarray]

    @property
    def n_ins(self) -> int:
        return int(self.ins_lo.shape[0])

    @property
    def n_del(self) -> int:
        return int(self.del_lo.shape[0])

    def ins_keys(self) -> Optional[KeyArray]:
        if not self.n_ins:
            return None
        return _to_keys(self.ins_lo, self.ins_hi)

    def del_keys(self) -> Optional[KeyArray]:
        if not self.n_del:
            return None
        return _to_keys(self.del_lo, self.del_hi)

    def ins_row_array(self):
        import jax.numpy as jnp
        return jnp.asarray(self.ins_rows) if self.n_ins else None


def _to_keys(lo: np.ndarray, hi: Optional[np.ndarray]) -> KeyArray:
    import jax.numpy as jnp
    return KeyArray(jnp.asarray(lo),
                    None if hi is None else jnp.asarray(hi))


def _host_parts(keys: Optional[KeyArray]
                ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    if keys is None:
        return np.zeros(0, np.uint32), None
    lo = np.asarray(keys.lo, dtype=np.uint32)
    hi = np.asarray(keys.hi, dtype=np.uint32) if keys.is64 else None
    return lo, hi


def encode_record(seq: int, epoch: int, part: int, nparts: int,
                  ins_keys: Optional[KeyArray], ins_rows,
                  del_keys: Optional[KeyArray]) -> bytes:
    ilo, ihi = _host_parts(ins_keys)
    dlo, dhi = _host_parts(del_keys)
    is64 = (ihi is not None) or (dhi is not None)
    if is64:                             # a mixed-width batch is a caller bug
        if ilo.shape[0] and ihi is None:
            raise WalError("mixed 32/64-bit keys in one WAL record")
        if dlo.shape[0] and dhi is None:
            raise WalError("mixed 32/64-bit keys in one WAL record")
    rows = (np.asarray(ins_rows, dtype=np.int32) if ilo.shape[0]
            else np.zeros(0, np.int32))
    if rows.shape[0] != ilo.shape[0]:
        raise WalError(
            f"{ilo.shape[0]} insert keys but {rows.shape[0]} rows")
    chunks = [ilo.tobytes()]
    if is64:
        chunks.append((ihi if ihi is not None
                       else np.zeros(0, np.uint32)).tobytes())
    chunks.append(rows.tobytes())
    chunks.append(dlo.tobytes())
    if is64:
        chunks.append((dhi if dhi is not None
                       else np.zeros(0, np.uint32)).tobytes())
    payload = b"".join(chunks)
    header = _HEADER.pack(MAGIC, seq, epoch, part, nparts,
                          _FLAG_IS64 if is64 else 0,
                          ilo.shape[0], dlo.shape[0],
                          zlib.crc32(payload) & 0xFFFFFFFF)
    return header + payload


def _decode_one(buf: bytes, off: int) -> Tuple[Optional[WalRecord], int]:
    """Decode the record at ``off``; (None, off) on a torn tail."""
    if off + _HEADER.size > len(buf):
        return None, off
    (magic, seq, epoch, part, nparts, flags,
     n_ins, n_del, crc) = _HEADER.unpack_from(buf, off)
    if magic != MAGIC:
        raise WalCorruptError(f"bad record magic at byte {off}")
    is64 = bool(flags & _FLAG_IS64)
    # u32 words per key: insert = lo [+ hi] + row, delete = lo [+ hi].
    size = 4 * (n_ins * (3 if is64 else 2) + n_del * (2 if is64 else 1))
    start = off + _HEADER.size
    if start + size > len(buf):
        return None, off
    payload = buf[start:start + size]
    if zlib.crc32(payload) & 0xFFFFFFFF != crc:
        # A torn final write can leave a full-length but half-flushed
        # payload; the caller decides whether tail position excuses it.
        return None, off
    p = 0

    def take(n, dtype):
        nonlocal p
        arr = np.frombuffer(payload, dtype=dtype, count=n, offset=p).copy()
        p += 4 * n
        return arr

    ins_lo = take(n_ins, np.uint32)
    ins_hi = take(n_ins, np.uint32) if is64 else None
    ins_rows = take(n_ins, np.int32)
    del_lo = take(n_del, np.uint32)
    del_hi = take(n_del, np.uint32) if is64 else None
    rec = WalRecord(seq=seq, epoch=epoch, part=part, nparts=nparts,
                    is64=is64, ins_lo=ins_lo, ins_hi=ins_hi,
                    ins_rows=ins_rows, del_lo=del_lo, del_hi=del_hi)
    return rec, start + size


# ---------------------------------------------------------------------------
# The log itself.
# ---------------------------------------------------------------------------

def _seg_name(first_seq: int) -> str:
    return f"seg-{first_seq:012d}.wal"


def _fsync_dir(path: str) -> None:
    """fsync a directory so entry creation/removal survives a crash."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _truncate_torn_tail(path: str) -> None:
    """Cut a segment back to its longest decodable prefix (fsynced)."""
    with open(path, "rb") as f:
        buf = f.read()
    off = 0
    while off < len(buf):
        rec, new_off = _decode_one(buf, off)
        if rec is None:
            break
        off = new_off
    if off < len(buf):
        with open(path, "rb+") as f:
            f.truncate(off)
            f.flush()
            os.fsync(f.fileno())


def _segments(directory: str) -> List[Tuple[int, str]]:
    out = []
    for name in os.listdir(directory):
        if name.startswith("seg-") and name.endswith(".wal"):
            out.append((int(name[4:-4]), os.path.join(directory, name)))
    return sorted(out)


class WriteAheadLog:
    """Appender over one segment directory (see module doc).

    ``append`` is the durability point: encode, write, flush, ``fsync``
    — all BEFORE the caller runs the device dispatch the record
    describes.  ``sync=False`` defers the fsync so a multi-record group
    can be made durable with one ``sync()`` per touched file.
    """

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        segs = _segments(directory)
        if segs:
            # Never append after a possibly-torn tail.  The torn record
            # is only excusable while its segment is the LAST one, and
            # the fresh segment this writer opens ends that — so drop
            # the tail now, then start one past the last decodable seq.
            _truncate_torn_tail(segs[-1][1])
            records, _ = read_records(directory)
            self.next_seq = (records[-1].seq + 1) if records else segs[-1][0]
        else:
            self.next_seq = 0
        self._file = None

    def _open_segment(self) -> None:
        path = os.path.join(self.dir, _seg_name(self.next_seq))
        self._file = open(path, "ab")
        _fsync_dir(self.dir)             # the new entry itself is durable

    def append(self, ins_keys: Optional[KeyArray], ins_rows,
               del_keys: Optional[KeyArray], *, epoch: int = 0,
               seq: Optional[int] = None, part: int = 0, nparts: int = 1,
               sync: bool = True) -> int:
        if self._file is None:
            self._open_segment()
        seq = self.next_seq if seq is None else seq
        self._file.write(encode_record(seq, epoch, part, nparts,
                                       ins_keys, ins_rows, del_keys))
        self.next_seq = max(self.next_seq, seq + 1)
        if sync:
            self.sync()
        return seq

    def sync(self) -> None:
        if self._file is not None:
            self._file.flush()
            os.fsync(self._file.fileno())

    def seal(self) -> None:
        """Close the open segment (fsynced); the next append starts a
        new one.  Part of the session ``close()`` contract."""
        if self._file is not None:
            self.sync()
            self._file.close()
            self._file = None

    close = seal

    def prune(self, upto_seq: int) -> None:
        """Drop sealed segments every record of which has seq <=
        ``upto_seq`` (i.e. is covered by a durable snapshot).  A segment's
        coverage ends where the next segment begins, so only segments
        with a successor can be proven complete."""
        segs = _segments(self.dir)
        open_path = (self._file.name if self._file is not None else None)
        removed = False
        for (first, path), (nxt, _) in zip(segs, segs[1:]):
            if path != open_path and nxt <= upto_seq + 1:
                os.remove(path)
                removed = True
            else:
                break
        if removed:
            _fsync_dir(self.dir)


def read_records(directory: str, from_seq: int = 0
                 ) -> Tuple[List[WalRecord], bool]:
    """Decode every record with ``seq >= from_seq``, in write order.

    Returns ``(records, truncated)`` — ``truncated`` is True when the
    last segment ended in a torn record (crash mid-append; the records
    before it are still valid).  Corruption anywhere else raises
    ``WalCorruptError``.
    """
    if not os.path.isdir(directory):
        return [], False
    segs = _segments(directory)
    out: List[WalRecord] = []
    truncated = False
    for i, (first, path) in enumerate(segs):
        with open(path, "rb") as f:
            buf = f.read()
        off = 0
        while off < len(buf):
            rec, new_off = _decode_one(buf, off)
            if rec is None:
                if i == len(segs) - 1:
                    truncated = True
                    break
                raise WalCorruptError(
                    f"undecodable record at byte {off} of {path} "
                    f"(not the final segment)")
            if rec.seq >= from_seq:
                out.append(rec)
            off = new_off
    return out, truncated


def read_groups(directories: List[str], from_seq: int = 0
                ) -> List[List[Tuple[int, WalRecord]]]:
    """Merge per-shard logs into complete apply groups.

    Returns a list of groups ordered by seq; each group is the list of
    ``(shard_id, record)`` pairs of one store-level apply, sorted by
    ``part``.  An INCOMPLETE group (fewer records than its ``nparts``
    claims) is tolerated only at the maximum seq — that is the crash
    point, and since the dispatch for the group never completed its
    fsync set, replay drops it.  Incompleteness anywhere else raises
    ``WalCorruptError``.
    """
    by_seq: Dict[int, List[Tuple[int, WalRecord]]] = {}
    for shard_id, d in enumerate(directories):
        records, _ = read_records(d, from_seq)
        for rec in records:
            by_seq.setdefault(rec.seq, []).append((shard_id, rec))
    groups = []
    seqs = sorted(by_seq)
    for seq in seqs:
        parts = sorted(by_seq[seq], key=lambda p: p[1].part)
        want = parts[0][1].nparts
        if len(parts) != want:
            if seq == seqs[-1]:
                break                    # torn group at the crash point
            raise WalCorruptError(
                f"apply group seq={seq} has {len(parts)} of {want} "
                f"per-shard records (not the final group)")
        groups.append(parts)
    return groups
