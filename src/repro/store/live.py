"""LiveIndex: a long-lived, updatable, queryable cgRX index.

The paper proves the *mechanism* (Sec. 4: bucket-local chain updates under
an immutable accelerated structure, up to 5.6x faster than rebuilding);
this module supplies the *lifecycle* that makes the mechanism a store:

    epoch snapshot (immutable CgrxIndex)  +  node-chain delta (NodeStore)
    -----------------------------------------------------------------
    insert/delete   ->  nodes.apply_batch   (bucket-local, reps untouched)
    lookup/range    ->  query.RankEngine over the 'node' backend
                        (chain-aware rank; see NodeIndexView below)
    point-in-time   ->  snapshot_reader(): the epoch base as a consistent
                        immutable view (excludes the chain delta)
    degradation     ->  compaction policy fires -> extract() a consistent
                        cut -> bulk-load a fresh epoch off the read path
                        -> replay mid-compaction writes -> swap

Every read is served through the batched rank engine (repro.query): the
``NodeIndexView`` adapts a ``NodeStore`` to the engine's duck-typed index
protocol — rep search + chain-walk rank via the registered 'node' backend,
and rank->result post-processing (``lookup_from_rank``/``range_from_ranks``)
via the chain-position walk, which is what makes *range lookups over the
updatable store* possible at all: a global rank maps to (bucket, node,
slot) through the bucket-count prefix and a static ``max_chain``-bounded
descent, exactly the shape of ``nodes.lookup``.

Results are bit-identical to a from-scratch ``cgrx.build`` over the same
live set (tests/test_live_store.py): ranks agree because both rank the
same sorted multiset, rows agree because chain-linearized order IS sorted
order.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cgrx, nodes
from repro.core.keys import KeyArray, key_eq, sort_with_payload
from repro.query import QueryBatch, RankEngine

from . import metrics
from .compaction import CompactionPolicy, CompactionTask, should_compact

NO_NODE = int(nodes.NO_NODE)
MISS = nodes.MISS


@jax.tree_util.register_pytree_node_class
class NodeIndexView:
    """Adapts a ``NodeStore`` to the query engine's index protocol.

    Provides (a) the attributes the 'node' backend ranks against —
    ``reps``/``tree``/``node_*``/``bucket_prefix`` — and (b) the
    rank->result hooks the engine post-processes with.  Registered as a
    pytree so the engine can pass it as a jit ARGUMENT: the store re-binds
    buffers on every update batch, and argument-passing lets successive
    versions reuse one compiled executable (see query/engine.py's shared
    cache) instead of re-tracing closure-captured constants.  Static walk
    bounds (``node_cap``/``max_chain``/``num_buckets``) live in the
    pytree aux data, so only a chain-growth or slab-growth event retraces.
    """

    def __init__(self, store: nodes.NodeStore, rep_method: str = "tree"):
        self.method = "node"          # RankEngine's default backend name
        self.rep_method = rep_method  # 'tree' | 'binary' | 'kernel'
        # Chain-aware rank surface (see query.backends.NodeBackend).
        self.reps = store.reps
        self.tree = store.tree
        self.node_keys = store.node_keys
        self.node_rows = store.node_rows
        self.node_next = store.node_next
        self.node_size = store.node_size
        self.node_cap = store.node_cap
        self.max_chain = store.max_chain
        self.num_buckets = store.num_buckets
        incl = jnp.cumsum(store.bucket_count.astype(jnp.int32))
        self.bucket_prefix = incl - store.bucket_count  # exclusive, (nb,)
        self.n_dev = incl[-1]                           # live total (device)

    # -- pytree protocol ------------------------------------------------------

    def tree_flatten(self):
        children = (self.node_keys, self.node_rows, self.node_next,
                    self.node_size, self.reps, self.tree,
                    self.bucket_prefix, self.n_dev)
        aux = (self.node_cap, self.max_chain, self.num_buckets,
               self.rep_method, self.method)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        view = object.__new__(cls)
        (view.node_keys, view.node_rows, view.node_next, view.node_size,
         view.reps, view.tree, view.bucket_prefix, view.n_dev) = children
        (view.node_cap, view.max_chain, view.num_buckets,
         view.rep_method, view.method) = aux
        return view

    @property
    def n(self) -> int:
        """Host live-key count (one small device sync)."""
        return int(self.n_dev)

    # -- rank -> (bucket, node, slot) -----------------------------------------

    def _locate(self, pos: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray,
                                                 jnp.ndarray]:
        """Map global live-order positions to chain slots.

        Bucket = rightmost b with prefix[b] <= pos (searchsorted 'right'
        naturally skips emptied buckets), then a bounded chain descent
        subtracting node sizes — the mirror image of the rank walk.
        """
        b = jnp.searchsorted(self.bucket_prefix, pos, side="right") - 1
        b = jnp.clip(b, 0, self.num_buckets - 1).astype(jnp.int32)
        rem = pos.astype(jnp.int32) - jnp.take(self.bucket_prefix, b,
                                               mode="clip")
        node = b
        for _ in range(max(self.max_chain - 1, 0)):
            sz = self.node_size[node]
            nxt = self.node_next[node]
            go = (rem >= sz) & (nxt != NO_NODE)
            rem = jnp.where(go, rem - sz, rem)
            node = jnp.where(go, nxt, node)
        slot = jnp.minimum(rem, self.node_cap - 1)
        return b, node, slot

    # -- engine post-processing hooks -----------------------------------------

    def lookup_from_rank(self, pos: jnp.ndarray,
                         queries: KeyArray) -> cgrx.LookupResult:
        """rank_left positions -> LookupResult over the chained store
        (the node-store analogue of ``cgrx.lookup_from_rank``)."""
        in_range = pos < self.n_dev
        safe = jnp.minimum(pos, jnp.maximum(self.n_dev - 1, 0))
        b, node, slot = self._locate(safe)
        flat = node * self.node_cap + slot
        hit_keys = self.node_keys.reshape(-1).take(flat)
        found = in_range & key_eq(hit_keys, queries)
        row = jnp.where(found, self.node_rows.reshape(-1)[flat], MISS)
        return cgrx.LookupResult(bucket_id=b.astype(jnp.int32),
                                 row_id=row.astype(jnp.int32),
                                 found=found,
                                 position=pos.astype(jnp.int32))

    def range_from_ranks(self, start: jnp.ndarray, end: jnp.ndarray,
                         max_hits: int) -> cgrx.RangeResult:
        """(rank_left(lo), rank_right(hi)) -> RangeResult by walking the
        touched chains: each of the ``max_hits`` candidate positions is
        located independently (static-shape gather), so one range costs
        O(max_hits * max_chain) lane work — the chained-store analogue of
        the paper's 'one successor search + sequential scan' (Sec. 3.2)."""
        count = jnp.maximum(end - start, 0)
        offs = start[..., None] + jnp.arange(max_hits, dtype=jnp.int32)
        valid = jnp.arange(max_hits, dtype=jnp.int32) < count[..., None]
        safe = jnp.minimum(offs, jnp.maximum(self.n_dev - 1, 0))
        _, node, slot = self._locate(safe)
        rows = self.node_rows.reshape(-1)[node * self.node_cap + slot]
        rows = jnp.where(valid, rows, MISS)
        return cgrx.RangeResult(start=start.astype(jnp.int32),
                                count=count.astype(jnp.int32), row_ids=rows)

    def agg_from_ranks(self, start: jnp.ndarray, end: jnp.ndarray,
                       with_keys: bool = False) -> cgrx.AggResult:
        """(rank_left(lo), rank_right(hi)) -> AggResult over the chained
        store.  COUNT is a subtraction of the ranks; MIN/MAX locate one
        chain slot per endpoint (two bounded descents) instead of the
        ``max_hits``-wide rowID walk ``range_from_ranks`` performs."""
        count = jnp.maximum(end - start, 0).astype(jnp.int32)
        if not with_keys:
            return cgrx.AggResult(count=count, min_key=None, max_key=None)
        last = jnp.maximum(self.n_dev - 1, 0)
        flat_keys = self.node_keys.reshape(-1)
        _, node_l, slot_l = self._locate(jnp.minimum(start, last))
        _, node_h, slot_h = self._locate(jnp.clip(end - 1, 0, last))
        min_key = flat_keys.take(node_l * self.node_cap + slot_l)
        max_key = flat_keys.take(node_h * self.node_cap + slot_h)
        return cgrx.AggResult(count=count, min_key=min_key, max_key=max_key)


@dataclasses.dataclass(frozen=True)
class LiveConfig:
    """Build/serve knobs of a ``LiveIndex``."""

    node_cap: int = 32                  # N: slots per chain node
    fill: Optional[int] = None          # bulk-load fill (default N/2)
    snapshot_bucket_size: int = 16      # B of the immutable epoch snapshot
    rep_method: str = "tree"            # successor search: tree|binary|kernel
    policy: CompactionPolicy = dataclasses.field(
        default_factory=CompactionPolicy)
    auto_compact: bool = True           # evaluate policy after every apply
    jit: bool = True                    # jit the engine: the view is a
                                        # pytree jit ARGUMENT, so store
                                        # versions share one executable
    cache_scope: Optional[str] = None   # executable-cache namespace; the
                                        # sharded store binds one scope for
                                        # all shards so they share compiled
                                        # pipelines (query/engine.py)


class LiveIndex:
    """One long-lived updatable index: epoch snapshot + chain delta.

    All state transitions are functional underneath (``nodes.apply_batch``
    returns a new ``NodeStore``); this handle owns the current version,
    the epoch counter, the compaction lifecycle and the engine cache.

    Usage::

        live = LiveIndex.build(keys, rows)
        live.insert(new_keys, new_rows)
        live.delete(old_keys)                       # policy may compact
        res = live.lookup(point_keys)               # via RankEngine
        rng = live.range_lookup(lo, hi, max_hits=64)
        live.stats()                                # metrics.LiveStats
    """

    def __init__(self, store: nodes.NodeStore, snapshot: cgrx.CgrxIndex,
                 config: LiveConfig, epoch: int = 0):
        self.store = store
        self.snapshot = snapshot
        self.config = config
        self.epoch = epoch
        # metrics counters (read by store/metrics.collect)
        self.applies = 0
        self.inserts = 0
        self.deletes = 0
        self.deletes_since_compact = 0
        self.compactions = 0
        # Durability hook: when a WriteAheadLog (store/wal.py) is
        # attached, every apply() is appended + fsynced to it BEFORE the
        # device dispatch runs (db/tiers.py attaches it; None = the
        # memory-only store this module always was).
        self.wal = None
        self._task: Optional[CompactionTask] = None
        self._view: Optional[NodeIndexView] = None
        self._engine: Optional[RankEngine] = None

    # -- construction ---------------------------------------------------------

    @classmethod
    def build(cls, keys: KeyArray, row_ids: Optional[jnp.ndarray] = None,
              config: Optional[LiveConfig] = None,
              *, presorted: bool = False) -> "LiveIndex":
        cfg = config or LiveConfig()
        if row_ids is None:
            row_ids = jnp.arange(keys.shape[0], dtype=jnp.int32)
        if not presorted:  # one construction sort feeds both structures
            keys, row_ids = sort_with_payload(keys,
                                              row_ids.astype(jnp.int32))
        store = nodes.build(keys, row_ids, cfg.node_cap, fill=cfg.fill,
                            presorted=True)
        snapshot = cgrx.build(keys, row_ids, cfg.snapshot_bucket_size,
                              presorted=True)
        return cls(store, snapshot, cfg)

    # -- durable cut / restore ------------------------------------------------

    def live_cut(self) -> Tuple[KeyArray, jnp.ndarray]:
        """A consistent sorted cut of the live set (keys, rows) — the
        snapshot payload.  The cut is the LOGICAL state: persisting it
        instead of the physical slab keeps snapshots layout-independent
        (a restore bulk-loads fresh flat chains), exactly how an epoch
        swap already rebuilds, so query results cannot drift."""
        skeys, srows, n_live = nodes.extract(self.store)
        return skeys[:n_live], srows[:n_live]

    @classmethod
    def from_cut(cls, keys: KeyArray, rows: jnp.ndarray,
                 config: Optional[LiveConfig] = None, *, epoch: int = 0,
                 counters: Optional[dict] = None) -> "LiveIndex":
        """Rebuild a store from a persisted ``live_cut`` (already
        sorted).  ``counters`` restores the update-traffic counters so
        stats continuity and compaction pressure survive recovery."""
        live = cls.build(keys, rows, config, presorted=True)
        live.epoch = epoch
        for name in ("applies", "inserts", "deletes",
                     "deletes_since_compact", "compactions"):
            if counters and name in counters:
                setattr(live, name, int(counters[name]))
        return live

    def counter_state(self) -> dict:
        """The counters ``from_cut`` restores (snapshot meta payload)."""
        return {"applies": self.applies, "inserts": self.inserts,
                "deletes": self.deletes,
                "deletes_since_compact": self.deletes_since_compact,
                "compactions": self.compactions}

    # -- engine plumbing ------------------------------------------------------

    def _invalidate(self) -> None:
        self._view = None
        self._engine = None

    @property
    def view(self) -> NodeIndexView:
        if self._view is None:
            self._view = NodeIndexView(self.store, self.config.rep_method)
        return self._view

    @property
    def engine(self) -> RankEngine:
        """RankEngine bound to the current store version.  Rebuilt after
        every update, but because the view is a pytree the engine passes
        it as a jit argument — successive versions with unchanged static
        bounds reuse one compiled executable."""
        if self._engine is None:
            self._engine = RankEngine(self.view, jit=self.config.jit,
                                      cache_scope=self.config.cache_scope)
        return self._engine

    def sync(self) -> None:
        """Block until the current store version's buffers are ready (the
        frontend's per-tick fence; duck-typed — ShardedLiveStore fences
        every shard)."""
        jax.block_until_ready(self.store.node_keys.lo)

    @property
    def live_keys(self) -> int:
        return self.view.n

    @property
    def compacting(self) -> bool:
        return self._task is not None

    # -- reads (all through the rank engine) ----------------------------------

    def lookup(self, queries: KeyArray) -> cgrx.LookupResult:
        return self.engine.lookup(queries)

    def range_lookup(self, lo: KeyArray, hi: KeyArray,
                     max_hits: int = 64) -> cgrx.RangeResult:
        return self.engine.range_lookup(lo, hi, max_hits)

    def execute(self, plan):
        """Serve a planned mixed point/range batch (``query.QueryBatch``)
        in one engine call."""
        return self.engine.execute(plan)

    def batch(self) -> QueryBatch:
        return QueryBatch()

    def snapshot_reader(self, backend: Optional[str] = None) -> RankEngine:
        """Point-in-time reader over this epoch's immutable snapshot.

        The snapshot is the live set as of the last epoch swap (build or
        compaction) — it deliberately excludes the chain delta, so a
        long-running scan can keep a consistent view while the store
        keeps mutating.  Served by any flat backend (default: the
        config's rep method when flat, else 'tree')."""
        name = backend or (self.config.rep_method
                           if self.config.rep_method != "node" else "tree")
        return RankEngine(self.snapshot, backend=name, jit=self.config.jit)

    # -- online retuning (tuning/autotune.py acts through these) --------------

    def set_rep_method(self, name: str) -> None:
        """Re-point the successor-search backend of the rep stage
        ('tree' | 'binary' | 'kernel').  Cheap: the chain slab is
        untouched — only the view/engine rebind, and the next dispatch
        traces (or cache-hits) the new backend's pipeline."""
        if name == self.config.rep_method:
            return
        self.config = dataclasses.replace(self.config, rep_method=name)
        self._invalidate()

    def retune_bucket_size(self, bucket_size: int) -> None:
        """Adopt a new snapshot bucket size via the existing epoch-swap
        path: extract a consistent cut, bulk-load the new geometry,
        swap.  Reads never observe a half-built epoch — the same safety
        argument as any compaction."""
        if bucket_size < 1:
            raise ValueError(f"bucket_size must be >= 1, got {bucket_size}")
        if bucket_size == self.config.snapshot_bucket_size:
            return
        self.config = dataclasses.replace(
            self.config, snapshot_bucket_size=bucket_size)
        self.compact("retune")

    # -- writes ---------------------------------------------------------------

    def apply(self, ins_keys: Optional[KeyArray] = None,
              ins_rows: Optional[jnp.ndarray] = None,
              del_keys: Optional[KeyArray] = None,
              *, auto_compact: Optional[bool] = None) -> Optional[str]:
        """Apply one mixed insert/delete batch.

        ``nodes.apply_batch`` multiset semantics (the paper's unique-key
        workloads): a key in both batches cancels pairwise (any
        pre-existing copy survives); inserting an already-live key adds a
        DUPLICATE (lookup keeps returning the older copy's row) and a
        delete removes every copy of its key — to re-key, delete in one
        batch and insert in the next.  Returns the firing compaction
        trigger's name when the policy compacted, else None.
        """
        if self.wal is not None:
            # Durability point: the batch is on disk before any device
            # state changes, so a crash at ANY later point replays it.
            self.wal.append(ins_keys, ins_rows, del_keys, epoch=self.epoch)
        self.store = nodes.apply_batch(self.store, ins_keys, ins_rows,
                                       del_keys)
        self._invalidate()
        self.applies += 1
        n_ins = int(ins_keys.shape[0]) if ins_keys is not None else 0
        n_del = int(del_keys.shape[0]) if del_keys is not None else 0
        self.inserts += n_ins
        self.deletes += n_del
        self.deletes_since_compact += n_del
        if self._task is not None:
            # Mid-compaction write: lands in the current epoch (reads see
            # it immediately) AND is replayed onto the new epoch at swap.
            self._task.replay.append((ins_keys, ins_rows, del_keys))
            return None
        ac = self.config.auto_compact if auto_compact is None else auto_compact
        if ac:
            return self.maybe_compact()
        return None

    def insert(self, keys: KeyArray, rows: jnp.ndarray) -> Optional[str]:
        return self.apply(ins_keys=keys, ins_rows=rows)

    def delete(self, keys: KeyArray) -> Optional[str]:
        return self.apply(del_keys=keys)

    # -- compaction lifecycle (epoch swap) ------------------------------------

    def stats(self) -> metrics.LiveStats:
        return metrics.collect(self)

    def maybe_compact(self) -> Optional[str]:
        """Evaluate the policy; run a full (begin+finish) compaction when
        a trigger fires.  Returns the trigger name or None."""
        if self._task is not None:
            return None
        reason = should_compact(self.config.policy, self.stats())
        if reason is not None:
            self.finish_compaction(self.begin_compaction(reason))
        return reason

    def compact(self, reason: str = "manual") -> None:
        """Unconditional foreground compaction."""
        self.finish_compaction(self.begin_compaction(reason))

    def begin_compaction(self, reason: str = "manual") -> CompactionTask:
        """Take a consistent cut of the live set and return the in-flight
        task.  Reads and writes keep hitting the current epoch; writes are
        additionally logged on the task for replay at finish."""
        if self._task is not None:
            raise RuntimeError("compaction already in flight")
        skeys, srows, n_live = nodes.extract(self.store)
        self._task = CompactionTask(reason=reason, epoch_at_begin=self.epoch,
                                    keys=skeys, rows=srows, n_live=n_live)
        return self._task

    def finish_compaction(self, task: CompactionTask) -> None:
        """Bulk-load the new epoch from the cut, replay writes that landed
        mid-compaction, and swap atomically (from the caller's view: the
        old epoch serves every read until this returns)."""
        if task is not self._task:
            raise RuntimeError("finishing a task that is not in flight")
        cfg = self.config
        keys = task.keys[:task.n_live]
        rows = task.rows[:task.n_live]
        store = nodes.build(keys, rows, cfg.node_cap, fill=cfg.fill,
                            presorted=True)
        snapshot = cgrx.build(keys, rows, cfg.snapshot_bucket_size,
                              presorted=True)
        for ins_keys, ins_rows, del_keys in task.replay:
            store = nodes.apply_batch(store, ins_keys, ins_rows, del_keys)
        self.store = store
        self.snapshot = snapshot
        self.epoch += 1
        self.compactions += 1
        self.deletes_since_compact = 0
        self._task = None
        self._invalidate()

    def abort_compaction(self) -> None:
        """Drop the in-flight task; the current epoch stays authoritative
        (mid-compaction writes were applied to it all along)."""
        self._task = None
