"""Attention: GQA/MQA/MHA with blockwise-causal prefill and cached decode.

Prefill/training uses a blockwise (FlashAttention-style) online-softmax
formulation: the (S x S) score matrix is never materialized — queries are
processed in blocks and KV blocks stream through a ``lax.scan`` carrying
running (max, denominator, accumulator).  This is what lets 32k-prefill
shapes compile inside a v5e's HBM budget; on CPU it also keeps the smoke
tests from allocating quadratic buffers.

Decode attends one new query position against the full KV cache (a matvec
per head), supporting caches sharded over heads or over sequence (the
contraction over a sequence-sharded cache lowers to a cheap partial-sum
all-reduce — the flash-decode pattern).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import _init, apply_rope, init_linear, linear, rmsnorm

NEG_INF = -1e30


def init_attention(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, qkv_bias: bool = False,
                   qk_norm: bool = False, dtype=jnp.float32) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": init_linear(k1, d_model, num_heads * head_dim, qkv_bias, dtype),
        "wk": init_linear(k2, d_model, num_kv_heads * head_dim, qkv_bias, dtype),
        "wv": init_linear(k3, d_model, num_kv_heads * head_dim, qkv_bias, dtype),
        "wo": init_linear(k4, num_heads * head_dim, d_model, False, dtype),
    }
    if qk_norm:
        p["q_norm"] = {"scale": jnp.ones((head_dim,), dtype)}
        p["k_norm"] = {"scale": jnp.ones((head_dim,), dtype)}
    return p


def _qkv(p: dict, x: jnp.ndarray, num_heads: int, num_kv_heads: int,
         head_dim: int, positions: jnp.ndarray, rope_theta: float,
         qk_norm: bool, dtype):
    B, S, _ = x.shape
    q = linear(p["wq"], x, dtype).reshape(B, S, num_heads, head_dim)
    k = linear(p["wk"], x, dtype).reshape(B, S, num_kv_heads, head_dim)
    v = linear(p["wv"], x, dtype).reshape(B, S, num_kv_heads, head_dim)
    if qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if rope_theta:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def blockwise_causal_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                               block_q: int = 512, block_kv: int = 512,
                               probs_bf16: bool = False) -> jnp.ndarray:
    """Online-softmax causal attention.

    q: (B, S, H, D); k/v: (B, S, KV, D) with H % KV == 0.
    Returns (B, S, H, D).  O(S^2) compute, O(S * block) memory.
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(D)

    nq = -(-S // block_q)
    nk = -(-S // block_kv)
    Sq, Sk = nq * block_q, nk * block_kv
    qp = jnp.pad(q, ((0, 0), (0, Sq - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Sk - S), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sk - S), (0, 0), (0, 0)))

    # (B, nq, bq, H, D) -> blocks of queries
    qb = qp.reshape(B, nq, block_q, H, D)
    kb = kp.reshape(B, nk, block_kv, KV, D)
    vb = vp.reshape(B, nk, block_kv, KV, D)

    q_pos = jnp.arange(Sq).reshape(nq, block_q)
    k_pos = jnp.arange(Sk).reshape(nk, block_kv)

    def per_qblock(qi, q_blk):
        # q_blk: (B, bq, H, D)
        q_idx = q_pos[qi]                            # (bq,)

        def kv_step(carry, inp):
            m, l, acc = carry                        # (B,bq,H), (B,bq,H), (B,bq,H,D)
            k_blk, v_blk, k_idx = inp                # (B,bk,KV,D), ..., (bk,)
            # scores: (B, bq, H, bk)
            qg = q_blk.reshape(B, block_q, KV, G, D)
            s = jnp.einsum("bqkgd,bpkd->bqkgp", qg.astype(jnp.float32),
                           k_blk.astype(jnp.float32)) * scale
            s = s.reshape(B, block_q, H, block_kv)
            causal = (k_idx[None, :] <= q_idx[:, None])  # (bq, bk)
            valid = (k_idx < S)[None, :] & (q_idx < S)[:, None]
            mask = (causal & valid)[None, :, None, :]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            # Optional bf16 probability tile: halves the bytes of the
            # second-matmul input streaming through HBM (softmax stats and
            # the accumulator stay f32) — §Perf memory-term knob.
            p_mm = (p.astype(jnp.bfloat16) if probs_bf16 else p)
            pv = jnp.einsum("bqkgp,bpkd->bqkgd",
                            p_mm.reshape(B, block_q, KV, G, block_kv),
                            v_blk.astype(jnp.bfloat16 if probs_bf16
                                         else jnp.float32))
            pv = pv.astype(jnp.float32)
            pv = pv.reshape(B, block_q, H, D)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, block_q, H), NEG_INF, jnp.float32),
                jnp.zeros((B, block_q, H), jnp.float32),
                jnp.zeros((B, block_q, H, D), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init,
            (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4), k_pos))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(lambda i: per_qblock(i, qb[:, i]), jnp.arange(nq))
    # out: (nq, B, bq, H, D) -> (B, S, H, D)
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, D)[:, :S]
    return out.astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     cache_len: jnp.ndarray) -> jnp.ndarray:
    """One-position attention against the cache.

    q: (B, 1, H, D); k_cache/v_cache: (B, S, KV, D); cache_len: () int32 —
    number of valid cache positions (including the newly written one).
    """
    B, S, KV, D = k_cache.shape
    H = q.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(D)
    qg = q.reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    valid = jnp.arange(S)[None, None, None, :] < cache_len
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)


def attention_decode_block_q8(p: dict, x: jnp.ndarray, k_cache, v_cache,
                              k_scale, v_scale, pos: jnp.ndarray, *,
                              num_heads: int, num_kv_heads: int,
                              head_dim: int, rope_theta: float,
                              qk_norm: bool, dtype=jnp.bfloat16):
    """int8 KV-cache decode: halves cache bytes (the cgRX 'bang per byte'
    thesis applied to the KV cache).  Values are stored symmetric-int8 with
    a per-(position, head) f32 scale; dequantization happens at the
    attention matvec (fused into the contraction on TPU, so HBM traffic is
    the int8 payload).  Returns (out, k_cache, v_cache, k_scale, v_scale).
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _qkv(p, x, num_heads, num_kv_heads, head_dim, positions,
                   rope_theta, qk_norm, dtype)

    def quant(t):
        s = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0 + 1e-8       # (B,1,KV,1)
        qt = jnp.clip(jnp.round(t.astype(jnp.float32) / s),
                      -127, 127).astype(jnp.int8)
        return qt, s

    k_q, k_s = quant(k)
    v_q, v_s = quant(v)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_q, (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_q, (0, pos, 0, 0))
    k_scale = jax.lax.dynamic_update_slice(k_scale, k_s, (0, pos, 0, 0))
    v_scale = jax.lax.dynamic_update_slice(v_scale, v_s, (0, pos, 0, 0))

    Bq, S, KV, D = k_cache.shape
    H = q.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(D)
    qg = q.reshape(B, KV, G, D).astype(jnp.float32)
    # scores: contract int8 keys in f32, then apply the per-position scale
    s = jnp.einsum("bkgd,bskd->bkgs", qg,
                   k_cache.astype(jnp.float32)) * k_scale[..., 0].transpose(
                       0, 2, 1)[:, :, None, :] * scale
    valid = jnp.arange(S)[None, None, None, :] < (pos + 1)
    s = jnp.where(valid, s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    # weight values by (prob x per-position scale) before the int8 contract
    pv = pattn * v_scale[..., 0].transpose(0, 2, 1)[:, :, None, :]
    o = jnp.einsum("bkgs,bskd->bkgd", pv, v_cache.astype(jnp.float32))
    out = linear(p["wo"], o.reshape(B, 1, H * D).astype(dtype), dtype)
    return out, k_cache, v_cache, k_scale, v_scale


# ---------------------------------------------------------------------------
# Full attention block (pre-norm residual).
# ---------------------------------------------------------------------------

def attention_block(p: dict, x: jnp.ndarray, *, num_heads: int,
                    num_kv_heads: int, head_dim: int, rope_theta: float,
                    qk_norm: bool, positions: jnp.ndarray,
                    dtype=jnp.bfloat16, block_q: int = 512,
                    block_kv: int = 512, policy=None,
                    probs_bf16: bool = False) -> jnp.ndarray:
    """Training / prefill path (no cache)."""
    q, k, v = _qkv(p, x, num_heads, num_kv_heads, head_dim, positions,
                   rope_theta, qk_norm, dtype)
    if policy is not None:
        q = policy(q, "heads")
        k = policy(k, "heads")
        v = policy(v, "heads")
    o = blockwise_causal_attention(q, k, v, block_q, block_kv,
                                   probs_bf16=probs_bf16)
    if policy is not None:
        o = policy(o, "heads")
    B, S = x.shape[:2]
    return linear(p["wo"], o.reshape(B, S, num_heads * head_dim), dtype)


def attention_decode_block(p: dict, x: jnp.ndarray, k_cache, v_cache,
                           pos: jnp.ndarray, *, num_heads: int,
                           num_kv_heads: int, head_dim: int,
                           rope_theta: float, qk_norm: bool,
                           dtype=jnp.bfloat16):
    """Decode path: x (B, 1, d); returns (out, new_k_cache, new_v_cache)."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _qkv(p, x, num_heads, num_kv_heads, head_dim, positions,
                   rope_theta, qk_norm, dtype)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
    o = decode_attention(q, k_cache, v_cache, pos + 1)
    out = linear(p["wo"], o.reshape(B, 1, num_heads * head_dim), dtype)
    return out, k_cache, v_cache
