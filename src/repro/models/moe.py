"""Mixture-of-Experts with sort-based bucketed dispatch.

Token->expert dispatch is the same sorted-bucket problem the paper's index
solves: sort the (expert_id, token) pairs, then each expert's slice is
delimited by two binary searches over the sorted ids — exactly the
per-bucket batch-update pattern of cgRX Sec. 4 (and it reuses
``core.bucketing.segment_bounds``).  Tokens beyond an expert's capacity
are dropped (their combine weight contributes nothing), standard
capacity-factor semantics.

Experts are laid out as stacked (E, d, f) weights so expert parallelism is
a single sharding annotation on the E axis; the gathered (E, C, d) token
buffers all-to-all across the mesh when EP is active.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bucketing import segment_bounds

from .layers import _init


def init_moe(key, d: int, f_expert: int, num_experts: int,
             num_shared: int = 0, f_shared: Optional[int] = None,
             dtype=jnp.float32) -> dict:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    E = num_experts
    p = {
        "router": {"w": _init(k1, (d, E), dtype=jnp.float32)},  # router in f32
        "wi_gate": _init(k2, (E, d, f_expert), dtype=dtype),
        "wi_up": _init(k3, (E, d, f_expert), dtype=dtype),
        "wo": _init(k4, (E, f_expert, d), dtype=dtype),
    }
    if num_shared:
        fs = f_shared or f_expert
        ks = jax.random.split(k5, 3)
        p["shared"] = {
            "wi_gate": _init(ks[0], (d, num_shared * fs), dtype=dtype),
            "wi_up": _init(ks[1], (d, num_shared * fs), dtype=dtype),
            "wo": _init(ks[2], (num_shared * fs, d), dtype=dtype),
        }
    return p


def moe_block(p: dict, x: jnp.ndarray, *, num_experts: int, top_k: int,
              capacity_factor: float = 1.25, dtype=jnp.bfloat16,
              ep_axis: Optional[str] = None) -> jnp.ndarray:
    """x: (B, S, d) -> (B, S, d).  Dropless up to the capacity factor."""
    B, S, d = x.shape
    T = B * S
    E = num_experts
    xt = x.reshape(T, d)

    # --- routing (f32 for numerics) ---
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, top_k)            # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # --- bucketed dispatch: sort (expert, flat position) pairs ---
    # NB: only integer operands are sorted (the permutation); float gates
    # are gathered afterwards.  Differentiating lax.sort with a float
    # payload trips a broken gather-batching path in this jax build, and
    # an int-only sort is also the cheaper radix-sort shape on TPU.
    flat_e = experts.reshape(-1).astype(jnp.int32)          # (T*k,)
    flat_g = gates.reshape(-1).astype(jnp.float32)
    flat_pos = jnp.arange(T * top_k, dtype=jnp.int32)
    se, sp = jax.lax.sort((flat_e, flat_pos), num_keys=1, is_stable=True)
    st = sp // top_k                                        # token of entry
    sg = jnp.take(flat_g, sp)                               # differentiable
    starts, _ends = segment_bounds(se, E)                   # two binary searches
    # Position of each entry within its expert segment.
    pos_in_e = jnp.arange(T * top_k, dtype=jnp.int32) - starts[se]

    C = int(np.ceil(T * top_k / E * capacity_factor))
    C = max(8, -(-C // 8) * 8)
    keep = pos_in_e < C

    # Scatter token ids into per-expert slots; empty slots point at token 0
    # with weight 0 (contributes nothing on combine).
    slot = se * C + pos_in_e
    slot = jnp.where(keep, slot, E * C)                      # drop slot
    slot_tok = jnp.zeros((E * C + 1,), jnp.int32).at[slot].set(st, mode="drop")
    slot_gate = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(sg, mode="drop")
    slot_tok, slot_gate = slot_tok[:-1], slot_gate[:-1]
    slot_used = jnp.zeros((E * C + 1,), jnp.bool_).at[slot].set(True, mode="drop")[:-1]

    # Gather expert inputs (E, C, d); EP shards the E axis.
    xe = jnp.take(xt, slot_tok, axis=0).reshape(E, C, d).astype(dtype)
    xe = xe * slot_used.reshape(E, C, 1).astype(dtype)
    if ep_axis:
        xe = jax.lax.with_sharding_constraint(
            xe, jax.sharding.PartitionSpec(ep_axis, None, None))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wi_gate"].astype(dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["wi_up"].astype(dtype))
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dtype))  # (E, C, d)

    # Combine: weighted scatter-add back to tokens.
    yflat = (ye.reshape(E * C, d).astype(jnp.float32)
             * slot_gate[:, None])
    out = jnp.zeros((T, d), jnp.float32).at[slot_tok].add(
        jnp.where(slot_used[:, None], yflat, 0.0))

    if "shared" in p:
        sh = p["shared"]
        g = jax.nn.silu(jnp.einsum("td,df->tf", xt.astype(dtype),
                                   sh["wi_gate"].astype(dtype)))
        g = g * jnp.einsum("td,df->tf", xt.astype(dtype),
                           sh["wi_up"].astype(dtype))
        out = out + jnp.einsum("tf,fd->td", g,
                               sh["wo"].astype(dtype)).astype(jnp.float32)

    return out.reshape(B, S, d).astype(x.dtype)


def aux_load_balance_loss(p: dict, x: jnp.ndarray, num_experts: int,
                          top_k: int) -> jnp.ndarray:
    """Switch-style load-balancing auxiliary loss (mean over tokens)."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    _, experts = jax.lax.top_k(probs, top_k)
    counts = jnp.zeros((num_experts,), jnp.float32).at[experts.reshape(-1)].add(1.0)
    frac_tokens = counts / counts.sum()
    frac_probs = probs.mean(axis=0)
    return num_experts * jnp.sum(frac_tokens * frac_probs)
