"""Multi-head Latent Attention (DeepSeek-V2), with compressed KV cache.

MLA down-projects keys/values into a small latent (kv_lora_rank) plus a
shared rotary key; the decode cache stores only (latent, rope_key) per
position — the architecture's entire point is the cache-footprint
reduction, which is also why paged-cgRX paging (serving/paged.py) pairs
well with it: pages are ~9x smaller than GQA pages at equal seq.

Shapes follow DeepSeek-V2-Lite: no q compression, qk_nope 128 + qk_rope 64
per head, v_head 128.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .attention import NEG_INF, blockwise_causal_attention
from .layers import _init, apply_rope, init_linear, linear, rmsnorm


def init_mla(key, d_model: int, num_heads: int, kv_lora_rank: int,
             qk_nope_dim: int, qk_rope_dim: int, v_head_dim: int,
             dtype=jnp.float32) -> dict:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    H = num_heads
    qd = qk_nope_dim + qk_rope_dim
    return {
        "wq": init_linear(k1, d_model, H * qd, False, dtype),
        # joint down-projection: latent + shared rope key
        "wkv_down": init_linear(k2, d_model, kv_lora_rank + qk_rope_dim,
                                False, dtype),
        "kv_norm": {"scale": jnp.ones((kv_lora_rank,), dtype)},
        "wkv_up": init_linear(k3, kv_lora_rank,
                              H * (qk_nope_dim + v_head_dim), False, dtype),
        "wo": init_linear(k4, H * v_head_dim, d_model, False, dtype),
    }


def _project(p, x, *, num_heads, kv_lora_rank, qk_nope_dim, qk_rope_dim,
             v_head_dim, positions, rope_theta, dtype):
    B, S, _ = x.shape
    H = num_heads
    q = linear(p["wq"], x, dtype).reshape(B, S, H, qk_nope_dim + qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, rope_theta)

    down = linear(p["wkv_down"], x, dtype)
    latent, k_rope = jnp.split(down, [kv_lora_rank], axis=-1)
    latent = rmsnorm(p["kv_norm"], latent)
    k_rope = apply_rope(k_rope.reshape(B, S, 1, qk_rope_dim), positions,
                        rope_theta)
    return q_nope, q_rope, latent, k_rope


def _expand_kv(p, latent, *, num_heads, qk_nope_dim, v_head_dim, dtype):
    B, S = latent.shape[:2]
    H = num_heads
    up = linear(p["wkv_up"], latent, dtype).reshape(
        B, S, H, qk_nope_dim + v_head_dim)
    k_nope, v = jnp.split(up, [qk_nope_dim], axis=-1)
    return k_nope, v


def mla_block(p: dict, x: jnp.ndarray, *, num_heads: int, kv_lora_rank: int,
              qk_nope_dim: int, qk_rope_dim: int, v_head_dim: int,
              positions: jnp.ndarray, rope_theta: float = 10000.0,
              dtype=jnp.bfloat16, block_q: int = 512,
              block_kv: int = 512) -> jnp.ndarray:
    """Training / prefill (no cache)."""
    B, S, _ = x.shape
    H = num_heads
    q_nope, q_rope, latent, k_rope = _project(
        p, x, num_heads=num_heads, kv_lora_rank=kv_lora_rank,
        qk_nope_dim=qk_nope_dim, qk_rope_dim=qk_rope_dim,
        v_head_dim=v_head_dim, positions=positions, rope_theta=rope_theta,
        dtype=dtype)
    k_nope, v = _expand_kv(p, latent, num_heads=num_heads,
                           qk_nope_dim=qk_nope_dim, v_head_dim=v_head_dim,
                           dtype=dtype)
    # Assemble full q/k with the shared rope key broadcast over heads, then
    # reuse the blockwise kernel (KV = H here; pad v to qk dim is avoided by
    # separate v width).
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (B, S, H, qk_rope_dim))], axis=-1)
    # blockwise expects equal q/k head dim and v may differ: pad v then slice.
    qd = qk_nope_dim + qk_rope_dim
    if v_head_dim < qd:
        v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qd - v_head_dim)))
    else:
        v_p = v
    o = blockwise_causal_attention(q, k, v_p, block_q, block_kv)
    o = o[..., :v_head_dim]
    return linear(p["wo"], o.reshape(B, S, H * v_head_dim), dtype)


def mla_decode_block(p: dict, x: jnp.ndarray, latent_cache: jnp.ndarray,
                     rope_cache: jnp.ndarray, pos: jnp.ndarray, *,
                     num_heads: int, kv_lora_rank: int, qk_nope_dim: int,
                     qk_rope_dim: int, v_head_dim: int,
                     rope_theta: float = 10000.0, dtype=jnp.bfloat16):
    """Decode with the *compressed* cache.

    latent_cache: (B, S, kv_lora_rank); rope_cache: (B, S, qk_rope_dim).
    The latent is re-expanded per step (the paper's absorbed-matmul trick is
    a further optimization; we expand explicitly, trading flops for cache
    bytes exactly as MLA intends).
    """
    B = x.shape[0]
    H = num_heads
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope, latent, k_rope = _project(
        p, x, num_heads=num_heads, kv_lora_rank=kv_lora_rank,
        qk_nope_dim=qk_nope_dim, qk_rope_dim=qk_rope_dim,
        v_head_dim=v_head_dim, positions=positions, rope_theta=rope_theta,
        dtype=dtype)
    latent_cache = jax.lax.dynamic_update_slice(
        latent_cache, latent.astype(latent_cache.dtype), (0, pos, 0))
    rope_cache = jax.lax.dynamic_update_slice(
        rope_cache, k_rope[:, :, 0].astype(rope_cache.dtype), (0, pos, 0))

    S = latent_cache.shape[1]
    k_nope, v = _expand_kv(p, latent_cache.astype(dtype), num_heads=H,
                           qk_nope_dim=qk_nope_dim, v_head_dim=v_head_dim,
                           dtype=dtype)                     # (B, S, H, *)
    scale = 1.0 / np.sqrt(qk_nope_dim + qk_rope_dim)
    s = (jnp.einsum("bhd,bshd->bhs", q_nope[:, 0].astype(jnp.float32),
                    k_nope.astype(jnp.float32))
         + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                      rope_cache.astype(jnp.float32))) * scale
    valid = jnp.arange(S)[None, None, :] < (pos + 1)
    s = jnp.where(valid, s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhs,bshd->bhd", pattn, v.astype(jnp.float32))
    out = linear(p["wo"], o.reshape(B, 1, H * v_head_dim).astype(dtype), dtype)
    return out, latent_cache, rope_cache
