"""Mamba2 / SSD (state-space duality) blocks.

Training uses the chunked SSD algorithm (Dao & Gu 2024, Sec. 6): the
sequence is split into chunks; within a chunk the recurrence is computed
as a masked quadratic form (MXU-friendly), across chunks a linear
recurrence over per-chunk states runs in a ``lax.scan``.  Decode is the
O(1) per-token recurrence over the (heads, head_dim, d_state) state.

Depthwise causal conv (k=4) is expressed as a sum of shifts (k is tiny),
with a rolling (k-1)-deep conv state for decode.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import _init, gated_rmsnorm, init_gated_rmsnorm, init_linear, linear


def init_mamba2(key, d_model: int, *, d_state: int = 128, expand: int = 2,
                head_dim: int = 64, n_groups: int = 1, conv_k: int = 4,
                dtype=jnp.float32) -> dict:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * n_groups * d_state
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # in_proj emits [z (gate), x, B, C, dt]
    d_in_proj = 2 * d_inner + 2 * n_groups * d_state + n_heads
    return {
        "in_proj": init_linear(k1, d_model, d_in_proj, False, dtype),
        "conv_w": _init(k2, (conv_k, conv_dim), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),   # A = -exp(A_log) = -1
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": init_gated_rmsnorm(d_inner, dtype),
        "out_proj": init_linear(k3, d_inner, d_model, False, dtype),
    }


def _split_proj(zxbcdt, d_inner, n_groups, d_state, n_heads):
    z, x, B, C, dt = jnp.split(
        zxbcdt,
        [d_inner, 2 * d_inner, 2 * d_inner + n_groups * d_state,
         2 * d_inner + 2 * n_groups * d_state],
        axis=-1)
    return z, x, B, C, dt


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x: (B, L, C); w: (k, C) depthwise; sum-of-shifts formulation."""
    k = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xi * w[i]
    return out + b


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """Stable 'segment sum': L[i, j] = sum_{j < k <= i} a[k]  (i >= j)."""
    T = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
             C: jnp.ndarray, chunk: int = 128,
             init_state: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD.

    x: (b, L, h, p); dt: (b, L, h) (post-softplus); A: (h,) negative;
    B, C: (b, L, g, n) with h % g == 0.
    Returns (y (b, L, h, p), final_state (b, h, p, n)).
    """
    b, L, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    nc = -(-L // chunk)
    Lp = nc * chunk
    if Lp != L:
        x = jnp.pad(x, ((0, 0), (0, Lp - L), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, Lp - L), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, Lp - L), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, Lp - L), (0, 0), (0, 0)))

    rep = h // g
    xc = x.reshape(b, nc, chunk, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, g, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, g, n).astype(jnp.float32)
    Bh = jnp.repeat(Bc, rep, axis=3)                    # (b,c,q,h,n)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A[None, None, None, :]                   # (b,c,q,h) <= 0
    dA_cs = jnp.cumsum(dA, axis=2)                      # within-chunk cumsum

    # 1. Intra-chunk (diagonal blocks): masked quadratic attention-form.
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))   # (b,c,h,q,q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)   # (b,c,h,q,k)
    y_diag = jnp.einsum("bchqk,bchqk,bckh,bckhp->bcqhp",
                        scores, Lmat, dtc, xc)

    # 2. Per-chunk final states.
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (b,c,q,h)
    states = jnp.einsum("bcqhn,bcqh,bcqh,bcqhp->bchpn",
                        Bh, decay_states, dtc, xc)       # (b,c,h,p,n)

    # 3. Inter-chunk recurrence (scan over chunks).
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])            # (b,c,h)

    def step(carry, inp):
        s_prev = carry                                   # (b,h,p,n)
        s_c, dec = inp                                   # (b,h,p,n), (b,h)
        s_new = s_c + dec[..., None, None] * s_prev
        return s_new, s_prev

    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final, prev_states = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # (b,c,h,p,n)

    # 4. Inter-chunk contribution to outputs.
    state_decay = jnp.exp(dA_cs)                         # (b,c,q,h)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                       Ch, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, Lp, h, p)[:, :L]
    return y.astype(x.dtype), final


def ssd_decode_step(state: jnp.ndarray, x: jnp.ndarray, dt: jnp.ndarray,
                    A: jnp.ndarray, B: jnp.ndarray, C: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-token recurrence.  state: (b,h,p,n); x: (b,h,p); dt: (b,h);
    B, C: (b,g,n)."""
    h = x.shape[1]
    g = B.shape[1]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=1)                      # (b,h,n)
    Ch = jnp.repeat(C, rep, axis=1)
    dA = jnp.exp(dt * A[None, :])                        # (b,h)
    state = (state * dA[..., None, None]
             + jnp.einsum("bh,bhn,bhp->bhpn", dt, Bh, x))
    y = jnp.einsum("bhn,bhpn->bhp", Ch, state)
    return y, state


class Mamba2State(NamedTuple):
    ssm: jnp.ndarray    # (b, h, p, n) f32
    conv: jnp.ndarray   # (b, k-1, conv_dim)


def mamba2_block(p: dict, u: jnp.ndarray, *, d_state: int, expand: int,
                 head_dim: int, n_groups: int = 1, chunk: int = 128,
                 dtype=jnp.bfloat16) -> jnp.ndarray:
    """Training / prefill.  u: (B, L, d_model)."""
    Bsz, L, d_model = u.shape
    d_inner = expand * d_model
    n_heads = d_inner // head_dim

    zxbcdt = linear(p["in_proj"], u, dtype)
    z, xBC_x, Bc, Cc, dt = _split_proj(zxbcdt, d_inner, n_groups, d_state,
                                       n_heads)
    xBC = jnp.concatenate([xBC_x, Bc, Cc], axis=-1)
    xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"].astype(dtype),
                                   p["conv_b"].astype(dtype)))
    x, Bc, Cc = jnp.split(xBC, [d_inner, d_inner + n_groups * d_state], -1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_scan(x.reshape(Bsz, L, n_heads, head_dim), dt, A,
                    Bc.reshape(Bsz, L, n_groups, d_state),
                    Cc.reshape(Bsz, L, n_groups, d_state), chunk=chunk)
    y = y + x.reshape(Bsz, L, n_heads, head_dim) * p["D"][None, None, :, None]
    y = y.reshape(Bsz, L, d_inner)
    y = gated_rmsnorm(p["norm"], y, z)
    return linear(p["out_proj"], y.astype(dtype), dtype)


def mamba2_decode_block(p: dict, u: jnp.ndarray, state: Mamba2State, *,
                        d_state: int, expand: int, head_dim: int,
                        n_groups: int = 1, dtype=jnp.bfloat16
                        ) -> Tuple[jnp.ndarray, Mamba2State]:
    """Decode one token.  u: (B, 1, d_model)."""
    Bsz, _, d_model = u.shape
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_k = p["conv_w"].shape[0]

    zxbcdt = linear(p["in_proj"], u[:, 0], dtype)          # (B, d_in_proj)
    z, xBC_x, Bc, Cc, dt = _split_proj(zxbcdt, d_inner, n_groups, d_state,
                                       n_heads)
    xBC = jnp.concatenate([xBC_x, Bc, Cc], axis=-1)        # (B, conv_dim)

    # Rolling conv state: window = [conv_state, current].
    window = jnp.concatenate([state.conv, xBC[:, None, :]], axis=1)  # (B,k,C)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xBC = jax.nn.silu(conv_out).astype(dtype)
    new_conv = window[:, 1:]

    x, Bc, Cc = jnp.split(xBC, [d_inner, d_inner + n_groups * d_state], -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, new_ssm = ssd_decode_step(
        state.ssm, x.reshape(Bsz, n_heads, head_dim).astype(jnp.float32),
        dt, A, Bc.reshape(Bsz, n_groups, d_state).astype(jnp.float32),
        Cc.reshape(Bsz, n_groups, d_state).astype(jnp.float32))
    y = y + x.reshape(Bsz, n_heads, head_dim).astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(Bsz, 1, d_inner)
    y = gated_rmsnorm(p["norm"], y, z[:, None, :])
    out = linear(p["out_proj"], y.astype(dtype), dtype)
    return out, Mamba2State(ssm=new_ssm, conv=new_conv)
