from . import attention, layers, lm, mla, moe, ssm  # noqa: F401
