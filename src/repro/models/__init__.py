from . import attention, embeddings, layers, lm, mla, moe, ssm  # noqa: F401
