"""Deterministic LM-style embedding corpora for the vector tier.

The vector benchmarks and examples want "realistic" embeddings — the
anisotropic, normalized distributions a language model's token table
produces — rather than the synthetic Gaussian mixtures of
``data/keygen.embedding_set``.  This module derives them from the repo's
own model stack (``models/layers.py``): a seeded embedding table,
context mixing as a mean over a short token window, and an rmsnorm to
put vectors on the scale LMs actually emit.  Everything is a pure
function of ``(n, dim, seed)``, so benchmark runs are reproducible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import layers


def token_embeddings(n: int, dim: int, *, vocab: int = 4096,
                     window: int = 4, seed: int = 0) -> np.ndarray:
    """``n`` float32 ``dim``-vectors from a seeded token-embedding table.

    Each vector is the rmsnorm'd mean of a random ``window``-token
    context drawn from a ``vocab``-entry table — the cheapest proxy for
    "pooled sentence embedding" the model stack can produce without a
    trained checkpoint.
    """
    key = jax.random.PRNGKey(seed)
    k_table, k_tokens = jax.random.split(key)
    table = layers.init_embedding(k_table, vocab, dim)
    tokens = jax.random.randint(k_tokens, (n, window), 0, vocab)
    pooled = jnp.mean(layers.embed(table, tokens, dtype=jnp.float32),
                      axis=1)
    norm = layers.init_rmsnorm(dim)
    return np.asarray(layers.rmsnorm(norm, pooled), dtype=np.float32)
