"""Shared model layers: norms, RoPE, linears, MLPs, embeddings.

Pure-JAX parameter-pytree style (init_* returns a dict of arrays; apply
functions are pure).  All matmuls take an explicit ``dtype`` so bf16
compute / f32 accumulate policies are uniform across architectures.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


def init_gated_rmsnorm(d: int, dtype=jnp.float32) -> dict:
    """Mamba2's gated RMSNorm: y = rmsnorm(x * silu(z)) * scale."""
    return {"scale": jnp.ones((d,), dtype)}


def gated_rmsnorm(p: dict, x: jnp.ndarray, z: jnp.ndarray,
                  eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Linear / embedding.
# ---------------------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, bias: bool = False,
                dtype=jnp.float32) -> dict:
    p = {"w": _init(key, (d_in, d_out), dtype=dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: dict, x: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    y = jnp.einsum("...d,df->...f", x.astype(dtype), p["w"].astype(dtype))
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


def init_embedding(key, vocab: int, d: int, dtype=jnp.float32) -> dict:
    return {"w": _init(key, (vocab, d), scale=1.0, dtype=dtype)}


def embed(p: dict, tokens: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    return jnp.take(p["w"], tokens, axis=0).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE.
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                           # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos = jnp.cos(angles)[..., None, :]                     # (..., s, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs.
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, f: int, gated: bool, act: str = "silu",
             dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"wo": _init(k2, (f, d), dtype=dtype)}
    if gated:
        p["wi_gate"] = _init(k1, (d, f), dtype=dtype)
        p["wi_up"] = _init(k3, (d, f), dtype=dtype)
    else:
        p["wi"] = _init(k1, (d, f), dtype=dtype)
    return p


def mlp(p: dict, x: jnp.ndarray, act: str = "silu",
        dtype=jnp.bfloat16) -> jnp.ndarray:
    actfn = {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
             "relu": jax.nn.relu}[act]
    x = x.astype(dtype)
    if "wi_gate" in p:
        h = actfn(jnp.einsum("...d,df->...f", x, p["wi_gate"].astype(dtype)))
        h = h * jnp.einsum("...d,df->...f", x, p["wi_up"].astype(dtype))
    else:
        h = actfn(jnp.einsum("...d,df->...f", x, p["wi"].astype(dtype)))
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(dtype))
