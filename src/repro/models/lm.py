"""Unified causal LM over all assigned architecture families.

One parameter pytree + three pure functions per config:

  init_params(cfg, key)                      -> params
  forward(cfg, params, batch, policy)        -> logits (chunked head)
  loss_fn(cfg, params, batch, policy)        -> (loss, metrics)
  init_decode_caches(cfg, B, S)              -> caches
  decode_step(cfg, params, caches, tok, pos) -> (logits, caches)

Layers are *scanned* (stacked params, ``lax.scan`` over the layer axis)
so the lowered HLO contains each distinct block once — essential to keep
40-cell x 512-device dry-run compiles tractable.  Hybrid (Zamba2-style)
architectures scan homogeneous Mamba2 layers and apply a *shared*
attention block every ``attn_every`` layers via ``lax.cond`` inside the
scan body (both branches compile once).

The LM head is applied in sequence chunks (``cfg.loss_chunks``) so the
(B, S, V) logits tensor is never fully materialized — with 100k-250k
vocabularies this is the difference between fitting HBM or not.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from . import attention as attn
from . import mla as mla_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (
    embed,
    init_embedding,
    init_layernorm,
    init_linear,
    init_mlp,
    init_rmsnorm,
    layernorm,
    linear,
    mlp,
    rmsnorm,
)

DTYPE = jnp.bfloat16


class ShardingPolicy:
    """Optional activation-sharding constraints (set by the launcher)."""

    def __init__(self, constrain=None):
        self._c = constrain or (lambda x, kind: x)

    def __call__(self, x, kind: str):
        return self._c(x, kind)


NO_POLICY = ShardingPolicy()


def _norm_init(cfg: ArchConfig):
    return init_layernorm if cfg.norm == "ln" else init_rmsnorm


def _norm_apply(cfg: ArchConfig):
    if cfg.norm == "ln":
        return lambda p, x: layernorm(p, x, cfg.norm_eps)
    return lambda p, x: rmsnorm(p, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Init.
# ---------------------------------------------------------------------------

def _init_block(cfg: ArchConfig, key) -> dict:
    ninit = _norm_init(cfg)
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": ninit(cfg.d_model)}
    if cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        p["mamba"] = ssm_mod.init_mamba2(
            ks[0], cfg.d_model, d_state=s.d_state, expand=s.expand,
            head_dim=s.head_dim, n_groups=s.n_groups, conv_k=s.conv_k)
        return p
    if cfg.mla:
        m = cfg.mla
        p["attn"] = mla_mod.init_mla(
            ks[0], cfg.d_model, cfg.num_heads, m.kv_lora_rank,
            m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim)
    else:
        p["attn"] = attn.init_attention(
            ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd,
            qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm)
    p["ln2"] = ninit(cfg.d_model)
    if cfg.moe:
        m = cfg.moe
        p["moe"] = moe_mod.init_moe(ks[1], cfg.d_model, m.d_ff_expert,
                                    m.num_experts, m.num_shared)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp,
                            cfg.act)
    return p


def init_params(cfg: ArchConfig, key) -> dict:
    keys = jax.random.split(key, cfg.num_layers + 4)
    blocks = [_init_block(cfg, keys[i]) for i in range(cfg.num_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    params: Dict[str, Any] = {
        "embed": init_embedding(keys[-1], cfg.vocab_size, cfg.d_model),
        "blocks": stacked,
        "final_norm": _norm_init(cfg)(cfg.d_model),
        "lm_head": init_linear(keys[-2], cfg.d_model, cfg.vocab_size),
    }
    if cfg.family == "hybrid":
        ka, kb = jax.random.split(keys[-3])
        params["shared_attn"] = {
            "ln1": _norm_init(cfg)(cfg.d_model),
            "attn": attn.init_attention(ka, cfg.d_model, cfg.num_heads,
                                        cfg.num_kv_heads, cfg.hd),
            "ln2": _norm_init(cfg)(cfg.d_model),
            "mlp": init_mlp(kb, cfg.d_model, cfg.d_ff, cfg.gated_mlp, cfg.act),
        }
    if cfg.num_patches:
        params["patch_proj"] = init_linear(keys[-4], cfg.d_model, cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# Forward (training / prefill).
# ---------------------------------------------------------------------------

def _attn_mlp_body(cfg: ArchConfig, bp, x, positions, policy):
    napply = _norm_apply(cfg)
    h = napply(bp["ln1"], x)
    if cfg.mla:
        m = cfg.mla
        a = mla_mod.mla_block(
            bp["attn"], h, num_heads=cfg.num_heads,
            kv_lora_rank=m.kv_lora_rank, qk_nope_dim=m.qk_nope_dim,
            qk_rope_dim=m.qk_rope_dim, v_head_dim=m.v_head_dim,
            positions=positions, rope_theta=cfg.rope_theta, dtype=DTYPE,
            block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv)
    else:
        a = attn.attention_block(
            bp["attn"], h, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
            rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
            positions=positions, dtype=DTYPE,
            block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
            policy=policy, probs_bf16=cfg.attn_probs_bf16)
    x = policy(x + a, "residual")
    h = napply(bp["ln2"], x)
    if cfg.moe:
        m = cfg.moe
        f = moe_mod.moe_block(bp["moe"], h, num_experts=m.num_experts,
                              top_k=m.top_k,
                              capacity_factor=m.capacity_factor, dtype=DTYPE,
                              ep_axis=None)
    else:
        f = mlp(bp["mlp"], h, cfg.act, DTYPE)
    return policy(x + f, "residual")


def _mamba_body(cfg: ArchConfig, bp, x, policy):
    napply = _norm_apply(cfg)
    s = cfg.ssm
    h = napply(bp["ln1"], x)
    y = ssm_mod.mamba2_block(bp["mamba"], h, d_state=s.d_state,
                             expand=s.expand, head_dim=s.head_dim,
                             n_groups=s.n_groups, chunk=s.chunk, dtype=DTYPE)
    return policy(x + y, "residual")


def _shared_attn_body(cfg: ArchConfig, sp, x, positions, policy):
    napply = _norm_apply(cfg)
    h = napply(sp["ln1"], x)
    a = attn.attention_block(
        sp["attn"], h, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
        rope_theta=cfg.rope_theta, qk_norm=False, positions=positions,
        dtype=DTYPE, block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
        policy=policy)
    x = policy(x + a, "residual")
    h = napply(sp["ln2"], x)
    return policy(x + mlp(sp["mlp"], h, cfg.act, DTYPE), "residual")


def forward(cfg: ArchConfig, params: dict, batch: Dict[str, jnp.ndarray],
            policy: ShardingPolicy = NO_POLICY) -> jnp.ndarray:
    """Returns final hidden states (B, S, d) — the head is applied by
    loss_fn / logits() in chunks."""
    tokens = batch["tokens"]
    B, S_text = tokens.shape
    x = embed(params["embed"], tokens, DTYPE)
    if cfg.num_patches:
        pe = batch["patch_embeds"].astype(DTYPE)
        pe = linear(params["patch_proj"], pe, DTYPE)
        x = jnp.concatenate([pe, x], axis=1)
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = policy(x, "residual")

    shared = params.get("shared_attn")

    def body(carry, xs):
        x = carry
        bp, idx = xs
        if cfg.family in ("ssm", "hybrid"):
            x = _mamba_body(cfg, bp, x, policy)
            if cfg.family == "hybrid":
                x = jax.lax.cond(
                    (idx + 1) % cfg.attn_every == 0,
                    lambda v: _shared_attn_body(cfg, shared, v, positions,
                                                policy),
                    lambda v: v, x)
        else:
            x = _attn_mlp_body(cfg, bp, x, positions, policy)
        return x, None

    if not cfg.remat or cfg.remat_policy == "none":
        body_fn = body
    elif cfg.remat_policy == "dots":
        # Save matmul outputs across the scan boundary: backward re-runs
        # only the cheap elementwise work, trading activation bytes for
        # ~1/3 less recomputed flops vs full remat (§Perf knob).
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    else:
        body_fn = jax.checkpoint(body)
    x, _ = jax.lax.scan(body_fn, x,
                        (params["blocks"],
                         jnp.arange(cfg.num_layers, dtype=jnp.int32)))
    return _norm_apply(cfg)(params["final_norm"], x)


def logits_chunked(cfg: ArchConfig, params: dict, hidden: jnp.ndarray
                   ) -> jnp.ndarray:
    """Full logits (only for small smoke configs / sampling)."""
    return linear(params["lm_head"], hidden, DTYPE)


def loss_fn(cfg: ArchConfig, params: dict, batch: Dict[str, jnp.ndarray],
            policy: ShardingPolicy = NO_POLICY) -> Tuple[jnp.ndarray, dict]:
    """Next-token cross entropy; the head+xent run per sequence chunk so the
    (B, S, V) tensor never materializes."""
    hidden = forward(cfg, params, batch, policy)
    labels = batch["labels"]
    if cfg.num_patches:   # loss only over text positions
        hidden = hidden[:, cfg.num_patches:]
    B, S, d = hidden.shape
    nc = cfg.loss_chunks
    while S % nc:
        nc -= 1
    hc = hidden.reshape(B, nc, S // nc, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, S // nc).transpose(1, 0, 2)

    w = params["lm_head"]["w"]

    def chunk_loss(args):
        h, l = args
        lg = jnp.einsum("bsd,dv->bsv", h.astype(DTYPE), w.astype(DTYPE))
        lg = lg.astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        tgt = jnp.take_along_axis(lg, l[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - tgt)

    per_chunk = jax.lax.map(jax.checkpoint(chunk_loss) if cfg.remat
                            else chunk_loss, (hc, lc))
    total = jnp.sum(per_chunk)
    ntok = B * S
    loss = total / ntok
    return loss, {"loss": loss, "tokens": ntok}


# ---------------------------------------------------------------------------
# Decode.
# ---------------------------------------------------------------------------

class DecodeCaches(NamedTuple):
    kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]]          # (L,B,S,KV,hd) x2
    mla: Optional[Tuple[jnp.ndarray, jnp.ndarray]]         # latent, rope
    ssm: Optional[Tuple[jnp.ndarray, jnp.ndarray]]         # (L,B,h,p,n), conv
    shared_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]]   # (sites,B,S,KV,hd)
    kv_scale: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None
    # int8 cache: per-(layer,batch,position,head) symmetric scales f32
    # (L,B,S,KV,1); bf16 caches carry kv_scale=None.


def init_decode_caches(cfg: ArchConfig, batch: int, max_seq: int,
                       dtype=jnp.bfloat16) -> DecodeCaches:
    L = cfg.num_layers
    kv = mla_c = ssm_c = shared = None
    if cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        nh = d_inner // s.head_dim
        conv_dim = d_inner + 2 * s.n_groups * s.d_state
        ssm_c = (jnp.zeros((L, batch, nh, s.head_dim, s.d_state), jnp.float32),
                 jnp.zeros((L, batch, s.conv_k - 1, conv_dim), dtype))
        if cfg.family == "hybrid":
            sites = cfg.num_layers // cfg.attn_every
            shared = (jnp.zeros((sites, batch, max_seq, cfg.num_kv_heads,
                                 cfg.hd), dtype),
                      jnp.zeros((sites, batch, max_seq, cfg.num_kv_heads,
                                 cfg.hd), dtype))
    elif cfg.mla:
        m = cfg.mla
        mla_c = (jnp.zeros((L, batch, max_seq, m.kv_lora_rank), dtype),
                 jnp.zeros((L, batch, max_seq, m.qk_rope_dim), dtype))
    else:
        kv = (jnp.zeros((L, batch, max_seq, cfg.num_kv_heads, cfg.hd), dtype),
              jnp.zeros((L, batch, max_seq, cfg.num_kv_heads, cfg.hd), dtype))
        if dtype == jnp.int8:
            scales = (jnp.ones((L, batch, max_seq, cfg.num_kv_heads, 1),
                               jnp.float32),
                      jnp.ones((L, batch, max_seq, cfg.num_kv_heads, 1),
                               jnp.float32))
            return DecodeCaches(kv=kv, mla=mla_c, ssm=ssm_c,
                                shared_kv=shared, kv_scale=scales)
    return DecodeCaches(kv=kv, mla=mla_c, ssm=ssm_c, shared_kv=shared)


def decode_step(cfg: ArchConfig, params: dict, caches: DecodeCaches,
                token: jnp.ndarray, pos: jnp.ndarray,
                policy: ShardingPolicy = NO_POLICY
                ) -> Tuple[jnp.ndarray, DecodeCaches]:
    """token: (B, 1) int32; pos: () int32 — write position (= cache len)."""
    B = token.shape[0]
    x = embed(params["embed"], token, DTYPE)
    napply = _norm_apply(cfg)
    shared = params.get("shared_attn")

    if cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm

        def body(carry, xs):
            x, sh_k, sh_v = carry
            bp, ssm_s, conv_s, idx = xs
            h = napply(bp["ln1"], x)
            y, new_state = ssm_mod.mamba2_decode_block(
                bp["mamba"], h, ssm_mod.Mamba2State(ssm_s, conv_s),
                d_state=s.d_state, expand=s.expand, head_dim=s.head_dim,
                n_groups=s.n_groups, dtype=DTYPE)
            x = x + y

            if cfg.family == "hybrid":
                site = idx // cfg.attn_every

                def do_attn(op):
                    x, sh_k, sh_v = op
                    h = napply(shared["ln1"], x)
                    a, nk, nv = attn.attention_decode_block(
                        shared["attn"], h, sh_k[site], sh_v[site], pos,
                        num_heads=cfg.num_heads,
                        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
                        rope_theta=cfg.rope_theta, qk_norm=False, dtype=DTYPE)
                    x = x + a
                    h2 = napply(shared["ln2"], x)
                    x = x + mlp(shared["mlp"], h2, cfg.act, DTYPE)
                    return x, sh_k.at[site].set(nk), sh_v.at[site].set(nv)

                x, sh_k, sh_v = jax.lax.cond(
                    (idx + 1) % cfg.attn_every == 0, do_attn,
                    lambda op: op, (x, sh_k, sh_v))
            return (x, sh_k, sh_v), (new_state.ssm, new_state.conv)

        sh_k, sh_v = (caches.shared_kv if caches.shared_kv is not None
                      else (jnp.zeros((1,)), jnp.zeros((1,))))
        (x, sh_k, sh_v), (new_ssm, new_conv) = jax.lax.scan(
            body, (x, sh_k, sh_v),
            (params["blocks"], caches.ssm[0], caches.ssm[1],
             jnp.arange(cfg.num_layers, dtype=jnp.int32)))
        new_caches = caches._replace(
            ssm=(new_ssm, new_conv),
            shared_kv=(sh_k, sh_v) if cfg.family == "hybrid" else None)
    elif cfg.mla:
        m = cfg.mla

        def body(x, xs):
            bp, lat, rope = xs
            h = napply(bp["ln1"], x)
            a, lat, rope = mla_mod.mla_decode_block(
                bp["attn"], h, lat, rope, pos, num_heads=cfg.num_heads,
                kv_lora_rank=m.kv_lora_rank, qk_nope_dim=m.qk_nope_dim,
                qk_rope_dim=m.qk_rope_dim, v_head_dim=m.v_head_dim,
                rope_theta=cfg.rope_theta, dtype=DTYPE)
            x = x + a
            h = napply(bp["ln2"], x)
            if cfg.moe:
                mo = cfg.moe
                f = moe_mod.moe_block(bp["moe"], h,
                                      num_experts=mo.num_experts,
                                      top_k=mo.top_k,
                                      capacity_factor=mo.capacity_factor,
                                      dtype=DTYPE)
            else:
                f = mlp(bp["mlp"], h, cfg.act, DTYPE)
            return x + f, (lat, rope)

        x, (lat, rope) = jax.lax.scan(
            body, x, (params["blocks"], caches.mla[0], caches.mla[1]))
        new_caches = caches._replace(mla=(lat, rope))
    else:
        quantized = caches.kv_scale is not None

        def ffn(bp, x):
            h = napply(bp["ln2"], x)
            if cfg.moe:
                mo = cfg.moe
                return moe_mod.moe_block(bp["moe"], h,
                                         num_experts=mo.num_experts,
                                         top_k=mo.top_k,
                                         capacity_factor=mo.capacity_factor,
                                         dtype=DTYPE)
            return mlp(bp["mlp"], h, cfg.act, DTYPE)

        if quantized:
            def body(x, xs):
                bp, kc, vc, ks, vs = xs
                h = napply(bp["ln1"], x)
                a, kc, vc, ks, vs = attn.attention_decode_block_q8(
                    bp["attn"], h, kc, vc, ks, vs, pos,
                    num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                    head_dim=cfg.hd, rope_theta=cfg.rope_theta,
                    qk_norm=cfg.qk_norm, dtype=DTYPE)
                x = x + a
                return x + ffn(bp, x), (kc, vc, ks, vs)

            x, (kc, vc, ks, vs) = jax.lax.scan(
                body, x, (params["blocks"], caches.kv[0], caches.kv[1],
                          caches.kv_scale[0], caches.kv_scale[1]))
            new_caches = caches._replace(kv=(kc, vc), kv_scale=(ks, vs))
        else:
            def body(x, xs):
                bp, kc, vc = xs
                h = napply(bp["ln1"], x)
                a, kc, vc = attn.attention_decode_block(
                    bp["attn"], h, kc, vc, pos, num_heads=cfg.num_heads,
                    num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
                    rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
                    dtype=DTYPE)
                x = x + a
                return x + ffn(bp, x), (kc, vc)

            x, (kc, vc) = jax.lax.scan(
                body, x, (params["blocks"], caches.kv[0], caches.kv[1]))
            new_caches = caches._replace(kv=(kc, vc))

    x = napply(params["final_norm"], x)
    logits = linear(params["lm_head"], x, DTYPE)
    return logits.astype(jnp.float32), new_caches
