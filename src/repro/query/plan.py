"""Logical query plans: composable expression IR + the lowering compiler.

The paper's claim is that ONE rank primitive over coarse buckets serves
points, ranges and updates alike; this module is the query-language face
of that claim.  Richer workloads — multi-predicate filters, IN-lists,
``COUNT(*)`` over a range, index nested-loop joins — no longer need one
dispatch per fragment hand-assembled caller-side: they are expression
trees of a small node algebra, and a logical->physical compiler lowers
ANY mix of trees onto the existing padded-lane ``QueryPlan`` so a whole
``repro.db.Session.flush()`` stays one dispatch per op class (RTCUDB,
arXiv 2412.09337: push pipelines, not lookups, onto the accelerator).

IR nodes (constructors in lowercase):

    eq(keys)             point predicate, one lane per key -> LookupResult
    between(lo, hi)      range predicate, two lanes        -> RangeResult
    isin(keys)           IN-list: deduplicated to one lane per UNIQUE key,
                         results scattered back to submission order
                         (duplicates answered for free)    -> LookupResult
    limit(k, between)    per-range hit cap: the fragment's rowID block is
                         (R, k) regardless of the session default
                                                           -> RangeResult
    count(between)       COUNT(*):  rank_right(hi) - rank_left(lo); no
                         rowID materialization at all      -> int32 (R,)
    min_key(between)     smallest / largest live key in each range (one
    max_key(between)     key gather per endpoint, never the rowID scan)
                                                           -> AggKeys
    probe(keys,
          outer_rows)    index nested-loop join probe: each outer row's
                         key probes the index, carrying the outer rowID
                         through                           -> ProbeResult
    rank_scan(keys,
              side)      raw global ranks (the ``scan_ranks`` verb)
                                                           -> int32 (Q,)
    postmap(fn, child)   extraction-time post-processor: resolves to
                         ``fn(child result)`` with no extra lanes or
                         dispatches (the refinement hook derived tiers
                         — e.g. the vector tier — lower through)

Lowering (``compile_exprs``): fragments of every tree are collected IN
SUBMISSION ORDER into the three physical sections of one ``QueryPlan`` —
point lanes (eq + isin-unique + probe), materializing ranges (between +
limit, planned at ``max`` of their per-fragment caps), and rank-only
aggregate ranges — plus one fused lane batch for the rank-scan op class.
Each expression gets an *extractor* closure that slices its fragments
back out of the executed ``BatchResult`` (and rank vector) and applies
the node's post-processing (IN-list inverse scatter, limit column cap,
join assembly, aggregate field selection).  Legacy single-node trees
(eq / between / rank_scan) lower to exactly the lane layout the
pre-plan Session produced, so the sugar surface stays bit-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, NamedTuple, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import cgrx
from repro.core.keys import KeyArray, concat_keys

from .batch import LANE, SIDE_LEFT, SIDE_RIGHT, QueryBatch, QueryPlan, \
    validate_max_hits

AGG_OPS = ("count", "min", "max")
_SIDES = {"left": SIDE_LEFT, "right": SIDE_RIGHT}


# ---------------------------------------------------------------------------
# Result shapes specific to the IR (LookupResult/RangeResult/AggResult are
# shared with the single-verb paths and live in core/cgrx.py).
# ---------------------------------------------------------------------------

class ProbeResult(NamedTuple):
    """One index nested-loop join probe batch, in outer-row order."""

    outer_row: jnp.ndarray   # int32 (P,) the outer side's row ids, echoed
    inner_row: jnp.ndarray   # int32 (P,) matched inner rowID, MISS if none
    matched: jnp.ndarray     # bool  (P,)


class AggKeys(NamedTuple):
    """A min/max aggregate batch: one key per range (valid where
    ``count > 0``), plus the count that qualifies it."""

    count: jnp.ndarray       # int32 (A,)
    keys: KeyArray           # (A,) the min or max live key per range


# ---------------------------------------------------------------------------
# IR nodes.  Frozen dataclasses: a constructed tree is immutable, so the
# compiler may walk it twice (sizing, lowering) without defensive copies.
# ---------------------------------------------------------------------------

class Expr:
    """Base of every logical-plan node (see module docstring)."""

    __slots__ = ()


@dataclasses.dataclass(frozen=True)
class Eq(Expr):
    keys: KeyArray


@dataclasses.dataclass(frozen=True)
class Between(Expr):
    lo: KeyArray
    hi: KeyArray


@dataclasses.dataclass(frozen=True)
class Isin(Expr):
    keys: KeyArray


@dataclasses.dataclass(frozen=True)
class Limit(Expr):
    k: int
    child: Between


@dataclasses.dataclass(frozen=True)
class Agg(Expr):
    op: str                  # 'count' | 'min' | 'max'
    child: Between


@dataclasses.dataclass(frozen=True)
class Probe(Expr):
    keys: KeyArray
    outer_rows: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class Postmap(Expr):
    fn: Callable
    child: Expr


@dataclasses.dataclass(frozen=True)
class RankScan(Expr):
    keys: KeyArray
    side: str                # 'left' | 'right'


# -- constructors (the public spelling) --------------------------------------

def eq(keys: KeyArray) -> Eq:
    """Point predicate: one lane per key; resolves to ``LookupResult``."""
    return Eq(keys=keys)


def between(lo: KeyArray, hi: KeyArray) -> Between:
    """Range predicate [lo, hi]; resolves to ``RangeResult``."""
    if lo.shape != hi.shape:
        raise ValueError(
            f"between lo/hi shapes differ: {lo.shape} vs {hi.shape}")
    return Between(lo=lo, hi=hi)


def isin(keys: KeyArray) -> Isin:
    """IN-list predicate: duplicates dispatch as ONE lane per unique key,
    results scatter back to submission order; resolves to
    ``LookupResult`` aligned with the submitted (duplicated) keys."""
    return Isin(keys=keys)


def limit(k: int, child: Between) -> Limit:
    """Cap a range's materialized rowIDs at ``k`` per range (the true
    ``count`` is still reported); resolves to ``RangeResult`` whose
    ``row_ids`` block is (R, k).

    The physical plan gathers EVERY materializing range of a flush at
    the max of the fragments' caps (one fused gather, one result shape —
    extractors slice each fragment back to its own cap).  A ``k`` far
    above the session default therefore widens the whole flush's rowID
    gather: batch a huge-``k`` limit in its own flush rather than beside
    thousands of default-cap ranges."""
    if not isinstance(child, Between):
        raise TypeError(
            f"limit() wraps a between() range, got {type(child).__name__}")
    try:
        validate_max_hits(k)
    except ValueError as e:
        raise ValueError(f"limit(k): {e}") from None
    return Limit(k=int(k), child=child)


def count(child: Between) -> Agg:
    """COUNT(*) over each range — rank subtraction only, no rowID
    materialization; resolves to an int32 (R,) array."""
    return _agg("count", child)


def min_key(child: Between) -> Agg:
    """Smallest live key per range; resolves to ``AggKeys`` (the key is
    valid where ``count > 0``)."""
    return _agg("min", child)


def max_key(child: Between) -> Agg:
    """Largest live key per range; resolves to ``AggKeys``."""
    return _agg("max", child)


def _agg(op: str, child: Between) -> Agg:
    if not isinstance(child, Between):
        raise TypeError(
            f"{op} aggregate wraps a between() range, "
            f"got {type(child).__name__}")
    return Agg(op=op, child=child)


def probe(keys: KeyArray, outer_rows) -> Probe:
    """Index nested-loop join probe: ``keys[i]`` is outer row
    ``outer_rows[i]``'s join key; resolves to ``ProbeResult``."""
    rows = jnp.asarray(outer_rows, jnp.int32)
    if rows.shape != keys.shape:
        raise ValueError(
            f"probe keys/outer_rows shapes differ: {keys.shape} vs "
            f"{rows.shape}")
    return Probe(keys=keys, outer_rows=rows)


def postmap(fn: Callable, child: Expr) -> Postmap:
    """Post-process a child tree's result with ``fn`` at extraction time.

    ``fn`` runs AFTER the flush's fused dispatch, on the child's already-
    extracted result — it adds no lanes and no extra op-class dispatch of
    its own, so a flush full of postmapped trees still compiles to one
    physical plan per class.  This is the hook derived tiers lower their
    refinement steps through (the vector tier's ``distance_topk``
    post-filter rides a ``postmap`` over the bucket ranges it retrieves).

    ``fn`` must also accept the child's canonical ZERO-LENGTH result: a
    zero-size submission resolves to ``fn(empty_result(child))`` without
    entering a plan (the session's empty-flush contract).
    """
    if not isinstance(child, Expr):
        raise TypeError(
            f"postmap() wraps a query expression, got "
            f"{type(child).__name__}")
    if not callable(fn):
        raise TypeError(f"postmap() fn must be callable, got "
                        f"{type(fn).__name__}")
    return Postmap(fn=fn, child=child)


def rank_scan(keys: KeyArray, side: str = "left") -> RankScan:
    """Raw global ranks (#keys < q, or <= q with ``side='right'``);
    resolves to an int32 array."""
    if side not in _SIDES:
        raise ValueError(f"side must be 'left' or 'right', got {side!r}")
    return RankScan(keys=keys, side=side)


# ---------------------------------------------------------------------------
# Tree inspection helpers.
# ---------------------------------------------------------------------------

def expr_size(expr: Expr) -> int:
    """Logical request count of a tree (0 = resolves empty, no lanes)."""
    if isinstance(expr, (Eq, Isin, Probe, RankScan)):
        return int(expr.keys.shape[0])
    if isinstance(expr, Between):
        return int(expr.lo.shape[0])
    if isinstance(expr, (Limit, Agg, Postmap)):
        return expr_size(expr.child)
    raise TypeError(f"not a query expression: {type(expr).__name__}")


def empty_result(expr: Expr, default_max_hits: int = 64):
    """The canonical zero-length result of a tree — what a zero-length
    submission resolves to without ever entering a plan."""
    if isinstance(expr, (Eq, Isin)):
        return cgrx.empty_lookup_result()
    if isinstance(expr, Between):
        return cgrx.empty_range_result(default_max_hits)
    if isinstance(expr, Limit):
        return cgrx.empty_range_result(expr.k)
    if isinstance(expr, Agg):
        if expr.op == "count":
            return jnp.zeros((0,), jnp.int32)
        return AggKeys(count=jnp.zeros((0,), jnp.int32),
                       keys=expr.child.lo[:0])
    if isinstance(expr, Probe):
        return ProbeResult(outer_row=jnp.zeros((0,), jnp.int32),
                           inner_row=jnp.zeros((0,), jnp.int32),
                           matched=jnp.zeros((0,), bool))
    if isinstance(expr, RankScan):
        return jnp.zeros((0,), jnp.int32)
    if isinstance(expr, Postmap):
        return expr.fn(empty_result(expr.child, default_max_hits))
    raise TypeError(f"not a query expression: {type(expr).__name__}")


# ---------------------------------------------------------------------------
# The logical -> physical compiler.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Program:
    """One flush's worth of compiled expressions.

    ``plan`` fuses every point / materializing-range / aggregate fragment
    into a single ``QueryPlan`` (one ``tier.execute`` dispatch);
    ``rank_keys``/``rank_sides`` fuse every rank-scan fragment (one
    ``tier.scan_ranks`` dispatch).  ``extractors[i]`` maps the executed
    ``(BatchResult, ranks)`` back to expression ``i``'s result.
    """

    plan: QueryPlan
    rank_keys: Optional[KeyArray]
    rank_sides: Optional[jnp.ndarray]
    extractors: List[Callable]
    n_point: int
    n_range: int
    n_agg: int
    n_rank: int

    @property
    def has_query(self) -> bool:
        return self.n_point + self.n_range + self.n_agg > 0

    @property
    def has_rank(self) -> bool:
        return self.n_rank > 0


def _slice_tuple(res, lo: int, hi: int):
    """Slice every field of a NamedTuple result along axis 0."""
    return type(res)(*(f[lo:hi] for f in res))


def _unique_host(keys: KeyArray) -> Tuple[KeyArray, np.ndarray]:
    """Host-side dedup of an IN-list: (unique KeyArray, inverse index)."""
    raw = keys.to_numpy()
    uniq, inverse = np.unique(raw, return_inverse=True)
    ukeys = (KeyArray.from_u64(uniq) if keys.is64
             else KeyArray.from_u32(uniq))
    return ukeys, inverse.astype(np.int32)


def compile_exprs(exprs: Sequence[Expr], *, default_max_hits: int = 64,
                  lane: int = LANE) -> Program:
    """Lower a flush's expression list onto one physical plan.

    Fragments are collected in submission order per section, so a list of
    plain ``eq``/``between``/``rank_scan`` trees lowers to exactly the
    lane layout the pre-IR Session produced (the sugar bit-identity
    contract).  The plan's ``max_hits`` is the max of the materializing
    fragments' caps (``limit(k)`` or the session default) — each
    fragment's extractor slices its own cap back out.  That max is
    flush-global: one outsized ``limit(k)`` widens the (R, max) rowID
    gather of every materializing range in the flush (see ``limit``), so
    keep extreme caps in their own flush.
    """
    validate_max_hits(default_max_hits)
    # Fragments append straight onto the QueryBatch — its per-section
    # accumulation in append order IS the physical section layout, so
    # extractor offsets are just running cursors per section (in
    # *requests*; ranges/aggs occupy 2 lanes each).
    batch = QueryBatch()
    p_off = r_off = a_off = k_off = 0
    caps: List[int] = []
    agg_keys_needed = False
    rank_parts: List[KeyArray] = []
    side_parts: List[np.ndarray] = []
    extractors: List[Callable] = []

    def lower_points(keys: KeyArray) -> Tuple[int, int]:
        nonlocal p_off
        m = int(keys.shape[0])
        batch.add_points(keys)
        off, p_off = p_off, p_off + m
        return off, m

    def lower_range(node: Between, cap: int) -> Tuple[int, int, int]:
        nonlocal r_off
        m = int(node.lo.shape[0])
        batch.add_ranges(node.lo, node.hi)
        caps.append(cap)
        off, r_off = r_off, r_off + m
        return off, m, cap

    def lower(expr: Expr) -> Callable:
        nonlocal a_off, k_off, agg_keys_needed
        if isinstance(expr, Eq):
            off, m = lower_points(expr.keys)
            return lambda res, ranks: _slice_tuple(res.points, off, off + m)
        if isinstance(expr, Isin):
            ukeys, inverse = _unique_host(expr.keys)
            off, m = lower_points(ukeys)
            inv = jnp.asarray(inverse)

            def extract_isin(res, ranks):
                sliced = _slice_tuple(res.points, off, off + m)
                return type(sliced)(*(f[inv] for f in sliced))
            return extract_isin
        if isinstance(expr, Probe):
            off, m = lower_points(expr.keys)
            outer = expr.outer_rows

            def extract_probe(res, ranks):
                sliced = _slice_tuple(res.points, off, off + m)
                return ProbeResult(outer_row=outer,
                                   inner_row=sliced.row_id,
                                   matched=sliced.found)
            return extract_probe
        if isinstance(expr, Between):
            off, m, cap = lower_range(expr, default_max_hits)

            def extract_range(res, ranks):
                sliced = _slice_tuple(res.ranges, off, off + m)
                return sliced._replace(row_ids=sliced.row_ids[:, :cap])
            return extract_range
        if isinstance(expr, Limit):
            off, m, cap = lower_range(expr.child, expr.k)

            def extract_limit(res, ranks):
                sliced = _slice_tuple(res.ranges, off, off + m)
                return sliced._replace(row_ids=sliced.row_ids[:, :cap])
            return extract_limit
        if isinstance(expr, Agg):
            m = int(expr.child.lo.shape[0])
            batch.add_agg_ranges(expr.child.lo, expr.child.hi)
            off, a_off = a_off, a_off + m
            op = expr.op
            if op != "count":
                agg_keys_needed = True

            def extract_agg(res, ranks):
                cnt = res.aggs.count[off:off + m]
                if op == "count":
                    return cnt
                keys = (res.aggs.min_key if op == "min"
                        else res.aggs.max_key)
                return AggKeys(count=cnt, keys=keys[off:off + m])
            return extract_agg
        if isinstance(expr, RankScan):
            m = int(expr.keys.shape[0])
            rank_parts.append(expr.keys)
            side_parts.append(np.full(m, _SIDES[expr.side], np.int32))
            off, k_off = k_off, k_off + m
            return lambda res, ranks: ranks[off:off + m]
        if isinstance(expr, Postmap):
            inner = lower(expr.child)
            fn = expr.fn
            return lambda res, ranks: fn(inner(res, ranks))
        raise TypeError(f"not a query expression: {type(expr).__name__}")

    for expr in exprs:
        extractors.append(lower(expr))

    eff_max_hits = max(caps) if caps else default_max_hits
    plan = batch.plan(lane=lane, max_hits=eff_max_hits,
                      agg_keys=agg_keys_needed)

    rank_keys: Optional[KeyArray] = None
    rank_sides: Optional[jnp.ndarray] = None
    if rank_parts:
        rank_keys = rank_parts[0]
        for p in rank_parts[1:]:
            rank_keys = concat_keys(rank_keys, p)
        rank_sides = jnp.asarray(np.concatenate(side_parts))

    return Program(plan=plan, rank_keys=rank_keys, rank_sides=rank_sides,
                   extractors=extractors, n_point=p_off, n_range=r_off,
                   n_agg=a_off, n_rank=k_off)
