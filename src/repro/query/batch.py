"""QueryBatch: coalesce mixed lookups into padded rank-query lanes.

The serving insight (RTCUDB, arXiv 2412.09337): amortizing launch and
traversal overhead across *concurrent queries* is where accelerator
throughput lives.  Every cgRX lookup is a rank query (paper Sec. 3.1-3.2):

    point  k        ->  1 lane:  rank_left(k)
    range  [l, u]   ->  2 lanes: rank_left(l), rank_right(u)
    agg    [l, u]   ->  2 lanes: rank_left(l), rank_right(u)  (rank-only)

so a tick's worth of heterogeneous requests flattens into ONE (L,) key
vector plus an (L,) side vector, padded to a multiple of the VPU lane
width so the fused kernel (kernels/fused_rank.py) sees full tiles.

An *aggregate range* is a range whose caller wants ``COUNT``/``MIN``/
``MAX`` rather than the qualifying rowIDs: it costs the same two rank
lanes but its post-processing never gathers the ``(R, max_hits)`` rowID
block — ``count = rank_right(hi) - rank_left(lo)`` is a subtraction of
ranks the batch already computed (GPU-RMQ, arXiv 2604.01811: range
aggregates without materializing hits).

Lane layout of a plan (static per shape, so the engine jit-caches on it):

    [ point keys | range lows | range highs | agg lows | agg highs | pad ]
      side=left    side=left    side=right    side=left   side=right

The planner is host-side and cheap (numpy concatenation); the resulting
``QueryPlan`` is consumed by ``query.engine.RankEngine.execute`` in a
single device call.  The logical-plan layer (``query/plan.py``) compiles
expression trees down to this module's sections.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.keys import KeyArray, concat_keys

LANE = 128

SIDE_LEFT = 0
SIDE_RIGHT = 1

# Upper bound on the per-range rowID capacity.  ``max_hits`` sizes the
# (R, max_hits) int32 gather every materializing range performs; a value
# past this cap is a config typo (a 4 MB+ result row per range), not a
# workload, and must fail at the plan boundary instead of silently
# dominating lane planning.
MAX_MAX_HITS = 1 << 20


def validate_max_hits(max_hits: int) -> int:
    """Reject non-positive or absurd per-range hit capacities.

    Shared by the planner (``QueryBatch.plan``) and the ``repro.db``
    boundary (``IndexSpec``/``Session``, which re-raise as the typed
    ``InvalidSpecError``); always names the offending value.
    """
    if not isinstance(max_hits, (int, np.integer)) or isinstance(
            max_hits, bool):
        raise ValueError(
            f"max_hits must be an int in [1, {MAX_MAX_HITS}], "
            f"got {max_hits!r}")
    if not 0 < max_hits <= MAX_MAX_HITS:
        raise ValueError(
            f"max_hits must be in [1, {MAX_MAX_HITS}], got {max_hits}")
    return int(max_hits)


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """A padded, device-ready lane batch (see module docstring layout)."""

    keys: KeyArray        # (L,) flat lane keys, L a multiple of ``lane``
    sides: jnp.ndarray    # (L,) int32, 0 = rank_left, 1 = rank_right
    n_point: int          # lanes [0, n_point) are point lookups
    n_range: int          # lanes [n_point, n_point + 2*n_range) are ranges
    max_hits: int         # row-id capacity per range result
    n_agg: int = 0        # 2*n_agg aggregate lanes follow the ranges
    agg_keys: bool = False  # aggregates also gather min/max keys

    @property
    def lanes(self) -> int:
        return self.keys.shape[0]

    @property
    def n_queries(self) -> int:
        """Logical request count (a range/aggregate is one request)."""
        return self.n_point + self.n_range + self.n_agg


class QueryBatch:
    """Accumulates point/range/aggregate requests, then plans them.

    Usage::

        batch = QueryBatch()
        batch.add_points(point_keys)          # KeyArray (P,)
        batch.add_ranges(lo_keys, hi_keys)    # KeyArrays (R,), (R,)
        batch.add_agg_ranges(lo, hi)          # rank-only ranges (A,)
        plan = batch.plan(max_hits=64)
        result = engine.execute(plan)         # one device call

    All added keys must agree on width (32- vs 64-bit).
    """

    def __init__(self) -> None:
        self._points: List[KeyArray] = []
        self._ranges: List[Tuple[KeyArray, KeyArray]] = []
        self._aggs: List[Tuple[KeyArray, KeyArray]] = []
        self._is64: Optional[bool] = None

    # -- building ------------------------------------------------------------

    def _check_width(self, keys: KeyArray) -> None:
        if self._is64 is None:
            self._is64 = keys.is64
        elif self._is64 != keys.is64:
            raise ValueError("mixed 32/64-bit keys in one QueryBatch")

    def add_points(self, keys: KeyArray) -> "QueryBatch":
        self._check_width(keys)
        self._points.append(keys)
        return self

    def add_ranges(self, lo: KeyArray, hi: KeyArray) -> "QueryBatch":
        if lo.shape != hi.shape:
            raise ValueError(f"range lo/hi shapes differ: {lo.shape} vs {hi.shape}")
        self._check_width(lo)
        self._check_width(hi)
        self._ranges.append((lo, hi))
        return self

    def add_agg_ranges(self, lo: KeyArray, hi: KeyArray) -> "QueryBatch":
        """Queue rank-only aggregate ranges: two lanes each, but the plan
        marks them so execution skips the rowID gather entirely."""
        if lo.shape != hi.shape:
            raise ValueError(f"agg lo/hi shapes differ: {lo.shape} vs {hi.shape}")
        self._check_width(lo)
        self._check_width(hi)
        self._aggs.append((lo, hi))
        return self

    @property
    def n_point(self) -> int:
        return sum(int(k.shape[0]) for k in self._points)

    @property
    def n_range(self) -> int:
        return sum(int(lo.shape[0]) for lo, _ in self._ranges)

    @property
    def n_agg(self) -> int:
        return sum(int(lo.shape[0]) for lo, _ in self._aggs)

    def __len__(self) -> int:
        return self.n_point + self.n_range + self.n_agg

    # -- planning ------------------------------------------------------------

    def plan(self, lane: int = LANE, max_hits: int = 64,
             agg_keys: bool = False) -> QueryPlan:
        """Flatten to the padded lane layout (one concat, one pad).

        A batch whose every submission was zero-length — or that was
        never touched at all — plans to a canonical zero-lane
        ``QueryPlan`` (32-bit keys by default) without any concat/pad
        work; the engine serves it without building an executable or
        touching the device (the empty-flush fast path), so callers need
        no emptiness pre-check.
        """
        validate_max_hits(max_hits)
        if self.n_point == 0 and self.n_range == 0 and self.n_agg == 0:
            is64 = bool(self._is64)  # never-touched batch defaults to 32-bit
            zeros = KeyArray(jnp.zeros((0,), jnp.uint32),
                             jnp.zeros((0,), jnp.uint32) if is64 else None)
            return QueryPlan(keys=zeros, sides=jnp.zeros((0,), jnp.int32),
                             n_point=0, n_range=0, max_hits=max_hits,
                             n_agg=0, agg_keys=agg_keys)
        parts: List[KeyArray] = []
        parts.extend(self._points)
        parts.extend(lo for lo, _ in self._ranges)
        parts.extend(hi for _, hi in self._ranges)
        parts.extend(lo for lo, _ in self._aggs)
        parts.extend(hi for _, hi in self._aggs)

        keys = parts[0]
        for p in parts[1:]:
            keys = concat_keys(keys, p)

        n_point, n_range, n_agg = self.n_point, self.n_range, self.n_agg
        total = n_point + 2 * n_range + 2 * n_agg
        pad = (-total) % lane
        if pad:
            zeros = KeyArray(
                jnp.zeros((pad,), jnp.uint32),
                jnp.zeros((pad,), jnp.uint32) if self._is64 else None)
            keys = concat_keys(keys, zeros)

        sides = np.zeros(total + pad, np.int32)
        sides[n_point + n_range: n_point + 2 * n_range] = SIDE_RIGHT
        a0 = n_point + 2 * n_range
        sides[a0 + n_agg: a0 + 2 * n_agg] = SIDE_RIGHT
        return QueryPlan(keys=keys, sides=jnp.asarray(sides),
                         n_point=n_point, n_range=n_range, max_hits=max_hits,
                         n_agg=n_agg, agg_keys=agg_keys)
