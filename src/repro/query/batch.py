"""QueryBatch: coalesce mixed lookups into padded rank-query lanes.

The serving insight (RTCUDB, arXiv 2412.09337): amortizing launch and
traversal overhead across *concurrent queries* is where accelerator
throughput lives.  Every cgRX lookup is a rank query (paper Sec. 3.1-3.2):

    point  k        ->  1 lane:  rank_left(k)
    range  [l, u]   ->  2 lanes: rank_left(l), rank_right(u)

so a tick's worth of heterogeneous requests flattens into ONE (L,) key
vector plus an (L,) side vector, padded to a multiple of the VPU lane
width so the fused kernel (kernels/fused_rank.py) sees full tiles.

Lane layout of a plan (static per shape, so the engine jit-caches on it):

    [ point keys | range lows | range highs | padding ]
      side=left    side=left    side=right    side=left, key=0

The planner is host-side and cheap (numpy concatenation); the resulting
``QueryPlan`` is consumed by ``query.engine.RankEngine.execute`` in a
single device call.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.keys import KeyArray, concat_keys

LANE = 128

SIDE_LEFT = 0
SIDE_RIGHT = 1


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """A padded, device-ready lane batch (see module docstring layout)."""

    keys: KeyArray        # (L,) flat lane keys, L a multiple of ``lane``
    sides: jnp.ndarray    # (L,) int32, 0 = rank_left, 1 = rank_right
    n_point: int          # lanes [0, n_point) are point lookups
    n_range: int          # lanes [n_point, n_point + 2*n_range) are ranges
    max_hits: int         # row-id capacity per range result

    @property
    def lanes(self) -> int:
        return self.keys.shape[0]

    @property
    def n_queries(self) -> int:
        """Logical request count (a range is one request, two lanes)."""
        return self.n_point + self.n_range


class QueryBatch:
    """Accumulates point/range requests, then plans them into lanes.

    Usage::

        batch = QueryBatch()
        batch.add_points(point_keys)          # KeyArray (P,)
        batch.add_ranges(lo_keys, hi_keys)    # KeyArrays (R,), (R,)
        plan = batch.plan(max_hits=64)
        result = engine.execute(plan)         # one device call

    All added keys must agree on width (32- vs 64-bit).
    """

    def __init__(self) -> None:
        self._points: List[KeyArray] = []
        self._ranges: List[Tuple[KeyArray, KeyArray]] = []
        self._is64: Optional[bool] = None

    # -- building ------------------------------------------------------------

    def _check_width(self, keys: KeyArray) -> None:
        if self._is64 is None:
            self._is64 = keys.is64
        elif self._is64 != keys.is64:
            raise ValueError("mixed 32/64-bit keys in one QueryBatch")

    def add_points(self, keys: KeyArray) -> "QueryBatch":
        self._check_width(keys)
        self._points.append(keys)
        return self

    def add_ranges(self, lo: KeyArray, hi: KeyArray) -> "QueryBatch":
        if lo.shape != hi.shape:
            raise ValueError(f"range lo/hi shapes differ: {lo.shape} vs {hi.shape}")
        self._check_width(lo)
        self._check_width(hi)
        self._ranges.append((lo, hi))
        return self

    @property
    def n_point(self) -> int:
        return sum(int(k.shape[0]) for k in self._points)

    @property
    def n_range(self) -> int:
        return sum(int(lo.shape[0]) for lo, _ in self._ranges)

    def __len__(self) -> int:
        return self.n_point + self.n_range

    # -- planning ------------------------------------------------------------

    def plan(self, lane: int = LANE, max_hits: int = 64) -> QueryPlan:
        """Flatten to the padded lane layout (one concat, one pad).

        A batch whose every submission was zero-length plans to a
        canonical zero-lane ``QueryPlan`` without any concat/pad work;
        the engine serves it without building an executable or touching
        the device (the empty-flush fast path).
        """
        if self._is64 is None:
            raise ValueError("empty QueryBatch: add points or ranges first")
        if self.n_point == 0 and self.n_range == 0:
            zeros = KeyArray(jnp.zeros((0,), jnp.uint32),
                             jnp.zeros((0,), jnp.uint32) if self._is64
                             else None)
            return QueryPlan(keys=zeros, sides=jnp.zeros((0,), jnp.int32),
                             n_point=0, n_range=0, max_hits=max_hits)
        parts: List[KeyArray] = []
        parts.extend(self._points)
        parts.extend(lo for lo, _ in self._ranges)
        parts.extend(hi for _, hi in self._ranges)

        keys = parts[0]
        for p in parts[1:]:
            keys = concat_keys(keys, p)

        n_point, n_range = self.n_point, self.n_range
        total = n_point + 2 * n_range
        pad = (-total) % lane
        if pad:
            zeros = KeyArray(
                jnp.zeros((pad,), jnp.uint32),
                jnp.zeros((pad,), jnp.uint32) if self._is64 else None)
            keys = concat_keys(keys, zeros)

        sides = np.zeros(total + pad, np.int32)
        sides[n_point + n_range: n_point + 2 * n_range] = SIDE_RIGHT
        return QueryPlan(keys=keys, sides=jnp.asarray(sides),
                         n_point=n_point, n_range=n_range, max_hits=max_hits)
