"""RankEngine: execute a QueryPlan in one device call.

The engine binds a built ``CgrxIndex`` to a registered backend and turns
a planned lane batch into results:

    ranks = backend.rank_batch(index, plan.keys, plan.sides)   # 1 launch
    points -> LookupResult   (hit check + rowID gather, paper Alg. 2 l.4-5)
    ranges -> RangeResult    (start/count + rowID scan, paper Sec. 3.2)
    aggs   -> AggResult      (count = rank difference; optional min/max
                              key gather — NEVER the rowID scan)

The whole pipeline — rank plus the per-section post-processing — is
jit-compiled per (backend, lane count, n_point, n_range, n_agg, agg_keys,
max_hits) signature, so a serving tick with a stable batch shape is
exactly ONE XLA executable dispatch; the index buffers are closure-
captured constants, never re-uploaded.  Sections a plan does not carry
are skipped STRUCTURALLY: a plan with zero point lanes never traces the
hit-check gather, and an aggregate-only plan never traces any rowID
materialization at all — the rank-only execution path.  ``STAGE_COUNTERS``
records which post-processing stages each built pipeline contains (bumped
when the pipeline body runs, i.e. at trace time under jit), which is the
observable tests pin the aggregate fast path on.  Results are
bit-identical to the per-query ``core/cgrx.lookup`` /
``core/cgrx.range_lookup`` paths for every backend (enforced by
tests/test_query_engine.py).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cgrx
from repro.core.keys import KeyArray

from .backends import Backend, get_backend
from .batch import QueryBatch, QueryPlan


class BatchResult(NamedTuple):
    """Per-kind results of one executed plan, in request order.

    ``aggs`` is ``None`` when the plan carried no aggregate section
    (every pre-aggregate plan shape), so legacy consumers of the
    two-section result never see a third field's cost.
    """

    points: "cgrx.LookupResult"   # fields shaped (n_point,)
    ranges: "cgrx.RangeResult"    # fields shaped (n_range,) / (n_range, max_hits)
    aggs: Optional["cgrx.AggResult"] = None   # fields shaped (n_agg,)


# Which post-processing stages the engine has BUILT into executed
# pipelines, process-wide.  Incremented inside the pipeline body — under
# jit that is trace time, so a cached executable re-dispatches without
# bumping; with a fresh executable (new engine / new cache scope) the
# counters record exactly which sections the compiled pipeline contains.
# ``row_gather`` counts the (R, max_hits) rowID materializations the
# aggregate path exists to avoid.
STAGE_COUNTERS: Dict[str, int] = {"rank": 0, "point_gather": 0,
                                  "row_gather": 0, "agg": 0}


def stage_counter_snapshot() -> Dict[str, int]:
    """A point-in-time copy of ``STAGE_COUNTERS`` — the shape the
    telemetry bus folds per flush (``tuning/telemetry.py``), detached so
    later pipeline builds cannot mutate a recorded snapshot."""
    return dict(STAGE_COUNTERS)


def _make_run(backend: "Backend", n_point: int, n_range: int, n_agg: int,
              agg_keys: bool, max_hits: int):
    """The engine pipeline as a pure function of (index, lanes).

    Post-processing is duck-typed: an index may carry its own
    rank->result mapping (the node store's chain-position walk,
    ``repro.store.live.NodeIndexView``); flat CgrxIndex-shaped indexes
    fall back to cgrx's shared helpers — bit-identity by construction
    either way.  Sections the plan does not carry are not traced at all
    (see module docstring).
    """

    def run(index, q_lo, q_hi, sides):
        queries = KeyArray(q_lo, q_hi)
        ranks = backend.rank_batch(index, queries, sides)
        STAGE_COUNTERS["rank"] += 1
        if n_point:
            lookup_from_rank = getattr(index, "lookup_from_rank", None) \
                or partial(cgrx.lookup_from_rank, index)
            points = lookup_from_rank(ranks[:n_point], queries[:n_point])
            STAGE_COUNTERS["point_gather"] += 1
        else:
            points = cgrx.empty_lookup_result()
        if n_range:
            range_from_ranks = getattr(index, "range_from_ranks", None) \
                or partial(cgrx.range_from_ranks, index)
            ranges = range_from_ranks(
                ranks[n_point:n_point + n_range],
                ranks[n_point + n_range:n_point + 2 * n_range], max_hits)
            STAGE_COUNTERS["row_gather"] += 1
        else:
            ranges = cgrx.empty_range_result(max_hits)
        if n_agg:
            agg_from_ranks = getattr(index, "agg_from_ranks", None) \
                or partial(cgrx.agg_from_ranks, index)
            a0 = n_point + 2 * n_range
            aggs = agg_from_ranks(ranks[a0:a0 + n_agg],
                                  ranks[a0 + n_agg:a0 + 2 * n_agg],
                                  agg_keys)
            STAGE_COUNTERS["agg"] += 1
        else:
            aggs = None
        return BatchResult(points=points, ranges=ranges, aggs=aggs)

    return run


# Process-wide executable cache for PYTREE indexes (argument-passed): one
# jitted pipeline per (cache scope, backend, plan signature); jax.jit's own
# cache then specializes per index treedef/shape, so successive store
# versions hit.  ``cache scope`` is the shard-indexing handle: every shard
# of a ShardedLiveStore binds the same scope, so S shards with matching
# static bounds share ONE compiled executable (shards whose bounds diverge
# — say one grew a longer chain — specialize under the same jitted callable
# via jax.jit's treedef/aux keying, not by cloning the pipeline).
_SHARED_EXEC: Dict[Tuple, object] = {}


def clear_shared_exec(scope: Optional[str] = None) -> int:
    """Drop shared executables (all, or one cache scope's).  Returns the
    number of entries dropped — an operator hook for long-lived serving
    processes that tear down a store."""
    if scope is None:
        n = len(_SHARED_EXEC)
        _SHARED_EXEC.clear()
        return n
    victims = [k for k in _SHARED_EXEC if k[0] == scope]
    for k in victims:
        del _SHARED_EXEC[k]
    return len(victims)


class RankEngine:
    """Batched lookup engine over one cgRX index.

    ``backend`` defaults to the index's build-time method; pass any name
    from ``query.backends.available_backends()`` to override (the index
    carries every structure all backends need).
    """

    def __init__(self, index: "cgrx.CgrxIndex",
                 backend: Optional[str] = None, jit: bool = True,
                 cache_scope: Optional[str] = None):
        self.index = index
        self.backend_name = backend or index.method
        self.backend: Backend = get_backend(self.backend_name)
        self._jit = jit
        self.cache_scope = cache_scope
        self._exec_cache: Dict[Tuple, object] = {}

    # -- raw rank ------------------------------------------------------------

    def rank_batch(self, queries: KeyArray, sides: jnp.ndarray) -> jnp.ndarray:
        """Global ranks of a mixed-side lane batch (0=left, 1=right)."""
        return self.backend.rank_batch(self.index, queries, sides)

    # -- plan execution ------------------------------------------------------

    def execute(self, plan: QueryPlan) -> BatchResult:
        """Serve an entire plan — one device call for the whole batch.

        A plan with zero queries (every submission was empty) dispatches
        NOTHING: no executable is built or cached and no device call is
        made — the empty-flush fast path ``repro.db.Session.flush``
        relies on (regression-tested in tests/test_query_engine.py).
        """
        if plan.n_point == 0 and plan.n_range == 0 and plan.n_agg == 0:
            return BatchResult(points=cgrx.empty_lookup_result(),
                               ranges=cgrx.empty_range_result(plan.max_hits),
                               aggs=None)
        sig = (plan.lanes, plan.n_point, plan.n_range, plan.n_agg,
               plan.agg_keys, plan.max_hits, plan.keys.is64)
        fn = self._exec_cache.get(sig)
        if fn is None:
            fn = self._build_exec(plan.n_point, plan.n_range, plan.n_agg,
                                  plan.agg_keys, plan.max_hits)
            self._exec_cache[sig] = fn
        return fn(plan.keys.lo, plan.keys.hi, plan.sides)

    def _build_exec(self, n_point: int, n_range: int, n_agg: int,
                    agg_keys: bool, max_hits: int):
        index = self.index
        run = _make_run(self.backend, n_point, n_range, n_agg, agg_keys,
                        max_hits)
        if not jax.tree_util.treedef_is_leaf(
                jax.tree_util.tree_structure(index)):
            # Pytree index (the live store's NodeIndexView): pass it as a
            # jit ARGUMENT through a process-wide executable cache.  The
            # store re-binds its buffers on every update batch, so
            # closure capture would re-trace per version; argument
            # passing lets every version with unchanged static bounds
            # (treedef aux + shapes) share one compiled executable.
            if self._jit:
                key = (self.cache_scope, self.backend_name,
                       n_point, n_range, n_agg, agg_keys, max_hits)
                jitted = _SHARED_EXEC.get(key)
                if jitted is None:
                    jitted = jax.jit(run)
                    _SHARED_EXEC[key] = jitted
                run = jitted
            return lambda q_lo, q_hi, sides: run(index, q_lo, q_hi, sides)

        # Flat CgrxIndex-shaped indexes are not pytrees: closure-capture
        # the buffers as compile-time constants (never re-uploaded).
        def run_closed(q_lo, q_hi, sides):
            return run(index, q_lo, q_hi, sides)

        return jax.jit(run_closed) if self._jit else run_closed

    # -- conveniences (single-kind batches) ----------------------------------

    def lookup(self, queries: KeyArray) -> "cgrx.LookupResult":
        """Batched point lookup through the planner (one device call)."""
        plan = QueryBatch().add_points(queries).plan()
        return self.execute(plan).points

    def range_lookup(self, lo: KeyArray, hi: KeyArray,
                     max_hits: int) -> "cgrx.RangeResult":
        """Batched range lookup through the planner (one device call)."""
        plan = QueryBatch().add_ranges(lo, hi).plan(max_hits=max_hits)
        return self.execute(plan).ranges

    def range_aggregate(self, lo: KeyArray, hi: KeyArray,
                        with_keys: bool = False) -> "cgrx.AggResult":
        """Batched rank-only range aggregate (count, optional min/max
        keys) through the planner — one device call, no rowID gather."""
        plan = QueryBatch().add_agg_ranges(lo, hi).plan(agg_keys=with_keys)
        return self.execute(plan).aggs
