"""Backend protocol + registry for the cgRX successor search.

The paper's lookup (Alg. 2) splits into two stages: an accelerated
*rep successor search* (the BVH/RT-core traversal — "find the smallest
representative >= k") and an *in-bucket post-filter* (Sec. 3.4).  The seed
threaded the choice of search structure through string branches inside
``core/cgrx.py``; this module makes it a first-class, pluggable layer —
FliX-style update-aware dispatch — with one protocol and three built-ins:

    'tree'    lane-width fanout tree (core/fanout.py), the BVH analogue;
    'binary'  plain binary search over reps (the B+/SA-style control);
    'kernel'  Pallas kernels (kernels/ops.py), the hardware path
              (interpret=True on CPU, compiled on TPU).

Every backend answers the same three questions:

    rep_search(index, q, side)          -> bucket of the successor rep
    bucket_count(index, b, q, side)     -> #keys (<|<=) q inside bucket b
    rank(index, q, side)                -> global rank = b * B + in-bucket

plus the batched entry point ``rank_batch(index, q, sides)`` which serves
a whole lane batch of *mixed* left/right queries (0 = rank_left,
1 = rank_right) in one call — the kernel backend fuses it into a single
Pallas launch (kernels/fused_rank.py); the jnp backends evaluate both
sides vectorized and select per lane (still one jit region).

``index`` is duck-typed: anything exposing ``buckets``/``tree``/
``bucket_size``/``num_buckets``/``n`` works (``core/cgrx.CgrxIndex`` and
test doubles both qualify), which keeps this module free of a cgrx import
and the layering acyclic: core -> kernels -> query -> serving.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Protocol, runtime_checkable

import jax.numpy as jnp

from repro.core import fanout
from repro.core.keys import KeyArray, key_eq, key_le, key_lt, searchsorted


@runtime_checkable
class Backend(Protocol):
    """A successor-search implementation (paper Alg. 2 stages 1+2).

    ``kind`` names the index shape a backend serves: 'flat' backends rank
    over a flat ``BucketedSet`` (CgrxIndex-like duck types); 'node'
    backends rank over chained node buckets (NodeStore-like duck types,
    see ``NodeBackend``).
    """

    name: str
    kind: str

    def rep_search(self, index, queries: KeyArray, side: str) -> jnp.ndarray:
        """searchsorted index of each query into the rep array [0..nb]."""
        ...

    def bucket_count(self, index, bucket_id: jnp.ndarray, queries: KeyArray,
                     side: str) -> jnp.ndarray:
        """#keys (<|<=) q inside bucket ``bucket_id`` (post-filter)."""
        ...

    def rank(self, index, queries: KeyArray, side: str) -> jnp.ndarray:
        """Global rank of each query in the sorted key set (0..n)."""
        ...

    def rank_batch(self, index, queries: KeyArray,
                   sides: jnp.ndarray) -> jnp.ndarray:
        """Global rank of a mixed-side lane batch (sides: 0=left 1=right)."""
        ...


_REGISTRY: Dict[str, Backend] = {}


def register(cls):
    """Class decorator: instantiate and register under ``cls.name``."""
    inst = cls()
    _REGISTRY[inst.name] = inst
    return cls


def get_backend(name: str, kind: Optional[str] = None) -> Backend:
    """Resolve a registered backend by name.

    ``kind`` asserts the index shape the caller is about to rank over
    ('flat' | 'node'); a mismatch fails loudly instead of producing
    garbage ranks — the sharded live store uses this to guarantee every
    shard dispatches through a chain-aware backend.
    """
    try:
        backend = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None
    if kind is not None and backend.kind != kind:
        raise ValueError(
            f"backend {name!r} serves kind={backend.kind!r}, "
            f"caller requires kind={kind!r} "
            f"(available: {available_backends(kind)})")
    return backend


def available_backends(kind: Optional[str] = None) -> List[str]:
    """Registered backend names, optionally filtered by ``kind``
    ('flat' = CgrxIndex-shaped indexes, 'node' = chained node stores)."""
    return sorted(n for n, b in _REGISTRY.items()
                  if kind is None or b.kind == kind)


def compose_rank(index, b: jnp.ndarray, inb: jnp.ndarray) -> jnp.ndarray:
    """(rep rank, in-bucket count) -> global rank, clamped to [0, n].

    b == num_buckets means q beyond the max rep: rank = n (paper Alg. 2
    l.2 upper-bound check).
    """
    full = b * index.bucket_size + inb
    return jnp.where(b >= index.num_buckets, index.n,
                     jnp.minimum(full, index.n))


class _BackendBase:
    """Shared compose/post-filter logic; subclasses supply rep_search."""

    name = "?"
    kind = "flat"

    def rep_search(self, index, queries: KeyArray, side: str) -> jnp.ndarray:
        raise NotImplementedError

    def bucket_count(self, index, bucket_id: jnp.ndarray, queries: KeyArray,
                     side: str) -> jnp.ndarray:
        # Pure-jnp post-filter: gather the bucket's key slice and count.
        # Sentinel padding inside the last bucket is included; the final
        # min(rank, n) in compose_rank removes it.
        offs = (
            jnp.minimum(bucket_id, index.num_buckets - 1)[..., None]
            * index.bucket_size
            + jnp.arange(index.bucket_size, dtype=jnp.int32)
        )
        rows = index.buckets.keys.take(offs)  # (Q, B) gather from flat buffer
        qb = KeyArray(queries.lo[..., None],
                      None if queries.hi is None else queries.hi[..., None])
        cmp = key_le if side == "right" else key_lt
        return jnp.sum(cmp(rows, qb).astype(jnp.int32), axis=-1)

    def rank(self, index, queries: KeyArray, side: str = "left") -> jnp.ndarray:
        b = self.rep_search(index, queries, side)
        inb = self.bucket_count(index, b, queries, side)
        return compose_rank(index, b, inb)

    def rank_batch(self, index, queries: KeyArray,
                   sides: jnp.ndarray) -> jnp.ndarray:
        # Vectorized both-sides evaluation + per-lane select.  Fine for the
        # jnp backends (two dense passes, one jit region); the kernel
        # backend overrides with the single-pass fused kernel.
        left = self.rank(index, queries, "left")
        right = self.rank(index, queries, "right")
        return jnp.where(sides != 0, right, left)


@register
class TreeBackend(_BackendBase):
    """Fanout-tree descent (core/fanout.py) — the paper's BVH analogue."""

    name = "tree"

    def rep_search(self, index, queries: KeyArray, side: str) -> jnp.ndarray:
        return fanout.descend(index.tree, queries, side=side)


@register
class BinaryBackend(_BackendBase):
    """Binary search over reps — the B+/sorted-array-style control."""

    name = "binary"

    def rep_search(self, index, queries: KeyArray, side: str) -> jnp.ndarray:
        return searchsorted(index.buckets.reps, queries, side=side)


@register
class KernelBackend(_BackendBase):
    """Pallas kernels (kernels/ops.py) — the hardware path."""

    name = "kernel"

    def rep_search(self, index, queries: KeyArray, side: str) -> jnp.ndarray:
        from repro.kernels import ops as kops

        return kops.successor_search(index.buckets.reps, queries, side=side)

    def bucket_count(self, index, bucket_id: jnp.ndarray, queries: KeyArray,
                     side: str) -> jnp.ndarray:
        from repro.kernels import ops as kops

        return kops.bucket_rank(index.buckets, bucket_id, queries, side=side)

    def rank_batch(self, index, queries: KeyArray,
                   sides: jnp.ndarray) -> jnp.ndarray:
        from repro.kernels import ops as kops

        return kops.rank_fused(index.buckets, queries, sides)


@register
class NodeBackend(_BackendBase):
    """Chain-aware rank over the updatable node store (paper Sec. 4).

    The rep successor search is unchanged from the flat backends — the
    accelerated structure is immutable under updates, the paper's whole
    point — and is delegated per ``index.rep_method`` ('tree' fanout
    descent, 'binary' searchsorted, 'kernel' the Pallas hierarchical
    successor kernel, i.e. the same representative-search stage the fused
    kernel runs).  The post-filter then walks the bucket's node chain
    with the store's static ``max_chain`` bound, counting per node, and
    the global rank composes against ``bucket_prefix`` (exclusive prefix
    sum of per-bucket live counts) instead of ``b * B`` — chained buckets
    have variable live sizes.

    The duck-typed ``index`` must expose: ``reps``/``tree`` (immutable
    search structure), ``node_keys``/``node_rows``/``node_next``/
    ``node_size`` (the chain slab), ``node_cap``/``max_chain``/
    ``num_buckets`` (static bounds), ``bucket_prefix`` ((nb,) int32,
    exclusive) and ``rep_method``.  ``repro.store.live.NodeIndexView``
    is the canonical provider.
    """

    name = "node"
    kind = "node"

    NO_NODE = -1  # chain terminator, == core.nodes.NO_NODE

    def rep_search(self, index, queries: KeyArray, side: str) -> jnp.ndarray:
        method = getattr(index, "rep_method", "tree")
        if method == "kernel":
            from repro.kernels import ops as kops

            return kops.successor_search(index.reps, queries, side=side)
        if method == "binary":
            return searchsorted(index.reps, queries, side=side)
        return fanout.descend(index.tree, queries, side=side)

    def _chain_count(self, index, bucket_id: jnp.ndarray, queries: KeyArray,
                     sides: Optional[jnp.ndarray], side: str) -> jnp.ndarray:
        """#keys (<|<=) q across bucket ``bucket_id``'s whole chain.

        Bounded walk (static ``max_chain`` unroll, like ``nodes.lookup``);
        occupancy masks make the count exact without sentinel tricks.
        """
        N = index.node_cap
        lane = jnp.arange(N, dtype=jnp.int32)
        node = jnp.minimum(bucket_id, index.num_buckets - 1).astype(jnp.int32)
        qb = KeyArray(queries.lo[..., None],
                      None if queries.hi is None else queries.hi[..., None])
        total = jnp.zeros(queries.shape, jnp.int32)
        alive = jnp.ones(queries.shape, bool)
        for _ in range(max(index.max_chain, 1)):
            keys = index.node_keys.take(node[..., None] * N + lane)
            if sides is None:
                cmp = key_le if side == "right" else key_lt
                hit = cmp(keys, qb)
            else:  # per-lane mixed sides: le where side==1, lt where 0
                hit = key_lt(keys, qb) | ((sides[..., None] != 0)
                                          & key_eq(keys, qb))
            occ = lane < index.node_size[node][..., None]
            total += jnp.sum((hit & occ & alive[..., None]).astype(jnp.int32),
                             axis=-1)
            nxt = index.node_next[node]
            alive = alive & (nxt != self.NO_NODE)
            node = jnp.where(nxt != self.NO_NODE, nxt, node)
        return total

    def bucket_count(self, index, bucket_id: jnp.ndarray, queries: KeyArray,
                     side: str) -> jnp.ndarray:
        return self._chain_count(index, bucket_id, queries, None, side)

    def _compose(self, index, b: jnp.ndarray, inb: jnp.ndarray) -> jnp.ndarray:
        bc = jnp.minimum(b, index.num_buckets - 1)
        return (jnp.take(index.bucket_prefix, bc, mode="clip")
                + inb).astype(jnp.int32)

    def rank(self, index, queries: KeyArray, side: str = "left") -> jnp.ndarray:
        b = self.rep_search(index, queries, side)
        inb = self.bucket_count(index, b, queries, side)
        return self._compose(index, b, inb)

    def rank_batch(self, index, queries: KeyArray,
                   sides: jnp.ndarray) -> jnp.ndarray:
        # Two cheap rep searches (immutable structure), ONE chain walk
        # with a per-lane side predicate — the walk dominates.
        b_left = self.rep_search(index, queries, "left")
        b_right = self.rep_search(index, queries, "right")
        b = jnp.where(sides != 0, b_right, b_left)
        inb = self._chain_count(index, b, queries, sides, "left")
        return self._compose(index, b, inb)


# ---------------------------------------------------------------------------
# Grid-probe dispatch (the "ray" oracle used by core/grid.py).
# ---------------------------------------------------------------------------

def _jnp_probe(arrs, qs) -> jnp.ndarray:
    from repro.core.grid import searchsorted_lex

    return searchsorted_lex(arrs, qs)


def _kernel_probe(arrs, qs) -> jnp.ndarray:
    # The Pallas lex3 kernel models all three ray arities; pad the missing
    # trailing coordinates with zeros (lex order is unaffected).
    from repro.kernels import ops as kops

    a = list(arrs) + [jnp.zeros_like(arrs[0])] * (3 - len(arrs))
    q = list(qs) + [jnp.zeros_like(qs[0])] * (3 - len(qs))
    return kops.ray_probe(a[0], a[1], a[2], q[0], q[1], q[2])


_PROBES: Dict[str, Callable] = {"jnp": _jnp_probe, "kernel": _kernel_probe}


def get_probe(name: str) -> Callable:
    """Probe backend for the grid emulation: 'jnp' (binary-search oracle)
    or 'kernel' (Pallas lexicographic count).  Same signature as
    ``core/grid.searchsorted_lex``: probe(sorted_arrays, query_arrays)."""
    try:
        return _PROBES[name]
    except KeyError:
        raise KeyError(
            f"unknown probe backend {name!r}; available: {sorted(_PROBES)}"
        ) from None
