"""Batched rank-query engine (the repo's unified lookup layer).

The paper reduces point- and range-lookups to *rank queries* against the
sorted key set (Sec. 3.1-3.2); this package turns that observation into a
serving-grade subsystem:

``backends``  one ``Backend`` protocol + registry unifying the three
              successor-search paths ('tree' / 'binary' / 'kernel') that
              used to be hard-coded in ``core/cgrx.py``;
``batch``     the ``QueryBatch`` planner that coalesces mixed point
              lookups and range endpoints into padded SIMD lanes;
``engine``    the ``RankEngine`` that executes a plan in one device call.

See docs/ARCHITECTURE.md for the module map and the lane layout.
"""
from .backends import Backend, available_backends, get_backend, get_probe
from .batch import QueryBatch, QueryPlan
from .engine import BatchResult, RankEngine, clear_shared_exec

__all__ = [
    "Backend",
    "BatchResult",
    "QueryBatch",
    "QueryPlan",
    "RankEngine",
    "available_backends",
    "clear_shared_exec",
    "get_backend",
    "get_probe",
]
