"""Batched rank-query engine (the repo's unified lookup layer).

The paper reduces point- and range-lookups to *rank queries* against the
sorted key set (Sec. 3.1-3.2); this package turns that observation into a
serving-grade subsystem:

``backends``  one ``Backend`` protocol + registry unifying the three
              successor-search paths ('tree' / 'binary' / 'kernel') that
              used to be hard-coded in ``core/cgrx.py``;
``batch``     the ``QueryBatch`` planner that coalesces mixed point
              lookups, range endpoints and rank-only aggregate ranges
              into padded SIMD lanes;
``plan``      the logical expression IR (eq / between / isin / limit /
              count / min_key / max_key / probe / rank_scan / postmap)
              and the
              logical->physical compiler that fuses any mix of trees
              onto one ``QueryPlan`` + one rank-scan batch;
``engine``    the ``RankEngine`` that executes a plan in one device call
              (aggregate-only plans run rank-only: no rowID gather).

See docs/ARCHITECTURE.md for the module map and the lane layout.
"""
from .backends import Backend, available_backends, get_backend, get_probe
from .batch import MAX_MAX_HITS, QueryBatch, QueryPlan, validate_max_hits
from .engine import (BatchResult, RankEngine, STAGE_COUNTERS,
                     clear_shared_exec, stage_counter_snapshot)
from .plan import (AggKeys, Expr, ProbeResult, Program, between,
                   compile_exprs, count, eq, isin, limit, max_key, min_key,
                   postmap, probe, rank_scan)

__all__ = [
    "AggKeys",
    "Backend",
    "BatchResult",
    "Expr",
    "MAX_MAX_HITS",
    "ProbeResult",
    "Program",
    "QueryBatch",
    "QueryPlan",
    "RankEngine",
    "STAGE_COUNTERS",
    "available_backends",
    "between",
    "clear_shared_exec",
    "stage_counter_snapshot",
    "compile_exprs",
    "count",
    "eq",
    "get_backend",
    "get_probe",
    "isin",
    "limit",
    "max_key",
    "min_key",
    "postmap",
    "probe",
    "rank_scan",
    "validate_max_hits",
]
