"""Sharded, atomic, elastic checkpointing.

Checkpoints store *logical* arrays (host numpy per leaf) plus the pytree
manifest — not device layouts — so a run checkpointed on one mesh restores
onto any other (elastic re-shard): ``restore`` device_puts every leaf with
the sharding the *new* mesh's rules assign.

Atomicity: write into ``<dir>/tmp-<step>``, fsync the payload and the
manifest, ``os.rename`` to ``step-<n>`` (rename is atomic on POSIX), then
fsync the PARENT directory — without that last fsync the rename itself
can be lost on a crash, leaving a fully-written checkpoint invisible (or
worse, a ``step-<n>`` entry whose files never hit disk).  A crash mid-
save leaves only a tmp dir that the next save garbage-collects, and
``all_steps`` lists only directories whose manifest exists, so readers
never see a half-committed step.  ``save_async`` runs the
serialization on a background thread so the train loop never blocks on
I/O (the arrays are fetched to host synchronously first — cheap relative
to a step — then written in the background).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _paths(tree: Any):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, _ in flat:
        out.append("/".join(str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
                            for k in path))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, meta: Optional[dict] = None) -> str:
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        return self._write(step, host, meta or {})

    def save_async(self, step: int, tree: Any,
                   meta: Optional[dict] = None) -> None:
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(x), tree)  # fetch now
        self._thread = threading.Thread(
            target=self._write, args=(step, host, meta or {}), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any, meta: dict) -> str:
        tmp = os.path.join(self.dir, f"tmp-{step}-{os.getpid()}")
        final = os.path.join(self.dir, f"step-{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, treedef = _flatten(host_tree)
        arrays_path = os.path.join(tmp, "arrays.npz")
        np.savez(arrays_path, **{f"leaf_{i}": l for i, l in enumerate(leaves)})
        with open(arrays_path, "rb+") as f:
            os.fsync(f.fileno())
        # The treedef itself is not persisted: restore() takes a ``like``
        # pytree (NamedTuple nodes are not proto-serializable), and the
        # leaf count guards against structure drift.
        manifest = {
            "step": step,
            "num_leaves": len(leaves),
            "meta": meta,
            "time": time.time(),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _fsync_dir(self.dir)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step-{s:010d}"),
                          ignore_errors=True)
        for d in os.listdir(self.dir):          # orphaned tmp dirs
            if d.startswith("tmp-"):
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step-") and os.path.exists(
                    os.path.join(self.dir, d, "manifest.json")):
                out.append(int(d.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_manifest(self, step: int) -> dict:
        """The full manifest of one committed step (step/num_leaves/meta/
        time) — recovery reads this to learn a snapshot's WAL position
        and state layout before deciding what to restore."""
        path = os.path.join(self.dir, f"step-{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            return json.load(f)

    def restore(self, step: int, like: Any,
                shardings: Optional[Any] = None) -> Tuple[Any, dict]:
        """``like``: a pytree with the target structure (shapes may be
        abstract).  ``shardings``: optional matching NamedSharding tree —
        the elastic re-shard path."""
        path = os.path.join(self.dir, f"step-{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves = [data[f"leaf_{i}"] for i in range(manifest["num_leaves"])]
        _, treedef = _flatten(like)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree, manifest["meta"]
