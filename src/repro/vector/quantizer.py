"""Coarse quantizer: k-means centroids as the vector tier's bucket keys.

IVF-style ANN search is the paper's recipe with embeddings for keys:
quantize every vector to its nearest coarse centroid, index the centroid
ID, post-filter the retrieved buckets with exact distances.  This module
owns step one — a plain-JAX Lloyd's k-means (no host loops over data,
one ``lax.scan`` over iterations) whose trained centroids travel as a
registered pytree, so a ``CoarseQuantizer`` passes through jit boundaries
and the engine's pytree-argument executable cache like every other index
structure in the repo.

Determinism contract: seeded init (host ``default_rng`` choice of data
points), ``argmin`` assignment with first-index tie-break, and empty
clusters keep their previous centroid — the same data and seed always
yield bit-identical centroids, which the cross-tier parity suite relies
on (two tiers built from the same corpus must bucket identically).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CoarseQuantizer:
    """Trained coarse centroids (ncentroids, dim) float32."""

    centroids: jnp.ndarray

    def tree_flatten(self):
        return (self.centroids,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(centroids=children[0])

    @property
    def ncentroids(self) -> int:
        return int(self.centroids.shape[0])

    @property
    def dim(self) -> int:
        return int(self.centroids.shape[1])

    def distances(self, vectors: jnp.ndarray) -> jnp.ndarray:
        """Squared L2 from each vector to each centroid: (N, C) f32."""
        vectors = jnp.asarray(vectors, jnp.float32)
        diff = vectors[:, None, :] - self.centroids[None, :, :]
        return jnp.sum(diff * diff, axis=-1)

    def assign(self, vectors: jnp.ndarray) -> jnp.ndarray:
        """Nearest-centroid ID per vector (int32; ties -> lowest ID)."""
        return jnp.argmin(self.distances(vectors), axis=-1).astype(jnp.int32)

    def topn(self, vectors: jnp.ndarray, n: int) -> jnp.ndarray:
        """The ``n`` nearest centroid IDs per vector, nearest first
        (ties -> lowest ID; this is the probe-order contract)."""
        d = self.distances(vectors)
        order = jnp.argsort(d, axis=-1, stable=True)
        return order[:, :n].astype(jnp.int32)

    def nbytes(self) -> int:
        return int(self.centroids.size * self.centroids.dtype.itemsize)


def train_kmeans(vectors: jnp.ndarray, ncentroids: int, *, iters: int = 16,
                 seed: int = 0) -> CoarseQuantizer:
    """Lloyd's k-means over ``vectors`` (N, D); returns the trained
    quantizer.  Init samples ``ncentroids`` distinct data points with a
    seeded host RNG; each iteration is one assignment + one
    ``segment_sum`` mean update, scanned on device; clusters that lose
    every member keep their previous centroid."""
    vectors = jnp.asarray(vectors, jnp.float32)
    n = int(vectors.shape[0])
    if n < ncentroids:
        raise ValueError(
            f"k-means needs at least ncentroids={ncentroids} vectors to "
            f"seed distinct centroids, got {n}")
    rng = np.random.default_rng(seed)
    init = vectors[jnp.asarray(rng.choice(n, ncentroids, replace=False))]

    def step(centroids, _):
        q = CoarseQuantizer(centroids)
        assign = q.assign(vectors)
        sums = jax.ops.segment_sum(vectors, assign,
                                   num_segments=ncentroids)
        counts = jax.ops.segment_sum(jnp.ones((n,), jnp.float32), assign,
                                     num_segments=ncentroids)
        fresh = sums / jnp.maximum(counts, 1.0)[:, None]
        return jnp.where((counts > 0)[:, None], fresh, centroids), None

    centroids, _ = jax.lax.scan(step, init, None, length=iters)
    return CoarseQuantizer(centroids=centroids)
