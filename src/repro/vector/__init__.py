"""``repro.vector`` — the coarse-bucket vector (ANN) tier.

The paper's thesis — index coarse buckets, post-filter after retrieval —
is the IVF recipe for vector search.  This package maps it onto the
existing machinery instead of building a second engine:

``quantizer``  plain-JAX k-means ``CoarseQuantizer`` (centroids as a
               registered pytree): assignment + nearest-``nprobe``
               probe order;
``tier``       ``VectorTier`` — embeddings become 64-bit composite keys
               ``(centroidID << 32) | rowID`` on any scalar tier
               (static / live / sharded), payloads live in the
               ``store.EmbeddingArena``; a centroid bucket is a key
               range, so retrieval, updates, sharding and compaction
               are all inherited;
``session``    ``VectorSession`` — ``probe_vectors`` lowered onto the
               logical-plan IR (``postmap`` over bucket ranges; one
               fused dispatch per flush plus one ``distance_topk``
               post-filter launch per ticket), ``insert_vectors`` /
               ``delete_vectors`` riding the scalar write path.

Front door: ``repro.db.open(IndexSpec(kind='vector', dim=, ncentroids=,
nprobe=), vectors)``.  See docs/ARCHITECTURE.md ("Vector tier").
"""
from .quantizer import CoarseQuantizer, train_kmeans
from .session import NeighborResult, VectorSession
from .tier import (VectorTier, bucket_bounds, build_vector_tier,
                   composite_keys)

__all__ = [
    "CoarseQuantizer",
    "NeighborResult",
    "VectorSession",
    "VectorTier",
    "bucket_bounds",
    "build_vector_tier",
    "composite_keys",
    "train_kmeans",
]
