"""``VectorSession``: the vector tier's front door over ``db.Session``.

``probe_vectors(queries, k)`` is the paper's probe-then-post-filter
split, lowered onto the PR-5 logical-plan IR so it coalesces with every
other ticket of the flush:

  1. submission time — the coarse quantizer ranks the query batch
     against the centroids and takes the ``nprobe`` nearest per query
     (a tiny dense op on device, not a per-op-class dispatch);
  2. the probe lowers to ``postmap(refine, limit(cap, between(lo, hi)))``
     — ``Q * nprobe`` bucket ranges over the composite key space that
     fuse into the flush's ONE materializing-range section (the
     dispatch-counter pin in tests/test_vector.py);
  3. extraction time — ``refine`` reshapes the retrieved rowID blocks to
     per-query candidate sets, gathers their embeddings from the arena,
     and runs ONE ``ops.distance_topk`` launch for the whole ticket:
     exact squared-L2 top-k with the deterministic (distance, rowID)
     tie-break.

Exactness: with ``nprobe == ncentroids`` and ``probe_cap`` at least the
largest bucket occupancy, every live vector is a candidate and the
result is bit-identical to brute force (the recall suite's oracle pin).
Partial probes trade candidates for speed exactly like IVF.

Writes ride the scalar write path: ``insert_vectors`` stages embeddings
on the tier's arena and queues the composite-key insert;
``delete_vectors`` re-derives each rowID's composite key from the arena
(assignment is deterministic, so the reconstructed key equals the
inserted one) and queues the delete.  Row IDs are the identity contract:
re-using a live rowID for a different embedding without deleting it
first would strand the old composite key.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.db.session import Session, Ticket
from repro.kernels import ops
from repro.query import plan as qplan
from repro.query.batch import validate_max_hits

from .tier import VectorTier, bucket_bounds, composite_keys


class NeighborResult(NamedTuple):
    """One probe batch's exact top-k neighbors, nearest first."""

    row_id: jnp.ndarray      # int32 (Q, k) neighbor rowIDs, -1 padded
    distance: jnp.ndarray    # f32  (Q, k) squared L2, +inf padded
    count: jnp.ndarray       # int32 (Q,) valid neighbors (= min(k, cands))


class VectorSession(Session):
    """``Session`` plus the vector verbs (see module docstring)."""

    def __init__(self, tier: VectorTier, *, max_hits: int = 64,
                 nprobe: int = 1, bus=None, admission=None,
                 autotuner=None):
        super().__init__(tier, max_hits=max_hits, bus=bus,
                         admission=admission, autotuner=autotuner)
        self.nprobe = nprobe

    # -- reads ----------------------------------------------------------------

    def probe_vectors(self, queries, k: int, *,
                      nprobe: Optional[int] = None,
                      probe_cap: Optional[int] = None) -> Ticket:
        """Queue an ANN probe batch; resolves to ``NeighborResult``.

        ``queries`` (Q, dim) float32; ``k`` neighbors per query;
        ``nprobe`` buckets probed per query (default: the spec's);
        ``probe_cap`` candidate rowIDs gathered per bucket (default: the
        session's ``max_hits`` — raise it toward the largest bucket
        occupancy for exact results).  Probes queued before a flush fuse
        with every other read into one dispatch per op class; the only
        extra launch is the ticket's ``distance_topk`` post-filter.
        """
        self._check_open("probe_vectors")
        tier: VectorTier = self.tier
        q = jnp.asarray(queries, jnp.float32)
        if q.ndim != 2 or int(q.shape[1]) != tier.quantizer.dim:
            raise ValueError(
                f"probe_vectors queries must be (Q, {tier.quantizer.dim}),"
                f" got shape {tuple(q.shape)}")
        if k < 1:
            raise ValueError(f"probe_vectors needs k >= 1, got {k}")
        p = self.nprobe if nprobe is None else int(nprobe)
        if not 1 <= p <= tier.quantizer.ncentroids:
            raise ValueError(
                f"nprobe must be in [1, ncentroids="
                f"{tier.quantizer.ncentroids}], got {p}")
        cap = self.max_hits if probe_cap is None else int(probe_cap)
        try:
            validate_max_hits(cap)
        except ValueError as e:
            raise ValueError(f"probe_cap: {e}") from None

        n_q = int(q.shape[0])
        arena = tier.arena
        k = int(k)

        def refine(rng: "qplan.cgrx.RangeResult") -> NeighborResult:
            rows = rng.row_ids.reshape(n_q, p * cap)
            valid = rows >= 0
            cands = arena.gather(rows)
            dist, out_rows = ops.distance_topk(q, cands, rows, valid, k)
            n_valid = jnp.sum(valid.astype(jnp.int32), axis=-1)
            return NeighborResult(row_id=out_rows, distance=dist,
                                  count=jnp.minimum(n_valid, k))

        if n_q == 0:
            t = self._ticket("vprobe")
            t._resolve(NeighborResult(
                row_id=jnp.zeros((0, k), jnp.int32),
                distance=jnp.zeros((0, k), jnp.float32),
                count=jnp.zeros((0,), jnp.int32)))
            return t
        probe_cids = tier.quantizer.topn(q, p).reshape(-1)
        lo, hi = bucket_bounds(probe_cids)
        expr = qplan.postmap(refine, qplan.limit(cap, qplan.between(lo, hi)))
        return self.query(expr, kind="vprobe")

    # -- writes ---------------------------------------------------------------

    def insert_vectors(self, vectors, row_ids=None) -> Ticket:
        """Queue an embedding insert batch; resolves to the submitted
        count.  ``row_ids`` default to freshly allocated arena slots;
        explicit ids must not collide with live ones (delete first to
        re-key).  Returns after staging — the flush writes arena and
        index together, before the same flush's reads."""
        self._check_writable("insert_vectors")
        tier: VectorTier = self.tier
        vecs = jnp.asarray(vectors, jnp.float32)
        if vecs.ndim != 2 or int(vecs.shape[1]) != tier.quantizer.dim:
            raise ValueError(
                f"insert_vectors expects (n, {tier.quantizer.dim}) "
                f"embeddings, got shape {tuple(vecs.shape)}")
        n = int(vecs.shape[0])
        rows = (tier.arena.alloc(n) if row_ids is None
                else np.asarray(row_ids, np.int32))
        if rows.shape != (n,):
            raise ValueError(
                f"row_ids must be ({n},) to match the batch, got "
                f"{rows.shape}")
        if n == 0:
            t = self._ticket("insert")
            t._resolve(0)
            return t
        tier.stage_vectors(rows, vecs)
        keys = composite_keys(tier.quantizer.assign(vecs), rows)
        return self.insert(keys, jnp.asarray(rows))

    def delete_vectors(self, row_ids) -> Ticket:
        """Queue a delete of the embeddings at ``row_ids``; resolves to
        the submitted count.  The composite keys are re-derived from the
        arena (assignment is deterministic), so callers only name rows."""
        self._check_writable("delete_vectors")
        tier: VectorTier = self.tier
        rows = np.asarray(row_ids, np.int32)
        if rows.ndim != 1:
            raise ValueError(
                f"delete_vectors expects a 1-D rowID array, got shape "
                f"{rows.shape}")
        if rows.size and (rows.min() < 0 or
                          int(rows.max()) >= tier.arena.next_row):
            raise ValueError(
                f"delete_vectors rowIDs must be previously inserted ids "
                f"< {tier.arena.next_row}, got range "
                f"[{rows.min()}, {rows.max()}]")
        if rows.size == 0:
            t = self._ticket("delete")
            t._resolve(0)
            return t
        vecs = tier.arena.gather(jnp.asarray(rows))
        keys = composite_keys(tier.quantizer.assign(vecs), rows)
        return self.delete(keys)

    # -- introspection --------------------------------------------------------

    @property
    def ncentroids(self) -> int:
        return self.tier.quantizer.ncentroids

    @property
    def dim(self) -> int:
        return self.tier.quantizer.dim
