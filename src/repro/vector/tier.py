"""``VectorTier``: the coarse-bucket ANN tier over the scalar rank engine.

The mapping is one line of key arithmetic: embedding ``v`` with rowID
``r`` and nearest centroid ``c`` is indexed under the 64-bit composite
key ``(c << 32) | r`` — centroid ID in the high word, rowID in the low
word.  Centroid ``c``'s bucket is then exactly the key range
``[(c << 32), (c << 32) | 0xFFFFFFFF]``, so every capability of the
scalar tiers transfers without new machinery:

  * retrieval  = range lookups on the rank engine (one fused dispatch
                 for a whole probe batch, ticket coalescing included);
  * insert     = a composite-key insert + an arena write;
  * delete     = a composite-key delete (rowID low word keeps every
                 key unique, so the scalar tiers' unique-key contracts
                 — sharded routing, delete-all-copies — hold);
  * sharding   = splitter routing over composite keys; a centroid
                 bucket that straddles a splitter decomposes exactly
                 like any other range, and the merged row block
                 concatenates in shard order — the cross-shard top-k
                 merge is the ordinary sharded range merge;
  * compaction = the inner tier's epoch machinery, untouched.

The tier owns the two vector-only structures: the ``CoarseQuantizer``
(assignment + probe order) and the ``EmbeddingArena`` (rowID-addressed
payload buffer).  Staged vectors land in the arena inside ``apply`` —
BEFORE the inner scalar apply — so within one session flush the arena
is already consistent when the same flush's reads gather from it
(mirroring the session's writes-before-reads contract).

Durability is deliberately not wired yet: the WAL logs key batches, not
embeddings, so a recovered vector tier would resurrect keys whose arena
slots are gone.  ``IndexSpec`` rejects durable vector specs at the
boundary (see ``db/spec.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.keys import KeyArray
from repro.db.spec import IndexSpec
from repro.db.tiers import Stats, build_tier
from repro.store.arena import EmbeddingArena

from .quantizer import CoarseQuantizer, train_kmeans

_LO_ALL = np.uint32(0xFFFFFFFF)


def composite_keys(centroid_ids, row_ids) -> KeyArray:
    """(centroidID << 32) | rowID as a 64-bit ``KeyArray``."""
    cids = jnp.asarray(centroid_ids).astype(jnp.uint32)
    rows = jnp.asarray(row_ids).astype(jnp.uint32)
    return KeyArray(rows, cids)


def bucket_bounds(centroid_ids) -> tuple:
    """Per-centroid bucket key range: ``[(c<<32), (c<<32)|0xFFFFFFFF]``."""
    cids = jnp.asarray(centroid_ids).astype(jnp.uint32)
    lo = KeyArray(jnp.zeros_like(cids), cids)
    hi = KeyArray(jnp.full_like(cids, _LO_ALL), cids)
    return lo, hi


class VectorTier:
    """IndexTier wrapper: scalar inner tier + quantizer + arena."""

    tier = "vector"

    def __init__(self, inner, quantizer: CoarseQuantizer,
                 arena: EmbeddingArena):
        self.inner = inner
        self.quantizer = quantizer
        self.arena = arena
        self._staged: list = []

    # -- vector-side write staging -------------------------------------------

    def stage_vectors(self, rows, vectors) -> None:
        """Buffer (rowID, embedding) pairs for the next ``apply`` — the
        session queues the matching composite-key insert, and the flush
        drains both in the same write step."""
        self._staged.append((np.asarray(rows, np.int32),
                             jnp.asarray(vectors, jnp.float32)))

    # -- IndexTier protocol ---------------------------------------------------

    @property
    def writable(self) -> bool:
        return self.inner.writable

    @property
    def auto_compact(self) -> bool:
        return self.inner.auto_compact

    def apply(self, ins_keys, ins_rows, del_keys) -> None:
        # Arena first: the reads of this same flush gather candidate
        # embeddings by rowID, so the payload must be resident before
        # the index makes the keys visible.
        staged, self._staged = self._staged, []
        for rows, vecs in staged:
            self.arena.add(rows, vecs)
        self.inner.apply(ins_keys, ins_rows, del_keys)

    def execute(self, plan):
        return self.inner.execute(plan)

    def scan_ranks(self, queries: KeyArray, sides: jnp.ndarray):
        return self.inner.scan_ranks(queries, sides)

    def maybe_compact(self) -> Optional[str]:
        return self.inner.maybe_compact()

    def sync(self) -> None:
        self.inner.sync()

    @property
    def epoch(self) -> int:
        return self.inner.epoch

    def stats(self) -> Stats:
        s = self.inner.stats()
        extra = self.arena.nbytes() + self.quantizer.nbytes()
        return dataclasses.replace(s, tier=self.tier,
                                   total_bytes=s.total_bytes + extra)

    def nbytes(self) -> dict:
        out = dict(self.inner.nbytes())
        out["arena_bytes"] = self.arena.nbytes()
        out["centroid_bytes"] = self.quantizer.nbytes()
        out["total_bytes"] = (out.get("total_bytes", 0)
                              + out["arena_bytes"] + out["centroid_bytes"])
        return out


def build_vector_tier(spec: IndexSpec, vectors, row_ids=None, *,
                      train_iters: int = 16, seed: int = 0) -> VectorTier:
    """Train the quantizer on the corpus, bucket it under composite
    keys on the scalar tier ``spec.tier`` names, and seed the arena."""
    vectors = jnp.asarray(vectors, jnp.float32)
    if vectors.ndim != 2 or int(vectors.shape[1]) != spec.dim:
        raise ValueError(
            f"vector corpus must be (n, dim={spec.dim}), got shape "
            f"{tuple(vectors.shape)}")
    n = int(vectors.shape[0])
    if row_ids is None:
        rows = np.arange(n, dtype=np.int32)
    else:
        rows = np.asarray(row_ids, np.int32)
        if rows.shape != (n,):
            raise ValueError(
                f"row_ids must be ({n},) to match the corpus, got "
                f"{rows.shape}")
    quantizer = train_kmeans(vectors, spec.ncentroids, iters=train_iters,
                             seed=seed)
    keys = composite_keys(quantizer.assign(vectors), rows)
    inner = build_tier(spec.scalar_spec(), keys, jnp.asarray(rows))
    arena = EmbeddingArena.build(vectors, rows)
    return VectorTier(inner, quantizer, arena)
