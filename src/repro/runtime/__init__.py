from .ft import ElasticMesh, Heartbeat, PreemptionGuard, StragglerMonitor  # noqa: F401
