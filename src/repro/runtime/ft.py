"""Fault-tolerance runtime: heartbeats, straggler detection, preemption.

On a real 1000+-node deployment each host runs this next to the training
loop; here the mechanisms are fully implemented and exercised by tests
with simulated failures.  The contract with the loop:

  * ``Heartbeat`` — a daemon thread writes {step, wall_time} to a
    heartbeat file every ``interval``; an external watchdog (or the test)
    declares a worker dead when the file goes stale and relaunches it —
    restart recovers from the latest checkpoint (checkpoint/store.py).

  * ``StragglerMonitor`` — EMA of per-step wall time; a step exceeding
    ``threshold x`` EMA flags a straggler.  The mitigation hook is
    pluggable: the default logs; the elastic driver can drop to a smaller
    mesh (see ``ElasticMesh``) at the next checkpoint boundary.

  * ``PreemptionGuard`` — SIGTERM/SIGINT set a flag the loop polls; the
    loop then checkpoints and exits 0 (clean preemption, the TPU-pod
    maintenance pattern).

  * ``ElasticMesh`` — picks the largest rule-compatible mesh for the
    devices that are actually alive, so a relaunch after losing a slice
    reshapes (data axis shrinks, model axis preserved) and restores
    elastically re-sharded checkpoints.
"""
from __future__ import annotations

import json
import os
import signal
import threading
import time
from typing import Callable, List, Optional, Tuple

import jax
import numpy as np


class Heartbeat:
    """Liveness + progress beacon (see module doc).

    Beyond the training-loop ``step``, a beat can carry an arbitrary
    JSON-able ``payload`` — the durable serving tier publishes its WAL
    sequence number and epoch this way, so replicas measure their lag
    against the primary's beacon instead of scraping its WAL directory
    (store/replica.py).
    """

    def __init__(self, path: str, interval: float = 5.0, bus=None):
        self.path = path
        self.interval = interval
        # Optional tuning.TelemetryBus: every written beat is mirrored
        # onto the bus event ring (thread-safe by the bus's contract),
        # so liveness shows up on the same surface the admission/
        # autotuning controllers read.
        self.bus = bus
        self._stop = threading.Event()
        self._step = 0
        self._payload: dict = {}
        self._thread: Optional[threading.Thread] = None

    def update(self, step: int, payload: Optional[dict] = None) -> None:
        self._step = step
        if payload is not None:
            self._payload = dict(payload)

    def write_now(self, step: Optional[int] = None,
                  payload: Optional[dict] = None) -> None:
        """Update and write one beat synchronously (no thread needed):
        the durable session beats once per flush rather than on a timer,
        so a replica's staleness view is at most one flush behind."""
        self.update(self._step if step is None else step, payload)
        self._write()

    def start(self) -> "Heartbeat":
        def run():
            while not self._stop.wait(self.interval):
                self._write()
        self._write()
        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()
        return self

    def _write(self) -> None:
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": self._step, "time": time.time(),
                       **self._payload}, f)
        os.replace(tmp, self.path)
        if self.bus is not None:
            self.bus.event("heartbeat", step=self._step, **self._payload)

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2 * self.interval)

    @staticmethod
    def read(path: str) -> Optional[dict]:
        """The last written beat (step/time/payload), or None when the
        beacon is missing or mid-replace garbage."""
        try:
            with open(path) as f:
                return json.load(f)
        except (OSError, ValueError):
            return None

    @staticmethod
    def is_alive(path: str, stale_after: float) -> bool:
        hb = Heartbeat.read(path)
        return hb is not None and (time.time() - hb["time"]) < stale_after


class StragglerMonitor:
    def __init__(self, threshold: float = 3.0, ema: float = 0.9,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None,
                 bus=None):
        self.threshold = threshold
        self.ema_coef = ema
        self.ema: Optional[float] = None
        self.events: List[Tuple[int, float, float]] = []
        self.on_straggler = on_straggler
        # Optional tuning.TelemetryBus: straggler flags land on the same
        # event ring the serving controllers read (see Heartbeat.bus).
        self.bus = bus

    def record(self, step: int, duration: float) -> bool:
        is_straggler = False
        if self.ema is not None and duration > self.threshold * self.ema:
            is_straggler = True
            self.events.append((step, duration, self.ema))
            if self.bus is not None:
                self.bus.event("straggler", step=step, duration=duration,
                               ema=self.ema)
            if self.on_straggler:
                self.on_straggler(step, duration, self.ema)
            # A straggler step must not poison the baseline.
            return True
        self.ema = (duration if self.ema is None
                    else self.ema_coef * self.ema + (1 - self.ema_coef) * duration)
        return is_straggler


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = threading.Event()
        self._signals = signals
        self._old = {}

    def __enter__(self) -> "PreemptionGuard":
        for s in self._signals:
            self._old[s] = signal.signal(s, lambda *_: self._flag.set())
        return self

    def __exit__(self, *exc) -> None:
        for s, h in self._old.items():
            signal.signal(s, h)

    def preempted(self) -> bool:
        return self._flag.is_set()

    def trigger(self) -> None:   # for tests
        self._flag.set()


class ElasticMesh:
    """Choose the largest (data, model) mesh for the live device count.

    The model axis is preserved (parameter layout is the expensive thing
    to change); the data axis shrinks to the largest divisor that fits —
    checkpoints restore onto the new mesh via the elastic re-shard path.
    """

    def __init__(self, model_axis: int, pod_axis: int = 1):
        self.model_axis = model_axis
        self.pod_axis = pod_axis

    def mesh_for(self, num_devices: int) -> Tuple[int, ...]:
        model = self.model_axis
        while model > 1 and num_devices % model:
            model //= 2
        data = num_devices // (model * self.pod_axis)
        # largest power-of-two data axis that fits
        d = 1
        while d * 2 <= data:
            d *= 2
        return (self.pod_axis, d, model) if self.pod_axis > 1 else (d, model)
