"""Pallas TPU kernel: fused exact-distance top-k post-filter.

The paper's design splits every lookup into a coarse index probe plus an
in-bucket post-filter; the vector tier (``repro.vector``) maps IVF-style
ANN search onto the same split — the rank engine retrieves the rowID
blocks of the ``nprobe`` nearest centroid buckets, and THIS kernel is the
post-filter: squared-L2 distances from each query to its gathered
candidate embeddings plus an exact top-k selection, fused into ONE launch
(the vector analogue of ``fused_rank.py``'s one-pass rank pipeline).

Grid: 1-D over queries; each grid step owns one query row — its embedding
(1, D_pad), its candidate block (1, C_pad, D_pad), the candidate rowIDs
and validity lanes (1, C_pad) — so the distance matrix never leaves VMEM.
Selection runs k rounds of masked argmin with a deterministic tie-break:
among equal distances the SMALLEST rowID wins (the lexicographic
(distance, rowID) order ``kernels/ref.distance_topk_ref`` mirrors and the
recall suite pins bit-identical to the numpy oracle).

Padding: D pads with zeros (a zero lane adds exactly 0.0 to every
squared distance — float32 addition with 0.0 is exact, so padded and
unpadded distances are the SAME f32 values); C pads with invalid lanes
(distance forced to +inf, rowID to INT32_MAX) that can never be picked
ahead of a real candidate.  Queries with fewer than k valid candidates
pad their tail with (distance=+inf, row=-1).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128

# Plain int (not a jnp scalar): Pallas kernels may not capture traced
# constants, and an int literal folds into the comparison lanes.
_I32_MAX = jnp.iinfo(jnp.int32).max


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _dtopk_kernel(q_ref, c_ref, r_ref, v_ref, od_ref, or_ref, *, k: int,
                  k_pad: int):
    q = q_ref[...]                                    # (1, D_pad)
    c = c_ref[...][0]                                 # (C_pad, D_pad)
    rows = r_ref[...][0]                              # (C_pad,)
    valid = v_ref[...][0] != 0

    diff = c - q                                      # broadcast over C_pad
    d2 = jnp.sum(diff * diff, axis=-1)                # (C_pad,)
    d2 = jnp.where(valid, d2, jnp.inf)
    rows_eff = jnp.where(valid, rows, _I32_MAX)

    def step(j, carry):
        rem, out_d, out_r = carry
        m = jnp.min(rem)
        tied = rem == m
        r = jnp.min(jnp.where(tied, rows_eff, _I32_MAX))
        pick = tied & (rows_eff == r)
        out_d = out_d.at[j].set(m)
        out_r = out_r.at[j].set(jnp.where(jnp.isfinite(m), r,
                                          jnp.int32(-1)))
        return jnp.where(pick, jnp.inf, rem), out_d, out_r

    init = (d2, jnp.full((k_pad,), jnp.inf, jnp.float32),
            jnp.full((k_pad,), -1, jnp.int32))
    _, out_d, out_r = jax.lax.fori_loop(0, k, step, init)
    od_ref[...] = out_d[None, :]
    or_ref[...] = out_r[None, :]


def distance_topk_kernel(queries: jnp.ndarray, cands: jnp.ndarray,
                         rows: jnp.ndarray, valid: jnp.ndarray, k: int,
                         *, interpret: bool = True
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact top-k by squared L2, one launch for the whole query batch.

    queries (Q, D) f32; cands (Q, C, D) f32; rows (Q, C) int32;
    valid (Q, C) bool.  Returns (distance (Q, k) f32, row_id (Q, k)
    int32) — identical selection order to ``ref.distance_topk_ref``.
    """
    n_q, dim = queries.shape
    n_cand = cands.shape[1]
    dp = _cdiv(max(dim, 1), LANES) * LANES
    cp = _cdiv(max(n_cand, 1), LANES) * LANES
    kp = _cdiv(max(k, 1), LANES) * LANES

    qs = jnp.pad(queries.astype(jnp.float32), ((0, 0), (0, dp - dim)))
    cs = jnp.pad(cands.astype(jnp.float32),
                 ((0, 0), (0, cp - n_cand), (0, dp - dim)))
    rs = jnp.pad(rows.astype(jnp.int32), ((0, 0), (0, cp - n_cand)))
    vs = jnp.pad(valid.astype(jnp.int32), ((0, 0), (0, cp - n_cand)))

    kern = functools.partial(_dtopk_kernel, k=k, k_pad=kp)
    out_d, out_r = pl.pallas_call(
        kern,
        grid=(n_q,),
        in_specs=[
            pl.BlockSpec((1, dp), lambda i: (i, 0)),
            pl.BlockSpec((1, cp, dp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, cp), lambda i: (i, 0)),
            pl.BlockSpec((1, cp), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, kp), lambda i: (i, 0)),
            pl.BlockSpec((1, kp), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_q, kp), jnp.float32),
            jax.ShapeDtypeStruct((n_q, kp), jnp.int32),
        ],
        interpret=interpret,
    )(qs, cs, rs, vs)
    return out_d[:, :k], out_r[:, :k]
