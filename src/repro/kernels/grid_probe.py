"""Pallas TPU kernel: the "ray" — lexicographic successor over (z,y,x).

In the grid scene (core/grid.py) every xCast/yCast/zCast of the paper's
Algorithm 2 is a successor search over a coordinate-sorted triangle
directory.  This kernel computes the lexicographic rank

    rank(q) = #{ i : (z_i, y_i, x_i) <lex (qz, qy, qx) }

by streaming coordinate tiles through the VPU, identically shaped to the
successor kernel but with a 3-term compare — one kernel models all three
ray types (y-rays pass x=0, z-rays pass y=x=0).

Coordinates are int32 (the paper's 23/23/18-bit mapping guarantees fit).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _lex3_kernel(qz_ref, qy_ref, qx_ref, tz_ref, ty_ref, tx_ref, out_ref, *,
                 n_tri: int, block_t: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    qz = qz_ref[...][..., None]            # (BQ, 128, 1)
    qy = qy_ref[...][..., None]
    qx = qx_ref[...][..., None]
    tz = tz_ref[...].reshape(1, 1, -1)     # (1, 1, BT*128)
    ty = ty_ref[...].reshape(1, 1, -1)
    tx = tx_ref[...].reshape(1, 1, -1)

    below = (tz < qz) | ((tz == qz) & ((ty < qy) | ((ty == qy) & (tx < qx))))

    base = j * block_t * LANES
    gidx = base + jax.lax.broadcasted_iota(jnp.int32, below.shape, 2)
    below &= gidx < n_tri

    out_ref[...] += jnp.sum(below.astype(jnp.int32), axis=-1)


def lex3_count(tz, ty, tx, qz, qy, qx, *, block_q: int = 8, block_t: int = 8,
               interpret: bool = True) -> jnp.ndarray:
    """Lexicographic rank of each (qz,qy,qx) in the sorted triangle set."""
    n_tri = tz.shape[0]
    n_q = qz.shape[0]

    qp = _cdiv(n_q, block_q * LANES) * block_q * LANES
    tp = _cdiv(max(n_tri, 1), block_t * LANES) * block_t * LANES

    def padq(a):
        return jnp.pad(a, (0, qp - n_q)).reshape(-1, LANES)

    def padt(a):
        return jnp.pad(a, (0, tp - n_tri)).reshape(-1, LANES)

    grid = (qp // (block_q * LANES), tp // (block_t * LANES))
    qspec = pl.BlockSpec((block_q, LANES), lambda i, j: (i, 0))
    tspec = pl.BlockSpec((block_t, LANES), lambda i, j: (j, 0))
    ospec = pl.BlockSpec((block_q, LANES), lambda i, j: (i, 0))

    out = pl.pallas_call(
        functools.partial(_lex3_kernel, n_tri=n_tri, block_t=block_t),
        grid=grid,
        in_specs=[qspec, qspec, qspec, tspec, tspec, tspec],
        out_specs=ospec,
        out_shape=jax.ShapeDtypeStruct((qp // LANES, LANES), jnp.int32),
        interpret=interpret,
    )(padq(qz), padq(qy), padq(qx), padt(tz), padt(ty), padt(tx))
    return out.reshape(-1)[:n_q]
