"""Pallas TPU kernel: batched successor search over a sorted rep array.

The paper's hot loop is "find the smallest representative >= k" — on the
GPU it is a hardware BVH traversal.  The TPU-native formulation exploits
that the rep array is *sorted*, so the successor index equals

    rank(q) = #{ reps < q }          (side='left';  <= for 'right')

which is an associative reduction: stream rep tiles HBM->VMEM and
accumulate per-query counts with full-lane vector compares on the VPU.
One grid step compares a (BQ, 128) query tile against a (BR, 128) rep tile
(BQ*128 x BR*128 predicate evaluations, reduced on the fly), i.e. the
kernel is compute-shaped like a small matmul and memory-shaped like a
single streaming pass over the reps.

Grid layout: (query_blocks, rep_blocks) with rep_blocks innermost, so the
output tile stays resident in VMEM while rep tiles stream past it
(the canonical TPU accumulator pattern).  Rep padding is masked with a
global-index iota, not sentinels, so 0xFFFF.. keys stay valid.

For large rep arrays ops.py composes this kernel hierarchically
(splitter level -> tile level), turning the O(R) stream into O(sqrt R)
per query tile while keeping every step a dense vector op.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

LANES = 128


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _count_kernel(q_lo_ref, q_hi_ref, r_lo_ref, r_hi_ref, out_ref, *,
                  side: str, n_reps: int, block_r: int):
    """One (query-tile, rep-tile) step: out += #{rep (<|<=) q} per query."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    q_lo = q_lo_ref[...]                       # (BQ, 128) uint32
    r_lo = r_lo_ref[...].reshape(1, 1, -1)     # (1, 1, BR*128)
    ql = q_lo[..., None]                       # (BQ, 128, 1)

    if q_hi_ref is not None:
        q_hi = q_hi_ref[...][..., None]
        r_hi = r_hi_ref[...].reshape(1, 1, -1)
        if side == "left":
            below = (r_hi < q_hi) | ((r_hi == q_hi) & (r_lo < ql))
        else:
            below = (r_hi < q_hi) | ((r_hi == q_hi) & (r_lo <= ql))
    else:
        below = (r_lo < ql) if side == "left" else (r_lo <= ql)

    # Mask rep padding by global index (no sentinel ambiguity).
    base = j * block_r * LANES
    gidx = base + jax.lax.broadcasted_iota(jnp.int32, below.shape, 2)
    below &= gidx < n_reps

    out_ref[...] += jnp.sum(below.astype(jnp.int32), axis=-1)


def successor_count(reps_lo: jnp.ndarray, reps_hi: Optional[jnp.ndarray],
                    q_lo: jnp.ndarray, q_hi: Optional[jnp.ndarray],
                    side: str = "left", *, block_q: int = 8, block_r: int = 8,
                    interpret: bool = True) -> jnp.ndarray:
    """rank(q) over the full rep array.  1-D in, 1-D int32 out."""
    n_reps = reps_lo.shape[0]
    n_q = q_lo.shape[0]
    is64 = reps_hi is not None

    qp = _cdiv(n_q, block_q * LANES) * block_q * LANES
    rp = _cdiv(max(n_reps, 1), block_r * LANES) * block_r * LANES

    def pad(a, n, c=0):
        return jnp.pad(a, (0, n - a.shape[0]), constant_values=c)

    q_lo2 = pad(q_lo, qp).reshape(-1, LANES)
    r_lo2 = pad(reps_lo, rp).reshape(-1, LANES)
    q_hi2 = pad(q_hi, qp).reshape(-1, LANES) if is64 else None
    r_hi2 = pad(reps_hi, rp).reshape(-1, LANES) if is64 else None

    grid = (qp // (block_q * LANES), rp // (block_r * LANES))

    qspec = pl.BlockSpec((block_q, LANES), lambda i, j: (i, 0))
    rspec = pl.BlockSpec((block_r, LANES), lambda i, j: (j, 0))
    ospec = pl.BlockSpec((block_q, LANES), lambda i, j: (i, 0))

    kern = functools.partial(_count_kernel, side=side, n_reps=n_reps,
                             block_r=block_r)
    if is64:
        def kernel(ql, qh, rl, rh, o):
            kern(ql, qh, rl, rh, o)
        in_specs = [qspec, qspec, rspec, rspec]
        args = (q_lo2, q_hi2, r_lo2, r_hi2)
    else:
        def kernel(ql, rl, o):
            kern(ql, None, rl, None, o)
        in_specs = [qspec, rspec]
        args = (q_lo2, r_lo2)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=ospec,
        out_shape=jax.ShapeDtypeStruct((qp // LANES, LANES), jnp.int32),
        interpret=interpret,
    )(*args)
    return out.reshape(-1)[:n_q]
