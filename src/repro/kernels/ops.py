"""jit'd public wrappers around the Pallas kernels, with backend dispatch.

On CPU (this container) kernels run in interpret mode — the kernel body
executes in Python per grid step, which validates correctness but is slow;
pure-jnp fallbacks therefore back the benchmarks unless kernels are
explicitly requested.  On TPU the compiled kernels are the hardware path.

``successor_search`` composes the streaming count kernel hierarchically:
for large rep arrays a first pass ranks queries against the 1/128-rate
*splitter* subsequence (reps[127::128] — the last rep of each lane tile,
mirroring how fanout.py builds its tree), then a second pass ranks within
the gathered 128-wide candidate tile.  Work per query drops from O(R) to
O(R/128 + 128) while every step stays a dense VPU compare.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.bucketing import BucketedSet
from repro.core.keys import KeyArray

from . import bucket_search, grid_probe, successor

LANES = 128


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Successor search (flat + hierarchical).
# ---------------------------------------------------------------------------

def successor_search_flat(reps: KeyArray, queries: KeyArray,
                          side: str = "left") -> jnp.ndarray:
    return successor.successor_count(
        reps.lo, reps.hi, queries.lo, queries.hi, side,
        interpret=_interpret())


def successor_search(reps: KeyArray, queries: KeyArray, side: str = "left",
                     two_level_threshold: int = 4096) -> jnp.ndarray:
    n = reps.shape[0]
    if n <= two_level_threshold:
        return successor_search_flat(reps, queries, side)

    # Level 1: rank against splitters (last rep of each 128-lane tile).
    spl = reps[LANES - 1::LANES]
    tile = successor.successor_count(
        spl.lo, spl.hi, queries.lo, queries.hi, side, interpret=_interpret())
    tile = jnp.minimum(tile, (n - 1) // LANES)

    # Level 2: rank inside the gathered candidate tile.
    offs = tile[:, None] * LANES + jnp.arange(LANES, dtype=jnp.int32)
    offs = jnp.minimum(offs, n - 1)
    rows = reps.take(offs)
    # Mask tail-tile padding (clamped gathers duplicate the last rep).
    valid = tile[:, None] * LANES + jnp.arange(LANES, dtype=jnp.int32) < n
    inb = bucket_search.bucket_rank_kernel(
        jnp.where(valid, rows.lo, jnp.uint32(0xFFFFFFFF)),
        None if rows.hi is None else jnp.where(valid, rows.hi, jnp.uint32(0xFFFFFFFF)),
        queries.lo, queries.hi, side, interpret=_interpret())
    # Sentinel masking breaks for q == MAX; correct those by the validity
    # count directly (rank can never exceed the number of valid slots).
    inb = jnp.minimum(inb, jnp.sum(valid, axis=-1))
    return jnp.minimum(tile * LANES + inb, n).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Bucket post-filter.
# ---------------------------------------------------------------------------

def bucket_rank(buckets: BucketedSet, bucket_id: jnp.ndarray,
                queries: KeyArray, side: str = "left") -> jnp.ndarray:
    B = buckets.bucket_size
    nb = buckets.num_buckets
    offs = (jnp.minimum(bucket_id, nb - 1)[..., None] * B
            + jnp.arange(B, dtype=jnp.int32))
    rows = buckets.keys.take(offs)
    return bucket_search.bucket_rank_kernel(
        rows.lo, rows.hi, queries.lo, queries.hi, side,
        interpret=_interpret())


# ---------------------------------------------------------------------------
# Grid ray probe.
# ---------------------------------------------------------------------------

def ray_probe(tz, ty, tx, qz, qy, qx) -> jnp.ndarray:
    return grid_probe.lex3_count(tz, ty, tx, qz, qy, qx,
                                 interpret=_interpret())
