"""jit'd public wrappers around the Pallas kernels.

This module is the hardware face of the ``'kernel'`` backend registered in
``repro.query.backends`` — the Pallas analogue of the paper's RT-core path.
On CPU (this container) kernels run in interpret mode — the kernel body
executes in Python per grid step, which validates correctness but is slow;
pure-jnp fallbacks therefore back the benchmarks unless kernels are
explicitly requested.  On TPU the compiled kernels are the hardware path.

Three granularities are exposed:

``successor_search`` (paper Alg. 2's BVH traversal, Sec. 3.1) composes the
streaming count kernel hierarchically: for large rep arrays a first pass
ranks queries against the 1/128-rate *splitter* subsequence
(reps[127::128] — the last rep of each lane tile, mirroring how fanout.py
builds its tree), then a second pass ranks within the gathered 128-wide
candidate tile.  Work per query drops from O(R) to O(R/128 + 128) while
every step stays a dense VPU compare.

``bucket_rank`` (the in-bucket post-filter, Sec. 3.4 Table 1) counts keys
below the query inside one pre-gathered bucket row — the vectorized
equivalent of the paper's per-thread upper-bound binary search.

``rank_fused`` (the batched engine's hot path) fuses both stages plus the
splitter level into ONE kernel launch for a whole batch of mixed
point/range lanes (per-lane left/right sides) — see kernels/fused_rank.py.
It degrades gracefully: when the flat key buffer would blow the VMEM
budget on a real TPU, it falls back to the composed two-pass path, which
streams tiles instead of holding them resident.

``distance_topk`` (the vector tier's post-filter, kernels/
distance_topk.py) is the same discipline for the ANN workload: exact
squared-L2 top-k over the candidate embeddings the rank engine
retrieved, one launch per probe batch, jnp fallback under the same VMEM
budget.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.bucketing import BucketedSet
from repro.core.keys import KeyArray

from . import bucket_search, distance_topk as dtopk_mod, fused_rank, \
    grid_probe, ref, successor

LANES = 128

# Residency budget for the fused kernel's block-pinned operands (reps +
# flat keys, lo+hi planes).  Compiled TPU kernels beyond this stream via
# the composed path; interpret mode (CPU) has no such limit.
FUSED_VMEM_BUDGET_BYTES = 8 * 2 ** 20


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Successor search (flat + hierarchical).
# ---------------------------------------------------------------------------

def successor_search_flat(reps: KeyArray, queries: KeyArray,
                          side: str = "left") -> jnp.ndarray:
    """rank(q) by one streaming pass over the full rep array (paper: the
    brute BVH-less scan; used directly for small rep sets)."""
    return successor.successor_count(
        reps.lo, reps.hi, queries.lo, queries.hi, side,
        interpret=_interpret())


def successor_search(reps: KeyArray, queries: KeyArray, side: str = "left",
                     two_level_threshold: int = 4096) -> jnp.ndarray:
    """Hierarchical successor search (splitters -> candidate tile).

    Equivalent to ``searchsorted(reps, queries, side)``; this is the
    kernel backend's rep-search stage (paper Alg. 2 l.3: the traversal
    that the GPU delegates to RT cores).
    """
    n = reps.shape[0]
    if n <= two_level_threshold:
        return successor_search_flat(reps, queries, side)

    # Level 1: rank against splitters (last rep of each 128-lane tile).
    spl = reps[LANES - 1::LANES]
    tile = successor.successor_count(
        spl.lo, spl.hi, queries.lo, queries.hi, side, interpret=_interpret())
    tile = jnp.minimum(tile, (n - 1) // LANES)

    # Level 2: rank inside the gathered candidate tile.
    offs = tile[:, None] * LANES + jnp.arange(LANES, dtype=jnp.int32)
    offs = jnp.minimum(offs, n - 1)
    rows = reps.take(offs)
    # Mask tail-tile padding (clamped gathers duplicate the last rep).
    valid = tile[:, None] * LANES + jnp.arange(LANES, dtype=jnp.int32) < n
    inb = bucket_search.bucket_rank_kernel(
        jnp.where(valid, rows.lo, jnp.uint32(0xFFFFFFFF)),
        None if rows.hi is None else jnp.where(valid, rows.hi, jnp.uint32(0xFFFFFFFF)),
        queries.lo, queries.hi, side, interpret=_interpret())
    # Sentinel masking breaks for q == MAX; correct those by the validity
    # count directly (rank can never exceed the number of valid slots).
    inb = jnp.minimum(inb, jnp.sum(valid, axis=-1))
    return jnp.minimum(tile * LANES + inb, n).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Bucket post-filter.
# ---------------------------------------------------------------------------

def bucket_rank(buckets: BucketedSet, bucket_id: jnp.ndarray,
                queries: KeyArray, side: str = "left") -> jnp.ndarray:
    """#keys (<|<=) q inside bucket ``bucket_id`` (paper Sec. 3.4: the
    bucket search after the traversal returns a bucketID)."""
    B = buckets.bucket_size
    nb = buckets.num_buckets
    offs = (jnp.minimum(bucket_id, nb - 1)[..., None] * B
            + jnp.arange(B, dtype=jnp.int32))
    rows = buckets.keys.take(offs)
    return bucket_search.bucket_rank_kernel(
        rows.lo, rows.hi, queries.lo, queries.hi, side,
        interpret=_interpret())


# ---------------------------------------------------------------------------
# Fused batched rank (the query engine's one-launch path).
# ---------------------------------------------------------------------------

def rank_fused(buckets: BucketedSet, queries: KeyArray,
               sides: jnp.ndarray) -> jnp.ndarray:
    """Global rank of a mixed-side lane batch in one kernel launch.

    ``sides``: (Q,) int32, 0 = rank_left (#keys < q), 1 = rank_right
    (#keys <= q).  Point lookups use one left lane; a range [l, u] uses a
    left lane for l and a right lane for u (paper Sec. 3.2).  Results are
    bit-identical to ``core/cgrx.rank`` with the corresponding ``side``.
    """
    interp = _interpret()
    planes = 2 if buckets.keys.is64 else 1
    resident = (buckets.reps.shape[0] + buckets.keys.shape[0]) * 4 * planes
    if not interp and resident > FUSED_VMEM_BUDGET_BYTES:
        # Too big to pin in VMEM: compose the streaming kernels per side
        # and select lanes (still one jit region, two passes over reps).
        left = successor_search(buckets.reps, queries, "left")
        right = successor_search(buckets.reps, queries, "right")
        b = jnp.where(sides != 0, right, left)
        inb_l = bucket_rank(buckets, b, queries, "left")
        inb_r = bucket_rank(buckets, b, queries, "right")
        inb = jnp.where(sides != 0, inb_r, inb_l)
        full = b * buckets.bucket_size + inb
        return jnp.where(b >= buckets.num_buckets, buckets.n,
                         jnp.minimum(full, buckets.n)).astype(jnp.int32)
    return fused_rank.fused_rank_count(
        buckets.reps.lo, buckets.reps.hi, buckets.keys.lo, buckets.keys.hi,
        queries.lo, queries.hi, sides, n=buckets.n,
        bucket_size=buckets.bucket_size, interpret=interp)


def range_count(buckets: BucketedSet, lo: KeyArray,
                hi: KeyArray) -> jnp.ndarray:
    """COUNT(*) over [lo, hi] ranges — the rank-only execution path.

    One fused mixed-side launch (left lanes for the lows, right lanes
    for the highs) followed by a subtraction:
    ``count = rank_right(hi) - rank_left(lo)``.  No rowID block is ever
    gathered — this is the kernel-level primitive under the query
    engine's aggregate fast path (GPU-RMQ-style range aggregation
    without materializing hits), and the hand-rolled comparator
    ``benchmarks/bench_query_plan.py`` times the compiled plans against.
    """
    r = int(lo.shape[0])
    queries = KeyArray(
        jnp.concatenate([lo.lo, hi.lo]),
        None if lo.hi is None else jnp.concatenate([lo.hi, hi.hi]))
    sides = jnp.concatenate([jnp.zeros((r,), jnp.int32),
                             jnp.ones((r,), jnp.int32)])
    ranks = rank_fused(buckets, queries, sides)
    return jnp.maximum(ranks[r:] - ranks[:r], 0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Vector post-filter (the vector tier's one-launch refinement step).
# ---------------------------------------------------------------------------

def distance_topk(queries: jnp.ndarray, cands: jnp.ndarray,
                  rows: jnp.ndarray, valid: jnp.ndarray, k: int,
                  method: str = "auto"):
    """Exact top-k neighbors by squared L2 over per-query candidates.

    queries (Q, D) f32; cands (Q, C, D) f32 (the gathered bucket
    embeddings); rows (Q, C) int32 rowIDs; valid (Q, C) bool.  Returns
    (distance (Q, k) f32 +inf-padded, row_id (Q, k) int32 -1-padded),
    ordered by the deterministic (distance, rowID) tie-break.

    ``method``: 'kernel' launches the fused Pallas kernel
    (kernels/distance_topk.py), 'ref' the pure-jnp oracle, 'auto' picks
    the kernel on TPU and the jnp path elsewhere — same split as the
    rank kernels (interpret-mode Pallas validates correctness but is the
    slow path).  A kernel request whose per-query candidate block would
    not fit the VMEM budget falls back to the streamed jnp path, the
    ``rank_fused`` degradation contract.
    """
    if method not in ("auto", "kernel", "ref"):
        raise ValueError(
            f"distance_topk method must be 'auto', 'kernel' or 'ref', "
            f"got {method!r}")
    n_q, dim = queries.shape
    if n_q == 0:
        return (jnp.zeros((0, k), jnp.float32),
                jnp.zeros((0, k), jnp.int32))
    interp = _interpret()
    use_kernel = method == "kernel" or (method == "auto" and not interp)
    if use_kernel:
        cp = -(-cands.shape[1] // LANES) * LANES
        dp = -(-dim // LANES) * LANES
        resident = (cp * dp + dp + 2 * cp) * 4
        if interp or resident <= FUSED_VMEM_BUDGET_BYTES:
            return dtopk_mod.distance_topk_kernel(
                queries, cands, rows, valid, k, interpret=interp)
    return ref.distance_topk_ref(queries, cands, rows, valid, k)


# ---------------------------------------------------------------------------
# Grid ray probe.
# ---------------------------------------------------------------------------

def ray_probe(tz, ty, tx, qz, qy, qx) -> jnp.ndarray:
    """One emulated "ray" (paper Alg. 2 casts): lexicographic rank of each
    (qz,qy,qx) in the coordinate-sorted triangle directory.  Lower-arity
    casts pass zeros for the missing coordinates."""
    return grid_probe.lex3_count(tz, ty, tx, qz, qy, qx,
                                 interpret=_interpret())
