"""Pure-jnp oracles for every Pallas kernel (tested with assert_allclose)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.grid import searchsorted_lex
from repro.core.keys import KeyArray, searchsorted

# Plain int, not jnp.int32(...): this module may first be imported inside
# a jit trace, and a module-level device constant created there would be a
# leaked tracer for every later caller.
_I32_MAX = jnp.iinfo(jnp.int32).max


def successor_count_ref(reps_lo, reps_hi, q_lo, q_hi, side: str = "left"):
    reps = KeyArray(reps_lo, reps_hi)
    q = KeyArray(q_lo, q_hi)
    return searchsorted(reps, q, side=side).astype(jnp.int32)


def bucket_rank_ref(rows_lo, rows_hi, q_lo, q_hi, side: str = "left"):
    """rows: (Q, B); per-row rank of q."""
    if rows_hi is not None:
        ql, qh = q_lo[:, None], q_hi[:, None]
        if side == "left":
            below = (rows_hi < qh) | ((rows_hi == qh) & (rows_lo < ql))
        else:
            below = (rows_hi < qh) | ((rows_hi == qh) & (rows_lo <= ql))
    else:
        ql = q_lo[:, None]
        below = (rows_lo < ql) if side == "left" else (rows_lo <= ql)
    return jnp.sum(below.astype(jnp.int32), axis=-1)


def lex3_count_ref(tz, ty, tx, qz, qy, qx):
    return searchsorted_lex((tz, ty, tx), (qz, qy, qx), side="left")


def distance_topk_ref(queries: jnp.ndarray, cands: jnp.ndarray,
                      rows: jnp.ndarray, valid: jnp.ndarray,
                      k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact top-k by squared L2 over per-query candidate sets.

    queries (Q, D) f32; cands (Q, C, D) f32; rows (Q, C) int32 rowIDs;
    valid (Q, C) bool.  Returns (distance (Q, k) f32 +inf-padded,
    row_id (Q, k) int32 -1-padded), selected by the deterministic
    (distance, rowID)-lexicographic order the Pallas kernel implements —
    k rounds of masked argmin with min-rowID tie-break.
    """
    q = queries.shape[0]
    d2 = jnp.sum(jnp.square(cands - queries[:, None, :]), axis=-1)
    d2 = jnp.where(valid, d2, jnp.inf)
    rows_eff = jnp.where(valid, rows.astype(jnp.int32), _I32_MAX)

    def step(j, carry):
        rem, out_d, out_r = carry
        m = jnp.min(rem, axis=-1)                         # (Q,)
        tied = rem == m[:, None]
        r = jnp.min(jnp.where(tied, rows_eff, _I32_MAX), axis=-1)
        pick = tied & (rows_eff == r[:, None])
        out_d = out_d.at[:, j].set(m)
        out_r = out_r.at[:, j].set(
            jnp.where(jnp.isfinite(m), r, jnp.int32(-1)))
        return jnp.where(pick, jnp.inf, rem), out_d, out_r

    init = (d2, jnp.full((q, k), jnp.inf, jnp.float32),
            jnp.full((q, k), -1, jnp.int32))
    _, out_d, out_r = jax.lax.fori_loop(0, k, step, init)
    return out_d, out_r
