"""Pure-jnp oracles for every Pallas kernel (tested with assert_allclose)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.grid import searchsorted_lex
from repro.core.keys import KeyArray, searchsorted


def successor_count_ref(reps_lo, reps_hi, q_lo, q_hi, side: str = "left"):
    reps = KeyArray(reps_lo, reps_hi)
    q = KeyArray(q_lo, q_hi)
    return searchsorted(reps, q, side=side).astype(jnp.int32)


def bucket_rank_ref(rows_lo, rows_hi, q_lo, q_hi, side: str = "left"):
    """rows: (Q, B); per-row rank of q."""
    if rows_hi is not None:
        ql, qh = q_lo[:, None], q_hi[:, None]
        if side == "left":
            below = (rows_hi < qh) | ((rows_hi == qh) & (rows_lo < ql))
        else:
            below = (rows_hi < qh) | ((rows_hi == qh) & (rows_lo <= ql))
    else:
        ql = q_lo[:, None]
        below = (rows_lo < ql) if side == "left" else (rows_lo <= ql)
    return jnp.sum(below.astype(jnp.int32), axis=-1)


def lex3_count_ref(tz, ty, tx, qz, qy, qx):
    return searchsorted_lex((tz, ty, tx), (qz, qy, qx), side="left")
