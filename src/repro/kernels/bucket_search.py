"""Pallas TPU kernel: in-bucket rank (the paper's bucket post-filter).

After the successor search yields a bucketID, the paper searches the
bucket's key slice with linear or binary search per thread (Sec. 3.4,
Table 1).  Per-lane binary search is hostile to the VPU (divergent gather
per step), so the TPU formulation is a *rank count*: the bucket row is a
(B,)-slice in VMEM and

    pos(q) = #{ keys_in_bucket (<|<=) q }

is one vector compare + reduce — the vectorized equivalent of the paper's
upper-bound binary search (it returns the identical index).  For large B
the count streams bucket chunks, giving the same
compute/footprint trade-off the paper tunes with the bucket size.

Inputs are pre-gathered bucket rows (Q, B) (an XLA gather — the TPU's
analogue of the coalesced per-thread bucket read) plus the queries (Q,).
Grid: (q_blocks, chunk_blocks), chunks innermost, accumulated in VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _rank_kernel(q_lo_ref, q_hi_ref, b_lo_ref, b_hi_ref, out_ref, *,
                 side: str, bucket_b: int, block_b: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ql = q_lo_ref[...]                     # (BQ, 1)
    bl = b_lo_ref[...]                     # (BQ, BB)
    if q_hi_ref is not None:
        qh = q_hi_ref[...]
        bh = b_hi_ref[...]
        if side == "left":
            below = (bh < qh) | ((bh == qh) & (bl < ql))
        else:
            below = (bh < qh) | ((bh == qh) & (bl <= ql))
    else:
        below = (bl < ql) if side == "left" else (bl <= ql)

    base = j * block_b
    gidx = base + jax.lax.broadcasted_iota(jnp.int32, below.shape, 1)
    below &= gidx < bucket_b

    out_ref[...] += jnp.sum(below.astype(jnp.int32), axis=-1, keepdims=True)


def bucket_rank_kernel(rows_lo: jnp.ndarray, rows_hi: Optional[jnp.ndarray],
                       q_lo: jnp.ndarray, q_hi: Optional[jnp.ndarray],
                       side: str = "left", *, block_q: int = 256,
                       block_b: int = 512, interpret: bool = True) -> jnp.ndarray:
    """rows: (Q, B) gathered bucket keys; queries: (Q,).  Returns (Q,) int32."""
    n_q, B = rows_lo.shape
    is64 = rows_hi is not None
    block_b = min(block_b, _cdiv(B, 128) * 128 if B >= 128 else B)
    block_b = max(block_b, 1)

    qp = _cdiv(n_q, block_q) * block_q
    bp = _cdiv(B, block_b) * block_b

    def pad2(a):
        return jnp.pad(a, ((0, qp - n_q), (0, bp - B)))

    def pad1(a):
        return jnp.pad(a, (0, qp - n_q)).reshape(-1, 1)

    rows_lo2 = pad2(rows_lo)
    q_lo2 = pad1(q_lo)
    rows_hi2 = pad2(rows_hi) if is64 else None
    q_hi2 = pad1(q_hi) if is64 else None

    grid = (qp // block_q, bp // block_b)
    qspec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))
    bspec = pl.BlockSpec((block_q, block_b), lambda i, j: (i, j))
    ospec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))

    kern = functools.partial(_rank_kernel, side=side, bucket_b=B,
                             block_b=block_b)
    if is64:
        def kernel(ql, qh, bl, bh, o):
            kern(ql, qh, bl, bh, o)
        in_specs = [qspec, qspec, bspec, bspec]
        args = (q_lo2, q_hi2, rows_lo2, rows_hi2)
    else:
        def kernel(ql, bl, o):
            kern(ql, None, bl, None, o)
        in_specs = [qspec, bspec]
        args = (q_lo2, rows_lo2)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=ospec,
        out_shape=jax.ShapeDtypeStruct((qp, 1), jnp.int32),
        interpret=interpret,
    )(*args)
    return out.reshape(-1)[:n_q]
