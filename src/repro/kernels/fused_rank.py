"""Pallas TPU kernel: fused multi-query rank over the whole cgRX index.

The per-call compose in ``ops.successor_search`` + ``ops.bucket_rank``
launches three kernels per lookup batch (splitter rank, candidate-tile
rank, in-bucket rank) with two host-visible gathers in between.  This
kernel fuses the paper's entire rank-query pipeline (Alg. 2 + Sec. 3.2's
rank formulation) into ONE pass per query tile:

    stage 1  splitter ranking    tile(q) = #{ splitters cmp q }
    stage 2  candidate gather    rank inside reps[tile*128 : tile*128+128]
    stage 3  in-bucket counting  rank inside bucket b's key slice

where ``cmp`` is *per-lane* ``<`` or ``<=`` selected by a ``sides`` vector
(0 = left / ``rank_left``, 1 = right / ``rank_right``).  Mixed point- and
range-lookups therefore share one launch: a point query occupies one lane
(side=left) and a range occupies two (lo/left, hi/right) — the batching
that RTCUDB applies to RT-core queries, expressed as VPU tiles.

The grid is 1-D over query tiles; the splitter, representative and
key-rowID arrays are block-resident (index_map pins them to block 0), so
each grid step performs all three stages without leaving VMEM.  That is
the right shape for coarse-granular indexes: the paper's recommended
config (Sec. 5.4, bucket size 16) keeps reps at n/16 entries, and the
flat key buffer for container-scale sets fits the ~16 MB VMEM budget.
``ops.rank_fused`` falls back to the composed streaming kernels when it
would not (the guard is there, not here, to keep this kernel branch-free).

Gathers (stages 2/3) use clamped indices exactly like the jnp oracle in
``query/backends.py``: the sentinel padding inside the last bucket is
*included* in the stage-3 count and the final ``min(rank, n)`` removes it,
matching ``core/cgrx.rank`` bit for bit.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _cmp(r_lo, r_hi, q_lo, q_hi, is_right):
    """Per-lane count predicate: r < q  |  (side=right & r == q)."""
    if q_hi is None:
        lt = r_lo < q_lo
        eq = r_lo == q_lo
    else:
        lt = (r_hi < q_hi) | ((r_hi == q_hi) & (r_lo < q_lo))
        eq = (r_hi == q_hi) & (r_lo == q_lo)
    return lt | (is_right & eq)


def _fused_kernel(q_lo_ref, q_hi_ref, side_ref, s_lo_ref, s_hi_ref,
                  r_lo_ref, r_hi_ref, k_lo_ref, k_hi_ref, out_ref, *,
                  n_spl: int, n_reps: int, num_buckets: int,
                  bucket_size: int, n_keys: int):
    is64 = q_hi_ref is not None
    ql = q_lo_ref[...]                                  # (BQ, 128)
    qh = q_hi_ref[...] if is64 else None
    is_right = side_ref[...] != 0

    ql3 = ql[..., None]                                 # (BQ, 128, 1)
    qh3 = qh[..., None] if is64 else None
    isr3 = is_right[..., None]

    # Stage 1: splitter ranking (splitter t = last rep of lane tile t).
    s_lo = s_lo_ref[...].reshape(1, 1, -1)
    s_hi = s_hi_ref[...].reshape(1, 1, -1) if is64 else None
    below = _cmp(s_lo, s_hi, ql3, qh3, isr3)
    sidx = jax.lax.broadcasted_iota(jnp.int32, below.shape, 2)
    below &= sidx < n_spl
    tile = jnp.sum(below.astype(jnp.int32), axis=-1)    # (BQ, 128)
    tile = jnp.minimum(tile, (n_reps - 1) // LANES)

    # Stage 2: candidate-tile gather + in-tile rank.
    lane = jax.lax.broadcasted_iota(jnp.int32, tile.shape + (LANES,), 2)
    offs = tile[..., None] * LANES + lane
    valid = offs < n_reps
    offs_c = jnp.minimum(offs, n_reps - 1)
    r_lo = jnp.take(r_lo_ref[...].reshape(-1), offs_c)
    r_hi = jnp.take(r_hi_ref[...].reshape(-1), offs_c) if is64 else None
    inb = _cmp(r_lo, r_hi, ql3, qh3, isr3) & valid
    b = tile * LANES + jnp.sum(inb.astype(jnp.int32), axis=-1)

    # Stage 3: bucket gather + in-bucket counting (post-filter).
    bb = jnp.minimum(b, num_buckets - 1)
    slot = jax.lax.broadcasted_iota(jnp.int32, bb.shape + (bucket_size,), 2)
    koffs = bb[..., None] * bucket_size + slot          # always < nb*B
    k_lo = jnp.take(k_lo_ref[...].reshape(-1), koffs)
    k_hi = jnp.take(k_hi_ref[...].reshape(-1), koffs) if is64 else None
    cnt = _cmp(k_lo, k_hi, ql3, qh3, isr3)
    full = b * bucket_size + jnp.sum(cnt.astype(jnp.int32), axis=-1)

    rank = jnp.where(b >= num_buckets, n_keys, jnp.minimum(full, n_keys))
    out_ref[...] = rank.astype(jnp.int32)


def fused_rank_count(reps_lo: jnp.ndarray, reps_hi: Optional[jnp.ndarray],
                     keys_lo: jnp.ndarray, keys_hi: Optional[jnp.ndarray],
                     q_lo: jnp.ndarray, q_hi: Optional[jnp.ndarray],
                     sides: jnp.ndarray, *, n: int, bucket_size: int,
                     block_q: int = 8, interpret: bool = True) -> jnp.ndarray:
    """Global rank of every query in one fused pass.

    reps: (num_buckets,) sorted representatives; keys: the flat sorted
    key buffer (num_buckets * bucket_size, sentinel padded); q/sides: (Q,)
    with sides[i] in {0: rank_left, 1: rank_right}.  Returns (Q,) int32
    ranks in [0, n] — identical to ``core/cgrx.rank`` per side.
    """
    n_reps = reps_lo.shape[0]
    n_keys_buf = keys_lo.shape[0]
    num_buckets = n_keys_buf // bucket_size
    n_q = q_lo.shape[0]
    is64 = reps_hi is not None

    spl_lo = reps_lo[LANES - 1::LANES]
    spl_hi = reps_hi[LANES - 1::LANES] if is64 else None
    n_spl = spl_lo.shape[0]

    qp = _cdiv(max(n_q, 1), block_q * LANES) * block_q * LANES
    sp = _cdiv(max(n_spl, 1), LANES) * LANES
    rp = _cdiv(max(n_reps, 1), LANES) * LANES
    kp = _cdiv(max(n_keys_buf, 1), LANES) * LANES

    def pad(a, m):
        return jnp.pad(a, (0, m - a.shape[0])).reshape(-1, LANES)

    grid = (qp // (block_q * LANES),)
    qspec = pl.BlockSpec((block_q, LANES), lambda i: (i, 0))

    def full_spec(m):
        return pl.BlockSpec((m // LANES, LANES), lambda i: (0, 0))

    kern = functools.partial(
        _fused_kernel, n_spl=n_spl, n_reps=n_reps, num_buckets=num_buckets,
        bucket_size=bucket_size, n_keys=n)
    if is64:
        def kernel(ql, qh, sd, sl, sh, rl, rh, kl, kh, o):
            kern(ql, qh, sd, sl, sh, rl, rh, kl, kh, o)
        in_specs = [qspec, qspec, qspec, full_spec(sp), full_spec(sp),
                    full_spec(rp), full_spec(rp), full_spec(kp), full_spec(kp)]
        args = (pad(q_lo, qp), pad(q_hi, qp), pad(sides.astype(jnp.int32), qp),
                pad(spl_lo, sp), pad(spl_hi, sp), pad(reps_lo, rp),
                pad(reps_hi, rp), pad(keys_lo, kp), pad(keys_hi, kp))
    else:
        def kernel(ql, sd, sl, rl, kl, o):
            kern(ql, None, sd, sl, None, rl, None, kl, None, o)
        in_specs = [qspec, qspec, full_spec(sp), full_spec(rp), full_spec(kp)]
        args = (pad(q_lo, qp), pad(sides.astype(jnp.int32), qp),
                pad(spl_lo, sp), pad(reps_lo, rp), pad(keys_lo, kp))

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct((qp // LANES, LANES), jnp.int32),
        interpret=interpret,
    )(*args)
    return out.reshape(-1)[:n_q]
