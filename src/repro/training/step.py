"""Train / serve step factories: jit-able, shardable, microbatched.

``make_train_step`` builds the function the launcher jits with explicit
in/out shardings:

    (params, opt_state, batch) -> (params, opt_state, metrics)

Microbatch gradient accumulation runs as a ``lax.scan`` over microbatch
slices (f32 accumulators), keeping activation peaks at 1/num_microbatches
of the global batch — the knob §Perf uses against memory-bound cells.

``make_serve_step`` builds the decode step (one token against a cache of
``seq_len``) used by the decode_* / long_* dry-run cells.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.models.lm import NO_POLICY, ShardingPolicy

from . import optim


def make_train_step(cfg: ArchConfig, opt_cfg: optim.AdamWConfig,
                    num_microbatches: int = 1,
                    policy: ShardingPolicy = NO_POLICY,
                    grad_transform: Optional[Callable] = None) -> Callable:
    """grad_transform: optional pytree->pytree hook (e.g. int8 compression
    with error feedback) applied to the summed gradients before AdamW."""

    def loss_for(params, batch):
        loss, metrics = lm.loss_fn(cfg, params, batch, policy)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_for, has_aux=True)

    def train_step(params, opt_state, batch):
        if num_microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def slice_mb(x, i):
                mb = x.shape[0] // num_microbatches
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

            def mb_step(acc, i):
                mb_batch = jax.tree.map(lambda x: slice_mb(x, i), batch)
                (l, _), g = grad_fn(params, mb_batch)
                acc_g, acc_l = acc
                return (jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc_g, g),
                    acc_l + l), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                mb_step, (zero, jnp.zeros((), jnp.float32)),
                jnp.arange(num_microbatches))
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            loss = loss_sum / num_microbatches
            metrics = {"loss": loss}

        if grad_transform is not None:
            grads = grad_transform(grads)

        params, opt_state, opt_metrics = optim.apply_updates(
            opt_cfg, params, opt_state, grads)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, policy: ShardingPolicy = NO_POLICY
                      ) -> Callable:
    """Prefill: full forward returning last-position logits (sampling seed)."""

    def prefill_step(params, batch):
        hidden = lm.forward(cfg, params, batch, policy)
        last = hidden[:, -1:]
        logits = lm.logits_chunked(cfg, params, last)
        return logits.astype(jnp.float32)

    return prefill_step


def make_serve_step(cfg: ArchConfig, policy: ShardingPolicy = NO_POLICY
                    ) -> Callable:
    """Decode: (params, caches, token, pos) -> (logits, caches)."""

    def serve_step(params, caches, token, pos):
        return lm.decode_step(cfg, params, caches, token, pos, policy)

    return serve_step
