"""AdamW with fully-sharded states, gradient clipping, LR schedules.

Optimizer moments mirror the parameter sharding exactly (same
PartitionSpec tree), so ZeRO-style state sharding falls out of the rules
in parallel/sharding.py with no extra machinery.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray  # () int32
    m: Any             # pytree like params (f32)
    v: Any             # pytree like params (f32)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup then cosine decay to 10% of peak."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.1 + 0.45 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr_peak * jnp.where(s < cfg.warmup_steps, warm, cos)


def init_state(params) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, state: AdamWState, grads
                  ) -> Tuple[Any, AdamWState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                         state.m, grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                         state.v, grads)

    def upd(p, m, v):
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, AdamWState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm, "lr": lr}
