from . import compression, optim, step  # noqa: F401
