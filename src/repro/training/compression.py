"""Gradient compression for the cross-pod all-reduce.

The pod axis of the production mesh is the lowest-bandwidth link (DCN /
inter-pod ICI), and its only traffic is the once-per-step gradient
all-reduce.  Two cooperating pieces:

  * ``ef_quantize`` — int8 quantization with *error feedback*: the
    quantization residual is carried to the next step, so the compressed
    SGD provably tracks the uncompressed trajectory (Karimireddy et al.,
    2019).  Pure pytree->pytree numerics, usable as a grad_transform.

  * ``compressed_pod_mean`` — the bytes-on-the-wire path: a shard_map over
    the pod axis that all-gathers int8 payloads + f32 scales instead of
    f32 gradients (4x fewer bytes over the weak link), then dequantizes
    and averages locally.  Model/data axes stay in auto mode so XLA keeps
    managing intra-pod sharding.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import shard_map


def _quant_leaf(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    g32 = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_leaf(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_quantize(grads: Any, error: Any) -> Tuple[Any, Any]:
    """Error-feedback int8 round trip.  Returns (dequantized, new_error)."""

    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = _quant_leaf(corrected)
        deq = _dequant_leaf(q, s)
        return deq, corrected - deq

    pairs = jax.tree.map(leaf, grads, error)
    deq = jax.tree.map(lambda p: p[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda p: p[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_err


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_pod_mean(mesh: Mesh, grads: Any) -> Any:
    """Mean-reduce a gradient pytree over the 'pod' axis with int8 payloads.

    Call with per-pod gradients whose intra-pod (data/model) layout is
    replicated at this boundary (the train driver reduces intra-pod
    first).  Fully-manual shard_map: every axis is manual, the pytree is
    unsharded per device, and the only collective is the int8 all-gather
    over 'pod' — 4x fewer bytes over the weak inter-pod link than an f32
    all-reduce.  (This jax build rejects partial-manual specs that don't
    name every auto axis, so the partial-auto formulation is avoided.)
    """

    def body(g):
        def leaf(x):
            q, s = _quant_leaf(x)
            qg = jax.lax.all_gather(q, "pod")          # (npod, ...)
            sg = jax.lax.all_gather(s, "pod")
            deq = qg.astype(jnp.float32) * sg.reshape(
                (-1,) + (1,) * (qg.ndim - 1))
            return jnp.mean(deq, axis=0).astype(x.dtype)

        return jax.tree.map(leaf, g)

    fn = shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                       check_vma=False)
    return fn(grads)


def estimate_allreduce_bytes(params: Any, compressed: bool) -> int:
    """Napkin accounting used by EXPERIMENTS.md: bytes per pod-axis reduce."""
    n = sum(int(jnp.size(p)) for p in jax.tree.leaves(params))
    return n * (1 if compressed else 4)
