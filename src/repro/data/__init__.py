from . import keygen, tokens  # noqa: F401
