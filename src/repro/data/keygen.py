"""Paper workload generators (Sec. 5.1 setup).

Key sets: the first part is dense (all keys 0..d-1), the second is drawn
uniformly from the remaining range; ``uniformity`` is the percentage drawn
uniformly.  The set is shuffled and a key's final position is its rowID.
Lookup batches: uniform over the key set, Zipf-skewed (Sec. 6.4), and
hit-ratio mixes with in-range / out-of-range misses (Sec. 6.3).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.keys import KeyArray


def keyset(n: int, uniformity: float, bits: int = 32,
           seed: int = 0) -> Tuple[KeyArray, np.ndarray, np.ndarray]:
    """Returns (keys (shuffled), row_ids, raw_np_u64)."""
    rng = np.random.default_rng(seed)
    space = (1 << bits) - 1
    n_uniform = int(round(n * uniformity))
    n_dense = n - n_uniform
    dense = np.arange(n_dense, dtype=np.uint64)
    if n_uniform:
        # Draw without replacement from [n_dense, space); oversample+unique.
        need = n_uniform
        picked = []
        while need > 0:
            cand = rng.integers(n_dense, space, int(need * 1.3) + 16,
                                dtype=np.uint64)
            cand = np.unique(cand)
            picked.append(cand[:need])
            got = len(picked[-1])
            need -= got
        uni = np.concatenate(picked)[:n_uniform]
        raw = np.concatenate([dense, uni])
    else:
        raw = dense
    raw = np.unique(raw)
    rng.shuffle(raw)                    # position after shuffle = rowID
    keys = (KeyArray.from_u64(raw) if bits > 32
            else KeyArray.from_u32(raw.astype(np.uint32)))
    row_ids = np.arange(len(raw), dtype=np.int32)
    return keys, row_ids, raw


def uniform_lookups(raw: np.ndarray, q: int, seed: int = 1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return raw[rng.integers(0, len(raw), q)]


def zipf_lookups(raw: np.ndarray, q: int, theta: float,
                 seed: int = 1) -> np.ndarray:
    """Zipf over key-set ranks (theta = paper's coefficient; 0 = uniform)."""
    rng = np.random.default_rng(seed)
    if theta <= 0:
        return uniform_lookups(raw, q, seed)
    n = len(raw)
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** (-theta)
    w /= w.sum()
    idx = rng.choice(n, size=q, p=w)
    return raw[idx]


def hit_ratio_lookups(raw: np.ndarray, q: int, hit_ratio: float,
                      out_of_range: bool, bits: int,
                      seed: int = 1) -> np.ndarray:
    """Misses either inside the indexed value range or beyond it (Fig. 13)."""
    rng = np.random.default_rng(seed)
    n_hit = int(round(q * hit_ratio))
    hits = raw[rng.integers(0, len(raw), n_hit)]
    n_miss = q - n_hit
    if n_miss == 0:
        return hits
    lo, hi = int(raw.min()), int(raw.max())
    key_set = set(raw.tolist())
    misses = []
    while len(misses) < n_miss:
        if out_of_range:
            cand = rng.integers(hi + 1, (1 << bits) - 1, n_miss * 2,
                                dtype=np.uint64)
        else:
            cand = rng.integers(lo, hi, n_miss * 2, dtype=np.uint64)
        for c in cand:
            if int(c) not in key_set:
                misses.append(c)
                if len(misses) == n_miss:
                    break
    out = np.concatenate([hits, np.array(misses, dtype=np.uint64)])
    rng.shuffle(out)
    return out


# ---------------------------------------------------------------------------
# Adaptive-runtime scenario workloads (benchmarks/scenarios.py): hostile
# traffic shapes the serving controllers are tuned against.  All are
# deterministic under a fixed seed (pinned by tests/test_keygen_props.py).
# ---------------------------------------------------------------------------

def zipfian_keys(raw: np.ndarray, q: int, theta: float, seed: int = 1,
                 *, spatial: bool = True) -> np.ndarray:
    """Zipf-skewed point-lookup batch over the key set.

    ``spatial=True`` ranks keys by VALUE (rank 1 = smallest key), so the
    hot probability mass clusters in one region of key space — the shape
    that makes ONE shard of a splitter-routed store hot, which is what
    the migration controller must fix.  ``spatial=False`` ranks over the
    shuffled insertion order like ``zipf_lookups`` (hot keys scattered
    across key space: heavy reuse but NO spatial skew).  ``theta <= 0``
    degrades to uniform.
    """
    rng = np.random.default_rng(seed)
    n = len(raw)
    if theta <= 0:
        return raw[rng.integers(0, n, q)]
    order = np.sort(raw) if spatial else raw
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** (-theta)
    w /= w.sum()
    return order[rng.choice(n, size=q, p=w)]


def flash_crowd_ranges(raw: np.ndarray, q: int, *, width: int = 64,
                       crowd_frac: float = 0.9,
                       center: Optional[int] = None,
                       seed: int = 1) -> Tuple[np.ndarray, np.ndarray]:
    """Range-lookup batch where a ``crowd_frac`` fraction of queries all
    hit ONE narrow window of key space (the flash crowd) and the rest
    are uniform.  Returns (lo, hi) with every range spanning exactly
    ``width`` consecutive live keys; ``center`` fixes the crowd's start
    position in the sorted key order (random when None).
    """
    if not 0.0 <= crowd_frac <= 1.0:
        raise ValueError(f"crowd_frac must be in [0, 1], got {crowd_frac}")
    rng = np.random.default_rng(seed)
    srt = np.sort(raw)
    n = len(srt)
    width = min(width, n)
    max_start = max(n - width, 1)
    n_crowd = int(round(q * crowd_frac))
    if center is None:
        center = int(rng.integers(0, max_start))
    center = min(max(center, 0), max_start - 1)
    # Crowd starts jitter within the window itself: every crowd range
    # overlaps the same few buckets.
    crowd = center + rng.integers(0, max(width // 4, 1), n_crowd)
    uniform = rng.integers(0, max_start, q - n_crowd)
    starts = np.concatenate([crowd, uniform])
    rng.shuffle(starts)
    starts = np.minimum(starts, max_start - 1)
    lo = srt[starts]
    hi = srt[np.minimum(starts + width - 1, n - 1)]
    return lo, hi


def boundary_hot_keys(raw: np.ndarray, q: int, num_shards: int,
                      boundary: int, *, width: int = 128,
                      hot_frac: float = 0.95,
                      seed: int = 1) -> np.ndarray:
    """Point lookups concentrated on the keys straddling one SPLITTER of
    an equal-split ``num_shards``-way store: ``boundary`` b targets the
    cut between shard b-1 and shard b (1 <= b < num_shards).  A
    ``hot_frac`` fraction of lookups lands in the ``width``-key window
    centered on the cut; the rest are uniform.  The nastiest shape for a
    splitter-routed store — heat the size histogram cannot see, split
    across two adjacent shards.
    """
    if not 1 <= boundary < num_shards:
        raise ValueError(
            f"boundary must be in [1, num_shards), got {boundary} of "
            f"{num_shards}")
    rng = np.random.default_rng(seed)
    srt = np.sort(raw)
    n = len(srt)
    cut = boundary * n // num_shards
    lo_i = max(cut - width // 2, 0)
    hi_i = min(cut + width // 2, n)
    n_hot = int(round(q * hot_frac))
    hot = srt[rng.integers(lo_i, max(hi_i, lo_i + 1), n_hot)]
    cold = srt[rng.integers(0, n, q - n_hot)]
    out = np.concatenate([hot, cold])
    rng.shuffle(out)
    return out


def tenant_mix(raw: np.ndarray, q: int,
               tenants: Tuple[Tuple[float, float], ...] = ((0.7, 1.2),
                                                          (0.2, 0.5),
                                                          (0.1, 0.0)),
               seed: int = 1) -> Tuple[np.ndarray, np.ndarray]:
    """Multi-tenant point workload: the sorted key space is cut into
    ``len(tenants)`` contiguous equal slices (one per tenant), and each
    query draws a tenant by its ``weight`` then a key from that tenant's
    slice with the tenant's own Zipf ``theta`` (spatial, like
    ``zipfian_keys``).  Returns (keys, tenant_ids) — the mixed-traffic
    shape where aggregate stats look balanced while individual tenants
    are violently skewed.
    """
    if not tenants:
        raise ValueError("tenant_mix needs at least one (weight, theta)")
    rng = np.random.default_rng(seed)
    srt = np.sort(raw)
    n = len(srt)
    t = len(tenants)
    weights = np.array([w for w, _ in tenants], np.float64)
    if (weights <= 0).any():
        raise ValueError(f"tenant weights must be positive, got {weights}")
    weights /= weights.sum()
    tenant_ids = rng.choice(t, size=q, p=weights).astype(np.int32)
    out = np.empty(q, srt.dtype)
    for tid, (_, theta) in enumerate(tenants):
        sel = tenant_ids == tid
        m = int(sel.sum())
        if not m:
            continue
        lo = tid * n // t
        hi = (tid + 1) * n // t
        slice_ = srt[lo:hi]
        if theta <= 0:
            idx = rng.integers(0, len(slice_), m)
        else:
            ranks = np.arange(1, len(slice_) + 1, dtype=np.float64)
            w = ranks ** (-theta)
            w /= w.sum()
            idx = rng.choice(len(slice_), size=m, p=w)
        out[sel] = slice_[idx]
    return out, tenant_ids


def as_keys(raw: np.ndarray, bits: int) -> KeyArray:
    return (KeyArray.from_u64(raw) if bits > 32
            else KeyArray.from_u32(raw.astype(np.uint32)))


def embedding_set(n: int, dim: int, *, nclusters: int = 8,
                  spread: float = 0.15, seed: int = 0,
                  grid: Optional[int] = None) -> np.ndarray:
    """Seeded clustered-Gaussian embedding corpus for the vector tier.

    ``n`` vectors of ``dim`` float32 components drawn as a Gaussian
    mixture: ``nclusters`` centers uniform in [-1, 1]^dim, per-vector
    noise N(0, spread) — the cluster count/spread knobs control how
    separable the coarse quantizer's job is.  ``grid`` (power of two)
    snaps components to multiples of ``1/grid``: squared distances then
    become exact dyadic floats, so float32 distance comparisons are
    bit-identical across numpy and JAX — the setting the exhaustive-
    probe bit-identity suite runs on.
    """
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-1.0, 1.0, size=(nclusters, dim))
    owner = rng.integers(0, nclusters, size=n)
    vecs = centers[owner] + rng.normal(0.0, spread, size=(n, dim))
    if grid is not None:
        vecs = np.round(vecs * grid) / grid
    return vecs.astype(np.float32)


def embedding_queries(corpus: np.ndarray, q: int, *, spread: float = 0.05,
                      seed: int = 1,
                      grid: Optional[int] = None) -> np.ndarray:
    """Query vectors near uniformly-sampled corpus points (the ANN
    benchmark's workload); ``grid`` as in ``embedding_set``."""
    rng = np.random.default_rng(seed)
    base = corpus[rng.integers(0, len(corpus), q)]
    vecs = base + rng.normal(0.0, spread, size=base.shape)
    if grid is not None:
        vecs = np.round(vecs * grid) / grid
    return vecs.astype(np.float32)


def range_lookups(raw_sorted: np.ndarray, q: int, hits_per_range: int,
                  seed: int = 1) -> Tuple[np.ndarray, np.ndarray]:
    """Dense-range bounds with an expected number of hits (Fig. 12 setup:
    dense 0% -uniformity key range)."""
    rng = np.random.default_rng(seed)
    n = len(raw_sorted)
    starts = rng.integers(0, max(n - hits_per_range, 1), q)
    lo = raw_sorted[starts]
    hi = raw_sorted[np.minimum(starts + hits_per_range - 1, n - 1)]
    return lo, hi
