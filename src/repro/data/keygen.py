"""Paper workload generators (Sec. 5.1 setup).

Key sets: the first part is dense (all keys 0..d-1), the second is drawn
uniformly from the remaining range; ``uniformity`` is the percentage drawn
uniformly.  The set is shuffled and a key's final position is its rowID.
Lookup batches: uniform over the key set, Zipf-skewed (Sec. 6.4), and
hit-ratio mixes with in-range / out-of-range misses (Sec. 6.3).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.keys import KeyArray


def keyset(n: int, uniformity: float, bits: int = 32,
           seed: int = 0) -> Tuple[KeyArray, np.ndarray, np.ndarray]:
    """Returns (keys (shuffled), row_ids, raw_np_u64)."""
    rng = np.random.default_rng(seed)
    space = (1 << bits) - 1
    n_uniform = int(round(n * uniformity))
    n_dense = n - n_uniform
    dense = np.arange(n_dense, dtype=np.uint64)
    if n_uniform:
        # Draw without replacement from [n_dense, space); oversample+unique.
        need = n_uniform
        picked = []
        while need > 0:
            cand = rng.integers(n_dense, space, int(need * 1.3) + 16,
                                dtype=np.uint64)
            cand = np.unique(cand)
            picked.append(cand[:need])
            got = len(picked[-1])
            need -= got
        uni = np.concatenate(picked)[:n_uniform]
        raw = np.concatenate([dense, uni])
    else:
        raw = dense
    raw = np.unique(raw)
    rng.shuffle(raw)                    # position after shuffle = rowID
    keys = (KeyArray.from_u64(raw) if bits > 32
            else KeyArray.from_u32(raw.astype(np.uint32)))
    row_ids = np.arange(len(raw), dtype=np.int32)
    return keys, row_ids, raw


def uniform_lookups(raw: np.ndarray, q: int, seed: int = 1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return raw[rng.integers(0, len(raw), q)]


def zipf_lookups(raw: np.ndarray, q: int, theta: float,
                 seed: int = 1) -> np.ndarray:
    """Zipf over key-set ranks (theta = paper's coefficient; 0 = uniform)."""
    rng = np.random.default_rng(seed)
    if theta <= 0:
        return uniform_lookups(raw, q, seed)
    n = len(raw)
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** (-theta)
    w /= w.sum()
    idx = rng.choice(n, size=q, p=w)
    return raw[idx]


def hit_ratio_lookups(raw: np.ndarray, q: int, hit_ratio: float,
                      out_of_range: bool, bits: int,
                      seed: int = 1) -> np.ndarray:
    """Misses either inside the indexed value range or beyond it (Fig. 13)."""
    rng = np.random.default_rng(seed)
    n_hit = int(round(q * hit_ratio))
    hits = raw[rng.integers(0, len(raw), n_hit)]
    n_miss = q - n_hit
    if n_miss == 0:
        return hits
    lo, hi = int(raw.min()), int(raw.max())
    key_set = set(raw.tolist())
    misses = []
    while len(misses) < n_miss:
        if out_of_range:
            cand = rng.integers(hi + 1, (1 << bits) - 1, n_miss * 2,
                                dtype=np.uint64)
        else:
            cand = rng.integers(lo, hi, n_miss * 2, dtype=np.uint64)
        for c in cand:
            if int(c) not in key_set:
                misses.append(c)
                if len(misses) == n_miss:
                    break
    out = np.concatenate([hits, np.array(misses, dtype=np.uint64)])
    rng.shuffle(out)
    return out


def as_keys(raw: np.ndarray, bits: int) -> KeyArray:
    return (KeyArray.from_u64(raw) if bits > 32
            else KeyArray.from_u32(raw.astype(np.uint32)))


def embedding_set(n: int, dim: int, *, nclusters: int = 8,
                  spread: float = 0.15, seed: int = 0,
                  grid: Optional[int] = None) -> np.ndarray:
    """Seeded clustered-Gaussian embedding corpus for the vector tier.

    ``n`` vectors of ``dim`` float32 components drawn as a Gaussian
    mixture: ``nclusters`` centers uniform in [-1, 1]^dim, per-vector
    noise N(0, spread) — the cluster count/spread knobs control how
    separable the coarse quantizer's job is.  ``grid`` (power of two)
    snaps components to multiples of ``1/grid``: squared distances then
    become exact dyadic floats, so float32 distance comparisons are
    bit-identical across numpy and JAX — the setting the exhaustive-
    probe bit-identity suite runs on.
    """
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-1.0, 1.0, size=(nclusters, dim))
    owner = rng.integers(0, nclusters, size=n)
    vecs = centers[owner] + rng.normal(0.0, spread, size=(n, dim))
    if grid is not None:
        vecs = np.round(vecs * grid) / grid
    return vecs.astype(np.float32)


def embedding_queries(corpus: np.ndarray, q: int, *, spread: float = 0.05,
                      seed: int = 1,
                      grid: Optional[int] = None) -> np.ndarray:
    """Query vectors near uniformly-sampled corpus points (the ANN
    benchmark's workload); ``grid`` as in ``embedding_set``."""
    rng = np.random.default_rng(seed)
    base = corpus[rng.integers(0, len(corpus), q)]
    vecs = base + rng.normal(0.0, spread, size=base.shape)
    if grid is not None:
        vecs = np.round(vecs * grid) / grid
    return vecs.astype(np.float32)


def range_lookups(raw_sorted: np.ndarray, q: int, hits_per_range: int,
                  seed: int = 1) -> Tuple[np.ndarray, np.ndarray]:
    """Dense-range bounds with an expected number of hits (Fig. 12 setup:
    dense 0% -uniformity key range)."""
    rng = np.random.default_rng(seed)
    n = len(raw_sorted)
    starts = rng.integers(0, max(n - hits_per_range, 1), q)
    lo = raw_sorted[starts]
    hi = raw_sorted[np.minimum(starts + hits_per_range - 1, n - 1)]
    return lo, hi
