"""Synthetic LM token pipeline with sharded host feed.

Deterministic per-step batches (seeded by step) so a restarted run
consumes the identical data stream — required for checkpoint/restart
equivalence tests.  ``ShardedFeeder`` device_puts each host batch with
the mesh's batch sharding (the host->device analogue of a distributed
input pipeline; one process feeds all local shards here).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding


def synthetic_batch(step: int, batch: int, seq: int, vocab: int,
                    num_patches: int = 0, d_model: int = 0,
                    seed: int = 0) -> Dict[str, np.ndarray]:
    """Markov-ish synthetic tokens: learnable local structure, not noise —
    a model that trains shows a falling loss curve on it."""
    rng = np.random.default_rng(hash((seed, step)) % (2 ** 31))
    base = rng.integers(0, vocab, (batch, seq), dtype=np.int32)
    # Inject copy structure: token[t] = token[t-k] for random strides.
    k = int(rng.integers(1, 8))
    base[:, k:] = np.where(rng.random((batch, seq - k)) < 0.5,
                           base[:, :-k], base[:, k:])
    labels = np.roll(base, -1, axis=1)
    out = {"tokens": base, "labels": labels.astype(np.int32)}
    if num_patches:
        out["patch_embeds"] = rng.normal(
            size=(batch, num_patches, d_model)).astype(np.float32)
    return out


class ShardedFeeder:
    def __init__(self, mesh: Optional[Mesh], batch_specs):
        self.mesh = mesh
        self.specs = batch_specs

    def put(self, host_batch: Dict[str, np.ndarray]):
        if self.mesh is None:
            return jax.tree.map(jnp.asarray, host_batch)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
            host_batch, self.specs)
