"""Sharding rules: parameter pytrees + activations -> PartitionSpecs.

2-D sharding (FSDP x TP): every weight is sharded over the ``data`` axis on
one dim (ZeRO-3 style — XLA inserts just-in-time all-gathers which the
latency-hiding scheduler overlaps) *and* over the ``model`` axis on the
Megatron-parallel dim (heads / ffn hidden / experts / vocab).  The ``pod``
axis (multi-pod mesh) carries pure data parallelism: its only collective
is the once-per-step gradient all-reduce, matching its lower bisection
bandwidth.

Rules are *suffix patterns* on the parameter path; resolution checks
divisibility against the actual mesh and silently drops axes that do not
divide (e.g. MQA's single KV head can't split 16 ways — it replicates),
so every assigned architecture shards without per-arch hand-tuning.
Dropped axes are reported by ``explain()`` for the dry-run log.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = False):
    """Version-portable ``shard_map``.

    ``jax.shard_map`` (with ``check_vma``) only exists in newer jax; older
    releases ship it as ``jax.experimental.shard_map.shard_map`` with the
    equivalent flag spelled ``check_rep``.  All shard_map call sites in
    this repo route through here.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


# (path-suffix regex, spec template) — first match wins.  Templates name
# mesh axes per tensor dim; 'dp' expands to the data-parallel axis group
# ('pod','data') when a pod axis exists, else 'data'.
PARAM_RULES: List[Tuple[str, Tuple]] = [
    (r"embed/w$",           ("model", "data")),
    (r"lm_head/w$",         ("data", "model")),
    (r"patch_proj/w$",      (None, "model")),
    (r"patch_proj/b$",      ("model",)),
    # attention
    (r"attn/wq/w$",         ("data", "model")),
    (r"attn/wk/w$",         ("data", "model")),
    (r"attn/wv/w$",         ("data", "model")),
    (r"attn/wo/w$",         ("model", "data")),
    (r"attn/w[qkv]/b$",     ("model",)),
    (r"attn/wo/b$",         (None,)),
    # MLA
    (r"attn/wkv_down/w$",   ("data", None)),
    (r"attn/wkv_up/w$",     (None, "model")),
    (r"attn/kv_norm/.*$",   (None,)),
    # MoE (experts over model = EP; dense dims FSDP over data)
    (r"moe/router/w$",      ("data", None)),
    (r"moe/wi_gate$",       ("model", "data", None)),
    (r"moe/wi_up$",         ("model", "data", None)),
    (r"moe/wo$",            ("model", None, "data")),
    (r"moe/shared/wi_gate$", ("data", "model")),
    (r"moe/shared/wi_up$",  ("data", "model")),
    (r"moe/shared/wo$",     ("model", "data")),
    # dense MLP (init_mlp stores bare arrays, no /w wrapper)
    (r"mlp/wi(_gate|_up)?$", ("data", "model")),
    (r"mlp/wo$",            ("model", "data")),
    # Mamba2
    (r"mamba/in_proj/w$",   ("data", "model")),
    (r"mamba/out_proj/w$",  ("model", "data")),
    (r"mamba/conv_w$",      (None, "model")),
    (r"mamba/conv_b$",      ("model",)),
    (r"mamba/(A_log|D|dt_bias)$", (None,)),
    # norms & everything else: replicated
    (r".*",                 None),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(k.name)
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


@dataclasses.dataclass
class MeshAxes:
    data: str = "data"
    model: str = "model"
    pod: Optional[str] = None

    @property
    def dp(self) -> Tuple[str, ...]:
        return (self.pod, self.data) if self.pod else (self.data,)


def infer_axes(mesh: Mesh) -> MeshAxes:
    names = mesh.axis_names
    return MeshAxes(pod="pod" if "pod" in names else None)


def _fit_axis(axis, dim: int, mesh: Mesh):
    """Return axis (or axis tuple) if it divides dim, else None."""
    if axis is None:
        return None
    axes = axis if isinstance(axis, tuple) else (axis,)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return axis if dim % size == 0 else None


_DROPPED: List[str] = []


def spec_for_param(path_str: str, shape: Tuple[int, ...], mesh: Mesh,
                   axes: MeshAxes) -> P:
    template = None
    for pat, tpl in PARAM_RULES:
        if re.search(pat, path_str):
            template = tpl
            break
    if template is None:
        return P()
    # Stacked per-layer params ('blocks/...') carry a leading layer dim.
    ndim = len(shape)
    tpl = list(template)
    if len(tpl) < ndim:
        tpl = [None] * (ndim - len(tpl)) + tpl
    tpl = tpl[:ndim]
    out = []
    for d, ax in enumerate(tpl):
        fit = _fit_axis(ax, shape[d], mesh)
        if ax is not None and fit is None:
            _DROPPED.append(f"{path_str}[{d}] {shape[d]} !% {ax}")
        out.append(fit)
    return P(*out)


def param_specs(params_shape, mesh: Mesh) -> Any:
    """PartitionSpec tree for a params (or ShapeDtypeStruct) pytree."""
    axes = infer_axes(mesh)

    def leaf(path, x):
        return spec_for_param(_path_str(path), x.shape, mesh, axes)

    return jax.tree_util.tree_map_with_path(leaf, params_shape)


def shardings(params_shape, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params_shape, mesh))


def explain_drops(clear: bool = True) -> List[str]:
    out = list(_DROPPED)
    if clear:
        _DROPPED.clear()
    return out


# ---------------------------------------------------------------------------
# Activation policy.
# ---------------------------------------------------------------------------

def activation_policy(mesh: Mesh):
    """ShardingPolicy callable: batch over dp axes, sequence over model
    (Megatron sequence parallelism for the residual stream), with automatic
    axis dropping for non-dividing dims (e.g. batch=1 long-context)."""
    axes = infer_axes(mesh)
    dp = axes.dp if len(axes.dp) > 1 else axes.dp[0]

    def constrain(x, kind: str):
        if x.ndim < 2:
            return x
        dims = [None] * x.ndim
        dims[0] = _fit_axis(dp, x.shape[0], mesh)
        if kind == "residual" and x.ndim >= 3:
            dims[1] = _fit_axis(axes.model, x.shape[1], mesh)
        elif kind == "heads" and x.ndim >= 4:
            # (B, S, H, hd): keep attention head-parallel over the model
            # axis.  Without this the partitioner loses the projection's
            # output sharding at the reshape into the attention scan and
            # replicates score tiles across all model shards (observed in
            # the dry-run HLO — §Perf iteration 1).
            dims[2] = _fit_axis(axes.model, x.shape[2], mesh)
        elif kind == "latent" and x.ndim >= 3:
            # MLA compressed cache (B, S, lora): lora over model.
            dims[-1] = _fit_axis(axes.model, x.shape[-1], mesh)
        spec = P(*dims)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    from repro.models.lm import ShardingPolicy

    return ShardingPolicy(constrain)


def batch_specs(batch_shape, mesh: Mesh) -> Any:
    """Input batch: leading dim over the dp axes (dropped if indivisible)."""
    axes = infer_axes(mesh)
    dp = axes.dp if len(axes.dp) > 1 else axes.dp[0]

    def leaf(x):
        dims = [None] * len(x.shape)
        if len(x.shape) >= 1:
            dims[0] = _fit_axis(dp, x.shape[0], mesh)
        return P(*dims)

    return jax.tree.map(leaf, batch_shape)


def cache_specs(caches_shape, cfg, mesh: Mesh, strategy: str = "auto") -> Any:
    """Decode caches: layer dim unsharded, batch over dp, and
      strategy='auto'/'heads': heads (or latent) over model, falling back
                               to sequence when heads don't divide;
      strategy='seq':          sequence over model (flash-decode layout —
                               the §Perf knob that turns per-step head
                               all-gathers into small partial-sum
                               all-reduces)."""
    axes = infer_axes(mesh)
    dp = axes.dp if len(axes.dp) > 1 else axes.dp[0]

    def leaf(x):
        shape = x.shape
        dims = [None] * len(shape)
        if len(shape) >= 2:
            dims[1] = _fit_axis(dp, shape[1], mesh)
        if len(shape) == 5:          # (L, B, S, KV, hd) or ssm (L,B,h,p,n)
            if strategy == "seq":
                dims[2] = _fit_axis(axes.model, shape[2], mesh)
                if dims[2] is None:
                    dims[3] = _fit_axis(axes.model, shape[3], mesh)
            else:
                dims[3] = _fit_axis(axes.model, shape[3], mesh)
                if dims[3] is None:
                    dims[2] = _fit_axis(axes.model, shape[2], mesh)
        elif len(shape) == 4:        # (L, B, S, lora/rope) or conv
            if strategy == "seq":
                dims[2] = _fit_axis(axes.model, shape[2], mesh)
            else:
                dims[3] = _fit_axis(axes.model, shape[3], mesh)
        return P(*dims)

    return jax.tree.map(leaf, caches_shape)
