"""Roofline report from dry-run artifacts.

For each (arch x shape) cell on the single-pod mesh, compute the three
terms (seconds, per device = per chip):

    compute    = FLOPs_per_chip          / 197e12      (bf16 peak, v5e)
    memory     = HBM_bytes_per_chip      / 819e9
    collective = collective_bytes_per_chip / 50e9      (per-link ICI)

FLOPs / collective bytes come from the loop-trip-corrected HLO analysis
(launch/hlo_loops.py); HBM bytes are the corrected operand+result model
(an upper bound — producer results and consumer operands both counted).
The dominant term is the bottleneck; MFU upper bound = model-flops-time /
dominant-time, where MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D
(prefill/decode).  The ratio MODEL_FLOPS / corrected_HLO_FLOPs exposes
remat/redundancy waste (>1 impossible; ~1/3 with full remat on train).

Usage:
  python -m repro.launch.roofline [--dir experiments/dryrun/pod1] [--md out]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12      # bf16 / chip (TPU v5e)
HBM_BW = 819e9           # B/s per chip
LINK_BW = 50e9           # B/s per ICI link

CHIPS = {"pod1": 256, "pod2": 512}


def cell_terms(rec: Dict) -> Dict:
    lc = rec.get("loop_corrected", {}) or {}
    ca = rec.get("cost_analysis", {}) or {}
    flops = float(lc.get("corrected_flops") or ca.get("flops") or 0.0)
    hbm = float(lc.get("corrected_hbm_bytes")
                or ca.get("bytes accessed") or 0.0)
    coll = float(lc.get("corrected_collective_bytes")
                 or rec.get("collective_bytes") or 0.0)

    t_compute = flops / PEAK_FLOPS
    t_memory = hbm / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    chips = CHIPS.get(rec.get("mesh", "pod1"), 256)
    tokens = rec["global_batch"] * (rec["seq_len"] if rec["kind"] != "decode"
                                    else 1)
    mult = 6 if rec["kind"] == "train" else 2
    model_flops = mult * rec.get("params_active", 0) * tokens
    model_flops_per_chip = model_flops / chips
    t_model = model_flops_per_chip / PEAK_FLOPS
    t_bound = max(terms.values())
    return {
        "flops_per_chip": flops,
        "hbm_bytes_per_chip": hbm,
        "coll_bytes_per_chip": coll,
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_collective": t_coll,
        "dominant": dominant,
        "model_flops_total": model_flops,
        "useful_flops_ratio": (model_flops_per_chip / flops) if flops else 0.0,
        "mfu_upper_bound": (t_model / t_bound) if t_bound else 0.0,
        "step_time_bound_s": t_bound,
    }


_SUGGEST = {
    ("compute", "train"): "raise MFU: fewer rematerialized flops "
    "(policy-based remat), fuse bucket ops, larger per-chip tile",
    ("compute", "decode"): "decode is matvec-bound: quantize weights or "
    "batch more sequences per chip",
    ("compute", "prefill"): "attention flops dominate: larger q/kv blocks "
    "to raise MXU utilization",
    ("memory", "train"): "raise arithmetic intensity: bigger microbatch, "
    "bf16 optimizer pack, avoid f32 round-trips",
    ("memory", "decode"): "KV-cache streaming bound: page gather locality, "
    "quantized (int8) cache, MQA/MLA-style cache compression",
    ("memory", "prefill"): "stream KV blocks once: larger kv block, "
    "flash-style fusion keeps tiles in VMEM",
    ("collective", "train"): "overlap grad all-reduce with backward, "
    "reduce-scatter+all-gather (ZeRO) instead of all-reduce, int8 compress",
    ("collective", "decode"): "shard KV along sequence to turn head "
    "all-gathers into cheap partial-sum all-reduces",
    ("collective", "prefill"): "re-shard activations once per block, "
    "not per projection; prefer reduce-scatter epilogues",
}


def row(rec: Dict) -> Dict:
    t = cell_terms(rec)
    t["suggest"] = _SUGGEST.get((t["dominant"], rec["kind"]), "")
    return t


def markdown(records: List[Dict]) -> str:
    out = ["| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) "
           "| dominant | MODEL_FLOPS | useful/HLO | MFU bound |",
           "|---|---|---|---|---|---|---|---|---|"]
    for rec in records:
        if rec.get("status") != "OK":
            out.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                       f"{rec.get('status')} ({rec.get('reason', '')[:40]}) "
                       f"| — | — | — |")
            continue
        t = row(rec)
        out.append(
            f"| {rec['arch']} | {rec['shape']} "
            f"| {t['t_compute']:.3e} | {t['t_memory']:.3e} "
            f"| {t['t_collective']:.3e} | **{t['dominant']}** "
            f"| {t['model_flops_total']:.2e} "
            f"| {t['useful_flops_ratio']:.2f} "
            f"| {t['mfu_upper_bound']:.2f} |")
    return "\n".join(out)


def load_dir(d: str, include_variants: bool = False) -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            rec = json.load(f)
        if rec.get("tag") and not include_variants:
            continue  # §Perf variant runs live in their own table
        recs.append(rec)
    return recs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun/pod1")
    ap.add_argument("--md", default=None)
    args = ap.parse_args()
    recs = load_dir(args.dir)
    md = markdown(recs)
    print(md)
    if args.md:
        with open(args.md, "w") as f:
            f.write(md + "\n")
    # per-cell one-liners
    for rec in recs:
        if rec.get("status") != "OK":
            continue
        t = row(rec)
        print(f"{rec['arch']}/{rec['shape']}: dominant={t['dominant']}; "
              f"{t['suggest']}")


if __name__ == "__main__":
    main()
