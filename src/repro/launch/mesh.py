"""Production mesh construction.

A function, not a module-level constant: importing this module never
touches jax device state (device count is locked at first backend init,
so only dryrun.py — which sets XLA_FLAGS first — may build the 512-way
meshes).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model); the pod axis
    carries pure DP (one grad all-reduce per step over the weak link)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4, pod: int = 0):
    """Small mesh for CPU tests (requires >= data*model*max(pod,1) host
    devices via --xla_force_host_platform_device_count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
