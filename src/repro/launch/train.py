"""End-to-end training driver: real steps, checkpoints, fault tolerance.

CPU-runnable (tiny configs) and mesh-aware (pass a host mesh via
--data/--model when the process was started with
``--xla_force_host_platform_device_count``).  Features exercised:

  * jit-compiled sharded train step (same factory the dry-run lowers)
  * deterministic synthetic data stream (restart-reproducible)
  * async atomic checkpoints + resume from latest (elastic re-shard)
  * heartbeat file, straggler monitor, preemption-safe shutdown
  * optional int8 error-feedback gradient quantization

Example (quick CPU run):
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --tiny \
      --steps 30 --batch 8 --seq 128 --ckpt /tmp/ckpt
"""
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import tokens as data_tokens
from repro.models import lm
from repro.parallel import sharding
from repro.runtime import Heartbeat, PreemptionGuard, StragglerMonitor
from repro.training import compression, optim, step as step_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--tiny", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--data", type=int, default=0, help="data axis size")
    ap.add_argument("--model", type=int, default=0, help="model axis size")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--heartbeat", default="/tmp/repro_heartbeat.json")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = cfg.tiny()

    mesh = None
    policy = lm.NO_POLICY
    if args.data and args.model:
        mesh = jax.make_mesh((args.data, args.model), ("data", "model"))
        policy = sharding.activation_policy(mesh)

    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    opt_cfg = optim.AdamWConfig(lr_peak=args.lr, warmup_steps=5,
                                total_steps=args.steps)
    opt_state = optim.init_state(params)

    err = compression.init_error(params) if args.compress_grads else None

    def grad_transform(grads):
        nonlocal err
        if err is None:
            return grads
        deq, err = compression.ef_quantize(grads, err)
        return deq

    train_step = step_mod.make_train_step(
        cfg, opt_cfg, args.microbatches, policy,
        grad_transform if args.compress_grads else None)

    if mesh is not None:
        pspecs = sharding.param_specs(params, mesh)
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
        osh = optim.AdamWState(
            step=NamedSharding(mesh, P()),
            m=jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs),
            v=jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs))
        params = jax.tree.map(lambda x, s: jax.device_put(x, s), params, psh)
        opt_state = optim.AdamWState(
            step=opt_state.step,
            m=jax.tree.map(lambda x, s: jax.device_put(x, s), opt_state.m, psh),
            v=jax.tree.map(lambda x, s: jax.device_put(x, s), opt_state.v, psh))
        jitted = jax.jit(train_step, in_shardings=(psh, osh, None),
                         donate_argnums=(0, 1))
        bspecs = sharding.batch_specs(
            {"tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
             "labels": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32)},
            mesh)
        feeder = data_tokens.ShardedFeeder(mesh, bspecs)
    else:
        jitted = jax.jit(train_step, donate_argnums=(0, 1))
        feeder = data_tokens.ShardedFeeder(None, None)

    ckpt = CheckpointManager(args.ckpt, keep=2)
    start = 0
    latest = ckpt.latest_step()
    if latest is not None:
        (params, opt_state), meta = ckpt.restore(
            latest, (params, opt_state))
        start = int(meta.get("data_step", latest))
        print(f"resumed from step {start}")

    hb = Heartbeat(args.heartbeat).start()
    strag = StragglerMonitor(threshold=4.0)

    with PreemptionGuard() as guard:
        for step_i in range(start, args.steps):
            t0 = time.time()
            batch = feeder.put(data_tokens.synthetic_batch(
                step_i, args.batch, args.seq, cfg.vocab_size,
                cfg.num_patches, cfg.d_model))
            params, opt_state, metrics = jitted(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            strag.record(step_i, dt)
            hb.update(step_i)
            print(f"step {step_i:5d} loss {loss:.4f} "
                  f"({dt*1e3:.0f} ms, gnorm {float(metrics.get('grad_norm', 0)):.2f})",
                  flush=True)
            if (step_i + 1) % args.ckpt_every == 0 or guard.preempted():
                ckpt.save_async(step_i + 1, (params, opt_state),
                                {"data_step": step_i + 1, "loss": loss})
            if guard.preempted():
                print("preempted: checkpointed and exiting cleanly")
                break
    ckpt.wait()
    hb.stop()
    if strag.events:
        print(f"stragglers observed: {strag.events}")
    print("done")


if __name__ == "__main__":
    main()
