import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (dry-run only: 512 placeholder host devices so jax.make_mesh can build the
# production meshes; smoke tests and benches must NOT import this module.)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
SPMD-partitions and compiles, and extract the roofline inputs.

For each cell:
  train_4k                -> train_step (grad + AdamW, microbatched)
  prefill_32k             -> prefill_step (blockwise attention forward)
  decode_32k / long_500k  -> serve_step (one token vs seq_len cache)

All model/optimizer/batch/cache arguments are ShapeDtypeStructs (zero
allocation); in_shardings come from the rule engine (parallel/sharding).
Results (cost_analysis, memory_analysis, parsed collective bytes, op
census, analytic per-device byte accounting) land in one JSON per cell
under experiments/dryrun/<mesh>/ — resumable, and the roofline reader
(launch/roofline.py) consumes them.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh pod1
  python -m repro.launch.dryrun --all --mesh pod1
  python -m repro.launch.dryrun --all --mesh pod2   # 2x16x16 multi-pod
"""
import argparse
import dataclasses
import functools
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, SHAPES, SHAPES_BY_NAME, cell_applicable,
                           get_config, input_specs)
from repro.configs.base import ArchConfig, ShapeCell
from repro.launch import hlo_stats
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.parallel import sharding
from repro.training import optim, step as step_mod

OUT_ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "experiments", "dryrun")


# ---------------------------------------------------------------------------
# Parameter accounting (MODEL_FLOPS and analytic bytes).
# ---------------------------------------------------------------------------

def count_params(params_shape) -> Dict[str, int]:
    total = 0
    expert = 0
    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        total += n
        pstr = sharding._path_str(path)
        if re.search(r"moe/(wi_gate|wi_up|wo)$", pstr):
            expert += n
    return {"total": total, "expert": expert}


def active_params(cfg: ArchConfig, counts: Dict[str, int]) -> int:
    if cfg.moe is None or counts["expert"] == 0:
        return counts["total"]
    frac = cfg.moe.top_k / cfg.moe.num_experts
    return counts["total"] - counts["expert"] + int(counts["expert"] * frac)


def tree_bytes_per_device(tree_shape, specs, mesh) -> int:
    total = 0
    flat_s, _ = jax.tree_util.tree_flatten(tree_shape)
    flat_p, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(flat_s, flat_p):
        n = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        div = 1
        for ax in spec:
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            for a in axes:
                div *= mesh.shape[a]
        total += n // max(div, 1)
    return total


# ---------------------------------------------------------------------------
# Per-cell lowering.
# ---------------------------------------------------------------------------

def microbatches_for(cfg: ArchConfig, cell: ShapeCell) -> int:
    if cell.kind != "train":
        return 1
    big = cfg.d_model >= 5120 or (cfg.moe is not None) or cfg.num_layers >= 48
    return 8 if big else 4


def lower_cell(cfg: ArchConfig, cell: ShapeCell, mesh,
               num_microbatches: Optional[int] = None,
               donate: bool = True, kv_shard: str = "auto",
               cache_dtype: str = "bf16") -> Dict[str, Any]:
    rec: Dict[str, Any] = {}
    policy = sharding.activation_policy(mesh)
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(functools.partial(lm.init_params, cfg), key)
    pspecs = sharding.param_specs(params_shape, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    counts = count_params(params_shape)
    rec["params_total"] = counts["total"]
    rec["params_active"] = active_params(cfg, counts)
    rec["param_bytes_per_dev"] = tree_bytes_per_device(params_shape, pspecs, mesh)

    specs_in = input_specs(cfg, cell)

    if cell.kind == "train":
        mb = num_microbatches or microbatches_for(cfg, cell)
        rec["num_microbatches"] = mb
        opt_cfg = optim.AdamWConfig()
        opt_shape = jax.eval_shape(optim.init_state, params_shape)
        # opt specs: step replicated; moments mirror params
        ospec_tree = optim.AdamWState(
            step=P(), m=pspecs, v=jax.tree.map(lambda s: s, pspecs))
        osh = jax.tree.map(lambda s: NamedSharding(mesh, s), ospec_tree)
        bspecs = sharding.batch_specs(specs_in, mesh)
        bsh = jax.tree.map(lambda s: NamedSharding(mesh, s), bspecs)
        rec["opt_bytes_per_dev"] = tree_bytes_per_device(
            opt_shape, ospec_tree, mesh)
        rec["batch_bytes_per_dev"] = tree_bytes_per_device(
            specs_in, bspecs, mesh)

        fn = step_mod.make_train_step(cfg, opt_cfg, mb, policy)
        jitted = jax.jit(fn, in_shardings=(psh, osh, bsh),
                         donate_argnums=(0, 1) if donate else ())
        t0 = time.time()
        lowered = jitted.lower(params_shape, opt_shape, specs_in)
        rec["seconds_lower"] = time.time() - t0
    elif cell.kind == "prefill":
        bspecs = sharding.batch_specs(specs_in, mesh)
        bsh = jax.tree.map(lambda s: NamedSharding(mesh, s), bspecs)
        rec["batch_bytes_per_dev"] = tree_bytes_per_device(
            specs_in, bspecs, mesh)
        fn = step_mod.make_prefill_step(cfg, policy)
        jitted = jax.jit(fn, in_shardings=(psh, bsh))
        t0 = time.time()
        lowered = jitted.lower(params_shape, specs_in)
        rec["seconds_lower"] = time.time() - t0
    else:  # decode
        B = cell.global_batch
        cdt = jnp.int8 if cache_dtype == "int8" else jnp.bfloat16
        caches_shape = jax.eval_shape(
            functools.partial(lm.init_decode_caches, cfg, B, cell.seq_len,
                              dtype=cdt))
        cspecs = sharding.cache_specs(caches_shape, cfg, mesh,
                                      strategy=kv_shard)
        csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs)
        rec["cache_bytes_per_dev"] = tree_bytes_per_device(
            caches_shape, cspecs, mesh)
        tok_shape = specs_in["token"]
        tok_spec = sharding.batch_specs({"token": tok_shape}, mesh)["token"]
        tsh = NamedSharding(mesh, tok_spec)
        fn = step_mod.make_serve_step(cfg, policy)
        jitted = jax.jit(
            fn, in_shardings=(psh, csh, tsh, NamedSharding(mesh, P())),
            donate_argnums=(1,) if donate else ())
        t0 = time.time()
        lowered = jitted.lower(params_shape, caches_shape, tok_shape,
                               jax.ShapeDtypeStruct((), jnp.int32))
        rec["seconds_lower"] = time.time() - t0

    # Global (pre-partition) analysis: useful-FLOPs denominator for the
    # MODEL_FLOPS / HLO_FLOPs ratio.
    try:
        gca = lowered.cost_analysis()
        rec["global_cost_analysis"] = {
            k: float(v) for k, v in gca.items()
            if isinstance(v, (int, float)) and k in ("flops", "bytes accessed")}
    except Exception as e:                                    # noqa: BLE001
        rec["global_cost_analysis"] = {"error": str(e)}

    t0 = time.time()
    compiled = lowered.compile()
    rec["seconds_compile"] = time.time() - t0

    # --- extract roofline inputs (per-device: SPMD-partitioned module) ---
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float))}
    except Exception as e:                                    # noqa: BLE001
        rec["cost_analysis"] = {"error": str(e)}
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            rec["memory_analysis"] = None
        else:
            rec["memory_analysis"] = {
                k: int(getattr(ma, k)) for k in dir(ma)
                if k.endswith("size_in_bytes") and not k.startswith("_")}
    except Exception as e:                                    # noqa: BLE001
        rec["memory_analysis"] = {"error": str(e)}

    hlo = compiled.as_text()
    rec["collectives"] = hlo_stats.collective_stats(hlo)
    rec["collective_bytes"] = hlo_stats.total_collective_bytes(hlo)
    rec["op_census"] = hlo_stats.op_census(hlo)
    rec["hlo_chars"] = len(hlo)
    rec["sharding_drops"] = sharding.explain_drops()
    # Loop-trip-corrected analysis (cost_analysis counts while bodies once).
    from repro.launch import hlo_loops
    try:
        rec["loop_corrected"] = hlo_loops.analyze(hlo)
    except Exception as e:                                    # noqa: BLE001
        rec["loop_corrected"] = {"error": str(e)}
    return rec


def run_cell(arch: str, shape: str, mesh_name: str, out_dir: str,
             force: bool = False,
             num_microbatches: Optional[int] = None,
             remat_policy: Optional[str] = None,
             kv_shard: str = "auto",
             cache_dtype: str = "bf16",
             tag: str = "") -> Dict[str, Any]:
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    out_path = os.path.join(out_dir, f"{arch}__{shape}{suffix}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch)
    if remat_policy:
        cfg = dataclasses.replace(cfg, remat_policy=remat_policy)
    if os.environ.get("REPRO_PROBS_BF16"):
        cfg = dataclasses.replace(cfg, attn_probs_bf16=True)
    cell = SHAPES_BY_NAME[shape]
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "seq_len": cell.seq_len, "global_batch": cell.global_batch,
        "kind": cell.kind, "family": cfg.family, "tag": tag,
        "remat_policy": cfg.remat_policy, "kv_shard": kv_shard,
        "cache_dtype": cache_dtype,
    }
    ok, why = cell_applicable(cfg, cell)
    if not ok:
        rec["status"] = "SKIP"
        rec["reason"] = why
    else:
        mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
        try:
            rec.update(lower_cell(cfg, cell, mesh, num_microbatches,
                                  kv_shard=kv_shard,
                                  cache_dtype=cache_dtype))
            rec["status"] = "OK"
        except Exception as e:                                # noqa: BLE001
            rec["status"] = "ERROR"
            rec["reason"] = f"{type(e).__name__}: {e}"
            rec["traceback"] = traceback.format_exc()[-4000:]
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=[s.name for s in SHAPES])
    ap.add_argument("--mesh", choices=["pod1", "pod2"], default="pod1")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat-policy", choices=["full", "dots", "none"],
                    default=None)
    ap.add_argument("--kv-shard", choices=["auto", "heads", "seq"],
                    default="auto")
    ap.add_argument("--cache-dtype", choices=["bf16", "int8"],
                    default="bf16")
    ap.add_argument("--tag", default="",
                    help="variant tag for §Perf experiments (names the "
                         "output JSON <arch>__<shape>__<tag>.json)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    out_dir = args.out or os.path.abspath(
        os.path.join(OUT_ROOT, args.mesh))
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    for arch, shape in cells:
        t0 = time.time()
        rec = run_cell(arch, shape, args.mesh, out_dir, args.force,
                       args.microbatches, args.remat_policy, args.kv_shard,
                       args.cache_dtype, args.tag)
        status = rec.get("status")
        extra = ""
        if status == "OK":
            ca = rec.get("cost_analysis", {})
            extra = (f" flops/dev={ca.get('flops', 0):.3e}"
                     f" coll={rec.get('collective_bytes', 0):.3e}B"
                     f" lower={rec.get('seconds_lower', 0):.0f}s"
                     f" compile={rec.get('seconds_compile', 0):.0f}s")
        elif status == "ERROR":
            extra = " " + rec.get("reason", "")[:160]
        print(f"[{args.mesh}] {arch:24s} {shape:12s} {status}{extra}",
              flush=True)


if __name__ == "__main__":
    main()
