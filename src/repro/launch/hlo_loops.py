"""Loop-trip-aware HLO analysis.

XLA's ``cost_analysis()`` (and any flat text scan) counts a while-loop
body ONCE, but a scanned transformer executes the body num_layers x
num_microbatches times — the dominant factor in every train/serve program
here.  This module parses the post-SPMD HLO text into its computation
graph, extracts while-loop trip counts from the loop-condition constants,
and walks the call graph accumulating multipliers, producing:

    corrected_flops              (dot/convolution flops x trips)
    corrected_hbm_bytes          (operand+result bytes of top-level ops,
                                  fusions counted at their boundary)
    corrected_collective_bytes   ({op: bytes, count} x trips)

Conditionals take the MAX across branches (upper bound; flagged in the
output so hybrid-model numbers can be interpreted — Zamba2's shared-attn
branch actually runs every 6th layer).

This is the dry-run "profiler": on hardware you would read these numbers
from a trace; structurally they are exactly what the roofline needs.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_ONE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(s: str) -> int:
    total = 0
    for m in _SHAPE_ONE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(s: str) -> Optional[List[int]]:
    m = _SHAPE_ONE.search(s)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


class Computation:
    def __init__(self, name: str, header: str):
        self.name = name
        self.header = header
        self.lines: List[str] = []
        self.defs: Dict[str, str] = {}       # instr name -> result shape str
        self.whiles: List[Tuple[str, str]] = []   # (body, cond)
        self.calls: List[str] = []                # fusion/call/map/reduce...
        self.branches: List[List[str]] = []       # conditional branch lists
        self.dot_flops = 0
        self.hbm_bytes = 0
        self.coll: Dict[str, Dict[str, float]] = defaultdict(
            lambda: {"count": 0.0, "bytes": 0.0})
        self.s32_constants: List[int] = []


_COMP_HDR = re.compile(r"^(ENTRY\s+)?(%[\w\.\-]+)\s*\((.*)$")
_INSTR = re.compile(r"^\s+(ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*)$")
_CALLED = re.compile(r"(?:calls|to_apply|body|condition)=(%[\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_WHILE = re.compile(r"\bwhile\(.*condition=(%[\w\.\-]+), body=(%[\w\.\-]+)")
_WHILE2 = re.compile(r"\bwhile\(.*body=(%[\w\.\-]+), condition=(%[\w\.\-]+)")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_COLLECTIVE = re.compile(
    r"^(.*?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_DOT = re.compile(r"^(.*?)\s+dot\((%[\w\.\-]+)[,)]")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
# Operand lists come in two textual forms depending on the XLA version:
# bare names "(%a, %b)" or shape-annotated "(f32[8,8]{1,0} %a, ...)".
# Tokenize operands individually (bracket-aware) — a plain split on ','
# would shred multi-dimensional shapes.
_OPERANDS = re.compile(r"\(([^()]*%[\w\.\-]+[^()]*)\)")
_OPERAND_TOK = re.compile(
    r"(?:(\w+\[[\d,]*\])(?:\{[\d,]*\})?\s+)?(%[\w\.\-]+)")
_DOT_LHS = re.compile(
    r"dot\(\s*(?:(\w+\[[\d,]*\])(?:\{[\d,]*\})?\s+)?(%[\w\.\-]+)")

# HBM-boundary op families.  The CPU backend leaves elementwise chains
# unfused that a TPU compile would fuse into neighbors, so counting every
# instruction wildly overstates TPU HBM traffic; heavy ops (matmuls,
# fusions, gathers/scatters, sorts, collectives, big data movement) are
# the buffers that genuinely cross HBM on either backend.
_HBM_OPS = ("fusion(", "dot(", "custom-call(", "dynamic-slice(",
            "dynamic-update-slice(", "all-reduce", "all-gather",
            "reduce-scatter", "all-to-all", "collective-permute",
            "reduce(", "sort(", "gather(", "scatter(", "concatenate(",
            "convolution(")


def parse(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for line in hlo.splitlines():
        if not line.startswith(" ") and ("(" in line and "{" in line):
            m = _COMP_HDR.match(line.strip())
            if m:
                name = m.group(2)
                cur = Computation(name, line)
                comps[name] = cur
                if m.group(1):
                    entry = name
                # header params define shapes: "name: shape"
                for pm in re.finditer(r"([\w\.\-]+):\s*((?:\([^)]*\))|[\w\[\],]+)",
                                      m.group(3)):
                    cur.defs["%" + pm.group(1)] = pm.group(2)
                continue
        if line.startswith("}"):
            continue
        if cur is None:
            continue
        im = _INSTR.match(line)
        if not im:
            continue
        name, rest = im.group(2), im.group(3)
        # result shape = text before the op name token
        cur.lines.append(line)
        # split "shape opname(" — shape may be tuple
        shape_str = rest.split("(", 1)[0]
        # strip trailing op token
        shape_only = re.sub(r"\s+[\w\-]+$", "", shape_str)
        cur.defs[name] = shape_only

        wm = _WHILE.search(rest) or _WHILE2.search(rest)
        if "while(" in rest and wm:
            if _WHILE.search(rest):
                cond, body = wm.group(1), wm.group(2)
            else:
                body, cond = wm.group(1), wm.group(2)
            cur.whiles.append((body, cond))
        else:
            bm = _BRANCHES.search(rest)
            if bm:
                cur.branches.append(
                    [b.strip() for b in bm.group(1).split(",")])
            else:
                for cm in _CALLED.finditer(rest):
                    cur.calls.append(cm.group(1))

        for km in _CONST_S32.finditer(rest):
            cur.s32_constants.append(int(km.group(1)))

        cm = _COLLECTIVE.match(line.strip().split("=", 1)[-1].strip()) \
            if "=" in line else None
        if cm is None:
            cm = _COLLECTIVE.match(rest) if any(
                c in rest for c in ("all-reduce", "all-gather",
                                    "reduce-scatter", "all-to-all",
                                    "collective-permute")) else None
        if cm and "-done(" not in rest:
            op = cm.group(2)
            cur.coll[op]["count"] += 1
            cur.coll[op]["bytes"] += _shape_bytes(cm.group(1))

    comps["__entry__"] = comps.get(entry) if entry else None  # type: ignore
    return comps


def _dot_flops_of(comp: Computation) -> int:
    total = 0
    for line in comp.lines:
        if " dot(" not in line:
            continue
        im = _INSTR.match(line)
        if not im:
            continue
        rest = im.group(3)
        out_shape = _shape_dims(rest.split(" dot(", 1)[0])
        if out_shape is None:
            out_shape = []
        lhs_m = _DOT_LHS.search(rest)
        contract = 1
        if lhs_m:
            if lhs_m.group(1):      # shape annotated inline at the call
                lhs_shape = _shape_dims(lhs_m.group(1))
            else:                   # bare name: resolve via its definition
                lhs_shape = _shape_dims(comp.defs.get(lhs_m.group(2), "") or "")
            cd = _LHS_CONTRACT.search(rest)
            if lhs_shape and cd and cd.group(1):
                for d in cd.group(1).split(","):
                    if d and int(d) < len(lhs_shape):
                        contract *= lhs_shape[int(d)]
        n_out = 1
        for d in out_shape:
            n_out *= d
        total += 2 * n_out * contract
    return total


def _hbm_bytes_of(comp: Computation, fusion_callees: set) -> int:
    """Top-level traffic: result + operand bytes per instruction.  Callees
    of fusions are interior (VMEM/register) and skipped at their own level
    via ``fusion_callees``.

    In-place / partial-touch ops get special handling — they dominate scan
    programs and naive counting overstates them by the buffer/slice ratio:
      * dynamic-update-slice (op or fused root): touches only the update
        region -> 2 x update bytes (read-modify-write), never the aliased
        full buffer;
      * dynamic-slice: reads only the slice -> 2 x result bytes.
    """
    if comp.name in fusion_callees:
        return 0
    total = 0
    for line in comp.lines:
        im = _INSTR.match(line)
        if not im:
            continue
        rest = im.group(3)
        if not any(op in rest for op in _HBM_OPS):
            continue
        om = _OPERANDS.search(rest)
        operand_bytes = []
        if om:
            for tm in _OPERAND_TOK.finditer(om.group(1)):
                if tm.group(1):  # shape annotated inline at the call
                    operand_bytes.append(_shape_bytes(tm.group(1)))
                else:
                    operand_bytes.append(
                        _shape_bytes(comp.defs.get(tm.group(2), "")))
        result_bytes = _shape_bytes(rest.split("(", 1)[0])

        if "dynamic-update-slice" in rest or "dynamic_update_slice" in rest:
            # update region = everything but the (largest) aliased buffer
            if operand_bytes:
                upd = sum(operand_bytes) - max(operand_bytes)
                total += 2 * upd
            continue
        if "dynamic-slice" in rest or "dynamic_slice" in rest:
            total += 2 * result_bytes
            continue
        total += result_bytes + sum(operand_bytes)
    return total


def trip_count(cond: Computation) -> int:
    """Canonical scan conditions compare the induction var to a constant."""
    if cond.s32_constants:
        return max(1, max(cond.s32_constants))
    return 1


def analyze(hlo: str) -> Dict:
    comps = parse(hlo)
    entry = comps.pop("__entry__", None)
    if entry is None:
        return {"error": "no ENTRY computation found"}

    # fusion callees (interior computations) for the HBM model: any callee
    # reached via calls= / to_apply= (not while bodies).
    fusion_callees = set()
    for c in comps.values():
        for callee in c.calls:
            fusion_callees.add(callee)

    mult: Dict[str, float] = defaultdict(float)
    had_conditional = False
    stack = [(entry.name, 1.0)]
    guard = 0
    while stack:
        guard += 1
        if guard > 100000:
            break
        name, m = stack.pop()
        comp = comps.get(name)
        if comp is None:
            continue
        mult[name] += m
        for body, cond in comp.whiles:
            trips = trip_count(comps[cond]) if cond in comps else 1
            stack.append((body, m * trips))
            stack.append((cond, m * trips))
        for callee in comp.calls:
            stack.append((callee, m))
        for branches in comp.branches:
            had_conditional = True
            for b in branches:      # MAX-bound: weight each branch fully
                stack.append((b, m))

    flops = 0.0
    hbm = 0.0
    coll: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0.0, "bytes": 0.0})
    for name, m in mult.items():
        comp = comps.get(name)
        if comp is None:
            continue
        flops += m * _dot_flops_of(comp)
        hbm += m * _hbm_bytes_of(comp, fusion_callees)
        for op, st in comp.coll.items():
            coll[op]["count"] += m * st["count"]
            coll[op]["bytes"] += m * st["bytes"]

    return {
        "corrected_flops": flops,
        "corrected_hbm_bytes": hbm,
        "corrected_collectives": {k: dict(v) for k, v in coll.items()},
        "corrected_collective_bytes": sum(v["bytes"] for v in coll.values()),
        "had_conditional": had_conditional,
        "num_computations": len(comps),
        "loop_multiplier_max": max(mult.values()) if mult else 1.0,
    }
