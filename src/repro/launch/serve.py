"""Serving driver: continuous batching over the cgRX-paged KV cache.

Runs a tiny config on CPU, submits a wave of synthetic requests and
reports generation throughput plus the page-table index churn (inserts /
deletes routed through the updatable cgRX node store).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 8
"""
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serving.engine import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch).tiny()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=args.max_batch, max_seq=64,
                 page_size=8, num_pages=256)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for _ in range(args.requests):
        eng.submit(rng.integers(0, cfg.vocab_size, args.prompt_len),
                   max_new_tokens=args.max_new)
    results = eng.run_to_completion()
    dt = time.time() - t0

    s = eng.stats
    print(f"served {len(results)} requests in {dt:.1f}s "
          f"({s.tokens_out / max(dt, 1e-9):.1f} tok/s)")
    print(f"prefills={s.prefills} decode_steps={s.decode_steps} "
          f"tokens={s.tokens_out}")
    ts = eng.cache.table.stats()          # unified repro.db Stats surface
    print(f"cgRX page-table: inserts={ts.inserts} "
          f"deletes={ts.deletes} "
          f"chains<= {ts.max_chain} "
          f"nodes={ts.detail.allocated_nodes} "
          f"({ts.total_bytes / 1e3:.1f} KB)")
    for rid, toks in sorted(results.items()):
        print(f"  req {rid}: {len(toks)} tokens: {toks[:8]}...")


if __name__ == "__main__":
    main()
