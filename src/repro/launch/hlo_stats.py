"""HLO text analysis: collective bytes + op census for the roofline.

``cost_analysis()`` does not expose collective traffic, so we parse the
post-SPMD HLO: every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute instruction's *result* shape is summed
(an upper bound on bytes-on-the-wire per device; ring algorithms move
(n-1)/n of it — noted in EXPERIMENTS.md).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# result shape(s) then " <op-name>(" — ops may be wrapped in fusion names,
# so match on "= <shape> opname(" and "= (<shapes>) opname(".
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[\w\[\],{}\s/#*]+?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """{op: {count, bytes}} per collective type (result-shape bytes)."""
    out: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "bytes": 0})
    seen_done = set()
    for m in _OP_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        # async pairs appear as -start/-done; count each logical op once
        # (the -done result shape equals the transferred buffer).
        full = m.group(0)
        if "-done(" in full:
            continue
        b = shape_bytes(shape_str)
        out[op]["count"] += 1
        out[op]["bytes"] += b
    return dict(out)


def total_collective_bytes(hlo_text: str) -> int:
    return int(sum(v["bytes"] for v in collective_stats(hlo_text).values()))


def op_census(hlo_text: str, ops=("fusion", "all-reduce", "all-gather",
                                  "reduce-scatter", "all-to-all",
                                  "collective-permute", "custom-call",
                                  "while", "dot", "convolution")) -> Dict[str, int]:
    out = {}
    for op in ops:
        out[op] = len(re.findall(rf"\b{re.escape(op)}\(", hlo_text))
    return out
