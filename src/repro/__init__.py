"""repro: cgRX coarse-granular indexing as a first-class feature of a
multi-pod JAX LM training/serving framework."""
