"""cgRX: the paper's coarse-granular index, TPU-native.

Build (paper Alg. 1/3): sort the key set, partition into buckets of size B,
materialize only bucket representatives in the accelerated search structure.
Lookup (paper Alg. 2): find the smallest representative >= k (successor
search — the role of the ray/BVH machinery on the GPU), then post-filter
inside the bucket's key-rowID slice.

Point- and range-lookups both reduce to *rank queries* against the sorted
structure:

    rank_left(q)  = #keys <  q        rank_right(q) = #keys <= q

computed hierarchically as  (rep successor search) * B + (in-bucket count),
which maps 1:1 onto the paper's  (BVH traversal) + (bucket search)  split.
The rep search runs through one of three backends, registered in
``repro.query.backends`` (``index.method`` names the one to use):

    'tree'   — lane-width fanout tree (fanout.py), the BVH analogue;
    'binary' — plain binary search over reps (the B+/SA-style control);
    'kernel' — Pallas successor/bucket kernels (kernels/ops.py), the
               hardware path (interpret=True on CPU).

This module is the single-call path; batched multi-query serving (one
device call for a whole tick of mixed point/range lookups) lives in
``repro.query`` (QueryBatch planner + RankEngine + fused Pallas kernel).

Range lookup [l, u]  =  rank_left(l) .. rank_right(u)  on the flat sorted
key-rowID array — one successor search + a sequential scan, exactly the
paper's Sec. 3.2 procedure (and the reason cgRX beats RX by ~2x on ranges).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import fanout
from .bucketing import BucketedSet, build_buckets
from .deprecation import warn_once
from .keys import KeyArray, key_eq

MISS = jnp.int32(-1)


@dataclasses.dataclass
class CgrxIndex:
    buckets: BucketedSet
    tree: fanout.FanoutTree
    min_rep: KeyArray  # scalar-shaped (1,): keys[B-1] (paper Alg. 1 l.1)
    max_rep: KeyArray  # scalar-shaped (1,): keys[n-1]
    method: str = "tree"  # 'tree' | 'binary' | 'kernel'

    @property
    def bucket_size(self) -> int:
        return self.buckets.bucket_size

    @property
    def num_buckets(self) -> int:
        return self.buckets.num_buckets

    @property
    def n(self) -> int:
        return self.buckets.n


class LookupResult(NamedTuple):
    bucket_id: jnp.ndarray  # int32, bucket containing the successor
    row_id: jnp.ndarray     # int32, rowID of the key, or MISS (-1)
    found: jnp.ndarray      # bool
    position: jnp.ndarray   # int32 global rank_left position


def build(keys: KeyArray, row_ids: Optional[jnp.ndarray], bucket_size: int,
          *, fanout_width: int = 128, method: str = "tree",
          presorted: bool = False) -> CgrxIndex:
    """``presorted=True`` skips the construction sort (paper Alg. 1 l.1)
    when the caller already holds sorted keys — the compaction epoch swap
    (repro.store) rebuilds from ``nodes.extract`` output, which is sorted
    by construction."""
    buckets = build_buckets(keys, row_ids, bucket_size, presorted=presorted)
    tree = fanout.build_tree(buckets.reps, fanout=fanout_width)
    min_rep = buckets.reps[jnp.array([0])]
    max_rep = buckets.reps[jnp.array([buckets.num_buckets - 1])]
    return CgrxIndex(buckets=buckets, tree=tree, min_rep=min_rep,
                     max_rep=max_rep, method=method)


# ---------------------------------------------------------------------------
# Rep successor search (the "ray" / BVH-traversal stage).
#
# The actual implementations live in the Backend registry
# (repro.query.backends): 'tree' / 'binary' / 'kernel'.  ``index.method``
# names the registered backend; these wrappers keep the historical
# cgrx-level API (benchmarks time the stages through them).  The batched
# multi-query path is repro.query.engine.RankEngine.
# ---------------------------------------------------------------------------

def _backend(index: CgrxIndex):
    from repro.query.backends import get_backend

    return get_backend(index.method)


def _rep_search(index: CgrxIndex, queries: KeyArray, side: str) -> jnp.ndarray:
    return _backend(index).rep_search(index, queries, side)


def _bucket_count(index: CgrxIndex, bucket_id: jnp.ndarray, queries: KeyArray,
                  side: str) -> jnp.ndarray:
    """#keys (<) / (<=) q inside bucket ``bucket_id`` (post-filter stage)."""
    return _backend(index).bucket_count(index, bucket_id, queries, side)


def rank(index: CgrxIndex, queries: KeyArray, side: str = "left") -> jnp.ndarray:
    """Global rank of each query in the sorted key set (0..n)."""
    return _backend(index).rank(index, queries, side)


# ---------------------------------------------------------------------------
# Point lookup (paper Alg. 2 + post-filter, Sec. 3.1/3.4).
# ---------------------------------------------------------------------------

def lookup_from_rank(index: CgrxIndex, pos: jnp.ndarray,
                     queries: KeyArray) -> LookupResult:
    """rank_left positions -> LookupResult (hit check + rowID gather).

    Shared post-processing of ``lookup`` and the batched engine
    (repro.query.engine) — one definition so the engine's bit-identity
    guarantee can't drift.
    """
    in_range = pos < index.n
    safe_pos = jnp.minimum(pos, index.n - 1)
    hit_keys = index.buckets.keys.take(safe_pos)
    found = in_range & key_eq(hit_keys, queries)
    row = jnp.where(found, index.buckets.row_ids[safe_pos], MISS)
    bucket_id = jnp.minimum(pos // index.bucket_size, index.num_buckets - 1)
    return LookupResult(bucket_id=bucket_id.astype(jnp.int32),
                        row_id=row.astype(jnp.int32),
                        found=found, position=pos.astype(jnp.int32))


def empty_lookup_result() -> LookupResult:
    """A zero-query ``LookupResult`` — the shared shape for empty plans
    and empty submissions (repro.query engine, repro.db sessions)."""
    z = jnp.zeros((0,), jnp.int32)
    return LookupResult(bucket_id=z, row_id=z,
                        found=jnp.zeros((0,), bool), position=z)


def lookup(index: CgrxIndex, queries: KeyArray) -> LookupResult:
    """Single-call point lookup.  Prefer ``repro.db`` sessions (or the
    batched ``repro.query.RankEngine``) for serving traffic."""
    warn_once("cgrx.lookup",
              "core.cgrx.lookup is a deprecated convenience path; open a "
              "repro.db session (repro.db.open) for unified batched "
              "point/range/update traffic")
    pos = rank(index, queries, side="left")
    return lookup_from_rank(index, pos, queries)


# ---------------------------------------------------------------------------
# Range lookup (paper Sec. 3.2: one successor search + sequential scan).
# ---------------------------------------------------------------------------

class RangeResult(NamedTuple):
    start: jnp.ndarray   # int32 (Q,) first qualifying global position
    count: jnp.ndarray   # int32 (Q,) number of qualifying keys
    row_ids: jnp.ndarray  # int32 (Q, max_hits) qualifying rowIDs, -1 padded


def range_from_ranks(index: CgrxIndex, start: jnp.ndarray, end: jnp.ndarray,
                     max_hits: int) -> RangeResult:
    """(rank_left(lo), rank_right(hi)) -> RangeResult (rowID scan).

    Shared post-processing of ``range_lookup`` and the batched engine
    (repro.query.engine).
    """
    count = jnp.maximum(end - start, 0)
    offs = start[..., None] + jnp.arange(max_hits, dtype=jnp.int32)
    valid = jnp.arange(max_hits, dtype=jnp.int32) < count[..., None]
    rows = jnp.take(index.buckets.row_ids, jnp.minimum(offs, index.n - 1),
                    mode="clip")
    rows = jnp.where(valid, rows, MISS)
    return RangeResult(start=start.astype(jnp.int32),
                       count=count.astype(jnp.int32), row_ids=rows)


def empty_range_result(max_hits: int) -> RangeResult:
    """A zero-query ``RangeResult`` with ``max_hits`` row capacity."""
    z = jnp.zeros((0,), jnp.int32)
    return RangeResult(start=z, count=z,
                       row_ids=jnp.zeros((0, max_hits), jnp.int32))


# ---------------------------------------------------------------------------
# Range aggregates (rank-only: COUNT needs no row materialization at all,
# MIN/MAX gather one key per endpoint instead of max_hits rowIDs).
# ---------------------------------------------------------------------------

class AggResult(NamedTuple):
    """Per-range aggregates over [lo, hi] (fields shaped (A,)).

    ``count = rank_right(hi) - rank_left(lo)`` — the quantity the range
    path always computes and normally discards after gathering rowIDs.
    ``min_key``/``max_key`` are the smallest/largest live keys inside the
    range (valid only where ``count > 0``); they are ``None`` unless the
    plan requested them (``QueryPlan.agg_keys``), so pure-COUNT pipelines
    stay a subtraction of ranks.
    """

    count: jnp.ndarray            # int32 (A,)
    min_key: Optional[KeyArray]   # (A,) or None
    max_key: Optional[KeyArray]   # (A,) or None


def agg_from_ranks(index: CgrxIndex, start: jnp.ndarray, end: jnp.ndarray,
                   with_keys: bool = False) -> AggResult:
    """(rank_left(lo), rank_right(hi)) -> AggResult.

    Shared post-processing of the batched engine's aggregate section
    (repro.query.engine); the node-store analogue lives on
    ``repro.store.live.NodeIndexView.agg_from_ranks``.
    """
    count = jnp.maximum(end - start, 0).astype(jnp.int32)
    if not with_keys:
        return AggResult(count=count, min_key=None, max_key=None)
    last = jnp.maximum(index.n - 1, 0)
    min_key = index.buckets.keys.take(jnp.minimum(start, last))
    max_key = index.buckets.keys.take(jnp.clip(end - 1, 0, last))
    return AggResult(count=count, min_key=min_key, max_key=max_key)


def empty_agg_result() -> AggResult:
    """A zero-range ``AggResult`` (count only — no key planes)."""
    return AggResult(count=jnp.zeros((0,), jnp.int32),
                     min_key=None, max_key=None)


def range_lookup(index: CgrxIndex, lo: KeyArray, hi: KeyArray,
                 max_hits: int) -> RangeResult:
    """Single-call range lookup.  Prefer ``repro.db`` sessions (or the
    batched ``repro.query.RankEngine``) for serving traffic."""
    warn_once("cgrx.range_lookup",
              "core.cgrx.range_lookup is a deprecated convenience path; "
              "open a repro.db session (repro.db.open) for unified "
              "batched point/range/update traffic")
    start = rank(index, lo, side="left")
    end = rank(index, hi, side="right")
    return range_from_ranks(index, start, end, max_hits)


# ---------------------------------------------------------------------------
# Footprint accounting (consumed by core/footprint.py and benchmarks).
# ---------------------------------------------------------------------------

def index_nbytes(index: CgrxIndex) -> dict:
    """Actual JAX buffer footprint, split the way the paper reports it."""
    b = index.buckets
    out = {
        "key_rowid_bytes": b.keys.nbytes + b.row_ids.nbytes,
        "rep_bytes": b.reps.nbytes,
        "tree_bytes": index.tree.nbytes,
    }
    out["total_bytes"] = sum(out.values())
    return out
