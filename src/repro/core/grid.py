"""Paper-faithful 3D-grid scene emulation (Sec. 3.1-3.3).

cgRX-on-GPU places representative *triangles* on an integer grid
(key -> (x,y,z) by bit slicing) and locates the successor representative by
firing up to five rays (Algorithm 2): x-ray in the query's row, y-ray to a
row marker, x-ray, z-ray to a plane marker, y-ray, x-ray.  The *optimized*
representation (Algorithm 3) removes explicit markers by moving
representatives to row ends, inserting auxiliary representatives, and
encoding "only rep in its row" in the triangle winding order (flipping =
back-side hit lets the follow-up x-ray be skipped).

On TPU each "ray" becomes a *vector probe*: a successor search over a
sorted coordinate directory (one masked VPU compare-count per tree level;
kernels/grid_probe.py provides the Pallas probe).  The probe sequence,
marker placement, duplicate handling, triangle budget and the
primitive-index remap formula follow the paper exactly so that ray counts
and memory accounting are comparable with Figures 8 and 10.

Device-side coordinates are int32 (x<=23 bits, y<=23, z<=18 — the paper's
own float-precision limits guarantee they fit), so no 64-bit device
arithmetic is needed: triangle positions are (z, y, x) triples compared
lexicographically.

Scene construction runs host-side in numpy (the paper builds with a CUDA
kernel; our device-side build cost is dominated by the sort in
bucketing.py and is benchmarked there).  Lookups are pure jnp and jit-able.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .bucketing import BucketedSet, build_buckets
from .keymap import KeyMapping, default_mapping
from .keys import KeyArray

MISS = -1


# ---------------------------------------------------------------------------
# Host-side coordinate extraction.
# ---------------------------------------------------------------------------

def _coords_np(kmap: KeyMapping, k: np.ndarray):
    k = k.astype(np.uint64)
    x = (k & np.uint64(kmap.x_max)).astype(np.int32)
    y = ((k >> np.uint64(kmap.x_bits)) & np.uint64(kmap.y_max)).astype(np.int32)
    z = ((k >> np.uint64(kmap.x_bits + kmap.y_bits))
         & np.uint64(max(kmap.z_max, 0))).astype(np.int32)
    return x, y, z


def coords_device(kmap: KeyMapping, queries: KeyArray):
    """(x, y, z) int32 coordinates of query keys, on device."""
    lo = queries.lo
    hi = queries.hi if queries.is64 else jnp.zeros_like(lo)
    x = (lo & jnp.uint32(kmap.x_max)).astype(jnp.int32)
    lo_part_bits = 32 - kmap.x_bits
    y = (((lo >> jnp.uint32(kmap.x_bits))
          | (hi << jnp.uint32(lo_part_bits))) & jnp.uint32(kmap.y_max)).astype(jnp.int32)
    zshift = max(kmap.x_bits + kmap.y_bits - 32, 0)
    z = ((hi >> jnp.uint32(zshift)) & jnp.uint32(max(kmap.z_max, 0))).astype(jnp.int32)
    return x, y, z


# ---------------------------------------------------------------------------
# Lexicographic successor search over int32 coordinate tuples.
# ---------------------------------------------------------------------------

def searchsorted_lex(arrs: Sequence[jnp.ndarray], qs: Sequence[jnp.ndarray],
                     side: str = "left") -> jnp.ndarray:
    """Vectorized binary search over parallel sorted int32 arrays compared
    lexicographically.  This is the pure-jnp probe oracle; one call = one
    "ray" in the emulation."""
    n = arrs[0].shape[0]
    if n == 0:
        return jnp.zeros(qs[0].shape, jnp.int32)
    n_iter = max(1, int(np.ceil(np.log2(n + 1))))

    def lex_le(mids):  # q <= mid  (side=left: go left when q <= mid)
        out = jnp.zeros(qs[0].shape, bool)
        tie = jnp.ones(qs[0].shape, bool)
        for m, q in zip(mids, qs):
            out = out | (tie & (q < m))
            tie = tie & (q == m)
        return (out | tie) if side == "left" else out  # left: q<=m, right: q<m

    def body(_, lohi):
        lo, hi = lohi
        done = lo >= hi
        mid = (lo + hi) // 2
        mids = [jnp.take(a, mid, mode="clip") for a in arrs]
        go_left = lex_le(mids)
        lo2 = jnp.where(done, lo, jnp.where(go_left, lo, mid + 1))
        hi2 = jnp.where(done, hi, jnp.where(go_left, mid, hi))
        return lo2, hi2

    lo = jnp.zeros(qs[0].shape, jnp.int32)
    hi = jnp.full(qs[0].shape, n, jnp.int32)
    lo, hi = jax.lax.fori_loop(0, n_iter, body, (lo, hi))
    return lo


# ---------------------------------------------------------------------------
# Scene container.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GridScene:
    representation: str            # 'naive' | 'optimized'
    kmap: KeyMapping
    num_buckets: int
    is64: bool
    # Triangles sorted lexicographically by (z, y, x).
    tri_z: jnp.ndarray
    tri_y: jnp.ndarray
    tri_x: jnp.ndarray
    tri_prim: jnp.ndarray          # int32 primitive index (slot in vertex buffer)
    tri_flip: jnp.ndarray          # bool (optimized only)
    # y-ray target set: naive = explicit row markers (populated-row
    # directory); optimized = row-END triangles (x == x_max).
    rowdir_z: jnp.ndarray
    rowdir_y: jnp.ndarray
    rowdir_flip: jnp.ndarray       # flip bit of the row-end triangle
    rowdir_prim: jnp.ndarray       # prim of the row-end triangle (optimized)
    # z-ray target set: populated planes (naive) / plane-end triangles (opt).
    plane_z: jnp.ndarray
    # Bounds (Alg. 2 l.1-2), as (1,)-shaped KeyArrays.
    min_rep: KeyArray
    max_rep: KeyArray
    multi_line: bool
    multi_plane: bool
    triangles_materialized: int
    slots_allocated: int

    def nbytes_model(self, bvh_bytes_per_tri: float = 64.0) -> dict:
        """Paper memory model: 36 B per triangle slot (9 f32) in the vertex
        buffer + per-materialized-triangle BVH overhead."""
        return {
            "vertex_buffer_bytes": 36 * self.slots_allocated,
            "bvh_bytes": int(bvh_bytes_per_tri * self.triangles_materialized),
        }


class GridLookupResult(NamedTuple):
    bucket_id: jnp.ndarray  # int32 bucketID or MISS(-1)
    rays: jnp.ndarray       # int32 rays fired (paper Fig. 8 metric)


def remap_prim(prim: jnp.ndarray, num_buckets: int) -> jnp.ndarray:
    """Paper Sec. 3.3 primitive-index -> bucketID remap."""
    nb = num_buckets
    return jnp.where(prim >= 2 * nb, prim - 2 * nb + 1,
                     jnp.where(prim >= nb, prim - nb + 1, prim)).astype(jnp.int32)


def _sorted_tris(z, y, x, prim, flip):
    order = np.lexsort((x, y, z))
    return z[order], y[order], x[order], prim[order], flip[order]


def _pad1(a: np.ndarray, fill) -> np.ndarray:
    """Ensure arrays are never zero-length (keeps gathers well-defined)."""
    if len(a) == 0:
        return np.array([fill], dtype=a.dtype if a.dtype != bool else bool)
    return a


# ---------------------------------------------------------------------------
# Construction: naive representation (Algorithm 1).
# ---------------------------------------------------------------------------

def build_naive(buckets: BucketedSet, kmap: Optional[KeyMapping] = None) -> GridScene:
    reps = buckets.reps.to_numpy().astype(np.uint64)
    nb = len(reps)
    if kmap is None:
        kmap = default_mapping(buckets.reps.is64)
    x, y, z = _coords_np(kmap, reps)
    rowkey = (z.astype(np.int64) << kmap.y_bits) | y

    is_dup = np.concatenate([[False], reps[1:] == reps[:-1]])
    mat = ~is_dup                                              # Alg.1 l.11
    prev_rowkey = np.concatenate([[-1], rowkey[:-1]])
    prev_plane = np.concatenate([[-1], z[:-1]])
    multi_line = bool(rowkey[0] != rowkey[-1])                 # Alg.1 l.2
    multi_plane = bool(z[0] != z[-1])                          # Alg.1 l.3

    first_in_row = mat & (rowkey != prev_rowkey)               # Alg.1 l.13
    first_in_plane = mat & (z != prev_plane)                   # Alg.1 l.15

    sel = np.nonzero(mat)[0]
    tz, ty, tx, tp, tf = _sorted_tris(
        z[sel], y[sel], x[sel], sel.astype(np.int32), np.zeros(len(sel), bool))

    if multi_line:
        rsel = np.nonzero(first_in_row)[0]
    else:
        rsel = sel[:1]
    rorder = np.lexsort((y[rsel], z[rsel]))
    rdz, rdy = z[rsel][rorder], y[rsel][rorder]

    if multi_plane:
        psel = np.nonzero(first_in_plane)[0]
        pz = np.sort(z[psel])
    else:
        pz = z[sel[:1]]

    n_mark = (len(rsel) if multi_line else 0) + (len(pz) if multi_plane else 0)
    scene = GridScene(
        representation="naive", kmap=kmap, num_buckets=nb,
        is64=buckets.reps.is64,
        tri_z=jnp.asarray(tz), tri_y=jnp.asarray(ty), tri_x=jnp.asarray(tx),
        tri_prim=jnp.asarray(tp), tri_flip=jnp.asarray(tf),
        rowdir_z=jnp.asarray(_pad1(rdz, 1 << 30)),
        rowdir_y=jnp.asarray(_pad1(rdy, 1 << 30)),
        rowdir_flip=jnp.asarray(_pad1(np.zeros(len(rdz), bool), False)),
        rowdir_prim=jnp.asarray(_pad1(np.full(len(rdz), -1, np.int32), -1)),
        plane_z=jnp.asarray(_pad1(pz, 1 << 30)),
        min_rep=buckets.reps[jnp.array([0])],
        max_rep=buckets.reps[jnp.array([nb - 1])],
        multi_line=multi_line, multi_plane=multi_plane,
        triangles_materialized=int(mat.sum()) + n_mark,
        slots_allocated=nb + (int(multi_line) + int(multi_plane)) * nb,  # l.5-6
    )
    return scene


# ---------------------------------------------------------------------------
# Construction: optimized representation (Algorithm 3).
# ---------------------------------------------------------------------------

def build_optimized(buckets: BucketedSet, keys_sorted: np.ndarray,
                    kmap: Optional[KeyMapping] = None) -> GridScene:
    reps = buckets.reps.to_numpy().astype(np.uint64)
    nb = len(reps)
    n = buckets.n
    if kmap is None:
        kmap = default_mapping(buckets.reps.is64)
    B = buckets.bucket_size
    x, y, z = _coords_np(kmap, reps)
    rowkey = (z.astype(np.int64) << kmap.y_bits) | y
    x_max, y_max = kmap.x_max, kmap.y_max

    rep_idx = np.minimum((np.arange(nb) + 1) * B, n) - 1
    has_next = rep_idx + 1 < n
    next_key = keys_sorted[np.minimum(rep_idx + 1, n - 1)].astype(np.uint64)
    nx, ny, nz = _coords_np(kmap, next_key)
    nk_row = np.where(has_next, (nz.astype(np.int64) << kmap.y_bits) | ny, -1)

    prev_row = np.concatenate([[-1], rowkey[:-1]])
    next_rep_row = np.concatenate([rowkey[1:], [-1]])
    next_rep_z = np.concatenate([z[1:], [-1]]).astype(np.int64)
    is_dup = np.concatenate([[False], reps[1:] == reps[:-1]])

    multi_line = bool(rowkey[0] != rowkey[-1])
    multi_plane = bool(z[0] != z[-1])

    movable = nk_row != rowkey                                   # l.10
    needs_rep = (~is_dup) | (movable & (x != x_max))             # l.13
    needs_row_mark = (~movable) & (rowkey != next_rep_row)       # l.14
    needs_plane_mark = (y != y_max) & (z.astype(np.int64) != next_rep_z)  # l.15
    do_flip = movable & (prev_row != rowkey)                     # l.18

    parts = []
    sel = np.nonzero(needs_rep)[0]
    rx = np.where(movable[sel], x_max, x[sel]).astype(np.int32)
    parts.append((z[sel], y[sel], rx, sel.astype(np.int32), do_flip[sel]))
    if multi_line:                                               # l.20-21
        m = np.nonzero(needs_row_mark)[0]
        parts.append((z[m], y[m], np.full(len(m), x_max, np.int32),
                      (m + nb).astype(np.int32), np.zeros(len(m), bool)))
    if multi_plane:                                              # l.22-23
        m = np.nonzero(needs_plane_mark)[0]
        parts.append((z[m], np.full(len(m), y_max, np.int32),
                      np.full(len(m), x_max, np.int32),
                      (m + 2 * nb).astype(np.int32), np.zeros(len(m), bool)))

    tz = np.concatenate([p[0] for p in parts])
    ty = np.concatenate([p[1] for p in parts])
    tx = np.concatenate([p[2] for p in parts])
    tp = np.concatenate([p[3] for p in parts])
    tf = np.concatenate([p[4] for p in parts])
    tz, ty, tx, tp, tf = _sorted_tris(tz, ty, tx, tp, tf)

    # y-ray target set: row-END triangles (x == x_max), deduped per row
    # keeping the lowest prim (deterministic closest-hit).
    is_end = tx == x_max
    eidx = np.nonzero(is_end)[0]
    erk = (tz[eidx].astype(np.int64) << kmap.y_bits) | ty[eidx]
    keep = np.concatenate([[True], erk[1:] != erk[:-1]]) if len(erk) else np.zeros(0, bool)
    eidx = eidx[keep]

    # z-ray target set: plane-end triangles (x_max, y_max).
    pidx = eidx[ty[eidx] == y_max]
    pz = tz[pidx]

    scene = GridScene(
        representation="optimized", kmap=kmap, num_buckets=nb,
        is64=buckets.reps.is64,
        tri_z=jnp.asarray(tz), tri_y=jnp.asarray(ty), tri_x=jnp.asarray(tx),
        tri_prim=jnp.asarray(tp), tri_flip=jnp.asarray(tf),
        rowdir_z=jnp.asarray(_pad1(tz[eidx], 1 << 30)),
        rowdir_y=jnp.asarray(_pad1(ty[eidx], 1 << 30)),
        rowdir_flip=jnp.asarray(_pad1(tf[eidx], False)),
        rowdir_prim=jnp.asarray(_pad1(tp[eidx], -1)),
        plane_z=jnp.asarray(_pad1(pz, 1 << 30)),
        min_rep=buckets.reps[jnp.array([0])],
        max_rep=buckets.reps[jnp.array([nb - 1])],
        multi_line=multi_line, multi_plane=multi_plane,
        triangles_materialized=len(tz),
        slots_allocated=(1 + int(multi_line) + int(multi_plane)) * nb,  # l.5
    )
    return scene


# ---------------------------------------------------------------------------
# Lookup: Algorithm 2 (both representations).
# ---------------------------------------------------------------------------

def lookup(scene: GridScene, queries: KeyArray,
           use_kernel: bool = False,
           probe: Optional[str] = None) -> GridLookupResult:
    """Point lookup (paper Alg. 2), with coalesced probe batching.

    ``probe`` selects the "ray" oracle from the query-layer registry
    (``repro.query.backends.get_probe``): ``'jnp'`` is the vectorized
    binary search below, ``'kernel'`` routes every probe through the
    Pallas lexicographic-count kernel (kernels/grid_probe.py) — same
    results, hardware path.  ``use_kernel=True`` is the legacy spelling
    of ``probe='kernel'``.

    The ray sequence is *coalesced*: the up-to-five casts of Algorithm 2
    are scheduled by data dependency, and every cast that targets the
    triangle directory (rays 1, 3 and 5) is issued as ONE probe over a
    3x-wide padded lane batch.  Per query batch that is 4 probe calls
    instead of 6, and the large triangle directory is traversed once
    instead of three times — the same query-level batching the engine
    applies to rank lookups.  Results are identical to the sequential
    schedule (each cast's inputs are unchanged); the per-query ray
    *accounting* (Fig. 8 metric) is also unchanged.
    """
    from repro.query.backends import get_probe

    from .keys import key_lt

    if probe is None:
        probe = "kernel" if use_kernel else "jnp"
    probe_fn = get_probe(probe)

    kmap = scene.kmap
    qx, qy, qz = coords_device(kmap, queries)
    T = scene.tri_z.shape[0]
    R = scene.rowdir_z.shape[0]

    below = key_lt(queries, scene.min_rep[jnp.array(0)])        # l.1
    above = key_lt(scene.max_rep[jnp.array(0)], queries)        # l.2

    zeros = jnp.zeros_like(qx)
    rays = jnp.zeros(qx.shape, jnp.int32)

    # Round A (no data dependencies): yCast to the row marker set and
    # zCast to the plane set.
    # Ray 2: yCast from the next row — probes the marker / row-end set.
    j = probe_fn((scene.rowdir_z, scene.rowdir_y), (qz, qy + 1))
    jc = jnp.minimum(j, R - 1)
    hit2 = (j < R) & (scene.rowdir_z[jc] == qz)
    row2_y = scene.rowdir_y[jc]
    flip2 = scene.rowdir_flip[jc]
    prim2_end = scene.rowdir_prim[jc]

    # Ray 4: zCast to the next populated plane.
    p = probe_fn((scene.plane_z,), (qz + 1,)).astype(jnp.int32)
    pc = jnp.minimum(p, scene.plane_z.shape[0] - 1)
    plane4 = scene.plane_z[pc]

    # Round B (needs plane4): yCast from y=0 in the discovered plane.
    j4 = probe_fn((scene.rowdir_z, scene.rowdir_y), (plane4, zeros))
    j4c = jnp.minimum(j4, R - 1)
    row4_y = scene.rowdir_y[j4c]
    flip4 = scene.rowdir_flip[j4c]
    prim4_end = scene.rowdir_prim[j4c]

    # Round C: all three xCasts against the triangle directory, coalesced
    # into ONE probe over 3Q padded lanes —
    #   ray 1: xCast(key.x, key.y, key.z)   (hit iff in the query's row)
    #   ray 3: xCast(0, row2_y, qz)         (first triangle of ray 2's row)
    #   ray 5: xCast(0, row4_y, plane4)     (first triangle of ray 4's row)
    tq_z = jnp.concatenate([qz, qz, plane4])
    tq_y = jnp.concatenate([qy, row2_y, row4_y])
    tq_x = jnp.concatenate([qx, zeros, zeros])
    i_all = probe_fn((scene.tri_z, scene.tri_y, scene.tri_x),
                     (tq_z, tq_y, tq_x))
    i1, i3, i5 = jnp.split(i_all, 3)

    i1c = jnp.minimum(i1, T - 1)
    hit1 = (i1 < T) & (scene.tri_z[i1c] == qz) & (scene.tri_y[i1c] == qy)
    prim1 = scene.tri_prim[i1c]
    prim3 = scene.tri_prim[jnp.minimum(i3, T - 1)]
    prim5 = scene.tri_prim[jnp.minimum(i5, T - 1)]

    # Ray accounting (paper Fig. 8): identical to the sequential schedule.
    flip2 = flip2 & hit2
    rays = rays + 1                                       # ray 1 always
    rays = rays + jnp.where(hit1, 0, 1)                   # ray 2
    rays = rays + jnp.where((~hit1) & hit2 & (~flip2), 1, 0)   # ray 3
    need_z = (~hit1) & (~hit2)
    rays = rays + jnp.where(need_z, jnp.where(flip4, 2, 3), 0)  # rays 4-6

    prim = jnp.where(
        hit1, prim1,
        jnp.where(hit2, jnp.where(flip2, prim2_end, prim3),
                  jnp.where(flip4, prim4_end, prim5)))
    if scene.representation == "optimized":
        bucket = remap_prim(prim, scene.num_buckets)
    else:
        bucket = prim  # naive: prim index == bucketID
    bucket = jnp.where(below, 0, bucket)
    bucket = jnp.where(above, MISS, bucket)
    rays = jnp.where(below | above, 0, rays)
    return GridLookupResult(bucket_id=bucket.astype(jnp.int32), rays=rays)


# ---------------------------------------------------------------------------
# Convenience: full point lookup (bucket via scene + post-filter).
# ---------------------------------------------------------------------------

def build_scene(keys: KeyArray, row_ids: Optional[jnp.ndarray], bucket_size: int,
                representation: str = "optimized",
                kmap: Optional[KeyMapping] = None) -> Tuple[GridScene, BucketedSet]:
    buckets = build_buckets(keys, row_ids, bucket_size)
    if representation == "naive":
        scene = build_naive(buckets, kmap)
    else:
        keys_sorted = buckets.keys.to_numpy()[: buckets.n]
        scene = build_optimized(buckets, keys_sorted, kmap)
    return scene, buckets


def point_lookup(scene: GridScene, buckets: BucketedSet,
                 queries: KeyArray):
    """bucketID via the ray emulation + in-bucket post-filter -> rowID."""
    from .keys import key_eq, key_le, key_lt

    res = lookup(scene, queries)
    B = buckets.bucket_size
    nb = buckets.num_buckets
    bid = jnp.clip(res.bucket_id, 0, nb - 1)
    offs = bid[..., None] * B + jnp.arange(B, dtype=jnp.int32)
    rows = buckets.keys.take(offs)
    qb = KeyArray(queries.lo[..., None],
                  None if queries.hi is None else queries.hi[..., None])
    inb = jnp.sum(key_lt(rows, qb).astype(jnp.int32), axis=-1)
    pos = bid * B + inb
    safe = jnp.minimum(pos, buckets.n - 1)
    found = (res.bucket_id >= 0) & (pos < buckets.n) & key_eq(buckets.keys.take(safe), queries)
    rowid = jnp.where(found, buckets.row_ids[safe], MISS)
    return rowid.astype(jnp.int32), found, res.rays
