"""Sorted-bucket machinery shared by the cgRX index and MoE dispatch.

The paper's construction (Algorithm 1/3) sorts the key set, partitions it
into buckets of ``bucket_size`` keys and materializes only the *last* key of
each bucket (the representative).  This module provides the sort/partition/
representative-extraction primitives; cgrx.py composes them into the index.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .keys import (
    KeyArray,
    key_eq,
    key_max_sentinel,
    sort_with_payload,
)


@dataclasses.dataclass
class BucketedSet:
    """A sorted key/rowID set partitioned into fixed-size buckets.

    ``keys``/``row_ids`` are the flat sorted arrays padded to
    ``num_buckets * bucket_size`` with MAX-sentinel keys; the 2-D *bucket
    matrix* view is just a reshape of the same buffer (zero-copy), which is
    the packed row layout's natural TPU form.
    """

    keys: KeyArray            # (num_buckets * bucket_size,), sorted, padded
    row_ids: jnp.ndarray      # (num_buckets * bucket_size,) int32, padded w/ -1
    reps: KeyArray            # (num_buckets,) last key of each bucket
    bucket_size: int
    n: int                    # true (unpadded) number of keys

    tree_flatten = None  # plain container; rebuilt per build()

    @property
    def num_buckets(self) -> int:
        return self.reps.shape[0]

    def bucket_matrix(self) -> KeyArray:
        return self.keys.reshape(self.num_buckets, self.bucket_size)

    def rowid_matrix(self) -> jnp.ndarray:
        return self.row_ids.reshape(self.num_buckets, self.bucket_size)


def build_buckets(keys: KeyArray, row_ids: jnp.ndarray, bucket_size: int,
                  *, presorted: bool = False) -> BucketedSet:
    """Sort (keys, row_ids) and partition into buckets (paper Alg. 1 l.1-9).

    ``presorted=True`` skips the sort — the caller asserts ``keys`` is
    already ascending with ``row_ids`` aligned (e.g. ``nodes.extract``
    output during a compaction epoch swap).
    """
    n = keys.shape[0]
    if row_ids is None:
        row_ids = jnp.arange(n, dtype=jnp.int32)
    if presorted:
        skeys, srow = keys, row_ids.astype(jnp.int32)
    else:
        skeys, srow = sort_with_payload(keys, row_ids.astype(jnp.int32))

    num_buckets = max(1, -(-n // bucket_size))  # ceil div
    padded = num_buckets * bucket_size
    pad = padded - n
    if pad:
        sentinel = key_max_sentinel(skeys, (pad,))
        from .keys import concat_keys

        skeys = concat_keys(skeys, sentinel)
        srow = jnp.concatenate([srow, jnp.full((pad,), -1, dtype=jnp.int32)])

    # Representative = last *real* key of each bucket: index
    # min((b+1)*B, n) - 1 into the sorted array (Alg. 1 l.8).
    b = jnp.arange(num_buckets, dtype=jnp.int32)
    rep_idx = jnp.minimum((b + 1) * bucket_size, n) - 1
    reps = skeys.take(rep_idx)

    return BucketedSet(keys=skeys, row_ids=srow, reps=reps, bucket_size=bucket_size, n=n)


def rep_duplicate_mask(reps: KeyArray) -> jnp.ndarray:
    """Paper Sec. 3.1 duplicate handling: when consecutive buckets share a
    representative (same key spilling over bucket boundaries), only the first
    gets a triangle.  Returns True where a rep is a duplicate of its
    predecessor (i.e. would NOT be materialized)."""
    nb = reps.shape[0]
    prev = reps[jnp.maximum(jnp.arange(nb) - 1, 0)]
    dup = key_eq(reps, prev)
    return dup & (jnp.arange(nb) > 0)


# ---------------------------------------------------------------------------
# Sort-based dispatch (reused by MoE): bucket boundaries by successor search.
# ---------------------------------------------------------------------------

def segment_bounds(sorted_ids: jnp.ndarray, num_segments: int):
    """Start/end offsets of each id-segment in a sorted id array.

    This is the same "two binary searches delimit my slice" pattern the
    paper's batch-update kernel uses per bucket (Sec. 4), applied to MoE
    token->expert dispatch.
    """
    seg = jnp.arange(num_segments, dtype=sorted_ids.dtype)
    starts = jnp.searchsorted(sorted_ids, seg, side="left")
    ends = jnp.searchsorted(sorted_ids, seg, side="right")
    return starts.astype(jnp.int32), ends.astype(jnp.int32)
