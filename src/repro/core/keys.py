"""Key arithmetic for 32-bit and 64-bit unsigned keys.

JAX runs with 32-bit defaults (no ``jax_enable_x64``), so 64-bit keys are
represented as ``(hi, lo)`` pairs of ``uint32`` arrays with lexicographic
comparison.  This mirrors the paper's own *packed* row layout, which stores
64-bit keys as two 32-bit numbers to circumvent 8-byte alignment (Sec. 3.4).

All comparison helpers are elementwise and broadcast like jnp primitives.
``KeyArray`` is a registered pytree so it can flow through jit/vmap/shard_map.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

U32_MAX = np.uint32(0xFFFFFFFF)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class KeyArray:
    """A (possibly 64-bit) unsigned key array.

    ``lo`` always holds the low 32 bits.  ``hi`` is ``None`` for 32-bit key
    sets and holds the high 32 bits otherwise.  Invariant: ``hi is None`` or
    ``hi.shape == lo.shape``.
    """

    lo: jnp.ndarray
    hi: Optional[jnp.ndarray] = None

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        if self.hi is None:
            return (self.lo,), ("u32",)
        return (self.lo, self.hi), ("u64",)

    @classmethod
    def tree_unflatten(cls, aux, children):
        if aux[0] == "u32":
            return cls(lo=children[0], hi=None)
        return cls(lo=children[0], hi=children[1])

    # -- basics ------------------------------------------------------------
    @property
    def shape(self):
        return self.lo.shape

    @property
    def ndim(self):
        return self.lo.ndim

    @property
    def is64(self) -> bool:
        return self.hi is not None

    @property
    def nbytes(self) -> int:
        n = int(np.prod(self.shape)) if self.shape else 1
        return n * (8 if self.is64 else 4)

    def __len__(self):
        return self.lo.shape[0]

    def __getitem__(self, idx):
        return KeyArray(self.lo[idx], None if self.hi is None else self.hi[idx])

    def reshape(self, *shape):
        return KeyArray(
            self.lo.reshape(*shape),
            None if self.hi is None else self.hi.reshape(*shape),
        )

    def take(self, idx, fill_value=None):
        """Gather by index.  Out-of-range indices clamp (jnp default)."""
        lo = jnp.take(self.lo, idx, mode="clip")
        hi = None if self.hi is None else jnp.take(self.hi, idx, mode="clip")
        return KeyArray(lo, hi)

    def astuple(self) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
        return self.lo, self.hi

    # -- host conversion (tests / benchmarks) --------------------------------
    @staticmethod
    def from_u64(arr) -> "KeyArray":
        """Build from a host numpy uint64 array (64-bit key set)."""
        arr = np.asarray(arr, dtype=np.uint64)
        return KeyArray(
            lo=jnp.asarray((arr & np.uint64(0xFFFFFFFF)).astype(np.uint32)),
            hi=jnp.asarray((arr >> np.uint64(32)).astype(np.uint32)),
        )

    @staticmethod
    def from_u32(arr) -> "KeyArray":
        arr = np.asarray(arr, dtype=np.uint32)
        return KeyArray(lo=jnp.asarray(arr), hi=None)

    def to_numpy(self) -> np.ndarray:
        """Back to host uint64 (or uint32) for test oracles."""
        lo = np.asarray(self.lo, dtype=np.uint64)
        if self.hi is None:
            return lo.astype(np.uint32)
        hi = np.asarray(self.hi, dtype=np.uint64)
        return (hi << np.uint64(32)) | lo


# ---------------------------------------------------------------------------
# Elementwise comparisons (broadcasting).
# ---------------------------------------------------------------------------

def key_lt(a: KeyArray, b: KeyArray) -> jnp.ndarray:
    if a.is64 or b.is64:
        ahi = a.hi if a.is64 else jnp.zeros_like(a.lo)
        bhi = b.hi if b.is64 else jnp.zeros_like(b.lo)
        return (ahi < bhi) | ((ahi == bhi) & (a.lo < b.lo))
    return a.lo < b.lo


def key_le(a: KeyArray, b: KeyArray) -> jnp.ndarray:
    if a.is64 or b.is64:
        ahi = a.hi if a.is64 else jnp.zeros_like(a.lo)
        bhi = b.hi if b.is64 else jnp.zeros_like(b.lo)
        return (ahi < bhi) | ((ahi == bhi) & (a.lo <= b.lo))
    return a.lo <= b.lo


def key_eq(a: KeyArray, b: KeyArray) -> jnp.ndarray:
    if a.is64 or b.is64:
        ahi = a.hi if a.is64 else jnp.zeros_like(a.lo)
        bhi = b.hi if b.is64 else jnp.zeros_like(b.lo)
        return (ahi == bhi) & (a.lo == b.lo)
    return a.lo == b.lo


def key_gt(a: KeyArray, b: KeyArray) -> jnp.ndarray:
    return key_lt(b, a)


def key_ge(a: KeyArray, b: KeyArray) -> jnp.ndarray:
    return key_le(b, a)


def key_where(pred: jnp.ndarray, a: KeyArray, b: KeyArray) -> KeyArray:
    hi = None
    if a.is64 or b.is64:
        ahi = a.hi if a.is64 else jnp.zeros_like(a.lo)
        bhi = b.hi if b.is64 else jnp.zeros_like(b.lo)
        hi = jnp.where(pred, ahi, bhi)
    return KeyArray(jnp.where(pred, a.lo, b.lo), hi)


def key_max_sentinel(like: KeyArray, shape=()) -> KeyArray:
    """All-ones key: compares >= any real key.  Used to pad buckets."""
    lo = jnp.full(shape, U32_MAX, dtype=jnp.uint32)
    hi = jnp.full(shape, U32_MAX, dtype=jnp.uint32) if like.is64 else None
    return KeyArray(lo, hi)


def key_scalar(value: int, is64: bool) -> KeyArray:
    if is64:
        return KeyArray(
            lo=jnp.uint32(value & 0xFFFFFFFF), hi=jnp.uint32((value >> 32) & 0xFFFFFFFF)
        )
    return KeyArray(lo=jnp.uint32(value & 0xFFFFFFFF), hi=None)


# ---------------------------------------------------------------------------
# Sorting and searching.
# ---------------------------------------------------------------------------

def sort_with_payload(keys: KeyArray, *payloads: jnp.ndarray):
    """Stable sort of keys, carrying payload arrays along.

    Uses ``lax.sort`` with ``num_keys=2`` for 64-bit keys (hi major) which is
    the TPU-native multi-operand sort (the analogue of CUB DeviceRadixSort the
    paper uses for its construction pipeline).
    """
    if keys.is64:
        operands = (keys.hi, keys.lo) + payloads
        out = jax.lax.sort(operands, num_keys=2, is_stable=True)
        skeys = KeyArray(lo=out[1], hi=out[0])
        return (skeys,) + tuple(out[2:])
    operands = (keys.lo,) + payloads
    out = jax.lax.sort(operands, num_keys=1, is_stable=True)
    return (KeyArray(lo=out[0], hi=None),) + tuple(out[1:])


def searchsorted(sorted_keys: KeyArray, queries: KeyArray, side: str = "left") -> jnp.ndarray:
    """Vectorized binary search over a lexicographically sorted KeyArray.

    Returns, per query, the insertion index in ``[0, n]``.  Pure-jnp oracle;
    the Pallas successor kernel (kernels/successor.py) computes the same
    quantity by tiled compare-count on the VPU.
    """
    n = sorted_keys.shape[0]
    if n == 0:
        return jnp.zeros(queries.shape, dtype=jnp.int32)
    n_iter = max(1, int(np.ceil(np.log2(n + 1))))
    cmp = key_lt if side == "right" else key_le

    def body(_, lohi):
        lo, hi = lohi
        done = lo >= hi
        mid = (lo + hi) // 2
        mid_keys = sorted_keys.take(mid)
        # side=left: first idx with sorted[idx] >= q  -> go left when q <= mid
        go_left = cmp(queries, mid_keys)
        lo = jnp.where(done, lo, jnp.where(go_left, lo, mid + 1))
        hi = jnp.where(done, hi, jnp.where(go_left, mid, hi))
        return lo, hi

    lo = jnp.zeros(queries.shape, dtype=jnp.int32)
    hi = jnp.full(queries.shape, n, dtype=jnp.int32)
    lo, hi = jax.lax.fori_loop(0, n_iter, body, (lo, hi))
    return lo


def unique_mask(sorted_keys: KeyArray) -> jnp.ndarray:
    """True at the first occurrence of each key in a sorted KeyArray."""
    n = sorted_keys.shape[0]
    prev = sorted_keys[jnp.maximum(jnp.arange(n) - 1, 0)]
    first = jnp.arange(n) == 0
    return first | ~key_eq(sorted_keys, prev)


def concat_keys(a: KeyArray, b: KeyArray) -> KeyArray:
    assert a.is64 == b.is64
    lo = jnp.concatenate([a.lo, b.lo])
    hi = jnp.concatenate([a.hi, b.hi]) if a.is64 else None
    return KeyArray(lo, hi)
