"""Key -> 3D-coordinate mappings (paper Sec. 2.1 / 5.2).

RX/cgRX embed keys on an integer grid:  ``k -> (x, y, z)`` by bit slicing,
with 23/23/18 bits for 64-bit keys (float-precision limit of RT cores) and
23/9/0 for 32-bit keys (single plane).

On TPU there is no float-precision cliff (we compare uint32 pairs exactly),
but the *row/plane decomposition* is retained because the paper's lookup
algorithm (Algorithm 2) is expressed in terms of rows (same y,z) and planes
(same z).  The *scaled* mapping (multiplying y by 2^15 and z by 2^25) exists
in the paper purely to steer OptiX's opaque BVH builder to group bounding
volumes along the x-axis (Fig. 9); our grouping is explicit and always
"along x" (we build on the sorted rep array), so scaling is accepted as a
config knob but is a no-op for correctness and grouping — recorded in
DESIGN.md Sec. 2 as a changed assumption.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .keys import KeyArray

X_BITS_64, Y_BITS_64, Z_BITS_64 = 23, 23, 18
X_BITS_32, Y_BITS_32, Z_BITS_32 = 23, 9, 0


@dataclasses.dataclass(frozen=True)
class KeyMapping:
    """Bit-slice mapping of a key into (x, y, z) integer coordinates."""

    x_bits: int
    y_bits: int
    z_bits: int
    # Paper's scaled mapping k -> (x, 2^15 * y, 2^25 * z); see module docstring.
    y_scale_log2: int = 0
    z_scale_log2: int = 0

    @property
    def x_max(self) -> int:
        return (1 << self.x_bits) - 1

    @property
    def y_max(self) -> int:
        return (1 << self.y_bits) - 1

    @property
    def z_max(self) -> int:
        return (1 << self.z_bits) - 1 if self.z_bits else 0

    def coords(self, keys: KeyArray):
        """Return integer (x, y, z) uint32 coordinate arrays."""
        lo = keys.lo
        x = lo & jnp.uint32(self.x_max)
        if keys.is64:
            hi = keys.hi
            # y bits straddle the 32-bit boundary for the default 23/23/18 map:
            # lo[31:x_bits] supplies the low (32 - x_bits) y-bits, hi supplies
            # the rest.
            lo_part = lo >> jnp.uint32(self.x_bits)
            lo_part_bits = 32 - self.x_bits
            y = (lo_part | (hi << jnp.uint32(lo_part_bits))) & jnp.uint32(self.y_max)
            z_shift = self.x_bits + self.y_bits - 32  # bits of hi consumed by y
            z = (hi >> jnp.uint32(max(z_shift, 0))) & jnp.uint32(self.z_max if self.z_bits else 0)
        else:
            y = (lo >> jnp.uint32(self.x_bits)) & jnp.uint32(self.y_max)
            z = jnp.zeros_like(lo)
        return x, y, z

    def rowkey(self, keys: KeyArray) -> jnp.ndarray:
        """(z,y) combined — equal rowkey <=> same row.  Paper's ``k.yz``."""
        x, y, z = self.coords(keys)
        return (z.astype(jnp.uint32) << jnp.uint32(self.y_bits)) | y

    def planekey(self, keys: KeyArray) -> jnp.ndarray:
        """Paper's ``k.z``."""
        _, _, z = self.coords(keys)
        return z


DEFAULT_64 = KeyMapping(X_BITS_64, Y_BITS_64, Z_BITS_64)
SCALED_64 = KeyMapping(X_BITS_64, Y_BITS_64, Z_BITS_64, y_scale_log2=15, z_scale_log2=25)
DEFAULT_32 = KeyMapping(X_BITS_32, Y_BITS_32, Z_BITS_32)


def default_mapping(is64: bool, scaled: bool = True) -> KeyMapping:
    if not is64:
        return DEFAULT_32
    return SCALED_64 if scaled else DEFAULT_64
