"""One-shot deprecation warnings for pre-``repro.db`` surfaces.

The unified session API (``repro.db``) is the supported front door over
the static / live / sharded index tiers; the older per-tier conveniences
(``core.cgrx.lookup``-style single calls, ``store.LiveFrontend``) keep
working as thin shims but announce themselves exactly once per process —
loud enough to steer migrations, quiet enough not to spam a serving loop
that calls a deprecated path per tick.
"""
from __future__ import annotations

import warnings
from typing import Optional

_seen: set = set()


def warn_once(key: str, message: str, *, stacklevel: int = 3) -> bool:
    """Emit ``DeprecationWarning(message)`` the first time ``key`` is
    seen this process; later calls are free no-ops.  Returns True when
    the warning actually fired (tests assert on it)."""
    if key in _seen:
        return False
    _seen.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel)
    return True


def reset(key: Optional[str] = None) -> None:
    """Forget emitted keys (all, or one) — test isolation hook."""
    if key is None:
        _seen.clear()
    else:
        _seen.discard(key)
