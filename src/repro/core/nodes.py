"""Node-based updatable cgRX variant (paper Section 4).

Each bucket is a linked list of fixed-size nodes living in one slab:
a *representative node region* (node i = head of bucket i, contiguous, so
the successor search result maps to a node address by multiplication) and a
*linked node region* for nodes appended on splits.  Updates never touch the
representatives or the search tree — the paper's whole point: RX's 78x
post-update lookup regression cannot occur because the accelerated
structure is immutable; growth happens in bucket-local chains.

Batch updates, hardware adaptation: the paper dedicates one CUDA thread per
bucket which walks its chain shifting keys one at a time.  A serial
pointer-walk per lane is the wrong shape for the TPU VPU, so the same
per-bucket work is expressed as a *masked merge*: every touched bucket
gathers its chain contents + its slice of the sorted update batch (located
by the same "two binary searches" the paper uses), drops deleted keys,
merge-sorts, and writes the result back through its (possibly extended)
chain.  Untouched buckets are not read or written.  Semantics (bucket-local
cost, immutable reps, deletions-before-insertions, node reuse, split-like
growth) are preserved; the per-key shift loop is not — recorded in
DESIGN.md Sec. 2.

Host/device split mirrors a real system: the host plans static shapes
(touched-bucket count, per-bucket batch cap, chain-length bound) and the
device executes fully-vectorized gathers/sorts/scatters.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import fanout
from .bucketing import build_buckets
from .keys import (
    KeyArray,
    concat_keys,
    key_eq,
    key_le,
    key_lt,
    key_max_sentinel,
    key_where,
    searchsorted,
    sort_with_payload,
)

NO_NODE = jnp.int32(-1)
MISS = jnp.int32(-1)


@dataclasses.dataclass
class NodeStore:
    """SoA slab of nodes + immutable successor-search structure."""

    # --- device state ---
    node_keys: KeyArray      # (C, N)
    node_rows: jnp.ndarray   # (C, N) int32
    node_next: jnp.ndarray   # (C,) int32, NO_NODE terminated
    node_size: jnp.ndarray   # (C,) int32
    node_maxkey: KeyArray    # (C,) largest valid key of the node
    bucket_count: jnp.ndarray  # (num_buckets,) int32 live keys per chain
    reps: KeyArray           # (num_buckets,) immutable representatives
    tree: fanout.FanoutTree  # immutable successor-search tree
    # --- host bookkeeping ---
    num_buckets: int
    node_cap: int            # N
    capacity: int            # C
    free_ptr: int            # next unused node in the linked region
    max_chain: int           # upper bound on chain length (for bounded walks)
    is64: bool

    @property
    def nbytes(self) -> dict:
        out = {
            "node_bytes": self.node_keys.nbytes + self.node_rows.nbytes
            + self.node_next.nbytes + self.node_size.nbytes
            + self.node_maxkey.nbytes + self.bucket_count.nbytes,
            "rep_bytes": self.reps.nbytes,
            "tree_bytes": self.tree.nbytes,
        }
        out["total_bytes"] = sum(out.values())
        return out


# ---------------------------------------------------------------------------
# Initial bulk load (paper Sec. 4 "Initial construction").
# ---------------------------------------------------------------------------

def build(keys: KeyArray, row_ids: Optional[jnp.ndarray], node_cap: int,
          *, fill: Optional[int] = None, slack: float = 1.0,
          fanout_width: int = 128, presorted: bool = False) -> NodeStore:
    """Bulk load with buckets of ``fill`` keys (default N/2, paper's choice:
    'divide them into buckets of size N/2 ... filled until a specified fill
    state').  ``slack`` scales the linked-node region reservation;
    ``presorted`` skips the bulk-load sort (compaction rebuilds from the
    already-sorted ``extract`` output)."""
    n = keys.shape[0]
    fill = fill or node_cap // 2
    buckets = build_buckets(keys, row_ids, fill, presorted=presorted)
    nb = buckets.num_buckets

    linked = max(int(nb * slack), 16)
    C = nb + linked
    N = node_cap

    sent = key_max_sentinel(buckets.keys, (C, N))
    nk_lo = sent.lo.at[:nb, :fill].set(buckets.keys.lo.reshape(nb, fill))
    nk_hi = None
    if buckets.keys.is64:
        nk_hi = sent.hi.at[:nb, :fill].set(buckets.keys.hi.reshape(nb, fill))
    node_keys = KeyArray(nk_lo, nk_hi)

    node_rows = jnp.full((C, N), -1, jnp.int32)
    node_rows = node_rows.at[:nb, :fill].set(buckets.row_ids.reshape(nb, fill))

    # Sizes: last bucket may be partial (padded slots hold MAX sentinels).
    sizes = jnp.zeros((C,), jnp.int32)
    b = jnp.arange(nb, dtype=jnp.int32)
    real = jnp.minimum(buckets.n - b * fill, fill)
    sizes = sizes.at[:nb].set(jnp.maximum(real, 0))

    maxkey = key_max_sentinel(buckets.keys, (C,))
    maxkey = key_where(
        jnp.arange(C) < nb,
        _scatter_keys(maxkey, jnp.arange(nb), buckets.reps, C),
        maxkey)

    tree = fanout.build_tree(buckets.reps, fanout=fanout_width)
    return NodeStore(
        node_keys=node_keys, node_rows=node_rows,
        node_next=jnp.full((C,), NO_NODE, jnp.int32),
        node_size=sizes, node_maxkey=maxkey,
        bucket_count=jnp.maximum(real, 0).astype(jnp.int32),
        reps=buckets.reps, tree=tree,
        num_buckets=nb, node_cap=N, capacity=C,
        free_ptr=nb, max_chain=1, is64=keys.is64)


def _scatter_keys(dst: KeyArray, idx, src: KeyArray, C) -> KeyArray:
    lo = dst.lo.at[idx].set(src.lo)
    hi = dst.hi.at[idx].set(src.hi) if dst.is64 else None
    return KeyArray(lo, hi)


# ---------------------------------------------------------------------------
# Point lookup (rep search unchanged; then a bounded chain walk).
# ---------------------------------------------------------------------------

class NodeLookupResult(NamedTuple):
    bucket_id: jnp.ndarray
    row_id: jnp.ndarray
    found: jnp.ndarray


def lookup(store: NodeStore, queries: KeyArray) -> NodeLookupResult:
    bid = fanout.descend(store.tree, queries, side="left")
    # Keys beyond maxRep may exist after inserts: they live in the LAST
    # bucket (the rep structure is immutable), so clamp instead of missing.
    start = jnp.minimum(bid, store.num_buckets - 1).astype(jnp.int32)

    # Walk: advance while this node's maxKey < q and a next node exists.
    def step(_, node):
        mk = store.node_maxkey.take(node)
        nxt = store.node_next[node]
        adv = key_lt(mk, queries) & (nxt != NO_NODE)
        return jnp.where(adv, nxt, node)

    node = jax.lax.fori_loop(0, max(store.max_chain - 1, 0), step, start)

    # In-node binary-search-equivalent: count keys < q (sentinel-padded).
    rows = store.node_keys.take(node[..., None] * store.node_cap
                                + jnp.arange(store.node_cap, dtype=jnp.int32))
    qb = KeyArray(queries.lo[..., None],
                  None if queries.hi is None else queries.hi[..., None])
    pos = jnp.sum(key_lt(rows, qb).astype(jnp.int32), axis=-1)
    limit = store.node_size[node]
    safe = jnp.minimum(pos, store.node_cap - 1)
    hit_key = KeyArray(
        jnp.take_along_axis(rows.lo, safe[..., None], axis=-1)[..., 0],
        None if rows.hi is None else
        jnp.take_along_axis(rows.hi, safe[..., None], axis=-1)[..., 0])
    found = (pos < limit) & key_eq(hit_key, queries)
    flat = node * store.node_cap + safe
    row = jnp.where(found, store.node_rows.reshape(-1)[flat], MISS)
    return NodeLookupResult(bucket_id=start, row_id=row.astype(jnp.int32),
                            found=found)


# ---------------------------------------------------------------------------
# Batch insert/delete (paper Sec. 4 "Insertion and deletion").
# ---------------------------------------------------------------------------

def _walk_chains(store: NodeStore, bucket_ids: np.ndarray) -> np.ndarray:
    """Host: chain node-id lists (T, max_chain), NO_NODE padded.

    Negative bucket ids (shape-padding rows, see ``_pow2``) yield all-
    invalid chains, so padded rows gather nothing and scatter nothing.
    """
    nxt = np.asarray(store.node_next)
    T = len(bucket_ids)
    out = np.full((T, store.max_chain), -1, np.int32)
    cur = bucket_ids.astype(np.int32).copy()
    alive = bucket_ids >= 0
    for i in range(store.max_chain):
        out[:, i] = np.where(alive, cur, -1)
        nx = np.where(alive, nxt[np.maximum(cur, 0)], -1)
        alive = alive & (nx != -1)
        cur = np.where(nx != -1, nx, cur)
    return out


def _pow2(x: int) -> int:
    """Next power of two: static-shape bucketing for the device program.

    Every distinct (touched count, per-bucket cap) pair is a fresh XLA
    compilation in eager mode; rounding the host plan's shape knobs up to
    powers of two makes successive update batches reuse a handful of
    compiled programs (the long-lived store applies thousands of them).
    """
    return 1 << max(int(x) - 1, 0).bit_length()


def apply_batch(store: NodeStore,
                ins_keys: Optional[KeyArray], ins_rows: Optional[jnp.ndarray],
                del_keys: Optional[KeyArray],
                *, fill_target: Optional[int] = None) -> NodeStore:
    """Apply one update batch; returns a new NodeStore (functional update).

    Paper order of operations: sort the batch, cancel insert∩delete pairs,
    deletions first (frees space), then insertions with split-like growth.
    """
    N = store.node_cap
    nb = store.num_buckets
    fill_target = fill_target or N

    is64 = store.is64
    empty = KeyArray(jnp.zeros((0,), jnp.uint32),
                     jnp.zeros((0,), jnp.uint32) if is64 else None)
    if ins_keys is None:
        ins_keys, ins_rows = empty, jnp.zeros((0,), jnp.int32)
    if del_keys is None:
        del_keys = empty

    # Sort both batches; cancel keys appearing in both (paper: a key in
    # both batches is removed from BOTH, so the pair is a no-op and any
    # pre-existing copy survives — a delete-then-reinsert must not leave
    # the key tombstoned, see tests/test_nodes.py).  Cancellation is
    # PAIRWISE on the sorted multisets: the i-th duplicate of a key among
    # the inserts cancels the i-th among the deletes, surplus occurrences
    # survive (batches being stably sorted, earlier-submitted duplicates
    # cancel first).
    if ins_keys.shape[0]:
        ins_keys, ins_rows = sort_with_payload(ins_keys, ins_rows.astype(jnp.int32))
    if del_keys.shape[0]:
        (del_keys,) = sort_with_payload(del_keys)
    if ins_keys.shape[0] and del_keys.shape[0]:
        d_lo = searchsorted(del_keys, ins_keys, side="left")
        d_hi = searchsorted(del_keys, ins_keys, side="right")
        occ_i = (jnp.arange(ins_keys.shape[0], dtype=jnp.int32)
                 - searchsorted(ins_keys, ins_keys, side="left"))
        ins_cancel = occ_i < (d_hi - d_lo)
        i_lo = searchsorted(ins_keys, del_keys, side="left")
        i_hi = searchsorted(ins_keys, del_keys, side="right")
        occ_d = (jnp.arange(del_keys.shape[0], dtype=jnp.int32)
                 - searchsorted(del_keys, del_keys, side="left"))
        del_cancel = occ_d < (i_hi - i_lo)
        # Cancelled entries become MAX sentinels (sorted to the tail & masked).
        ins_keys = key_where(ins_cancel, key_max_sentinel(ins_keys, ins_keys.shape), ins_keys)
        ins_rows = jnp.where(ins_cancel, -1, ins_rows)
        ins_keys, ins_rows = sort_with_payload(ins_keys, ins_rows)
        n_ins = int(jnp.sum(~ins_cancel))
        del_keys = key_where(del_cancel, key_max_sentinel(del_keys, del_keys.shape), del_keys)
        (del_keys,) = sort_with_payload(del_keys)
        n_del = int(jnp.sum(~del_cancel))
    else:
        n_ins = ins_keys.shape[0]
        n_del = del_keys.shape[0]

    # Target bucket per key: successor over immutable reps; keys beyond the
    # last rep go to the last bucket.
    def targets(k: KeyArray) -> jnp.ndarray:
        t = fanout.descend(store.tree, k, side="left")
        return jnp.minimum(t, nb - 1).astype(jnp.int32)

    ins_b = targets(ins_keys) if ins_keys.shape[0] else jnp.zeros((0,), jnp.int32)
    del_b = targets(del_keys) if del_keys.shape[0] else jnp.zeros((0,), jnp.int32)
    if n_ins < ins_keys.shape[0]:  # keep cancelled sentinels out of buckets
        ins_b = jnp.where(jnp.arange(ins_keys.shape[0]) < n_ins, ins_b, nb)
    if n_del < del_keys.shape[0]:
        del_b = jnp.where(jnp.arange(del_keys.shape[0]) < n_del, del_b, nb)

    # ---- host planning: touched buckets + static caps ----
    ins_b_np = np.asarray(ins_b)[:n_ins]
    del_b_np = np.asarray(del_b)[:n_del]
    touched = np.unique(np.concatenate([ins_b_np, del_b_np])).astype(np.int32)
    if len(touched) == 0:
        return store
    # Pad the plan to power-of-two shapes (see _pow2): padded rows carry
    # bucket id -1 -> invalid chains, empty batch slices, no allocation,
    # masked scatters — fully inert.
    n_touched = len(touched)
    T = _pow2(n_touched)
    touched = np.concatenate(
        [touched, np.full(T - n_touched, -1, np.int32)])
    ins_start = np.searchsorted(ins_b_np, touched, side="left").astype(np.int32)
    ins_end = np.searchsorted(ins_b_np, touched, side="right").astype(np.int32)
    del_start = np.searchsorted(del_b_np, touched, side="left").astype(np.int32)
    del_end = np.searchsorted(del_b_np, touched, side="right").astype(np.int32)
    cap_ins = _pow2(max(int((ins_end - ins_start).max()), 1))
    cap_del = _pow2(max(int((del_end - del_start).max()), 1))

    chains = _walk_chains(store, touched)                  # (T, max_chain)
    chain_valid = chains >= 0
    old_slots = store.max_chain * N
    L = old_slots + cap_ins

    # ---- device: gather -> filter -> merge -> redistribute ----
    chains_j = jnp.asarray(chains)
    cv = jnp.asarray(chain_valid)

    gidx = jnp.maximum(chains_j, 0)[..., None] * N + jnp.arange(N)  # (T, mc, N)
    old_keys = store.node_keys.take(gidx.reshape(T, -1))            # (T, mc*N)
    old_rows = jnp.take(store.node_rows.reshape(-1), gidx.reshape(T, -1), mode="clip")
    slot_ok = (jnp.arange(N) < store.node_size[jnp.maximum(chains_j, 0)][..., None])
    slot_ok = (slot_ok & cv[..., None]).reshape(T, -1)

    # Deletions first (paper): membership test against this bucket's slice
    # of the sorted delete batch.
    if del_keys.shape[0]:
        doffs = jnp.asarray(del_start)[:, None] + jnp.arange(cap_del)
        dvalid = doffs < jnp.asarray(del_end)[:, None]
        dk = del_keys.take(jnp.minimum(doffs, del_keys.shape[0] - 1))
        # old_keys (T, mc*N) vs dk (T, cap_del): equality any
        eq = (old_keys.lo[:, :, None] == dk.lo[:, None, :])
        if is64:
            eq &= (old_keys.hi[:, :, None] == dk.hi[:, None, :])
        deleted = jnp.any(eq & dvalid[:, None, :], axis=-1)
        # Delete each key at most once per duplicate (paper deletes one per
        # delete-batch entry); we delete all duplicates of a deleted key —
        # matching the benchmark workloads where keys are unique.
        slot_ok = slot_ok & ~deleted

    keep = slot_ok
    sent = key_max_sentinel(old_keys, old_keys.shape)
    old_keys = key_where(keep, old_keys, sent)
    old_rows = jnp.where(keep, old_rows, -1)

    ioffs = jnp.asarray(ins_start)[:, None] + jnp.arange(cap_ins)
    ivalid = ioffs < jnp.asarray(ins_end)[:, None]
    if ins_keys.shape[0]:
        ik = ins_keys.take(jnp.minimum(ioffs, ins_keys.shape[0] - 1))
        ik = key_where(ivalid, ik, key_max_sentinel(ik, ik.shape))
        ir = jnp.where(ivalid, jnp.take(ins_rows, jnp.minimum(
            ioffs, ins_rows.shape[0] - 1), mode="clip"), -1)
    else:  # delete-only batch
        ik = key_max_sentinel(store.node_keys, ioffs.shape)
        ir = jnp.full(ioffs.shape, -1, jnp.int32)

    merged = KeyArray(
        jnp.concatenate([old_keys.lo, ik.lo], axis=1),
        jnp.concatenate([old_keys.hi, ik.hi], axis=1) if is64 else None)
    mrows = jnp.concatenate([old_rows, ir], axis=1)
    if is64:
        ops = jax.lax.sort((merged.hi, merged.lo, mrows), num_keys=2,
                           is_stable=True, dimension=1)
        merged, mrows = KeyArray(ops[1], ops[0]), ops[2]
    else:
        ops = jax.lax.sort((merged.lo, mrows), num_keys=1, is_stable=True,
                           dimension=1)
        merged, mrows = KeyArray(ops[0], None), ops[1]
    counts = jnp.sum(keep, axis=1) + jnp.sum(ivalid, axis=1)       # (T,)

    # ---- chain layout: reuse rep node + old linked nodes, then alloc ----
    # Real buckets keep >= 1 node (the rep-region head survives even when
    # emptied); shape-padding rows (no valid chain) need none.
    have_nodes = jnp.sum(cv, axis=1)
    need_nodes = jnp.where(have_nodes > 0,
                           jnp.maximum(-(-counts // fill_target), 1), 0)
    extra = jnp.maximum(need_nodes - have_nodes, 0)
    extra_np = np.asarray(extra)
    alloc_off = np.concatenate([[0], np.cumsum(extra_np)[:-1]]).astype(np.int32)
    total_new = int(extra_np.sum())
    new_max_chain = int(np.asarray(need_nodes).max())
    mc2 = max(store.max_chain, new_max_chain)

    if store.free_ptr + total_new > store.capacity:
        store = _grow(store, store.free_ptr + total_new)

    # chain2[t, j] = j-th node of bucket t's new chain.
    j_idx = jnp.arange(mc2)
    old_part = jnp.pad(chains_j, ((0, 0), (0, mc2 - store.max_chain)),
                       constant_values=-1)
    new_ids = store.free_ptr + jnp.asarray(alloc_off)[:, None] + (j_idx - have_nodes[:, None])
    chain2 = jnp.where(j_idx < have_nodes[:, None], old_part,
                       jnp.where(j_idx < need_nodes[:, None], new_ids, -1))
    chain2 = chain2.astype(jnp.int32)

    # Distribute merged keys: node j of bucket t gets merged[t, j*F:(j+1)*F]
    # (F = fill_target), except full-pack tails; sizes + maxKey follow.
    F = fill_target
    take_pos = j_idx[:, None] * F + jnp.arange(N)                  # (mc2, N)
    valid_pos = (jnp.arange(N) < F) & (take_pos < L)
    tp = jnp.minimum(take_pos, L - 1)
    tp_full = jnp.broadcast_to(tp.reshape(1, mc2 * N), (T, mc2 * N))
    nk_lo = jnp.take_along_axis(merged.lo, tp_full, axis=1)
    nk_hi = jnp.take_along_axis(merged.hi, tp_full, axis=1) if is64 else None
    nr = jnp.take_along_axis(mrows, tp_full, axis=1)
    in_count = (take_pos.reshape(-1)[None] < counts[:, None]) & valid_pos.reshape(-1)[None]
    sentinel32 = jnp.uint32(0xFFFFFFFF)
    nk_lo = jnp.where(in_count, nk_lo, sentinel32)
    if is64:
        nk_hi = jnp.where(in_count, nk_hi, sentinel32)
    nr = jnp.where(in_count, nr, -1)

    nk_lo = nk_lo.reshape(T, mc2, N)
    nk_hi = nk_hi.reshape(T, mc2, N) if is64 else None
    nr = nr.reshape(T, mc2, N)
    node_counts = jnp.clip(counts[:, None] - j_idx[None, :] * F, 0, F)  # (T, mc2)

    # maxKey: largest real key in the node; the chain's last occupied node
    # keeps the bucket representative as maxKey so walks terminate exactly
    # like the paper's (rep is an upper bound of the bucket by construction
    # — except the LAST bucket, which absorbs > maxRep inserts; its tail
    # node's maxKey is its true max key, and the walk's "next exists" guard
    # handles it).
    last_slot = jnp.maximum(node_counts - 1, 0)
    mk_lo = jnp.take_along_axis(nk_lo, last_slot[..., None], axis=2)[..., 0]
    mk_hi = (jnp.take_along_axis(nk_hi, last_slot[..., None], axis=2)[..., 0]
             if is64 else None)

    # ---- scatter back ----
    valid_nodes = chain2 >= 0
    ids = jnp.where(valid_nodes, chain2, store.capacity - 1)  # dummy, masked below
    flat_ids = ids.reshape(-1)
    m = valid_nodes.reshape(-1)

    def scat(dst, upd):
        return dst.at[flat_ids].set(jnp.where(m[:, None] if upd.ndim == 2 else m,
                                              upd, dst[flat_ids]))

    store_nk_lo = scat(store.node_keys.lo, nk_lo.reshape(-1, N))
    store_nk_hi = (scat(store.node_keys.hi, nk_hi.reshape(-1, N)) if is64 else None)
    store_nr = scat(store.node_rows, nr.reshape(-1, N))
    store_sz = scat(store.node_size, node_counts.reshape(-1))
    store_mk_lo = scat(store.node_maxkey.lo, mk_lo.reshape(-1))
    store_mk_hi = (scat(store.node_maxkey.hi, mk_hi.reshape(-1)) if is64 else None)

    nxt = jnp.where(j_idx[None, :] + 1 < need_nodes[:, None],
                    jnp.roll(chain2, -1, axis=1), NO_NODE).astype(jnp.int32)
    store_nx = scat(store.node_next, nxt.reshape(-1))

    # Shape-padding rows scatter to index nb (out of bounds -> dropped).
    t_idx = jnp.asarray(np.where(touched >= 0, touched, nb))
    bcount = store.bucket_count.at[t_idx].set(
        counts.astype(jnp.int32), mode="drop")

    return dataclasses.replace(
        store,
        node_keys=KeyArray(store_nk_lo, store_nk_hi),
        node_rows=store_nr, node_next=store_nx, node_size=store_sz,
        node_maxkey=KeyArray(store_mk_lo, store_mk_hi),
        bucket_count=bcount,
        free_ptr=store.free_ptr + total_new,
        max_chain=mc2)


def _grow(store: NodeStore, needed: int) -> NodeStore:
    """Enlarge the linked-node region (paper: 'once this region has been
    entirely used, we enlarge it by allocating additional memory')."""
    new_cap = max(needed, int(store.capacity * 1.5) + 1)
    add = new_cap - store.capacity
    N = store.node_cap
    pad_keys = key_max_sentinel(store.node_keys, (add, N))
    nk = concat_keys(store.node_keys.reshape(-1), pad_keys.reshape(-1)).reshape(new_cap, N)
    nr = jnp.concatenate([store.node_rows, jnp.full((add, N), -1, jnp.int32)])
    nx = jnp.concatenate([store.node_next, jnp.full((add,), NO_NODE, jnp.int32)])
    sz = jnp.concatenate([store.node_size, jnp.zeros((add,), jnp.int32)])
    mk = concat_keys(store.node_maxkey, key_max_sentinel(store.node_maxkey, (add,)))
    return dataclasses.replace(store, node_keys=nk, node_rows=nr, node_next=nx,
                               node_size=sz, node_maxkey=mk, capacity=new_cap)


# ---------------------------------------------------------------------------
# Full rebuild (paper's baseline for Fig. 15): extract + bulk-load.
# ---------------------------------------------------------------------------

def live_count(store: NodeStore) -> jnp.ndarray:
    """Device scalar: number of live keys across all chains."""
    return jnp.sum(store.bucket_count)


def extract(store: NodeStore) -> Tuple[KeyArray, jnp.ndarray, int]:
    """All live key/rowID pairs, sorted, plus the live count."""
    flat_keys = store.node_keys.reshape(-1)
    flat_rows = store.node_rows.reshape(-1)
    slot = jnp.arange(store.capacity * store.node_cap) % store.node_cap
    owner = jnp.arange(store.capacity * store.node_cap) // store.node_cap
    live = slot < store.node_size[owner]
    keys = key_where(live, flat_keys, key_max_sentinel(flat_keys, flat_keys.shape))
    rows = jnp.where(live, flat_rows, -1)
    skeys, srows, slive = sort_with_payload(keys, rows, live.astype(jnp.int32))
    n_live = int(jnp.sum(live))
    return skeys, srows, n_live


def rebuild(store: NodeStore) -> NodeStore:
    skeys, srows, n_live = extract(store)
    return build(skeys[:n_live], srows[:n_live], store.node_cap,
                 presorted=True)
