"""Baseline GPU-resident indexes re-implemented in JAX (paper Sec. 6 setup).

The paper compares cgRX against:
  HT — open-addressing hash table with cooperative probing (WarpCore),
       target load factor 0.8; point lookups only.
  B+ — GPU B+-tree with 16-wide nodes; 32-bit keys in the paper's build,
       ours supports both widths.
  SA — sorted array + binary search (CUB radix sort).
  RX — the fine-granular predecessor: every key is its own triangle.

TPU adaptations: HT probing is vectorized (a probe window of W slots per
step = one VPU compare, the analogue of a cooperative warp probe); the
B+-tree is the fanout tree with F=16 bulk-loaded over *all* keys (a static
array-based B+-tree — the honest stand-in for Awad et al.'s pointer-based
tree); RX reuses the successor machinery with bucket_size=1 semantics and
is footprint-accounted with the paper's 9-float-per-key triangle model.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import fanout
from .keys import (
    KeyArray,
    key_eq,
    key_le,
    key_lt,
    key_max_sentinel,
    key_where,
    searchsorted,
    sort_with_payload,
)

MISS = jnp.int32(-1)


class PointResult(NamedTuple):
    row_id: jnp.ndarray
    found: jnp.ndarray


# ---------------------------------------------------------------------------
# SA — sorted array + binary search.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SortedArray:
    keys: KeyArray
    row_ids: jnp.ndarray
    n: int

    @property
    def nbytes(self) -> int:
        return self.keys.nbytes + self.row_ids.nbytes


def sa_build(keys: KeyArray, row_ids: Optional[jnp.ndarray]) -> SortedArray:
    n = keys.shape[0]
    if row_ids is None:
        row_ids = jnp.arange(n, dtype=jnp.int32)
    skeys, srows = sort_with_payload(keys, row_ids.astype(jnp.int32))
    return SortedArray(keys=skeys, row_ids=srows, n=n)


def sa_lookup(sa: SortedArray, queries: KeyArray) -> PointResult:
    pos = searchsorted(sa.keys, queries, side="left")
    safe = jnp.minimum(pos, sa.n - 1)
    found = (pos < sa.n) & key_eq(sa.keys.take(safe), queries)
    return PointResult(jnp.where(found, sa.row_ids[safe], MISS), found)


def sa_range(sa: SortedArray, lo: KeyArray, hi: KeyArray, max_hits: int):
    start = searchsorted(sa.keys, lo, side="left")
    end = searchsorted(sa.keys, hi, side="right")
    count = jnp.maximum(end - start, 0)
    offs = start[..., None] + jnp.arange(max_hits, dtype=jnp.int32)
    valid = jnp.arange(max_hits, dtype=jnp.int32) < count[..., None]
    rows = jnp.where(valid, jnp.take(sa.row_ids, jnp.minimum(offs, sa.n - 1),
                                     mode="clip"), MISS)
    return count.astype(jnp.int32), rows


# ---------------------------------------------------------------------------
# HT — open addressing, linear probing, load factor 0.8.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HashTable:
    slot_lo: jnp.ndarray    # (C,) uint32 key low bits; EMPTY = all-ones
    slot_hi: Optional[jnp.ndarray]
    slot_row: jnp.ndarray   # (C,) int32
    slot_used: jnp.ndarray  # (C,) bool
    capacity: int
    max_probe: int          # host-recorded worst probe distance
    probe_window: int

    @property
    def nbytes(self) -> int:
        b = self.slot_lo.nbytes + self.slot_row.nbytes + self.slot_used.nbytes
        if self.slot_hi is not None:
            b += self.slot_hi.nbytes
        return b


def _hash(keys: KeyArray, mask: int) -> jnp.ndarray:
    """Murmur-style finalizer over (hi, lo)."""
    h = keys.lo
    if keys.is64:
        h = h ^ (keys.hi * jnp.uint32(0x9E3779B1))
    h ^= h >> 16
    h = h * jnp.uint32(0x85EBCA6B)
    h ^= h >> 13
    h = h * jnp.uint32(0xC2B2AE35)
    h ^= h >> 16
    return (h & jnp.uint32(mask)).astype(jnp.int32)


def ht_build(keys: KeyArray, row_ids: Optional[jnp.ndarray],
             load_factor: float = 0.8, probe_window: int = 8,
             max_rounds: int = 512) -> HashTable:
    n = keys.shape[0]
    if row_ids is None:
        row_ids = jnp.arange(n, dtype=jnp.int32)
    cap = 1 << int(np.ceil(np.log2(max(n / load_factor, 16))))
    mask = cap - 1

    used = jnp.zeros((cap,), bool)
    slot_lo = jnp.full((cap,), 0xFFFFFFFF, jnp.uint32)
    slot_hi = jnp.full((cap,), 0xFFFFFFFF, jnp.uint32) if keys.is64 else None
    slot_row = jnp.full((cap,), MISS, jnp.int32)

    h0 = _hash(keys, mask)
    placed = jnp.zeros((n,), bool)
    order = jnp.arange(n, dtype=jnp.int32)

    max_probe = 0
    for r in range(max_rounds):
        cand = (h0 + r) & mask
        # Claim: lowest batch index wins an empty slot this round.
        claim = jnp.full((cap,), n, jnp.int32)
        claim = claim.at[cand].min(jnp.where(placed, n, order))
        win = (~placed) & (claim[cand] == order) & (~used[cand])
        used = used.at[jnp.where(win, cand, cap)].set(True, mode="drop")
        slot_lo = slot_lo.at[jnp.where(win, cand, cap)].set(keys.lo, mode="drop")
        if keys.is64:
            slot_hi = slot_hi.at[jnp.where(win, cand, cap)].set(keys.hi, mode="drop")
        slot_row = slot_row.at[jnp.where(win, cand, cap)].set(
            row_ids.astype(jnp.int32), mode="drop")
        placed = placed | win
        max_probe = r + 1
        if bool(placed.all()):
            break
    assert bool(placed.all()), "hash table build did not converge"
    return HashTable(slot_lo=slot_lo, slot_hi=slot_hi, slot_row=slot_row,
                     slot_used=used, capacity=cap, max_probe=max_probe,
                     probe_window=probe_window)


def ht_lookup(ht: HashTable, queries: KeyArray) -> PointResult:
    mask = ht.capacity - 1
    h0 = _hash(queries, mask)
    W = ht.probe_window
    n_steps = -(-ht.max_probe // W)

    def step(i, state):
        found, row, done = state
        offs = (h0[..., None] + i * W + jnp.arange(W, dtype=jnp.int32)) & mask
        lo = ht.slot_lo[offs]
        eq = lo == queries.lo[..., None]
        if ht.slot_hi is not None:
            eq &= ht.slot_hi[offs] == queries.hi[..., None]
        eq &= ht.slot_used[offs]
        hit = jnp.any(eq, axis=-1)
        first = jnp.argmax(eq, axis=-1)
        rows = jnp.take_along_axis(ht.slot_row[offs], first[..., None], -1)[..., 0]
        # Early-out semantics: an empty slot in the window before a hit
        # terminates the probe (standard linear-probing miss detection).
        any_empty = jnp.any(~ht.slot_used[offs], axis=-1)
        found = jnp.where(done, found, hit)
        row = jnp.where(done | ~hit, row, rows)
        done = done | hit | any_empty
        return found, row, done

    found = jnp.zeros(queries.shape, bool)
    row = jnp.full(queries.shape, MISS, jnp.int32)
    done = jnp.zeros(queries.shape, bool)
    found, row, done = jax.lax.fori_loop(0, n_steps, step, (found, row, done))
    return PointResult(jnp.where(found, row, MISS), found)


# ---------------------------------------------------------------------------
# B+ — bulk-loaded 16-wide static tree over all keys.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BPlusTree:
    tree: fanout.FanoutTree
    keys: KeyArray          # sorted leaf level (the tree's own leaf = keys)
    row_ids: jnp.ndarray
    n: int

    @property
    def nbytes(self) -> int:
        return self.tree.nbytes + self.keys.nbytes + self.row_ids.nbytes


def bp_build(keys: KeyArray, row_ids: Optional[jnp.ndarray],
             fanout_width: int = 16) -> BPlusTree:
    n = keys.shape[0]
    if row_ids is None:
        row_ids = jnp.arange(n, dtype=jnp.int32)
    skeys, srows = sort_with_payload(keys, row_ids.astype(jnp.int32))
    tree = fanout.build_tree(skeys, fanout=fanout_width)
    return BPlusTree(tree=tree, keys=skeys, row_ids=srows, n=n)


def bp_lookup(bp: BPlusTree, queries: KeyArray) -> PointResult:
    pos = fanout.descend(bp.tree, queries, side="left")
    safe = jnp.minimum(pos, bp.n - 1)
    found = (pos < bp.n) & key_eq(bp.keys.take(safe), queries)
    return PointResult(jnp.where(found, bp.row_ids[safe], MISS), found)


def bp_range(bp: BPlusTree, lo: KeyArray, hi: KeyArray, max_hits: int):
    start = fanout.descend(bp.tree, lo, side="left")
    end = fanout.descend(bp.tree, hi, side="right")
    count = jnp.maximum(end - start, 0)
    offs = start[..., None] + jnp.arange(max_hits, dtype=jnp.int32)
    valid = jnp.arange(max_hits, dtype=jnp.int32) < count[..., None]
    rows = jnp.where(valid, jnp.take(bp.row_ids, jnp.minimum(offs, bp.n - 1),
                                     mode="clip"), MISS)
    return count.astype(jnp.int32), rows


# ---------------------------------------------------------------------------
# RX — fine-granular predecessor (every key its own triangle).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RxIndex:
    """RX emulation: the BVH over *all* key-triangles is a fanout tree over
    all keys; rowID = primitive index = position in the (unsorted!) vertex
    buffer.  We keep the paper's memory model: 9 f32 per key, no separate
    key/rowID array (the triangle position encodes the key; the primitive
    index encodes the rowID)."""

    tree: fanout.FanoutTree
    keys: KeyArray           # sorted
    prim: jnp.ndarray        # rowID of each sorted key (primitive index)
    n: int

    def nbytes_model(self, bvh_bytes_per_tri: float = 64.0) -> dict:
        return {
            "vertex_buffer_bytes": 36 * self.n,
            "bvh_bytes": int(bvh_bytes_per_tri * self.n),
        }


def rx_build(keys: KeyArray, row_ids: Optional[jnp.ndarray]) -> RxIndex:
    n = keys.shape[0]
    if row_ids is None:
        row_ids = jnp.arange(n, dtype=jnp.int32)
    skeys, sprim = sort_with_payload(keys, row_ids.astype(jnp.int32))
    tree = fanout.build_tree(skeys, fanout=128)
    return RxIndex(tree=tree, keys=skeys, prim=sprim, n=n)


def rx_lookup(rx: RxIndex, queries: KeyArray) -> PointResult:
    pos = fanout.descend(rx.tree, queries, side="left")
    safe = jnp.minimum(pos, rx.n - 1)
    found = (pos < rx.n) & key_eq(rx.keys.take(safe), queries)
    return PointResult(jnp.where(found, rx.prim[safe], MISS), found)


def rx_range(rx: RxIndex, lo: KeyArray, hi: KeyArray, max_hits: int):
    """RX range lookup: the ray must intersection-test every candidate
    triangle between the bounds (paper Sec. 2.2) — each hit is a separate
    closest-hit traversal, i.e. one successor probe *per hit*, which is why
    RX loses to cgRX on ranges.  We reproduce that cost shape: max_hits
    successive probes, each re-descending the tree."""
    start = fanout.descend(rx.tree, lo, side="left")
    count_ub = fanout.descend(rx.tree, hi, side="right") - start
    count = jnp.maximum(count_ub, 0)

    def probe(i, acc):
        rows = acc
        offs = start + i
        safe = jnp.minimum(offs, rx.n - 1)
        # Re-descend per hit: emulate the repeated BVH traversals by an
        # actual (redundant) tree descent of the hit key.
        k = rx.keys.take(safe)
        _ = fanout.descend(rx.tree, k, side="left")
        valid = i < count
        rows = rows.at[..., i].set(jnp.where(valid, rx.prim[safe], MISS))
        return rows

    rows = jnp.full(queries_shape(lo) + (max_hits,), MISS, jnp.int32)
    rows = jax.lax.fori_loop(0, max_hits, probe, rows)
    return count.astype(jnp.int32), rows


def queries_shape(k: KeyArray):
    return k.shape
