"""Memory-footprint accounting (paper Figs. 1a, 10a, 11a/c).

Two accountings are reported side by side:

  * ``actual``  — bytes of the JAX device buffers this implementation holds
                  (what a TPU deployment would pay);
  * ``paper``   — the paper's GPU memory model for triangle-based structures
                  (36 B per triangle slot = 9 f32, plus a BVH overhead per
                  materialized triangle; default 64 B/tri, calibrated so that
                  RX's 2^26-key footprint lands in the paper's 2.2-2.6 GiB
                  band), so that Fig. 11-style comparisons are reproducible.

Throughput-per-byte ("bang for the buck", Fig. 11c) divides lookups/s by
the *permanent* footprint, exactly as Sec. 6.1 does.
"""
from __future__ import annotations

from typing import Union

from . import baselines, cgrx, grid, nodes

BVH_BYTES_PER_TRI = 64.0


def footprint(obj, paper_model: bool = False) -> dict:
    """Bytes held by an index structure, as {component: bytes, total_bytes}."""
    if isinstance(obj, cgrx.CgrxIndex):
        out = cgrx.index_nbytes(obj)
        if paper_model:
            # Paper accounting for the accelerated part: reps are triangles.
            tri = obj.num_buckets
            out = {
                "key_rowid_bytes": out["key_rowid_bytes"],
                "vertex_buffer_bytes": 36 * tri,
                "bvh_bytes": int(BVH_BYTES_PER_TRI * tri),
            }
            out["total_bytes"] = sum(out.values())
        return out
    if isinstance(obj, grid.GridScene):
        out = obj.nbytes_model(BVH_BYTES_PER_TRI)
        out["total_bytes"] = sum(out.values())
        return out
    if isinstance(obj, nodes.NodeStore):
        return obj.nbytes
    if isinstance(obj, baselines.SortedArray):
        return {"total_bytes": obj.nbytes, "key_rowid_bytes": obj.nbytes}
    if isinstance(obj, baselines.HashTable):
        return {"total_bytes": obj.nbytes, "table_bytes": obj.nbytes}
    if isinstance(obj, baselines.BPlusTree):
        return {
            "total_bytes": obj.nbytes,
            "key_rowid_bytes": obj.keys.nbytes + obj.row_ids.nbytes,
            "tree_bytes": obj.tree.nbytes,
        }
    if isinstance(obj, baselines.RxIndex):
        out = obj.nbytes_model(BVH_BYTES_PER_TRI)
        out["total_bytes"] = sum(out.values())
        return out
    raise TypeError(f"no footprint accounting for {type(obj)}")


def bang_for_buck(lookups_per_s: float, obj) -> float:
    """Paper Fig. 11c metric: throughput divided by footprint in bytes."""
    return lookups_per_s / max(footprint(obj)["total_bytes"], 1)
