"""Lane-width fanout tree: the TPU-native analogue of the paper's BVH.

The BVH over representative triangles is, on sorted 1-D data, exactly a
bulk-loaded static search tree whose traversal the RT cores accelerate.  On
TPU the fastest fixed-function "node visit" is a full-lane vector compare:
one (8x128)-shaped VPU op tests a query against up to 128 splitters at once.
So the BVH becomes a k-ary tree with fanout = 128 whose every level is a
dense sorted array; a descent step is

    child = count(splitters_of_node < q)          (left / lower-bound)

which is a masked vector sum — no branching, no pointer chasing.  Depth is
ceil(log_128(num_buckets)): 2^26 keys at bucket size 16 -> 4M buckets -> a
3-level tree, i.e. three vector compares per lookup vs ~22 serial steps for
a binary search.

Levels are padded to a multiple of ``fanout`` with MAX sentinels so every
node's child segment is a static-size slice.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from .keys import KeyArray, concat_keys, key_le, key_lt, key_max_sentinel


@dataclasses.dataclass
class FanoutTree:
    """Static k-ary successor-search tree built on the sorted rep array.

    ``levels[0]`` is the root level (<= fanout entries); ``levels[-1]`` is
    the (padded) rep array itself.  Each level entry is the max key of the
    subtree below it, so descent-left lands on the successor bucket.
    """

    levels: List[KeyArray]
    fanout: int
    num_leaves: int  # true number of reps (pre-padding)

    @property
    def depth(self) -> int:
        return len(self.levels)

    @property
    def nbytes(self) -> int:
        # Internal levels only: the leaf level *is* the rep array, which the
        # index already accounts for (paper: BVH size excl. triangles).
        return sum(l.nbytes for l in self.levels[:-1])


# Registered as a pytree so an index carrying a tree can be passed as a
# jit ARGUMENT (the live store re-binds buffers every update batch; see
# query/engine.py's shared executable cache) instead of closure-captured.
jax.tree_util.register_pytree_node(
    FanoutTree,
    lambda t: (tuple(t.levels), (t.fanout, t.num_leaves)),
    lambda aux, ch: FanoutTree(levels=list(ch), fanout=aux[0],
                               num_leaves=aux[1]),
)


def _pad_to_multiple(keys: KeyArray, multiple: int) -> KeyArray:
    n = keys.shape[0]
    pad = (-n) % multiple
    if pad:
        keys = concat_keys(keys, key_max_sentinel(keys, (pad,)))
    return keys


def build_tree(reps: KeyArray, fanout: int = 128) -> FanoutTree:
    """O(n) deterministic bulk load from the sorted representative array."""
    num_leaves = reps.shape[0]
    levels = [_pad_to_multiple(reps, fanout)]
    while levels[0].shape[0] > fanout:
        cur = levels[0]
        # Parent splitter = max of each fanout-group = its last element.
        groups = cur.reshape(cur.shape[0] // fanout, fanout)
        parents = groups[:, fanout - 1]
        levels.insert(0, _pad_to_multiple(parents, fanout))
    return FanoutTree(levels=levels, fanout=fanout, num_leaves=num_leaves)


def descend(tree: FanoutTree, queries: KeyArray, side: str = "left") -> jnp.ndarray:
    """Find, per query, the searchsorted index into the rep array.

    side='left':  count of reps <  q  (first bucket whose rep >= q)
    side='right': count of reps <= q
    Result is clamped to [0, num_leaves] (padded sentinels never match).
    """
    cmp = key_le if side == "right" else key_lt  # splitter < q (left) / <= q (right)

    idx = jnp.zeros(queries.shape, dtype=jnp.int32)
    for level in tree.levels:
        f = tree.fanout if level.shape[0] > tree.fanout else level.shape[0]
        offs = idx[..., None] * f + jnp.arange(f, dtype=jnp.int32)
        seg = level.take(offs)
        qb = KeyArray(
            queries.lo[..., None],
            None if queries.hi is None else queries.hi[..., None],
        )
        below = cmp(seg, qb)
        count = jnp.sum(below.astype(jnp.int32), axis=-1)
        idx = idx * f + count
    return jnp.minimum(idx, tree.num_leaves)
