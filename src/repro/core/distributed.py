"""Mesh-sharded cgRX: range-partitioned coarse-granular index.

Scaling the paper's single-GPU index to a pod: the sorted key space is
range-partitioned into ``S`` contiguous shards along the mesh's *model*
axis (each shard holds its own reps + buckets — a complete local cgRX),
while query batches are data-parallel along the *data*/*pod* axes.

A point lookup is then:
  1. local successor search on every model shard (no communication);
  2. exactly one shard owns the query's range -> combine the masked
     (found, rowID) pairs with one ``psum`` over the model axis.

This keeps the collective cost at one small all-reduce per batch
(O(queries_per_device * 8 bytes)), independent of index size — the same
"the accelerated structure never moves" philosophy the paper applies to
updates.  Shard ownership is decided by per-shard max-key splitters, which
are just the last representatives — no extra structure.

Two serving modes share the splitter math below:

* **static read-only mode** (this module): the mesh-mapped ``ShardedIndex``
  — immutable stacked per-shard cgRX state, lookups/range counts as
  ``shard_map`` collectives.  Fastest when the key set doesn't change.
* **live mode** (``repro.store.sharded.ShardedLiveStore``): one epoch-
  versioned ``LiveIndex`` per shard, routed updates, cross-shard range
  decomposition and per-shard compaction.  It imports ``route_keys`` /
  ``route_ranges`` / ``compute_splitters`` from here, so both tiers agree
  on ownership by construction.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.sharding import shard_map

from . import cgrx
from .keys import KeyArray, key_eq, key_le, searchsorted, sort_with_payload


@dataclasses.dataclass
class ShardedIndex:
    """Stacked per-shard cgRX state (leading axis = shard)."""

    # (S, n_shard) sorted keys + rowids, (S, nb_shard) reps.
    keys: KeyArray
    row_ids: jnp.ndarray
    reps: KeyArray
    splitters: KeyArray          # (S,) per-shard max key, replicated
    bucket_size: int
    n_per_shard: int
    num_shards: int
    mesh: Optional[Mesh] = None
    shard_axis: str = "model"

    @property
    def num_buckets_per_shard(self) -> int:
        return self.reps.shape[1]


def build_sharded(keys: KeyArray, row_ids: Optional[jnp.ndarray],
                  bucket_size: int, num_shards: int,
                  mesh: Optional[Mesh] = None,
                  shard_axis: str = "model") -> ShardedIndex:
    """Global sort, then contiguous range partition into equal shards."""
    n = keys.shape[0]
    if row_ids is None:
        row_ids = jnp.arange(n, dtype=jnp.int32)
    skeys, srows = sort_with_payload(keys, row_ids.astype(jnp.int32))

    per = -(-n // num_shards)
    per = -(-per // bucket_size) * bucket_size  # round up to bucket multiple
    padded = per * num_shards
    pad = padded - n
    if pad:
        from .keys import concat_keys, key_max_sentinel

        skeys = concat_keys(skeys, key_max_sentinel(skeys, (pad,)))
        srows = jnp.concatenate([srows, jnp.full((pad,), -1, jnp.int32)])

    keys2 = skeys.reshape(num_shards, per)
    rows2 = srows.reshape(num_shards, per)
    nb = per // bucket_size
    reps = keys2.reshape(num_shards, nb, bucket_size)[:, :, bucket_size - 1]
    splitters = reps[:, nb - 1]  # (S,) per-shard max
    return ShardedIndex(keys=keys2, row_ids=rows2, reps=reps,
                        splitters=splitters, bucket_size=bucket_size,
                        n_per_shard=per, num_shards=num_shards,
                        mesh=mesh, shard_axis=shard_axis)


def _local_lookup(keys: KeyArray, rows: jnp.ndarray, reps: KeyArray,
                  bucket_size: int, queries: KeyArray):
    """Single-shard rank+probe (same math as cgrx.rank on local arrays)."""
    from .keys import key_lt

    nb = reps.shape[0]
    n = keys.shape[0]
    b = searchsorted(reps, queries, side="left")
    offs = (jnp.minimum(b, nb - 1)[..., None] * bucket_size
            + jnp.arange(bucket_size, dtype=jnp.int32))
    seg = keys.take(offs)
    qb = KeyArray(queries.lo[..., None],
                  None if queries.hi is None else queries.hi[..., None])
    inb = jnp.sum(key_lt(seg, qb).astype(jnp.int32), axis=-1)
    pos = jnp.minimum(b * bucket_size + inb, n - 1)
    found = (b < nb) & key_eq(keys.take(pos), queries)
    rowid = jnp.where(found, rows[pos], 0)
    return found, rowid


def sharded_lookup(idx: ShardedIndex, queries: KeyArray,
                   data_axis: Tuple[str, ...] = ("data",)) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Distributed point lookup under shard_map.

    queries: (Q,) sharded over the data axes; index sharded over model.
    Returns (found, row_id) with row_id = -1 on miss.
    """
    mesh = idx.mesh
    assert mesh is not None, "build_sharded(..., mesh=...) required"
    ax = idx.shard_axis

    def local(keys_lo, keys_hi, rows, reps_lo, reps_hi, q_lo, q_hi):
        keys = KeyArray(keys_lo[0], None if keys_hi is None else keys_hi[0])
        reps = KeyArray(reps_lo[0], None if reps_hi is None else reps_hi[0])
        q = KeyArray(q_lo, None if q_hi is None else q_hi)
        found, rowid = _local_lookup(keys, rows[0], reps, idx.bucket_size, q)
        # Exactly one shard can own a key; rank-0-style combine:
        f = jax.lax.psum(found.astype(jnp.int32), ax)
        r = jax.lax.psum(jnp.where(found, rowid + 1, 0), ax)
        return f > 0, jnp.where(f > 0, r - 1, -1)

    spec_idx = P(ax)           # shard-stacked arrays: leading dim over model
    spec_q = P(data_axis)      # queries over data axes
    spec_out = P(data_axis)

    is64 = idx.keys.is64
    args = [idx.keys.lo, idx.keys.hi, idx.row_ids, idx.reps.lo, idx.reps.hi,
            queries.lo, queries.hi]
    in_specs = (spec_idx, spec_idx if is64 else None, spec_idx,
                spec_idx, spec_idx if is64 else None,
                spec_q, spec_q if is64 else None)
    # shard_map can't take None args; filter them.
    live = [(a, s) for a, s in zip(args, in_specs) if a is not None]
    arrs, specs = zip(*live)

    def wrapper(*live_args):
        it = iter(live_args)
        full = [next(it) if a is not None else None for a in args]
        return local(*full)

    fn = shard_map(wrapper, mesh=mesh, in_specs=tuple(specs),
                       out_specs=(spec_out, spec_out), check_vma=False)
    return fn(*arrs)


# ---------------------------------------------------------------------------
# Splitter math — the routing layer shared by the static mesh path above and
# the live sharded store (repro.store.sharded).  A "splitter" is the max key
# a shard owns; shard s owns the half-open key interval
# (splitters[s-1], splitters[s]], and the LAST shard additionally absorbs
# everything beyond the last splitter (mirroring how a cgRX/NodeStore last
# bucket absorbs > maxRep inserts under an immutable search structure).
# ---------------------------------------------------------------------------

def route_keys(splitters: KeyArray, keys: KeyArray) -> jnp.ndarray:
    """Owning shard of each key: successor search over per-shard max-key
    splitters (keys beyond the last splitter go to the last shard)."""
    num_shards = splitters.shape[0]
    s = searchsorted(splitters, keys, side="left")
    return jnp.minimum(s, num_shards - 1).astype(jnp.int32)


def route_ranges(splitters: KeyArray, lo: KeyArray,
                 hi: KeyArray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(first, last) owning shard of each range [lo, hi].

    Every shard in ``[first, last]`` intersects the range; the per-shard
    sub-range is just [lo, hi] evaluated shard-locally (a shard only ranks
    its own keys, so no bound clamping is needed — the decomposition at
    the splitters is implicit in ownership).
    """
    first = route_keys(splitters, lo)
    last = jnp.maximum(first, route_keys(splitters, hi))
    return first, last


def partition_cuts(n: int, num_shards: int) -> np.ndarray:
    """Equal-count partition offsets: ``num_shards + 1`` monotonically
    increasing cut positions with shard s owning ``[cuts[s], cuts[s+1])``.

    The ONE place the slice math lives: ``compute_splitters`` derives the
    splitters from these cuts and the live sharded store loads its shards
    from the same cuts, so splitters and shard contents cannot drift.
    """
    if n < num_shards:
        raise ValueError(f"cannot split {n} keys into {num_shards} shards")
    per = -(-n // num_shards)
    return np.minimum(np.arange(num_shards + 1, dtype=np.int64) * per, n)


def compute_splitters(sorted_keys: KeyArray, num_shards: int) -> KeyArray:
    """Equal-count splitters over an ascending key array.

    splitters[s] = last key of the s-th contiguous slice (the last
    splitter is the global max key).  Used at build time and by the skew
    monitor's rebalance.
    """
    cuts = partition_cuts(sorted_keys.shape[0], num_shards)
    return sorted_keys.take(jnp.asarray(np.maximum(cuts[1:] - 1, 0),
                                        dtype=jnp.int32))


def _local_rank(keys: KeyArray, reps: KeyArray, bucket_size: int,
                queries: KeyArray, side: str) -> jnp.ndarray:
    """Shard-local rank (#keys </<= q), the range-lookup primitive."""
    from .keys import key_le, key_lt

    nb = reps.shape[0]
    n = keys.shape[0]
    b = searchsorted(reps, queries, side=side)
    offs = (jnp.minimum(b, nb - 1)[..., None] * bucket_size
            + jnp.arange(bucket_size, dtype=jnp.int32))
    seg = keys.take(offs)
    qb = KeyArray(queries.lo[..., None],
                  None if queries.hi is None else queries.hi[..., None])
    cmp = key_le if side == "right" else key_lt
    inb = jnp.sum(cmp(seg, qb).astype(jnp.int32), axis=-1)
    return jnp.where(b >= nb, n, jnp.minimum(b * bucket_size + inb, n))


def sharded_range_count(idx: ShardedIndex, lo: KeyArray, hi: KeyArray,
                        data_axis: Tuple[str, ...] = ("data",)
                        ) -> jnp.ndarray:
    """Distributed range-lookup COUNT: |{keys in [lo, hi]}| per query.

    Each model shard computes its local (rank_right(hi) - rank_left(lo)),
    clipped to its own range; one psum combines — a range over the whole
    pod-sharded key space costs a single small all-reduce, preserving the
    paper's 'one successor search + scan' cost shape at cluster scale.
    Padded sentinel slots never count (they compare > every real key).
    """
    mesh = idx.mesh
    assert mesh is not None
    ax = idx.shard_axis
    is64 = idx.keys.is64

    def local(keys_lo, keys_hi, reps_lo, reps_hi, lo_lo, lo_hi, hi_lo, hi_hi):
        keys = KeyArray(keys_lo[0], None if keys_hi is None else keys_hi[0])
        reps = KeyArray(reps_lo[0], None if reps_hi is None else reps_hi[0])
        lo_k = KeyArray(lo_lo, None if lo_hi is None else lo_hi)
        hi_k = KeyArray(hi_lo, None if hi_hi is None else hi_hi)
        start = _local_rank(keys, reps, idx.bucket_size, lo_k, "left")
        end = _local_rank(keys, reps, idx.bucket_size, hi_k, "right")
        cnt = jnp.maximum(end - start, 0)
        return jax.lax.psum(cnt, ax)

    spec_idx = P(ax)
    spec_q = P(data_axis)
    args = [idx.keys.lo, idx.keys.hi, idx.reps.lo, idx.reps.hi,
            lo.lo, lo.hi, hi.lo, hi.hi]
    in_specs = (spec_idx, spec_idx if is64 else None,
                spec_idx, spec_idx if is64 else None,
                spec_q, spec_q if is64 else None,
                spec_q, spec_q if is64 else None)
    live = [(a, s) for a, s in zip(args, in_specs) if a is not None]
    arrs, specs = zip(*live)

    def wrapper(*live_args):
        it = iter(live_args)
        full = [next(it) if a is not None else None for a in args]
        return local(*full)

    fn = shard_map(wrapper, mesh=mesh, in_specs=tuple(specs),
                       out_specs=P(data_axis), check_vma=False)
    return fn(*arrs)
