"""repro.core — the paper's contribution: coarse-granular indexing on TPU.

Modules:
  keys        u32/u64-as-uint32-pairs key arithmetic (packed layout)
  keymap      key -> (x,y,z) bit-slice mappings (paper Sec. 2.1/5.2)
  bucketing   sort + bucket partition + representative extraction
  fanout      lane-width successor-search tree (the BVH analogue)
  cgrx        the coarse-granular index: build, point/range lookup
  grid        paper-faithful 3D scene + up-to-5-ray lookup emulation
  nodes       updatable node-chain variant (paper Sec. 4)
  baselines   HT / B+ / SA / RX re-implementations (paper Sec. 6)
  footprint   memory accounting, actual + paper model
  distributed range-partitioned mesh-sharded index (beyond paper)
"""
from . import baselines, bucketing, cgrx, distributed, fanout, footprint, grid, keymap, keys, nodes  # noqa: F401

__all__ = [
    "baselines", "bucketing", "cgrx", "distributed", "fanout", "footprint",
    "grid", "keymap", "keys", "nodes",
]
