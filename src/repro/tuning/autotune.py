"""Online autotuner: measured-cost steering of backend, bucket size and
shard placement.

``AutoTuner.tick()`` runs after every session flush (host-side, no
device work of its own) and closes three independent control loops, each
reading the telemetry bus and acting through machinery the serving stack
already trusts:

*Backend re-selection* — explore-then-commit over the flat successor-
search backends ('tree' | 'binary' | 'kernel').  Exploration order comes
from the roofline prior (``launch/roofline.py`` constants: estimated
bytes-per-probe over HBM bandwidth plus a per-launch overhead), so the
predicted-best candidate is measured first; each candidate then serves
real flushes while the session tags its query spans with the backend
name, and once every candidate has enough tagged samples the tuner
commits to the measured-fastest median.  Measurement beats prior by
construction — the prior only orders exploration.

*Bucket-size retuning* — the paper's core trade: bigger buckets shrink
the rep array (cheaper successor search) but lengthen the in-bucket
scan, so range/aggregate-heavy plans want bigger buckets and point-heavy
plans smaller ones.  The tuner reads the session's lane-mix counters off
the bus and proposes a doubling/halving, executed as the existing
compaction-style epoch swap (``tier.retune_bucket_size``) — reads never
see a half-built geometry, recovery replays onto the logical cut exactly
as for any compaction.

*Skew-triggered incremental migration* — on the sharded tier, when
either size imbalance (``ShardedStats.imbalance``) or touch-rate
imbalance (the bus's per-shard EWMA histogram — the axis size alone
cannot see) exceeds the spec's ``max_imbalance``, the tuner runs bounded
``store.migrate_step(max_keys)`` ticks: each moves at most ``max_keys``
keys between ADJACENT shards and nudges one splitter, instead of the
stop-and-rebuild ``extract -> presorted-build`` full rebalance.  Reads
stay bit-identical throughout because merged results depend only on the
live key multiset, never on which shard holds a key (the PR-6 recovery
invariant); migration does not touch the WAL for the same reason — the
multiset is unchanged, so replay-rebuilt stores answer identically.

Every action is appended to the bus event ring
(``bus.events("autotune")``), which is how tests pin convergence.
"""
from __future__ import annotations

import math
import time
from typing import List, Optional, Sequence

from repro.launch.roofline import HBM_BW, PEAK_FLOPS

from .telemetry import TelemetryBus

FLAT_BACKENDS = ("tree", "binary", "kernel")

# Per-launch fixed overhead (seconds) in the prior: dominated by dispatch
# + pipeline setup, not by the probe itself, on small batches.
LAUNCH_OVERHEAD = {"tree": 2e-5, "binary": 2e-5, "kernel": 6e-5}

MIN_BUCKET = 4
MAX_BUCKET = 256


def prior_cost(backend: str, num_buckets: int, batch: int = 256,
               key_bytes: int = 8) -> float:
    """Roofline-style prior seconds-per-batch for one rep search.

    'binary' probes log2(nb) scattered cache lines per query; 'tree'
    walks the implicit layout with ~half the effective traffic (top
    levels stay resident); 'kernel' streams rep tiles once per batch at
    HBM bandwidth and amortizes across lanes, paying a bigger launch
    overhead.  A PRIOR, not a model — it only orders exploration; the
    commit decision is measured.
    """
    nb = max(num_buckets, 2)
    depth = math.log2(nb)
    if backend == "binary":
        bytes_q = depth * 128.0          # one cache line per probe level
    elif backend == "tree":
        bytes_q = depth * 64.0           # resident top levels
    elif backend == "kernel":
        # Streams the rep array once per batch tile + O(1) flops/lane.
        bytes_q = (nb * key_bytes) / max(batch, 1)
    else:
        raise ValueError(f"unknown backend {backend!r}; expected one of "
                         f"{FLAT_BACKENDS}")
    t_mem = batch * bytes_q / HBM_BW
    t_flops = batch * depth * 8.0 / PEAK_FLOPS
    return LAUNCH_OVERHEAD[backend] + t_mem + t_flops


def prior_order(candidates: Sequence[str], num_buckets: int,
                batch: int = 256) -> List[str]:
    """Candidates ordered cheapest-first under the roofline prior."""
    return sorted(candidates,
                  key=lambda b: prior_cost(b, num_buckets, batch))


class AutoTuner:
    """Per-session background controller (see module doc).

    ``tier`` is duck-typed against the hooks db/tiers.py grew for this
    subsystem: ``current_backend`` / ``set_backend(name)`` /
    ``retune_bucket_size(b)`` / (sharded only) ``store.migrate_step``.
    The tuner never imports repro.db — it acts through the tier object
    the session hands it.
    """

    def __init__(self, tier, bus: TelemetryBus, *,
                 backends: Sequence[str] = FLAT_BACKENDS,
                 explore_flushes: int = 3,
                 interval: int = 1,
                 retune_buckets: bool = False,
                 bucket_cooldown: int = 8,
                 min_lanes: int = 256,
                 max_imbalance: Optional[float] = None,
                 rebalance_mode: str = "incremental",
                 migrate_max_keys: int = 256):
        self.tier = tier
        self.bus = bus
        self.explore_flushes = int(explore_flushes)
        self.interval = max(int(interval), 1)
        self.retune_buckets = retune_buckets
        self.bucket_cooldown = int(bucket_cooldown)
        self.min_lanes = int(min_lanes)
        self.max_imbalance = max_imbalance
        if rebalance_mode not in ("incremental", "full"):
            raise ValueError(
                f"rebalance_mode must be 'incremental' or 'full', got "
                f"{rebalance_mode!r}")
        self.rebalance_mode = rebalance_mode
        self.migrate_max_keys = int(migrate_max_keys)

        nb = self._num_buckets()
        self.candidates = prior_order(backends, nb)
        self.committed_backend: Optional[str] = None
        self._explore_idx: Optional[int] = None
        self._explore_left = 0
        self._ticks = 0
        self._last_retune = -bucket_cooldown
        self._lanes_at_retune = 0

    def _num_buckets(self) -> int:
        try:
            return max(int(self.tier.stats().num_buckets), 2)
        except Exception:
            return 2

    # -- the tick -------------------------------------------------------------

    def tick(self) -> None:
        """One control step; called by the session after each flush."""
        self._ticks += 1
        if self._ticks % self.interval:
            return
        if getattr(self.tier, "set_backend", None) is not None:
            self._tune_backend()
        if self.retune_buckets and \
                getattr(self.tier, "retune_bucket_size", None) is not None:
            self._tune_bucket()
        if self.max_imbalance is not None and \
                getattr(self.tier, "store", None) is not None:
            self._tune_placement()

    # -- loop 1: backend explore-then-commit ----------------------------------

    def _tune_backend(self) -> None:
        if self.committed_backend is not None:
            return
        if self._explore_idx is None:
            # Begin exploration at the prior's pick (often already the
            # serving backend — then its flushes count as exploration).
            self._explore_idx = 0
            self._explore_left = self.explore_flushes
            self._point_backend(self.candidates[0])
            return
        self._explore_left -= 1
        if self._explore_left > 0:
            return
        if self._explore_idx + 1 < len(self.candidates):
            self._explore_idx += 1
            self._explore_left = self.explore_flushes
            self._point_backend(self.candidates[self._explore_idx])
            return
        self._commit_backend()

    def _point_backend(self, name: str) -> None:
        if self.tier.current_backend != name:
            self.tier.set_backend(name)
            self.bus.event("autotune", action="explore_backend",
                           backend=name)

    def _commit_backend(self) -> None:
        """Pick the measured-fastest candidate by median tagged query
        latency; candidates with no samples lose to any measured one."""
        table = self.bus.by_tag("query")

        def measured(name: str) -> float:
            q = table.get(name)
            return q["p50"] if q and q["n"] else float("inf")

        best = min(self.candidates, key=measured)
        if measured(best) == float("inf"):
            # No read traffic at all during exploration: keep the
            # prior's pick, stay uncommitted is pointless — commit it.
            best = self.candidates[0]
        self.committed_backend = best
        if self.tier.current_backend != best:
            self.tier.set_backend(best)
        self.bus.event("autotune", action="commit_backend", backend=best,
                       measured_p50_ms={n: (None if measured(n) ==
                                            float("inf")
                                            else measured(n) * 1e3)
                                        for n in self.candidates})

    # -- loop 2: bucket-size retune -------------------------------------------

    def _tune_bucket(self) -> None:
        if self._ticks - self._last_retune < self.bucket_cooldown:
            return
        pts = self.bus.counter("lanes_point")
        rngs = self.bus.counter("lanes_range") + self.bus.counter("lanes_agg")
        new_lanes = (pts + rngs) - self._lanes_at_retune
        if new_lanes < self.min_lanes:
            return
        current = self.tier.bucket_size
        proposal = None
        if rngs > 4 * max(pts, 1) and current < MAX_BUCKET:
            proposal = current * 2      # range-heavy: cheaper rep stage
        elif pts > 4 * max(rngs, 1) and current > MIN_BUCKET:
            proposal = current // 2     # point-heavy: shorter scans
        if proposal is None:
            return
        self.tier.retune_bucket_size(proposal)   # epoch-swap inside
        self._last_retune = self._ticks
        self._lanes_at_retune = pts + rngs
        self.bus.event("autotune", action="retune_bucket",
                       bucket_size=proposal, previous=current,
                       lanes_point=pts, lanes_range=rngs)

    # -- loop 3: skew-triggered incremental migration -------------------------

    def _tune_placement(self) -> None:
        store = self.tier.store
        if store.compacting:
            return
        stats = store.stats()
        size_imb = stats.imbalance
        touch_imb = getattr(stats, "touch_imbalance", 0.0)
        if max(size_imb, touch_imb) <= self.max_imbalance:
            return
        # The action itself is timed onto the bus ("migrate" vs
        # "rebalance" spans): the scenario suite's pause comparison is
        # the controller's own cost — splitter nudge + bounded key moves
        # against extract -> full rebuild — not downstream jit effects.
        if self.rebalance_mode == "full":
            t0 = time.perf_counter()
            store.rebalance()
            self.bus.span("rebalance", time.perf_counter() - t0)
            self.bus.event("autotune", action="rebalance_full",
                           size_imbalance=size_imb,
                           touch_imbalance=touch_imb)
            return
        t0 = time.perf_counter()
        moved = store.migrate_step(self.migrate_max_keys)
        if moved:
            self.bus.span("migrate", time.perf_counter() - t0, n=moved)
            self.bus.event("autotune", action="migrate_step", moved=moved,
                           size_imbalance=size_imb,
                           touch_imbalance=touch_imb,
                           splitters=None)

    # -- introspection --------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-able tuner state (exported via Session.telemetry)."""
        exploring = (self.candidates[self._explore_idx]
                     if self._explore_idx is not None
                     and self.committed_backend is None else None)
        return {"candidates": list(self.candidates),
                "committed_backend": self.committed_backend,
                "exploring": exploring,
                "ticks": self._ticks,
                "rebalance_mode": self.rebalance_mode}
